"""L2 correctness: ST-DiT model pieces, parameter ABI, pallas/ref parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import configs, model

settings.register_profile("ci", deadline=None, max_examples=10)
settings.load_profile("ci")

CFG = configs.MODELS["opensora-sim"]
BUCKET = configs.BUCKETS["240p-2s"]


@pytest.fixture(scope="module")
def params():
    return model.init_params(CFG)


def _args(params, piece_key, spec_key):
    spec = model.piece_params(CFG)[spec_key]
    return [jnp.asarray(params[piece_key][n]) for n, _ in spec]


def _rand_state(seed):
    rng = np.random.default_rng(seed)
    return {
        "h": jnp.asarray(
            rng.normal(size=(BUCKET.frames, BUCKET.tokens, CFG.d_model)).astype(np.float32)
        ),
        "c": jnp.asarray(rng.normal(size=(CFG.d_model,)).astype(np.float32)),
        "tk": jnp.asarray(
            rng.normal(size=(CFG.text_len, CFG.d_model)).astype(np.float32)
        ),
        "tv": jnp.asarray(
            rng.normal(size=(CFG.text_len, CFG.d_model)).astype(np.float32)
        ),
        "x": jnp.asarray(
            rng.normal(size=(BUCKET.frames, BUCKET.tokens, CFG.latent_channels)).astype(
                np.float32
            )
        ),
        "raw": jnp.asarray(
            rng.normal(size=(CFG.text_len, CFG.d_text)).astype(np.float32)
        ),
    }


# ---------------------------------------------------------------------------
# parameter ABI
# ---------------------------------------------------------------------------


def test_init_params_match_declared_shapes(params):
    spec = model.piece_params(CFG)
    for piece in ("t_embed", "text_proj", "embed", "final"):
        for name, shape in spec[piece]:
            assert params[piece][name].shape == shape, f"{piece}.{name}"
    for i in range(CFG.layers):
        for kind in ("spatial", "temporal"):
            key = f"layer{i:02d}.{kind}"
            for name, shape in spec["spatial_block"]:
                assert params[key][name].shape == shape, f"{key}.{name}"
            for sub in ("sb_attn", "sb_cross", "sb_mlp", "text_k", "text_v"):
                for name, shape in spec[sub]:
                    assert params[key][name].shape == shape, f"{key}.{name} ({sub})"


def test_init_is_deterministic():
    a = model.init_params(CFG)
    b = model.init_params(CFG)
    np.testing.assert_array_equal(
        a["layer03.spatial"]["qkv_w"], b["layer03.spatial"]["qkv_w"]
    )


def test_gate_bias_ramps_with_depth(params):
    d = CFG.d_model
    g_first = params["layer00.spatial"]["adaln_b"][2 * d]
    g_last = params[f"layer{CFG.layers-1:02d}.spatial"]["adaln_b"][2 * d]
    assert g_first == pytest.approx(CFG.gate_lo)
    assert g_last == pytest.approx(CFG.gate_hi)
    assert g_first < g_last


def test_models_have_distinct_weights():
    a = model.init_params(configs.MODELS["opensora-sim"])
    b = model.init_params(configs.MODELS["latte-sim"])
    assert a["t_embed"]["tw1"].shape != b["t_embed"]["tw1"].shape or not np.array_equal(
        a["t_embed"]["tw1"], b["t_embed"]["tw1"]
    )


# ---------------------------------------------------------------------------
# piece semantics
# ---------------------------------------------------------------------------


def test_sub_blocks_compose_to_full_block(params):
    s = _rand_state(0)
    for kind in ("spatial", "temporal"):
        key = f"layer02.{kind}"
        full = model.dit_block(
            s["h"], s["c"], s["tk"], s["tv"], *_args(params, key, "spatial_block"),
            cfg=CFG, bucket=BUCKET, kind=kind, ops=model.REF_OPS,
        )
        h1 = model.block_attn_sub(
            s["h"], s["c"], *_args(params, key, "sb_attn"),
            cfg=CFG, bucket=BUCKET, kind=kind, ops=model.REF_OPS,
        )
        h2 = model.block_cross_sub(
            h1, s["tk"], s["tv"], *_args(params, key, "sb_cross"),
            cfg=CFG, bucket=BUCKET, ops=model.REF_OPS,
        )
        h3 = model.block_mlp_sub(
            h2, s["c"], *_args(params, key, "sb_mlp"),
            cfg=CFG, bucket=BUCKET, ops=model.REF_OPS,
        )
        np.testing.assert_allclose(full, h3, rtol=1e-6, atol=1e-6)


@settings(deadline=None, max_examples=6)
@given(seed=st.integers(0, 2**31 - 1), layer=st.integers(0, CFG.layers - 1))
def test_block_pallas_matches_ref(seed, layer):
    params = model.init_params(CFG)
    s = _rand_state(seed)
    key = f"layer{layer:02d}.spatial"
    a = model.dit_block(
        s["h"], s["c"], s["tk"], s["tv"], *_args(params, key, "spatial_block"),
        cfg=CFG, bucket=BUCKET, kind="spatial", ops=model.REF_OPS,
    )
    b = model.dit_block(
        s["h"], s["c"], s["tk"], s["tv"], *_args(params, key, "spatial_block"),
        cfg=CFG, bucket=BUCKET, kind="spatial", ops=model.PALLAS_OPS,
    )
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_temporal_block_differs_from_spatial(params):
    """Temporal attention attends over frames — same weights must give a
    different result than spatial attention unless F == P."""
    s = _rand_state(1)
    key = "layer00.spatial"
    a = model.dit_block(
        s["h"], s["c"], s["tk"], s["tv"], *_args(params, key, "spatial_block"),
        cfg=CFG, bucket=BUCKET, kind="spatial", ops=model.REF_OPS,
    )
    b = model.dit_block(
        s["h"], s["c"], s["tk"], s["tv"], *_args(params, key, "spatial_block"),
        cfg=CFG, bucket=BUCKET, kind="temporal", ops=model.REF_OPS,
    )
    assert not np.allclose(a, b)


def test_text_kv_pieces(params):
    s = _rand_state(2)
    text = np.asarray(
        model.text_proj(s["raw"], *_args(params, "text_proj", "text_proj"))
    )
    key = "layer01.temporal"
    k = model.text_k(jnp.asarray(text), *_args(params, key, "text_k"))
    v = model.text_v(jnp.asarray(text), *_args(params, key, "text_v"))
    assert k.shape == (CFG.text_len, CFG.d_model)
    assert v.shape == (CFG.text_len, CFG.d_model)
    assert not np.allclose(np.asarray(k), np.asarray(v))


def test_t_embed_varies_smoothly(params):
    args = _args(params, "t_embed", "t_embed")
    c1 = np.asarray(model.t_embed(jnp.float32(500.0), *args, cfg=CFG))
    c2 = np.asarray(model.t_embed(jnp.float32(501.0), *args, cfg=CFG))
    c3 = np.asarray(model.t_embed(jnp.float32(900.0), *args, cfg=CFG))
    assert c1.shape == (CFG.d_model,)
    d_near = np.linalg.norm(c1 - c2)
    d_far = np.linalg.norm(c1 - c3)
    assert d_near < d_far
    assert d_near > 0


def test_embed_adds_position_information(params):
    s = _rand_state(3)
    h = np.asarray(model.embed(s["x"], *_args(params, "embed", "embed"), cfg=CFG, bucket=BUCKET))
    assert h.shape == (BUCKET.frames, BUCKET.tokens, CFG.d_model)
    # identical latent tokens at different positions must embed differently
    x_const = jnp.asarray(np.ones((BUCKET.frames, BUCKET.tokens, CFG.latent_channels), np.float32))
    hc = np.asarray(model.embed(x_const, *_args(params, "embed", "embed"), cfg=CFG, bucket=BUCKET))
    assert not np.allclose(hc[0, 0], hc[0, 1])
    assert not np.allclose(hc[0, 0], hc[1, 0])


def test_final_shape(params):
    s = _rand_state(4)
    out = model.final(
        s["h"], s["c"], *_args(params, "final", "final"),
        cfg=CFG, bucket=BUCKET, ops=model.REF_OPS,
    )
    assert out.shape == (BUCKET.frames, BUCKET.tokens, CFG.latent_channels)


def test_forward_step_pallas_ref_parity():
    params = model.init_params(CFG)
    s = _rand_state(5)
    a = model.forward_step(params, CFG, BUCKET, s["x"], jnp.float32(500.0), s["raw"],
                           ops=model.REF_OPS)
    b = model.forward_step(params, CFG, BUCKET, s["x"], jnp.float32(500.0), s["raw"],
                           ops=model.PALLAS_OPS)
    np.testing.assert_allclose(a, b, rtol=5e-4, atol=5e-4)
    assert np.isfinite(np.asarray(a)).all()


def test_forward_step_prompt_sensitivity():
    params = model.init_params(CFG)
    s = _rand_state(6)
    raw2 = jnp.asarray(np.asarray(s["raw"]) * 2.0 + 0.5)
    a = model.forward_step(params, CFG, BUCKET, s["x"], jnp.float32(500.0), s["raw"])
    b = model.forward_step(params, CFG, BUCKET, s["x"], jnp.float32(500.0), raw2)
    assert not np.allclose(np.asarray(a), np.asarray(b))


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
