"""AOT export path: HLO text validity, manifest shape, incremental stamp."""

import json

import pytest

from compile import aot, configs, model


CFG = configs.MODELS["opensora-sim"]
BUCKET = configs.BUCKETS["240p-2s"]


def test_lowered_hlo_is_plain_text_without_custom_calls():
    text = aot.lower_piece(CFG, "spatial_block", BUCKET)
    assert text.startswith("HloModule")
    # interpret=True pallas must lower to portable HLO — a Mosaic
    # custom-call would be unrunnable on the CPU PJRT client
    assert "custom-call" not in text
    # single non-tuple root so the Rust side chains buffers directly
    assert ")->f32[" in text.splitlines()[0].replace(" ", "")


def test_entry_arity_matches_abi():
    text = aot.lower_piece(CFG, "temporal_block", BUCKET)
    header = text.splitlines()[0]
    params_sect = header.split("{(", 1)[1].split(")->")[0]
    n_args = params_sect.count("f32[")
    # h, c, tk, tv + 14 block params
    assert n_args == 4 + len(model.piece_params(CFG)["temporal_block"])


@pytest.mark.parametrize("piece", aot.MODEL_PIECES)
def test_model_level_pieces_lower(piece):
    text = aot.lower_piece(CFG, piece, None)
    assert text.startswith("HloModule")
    assert "custom-call" not in text


@pytest.mark.parametrize("piece", aot.BUCKET_PIECES)
def test_bucket_pieces_lower(piece):
    text = aot.lower_piece(CFG, piece, BUCKET)
    assert text.startswith("HloModule")


def test_source_hash_stable_and_content_sensitive():
    a = aot.source_hash()
    b = aot.source_hash()
    assert a == b
    assert len(a) == 64


def test_export_weights_and_manifest(tmp_path):
    windex = aot.export_weights(CFG, tmp_path)
    # every init param present in the index and on disk
    params = model.init_params(CFG)
    assert set(windex) == set(params)
    for piece_key, names in windex.items():
        assert set(names) == set(params[piece_key])
        for n in names:
            assert (tmp_path / CFG.name / "weights" / f"{piece_key}.{n}.npy").exists()


def test_export_all_writes_manifest_and_is_incremental(tmp_path, capsys):
    # restrict to one tiny model+bucket by monkeypatching the export plan
    plan = {"latte-sim": ["512sq-2s"]}
    orig = aot.EXPORT_PLAN
    aot.EXPORT_PLAN = plan
    try:
        aot.export_all(tmp_path, ["latte-sim"], force=False)
        m = json.loads((tmp_path / "manifest.json").read_text())
        assert m["schedule"]["train_timesteps"] == configs.TRAIN_TIMESTEPS
        lm = m["models"]["latte-sim"]
        assert lm["sampler"] == "ddim"
        assert lm["buckets"]["512sq-2s"]["tokens"] == 64
        assert "spatial_block" in lm["piece_params"]
        for piece in aot.BUCKET_PIECES:
            assert (tmp_path / "latte-sim" / "512sq-2s" / f"{piece}.hlo.txt").exists()
        # second run is a no-op
        capsys.readouterr()
        aot.export_all(tmp_path, ["latte-sim"], force=False)
        assert "up-to-date" in capsys.readouterr().out
    finally:
        aot.EXPORT_PLAN = orig


def test_bucket_token_counts_tile_evenly():
    """Every exported bucket's sequence lengths must divide into the Pallas
    tile grid (the kernels assert divisibility)."""
    from compile.kernels.attention import _largest_divisor_tile

    for mname, buckets in configs.EXPORT_PLAN.items():
        cfg = configs.MODELS[mname]
        for bname in buckets:
            b = configs.BUCKETS[bname]
            for s in (b.tokens, b.frames, cfg.text_len, b.frames * b.tokens):
                t = _largest_divisor_tile(s, 32)
                assert s % t == 0


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
