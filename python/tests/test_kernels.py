"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes/seeds; the kernels must match ref.py to f32
tolerance for every shape the model can feed them (the CORE correctness
signal of the build path — if these fail, the AOT artifacts are wrong).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref as kref

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")


def rand(rng, *shape):
    return rng.normal(size=shape).astype(np.float32)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@settings(deadline=None, max_examples=20)
@given(
    bh=st.sampled_from([1, 2, 8]),
    sq=st.sampled_from([8, 16, 48, 96]),
    skv=st.sampled_from([8, 16, 48]),
    d=st.sampled_from([8, 24, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_flash_attention_matches_ref(bh, sq, skv, d, seed):
    rng = np.random.default_rng(seed)
    q, k, v = rand(rng, bh, sq, d), rand(rng, bh, skv, d), rand(rng, bh, skv, d)
    got = kernels.flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v))
    want = kref.attention_ref(q, k, v)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_flash_attention_cross_lengths():
    """Cross-attention shape: many queries, few kv tokens."""
    rng = np.random.default_rng(0)
    q, k, v = rand(rng, 4, 384, 24), rand(rng, 4, 16, 24), rand(rng, 4, 16, 24)
    got = kernels.flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v))
    np.testing.assert_allclose(got, kref.attention_ref(q, k, v), rtol=2e-5, atol=2e-5)


def test_flash_attention_softmax_rows_sum_to_one_property():
    """Attention output of constant V must be that constant (softmax sums 1)."""
    rng = np.random.default_rng(1)
    q, k = rand(rng, 2, 32, 16), rand(rng, 2, 32, 16)
    v = np.ones((2, 32, 16), np.float32) * 3.5
    got = kernels.flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v))
    np.testing.assert_allclose(got, v, rtol=1e-5, atol=1e-5)


def test_flash_attention_extreme_logits_stable():
    """Online softmax must not overflow with large score magnitudes."""
    rng = np.random.default_rng(2)
    q = (rand(rng, 1, 16, 8) * 50).astype(np.float32)
    k = (rand(rng, 1, 16, 8) * 50).astype(np.float32)
    v = rand(rng, 1, 16, 8)
    got = np.asarray(kernels.flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v)))
    assert np.isfinite(got).all()
    np.testing.assert_allclose(got, kref.attention_ref(q, k, v), rtol=1e-4, atol=1e-4)


@settings(deadline=None, max_examples=10)
@given(
    b=st.sampled_from([1, 8]),
    s=st.sampled_from([16, 48]),
    nh=st.sampled_from([2, 4]),
    dh=st.sampled_from([8, 24]),
    seed=st.integers(0, 2**31 - 1),
)
def test_multi_head_attention_matches_ref(b, s, nh, dh, seed):
    rng = np.random.default_rng(seed)
    d = nh * dh
    q, k, v = rand(rng, b, s, d), rand(rng, b, s, d), rand(rng, b, s, d)
    got = kernels.multi_head_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), nh)
    want = kref.multi_head_attention_ref(q, k, v, nh)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# fused layernorm + modulate
# ---------------------------------------------------------------------------


@settings(deadline=None, max_examples=25)
@given(
    r=st.sampled_from([8, 64, 384]),
    d=st.sampled_from([32, 48, 96]),
    seed=st.integers(0, 2**31 - 1),
)
def test_ln_modulate_matches_ref(r, d, seed):
    rng = np.random.default_rng(seed)
    x, sh, sc = rand(rng, r, d), rand(rng, d), rand(rng, d)
    got = kernels.ln_modulate(jnp.asarray(x), jnp.asarray(sh), jnp.asarray(sc))
    want = kref.ln_modulate_ref(x, sh, sc)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_ln_modulate_zero_modulation_is_layernorm():
    rng = np.random.default_rng(3)
    x = rand(rng, 32, 48)
    got = kernels.layernorm(jnp.asarray(x))
    want = kref.layernorm_ref(x)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)
    # normalised rows: mean 0, var 1
    np.testing.assert_allclose(np.asarray(got).mean(-1), 0.0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got).var(-1), 1.0, rtol=1e-3)


def test_ln_modulate_constant_rows():
    """Constant rows have zero variance — eps must keep this finite."""
    x = np.full((8, 16), 2.5, np.float32)
    sh = np.zeros(16, np.float32)
    sc = np.zeros(16, np.float32)
    got = np.asarray(kernels.ln_modulate(jnp.asarray(x), jnp.asarray(sh), jnp.asarray(sc)))
    assert np.isfinite(got).all()


# ---------------------------------------------------------------------------
# fused MLP
# ---------------------------------------------------------------------------


@settings(deadline=None, max_examples=20)
@given(
    r=st.sampled_from([8, 64, 384]),
    d=st.sampled_from([32, 48]),
    ratio=st.sampled_from([2, 4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_mlp_matches_ref(r, d, ratio, seed):
    rng = np.random.default_rng(seed)
    h = ratio * d
    x = rand(rng, r, d)
    w1, b1 = rand(rng, d, h) / np.sqrt(d), rand(rng, h) * 0.1
    w2, b2 = rand(rng, h, d) / np.sqrt(h), rand(rng, d) * 0.1
    got = kernels.fused_mlp(*map(jnp.asarray, (x, w1, b1, w2, b2)))
    want = kref.mlp_ref(x, w1, b1, w2, b2)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_fused_mlp_zero_weights_gives_bias():
    x = np.ones((16, 8), np.float32)
    w1 = np.zeros((8, 32), np.float32)
    b1 = np.zeros(32, np.float32)
    w2 = np.zeros((32, 8), np.float32)
    b2 = np.full(8, 7.0, np.float32)
    got = np.asarray(kernels.fused_mlp(*map(jnp.asarray, (x, w1, b1, w2, b2))))
    np.testing.assert_allclose(got, 7.0)


# ---------------------------------------------------------------------------
# kernels compose under jit (the AOT path wraps everything in jax.jit)
# ---------------------------------------------------------------------------


def test_kernels_jit_compatible():
    rng = np.random.default_rng(4)

    @jax.jit
    def f(q, k, v, sh, sc):
        a = kernels.flash_attention(q, k, v)
        return kernels.ln_modulate(a.reshape(-1, a.shape[-1]), sh, sc)

    q, k, v = rand(rng, 2, 16, 8), rand(rng, 2, 16, 8), rand(rng, 2, 16, 8)
    sh, sc = rand(rng, 8), rand(rng, 8)
    out = f(*map(jnp.asarray, (q, k, v, sh, sc)))
    ref_a = kref.attention_ref(q, k, v)
    ref_o = kref.ln_modulate_ref(ref_a.reshape(-1, 8), sh, sc)
    np.testing.assert_allclose(out, ref_o, rtol=2e-5, atol=2e-5)


def test_tile_divisor_selection():
    from compile.kernels.attention import _largest_divisor_tile

    assert _largest_divisor_tile(48, 32) == 24
    assert _largest_divisor_tile(96, 32) == 32
    assert _largest_divisor_tile(7, 32) == 7
    assert _largest_divisor_tile(16, 32) == 16
    for n in [8, 12, 48, 96, 192, 17]:
        t = _largest_divisor_tile(n, 32)
        assert n % t == 0 and t <= 32


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
