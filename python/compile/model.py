"""L2: Spatial-Temporal DiT (ST-DiT) in JAX, composed from the L1 kernels.

The model mirrors the topology the paper targets (OpenSora/Latte/CogVideoX
family, Appendix A.1 Fig. 8): alternating Spatial-DiT and Temporal-DiT
blocks, each ``{self/temporal attention, text cross-attention, MLP}`` with
adaLN timestep conditioning, plus patch/text/timestep embedders and a final
projection back to latent channels.

Crucially for Foresight, each piece is lowered to a **separate** HLO module
(see aot.py): the Rust coordinator makes the paper's per-layer, per-step
reuse decision by either dispatching a block executable or feeding the
cached activation forward — so the block boundary here *is* the reuse
granularity (coarse, 2 blocks/layer → the paper's 2LHWF cache).

All functions take weights as explicit positional arguments in the order
given by ``piece_params``; that order is recorded in artifacts/manifest.json
and is the ABI between Python (build time) and Rust (request path).
"""

from __future__ import annotations

import math
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .configs import Bucket, ModelConfig
from . import kernels
from .kernels import ref as kref

# ---------------------------------------------------------------------------
# Parameter ABI: piece name -> ordered (param name, shape) list.
# Shapes are functions of cfg only — buckets never affect weight shapes.
# ---------------------------------------------------------------------------


def piece_params(cfg: ModelConfig) -> dict[str, list[tuple[str, tuple[int, ...]]]]:
    """Ordered parameter (name, shape) lists per piece — the Python/Rust ABI."""
    d = cfg.d_model
    h = cfg.mlp_ratio * d
    c = cfg.latent_channels
    block = [
        ("adaln_w", (d, 6 * d)),
        ("adaln_b", (6 * d,)),
        ("qkv_w", (d, 3 * d)),
        ("qkv_b", (3 * d,)),
        ("attn_proj_w", (d, d)),
        ("attn_proj_b", (d,)),
        ("cross_q_w", (d, d)),
        ("cross_q_b", (d,)),
        ("cross_proj_w", (d, d)),
        ("cross_proj_b", (d,)),
        ("mlp_w1", (d, h)),
        ("mlp_b1", (h,)),
        ("mlp_w2", (h, d)),
        ("mlp_b2", (d,)),
    ]
    return {
        "t_embed": [
            ("tw1", (cfg.t_freq_dim, d)),
            ("tb1", (d,)),
            ("tw2", (d, d)),
            ("tb2", (d,)),
        ],
        "text_proj": [("w", (cfg.d_text, d)), ("b", (d,))],
        "text_k": [("k_w", (d, d)), ("k_b", (d,))],
        "text_v": [("v_w", (d, d)), ("v_b", (d,))],
        "embed": [("patch_w", (c, d)), ("patch_b", (d,))],
        "spatial_block": block,
        "temporal_block": block,
        # Sub-block pieces reuse subsets of the block weights (same arrays,
        # narrower argument lists) — needed by the PAB / T-GATE baselines.
        "sb_attn": [
            ("adaln_w", (d, 6 * d)),
            ("adaln_b", (6 * d,)),
            ("qkv_w", (d, 3 * d)),
            ("qkv_b", (3 * d,)),
            ("attn_proj_w", (d, d)),
            ("attn_proj_b", (d,)),
        ],
        "sb_cross": [
            ("cross_q_w", (d, d)),
            ("cross_q_b", (d,)),
            ("cross_proj_w", (d, d)),
            ("cross_proj_b", (d,)),
        ],
        "sb_mlp": [
            ("adaln_w", (d, 6 * d)),
            ("adaln_b", (6 * d,)),
            ("mlp_w1", (d, h)),
            ("mlp_b1", (h,)),
            ("mlp_w2", (h, d)),
            ("mlp_b2", (d,)),
        ],
        "final": [
            ("f_adaln_w", (d, 2 * d)),
            ("f_adaln_b", (2 * d,)),
            ("out_w", (d, c)),
            ("out_b", (c,)),
        ],
    }


# ---------------------------------------------------------------------------
# Weight initialisation.
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig) -> dict[str, dict[str, np.ndarray]]:
    """Deterministic weight init for one model preset.

    Returns ``{piece_key: {param_name: array}}`` where block piece keys are
    ``layer{i:02d}.spatial`` / ``layer{i:02d}.temporal`` (each holding the 14
    block params plus its own cross-attention ``kv_w``/``kv_b`` consumed by
    the per-layer ``text_kv`` executable).

    Init scheme (DESIGN.md §1): fan-in-scaled Gaussians, zero biases, and an
    adaLN gate bias that ramps with depth from ``gate_lo`` to ``gate_hi`` so
    later layers contribute larger residual updates — the synthetic
    counterpart of the paper's Fig. 2 observation that late-layer features
    change more between steps.
    """
    rng = np.random.default_rng(cfg.seed)
    d = cfg.d_model
    specs = piece_params(cfg)

    def w(shape: tuple[int, ...]) -> np.ndarray:
        std = 1.0 / math.sqrt(shape[0])
        return rng.normal(0.0, std, size=shape).astype(np.float32)

    def zeros(shape: tuple[int, ...]) -> np.ndarray:
        return np.zeros(shape, np.float32)

    def init_piece(spec: list[tuple[str, tuple[int, ...]]]) -> dict[str, np.ndarray]:
        out = {}
        for name, shape in spec:
            out[name] = zeros(shape) if len(shape) == 1 else w(shape)
        return out

    params: dict[str, dict[str, np.ndarray]] = {
        "t_embed": init_piece(specs["t_embed"]),
        "text_proj": init_piece(specs["text_proj"]),
        "embed": init_piece(specs["embed"]),
        "final": init_piece(specs["final"]),
    }

    n = cfg.layers
    for i in range(n):
        gate = cfg.gate_lo + (cfg.gate_hi - cfg.gate_lo) * (i / max(n - 1, 1))
        for kind in ("spatial", "temporal"):
            p = init_piece(specs["spatial_block"])
            # adaLN weights are small so conditioning perturbs rather than
            # dominates; the bias carries the depth-ramped gate.
            p["adaln_w"] = (0.1 * p["adaln_w"]).astype(np.float32)
            b = np.zeros(6 * d, np.float32)
            b[2 * d : 3 * d] = gate  # gate_msa
            b[5 * d : 6 * d] = gate  # gate_mlp
            p["adaln_b"] = b
            # Per-layer cross-attention K/V projections (consumed by the
            # text_k / text_v executables, hoisted out of the step loop).
            p["k_w"] = w((d, d))
            p["k_b"] = zeros((d,))
            p["v_w"] = w((d, d))
            p["v_b"] = zeros((d,))
            params[f"layer{i:02d}.{kind}"] = p
    return params


# ---------------------------------------------------------------------------
# Kernel indirection: the same model code builds the Pallas-kernel HLO
# (use_pallas=True — the AOT path) or a pure-jnp reference HLO (tests).
# ---------------------------------------------------------------------------


class Ops:
    """Dispatch table selecting Pallas kernels or jnp reference ops."""

    def __init__(self, use_pallas: bool):
        self.use_pallas = use_pallas
        if use_pallas:
            self.mha: Callable = kernels.multi_head_attention
            self.ln_modulate: Callable = kernels.ln_modulate
            self.layernorm: Callable = kernels.layernorm
            self.mlp: Callable = kernels.fused_mlp
        else:
            self.mha = kref.multi_head_attention_ref
            self.ln_modulate = kref.ln_modulate_ref
            self.layernorm = kref.layernorm_ref
            self.mlp = kref.mlp_ref


PALLAS_OPS = Ops(use_pallas=True)
REF_OPS = Ops(use_pallas=False)


# ---------------------------------------------------------------------------
# Model pieces. Each returns a single array so the lowered HLO root is a
# plain (non-tuple) buffer that chains directly into the next execute_b call
# on the Rust side.
# ---------------------------------------------------------------------------


def silu(x: jax.Array) -> jax.Array:
    return x * jax.nn.sigmoid(x)


def t_embed(t, tw1, tb1, tw2, tb2, *, cfg: ModelConfig):
    """Timestep scalar -> conditioning vector c [D].

    Sinusoidal features of the schedule timestep (0..1000 for DDIM, 0..1
    sigma scaled by 1000 for rflow — the Rust sampler defines the value)
    followed by a 2-layer SiLU MLP.
    """
    half = cfg.t_freq_dim // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = t.astype(jnp.float32) * freqs
    emb = jnp.concatenate([jnp.cos(ang), jnp.sin(ang)])  # [t_freq_dim]
    return silu(emb @ tw1 + tb1) @ tw2 + tb2


def text_proj(raw, w, b):
    """Raw prompt embedding [S, d_text] -> model-width text tokens [S, D]."""
    return raw @ w + b


def text_k(text, k_w, k_b):
    """Per-layer cross-attention K, hoisted out of the step loop.

    Text tokens are step-invariant, so K/V are computed once per request per
    layer-block by the Rust engine instead of inside every block dispatch
    (L2 perf item, DESIGN.md §8).
    """
    return text @ k_w + k_b


def text_v(text, v_w, v_b):
    """Per-layer cross-attention V (see text_k)."""
    return text @ v_w + v_b


def _sincos_1d(n: int, dim: int) -> jnp.ndarray:
    """Fixed sinusoidal positional table [n, dim] (computed in-graph)."""
    half = dim // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    pos = jnp.arange(n, dtype=jnp.float32)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(pos), jnp.cos(pos)], axis=-1)


def embed(x, patch_w, patch_b, *, cfg: ModelConfig, bucket: Bucket):
    """Latent video [F, P, C] -> token states [F, P, D] with spatial and
    temporal sinusoidal position embeddings added in-graph (no weight
    dependence on the bucket)."""
    h = x @ patch_w + patch_b  # [F, P, D]
    d = cfg.d_model
    pos_p = _sincos_1d(bucket.tokens, d)[None, :, :]  # [1, P, D]
    pos_f = _sincos_1d(bucket.frames, d)[:, None, :]  # [F, 1, D]
    return h + 0.5 * pos_p + 0.5 * pos_f


def _adaln(c, adaln_w, adaln_b, d: int):
    m = silu(c) @ adaln_w + adaln_b  # [6D]
    return [m[i * d : (i + 1) * d] for i in range(6)]


def dit_block(
    h, c, tk, tv,
    adaln_w, adaln_b, qkv_w, qkv_b, attn_proj_w, attn_proj_b,
    cross_q_w, cross_q_b, cross_proj_w, cross_proj_b,
    mlp_w1, mlp_b1, mlp_w2, mlp_b2,
    *, cfg: ModelConfig, bucket: Bucket, kind: str, ops: Ops = PALLAS_OPS,
):
    """One DiT block — the paper's coarse reuse unit.

    kind="spatial": self-attention over the P patch tokens, frames batched.
    kind="temporal": self-attention over the F frames, patches batched
    (states transposed around the attention). Both kinds share the text
    cross-attention (precomputed K/V) and the fused MLP.
    """
    assert kind in ("spatial", "temporal")
    f, p, d = bucket.frames, bucket.tokens, cfg.d_model
    nh = cfg.n_heads
    (shift_msa, scale_msa, gate_msa, shift_mlp, scale_mlp, gate_mlp) = _adaln(
        c, adaln_w, adaln_b, d
    )

    # --- self / temporal attention ---------------------------------------
    xm = ops.ln_modulate(h.reshape(f * p, d), shift_msa, scale_msa).reshape(f, p, d)
    if kind == "temporal":
        xm = xm.transpose(1, 0, 2)  # [P, F, D]
    qkv = xm @ qkv_w + qkv_b
    q, k, v = qkv[..., :d], qkv[..., d : 2 * d], qkv[..., 2 * d :]
    a = ops.mha(q, k, v, nh)
    if kind == "temporal":
        a = a.transpose(1, 0, 2)  # back to [F, P, D]
    a = a.reshape(f * p, d) @ attn_proj_w + attn_proj_b
    h = h + (gate_msa * a).reshape(f, p, d)

    # --- cross attention over text tokens --------------------------------
    xq = ops.layernorm(h.reshape(f * p, d))
    q = (xq @ cross_q_w + cross_q_b).reshape(1, f * p, d)
    ca = ops.mha(q, tk[None, :, :], tv[None, :, :], nh).reshape(f * p, d)
    ca = ca @ cross_proj_w + cross_proj_b
    h = h + ca.reshape(f, p, d)

    # --- MLP ---------------------------------------------------------------
    xm2 = ops.ln_modulate(h.reshape(f * p, d), shift_mlp, scale_mlp)
    m = ops.mlp(xm2, mlp_w1, mlp_b1, mlp_w2, mlp_b2)
    h = h + (gate_mlp * m).reshape(f, p, d)
    return h


# ---------------------------------------------------------------------------
# Sub-block pieces: the three sublayers of a DiT block exported separately.
#
# The fine-grained baselines the paper compares against (PAB's pyramid
# attention broadcast and T-GATE's CA/SA phase split, Appendix A.6) reuse
# *sublayers* at different rates, so the Rust coordinator needs dispatchable
# units below the coarse DiT block. Composing attn -> cross -> mlp is
# bit-identical to `dit_block` (asserted by python/tests/test_model.py);
# Foresight itself only ever uses the fused block executable.
# ---------------------------------------------------------------------------


def block_attn_sub(
    h, c, adaln_w, adaln_b, qkv_w, qkv_b, attn_proj_w, attn_proj_b,
    *, cfg: ModelConfig, bucket: Bucket, kind: str, ops: Ops = PALLAS_OPS,
):
    """Self/temporal-attention sublayer with its adaLN modulation + residual."""
    assert kind in ("spatial", "temporal")
    f, p, d = bucket.frames, bucket.tokens, cfg.d_model
    (shift_msa, scale_msa, gate_msa, _, _, _) = _adaln(c, adaln_w, adaln_b, d)
    xm = ops.ln_modulate(h.reshape(f * p, d), shift_msa, scale_msa).reshape(f, p, d)
    if kind == "temporal":
        xm = xm.transpose(1, 0, 2)
    qkv = xm @ qkv_w + qkv_b
    q, k, v = qkv[..., :d], qkv[..., d : 2 * d], qkv[..., 2 * d :]
    a = ops.mha(q, k, v, cfg.n_heads)
    if kind == "temporal":
        a = a.transpose(1, 0, 2)
    a = a.reshape(f * p, d) @ attn_proj_w + attn_proj_b
    return h + (gate_msa * a).reshape(f, p, d)


def block_cross_sub(
    h, tk, tv, cross_q_w, cross_q_b, cross_proj_w, cross_proj_b,
    *, cfg: ModelConfig, bucket: Bucket, ops: Ops = PALLAS_OPS,
):
    """Text cross-attention sublayer + residual (kind-independent)."""
    f, p, d = bucket.frames, bucket.tokens, cfg.d_model
    xq = ops.layernorm(h.reshape(f * p, d))
    q = (xq @ cross_q_w + cross_q_b).reshape(1, f * p, d)
    ca = ops.mha(q, tk[None, :, :], tv[None, :, :], cfg.n_heads).reshape(f * p, d)
    ca = ca @ cross_proj_w + cross_proj_b
    return h + ca.reshape(f, p, d)


def block_mlp_sub(
    h, c, adaln_w, adaln_b, mlp_w1, mlp_b1, mlp_w2, mlp_b2,
    *, cfg: ModelConfig, bucket: Bucket, ops: Ops = PALLAS_OPS,
):
    """MLP sublayer with its adaLN modulation + residual."""
    f, p, d = bucket.frames, bucket.tokens, cfg.d_model
    (_, _, _, shift_mlp, scale_mlp, gate_mlp) = _adaln(c, adaln_w, adaln_b, d)
    xm2 = ops.ln_modulate(h.reshape(f * p, d), shift_mlp, scale_mlp)
    m = ops.mlp(xm2, mlp_w1, mlp_b1, mlp_w2, mlp_b2)
    return h + (gate_mlp * m).reshape(f, p, d)


def final(h, c, f_adaln_w, f_adaln_b, out_w, out_b,
          *, cfg: ModelConfig, bucket: Bucket, ops: Ops = PALLAS_OPS):
    """Final adaLN-modulated projection back to latent channels [F, P, C]."""
    f, p, d = bucket.frames, bucket.tokens, cfg.d_model
    m = silu(c) @ f_adaln_w + f_adaln_b
    shift, scale = m[:d], m[d:]
    x = ops.ln_modulate(h.reshape(f * p, d), shift, scale)
    out = x @ out_w + out_b
    return out.reshape(f, p, cfg.latent_channels)


# ---------------------------------------------------------------------------
# Whole-model reference forward (Python-side oracle for tests and for the
# Rust engine's no-reuse cross-check).
# ---------------------------------------------------------------------------


def forward_step(
    params: dict[str, dict[str, np.ndarray]],
    cfg: ModelConfig,
    bucket: Bucket,
    x: jax.Array,
    t: jax.Array,
    text_raw: jax.Array,
    ops: Ops = REF_OPS,
) -> jax.Array:
    """One full denoising-network evaluation (all layers computed).

    Mirrors exactly what the Rust engine does with reuse disabled: embed,
    L x (spatial block, temporal block), final. Used by
    python/tests/test_model.py and the Rust integration cross-check.
    """
    spec = piece_params(cfg)

    def args(piece_key: str, spec_key: str):
        return [jnp.asarray(params[piece_key][name]) for name, _ in spec[spec_key]]

    c = t_embed(t, *args("t_embed", "t_embed"), cfg=cfg)
    text = text_proj(text_raw, *args("text_proj", "text_proj"))
    h = embed(x, *args("embed", "embed"), cfg=cfg, bucket=bucket)
    for i in range(cfg.layers):
        for kind in ("spatial", "temporal"):
            key = f"layer{i:02d}.{kind}"
            tk = text_k(text, jnp.asarray(params[key]["k_w"]),
                        jnp.asarray(params[key]["k_b"]))
            tv = text_v(text, jnp.asarray(params[key]["v_w"]),
                        jnp.asarray(params[key]["v_b"]))
            h = dit_block(
                h, c, tk, tv, *args(key, f"{kind}_block"),
                cfg=cfg, bucket=bucket, kind=kind, ops=ops,
            )
    return final(h, c, *args("final", "final"), cfg=cfg, bucket=bucket, ops=ops)
