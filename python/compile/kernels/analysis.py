"""L1 kernel VMEM-footprint / MXU-utilisation estimates (DESIGN.md §9).

interpret=True wallclock is CPU-interpreter time, not a TPU proxy, so the
TPU-facing performance story is analytical: for every kernel × shape the
model uses, emit the VMEM working set per grid step, the arithmetic
intensity, and an MXU-utilisation upper bound from how well the matmul tile
shapes fill the 128×128 systolic array.

Run: ``python -m compile.kernels.analysis`` → artifacts/kernel_analysis.json
(also executed by `make artifacts` via aot? no — standalone, cheap).
"""

from __future__ import annotations

import json
from pathlib import Path

from ..configs import BUCKETS, EXPORT_PLAN, MODELS
from .attention import VMEM_BUDGET_BYTES, _largest_divisor_tile, DEFAULT_BLOCK_Q, DEFAULT_BLOCK_K

MXU_DIM = 128  # TPU systolic array side
VMEM_BYTES = 16 * 1024 * 1024


def mxu_utilisation(m: int, k: int, n: int) -> float:
    """Fraction of the 128x128 MXU a (m,k)x(k,n) matmul keeps busy.

    Tiles smaller than 128 in the contracted or output dims leave array
    rows/columns idle; utilisation is the product of fill fractions.
    """
    fill = lambda d: min(d, MXU_DIM) / MXU_DIM
    return fill(m) * fill(k) * fill(n)


def attention_estimate(bh: int, sq: int, skv: int, d: int) -> dict:
    working_set = 4 * (2 * bh * sq * d + 2 * bh * skv * d + bh * sq * skv)
    whole = working_set <= VMEM_BUDGET_BYTES
    if whole:
        vmem = working_set
        grid = 1
        # scores matmul (sq x d)·(d x skv) and pv (sq x skv)·(skv x d)
        util = 0.5 * (mxu_utilisation(sq, d, skv) + mxu_utilisation(sq, skv, d))
    else:
        bq = _largest_divisor_tile(sq, DEFAULT_BLOCK_Q)
        bk = _largest_divisor_tile(skv, DEFAULT_BLOCK_K)
        # q tile + full k/v + accumulators per grid step
        vmem = 4 * (bq * d * 2 + 2 * skv * d + bq * bk + 2 * bq)
        grid = bh * (sq // bq)
        util = 0.5 * (mxu_utilisation(bq, d, bk) + mxu_utilisation(bq, bk, d))
    flops = 4.0 * bh * sq * skv * d
    bytes_hbm = 4.0 * (2 * bh * sq * d + 2 * bh * skv * d)
    return {
        "path": "whole" if whole else "tiled-flash",
        "grid_steps": grid,
        "vmem_bytes_per_step": vmem,
        "vmem_fraction": vmem / VMEM_BYTES,
        "flops": flops,
        "hbm_bytes": bytes_hbm,
        "arith_intensity": flops / bytes_hbm,
        "mxu_util_upper_bound": util,
    }


def mlp_estimate(rows: int, d: int, h: int) -> dict:
    ws = 4 * (2 * rows * d + rows * h + 2 * d * h + d + h)
    whole = ws <= VMEM_BUDGET_BYTES
    vmem = ws if whole else 4 * (64 * d * 2 + 64 * h + 2 * d * h + d + h)
    flops = 4.0 * rows * d * h
    bytes_hbm = 4.0 * (2 * rows * d + 2 * d * h)
    util = 0.5 * (mxu_utilisation(rows, d, h) + mxu_utilisation(rows, h, d))
    return {
        "path": "whole" if whole else "row-tiled",
        "vmem_bytes_per_step": vmem,
        "vmem_fraction": vmem / VMEM_BYTES,
        "flops": flops,
        "hbm_bytes": bytes_hbm,
        "arith_intensity": flops / bytes_hbm,
        "mxu_util_upper_bound": util,
    }


def ln_modulate_estimate(rows: int, d: int) -> dict:
    ws = 4 * (2 * rows * d + 2 * d)
    return {
        "path": "whole" if ws <= VMEM_BUDGET_BYTES else "row-tiled",
        "vmem_bytes_per_step": min(ws, VMEM_BUDGET_BYTES),
        "vmem_fraction": min(ws, VMEM_BUDGET_BYTES) / VMEM_BYTES,
        "flops": 8.0 * rows * d,  # elementwise + moments
        "hbm_bytes": 4.0 * 2 * rows * d,
        "arith_intensity": 1.0,  # memory-bound by construction
        "mxu_util_upper_bound": 0.0,  # VPU op, no MXU
    }


def build_report() -> dict:
    report: dict = {"vmem_budget_bytes": VMEM_BUDGET_BYTES, "configs": {}}
    for mname, buckets in EXPORT_PLAN.items():
        cfg = MODELS[mname]
        d = cfg.d_model
        dh = cfg.d_head
        for bname in buckets:
            b = BUCKETS[bname]
            rows = b.frames * b.tokens
            key = f"{mname}/{bname}"
            report["configs"][key] = {
                "spatial_attention": attention_estimate(
                    b.frames * cfg.n_heads, b.tokens, b.tokens, dh
                ),
                "temporal_attention": attention_estimate(
                    b.tokens * cfg.n_heads, b.frames, b.frames, dh
                ),
                "cross_attention": attention_estimate(
                    cfg.n_heads, rows, cfg.text_len, dh
                ),
                "mlp": mlp_estimate(rows, d, cfg.mlp_ratio * d),
                "ln_modulate": ln_modulate_estimate(rows, d),
            }
    return report


def main() -> None:
    out = Path(__file__).resolve().parents[3] / "artifacts" / "kernel_analysis.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    report = build_report()
    out.write_text(json.dumps(report, indent=1))
    print(f"wrote {out}")
    # summary to stdout
    for key, cfgs in report["configs"].items():
        sa = cfgs["spatial_attention"]
        print(
            f"{key:28} spatial-attn: {sa['path']:12} vmem {sa['vmem_fraction']*100:5.1f}% "
            f"AI {sa['arith_intensity']:6.1f} MXU≤{sa['mxu_util_upper_bound']*100:4.0f}%"
        )


if __name__ == "__main__":
    main()
