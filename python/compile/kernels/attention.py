"""Pallas flash-style multi-head attention kernel (L1).

Hardware adaptation (DESIGN.md §3): the paper's models run FlashAttention on
A100 (threadblock-tiled, softmax accumulators in shared memory / registers).
On the TPU model targeted by Pallas the same insight — never materialise the
S_q x S_kv score matrix in HBM — maps to:

* grid over ``(batch*heads, q_tiles)``: each grid step holds one q tile in
  VMEM (the TPU scratchpad standing in for shared memory);
* K/V are brought into VMEM by ``BlockSpec`` once per grid step and walked in
  ``bk``-sized tiles by an in-kernel ``fori_loop`` carrying the online-softmax
  running statistics ``(m, l, acc)``;
* matmuls are ``q_tile @ k_tile.T`` / ``p @ v_tile`` shapes sized for the MXU
  (see kernels/analysis.py for the VMEM-footprint / MXU-utilisation model).

Execution here uses ``interpret=True`` (CPU PJRT cannot run Mosaic
custom-calls); the lowered HLO is plain XLA ops, so the AOT artifacts run on
the Rust PJRT client unchanged.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes. Both are upper bounds; callers get the largest divisor
# of the actual sequence length not exceeding these.
DEFAULT_BLOCK_Q = 32
DEFAULT_BLOCK_K = 32

# VMEM working-set budget for the untiled fast path (half of a TPU core's
# ~16 MiB VMEM, leaving headroom for double-buffering and scratch). When
# q, k, v, o and the score matrix all fit, the whole attention runs as a
# single-block kernel — on real hardware this avoids pointless HBM
# round-trips between tiles, and under interpret=True it avoids the
# per-grid-step interpreter overhead (EXPERIMENTS.md §Perf iteration 1).
VMEM_BUDGET_BYTES = 8 * 1024 * 1024


def _whole_kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float):
    """Single-block attention: everything resident in VMEM."""
    q = q_ref[...]
    k = k_ref[...]
    v = v_ref[...]
    s = jnp.einsum("bqd,bkd->bqk", q, k) * scale
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    o_ref[...] = jnp.einsum("bqk,bkd->bqd", p, v) / p.sum(axis=-1, keepdims=True)


def _largest_divisor_tile(n: int, cap: int) -> int:
    """Largest t <= cap with n % t == 0 (n >= 1)."""
    t = min(n, cap)
    while n % t != 0:
        t -= 1
    return t


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, bk: int, skv: int, scale: float):
    """One (batch-head, q-tile) grid step of online-softmax attention."""
    q = q_ref[0]  # [bq, d] VMEM tile
    bq, d = q.shape

    m0 = jnp.full((bq,), -jnp.inf, dtype=jnp.float32)
    l0 = jnp.zeros((bq,), dtype=jnp.float32)
    acc0 = jnp.zeros((bq, d), dtype=jnp.float32)

    def body(i, carry):
        m, l, acc = carry
        k = pl.load(k_ref, (0, pl.dslice(i * bk, bk), slice(None)))  # [bk, d]
        v = pl.load(v_ref, (0, pl.dslice(i * bk, bk), slice(None)))  # [bk, d]
        s = jnp.dot(q, k.T) * scale                                   # [bq, bk]
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[:, None] + jnp.dot(p, v)
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, skv // bk, body, (m0, l0, acc0))
    o_ref[0, ...] = acc / l[:, None]


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
) -> jax.Array:
    """Fused attention over flattened batch-heads.

    Args:
      q: ``[BH, Sq, d]`` queries (batch x heads already flattened).
      k: ``[BH, Skv, d]`` keys; ``Skv`` may differ from ``Sq``
         (cross-attention).
      v: ``[BH, Skv, d]`` values.

    Returns:
      ``[BH, Sq, d]`` attention output, numerically equal (to f32 tolerance)
      to ``softmax(q k^T / sqrt(d)) v``.
    """
    bh, sq, d = q.shape
    bh_k, skv, dk = k.shape
    assert bh == bh_k and d == dk, (q.shape, k.shape)
    assert v.shape == k.shape, (v.shape, k.shape)
    scale_f = 1.0 / (d ** 0.5)

    # Fast path: whole working set fits the VMEM budget → one block.
    working_set = 4 * (2 * bh * sq * d + 2 * bh * skv * d + bh * sq * skv)
    if working_set <= VMEM_BUDGET_BYTES:
        return pl.pallas_call(
            functools.partial(_whole_kernel, scale=scale_f),
            out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
            interpret=True,
        )(q, k, v)

    bq = _largest_divisor_tile(sq, block_q)
    bk = _largest_divisor_tile(skv, block_k)
    scale = 1.0 / (d ** 0.5)

    kernel = functools.partial(_flash_kernel, bk=bk, skv=skv, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(bh, sq // bq),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, skv, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, skv, d), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        interpret=True,
    )(q, k, v)


def multi_head_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, n_heads: int
) -> jax.Array:
    """Head split/merge wrapper around :func:`flash_attention`.

    Args:
      q: ``[B, Sq, D]``; k, v: ``[B, Skv, D]`` with ``D = n_heads * d_head``.

    Returns:
      ``[B, Sq, D]``.
    """
    b, sq, dm = q.shape
    skv = k.shape[1]
    dh = dm // n_heads

    def split(x, s):
        return (
            x.reshape(b, s, n_heads, dh).transpose(0, 2, 1, 3).reshape(b * n_heads, s, dh)
        )

    o = flash_attention(split(q, sq), split(k, skv), split(v, skv))
    return o.reshape(b, n_heads, sq, dh).transpose(0, 2, 1, 3).reshape(b, sq, dm)
