"""Pallas fused transformer MLP kernel (L1).

Fuses ``gelu(x @ w1 + b1) @ w2 + b2`` into one kernel so the ``[R, 4D]``
intermediate activation never round-trips through HBM: each grid step keeps
one row tile plus both weight panels in VMEM and produces the output tile
directly. On the MXU model both matmuls are ``(br x D) @ (D x 4D)`` and
``(br x 4D) @ (4D x D)`` — see kernels/analysis.py for the footprint math.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 64


def _largest_divisor_tile(n: int, cap: int) -> int:
    t = min(n, cap)
    while n % t != 0:
        t -= 1
    return t


def _mlp_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    x = x_ref[...]  # [br, D]
    h = x @ w1_ref[...] + b1_ref[...]
    h = jax.nn.gelu(h, approximate=True)
    o_ref[...] = h @ w2_ref[...] + b2_ref[...]


def fused_mlp(
    x: jax.Array,
    w1: jax.Array,
    b1: jax.Array,
    w2: jax.Array,
    b2: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
) -> jax.Array:
    """Fused two-layer GELU MLP.

    Args:
      x: ``[R, D]`` input rows (callers flatten leading dims).
      w1: ``[D, H]``, b1: ``[H]``, w2: ``[H, D]``, b2: ``[D]``.

    Returns:
      ``[R, D]``.
    """
    r, d = x.shape
    h = w1.shape[1]
    assert w1.shape == (d, h) and b1.shape == (h,), (w1.shape, b1.shape)
    assert w2.shape == (h, d) and b2.shape == (d,), (w2.shape, b2.shape)
    # Whole-block fast path when activations + weights + the [r, H]
    # intermediate fit the VMEM budget (see attention.VMEM_BUDGET_BYTES).
    from .attention import VMEM_BUDGET_BYTES

    working_set = 4 * (2 * r * d + r * h + 2 * d * h + d + h)
    if working_set <= VMEM_BUDGET_BYTES:
        return pl.pallas_call(
            _mlp_kernel,
            out_shape=jax.ShapeDtypeStruct((r, d), x.dtype),
            interpret=True,
        )(x, w1, b1, w2, b2)
    br = _largest_divisor_tile(r, block_rows)

    return pl.pallas_call(
        _mlp_kernel,
        grid=(r // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d, h), lambda i: (0, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
            pl.BlockSpec((h, d), lambda i: (0, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, d), x.dtype),
        interpret=True,
    )(x, w1, b1, w2, b2)
