"""Pure-jnp oracles for the Pallas kernels (L1 correctness reference).

Every kernel in this package has an exact (to f32 tolerance) counterpart
here, written with the most literal jnp formulation possible. pytest
(python/tests/test_kernels.py) asserts allclose between the two over
hypothesis-driven shape/seed sweeps; the L2 model can also be built entirely
from these for a second, kernel-free HLO path used in equivalence tests.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

LN_EPS = 1e-6


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Reference attention over flattened batch-heads: ``[BH, Sq, d]``."""
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q, k) / jnp.sqrt(jnp.float32(d)).astype(q.dtype)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v)


def multi_head_attention_ref(
    q: jax.Array, k: jax.Array, v: jax.Array, n_heads: int
) -> jax.Array:
    """Reference multi-head attention: q ``[B, Sq, D]``, k/v ``[B, Skv, D]``."""
    b, sq, dm = q.shape
    skv = k.shape[1]
    dh = dm // n_heads

    def split(x, s):
        return x.reshape(b, s, n_heads, dh).transpose(0, 2, 1, 3).reshape(
            b * n_heads, s, dh
        )

    o = attention_ref(split(q, sq), split(k, skv), split(v, skv))
    return o.reshape(b, n_heads, sq, dh).transpose(0, 2, 1, 3).reshape(b, sq, dm)


def ln_modulate_ref(
    x: jax.Array, shift: jax.Array, scale: jax.Array, *, eps: float = LN_EPS
) -> jax.Array:
    """Reference ``LN(x) * (1 + scale) + shift`` over the last dim."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    xn = (x - mu) / jnp.sqrt(var + eps)
    return xn * (1.0 + scale) + shift


def layernorm_ref(x: jax.Array, *, eps: float = LN_EPS) -> jax.Array:
    d = x.shape[-1]
    z = jnp.zeros((d,), x.dtype)
    return ln_modulate_ref(x, z, z, eps=eps)


def mlp_ref(
    x: jax.Array, w1: jax.Array, b1: jax.Array, w2: jax.Array, b2: jax.Array
) -> jax.Array:
    """Reference two-layer GELU MLP."""
    h = jax.nn.gelu(x @ w1 + b1, approximate=True)
    return h @ w2 + b2
