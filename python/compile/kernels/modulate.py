"""Pallas fused LayerNorm + adaLN modulation kernel (L1).

The paper's workload characterisation (Appendix A.2, Fig. 9) attributes ~35%
of inference time to non-linear glue ops — LayerNorm, scaling, residuals —
which on GPU are separate memory-bound kernels. The TPU adaptation fuses the
chain ``modulate(LN(x), shift, scale) = LN(x) * (1 + scale) + shift`` into a
single VMEM-resident pass: each grid step loads one row tile, computes the
normalisation moments in registers and applies the conditioning affine
before writing back — one HBM read + one HBM write per element.

``shift``/``scale`` come from the block's adaLN projection of the timestep
conditioning vector and are ``[D]`` (per-video, token-invariant), so they are
broadcast into VMEM once per grid step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 64
LN_EPS = 1e-6


def _largest_divisor_tile(n: int, cap: int) -> int:
    t = min(n, cap)
    while n % t != 0:
        t -= 1
    return t


def _ln_modulate_kernel(x_ref, shift_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...]  # [br, D]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    xn = (x - mu) * jax.lax.rsqrt(var + eps)
    o_ref[...] = xn * (1.0 + scale_ref[...]) + shift_ref[...]


def ln_modulate(
    x: jax.Array,
    shift: jax.Array,
    scale: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    eps: float = LN_EPS,
) -> jax.Array:
    """Fused ``LN(x) * (1 + scale) + shift`` over the last dim.

    Args:
      x: ``[R, D]`` rows to normalise (callers flatten leading dims).
      shift, scale: ``[D]`` conditioning vectors.

    Returns:
      ``[R, D]``.
    """
    r, d = x.shape
    assert shift.shape == (d,) and scale.shape == (d,), (shift.shape, scale.shape, d)
    kernel = functools.partial(_ln_modulate_kernel, eps=eps)

    # Whole-block fast path (see attention.VMEM_BUDGET_BYTES).
    from .attention import VMEM_BUDGET_BYTES

    if 4 * (2 * r * d + 2 * d) <= VMEM_BUDGET_BYTES:
        return pl.pallas_call(
            kernel,
            out_shape=jax.ShapeDtypeStruct((r, d), x.dtype),
            interpret=True,
        )(x, shift, scale)

    br = _largest_divisor_tile(r, block_rows)
    return pl.pallas_call(
        kernel,
        grid=(r // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, d), x.dtype),
        interpret=True,
    )(x, shift, scale)


def layernorm(x: jax.Array, *, eps: float = LN_EPS) -> jax.Array:
    """Plain affine-free LayerNorm via the fused kernel (shift=scale=0)."""
    d = x.shape[-1]
    zeros = jnp.zeros((d,), x.dtype)
    return ln_modulate(x, zeros, zeros, eps=eps)
