"""L1 Pallas kernels for the Foresight ST-DiT (build-time only).

Exports the three fused kernels the L2 model composes, plus their pure-jnp
oracles (``ref``). All kernels lower with ``interpret=True`` so the AOT HLO
runs on the CPU PJRT client driven by the Rust coordinator.
"""

from . import ref
from .attention import flash_attention, multi_head_attention
from .mlp import fused_mlp
from .modulate import layernorm, ln_modulate

__all__ = [
    "ref",
    "flash_attention",
    "multi_head_attention",
    "fused_mlp",
    "layernorm",
    "ln_modulate",
]
