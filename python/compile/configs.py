"""Model presets and shape buckets for the Foresight reproduction.

The paper evaluates on OpenSora-v1.2, Latte-1.0 and CogVideoX-2b (A100,
pretrained billion-parameter models). This environment is CPU-only with no
pretrained weights, so each model is replaced by a scaled-down ST-DiT with
the same topology, sampler family, step count and CFG scale (see DESIGN.md
§1). The `analysis` preset has the paper's 28 layer pairs so that the
layer-resolution of the Fig. 2/6/13/14 analyses is faithful.

Everything the Rust coordinator needs to know about shapes and parameter
ordering is emitted into artifacts/manifest.json by aot.py; this module is
the single source of truth on the Python side.
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class ModelConfig:
    """Architecture + sampling hyper-parameters for one model preset."""

    name: str
    layers: int           # number of (spatial, temporal) layer pairs
    d_model: int
    n_heads: int
    d_text: int           # raw prompt-embedding dim (text-encoder substitute)
    text_len: int         # number of text tokens
    latent_channels: int
    mlp_ratio: int
    t_freq_dim: int       # sinusoidal timestep embedding dim
    sampler: str          # "rflow" | "ddim"
    steps: int            # default denoising steps
    cfg_scale: float
    seed: int             # weight-init seed
    # Depth-dependent gate bias: later layers contribute more, reproducing
    # the paper's observation (Fig. 2) that late layers show larger
    # step-to-step feature change. Gate bias ramps from gate_lo..gate_hi.
    gate_lo: float = 0.3
    gate_hi: float = 1.0

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


@dataclass(frozen=True)
class Bucket:
    """A static-shape compilation bucket: latent patch grid x frames."""

    name: str
    ph: int               # patch rows
    pw: int               # patch cols
    frames: int

    @property
    def tokens(self) -> int:
        return self.ph * self.pw


# ---------------------------------------------------------------------------
# Presets. Paper models -> sim presets (DESIGN.md §1, §4).
# ---------------------------------------------------------------------------

OPENSORA_SIM = ModelConfig(
    name="opensora-sim", layers=6, d_model=96, n_heads=4,
    d_text=64, text_len=16, latent_channels=8, mlp_ratio=4, t_freq_dim=128,
    sampler="rflow", steps=30, cfg_scale=7.5, seed=1001,
)

LATTE_SIM = ModelConfig(
    name="latte-sim", layers=7, d_model=80, n_heads=4,
    d_text=64, text_len=16, latent_channels=8, mlp_ratio=4, t_freq_dim=128,
    sampler="ddim", steps=50, cfg_scale=7.5, seed=1002,
)

COGVIDEOX_SIM = ModelConfig(
    name="cogvideox-sim", layers=8, d_model=112, n_heads=4,
    d_text=64, text_len=16, latent_channels=8, mlp_ratio=4, t_freq_dim=128,
    sampler="ddim", steps=50, cfg_scale=6.0, seed=1003,
)

# 28 layer pairs like OpenSora-v1.2, narrow width: used for the feature
# dynamics analyses that need the paper's layer resolution.
ANALYSIS = ModelConfig(
    name="analysis", layers=28, d_model=48, n_heads=4,
    d_text=64, text_len=16, latent_channels=8, mlp_ratio=4, t_freq_dim=128,
    sampler="rflow", steps=30, cfg_scale=7.5, seed=1004,
)

MODELS: dict[str, ModelConfig] = {
    m.name: m for m in (OPENSORA_SIM, LATTE_SIM, COGVIDEOX_SIM, ANALYSIS)
}

# Resolution buckets. Names mirror the paper's settings; the patch grids are
# the scaled-down latent equivalents. All token counts are multiples of 8 so
# the Pallas tiles divide evenly (see kernels/attention.py).
BUCKETS: dict[str, Bucket] = {
    b.name: b
    for b in (
        Bucket("240p-2s", 6, 8, 8),     # P=48
        Bucket("240p-4s", 6, 8, 16),    # P=48, F=16
        Bucket("480p-2s", 8, 12, 8),    # P=96
        Bucket("720p-2s", 12, 16, 8),   # P=192
        Bucket("512sq-2s", 8, 8, 8),    # Latte 512x512 -> P=64
        Bucket("480x720-2s", 8, 10, 8),  # CogVideoX 480x720 -> P=80
    )
}

# Which buckets each model preset is exported for (driven by the experiment
# index in DESIGN.md §5).
EXPORT_PLAN: dict[str, list[str]] = {
    "opensora-sim": ["240p-2s", "240p-4s", "480p-2s", "720p-2s"],
    "latte-sim": ["512sq-2s"],
    "cogvideox-sim": ["480x720-2s"],
    "analysis": ["240p-2s", "480p-2s", "720p-2s"],
}

# Denoising-schedule constants shared with the Rust samplers (emitted into
# the manifest so both sides agree bit-for-bit on the timestep grid).
TRAIN_TIMESTEPS = 1000
BETA_START = 1e-4
BETA_END = 2e-2
