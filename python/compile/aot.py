"""AOT exporter: lower every model piece to HLO text + dump weights.

This is the only place Python touches the pipeline; it runs once under
``make artifacts`` and is a no-op when sources are unchanged (content hash
stamp). The Rust coordinator consumes:

* ``artifacts/<model>/<bucket>/{embed,spatial_block,temporal_block,final}.hlo.txt``
* ``artifacts/<model>/{t_embed,text_proj,text_kv}.hlo.txt`` (bucket-free)
* ``artifacts/<model>/weights/<piece>.<param>.npy``
* ``artifacts/manifest.json`` — shapes, parameter ordering (the ABI),
  sampler constants.

Each piece returns a single array and is converted with
``return_tuple=False`` so the entry root is a plain buffer — outputs chain
straight into the next ``execute_b`` on the Rust side with no tuple
unwrapping.

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version behind the published ``xla`` crate) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import configs, model
from .configs import BUCKETS, EXPORT_PLAN, MODELS, Bucket, ModelConfig


def to_hlo_text(lowered) -> str:
    """jax.jit(...).lower(...) -> XLA HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def _spec(shape: tuple[int, ...]) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _param_specs(cfg: ModelConfig, piece: str) -> list[jax.ShapeDtypeStruct]:
    return [_spec(s) for _, s in model.piece_params(cfg)[piece]]


def lower_piece(cfg: ModelConfig, piece: str, bucket: Bucket | None) -> str:
    """Lower one model piece to HLO text with static shapes."""
    d = cfg.d_model
    s = cfg.text_len
    if piece == "t_embed":
        fn = lambda t, *w: model.t_embed(t, *w, cfg=cfg)
        args = [_spec(())] + _param_specs(cfg, "t_embed")
    elif piece == "text_proj":
        fn = model.text_proj
        args = [_spec((s, cfg.d_text))] + _param_specs(cfg, "text_proj")
    elif piece == "text_k":
        fn = model.text_k
        args = [_spec((s, d))] + _param_specs(cfg, "text_k")
    elif piece == "text_v":
        fn = model.text_v
        args = [_spec((s, d))] + _param_specs(cfg, "text_v")
    elif piece == "embed":
        assert bucket is not None
        fn = lambda x, *w: model.embed(x, *w, cfg=cfg, bucket=bucket)
        args = [_spec((bucket.frames, bucket.tokens, cfg.latent_channels))]
        args += _param_specs(cfg, "embed")
    elif piece in ("spatial_block", "temporal_block"):
        assert bucket is not None
        kind = piece.split("_")[0]
        fn = lambda h, c, tk, tv, *w: model.dit_block(
            h, c, tk, tv, *w, cfg=cfg, bucket=bucket, kind=kind,
            ops=model.PALLAS_OPS,
        )
        args = [
            _spec((bucket.frames, bucket.tokens, d)),
            _spec((d,)),
            _spec((s, d)),
            _spec((s, d)),
        ] + _param_specs(cfg, piece)
    elif piece in ("sb_attn_spatial", "sb_attn_temporal"):
        assert bucket is not None
        kind = piece.rsplit("_", 1)[1]
        fn = lambda h, c, *w: model.block_attn_sub(
            h, c, *w, cfg=cfg, bucket=bucket, kind=kind, ops=model.PALLAS_OPS
        )
        args = [_spec((bucket.frames, bucket.tokens, d)), _spec((d,))]
        args += _param_specs(cfg, "sb_attn")
    elif piece == "sb_cross":
        assert bucket is not None
        fn = lambda h, tk, tv, *w: model.block_cross_sub(
            h, tk, tv, *w, cfg=cfg, bucket=bucket, ops=model.PALLAS_OPS
        )
        args = [
            _spec((bucket.frames, bucket.tokens, d)),
            _spec((s, d)),
            _spec((s, d)),
        ] + _param_specs(cfg, "sb_cross")
    elif piece == "sb_mlp":
        assert bucket is not None
        fn = lambda h, c, *w: model.block_mlp_sub(
            h, c, *w, cfg=cfg, bucket=bucket, ops=model.PALLAS_OPS
        )
        args = [_spec((bucket.frames, bucket.tokens, d)), _spec((d,))]
        args += _param_specs(cfg, "sb_mlp")
    elif piece == "final":
        assert bucket is not None
        fn = lambda h, c, *w: model.final(
            h, c, *w, cfg=cfg, bucket=bucket, ops=model.PALLAS_OPS
        )
        args = [_spec((bucket.frames, bucket.tokens, d)), _spec((d,))]
        args += _param_specs(cfg, "final")
    else:
        raise ValueError(f"unknown piece {piece}")
    return to_hlo_text(jax.jit(fn).lower(*args))


MODEL_PIECES = ("t_embed", "text_proj", "text_k", "text_v")
BUCKET_PIECES = (
    "embed",
    "spatial_block",
    "temporal_block",
    "sb_attn_spatial",
    "sb_attn_temporal",
    "sb_cross",
    "sb_mlp",
    "final",
)


def export_weights(cfg: ModelConfig, out: Path) -> dict[str, list[str]]:
    """Dump all weights as .npy and return {piece_key: [param names]}."""
    params = model.init_params(cfg)
    wdir = out / cfg.name / "weights"
    wdir.mkdir(parents=True, exist_ok=True)
    index: dict[str, list[str]] = {}
    for piece_key, arrays in params.items():
        index[piece_key] = list(arrays.keys())
        for name, arr in arrays.items():
            np.save(wdir / f"{piece_key}.{name}.npy", arr)
    return index


def source_hash() -> str:
    """Hash of everything that affects the artifacts."""
    here = Path(__file__).parent
    files = sorted(
        list(here.glob("*.py")) + list((here / "kernels").glob("*.py"))
    )
    h = hashlib.sha256()
    for f in files:
        h.update(f.read_bytes())
    return h.hexdigest()


def export_all(out: Path, models: list[str], force: bool) -> None:
    stamp = out / ".stamp"
    want = source_hash() + ":" + ",".join(sorted(models))
    if not force and stamp.exists() and stamp.read_text() == want:
        print(f"artifacts up-to-date ({out})")
        return

    manifest: dict = {
        "version": 1,
        "schedule": {
            "train_timesteps": configs.TRAIN_TIMESTEPS,
            "beta_start": configs.BETA_START,
            "beta_end": configs.BETA_END,
        },
        "models": {},
    }

    for mname in models:
        cfg = MODELS[mname]
        print(f"[aot] {mname}: weights", flush=True)
        windex = export_weights(cfg, out)
        specs = model.piece_params(cfg)

        mdir = out / cfg.name
        mdir.mkdir(parents=True, exist_ok=True)
        for piece in MODEL_PIECES:
            print(f"[aot] {mname}: lower {piece}", flush=True)
            (mdir / f"{piece}.hlo.txt").write_text(lower_piece(cfg, piece, None))

        buckets = {}
        for bname in EXPORT_PLAN[cfg.name]:
            bucket = BUCKETS[bname]
            bdir = mdir / bname
            bdir.mkdir(parents=True, exist_ok=True)
            for piece in BUCKET_PIECES:
                print(f"[aot] {mname}/{bname}: lower {piece}", flush=True)
                (bdir / f"{piece}.hlo.txt").write_text(
                    lower_piece(cfg, piece, bucket)
                )
            buckets[bname] = {
                "ph": bucket.ph,
                "pw": bucket.pw,
                "frames": bucket.frames,
                "tokens": bucket.tokens,
                "dir": f"{cfg.name}/{bname}",
            }

        manifest["models"][cfg.name] = {
            "layers": cfg.layers,
            "d_model": cfg.d_model,
            "n_heads": cfg.n_heads,
            "d_text": cfg.d_text,
            "text_len": cfg.text_len,
            "latent_channels": cfg.latent_channels,
            "mlp_ratio": cfg.mlp_ratio,
            "t_freq_dim": cfg.t_freq_dim,
            "sampler": cfg.sampler,
            "steps": cfg.steps,
            "cfg_scale": cfg.cfg_scale,
            "weights_dir": f"{cfg.name}/weights",
            "piece_params": {p: [n for n, _ in sp] for p, sp in specs.items()},
            "weight_index": windex,
            "buckets": buckets,
        }

    (out / "manifest.json").write_text(json.dumps(manifest, indent=1))
    stamp.write_text(want)
    print(f"[aot] wrote manifest + stamp to {out}")


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts dir")
    ap.add_argument(
        "--models", default=",".join(MODELS), help="comma-separated presets"
    )
    ap.add_argument("--force", action="store_true")
    ns = ap.parse_args(argv)
    export_all(Path(ns.out), [m for m in ns.models.split(",") if m], ns.force)


if __name__ == "__main__":
    main()
