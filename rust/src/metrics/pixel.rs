//! Pixel-space quality metrics: PSNR and SSIM (exact standard formulas,
//! identical to the paper's usage: computed per frame against the no-reuse
//! baseline video, averaged over frames — Appendix A.5).

use super::decoder::Frames;

/// Peak signal-to-noise ratio in dB over [0,1] frames, averaged per frame.
pub fn psnr(a: &Frames, b: &Frames) -> f64 {
    assert_eq!(a.data.len(), b.data.len(), "frame geometry mismatch");
    let per = a.pixels_per_frame();
    let mut acc = 0.0;
    for f in 0..a.f {
        let (fa, fb) = (a.frame(f), b.frame(f));
        let mse: f64 = fa
            .iter()
            .zip(fb)
            .map(|(x, y)| ((x - y) as f64).powi(2))
            .sum::<f64>()
            / per as f64;
        acc += if mse <= 1e-12 { 100.0 } else { -10.0 * (mse).log10() };
    }
    acc / a.f as f64
}

/// 2D gaussian window (side × side, given sigma), normalised to sum 1.
fn gaussian_window(side: usize, sigma: f64) -> Vec<f64> {
    let c = (side as f64 - 1.0) / 2.0;
    let mut w = Vec::with_capacity(side * side);
    for y in 0..side {
        for x in 0..side {
            let dy = y as f64 - c;
            let dx = x as f64 - c;
            w.push((-(dx * dx + dy * dy) / (2.0 * sigma * sigma)).exp());
        }
    }
    let s: f64 = w.iter().sum();
    w.iter().map(|v| v / s).collect()
}

/// Structural similarity of one channel plane (valid-window convolution).
fn ssim_plane(a: &[f32], b: &[f32], h: usize, w: usize) -> f64 {
    const SIDE: usize = 7;
    const SIGMA: f64 = 1.5;
    const C1: f64 = 0.01 * 0.01; // (k1·L)², L = 1
    const C2: f64 = 0.03 * 0.03;
    if h < SIDE || w < SIDE {
        return 1.0;
    }
    let win = gaussian_window(SIDE, SIGMA);
    let mut acc = 0.0;
    let mut count = 0usize;
    for y0 in 0..=(h - SIDE) {
        for x0 in 0..=(w - SIDE) {
            let (mut ma, mut mb) = (0.0f64, 0.0f64);
            for wy in 0..SIDE {
                for wx in 0..SIDE {
                    let k = win[wy * SIDE + wx];
                    ma += k * a[(y0 + wy) * w + (x0 + wx)] as f64;
                    mb += k * b[(y0 + wy) * w + (x0 + wx)] as f64;
                }
            }
            let (mut va, mut vb, mut cov) = (0.0f64, 0.0f64, 0.0f64);
            for wy in 0..SIDE {
                for wx in 0..SIDE {
                    let k = win[wy * SIDE + wx];
                    let da = a[(y0 + wy) * w + (x0 + wx)] as f64 - ma;
                    let db = b[(y0 + wy) * w + (x0 + wx)] as f64 - mb;
                    va += k * da * da;
                    vb += k * db * db;
                    cov += k * da * db;
                }
            }
            let s = ((2.0 * ma * mb + C1) * (2.0 * cov + C2))
                / ((ma * ma + mb * mb + C1) * (va + vb + C2));
            acc += s;
            count += 1;
        }
    }
    acc / count as f64
}

/// Mean SSIM over frames and RGB channels.
pub fn ssim(a: &Frames, b: &Frames) -> f64 {
    assert_eq!(a.data.len(), b.data.len(), "frame geometry mismatch");
    let mut acc = 0.0;
    for f in 0..a.f {
        for c in 0..3 {
            acc += ssim_plane(a.channel(f, c), b.channel(f, c), a.h, a.w);
        }
    }
    acc / (a.f * 3) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn frames(seed: u64, f: usize, h: usize, w: usize) -> Frames {
        let mut rng = Rng::new(seed);
        Frames { f, h, w, data: rng.uniform_vec(f * 3 * h * w, 0.0, 1.0) }
    }

    #[test]
    fn psnr_identity_is_max() {
        let a = frames(1, 2, 16, 16);
        assert_eq!(psnr(&a, &a), 100.0);
    }

    #[test]
    fn psnr_known_uniform_noise() {
        let a = frames(1, 1, 16, 16);
        let mut b = a.clone();
        for v in &mut b.data {
            *v = (*v + 0.1).min(1.5); // constant offset 0.1 (no clamp below 1.5)
        }
        // mse = 0.01 → psnr = 20 dB
        let p = psnr(&a, &b);
        assert!((p - 20.0).abs() < 0.2, "psnr={p}");
    }

    #[test]
    fn psnr_decreases_with_noise() {
        let a = frames(2, 2, 16, 16);
        let mut rng = Rng::new(9);
        let mut small = a.clone();
        let mut big = a.clone();
        for v in &mut small.data {
            *v += 0.01 * rng.next_normal();
        }
        for v in &mut big.data {
            *v += 0.2 * rng.next_normal();
        }
        assert!(psnr(&a, &small) > psnr(&a, &big));
    }

    #[test]
    fn ssim_identity_is_one() {
        let a = frames(3, 1, 12, 12);
        let s = ssim(&a, &a);
        assert!((s - 1.0).abs() < 1e-9, "ssim={s}");
    }

    #[test]
    fn ssim_in_range_and_orders_degradation() {
        let a = frames(4, 1, 16, 16);
        let mut rng = Rng::new(10);
        let mut small = a.clone();
        let mut big = a.clone();
        for v in &mut small.data {
            *v = (*v + 0.02 * rng.next_normal()).clamp(0.0, 1.0);
        }
        for v in &mut big.data {
            *v = (*v + 0.3 * rng.next_normal()).clamp(0.0, 1.0);
        }
        let (ss, sb) = (ssim(&a, &small), ssim(&a, &big));
        assert!(ss > sb, "{ss} vs {sb}");
        assert!((-1.0..=1.0).contains(&sb));
    }

    #[test]
    fn gaussian_window_normalised() {
        let w = gaussian_window(7, 1.5);
        let s: f64 = w.iter().sum();
        assert!((s - 1.0).abs() < 1e-12);
        // symmetric
        assert!((w[0] - w[48]).abs() < 1e-12);
    }
}
