//! Video-quality metrics (paper §4.1 "Evaluation Datasets and Metrics",
//! Appendix A.5).
//!
//! Exact implementations: PSNR, SSIM (pixel.rs). Documented proxies for the
//! pretrained-network metrics: LPIPS/FVD (perceptual.rs over the fixed
//! random feature net in features.rs), CLIPSIM/CLIP-Temp (clip.rs), DOVER
//! VQA (vqa.rs) and VBench (vbench.rs). Latents are decoded to pixel-shaped
//! frames by the fixed linear decoder (decoder.rs).
//!
//! [`QualityReport::compare`] bundles everything a paper table row needs.

pub mod clip;
pub mod decoder;
pub mod features;
pub mod perceptual;
pub mod pixel;
pub mod vbench;
pub mod vqa;

pub use clip::ClipProxy;
pub use decoder::{Decoder, Frames};
pub use features::FeatureNet;
pub use perceptual::{fvd, lpips};
pub use pixel::{psnr, ssim};
pub use vbench::{evaluate as vbench_evaluate, vbench_percent, VbenchScores};
pub use vqa::{vqa_aesthetic, vqa_overall, vqa_technical};

/// Per-video quality vs. a baseline video (the paper's Table 1 columns).
#[derive(Debug, Clone)]
pub struct QualityReport {
    pub psnr: f64,
    pub ssim: f64,
    pub lpips: f64,
    pub vbench: f64,
}

impl QualityReport {
    /// Compare a policy's decoded video against the no-reuse baseline.
    pub fn compare(net: &FeatureNet, baseline: &Frames, candidate: &Frames) -> Self {
        Self {
            psnr: psnr(baseline, candidate),
            ssim: ssim(baseline, candidate),
            lpips: lpips(net, baseline, candidate),
            vbench: vbench_evaluate(net, candidate).overall(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn quality_report_identity() {
        let mut rng = Rng::new(1);
        let f = Frames { f: 2, h: 16, w: 16, data: rng.uniform_vec(2 * 3 * 16 * 16, 0.0, 1.0) };
        let net = FeatureNet::new();
        let q = QualityReport::compare(&net, &f, &f);
        assert_eq!(q.psnr, 100.0);
        assert!((q.ssim - 1.0).abs() < 1e-9);
        assert!(q.lpips < 1e-12);
        assert!((0.0..=100.0).contains(&q.vbench));
    }

    #[test]
    fn quality_report_orders_perturbations() {
        let mut rng = Rng::new(2);
        let base =
            Frames { f: 2, h: 16, w: 16, data: rng.uniform_vec(2 * 3 * 16 * 16, 0.0, 1.0) };
        let perturb = |scale: f32, seed: u64| {
            let mut r = Rng::new(seed);
            let mut f = base.clone();
            for v in &mut f.data {
                *v = (*v + scale * r.next_normal()).clamp(0.0, 1.0);
            }
            f
        };
        let net = FeatureNet::new();
        let close = QualityReport::compare(&net, &base, &perturb(0.01, 3));
        let far = QualityReport::compare(&net, &base, &perturb(0.3, 4));
        assert!(close.psnr > far.psnr);
        assert!(close.ssim > far.ssim);
        assert!(close.lpips < far.lpips);
    }
}
