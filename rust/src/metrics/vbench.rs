//! VBench-proxy: multi-dimensional video-generation quality score
//! (paper §4.1/§4.2; VBench has 16 dimensions over 11 prompt categories).
//!
//! Each dimension below implements the *definition* of a VBench dimension
//! with closed-form statistics over decoded frames instead of pretrained
//! feature extractors (documented substitution, DESIGN.md §1). Scores are
//! in [0, 1]; the overall score is the VBench-style weighted mean reported
//! as a percentage — the paper's "VBench(%)" column.

use super::decoder::Frames;
use super::features::FeatureNet;
use super::vqa;
use crate::util::stats::cosine_f32;

/// Individual dimension scores for one video.
#[derive(Debug, Clone)]
pub struct VbenchScores {
    pub subject_consistency: f64,
    pub background_consistency: f64,
    pub temporal_flickering: f64,
    pub motion_smoothness: f64,
    pub dynamic_degree: f64,
    pub imaging_quality: f64,
    pub aesthetic_quality: f64,
}

impl VbenchScores {
    /// VBench-style weighted aggregate (%), weights follow VBench's
    /// emphasis on consistency and smoothness.
    pub fn overall(&self) -> f64 {
        let weighted = 0.20 * self.subject_consistency
            + 0.15 * self.background_consistency
            + 0.15 * self.temporal_flickering
            + 0.20 * self.motion_smoothness
            + 0.10 * self.dynamic_degree
            + 0.10 * self.imaging_quality
            + 0.10 * self.aesthetic_quality;
        100.0 * weighted
    }
}

/// Evaluate all dimensions for one video.
pub fn evaluate(net: &FeatureNet, fr: &Frames) -> VbenchScores {
    let descs = net.video_descriptors(fr);

    // subject consistency: cosine similarity of every frame to the first
    let subject_consistency = if descs.len() < 2 {
        1.0
    } else {
        (1..descs.len())
            .map(|t| 0.5 * (cosine_f32(&descs[0], &descs[t]) + 1.0))
            .sum::<f64>()
            / (descs.len() - 1) as f64
    };

    // background consistency: same statistic on 4x-downsampled frames
    let background_consistency = {
        let coarse: Vec<Vec<f32>> = (0..fr.f).map(|f| downsample4(fr, f)).collect();
        if coarse.len() < 2 {
            1.0
        } else {
            (1..coarse.len())
                .map(|t| 0.5 * (cosine_f32(&coarse[0], &coarse[t]) + 1.0))
                .sum::<f64>()
                / (coarse.len() - 1) as f64
        }
    };

    // temporal flickering: 1 - normalised mean |frame_t - frame_{t-1}|
    let mean_abs_diff = if fr.f < 2 {
        0.0
    } else {
        (1..fr.f)
            .map(|t| {
                fr.frame(t)
                    .iter()
                    .zip(fr.frame(t - 1))
                    .map(|(a, b)| (a - b).abs() as f64)
                    .sum::<f64>()
                    / fr.pixels_per_frame() as f64
            })
            .sum::<f64>()
            / (fr.f - 1) as f64
    };
    let temporal_flickering = (1.0 - 4.0 * mean_abs_diff).clamp(0.0, 1.0);

    // motion smoothness: second-order temporal difference energy relative
    // to first-order (constant-velocity motion scores 1)
    let motion_smoothness = if fr.f < 3 {
        1.0
    } else {
        let per = fr.pixels_per_frame();
        let mut first = 0.0f64;
        let mut second = 0.0f64;
        for t in 1..fr.f {
            let (a, b) = (fr.frame(t - 1), fr.frame(t));
            for i in 0..per {
                first += ((b[i] - a[i]) as f64).powi(2);
            }
        }
        for t in 2..fr.f {
            let (a, b, c) = (fr.frame(t - 2), fr.frame(t - 1), fr.frame(t));
            for i in 0..per {
                second += ((c[i] - 2.0 * b[i] + a[i]) as f64).powi(2);
            }
        }
        if first < 1e-12 {
            1.0
        } else {
            (1.0 - (second / (4.0 * first)).sqrt()).clamp(0.0, 1.0)
        }
    };

    // dynamic degree: enough motion to not be a still image (saturating)
    let dynamic_degree = (mean_abs_diff * 20.0).min(1.0);

    // imaging quality / aesthetics from the VQA proxies
    let imaging_quality = vqa::vqa_technical(fr) / 100.0;
    let aesthetic_quality = vqa::vqa_aesthetic(fr) / 100.0;

    VbenchScores {
        subject_consistency,
        background_consistency,
        temporal_flickering,
        motion_smoothness,
        dynamic_degree,
        imaging_quality,
        aesthetic_quality,
    }
}

fn downsample4(fr: &Frames, f: usize) -> Vec<f32> {
    let (h, w) = (fr.h / 4, fr.w / 4);
    let mut out = vec![0.0f32; 3 * h * w];
    for c in 0..3 {
        let p = fr.channel(f, c);
        for y in 0..h {
            for x in 0..w {
                let mut acc = 0.0;
                for dy in 0..4 {
                    for dx in 0..4 {
                        acc += p[(4 * y + dy) * fr.w + 4 * x + dx];
                    }
                }
                out[c * h * w + y * w + x] = acc / 16.0;
            }
        }
    }
    out
}

/// Mean overall VBench score (%) over a set of videos.
pub fn vbench_percent(net: &FeatureNet, videos: &[Frames]) -> f64 {
    if videos.is_empty() {
        return 0.0;
    }
    videos.iter().map(|v| evaluate(net, v).overall()).sum::<f64>() / videos.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn frames(seed: u64) -> Frames {
        let mut rng = Rng::new(seed);
        Frames { f: 6, h: 16, w: 16, data: rng.uniform_vec(6 * 3 * 16 * 16, 0.0, 1.0) }
    }

    fn static_video(seed: u64) -> Frames {
        let one = frames(seed);
        let per = one.pixels_per_frame();
        let mut st = one.clone();
        let first: Vec<f32> = st.data[..per].to_vec();
        for f in 0..st.f {
            st.data[f * per..(f + 1) * per].copy_from_slice(&first);
        }
        st
    }

    /// Smoothly drifting video: constant-velocity pixel ramp.
    fn smooth_video(seed: u64) -> Frames {
        let mut v = static_video(seed);
        let per = v.pixels_per_frame();
        for f in 0..v.f {
            for p in &mut v.data[f * per..(f + 1) * per] {
                *p = (*p + 0.02 * f as f32).min(1.0);
            }
        }
        v
    }

    #[test]
    fn scores_in_unit_range() {
        let net = FeatureNet::new();
        let s = evaluate(&net, &frames(1));
        for v in [
            s.subject_consistency,
            s.background_consistency,
            s.temporal_flickering,
            s.motion_smoothness,
            s.dynamic_degree,
            s.imaging_quality,
            s.aesthetic_quality,
        ] {
            assert!((0.0..=1.0).contains(&v), "{v}");
        }
        assert!((0.0..=100.0).contains(&s.overall()));
    }

    #[test]
    fn static_video_perfect_consistency_zero_dynamics() {
        let net = FeatureNet::new();
        let s = evaluate(&net, &static_video(2));
        assert!(s.subject_consistency > 0.999);
        assert!(s.temporal_flickering > 0.999);
        assert!(s.dynamic_degree < 1e-6);
    }

    #[test]
    fn smooth_motion_beats_flicker() {
        let net = FeatureNet::new();
        let smooth = evaluate(&net, &smooth_video(3));
        let mut fl = static_video(3);
        let per = fl.pixels_per_frame();
        for f in (1..fl.f).step_by(2) {
            for v in &mut fl.data[f * per..(f + 1) * per] {
                *v = (*v + 0.3).min(1.0);
            }
        }
        let flicker = evaluate(&net, &fl);
        assert!(smooth.motion_smoothness > flicker.motion_smoothness);
        assert!(smooth.temporal_flickering > flicker.temporal_flickering);
    }

    #[test]
    fn frozen_video_scores_lower_dynamic_degree_than_moving() {
        let net = FeatureNet::new();
        let frozen = evaluate(&net, &static_video(4));
        let moving = evaluate(&net, &frames(4));
        assert!(frozen.dynamic_degree < moving.dynamic_degree);
    }

    #[test]
    fn set_aggregate_is_mean() {
        let net = FeatureNet::new();
        let vs = vec![static_video(5), frames(6)];
        let agg = vbench_percent(&net, &vs);
        let manual = (evaluate(&net, &vs[0]).overall() + evaluate(&net, &vs[1]).overall()) / 2.0;
        assert!((agg - manual).abs() < 1e-9);
        assert_eq!(vbench_percent(&net, &[]), 0.0);
    }
}
