//! Perceptual metric proxies: LPIPS and FVD (documented substitutions for
//! the pretrained-network metrics; see features.rs and DESIGN.md §1).

use super::decoder::Frames;
use super::features::FeatureNet;

/// LPIPS-proxy: channel-normalised multi-scale feature distance, averaged
/// over frames. Lower = more perceptually similar (same orientation as the
/// paper's LPIPS column).
pub fn lpips(net: &FeatureNet, a: &Frames, b: &Frames) -> f64 {
    assert_eq!(a.data.len(), b.data.len());
    let mut acc = 0.0;
    for f in 0..a.f {
        let pa = net.pyramid(a.frame(f), a.h, a.w);
        let pb = net.pyramid(b.frame(f), b.h, b.w);
        let mut frame_d = 0.0;
        for ((ca, ha, wa, da), (_, _, _, db)) in pa.scales.iter().zip(&pb.scales) {
            // unit-normalise each spatial position's channel vector, then
            // mean squared distance over positions (the LPIPS recipe)
            let hw = ha * wa;
            let mut scale_d = 0.0;
            for pos in 0..hw {
                let (mut na, mut nb) = (1e-10f64, 1e-10f64);
                for c in 0..*ca {
                    na += (da[c * hw + pos] as f64).powi(2);
                    nb += (db[c * hw + pos] as f64).powi(2);
                }
                let (na, nb) = (na.sqrt(), nb.sqrt());
                let mut d = 0.0;
                for c in 0..*ca {
                    let va = da[c * hw + pos] as f64 / na;
                    let vb = db[c * hw + pos] as f64 / nb;
                    d += (va - vb).powi(2);
                }
                scale_d += d;
            }
            frame_d += scale_d / hw as f64;
        }
        acc += frame_d / pa.scales.len() as f64;
    }
    acc / a.f as f64
}

/// Gaussian moments of a set of feature vectors (diagonal covariance).
pub struct GaussianStats {
    pub mean: Vec<f64>,
    pub var: Vec<f64>,
    pub n: usize,
}

/// Fit diagonal-Gaussian moments over a collection of descriptors.
pub fn fit_gaussian(descriptors: &[Vec<f32>]) -> GaussianStats {
    assert!(!descriptors.is_empty());
    let d = descriptors[0].len();
    let n = descriptors.len();
    let mut mean = vec![0.0f64; d];
    for v in descriptors {
        for i in 0..d {
            mean[i] += v[i] as f64;
        }
    }
    for m in &mut mean {
        *m /= n as f64;
    }
    let mut var = vec![0.0f64; d];
    for v in descriptors {
        for i in 0..d {
            var[i] += (v[i] as f64 - mean[i]).powi(2);
        }
    }
    for v in &mut var {
        *v /= n.max(1) as f64;
    }
    GaussianStats { mean, var, n }
}

/// Fréchet distance between two diagonal Gaussians:
/// `|μ1-μ2|² + Σ_i (σ1ᵢ + σ2ᵢ - 2·√(σ1ᵢ·σ2ᵢ))`.
///
/// The paper's FVD uses I3D features with full covariance; the diagonal
/// form is the standard cheap estimator and preserves ordering for the
/// relative comparisons the tables make.
pub fn frechet_distance(a: &GaussianStats, b: &GaussianStats) -> f64 {
    assert_eq!(a.mean.len(), b.mean.len());
    let mut d2 = 0.0;
    for i in 0..a.mean.len() {
        d2 += (a.mean[i] - b.mean[i]).powi(2);
        d2 += a.var[i] + b.var[i] - 2.0 * (a.var[i] * b.var[i]).sqrt();
    }
    d2.max(0.0)
}

/// Spatio-temporal video descriptor for FVD-proxy: per-frame descriptors
/// pooled with mean + mean-absolute-temporal-difference (captures both
/// appearance and motion, like I3D features do).
pub fn video_descriptor(net: &FeatureNet, fr: &Frames) -> Vec<f32> {
    let per_frame = net.video_descriptors(fr);
    let d = per_frame[0].len();
    let f = per_frame.len();
    let mut mean = vec![0.0f32; d];
    for v in &per_frame {
        for i in 0..d {
            mean[i] += v[i] / f as f32;
        }
    }
    let mut motion = vec![0.0f32; d];
    if f > 1 {
        for t in 1..f {
            for i in 0..d {
                motion[i] += (per_frame[t][i] - per_frame[t - 1][i]).abs() / (f - 1) as f32;
            }
        }
    }
    mean.extend(motion);
    mean // 80 dims
}

/// FVD-proxy between two *sets* of videos (e.g. baseline vs reuse-policy
/// outputs over a prompt set). Lower is better.
pub fn fvd(net: &FeatureNet, set_a: &[Frames], set_b: &[Frames]) -> f64 {
    let da: Vec<Vec<f32>> = set_a.iter().map(|v| video_descriptor(net, v)).collect();
    let db: Vec<Vec<f32>> = set_b.iter().map(|v| video_descriptor(net, v)).collect();
    // scale into the paper's familiar magnitude range (pure display scale,
    // applied identically to every method)
    1e5 * frechet_distance(&fit_gaussian(&da), &fit_gaussian(&db))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn frames(seed: u64) -> Frames {
        let mut rng = Rng::new(seed);
        Frames { f: 4, h: 16, w: 16, data: rng.uniform_vec(4 * 3 * 16 * 16, 0.0, 1.0) }
    }

    #[test]
    fn lpips_identity_zero_and_orders() {
        let net = FeatureNet::new();
        let a = frames(1);
        assert!(lpips(&net, &a, &a) < 1e-12);
        let mut rng = Rng::new(2);
        let mut small = a.clone();
        let mut big = a.clone();
        for v in &mut small.data {
            *v = (*v + 0.02 * rng.next_normal()).clamp(0.0, 1.0);
        }
        for v in &mut big.data {
            *v = (*v + 0.3 * rng.next_normal()).clamp(0.0, 1.0);
        }
        let (ls, lb) = (lpips(&net, &a, &small), lpips(&net, &a, &big));
        assert!(ls < lb, "{ls} vs {lb}");
    }

    #[test]
    fn frechet_identical_sets_is_zero() {
        let net = FeatureNet::new();
        let set: Vec<Frames> = (0..4).map(frames).collect();
        let d = fvd(&net, &set, &set);
        assert!(d.abs() < 1e-9, "fvd={d}");
    }

    #[test]
    fn frechet_separates_distributions() {
        let net = FeatureNet::new();
        let set_a: Vec<Frames> = (0..4).map(frames).collect();
        // set_b: same videos, heavily darkened → different distribution
        let set_b: Vec<Frames> = set_a
            .iter()
            .map(|f| {
                let mut g = f.clone();
                for v in &mut g.data {
                    *v *= 0.3;
                }
                g
            })
            .collect();
        // mildly perturbed set
        let mut rng = Rng::new(77);
        let set_c: Vec<Frames> = set_a
            .iter()
            .map(|f| {
                let mut g = f.clone();
                for v in &mut g.data {
                    *v = (*v + 0.01 * rng.next_normal()).clamp(0.0, 1.0);
                }
                g
            })
            .collect();
        let d_far = fvd(&net, &set_a, &set_b);
        let d_near = fvd(&net, &set_a, &set_c);
        assert!(d_near < d_far, "{d_near} vs {d_far}");
    }

    #[test]
    fn gaussian_fit_moments() {
        let descs = vec![vec![1.0f32, 0.0], vec![3.0, 0.0]];
        let g = fit_gaussian(&descs);
        assert_eq!(g.mean, vec![2.0, 0.0]);
        assert_eq!(g.var, vec![1.0, 0.0]);
        assert_eq!(g.n, 2);
    }

    #[test]
    fn video_descriptor_captures_motion() {
        let net = FeatureNet::new();
        // static video: every frame identical → motion half is zero
        let one = frames(5);
        let mut static_v = one.clone();
        let per = one.pixels_per_frame();
        let first: Vec<f32> = one.data[..per].to_vec();
        for f in 0..static_v.f {
            static_v.data[f * per..(f + 1) * per].copy_from_slice(&first);
        }
        let d = video_descriptor(&net, &static_v);
        let (appearance, motion) = d.split_at(d.len() / 2);
        assert!(motion.iter().all(|&v| v.abs() < 1e-9));
        assert!(appearance.iter().any(|&v| v != 0.0));
        // dynamic video has non-zero motion part
        let dm = video_descriptor(&net, &one);
        assert!(dm[d.len() / 2..].iter().any(|&v| v > 0.0));
    }
}
