//! Fixed random-feature extractor shared by the perceptual metric proxies.
//!
//! LPIPS/FVD/CLIP in the paper use pretrained networks (AlexNet, I3D, CLIP)
//! as feature spaces. Here the feature space is a *fixed seeded* 3-stage
//! conv stack (3→8→16→16 channels with ReLU + 2× average pooling). Random
//! convolutional features are a standard stand-in for perceptual metrics:
//! they are multi-scale, translation-equivariant and structure-sensitive,
//! so distances in them order degradations the same way even though the
//! absolute values differ from the pretrained-network metrics (documented
//! substitution, DESIGN.md §1).

use super::decoder::Frames;
use crate::util::prng::Rng;

/// One conv stage: 3x3 conv (padding 1) + ReLU + 2x2 average pool.
struct Stage {
    cin: usize,
    cout: usize,
    /// [cout, cin, 3, 3]
    weight: Vec<f32>,
}

impl Stage {
    fn new(rng: &mut Rng, cin: usize, cout: usize) -> Self {
        let scale = (2.0 / (cin as f32 * 9.0)).sqrt();
        let weight = (0..cout * cin * 9).map(|_| rng.next_normal() * scale).collect();
        Self { cin, cout, weight }
    }

    /// input [cin, h, w] → output [cout, h/2, w/2]
    fn forward(&self, x: &[f32], h: usize, w: usize) -> (Vec<f32>, usize, usize) {
        let mut conv = vec![0.0f32; self.cout * h * w];
        for co in 0..self.cout {
            for ci in 0..self.cin {
                let wbase = (co * self.cin + ci) * 9;
                for y in 0..h {
                    for x0 in 0..w {
                        let mut acc = 0.0f32;
                        for ky in 0..3usize {
                            let yy = y + ky;
                            if yy < 1 || yy > h {
                                continue;
                            }
                            let yy = yy - 1;
                            for kx in 0..3usize {
                                let xx = x0 + kx;
                                if xx < 1 || xx > w {
                                    continue;
                                }
                                let xx = xx - 1;
                                acc += self.weight[wbase + ky * 3 + kx]
                                    * x[ci * h * w + yy * w + xx];
                            }
                        }
                        conv[co * h * w + y * w + x0] += acc;
                    }
                }
            }
        }
        // ReLU + 2x2 average pool
        let (oh, ow) = (h / 2, w / 2);
        let mut out = vec![0.0f32; self.cout * oh * ow];
        for c in 0..self.cout {
            for y in 0..oh {
                for x0 in 0..ow {
                    let mut acc = 0.0f32;
                    for dy in 0..2 {
                        for dx in 0..2 {
                            acc += conv[c * h * w + (2 * y + dy) * w + (2 * x0 + dx)].max(0.0);
                        }
                    }
                    out[c * oh * ow + y * ow + x0] = acc / 4.0;
                }
            }
        }
        (out, oh, ow)
    }
}

/// The shared 3-stage feature pyramid.
pub struct FeatureNet {
    stages: Vec<Stage>,
}

/// Feature maps at each scale: (channels, h, w, data).
pub struct Pyramid {
    pub scales: Vec<(usize, usize, usize, Vec<f32>)>,
}

impl FeatureNet {
    pub fn new() -> Self {
        let mut rng = Rng::from_seed_and_label(0xFEA7, "metric-feature-net");
        Self {
            stages: vec![
                Stage::new(&mut rng, 3, 8),
                Stage::new(&mut rng, 8, 16),
                Stage::new(&mut rng, 16, 16),
            ],
        }
    }

    /// Multi-scale features of one frame [3, h, w].
    pub fn pyramid(&self, frame: &[f32], h: usize, w: usize) -> Pyramid {
        let mut scales = Vec::with_capacity(self.stages.len());
        let mut x = frame.to_vec();
        let (mut ch, mut cw) = (h, w);
        let mut _cin = 3;
        for st in &self.stages {
            let (nx, nh, nw) = st.forward(&x, ch, cw);
            scales.push((st.cout, nh, nw, nx.clone()));
            x = nx;
            ch = nh;
            cw = nw;
            _cin = st.cout;
        }
        Pyramid { scales }
    }

    /// Global pooled descriptor of one frame (concatenated per-scale,
    /// per-channel means) — the "embedding" used by FVD/CLIP proxies.
    pub fn descriptor(&self, frame: &[f32], h: usize, w: usize) -> Vec<f32> {
        let pyr = self.pyramid(frame, h, w);
        let mut out = Vec::new();
        for (c, sh, sw, data) in &pyr.scales {
            for ci in 0..*c {
                let plane = &data[ci * sh * sw..(ci + 1) * sh * sw];
                out.push(plane.iter().sum::<f32>() / (sh * sw) as f32);
            }
        }
        out // 8 + 16 + 16 = 40 dims
    }

    /// Per-frame descriptors of a whole video.
    pub fn video_descriptors(&self, fr: &Frames) -> Vec<Vec<f32>> {
        (0..fr.f)
            .map(|i| self.descriptor(fr.frame(i), fr.h, fr.w))
            .collect()
    }
}

impl Default for FeatureNet {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame(seed: u64, h: usize, w: usize) -> Vec<f32> {
        Rng::new(seed).uniform_vec(3 * h * w, 0.0, 1.0)
    }

    #[test]
    fn pyramid_shapes_halve() {
        let net = FeatureNet::new();
        let p = net.pyramid(&frame(1, 16, 24), 16, 24);
        assert_eq!(p.scales.len(), 3);
        assert_eq!((p.scales[0].0, p.scales[0].1, p.scales[0].2), (8, 8, 12));
        assert_eq!((p.scales[1].0, p.scales[1].1, p.scales[1].2), (16, 4, 6));
        assert_eq!((p.scales[2].0, p.scales[2].1, p.scales[2].2), (16, 2, 3));
    }

    #[test]
    fn descriptor_is_deterministic_and_40d() {
        let net1 = FeatureNet::new();
        let net2 = FeatureNet::new();
        let f = frame(2, 16, 16);
        let d1 = net1.descriptor(&f, 16, 16);
        let d2 = net2.descriptor(&f, 16, 16);
        assert_eq!(d1, d2);
        assert_eq!(d1.len(), 40);
    }

    #[test]
    fn distinct_frames_distinct_descriptors() {
        let net = FeatureNet::new();
        let d1 = net.descriptor(&frame(1, 16, 16), 16, 16);
        let d2 = net.descriptor(&frame(2, 16, 16), 16, 16);
        assert_ne!(d1, d2);
    }

    #[test]
    fn descriptor_continuity() {
        // small pixel change → small descriptor change vs large change
        let net = FeatureNet::new();
        let f0 = frame(3, 16, 16);
        let mut fs = f0.clone();
        let mut fl = f0.clone();
        for (i, v) in fs.iter_mut().enumerate() {
            if i % 5 == 0 {
                *v = (*v + 0.01).min(1.0);
            }
        }
        for (i, v) in fl.iter_mut().enumerate() {
            if i % 5 == 0 {
                *v = (*v + 0.4).min(1.0);
            }
        }
        let d0 = net.descriptor(&f0, 16, 16);
        let ds = net.descriptor(&fs, 16, 16);
        let dl = net.descriptor(&fl, 16, 16);
        let dist = |a: &[f32], b: &[f32]| {
            a.iter().zip(b).map(|(x, y)| ((x - y) as f64).powi(2)).sum::<f64>()
        };
        assert!(dist(&d0, &ds) < dist(&d0, &dl));
    }
}
