//! CLIP-proxy metrics: text-video similarity (CLIPSIM) and temporal
//! consistency (CLIP-Temp), per EvalCrafter's definitions (paper Appendix
//! A.7, Table 8). Substitution: the shared-space projections are fixed
//! seeded matrices over the prompt embedding and the frame descriptors
//! (DESIGN.md §1) — relative comparisons between methods are preserved.

use super::decoder::Frames;
use super::features::FeatureNet;
use crate::runtime::HostTensor;
use crate::util::prng::Rng;
use crate::util::stats::cosine_f32;

/// Dimensionality of the joint text-video space.
const JOINT_DIM: usize = 32;

/// Fixed projection pair mapping prompt embeddings and video descriptors
/// into a joint space.
pub struct ClipProxy {
    net: FeatureNet,
    /// [d_text_pooled(=64 max), JOINT_DIM]
    text_proj: Vec<f32>,
    d_text: usize,
    /// [40, JOINT_DIM] (frame descriptor dim)
    video_proj: Vec<f32>,
}

impl ClipProxy {
    pub fn new(d_text: usize) -> Self {
        let mut rng = Rng::from_seed_and_label(0xC11F, "clip-proxy");
        let text_proj = (0..d_text * JOINT_DIM)
            .map(|_| rng.next_normal() / (d_text as f32).sqrt())
            .collect();
        let video_proj = (0..40 * JOINT_DIM)
            .map(|_| rng.next_normal() / 40f32.sqrt())
            .collect();
        Self { net: FeatureNet::new(), text_proj, d_text, video_proj }
    }

    fn project(&self, v: &[f32], proj: &[f32], din: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; JOINT_DIM];
        for i in 0..din {
            for j in 0..JOINT_DIM {
                out[j] += v[i] * proj[i * JOINT_DIM + j];
            }
        }
        out
    }

    /// CLIPSIM-proxy: mean cosine similarity between the projected prompt
    /// embedding and each projected frame descriptor, scaled ×20 + 20 into
    /// the familiar EvalCrafter CLIPSIM range for readability (identical
    /// affine for every method; ordering unchanged).
    pub fn clipsim(&self, prompt_emb: &HostTensor, fr: &Frames) -> f64 {
        // pool prompt tokens
        let (s, d) = (prompt_emb.dims[0], prompt_emb.dims[1]);
        assert_eq!(d, self.d_text);
        let mut pooled = vec![0.0f32; d];
        for tok in 0..s {
            for i in 0..d {
                pooled[i] += prompt_emb.data[tok * d + i] / s as f32;
            }
        }
        let t = self.project(&pooled, &self.text_proj, d);
        let descs = self.net.video_descriptors(fr);
        let mut acc = 0.0;
        for desc in &descs {
            let v = self.project(desc, &self.video_proj, 40);
            acc += cosine_f32(&t, &v);
        }
        20.0 + 20.0 * (acc / descs.len() as f64)
    }

    /// CLIP-Temp: mean cosine similarity of consecutive frame descriptors
    /// × 100 (this *is* EvalCrafter's definition, just in our feature
    /// space; paper values are 99.x).
    pub fn clip_temp(&self, fr: &Frames) -> f64 {
        let descs = self.net.video_descriptors(fr);
        if descs.len() < 2 {
            return 100.0;
        }
        let mut acc = 0.0;
        for t in 1..descs.len() {
            acc += cosine_f32(&descs[t - 1], &descs[t]);
        }
        100.0 * acc / (descs.len() - 1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::embed_prompt;

    fn frames(seed: u64) -> Frames {
        let mut rng = Rng::new(seed);
        Frames { f: 4, h: 16, w: 16, data: rng.uniform_vec(4 * 3 * 16 * 16, 0.0, 1.0) }
    }

    #[test]
    fn clip_temp_static_video_is_100() {
        let c = ClipProxy::new(64);
        let one = frames(1);
        let per = one.pixels_per_frame();
        let mut st = one.clone();
        let first: Vec<f32> = st.data[..per].to_vec();
        for f in 0..st.f {
            st.data[f * per..(f + 1) * per].copy_from_slice(&first);
        }
        let v = c.clip_temp(&st);
        assert!((v - 100.0).abs() < 1e-6, "{v}");
    }

    #[test]
    fn clip_temp_smooth_above_noisy() {
        let c = ClipProxy::new(64);
        let smooth = frames(2); // uniform random but same distribution per frame
        let mut noisy = smooth.clone();
        // alternate inverted frames → violently changing video
        let per = noisy.pixels_per_frame();
        for f in (1..noisy.f).step_by(2) {
            for v in &mut noisy.data[f * per..(f + 1) * per] {
                *v = 1.0 - *v;
            }
        }
        assert!(c.clip_temp(&smooth) > c.clip_temp(&noisy));
    }

    #[test]
    fn clipsim_deterministic_and_bounded() {
        let c = ClipProxy::new(64);
        let p = embed_prompt("a calm lake at dawn", 64, 16);
        let f = frames(3);
        let a = c.clipsim(&p, &f);
        let b = c.clipsim(&p, &f);
        assert_eq!(a, b);
        assert!((0.0..=40.0).contains(&a), "{a}");
    }

    #[test]
    fn clipsim_differs_across_prompts() {
        let c = ClipProxy::new(64);
        let f = frames(4);
        let a = c.clipsim(&embed_prompt("a calm lake", 64, 16), &f);
        let b = c.clipsim(&embed_prompt("explosive racing storm chaos", 64, 16), &f);
        assert_ne!(a, b);
    }
}
