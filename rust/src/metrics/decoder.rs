//! VAE-decoder substitute: deterministic linear patch decoder.
//!
//! The paper decodes latents with each model's pretrained VAE before
//! computing pixel metrics. No VAE exists here, so latent tokens are
//! decoded with a *fixed seeded* linear projection per patch
//! (`C → 3·s·s` pixel-shuffle, s = 8) followed by a smooth squash into
//! [0, 1]. Fixed weights mean the decoder is a measurable, reproducible
//! function: identical latents → identical frames, and latent-space
//! differences map monotonically into pixel-space differences, which is all
//! the relative quality comparisons in the paper's tables require
//! (DESIGN.md §1).

use crate::runtime::HostTensor;
use crate::util::prng::Rng;

/// Pixel upsampling factor per latent patch.
pub const PATCH_SIDE: usize = 8;

/// A decoded video: frames in [F, 3, H, W] layout, values in [0, 1].
#[derive(Debug, Clone)]
pub struct Frames {
    pub f: usize,
    pub h: usize,
    pub w: usize,
    /// len = f * 3 * h * w
    pub data: Vec<f32>,
}

impl Frames {
    pub fn frame(&self, i: usize) -> &[f32] {
        let sz = 3 * self.h * self.w;
        &self.data[i * sz..(i + 1) * sz]
    }

    pub fn channel(&self, frame: usize, c: usize) -> &[f32] {
        let hw = self.h * self.w;
        let base = frame * 3 * hw + c * hw;
        &self.data[base..base + hw]
    }

    pub fn pixels_per_frame(&self) -> usize {
        3 * self.h * self.w
    }
}

/// The fixed decoder for one latent geometry.
pub struct Decoder {
    ph: usize,
    pw: usize,
    channels: usize,
    /// [C, 3*s*s] projection, seeded once.
    weight: Vec<f32>,
}

impl Decoder {
    pub fn new(ph: usize, pw: usize, channels: usize) -> Self {
        let mut rng = Rng::from_seed_and_label(0xDEC0DE, "linear-vae-decoder");
        let out = 3 * PATCH_SIDE * PATCH_SIDE;
        let scale = 1.0 / (channels as f32).sqrt();
        let weight = (0..channels * out)
            .map(|_| rng.next_normal() * scale)
            .collect();
        Self { ph, pw, channels, weight }
    }

    pub fn out_height(&self) -> usize {
        self.ph * PATCH_SIDE
    }

    pub fn out_width(&self) -> usize {
        self.pw * PATCH_SIDE
    }

    /// Decode latents [F, P, C] (P = ph*pw) into frames [F, 3, H, W].
    pub fn decode(&self, latents: &HostTensor) -> Frames {
        assert_eq!(latents.dims.len(), 3, "latents must be [F, P, C]");
        let (f, p, c) = (latents.dims[0], latents.dims[1], latents.dims[2]);
        assert_eq!(p, self.ph * self.pw, "patch grid mismatch");
        assert_eq!(c, self.channels, "channel mismatch");
        let (h, w) = (self.out_height(), self.out_width());
        let s = PATCH_SIDE;
        let out_per_patch = 3 * s * s;
        let mut data = vec![0.0f32; f * 3 * h * w];
        for fi in 0..f {
            for py in 0..self.ph {
                for px in 0..self.pw {
                    let tok = &latents.data
                        [(fi * p + py * self.pw + px) * c..(fi * p + py * self.pw + px + 1) * c];
                    for o in 0..out_per_patch {
                        let mut acc = 0.0f32;
                        for ci in 0..c {
                            acc += tok[ci] * self.weight[ci * out_per_patch + o];
                        }
                        // smooth squash into [0, 1]
                        let v = 0.5 + 0.5 * (acc * 0.7).tanh();
                        let ch = o / (s * s);
                        let yy = (o / s) % s;
                        let xx = o % s;
                        let y = py * s + yy;
                        let x = px * s + xx;
                        data[fi * 3 * h * w + ch * h * w + y * w + x] = v;
                    }
                }
            }
        }
        Frames { f, h, w, data }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn latents(seed: u64) -> HostTensor {
        let mut rng = Rng::new(seed);
        HostTensor::new(vec![4, 6, 8], rng.normal_vec(4 * 6 * 8))
    }

    #[test]
    fn decode_shapes_and_range() {
        let d = Decoder::new(2, 3, 8);
        let fr = d.decode(&latents(1));
        assert_eq!(fr.f, 4);
        assert_eq!(fr.h, 16);
        assert_eq!(fr.w, 24);
        assert_eq!(fr.data.len(), 4 * 3 * 16 * 24);
        assert!(fr.data.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn decoder_is_deterministic() {
        let d1 = Decoder::new(2, 3, 8);
        let d2 = Decoder::new(2, 3, 8);
        let l = latents(2);
        assert_eq!(d1.decode(&l).data, d2.decode(&l).data);
    }

    #[test]
    fn different_latents_different_frames() {
        let d = Decoder::new(2, 3, 8);
        assert_ne!(d.decode(&latents(1)).data, d.decode(&latents(2)).data);
    }

    #[test]
    fn latent_distance_monotone_in_pixels() {
        // small latent perturbation → smaller pixel distance than large one
        let d = Decoder::new(2, 3, 8);
        let base = latents(3);
        let mut small = base.clone();
        let mut large = base.clone();
        for (i, v) in small.data.iter_mut().enumerate() {
            *v += if i % 7 == 0 { 0.01 } else { 0.0 };
        }
        for (i, v) in large.data.iter_mut().enumerate() {
            *v += if i % 7 == 0 { 0.5 } else { 0.0 };
        }
        let f0 = d.decode(&base);
        let fs = d.decode(&small);
        let fl = d.decode(&large);
        let dist = |a: &Frames, b: &Frames| {
            a.data
                .iter()
                .zip(&b.data)
                .map(|(x, y)| ((x - y) as f64).powi(2))
                .sum::<f64>()
        };
        assert!(dist(&f0, &fs) < dist(&f0, &fl));
    }

    #[test]
    fn frame_and_channel_views() {
        let d = Decoder::new(2, 2, 8);
        let mut rng = Rng::new(5);
        let l = HostTensor::new(vec![2, 4, 8], rng.normal_vec(2 * 4 * 8));
        let fr = d.decode(&l);
        assert_eq!(fr.frame(0).len(), fr.pixels_per_frame());
        assert_eq!(fr.channel(1, 2).len(), fr.h * fr.w);
    }
}
