//! DOVER-style VQA proxies: aesthetic and technical quality scores
//! (paper Appendix A.7, Table 8). DOVER is a pretrained video-quality
//! network; the substitution scores the same perceptual properties with
//! closed-form image statistics (documented, DESIGN.md §1):
//!
//! * **aesthetic** — colorfulness (Hasler–Süsstrunk-style opponent-channel
//!   statistics), contrast and luminance balance;
//! * **technical** — sharpness (Laplacian energy), exposure clipping and
//!   temporal stability.
//!
//! Both map to 0..100; higher is better.

use super::decoder::Frames;

fn mean_std(xs: impl Iterator<Item = f64> + Clone) -> (f64, f64) {
    let n = xs.clone().count().max(1) as f64;
    let mean = xs.clone().sum::<f64>() / n;
    let var = xs.map(|v| (v - mean).powi(2)).sum::<f64>() / n;
    (mean, var.sqrt())
}

/// Colorfulness of one frame (opponent-channel statistic).
fn colorfulness(fr: &Frames, f: usize) -> f64 {
    let (r, g, b) = (fr.channel(f, 0), fr.channel(f, 1), fr.channel(f, 2));
    let rg = r.iter().zip(g).map(|(x, y)| (x - y) as f64);
    let yb = r
        .iter()
        .zip(g)
        .zip(b)
        .map(|((x, y), z)| (0.5 * (x + y) - z) as f64);
    let (m_rg, s_rg) = mean_std(rg);
    let (m_yb, s_yb) = mean_std(yb);
    ((s_rg * s_rg + s_yb * s_yb).sqrt() + 0.3 * (m_rg * m_rg + m_yb * m_yb).sqrt()) * 100.0
}

/// Laplacian energy (sharpness) of one channel plane.
fn laplacian_energy(p: &[f32], h: usize, w: usize) -> f64 {
    let mut acc = 0.0;
    for y in 1..h - 1 {
        for x in 1..w - 1 {
            let c = p[y * w + x] as f64;
            let lap = 4.0 * c
                - p[(y - 1) * w + x] as f64
                - p[(y + 1) * w + x] as f64
                - p[y * w + x - 1] as f64
                - p[y * w + x + 1] as f64;
            acc += lap * lap;
        }
    }
    acc / ((h - 2) * (w - 2)) as f64
}

/// Aesthetic VQA proxy in 0..100.
pub fn vqa_aesthetic(fr: &Frames) -> f64 {
    let mut acc = 0.0;
    for f in 0..fr.f {
        let color = colorfulness(fr, f).min(60.0);
        // contrast: luminance std (balanced exposure scores higher)
        let lum: Vec<f64> = {
            let (r, g, b) = (fr.channel(f, 0), fr.channel(f, 1), fr.channel(f, 2));
            r.iter()
                .zip(g)
                .zip(b)
                .map(|((x, y), z)| 0.299 * *x as f64 + 0.587 * *y as f64 + 0.114 * *z as f64)
                .collect()
        };
        let (m, s) = mean_std(lum.iter().copied());
        let contrast = (s * 4.0).min(1.0) * 25.0;
        let balance = (1.0 - (m - 0.5).abs() * 2.0).max(0.0) * 15.0;
        acc += color + contrast + balance;
    }
    (acc / fr.f as f64).min(100.0)
}

/// Technical VQA proxy in 0..100.
pub fn vqa_technical(fr: &Frames) -> f64 {
    let mut sharp = 0.0;
    let mut clip_penalty = 0.0;
    for f in 0..fr.f {
        for c in 0..3 {
            let p = fr.channel(f, c);
            sharp += laplacian_energy(p, fr.h, fr.w);
            let clipped = p.iter().filter(|&&v| v <= 0.002 || v >= 0.998).count();
            clip_penalty += clipped as f64 / p.len() as f64;
        }
    }
    let n = (fr.f * 3) as f64;
    sharp /= n;
    clip_penalty /= n;
    // temporal stability: penalise frame-to-frame jumps
    let mut temporal = 0.0;
    if fr.f > 1 {
        for f in 1..fr.f {
            let (a, b) = (fr.frame(f - 1), fr.frame(f));
            temporal += a
                .iter()
                .zip(b)
                .map(|(x, y)| (x - y).abs() as f64)
                .sum::<f64>()
                / a.len() as f64;
        }
        temporal /= (fr.f - 1) as f64;
    }
    // monotone saturating map (never hard-clips, so blur always lowers it)
    let sharp_score = 60.0 * sharp / (sharp + 0.02);
    let stability = (1.0 - (temporal * 4.0).min(1.0)) * 30.0;
    let exposure = (1.0 - clip_penalty * 4.0).max(0.0) * 10.0;
    (sharp_score + stability + exposure).min(100.0)
}

/// Overall VQA (DOVER-style fusion: mean of the two branches).
pub fn vqa_overall(fr: &Frames) -> f64 {
    0.5 * (vqa_aesthetic(fr) + vqa_technical(fr))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn frames(seed: u64) -> Frames {
        let mut rng = Rng::new(seed);
        Frames { f: 4, h: 16, w: 16, data: rng.uniform_vec(4 * 3 * 16 * 16, 0.0, 1.0) }
    }

    #[test]
    fn scores_bounded() {
        let f = frames(1);
        for v in [vqa_aesthetic(&f), vqa_technical(&f), vqa_overall(&f)] {
            assert!((0.0..=100.0).contains(&v), "{v}");
        }
    }

    #[test]
    fn gray_video_less_aesthetic_than_colorful() {
        let colorful = frames(2);
        let mut gray = colorful.clone();
        let hw = gray.h * gray.w;
        for f in 0..gray.f {
            for pos in 0..hw {
                let base = f * 3 * hw;
                let m = (gray.data[base + pos] + gray.data[base + hw + pos]
                    + gray.data[base + 2 * hw + pos])
                    / 3.0;
                gray.data[base + pos] = m;
                gray.data[base + hw + pos] = m;
                gray.data[base + 2 * hw + pos] = m;
            }
        }
        assert!(vqa_aesthetic(&gray) < vqa_aesthetic(&colorful));
    }

    #[test]
    fn blurred_video_less_technical_than_sharp() {
        let sharp = frames(3);
        let mut blurred = sharp.clone();
        // 3x3 box blur per channel
        let (h, w) = (sharp.h, sharp.w);
        for f in 0..sharp.f {
            for c in 0..3 {
                let src: Vec<f32> = sharp.channel(f, c).to_vec();
                let hw = h * w;
                let base = f * 3 * hw + c * hw;
                for y in 1..h - 1 {
                    for x in 1..w - 1 {
                        let mut acc = 0.0;
                        for dy in 0..3 {
                            for dx in 0..3 {
                                acc += src[(y + dy - 1) * w + (x + dx - 1)];
                            }
                        }
                        blurred.data[base + y * w + x] = acc / 9.0;
                    }
                }
            }
        }
        assert!(vqa_technical(&blurred) < vqa_technical(&sharp));
    }

    #[test]
    fn flickering_video_less_technical_than_stable() {
        let stable = {
            let one = frames(4);
            let per = one.pixels_per_frame();
            let mut st = one.clone();
            let first: Vec<f32> = st.data[..per].to_vec();
            for f in 0..st.f {
                st.data[f * per..(f + 1) * per].copy_from_slice(&first);
            }
            st
        };
        let mut flicker = stable.clone();
        let per = flicker.pixels_per_frame();
        for f in (1..flicker.f).step_by(2) {
            for v in &mut flicker.data[f * per..(f + 1) * per] {
                *v = 1.0 - *v;
            }
        }
        assert!(vqa_technical(&flicker) < vqa_technical(&stable));
    }
}
