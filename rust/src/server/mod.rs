//! TCP JSON-lines serving front-end: routing front, per-device queues,
//! continuous (step-level) batching scheduler, sharded worker pool.
//!
//! This is the L3 deployment surface: a newline-delimited JSON protocol
//! over TCP (one request object per line, one response object per line),
//! per-device FIFO queues whose workers drive **session cohorts** one
//! denoising step at a time, and aggregate latency telemetry. Python is
//! never involved; workers drive the PJRT executables directly.
//!
//! # Sharded topology (`--devices N`)
//!
//! The server scales out across **N runtime replicas**
//! ([`crate::runtime::DevicePool`]): each device ordinal owns an
//! independent PJRT client, executable caches and transfer meters, the
//! [`EngineRegistry`] loads every (model, bucket) once *per device*, and
//! the scheduler runs one worker per device over a per-device queue
//! (`devices: 1`, the default, keeps the classic shared-queue worker
//! pool on device 0 — behavior and wire responses are byte-compatible
//! with the single-device server).
//!
//! **Routing rules** (admit time, under one router lock): a `generate`
//! job goes to (1) *cohort affinity* — the device whose in-flight cohort
//! has the same (model, bucket) key and a spare lane absorbs it at its
//! next step boundary; fewest active lanes wins, ties to the lowest
//! ordinal — else (2) *least-loaded* — fewest active lanes, ties broken
//! by shortest queue (FIFO pressure), then lowest ordinal. Per-device
//! queues are strict FIFO and boundary admission still takes only the
//! compatible queue-front **prefix** (scheduler docs), so a routed job is
//! never reordered behind later arrivals for its device.
//!
//! **Steal policy** (step boundaries only): an idle device first takes
//! the *front* job of the most-loaded device's queue (free — the oldest
//! queued job just starts earlier, preserving per-key FIFO); when every
//! queue is empty it asks for a **session migration**, and the
//! most-loaded device — at its next step boundary, holding ≥ 2 lanes —
//! moves one in-flight session over via
//! [`crate::engine::Session::migrate`]: exactly one lane download on the
//! source plus one upload on the target charged to the request's
//! `RunStats` (cache/conditioning round-trips are metered only by the
//! runtimes' `TransferStats`), with latents bit-identical to a
//! never-migrated run. A device mid-cohort with spare lanes and an empty
//! queue may also pull compatible queue-front jobs from other devices.
//!
//! **Per-device stats schema**: with `devices > 1` the `stats` op adds
//! `devices` (count), `steals` (sessions migrated, total) and a
//! `per_device` array of `{device, lanes_active, occupancy_mean,
//! occupancy_max, joins, retires, steals, h2d_bytes, h2d_calls,
//! d2h_bytes, d2h_calls}` — transfer counters come straight from each
//! replica's [`crate::runtime::TransferStats`]. All existing aggregate
//! fields keep their names and meaning; at `devices: 1` the response is
//! unchanged.
//!
//! Protocol ops:
//! * `{"op":"ping"}` → `{"status":"ok","pong":true}`
//! * `{"op":"generate","model":..,"bucket":..,"policy":..,"prompt":..,
//!    "seed":..,"steps"?:..,"cfg_scale"?:..}` → run stats (including the
//!    `h2d_bytes`/`h2d_calls`/`d2h_bytes`/`d2h_calls` transfer meters,
//!    the `batch_size` the request was served at, the concrete
//!    `policy_spec` that was executed, and a `latent_l2` checksum of the
//!    final latent for wire-level equivalence checks)
//! * `{"op":"stats"}` → server-level counters + latency percentiles
//! * `{"op":"shutdown"}` → stops the server
//!
//! # `policy=auto` resolution
//!
//! With a [`crate::autotune::ProfileStore`] loaded
//! ([`ServerConfig::profiles`], CLI `serve --profiles <path>`), a
//! `generate` request may send `policy: "auto"`. The connection handler
//! resolves it to a concrete spec **at enqueue time**: the payload's
//! `policy` field is rewritten to the tuned spec before the job is
//! queued, so the scheduler and the response only ever see concrete
//! specs. (Under continuous batching the policy no longer gates pass
//! sharing at all — auto requests batch with any same-(model, bucket)
//! traffic.) Resolution follows
//! [`crate::autotune::ProfileStore::lookup`]: exact
//! (model, bucket, sampler, steps) profile, else the nearest profile of
//! the same (model, sampler), else [`DEFAULT_POLICY`] with a counted
//! fallback. A matched profile whose spec this build cannot parse (a
//! hand-edited or newer-format store) also falls back to the default with
//! a counted fallback rather than failing every auto request at dispatch.
//! Auto responses additionally echo `policy_requested: "auto"`,
//! the `resolved_policy`, the `profile_version`/`profile_store_version`
//! behind it, the `profile_match` kind (`exact`/`nearest`/`default`) and
//! `profile_fallback`; the `stats` op reports `profile_store_version`,
//! `profiles_loaded`, `auto_resolved` and `auto_fallbacks` so operators
//! can see when `auto` traffic is served untuned. Resolution happens
//! before wire validation (it only needs a concrete spec), so a request
//! that later fails validation may still tick the resolution counters.
//!
//! # Continuous batching
//!
//! Workers batch at **step granularity**, not request granularity (the
//! `scheduler` submodule). A worker blocks for the first `generate` job —
//! an empty queue waits on a condvar, never out a window — starts a
//! [`crate::engine::session::Session`] for it, and then advances its
//! cohort one denoising step per pass. At every step boundary it admits
//! queued *compatible* jobs (same raw `model`/`bucket` — the only fields
//! that pin the shared device pass) up to [`ServerConfig::max_batch`],
//! and retires finished lanes immediately: requests with **different**
//! `steps`, `cfg_scale` or `policy` now share passes, a late arrival
//! joins an in-flight batch at the next boundary, and a short request
//! never waits for a long batchmate to finish. A job whose routing
//! fields cannot be keyed (wrong types) dispatches solo so validation
//! fails it individually; seeds and prompts are deliberately never part
//! of the key — per-request latents, text conditioning, policy state and
//! drift measurements are per-session inside the engine, and each
//! response's transfer meters report the request's standalone cost
//! (unchanged by batching; see the `engine::session` docs §Byte model).
//! Every `generate` response echoes `batch_size`: the largest cohort the
//! request ever shared a device pass with. [`ServerConfig::admit_window_ms`]
//! (default 0) optionally lets a *fresh* cohort linger for batchmates
//! before its first step; the legacy `--gather-ms` flag maps onto it
//! with a deprecation warning.
//!
//! `generate` payloads are validated before a sampler is built: `steps`
//! must be a positive integer no larger than the preset's training
//! schedule, `seed` must be a non-negative **integer** (fractional seeds
//! used to truncate silently), `cfg_scale` must be a finite number. A
//! malformed field is a per-request `{"status":"error"}` response, never a
//! worker panic — and never poisons the rest of its batch.
//!
//! # Robustness
//!
//! The accept loop retries transient `accept(2)` failures (connection
//! aborts/resets, EMFILE/ENFILE/ENOBUFS/ENOMEM under load) with capped
//! exponential backoff instead of silently killing the listener, counting
//! them in the `stats` op's `accept_errors`; only genuinely fatal errors
//! (the listener itself is gone) stop it. Latency/queue telemetry lives in
//! bounded [`Reservoir`]s (exact until [`ServerConfig::telemetry_reservoir`]
//! samples, then uniform reservoir sampling), so sustained traffic cannot
//! grow server memory without bound; the `stats` op reports p50/p95/p99
//! latency, mean/p95 queueing, and the reservoir's `latency_samples` /
//! `latency_seen` accounting. Scheduler occupancy is observable the same
//! way: `lanes_active` (gauge), `occupancy_mean`/`occupancy_max` (per-step
//! cohort size over a reservoir), and the `joins` / `retires` / `regroups`
//! counters expose how much continuous batching is actually happening.
//!
//! [`Client`] sets socket read/write timeouts
//! ([`Client::DEFAULT_TIMEOUT`], overridable via
//! [`Client::connect_with_timeout`]) so a hung server fails a bench or
//! the autotune CLI with an error instead of stalling it forever.

use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use crate::autotune::ProfileStore;
use crate::config::Manifest;
use crate::engine::{Engine, Request, RunResult};
use crate::model::LoadedModel;
use crate::policy::build_policy;
use crate::runtime::{DevicePool, Runtime};
use crate::util::json::{self, Json};
use crate::util::stats::{self, Reservoir};

mod scheduler;

/// Wire-level defaults applied when a `generate` payload omits a field
/// (shared by validation and the batch key so they can never disagree).
const DEFAULT_MODEL: &str = "opensora-sim";
const DEFAULT_BUCKET: &str = "240p-2s";
pub const DEFAULT_POLICY: &str = "foresight";
/// The sentinel spec resolved through the profile store (module docs
/// §`policy=auto` resolution).
pub const AUTO_POLICY: &str = "auto";

/// Engines per (model, bucket) **per device replica**, loaded once and
/// shared by all workers. Each (model, bucket) pair gets one independent
/// [`Engine`] on every device of the pool (module docs §Sharded
/// topology); index `d` of a pair's vector is pinned to pool ordinal `d`.
pub struct EngineRegistry {
    pool: Arc<DevicePool>,
    engines: BTreeMap<(String, String), Vec<Arc<Engine>>>,
}

impl EngineRegistry {
    /// Load the given (model, bucket) pairs from the artifact manifest
    /// onto a single runtime (device 0). The single-device entry point
    /// every pre-sharding caller keeps using.
    pub fn load(rt: Arc<Runtime>, manifest: &Manifest, pairs: &[(String, String)]) -> Result<Self> {
        Self::load_pool(Arc::new(DevicePool::from_runtimes(vec![rt])?), manifest, pairs)
    }

    /// Load every (model, bucket) pair once per device of the pool.
    pub fn load_pool(
        pool: Arc<DevicePool>,
        manifest: &Manifest,
        pairs: &[(String, String)],
    ) -> Result<Self> {
        let mut engines = BTreeMap::new();
        for (model, bucket) in pairs {
            let mut per_dev = Vec::with_capacity(pool.len());
            for rt in pool.devices() {
                let lm = Arc::new(LoadedModel::load(rt.clone(), manifest, model, bucket)?);
                per_dev.push(Arc::new(Engine::new(lm, manifest.schedule)));
            }
            engines.insert((model.clone(), bucket.clone()), per_dev);
        }
        Ok(Self { pool, engines })
    }

    /// The device-0 replica (single-device callers).
    pub fn get(&self, model: &str, bucket: &str) -> Result<&Arc<Engine>> {
        self.get_on(model, bucket, 0)
    }

    /// The replica pinned to device ordinal `device`.
    pub fn get_on(&self, model: &str, bucket: &str, device: usize) -> Result<&Arc<Engine>> {
        let per_dev = self
            .engines
            .get(&(model.to_string(), bucket.to_string()))
            .ok_or_else(|| anyhow!("no engine loaded for {model}/{bucket}"))?;
        per_dev
            .get(device)
            .ok_or_else(|| anyhow!("no device-{device} replica for {model}/{bucket}"))
    }

    /// Number of device replicas behind this registry.
    pub fn devices(&self) -> usize {
        self.pool.len()
    }

    pub fn pool(&self) -> &Arc<DevicePool> {
        &self.pool
    }

    pub fn keys(&self) -> Vec<(String, String)> {
        self.engines.keys().cloned().collect()
    }
}

struct Job {
    payload: Json,
    enqueued: Instant,
    reply: mpsc::Sender<Json>,
    /// Present when the request sent `policy:"auto"` (the payload's policy
    /// field has already been rewritten to `auto.spec`).
    auto: Option<AutoInfo>,
}

/// Outcome of resolving a `policy:"auto"` request at enqueue time.
#[derive(Debug, Clone)]
struct AutoInfo {
    /// The concrete spec `auto` resolved to.
    spec: String,
    /// Generation counter of the store that resolved it (0 = no store).
    store_version: u64,
    /// `profile_version` of the matched profile (0 on fallback).
    profile_version: u64,
    /// `exact` | `nearest` | `default`.
    matched: &'static str,
    /// True when no profile matched and [`DEFAULT_POLICY`] was served.
    fallback: bool,
}

/// Resolve `policy:"auto"` against the loaded profile store, rewriting the
/// payload's `policy` field to the concrete spec so the batch key and wire
/// validation only ever see concrete specs. Returns `None` for non-auto
/// payloads. Counts the resolution (or fallback) in the telemetry.
fn resolve_auto(payload: &mut Json, ctx: &ServeCtx) -> Option<AutoInfo> {
    if payload.get("policy").and_then(|p| p.as_str()) != Some(AUTO_POLICY) {
        return None;
    }
    let str_field = |k: &str, default: &str| -> String {
        // A wrong-typed field resolves via the default here; the request
        // still fails wire validation at dispatch, this just guarantees a
        // concrete spec.
        payload
            .get(k)
            .and_then(|v| v.as_str())
            .unwrap_or(default)
            .to_string()
    };
    let model = str_field("model", DEFAULT_MODEL);
    let bucket = str_field("bucket", DEFAULT_BUCKET);
    let resolved = ctx.registry.get(&model, &bucket).ok().and_then(|engine| {
        let info = &engine.model().info;
        let steps = match payload.get("steps").and_then(|v| v.as_f64()) {
            Some(s) if s.is_finite() && s >= 1.0 && s.fract() == 0.0 => s as usize,
            // absent (or malformed — rejected later anyway): the preset
            // default, which is what the request would run at.
            _ => info.steps,
        };
        let store = ctx.profiles.as_deref()?;
        let m = store.lookup(&model, &bucket, info.sampler.name(), steps)?;
        // A stored spec this build cannot parse (hand-edited store, or a
        // newer writer's syntax) must not poison every auto request with a
        // dispatch error counted as a successful resolution — serve the
        // default and count the fallback instead.
        build_policy(&m.profile().spec, info, steps).ok()?;
        Some(AutoInfo {
            spec: m.profile().spec.clone(),
            store_version: store.version(),
            profile_version: m.profile().profile_version,
            matched: m.kind(),
            fallback: false,
        })
    });
    let auto = resolved.unwrap_or_else(|| AutoInfo {
        spec: DEFAULT_POLICY.to_string(),
        store_version: ctx.profiles.as_deref().map_or(0, |s| s.version()),
        profile_version: 0,
        matched: "default",
        fallback: true,
    });
    if auto.fallback {
        ctx.telemetry.auto_fallbacks.fetch_add(1, Ordering::Relaxed);
    } else {
        ctx.telemetry.auto_resolved.fetch_add(1, Ordering::Relaxed);
    }
    if let Json::Obj(o) = payload {
        o.insert("policy".to_string(), Json::Str(auto.spec.clone()));
    }
    Some(auto)
}

/// Cohort compatibility key (module docs §Continuous batching): only the
/// fields that pin the shared device pass — the engine a session runs on.
/// `steps`, `cfg_scale` and `policy` are per-session state and batch
/// freely. Compared on the **raw** wire values; `None` when the payload
/// cannot be keyed (non-generate op, wrong-typed routing fields — those
/// dispatch solo and fail validation individually).
fn cohort_key(payload: &Json) -> Option<(String, String)> {
    if payload.get("op").and_then(|o| o.as_str()) != Some("generate") {
        return None;
    }
    let get_str = |k: &str, default: &str| -> Option<String> {
        match payload.get(k) {
            None => Some(default.to_string()),
            Some(v) => v.as_str().map(str::to_string),
        }
    };
    let model = get_str("model", DEFAULT_MODEL)?;
    let bucket = get_str("bucket", DEFAULT_BUCKET)?;
    Some((model, bucket))
}

struct Telemetry {
    requests: AtomicU64,
    errors: AtomicU64,
    /// Transient accept(2) failures retried by the listener loop.
    accept_errors: AtomicU64,
    /// Cohorts started (a cohort of any size counts once).
    batches: AtomicU64,
    /// Requests that shared a device pass with at least one other.
    batched_requests: AtomicU64,
    /// Sessions currently in flight across all workers (gauge).
    lanes_active: AtomicU64,
    /// Sessions admitted into an already-stepping cohort (mid-flight).
    joins: AtomicU64,
    /// Sessions finished and answered.
    retires: AtomicU64,
    /// Cohort steps that rebuilt/compacted the resident stack because
    /// membership changed since the previous step.
    regroups: AtomicU64,
    /// Largest per-step cohort occupancy ever observed (a true running
    /// max — the reservoir below is a uniform sample and cannot carry a
    /// max statistic once it evicts).
    occupancy_peak: AtomicU64,
    /// Per-step cohort occupancy (lanes advanced per pass).
    occupancy: Mutex<Reservoir>,
    /// `policy=auto` requests resolved to a tuned profile.
    auto_resolved: AtomicU64,
    /// `policy=auto` requests served [`DEFAULT_POLICY`] because no profile
    /// matched (or no store was loaded) — untuned traffic.
    auto_fallbacks: AtomicU64,
    /// Sessions migrated between devices by work stealing (total; each is
    /// also credited to the *target* device's [`DeviceTelemetry`]).
    steals: AtomicU64,
    /// One entry per device ordinal (module docs §Per-device stats).
    per_device: Vec<DeviceTelemetry>,
    latencies_s: Mutex<Reservoir>,
    queue_s: Mutex<Reservoir>,
}

/// Per-device slice of the scheduler telemetry. The aggregate counters
/// above keep their exact pre-sharding meaning; these split the same
/// events by the device ordinal whose worker performed them.
struct DeviceTelemetry {
    /// Sessions resident on this device right now (gauge).
    lanes_active: AtomicU64,
    /// Mid-flight admissions into this device's cohorts.
    joins: AtomicU64,
    /// Sessions finished and answered by this device's worker.
    retires: AtomicU64,
    /// Sessions migrated *onto* this device by work stealing.
    steals: AtomicU64,
    /// Largest per-step cohort occupancy seen on this device.
    occupancy_peak: AtomicU64,
    /// Per-step cohort occupancy on this device.
    occupancy: Mutex<Reservoir>,
}

impl Telemetry {
    fn new(reservoir_cap: usize, devices: usize) -> Self {
        Self {
            requests: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            accept_errors: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_requests: AtomicU64::new(0),
            lanes_active: AtomicU64::new(0),
            joins: AtomicU64::new(0),
            retires: AtomicU64::new(0),
            regroups: AtomicU64::new(0),
            occupancy_peak: AtomicU64::new(0),
            occupancy: Mutex::new(Reservoir::new(reservoir_cap)),
            auto_resolved: AtomicU64::new(0),
            auto_fallbacks: AtomicU64::new(0),
            steals: AtomicU64::new(0),
            per_device: (0..devices.max(1))
                .map(|_| DeviceTelemetry {
                    lanes_active: AtomicU64::new(0),
                    joins: AtomicU64::new(0),
                    retires: AtomicU64::new(0),
                    steals: AtomicU64::new(0),
                    occupancy_peak: AtomicU64::new(0),
                    occupancy: Mutex::new(Reservoir::new(reservoir_cap)),
                })
                .collect(),
            latencies_s: Mutex::new(Reservoir::new(reservoir_cap)),
            queue_s: Mutex::new(Reservoir::new(reservoir_cap)),
        }
    }
}

/// Shared context a connection handler needs to route one protocol line.
struct ServeCtx {
    router: Arc<scheduler::Router>,
    stop: Arc<AtomicBool>,
    telemetry: Arc<Telemetry>,
    registry: Arc<EngineRegistry>,
    profiles: Option<Arc<ProfileStore>>,
    /// Scheduler shards (`devices > 1` adds per-device stats fields).
    devices: usize,
}

/// The running server; dropping it (or calling [`Server::shutdown`]) stops
/// the listener and workers. Shutdown broadcasts on the router condvar so
/// idle workers on every device wake and exit immediately instead of
/// polling (see [`scheduler::Router::signal_stop`]).
pub struct Server {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    router: Arc<scheduler::Router>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

/// Server configuration.
pub struct ServerConfig {
    /// Bind address; use port 0 for an ephemeral port (tests).
    pub addr: String,
    /// Scheduler threads **at `devices: 1`** (the classic worker pool all
    /// sharing device 0). With `devices > 1` the scheduler is sharded —
    /// exactly one worker per device — and this field is ignored.
    pub workers: usize,
    /// Runtime replicas to serve across (module docs §Sharded topology).
    /// The registry must have been loaded with at least this many devices
    /// ([`EngineRegistry::load_pool`]). 1 (default): single-device server,
    /// byte-compatible behavior and wire responses.
    pub devices: usize,
    /// Maximum sessions sharing one cohort's device pass (1 disables
    /// batching entirely).
    pub max_batch: usize,
    /// Optional wait before a *fresh* cohort's first step for batchmates,
    /// in milliseconds (module docs §Continuous batching). 0 (default):
    /// start stepping immediately — late arrivals join at step boundaries
    /// anyway, so unlike the retired gather window this costs a lone
    /// request nothing. Replaces `gather_window_ms`; the CLI keeps
    /// `--gather-ms` as a deprecated alias.
    pub admit_window_ms: u64,
    /// Latency/queue telemetry reservoir capacity: exact percentiles below
    /// this many samples, uniform reservoir sampling above.
    pub telemetry_reservoir: usize,
    /// Tuned reuse profiles for `policy=auto` resolution (module docs
    /// §`policy=auto` resolution). `None`: every `auto` request falls back
    /// to [`DEFAULT_POLICY`] and is counted in `auto_fallbacks`.
    pub profiles: Option<Arc<ProfileStore>>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:0".to_string(),
            workers: 2,
            devices: 1,
            max_batch: 4,
            admit_window_ms: 0,
            telemetry_reservoir: 4096,
            profiles: None,
        }
    }
}

/// Transient accept(2) failures worth retrying: per-connection errors the
/// kernel reports on the listening socket (the peer aborted before we
/// accepted) and resource-pressure conditions that clear on their own —
/// EMFILE/ENFILE when a loaded server briefly exhausts file descriptors,
/// ENOBUFS/ENOMEM under memory pressure. Anything else means the listener
/// itself is broken (EBADF, EINVAL, ...) and retrying would spin forever.
fn accept_should_retry(e: &std::io::Error) -> bool {
    use std::io::ErrorKind;
    if matches!(
        e.kind(),
        ErrorKind::ConnectionAborted
            | ErrorKind::ConnectionReset
            | ErrorKind::Interrupted
            | ErrorKind::TimedOut
    ) {
        return true;
    }
    // ENOMEM(12)/ENFILE(23)/EMFILE(24)/ENOBUFS(105) have no stable
    // ErrorKind mapping across Rust versions; match the raw errno.
    matches!(e.raw_os_error(), Some(12 | 23 | 24 | 105))
}

impl Server {
    /// Start the listener + worker pool.
    pub fn start(registry: Arc<EngineRegistry>, cfg: ServerConfig) -> Result<Server> {
        let devices = cfg.devices.max(1);
        if registry.devices() < devices {
            return Err(anyhow!(
                "server configured for {devices} devices but the registry loaded {}",
                registry.devices()
            ));
        }
        let listener = TcpListener::bind(&cfg.addr).context("bind")?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let max_batch = cfg.max_batch.max(1);
        let admit_window = Duration::from_millis(cfg.admit_window_ms);
        let router = Arc::new(scheduler::Router::new(devices, max_batch));
        let telemetry = Arc::new(Telemetry::new(cfg.telemetry_reservoir, devices));
        let mut handles = Vec::new();

        // Scheduler shards: at devices == 1 keep the classic pool —
        // cfg.workers threads all draining device 0's queue; with
        // devices > 1 spawn exactly one worker per device (scheduler
        // module docs §Sharding).
        let worker_devices: Vec<usize> = if devices == 1 {
            vec![0; cfg.workers.max(1)]
        } else {
            (0..devices).collect()
        };
        for (wid, device) in worker_devices.into_iter().enumerate() {
            let wctx = scheduler::WorkerCtx {
                router: Arc::clone(&router),
                stop: Arc::clone(&stop),
                registry: Arc::clone(&registry),
                telemetry: Arc::clone(&telemetry),
                cfg: scheduler::SchedConfig { max_batch, admit_window },
                device,
            };
            handles.push(
                std::thread::Builder::new()
                    .name(format!("foresight-server-worker-{wid}"))
                    .spawn(move || scheduler::run_worker(&wctx))
                    .expect("spawn worker"),
            );
        }

        // accept loop
        {
            let stop_accept = Arc::clone(&stop);
            let ctx = Arc::new(ServeCtx {
                router: Arc::clone(&router),
                stop: Arc::clone(&stop),
                telemetry: Arc::clone(&telemetry),
                registry: Arc::clone(&registry),
                profiles: cfg.profiles.clone(),
                devices,
            });
            handles.push(
                std::thread::Builder::new()
                    .name("foresight-server-accept".to_string())
                    .spawn(move || {
                        let mut conn_handles: Vec<std::thread::JoinHandle<()>> = Vec::new();
                        let mut consecutive_errs = 0u32;
                        while !stop_accept.load(Ordering::SeqCst) {
                            // Reap finished connection handlers each pass so
                            // the handle list tracks live connections instead
                            // of growing for the server's lifetime.
                            let mut i = 0;
                            while i < conn_handles.len() {
                                if conn_handles[i].is_finished() {
                                    let _ = conn_handles.swap_remove(i).join();
                                } else {
                                    i += 1;
                                }
                            }
                            match listener.accept() {
                                Ok((stream, _peer)) => {
                                    consecutive_errs = 0;
                                    let ctx = Arc::clone(&ctx);
                                    conn_handles.push(std::thread::spawn(move || {
                                        let _ = handle_conn(stream, ctx);
                                    }));
                                }
                                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                                    std::thread::sleep(Duration::from_millis(10));
                                }
                                Err(e) if accept_should_retry(&e) => {
                                    // Transient (ECONNABORTED, EMFILE under
                                    // load, ...): back off exponentially —
                                    // capped so shutdown stays prompt — and
                                    // keep listening rather than silently
                                    // killing the accept loop.
                                    telemetry.accept_errors.fetch_add(1, Ordering::Relaxed);
                                    let delay = Duration::from_millis(
                                        5u64.saturating_mul(1 << consecutive_errs.min(6)),
                                    );
                                    consecutive_errs = consecutive_errs.saturating_add(1);
                                    std::thread::sleep(delay.min(Duration::from_millis(250)));
                                }
                                Err(e) => {
                                    // Fatal: the listening socket itself is
                                    // gone; existing connections keep
                                    // draining through their own threads.
                                    telemetry.accept_errors.fetch_add(1, Ordering::Relaxed);
                                    eprintln!("[server] accept loop stopping: {e}");
                                    break;
                                }
                            }
                        }
                        for h in conn_handles {
                            let _ = h.join();
                        }
                    })
                    .expect("spawn accept"),
            );
        }

        Ok(Server { addr, stop, router, handles })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting and join all threads — including every per-device
    /// scheduler worker, even one mid-cohort (it finishes answering its
    /// in-flight lanes first; see [`scheduler::Router::signal_stop`]).
    pub fn shutdown(mut self) {
        self.router.signal_stop(&self.stop);
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.router.signal_stop(&self.stop);
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn err_json(msg: &str) -> Json {
    Json::obj(vec![("status", Json::str("error")), ("error", Json::str(msg))])
}

fn handle_conn(mut stream: TcpStream, ctx: Arc<ServeCtx>) -> Result<()> {
    use std::io::Read;
    // Poll with a read timeout so idle connections notice server shutdown
    // instead of blocking forever in a read (which would deadlock
    // Server::shutdown's thread joins).
    stream.set_read_timeout(Some(std::time::Duration::from_millis(100)))?;
    let mut writer = stream.try_clone()?;
    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 4096];
    'conn: loop {
        // extract complete lines already buffered
        while let Some(pos) = buf.iter().position(|&b| b == b'\n') {
            let line_bytes: Vec<u8> = buf.drain(..=pos).collect();
            let line = String::from_utf8_lossy(&line_bytes).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if !handle_line(&line, &mut writer, &ctx)? {
                break 'conn;
            }
        }
        if ctx.stop.load(Ordering::SeqCst) {
            break;
        }
        match stream.read(&mut chunk) {
            Ok(0) => break, // peer closed
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue;
            }
            Err(_) => break,
        }
    }
    Ok(())
}

/// Process one protocol line; returns false when the connection should end.
fn handle_line(line: &str, writer: &mut TcpStream, ctx: &ServeCtx) -> Result<bool> {
    {
        let telemetry = &ctx.telemetry;
        let mut payload = match json::parse(line) {
            Ok(j) => j,
            Err(e) => {
                writeln!(writer, "{}", err_json(&format!("bad json: {e}")))?;
                return Ok(true);
            }
        };
        let op = payload
            .get("op")
            .and_then(|o| o.as_str())
            .unwrap_or("")
            .to_string();
        let resp = match op.as_str() {
            "ping" => Json::obj(vec![("status", Json::str("ok")), ("pong", Json::Bool(true))]),
            "stats" => {
                let (lat, lat_seen) = {
                    let r = telemetry.latencies_s.lock().unwrap();
                    (r.samples().to_vec(), r.seen())
                };
                let qs = telemetry.queue_s.lock().unwrap().samples().to_vec();
                let occ = telemetry.occupancy.lock().unwrap().samples().to_vec();
                let occ_max = telemetry.occupancy_peak.load(Ordering::Relaxed) as f64;
                let mut fields = vec![
                    ("status", Json::str("ok")),
                    ("requests", Json::num(telemetry.requests.load(Ordering::Relaxed) as f64)),
                    ("errors", Json::num(telemetry.errors.load(Ordering::Relaxed) as f64)),
                    (
                        "accept_errors",
                        Json::num(telemetry.accept_errors.load(Ordering::Relaxed) as f64),
                    ),
                    ("batches", Json::num(telemetry.batches.load(Ordering::Relaxed) as f64)),
                    (
                        "batched_requests",
                        Json::num(telemetry.batched_requests.load(Ordering::Relaxed) as f64),
                    ),
                    (
                        "lanes_active",
                        Json::num(telemetry.lanes_active.load(Ordering::Relaxed) as f64),
                    ),
                    ("joins", Json::num(telemetry.joins.load(Ordering::Relaxed) as f64)),
                    ("retires", Json::num(telemetry.retires.load(Ordering::Relaxed) as f64)),
                    ("regroups", Json::num(telemetry.regroups.load(Ordering::Relaxed) as f64)),
                    ("occupancy_mean", Json::num(stats::mean(&occ))),
                    ("occupancy_max", Json::num(occ_max)),
                    (
                        "profile_store_version",
                        Json::num(ctx.profiles.as_deref().map_or(0, |s| s.version()) as f64),
                    ),
                    (
                        "profiles_loaded",
                        Json::num(ctx.profiles.as_deref().map_or(0, |s| s.len()) as f64),
                    ),
                    (
                        "auto_resolved",
                        Json::num(telemetry.auto_resolved.load(Ordering::Relaxed) as f64),
                    ),
                    (
                        "auto_fallbacks",
                        Json::num(telemetry.auto_fallbacks.load(Ordering::Relaxed) as f64),
                    ),
                    ("latency_p50_s", Json::num(stats::percentile(&lat, 50.0))),
                    ("latency_p95_s", Json::num(stats::percentile(&lat, 95.0))),
                    ("latency_p99_s", Json::num(stats::percentile(&lat, 99.0))),
                    ("latency_mean_s", Json::num(stats::mean(&lat))),
                    ("latency_samples", Json::num(lat.len() as f64)),
                    ("latency_seen", Json::num(lat_seen as f64)),
                    ("queue_mean_s", Json::num(stats::mean(&qs))),
                    ("queue_p95_s", Json::num(stats::percentile(&qs, 95.0))),
                ];
                // Sharded-only fields (module docs §Per-device stats):
                // gated on devices > 1 so the single-device response stays
                // byte-identical to the pre-sharding server.
                if ctx.devices > 1 {
                    let xfer = ctx.registry.pool().transfer_snapshots();
                    let per_device: Vec<Json> = telemetry
                        .per_device
                        .iter()
                        .enumerate()
                        .map(|(d, t)| {
                            let occ = t.occupancy.lock().unwrap().samples().to_vec();
                            let x = &xfer[d];
                            Json::obj(vec![
                                ("device", Json::num(d as f64)),
                                (
                                    "lanes_active",
                                    Json::num(t.lanes_active.load(Ordering::Relaxed) as f64),
                                ),
                                ("occupancy_mean", Json::num(stats::mean(&occ))),
                                (
                                    "occupancy_max",
                                    Json::num(t.occupancy_peak.load(Ordering::Relaxed) as f64),
                                ),
                                ("joins", Json::num(t.joins.load(Ordering::Relaxed) as f64)),
                                ("retires", Json::num(t.retires.load(Ordering::Relaxed) as f64)),
                                ("steals", Json::num(t.steals.load(Ordering::Relaxed) as f64)),
                                ("h2d_bytes", Json::num(x.h2d_bytes as f64)),
                                ("h2d_calls", Json::num(x.h2d_calls as f64)),
                                ("d2h_bytes", Json::num(x.d2h_bytes as f64)),
                                ("d2h_calls", Json::num(x.d2h_calls as f64)),
                            ])
                        })
                        .collect();
                    fields.extend([
                        ("devices", Json::num(ctx.devices as f64)),
                        ("steals", Json::num(telemetry.steals.load(Ordering::Relaxed) as f64)),
                        ("per_device", Json::Arr(per_device)),
                    ]);
                }
                Json::obj(fields)
            }
            "shutdown" => {
                ctx.router.signal_stop(&ctx.stop);
                let r = Json::obj(vec![("status", Json::str("ok")), ("stopping", Json::Bool(true))]);
                writeln!(writer, "{r}")?;
                return Ok(false);
            }
            "generate" => {
                // Resolve `policy:"auto"` to a concrete spec before the
                // job is queued, so the batch key (derived from the raw
                // payload) groups identically-resolved requests.
                let auto = resolve_auto(&mut payload, ctx);
                let (tx, rx) = mpsc::channel();
                // Routing front: the router picks the device queue under
                // its own lock and checks `stop` there — workers only
                // exit after observing `stop` (set under the same lock),
                // so a routed job is guaranteed a live worker;
                // enqueueing after shutdown would otherwise block
                // rx.recv() forever and deadlock Server::shutdown's join.
                let job = Job { payload, enqueued: Instant::now(), reply: tx, auto };
                if ctx.router.enqueue(job, &ctx.stop) {
                    rx.recv().unwrap_or_else(|_| err_json("worker dropped"))
                } else {
                    err_json("server is shutting down")
                }
            }
            other => err_json(&format!("unknown op '{other}'")),
        };
        writeln!(writer, "{resp}")?;
    }
    Ok(true)
}

/// A `generate` payload after wire validation, ready for dispatch.
#[derive(Debug)]
struct GenerateParams {
    model: String,
    bucket: String,
    policy_spec: String,
    req: Request,
}

/// Wire validation before any sampler is built: a `steps: 0` (or
/// out-of-schedule DDIM step count) used to trip the sampler
/// constructor's assert, panic the worker, and turn every later request
/// on that worker into "worker dropped"; a fractional seed used to
/// truncate silently. (The schedule upper bound on `steps` needs the
/// engine and is checked at dispatch.)
fn parse_generate(payload: &Json) -> Result<GenerateParams> {
    // Routing fields must be strings when present (absent = default). A
    // wrong-typed field is unkeyable for the batch scheduler, so it must
    // also fail validation here — silently substituting the default would
    // serve the wrong model.
    let field_str = |k: &str, default: &str| -> Result<String> {
        match payload.get(k) {
            None => Ok(default.to_string()),
            Some(v) => v
                .as_str()
                .map(str::to_string)
                .ok_or_else(|| anyhow!("{k} must be a string")),
        }
    };
    let model = field_str("model", DEFAULT_MODEL)?;
    let bucket = field_str("bucket", DEFAULT_BUCKET)?;
    let policy_spec = field_str("policy", DEFAULT_POLICY)?;
    let prompt = payload
        .get("prompt")
        .and_then(|v| v.as_str())
        .unwrap_or_default()
        .to_string();

    let seed = match payload.get("seed") {
        None => 0,
        Some(v) => {
            let s = v.as_f64().ok_or_else(|| anyhow!("seed must be a number"))?;
            // Reject fractions the same way `steps` does: `1.5 as u64`
            // would silently truncate to 1 and serve the wrong video.
            if !s.is_finite() || s < 0.0 || s.fract() != 0.0 {
                return Err(anyhow!(
                    "seed must be a finite non-negative integer, got {s}"
                ));
            }
            s as u64
        }
    };
    let steps = match payload.get("steps") {
        None => None,
        Some(v) => {
            let s = v
                .as_f64()
                .ok_or_else(|| anyhow!("steps must be a positive integer"))?;
            if !s.is_finite() || s < 1.0 || s.fract() != 0.0 {
                return Err(anyhow!("steps must be a positive integer, got {s}"));
            }
            Some(s as usize)
        }
    };
    let cfg_scale = match payload.get("cfg_scale") {
        None => None,
        Some(v) => {
            let c = v.as_f64().ok_or_else(|| anyhow!("cfg_scale must be a number"))?;
            if !c.is_finite() {
                return Err(anyhow!("cfg_scale must be finite, got {c}"));
            }
            Some(c)
        }
    };

    let mut req = Request::new(&prompt, seed);
    req.steps = steps;
    req.cfg_scale = cfg_scale;
    Ok(GenerateParams { model, bucket, policy_spec, req })
}

/// One `generate` response object (module docs list the fields).
/// `policy_spec` is the concrete spec that was executed (post-`auto`
/// resolution); `auto` adds the resolution echo fields when the request
/// asked for `policy=auto`.
fn generate_response(
    model: &str,
    bucket: &str,
    r: &RunResult,
    queue_s: f64,
    batch_size: usize,
    policy_spec: &str,
    auto: Option<&AutoInfo>,
) -> Json {
    let s = &r.stats;
    let latent_l2 = r
        .latents
        .data
        .iter()
        .map(|&v| v as f64 * v as f64)
        .sum::<f64>()
        .sqrt();
    let mut fields = vec![
        ("status", Json::str("ok")),
        ("model", Json::str(model)),
        ("bucket", Json::str(bucket)),
        ("policy", Json::str(&s.policy)),
        ("policy_spec", Json::str(policy_spec)),
        ("wall_s", Json::num(s.wall_s)),
        ("queue_s", Json::num(queue_s)),
        ("steps", Json::num(s.per_step_s.len() as f64)),
        ("computed_units", Json::num(s.computed_units as f64)),
        ("reused_units", Json::num(s.reused_units as f64)),
        ("reuse_fraction", Json::num(s.reuse_fraction())),
        ("cache_peak_bytes", Json::num(s.cache_peak_bytes as f64)),
        ("h2d_bytes", Json::num(s.h2d_bytes as f64)),
        ("h2d_calls", Json::num(s.h2d_calls as f64)),
        ("d2h_bytes", Json::num(s.d2h_bytes as f64)),
        ("d2h_calls", Json::num(s.d2h_calls as f64)),
        ("batch_size", Json::num(batch_size as f64)),
        ("latent_l2", Json::num(latent_l2)),
    ];
    if let Some(a) = auto {
        fields.extend([
            ("policy_requested", Json::str(AUTO_POLICY)),
            ("resolved_policy", Json::str(&a.spec)),
            ("profile_version", Json::num(a.profile_version as f64)),
            ("profile_store_version", Json::num(a.store_version as f64)),
            ("profile_match", Json::str(a.matched)),
            ("profile_fallback", Json::Bool(a.fallback)),
        ]);
    }
    Json::obj(fields)
}

/// Blocking JSON-lines client for the server (used by examples and tests).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    /// Default socket read/write timeout: generous enough for a queued
    /// full-schedule generation under load, finite so a hung server fails
    /// a bench or the autotune CLI instead of stalling it forever.
    pub const DEFAULT_TIMEOUT: Duration = Duration::from_secs(120);

    pub fn connect(addr: &SocketAddr) -> Result<Self> {
        Self::connect_with_timeout(addr, Some(Self::DEFAULT_TIMEOUT))
    }

    /// Connect with an explicit socket timeout (`None` = block forever,
    /// the pre-timeout behavior).
    pub fn connect_with_timeout(addr: &SocketAddr, timeout: Option<Duration>) -> Result<Self> {
        let stream = TcpStream::connect(addr).context("connect")?;
        stream.set_read_timeout(timeout)?;
        stream.set_write_timeout(timeout)?;
        let writer = stream.try_clone()?;
        Ok(Self { reader: BufReader::new(stream), writer })
    }

    /// Adjust the socket timeout of an existing connection.
    pub fn set_timeout(&mut self, timeout: Option<Duration>) -> Result<()> {
        let stream = self.reader.get_ref();
        stream.set_read_timeout(timeout)?;
        stream.set_write_timeout(timeout)?;
        Ok(())
    }

    /// Send one request object; wait for one response line.
    pub fn call(&mut self, req: &Json) -> Result<Json> {
        writeln!(self.writer, "{req}")?;
        let mut line = String::new();
        match self.reader.read_line(&mut line) {
            Ok(0) => return Err(anyhow!("server closed connection")),
            Ok(_) => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                return Err(anyhow!("timed out waiting for server response"));
            }
            Err(e) => return Err(e.into()),
        }
        if line.trim().is_empty() {
            return Err(anyhow!("server closed connection"));
        }
        json::parse(line.trim()).map_err(|e| anyhow!("bad response: {e}"))
    }

    pub fn ping(&mut self) -> Result<bool> {
        let r = self.call(&Json::obj(vec![("op", Json::str("ping"))]))?;
        Ok(r.get("pong").and_then(|v| v.as_bool()).unwrap_or(false))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gen_payload(fields: Vec<(&str, Json)>) -> Json {
        let mut all = vec![("op", Json::str("generate"))];
        all.extend(fields);
        Json::obj(all)
    }

    #[test]
    fn cohort_key_groups_across_steps_cfg_policy_seed_prompt() {
        // Only (model, bucket) pin the shared device pass: sessions carry
        // their own schedule cursor, CFG scalar and policy, so everything
        // else batches freely under the continuous scheduler.
        let a = gen_payload(vec![
            ("policy", Json::str("foresight")),
            ("steps", Json::num(12.0)),
            ("cfg_scale", Json::num(3.0)),
            ("seed", Json::num(1.0)),
            ("prompt", Json::str("a lake")),
        ]);
        let b = gen_payload(vec![
            ("policy", Json::str("static")),
            ("steps", Json::num(7.0)),
            ("seed", Json::num(999.0)),
            ("prompt", Json::str("a storm")),
        ]);
        assert_eq!(cohort_key(&a), cohort_key(&b));
        assert!(cohort_key(&a).is_some());
        // absent routing fields resolve to the wire defaults
        assert_eq!(
            cohort_key(&gen_payload(vec![])),
            Some((DEFAULT_MODEL.to_string(), DEFAULT_BUCKET.to_string()))
        );
    }

    #[test]
    fn cohort_key_separates_models_and_buckets() {
        let base = gen_payload(vec![]);
        for other in [
            gen_payload(vec![("bucket", Json::str("other"))]),
            gen_payload(vec![("model", Json::str("latte-sim"))]),
        ] {
            assert_ne!(cohort_key(&base), cohort_key(&other), "{other}");
        }
    }

    #[test]
    fn cohort_key_rejects_unkeyable_payloads() {
        // wrong-typed routing fields dispatch solo (validation fails them)
        assert!(cohort_key(&gen_payload(vec![("model", Json::num(4.0))])).is_none());
        assert!(cohort_key(&gen_payload(vec![("bucket", Json::num(4.0))])).is_none());
        assert!(cohort_key(&Json::obj(vec![("op", Json::str("ping"))])).is_none());
    }

    #[test]
    fn client_call_times_out_against_unresponsive_server() {
        // A listener that accepts but never replies must fail a call
        // within the configured timeout instead of hanging the caller
        // forever (the pre-timeout behavior this regression test pins).
        use std::net::TcpListener;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let hold = std::thread::spawn(move || {
            // Keep the accepted connection open, silently, long enough to
            // outlive the client's timeout.
            let conn = listener.accept();
            std::thread::sleep(Duration::from_millis(1200));
            drop(conn);
        });
        let mut c = Client::connect_with_timeout(&addr, Some(Duration::from_millis(150))).unwrap();
        let t0 = Instant::now();
        let err = c
            .call(&Json::obj(vec![("op", Json::str("ping"))]))
            .unwrap_err()
            .to_string();
        let took = t0.elapsed();
        assert!(err.contains("timed out"), "{err}");
        assert!(
            took < Duration::from_millis(1000),
            "timeout did not bound the call: {took:?}"
        );
        let _ = hold.join();
    }

    #[test]
    fn parse_generate_rejects_fractional_seed() {
        let err = parse_generate(&gen_payload(vec![("seed", Json::num(1.5))]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("seed"), "{err}");
        let err = parse_generate(&gen_payload(vec![("seed", Json::num(-3.0))]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("seed"), "{err}");
        // integral-valued floats are fine
        let p = parse_generate(&gen_payload(vec![("seed", Json::num(7.0))])).unwrap();
        assert_eq!(p.req.seed, 7);
    }

    #[test]
    fn parse_generate_rejects_wrong_typed_routing_fields() {
        // Unkeyable for the batch scheduler ⇒ must also fail validation
        // (not silently fall back to the default model).
        for k in ["model", "bucket", "policy"] {
            let err = parse_generate(&gen_payload(vec![(k, Json::num(4.0))]))
                .unwrap_err()
                .to_string();
            assert!(err.contains(k), "{k}: {err}");
        }
        // absent routing fields still default
        let p = parse_generate(&gen_payload(vec![])).unwrap();
        assert_eq!(p.model, DEFAULT_MODEL);
        assert_eq!(p.policy_spec, DEFAULT_POLICY);
    }

    #[test]
    fn accept_retry_classification() {
        use std::io::{Error, ErrorKind};
        assert!(accept_should_retry(&Error::new(ErrorKind::ConnectionAborted, "x")));
        assert!(accept_should_retry(&Error::new(ErrorKind::ConnectionReset, "x")));
        assert!(accept_should_retry(&Error::from_raw_os_error(24))); // EMFILE
        assert!(accept_should_retry(&Error::from_raw_os_error(23))); // ENFILE
        assert!(!accept_should_retry(&Error::from_raw_os_error(9))); // EBADF
        assert!(!accept_should_retry(&Error::new(ErrorKind::InvalidInput, "x")));
    }
}
