//! TCP JSON-lines serving front-end: routing front, per-device queues,
//! continuous (step-level) batching scheduler, sharded worker pool.
//!
//! This is the L3 deployment surface: a newline-delimited JSON protocol
//! over TCP (one request object per line, one response object per line),
//! per-device FIFO queues whose workers drive **session cohorts** one
//! denoising step at a time, and aggregate latency telemetry. Python is
//! never involved; workers drive the PJRT executables directly.
//!
//! # Sharded topology (`--devices N`)
//!
//! The server scales out across **N runtime replicas**
//! ([`crate::runtime::DevicePool`]): each device ordinal owns an
//! independent PJRT client, executable caches and transfer meters, the
//! [`EngineRegistry`] loads every (model, bucket) once *per device*, and
//! the scheduler runs one worker per device over a per-device queue
//! (`devices: 1`, the default, keeps the classic shared-queue worker
//! pool on device 0 — behavior and wire responses are byte-compatible
//! with the single-device server).
//!
//! **Routing rules** (admit time, under one router lock): a `generate`
//! job goes to (1) *cohort affinity* — the device whose in-flight cohort
//! has the same (model, bucket) key and a spare lane absorbs it at its
//! next step boundary; fewest active lanes wins, ties to the lowest
//! ordinal — else (2) *least-loaded* — fewest active lanes, ties broken
//! by shortest queue (FIFO pressure), then lowest ordinal. Per-device
//! queues are strict FIFO and boundary admission still takes only the
//! compatible queue-front **prefix** (scheduler docs), so a routed job is
//! never reordered behind later arrivals for its device.
//!
//! **Steal policy** (step boundaries only): an idle device first takes
//! the *front* job of the most-loaded device's queue (free — the oldest
//! queued job just starts earlier, preserving per-key FIFO); when every
//! queue is empty it asks for a **session migration**, and the
//! most-loaded device — at its next step boundary, holding ≥ 2 lanes —
//! moves one in-flight session over via
//! [`crate::engine::Session::migrate`]: exactly one lane download on the
//! source plus one upload on the target charged to the request's
//! `RunStats` (cache/conditioning round-trips are metered only by the
//! runtimes' `TransferStats`), with latents bit-identical to a
//! never-migrated run. A device mid-cohort with spare lanes and an empty
//! queue may also pull compatible queue-front jobs from other devices.
//!
//! **Per-device stats schema**: with `devices > 1` the `stats` op adds
//! `devices` (count), `steals` (sessions migrated, total) and a
//! `per_device` array of `{device, lanes_active, occupancy_mean,
//! occupancy_max, joins, retires, steals, h2d_bytes, h2d_calls,
//! d2h_bytes, d2h_calls}` — transfer counters come straight from each
//! replica's [`crate::runtime::TransferStats`]. All existing aggregate
//! fields keep their names and meaning; at `devices: 1` the response is
//! unchanged.
//!
//! Protocol ops:
//! * `{"op":"ping"}` → `{"status":"ok","pong":true}`
//! * `{"op":"generate","model":..,"bucket":..,"policy":..,"prompt":..,
//!    "seed":..,"steps"?:..,"cfg_scale"?:..,"deadline_ms"?:..}` → run
//!    stats (including the
//!    `h2d_bytes`/`h2d_calls`/`d2h_bytes`/`d2h_calls` transfer meters,
//!    the `batch_size` the request was served at, the concrete
//!    `policy_spec` that was executed, and a `latent_l2` checksum of the
//!    final latent for wire-level equivalence checks). `deadline_ms`
//!    (optional, positive integer) is a completion deadline measured
//!    from arrival; a request that cannot finish in time is answered
//!    `{"status":"error", "deadline_exceeded":true, ...}` instead of
//!    occupying a lane (§Overload control). At capacity the request is
//!    refused with `{"status":"error", "overloaded":true,
//!    "retry_after_ms":.., "queue_depth":..}` without being queued.
//! * `{"op":"stats"}` → server-level counters + latency percentiles
//! * `{"op":"metrics"}` → the same counters rendered as Prometheus text
//!    exposition (§Observability) inside `{"status":"ok","body":..,
//!    "content_type":"text/plain; version=0.0.4"}`
//! * `{"op":"trace","since"?:<seq>,"enable"?:bool}` → incremental drain of
//!    the process-wide event tracer as Chrome trace-event objects
//!    (§Observability)
//! * `{"op":"shutdown"}` → stops the server
//!
//! A `generate` request may additionally send `"trace": true` to get a
//! compact per-step reuse timeline back in its response (§Observability).
//!
//! # Observability
//!
//! Three surfaces expose what the aggregate `stats` op cannot
//! (see [`crate::trace`] and the crate docs §Observability):
//!
//! * **`{"op":"trace","since":<seq>,"enable":<bool>}`** — drains the
//!   bounded ring buffers of the process-wide tracer, returning
//!   `{"status":"ok", "events":[..], "next":<seq>, "emitted":..,
//!   "dropped":.., "enabled":..}`. `events` are Chrome trace-event
//!   objects ([`crate::trace::chrome`]) ready to wrap in a
//!   `{"traceEvents":[...]}` envelope (the `foresight trace` CLI does
//!   exactly that); pass the returned `next` as the following request's
//!   `since` to read incrementally. The drain is non-destructive. The
//!   optional `enable` flag toggles recording at runtime (tracing also
//!   starts enabled under `FORESIGHT_TRACE=1`). **Drop semantics**: the
//!   tracer never blocks a hot path — a contended ring shard or a full
//!   ring (capacity `FORESIGHT_TRACE_RING`, default 16384 events/shard)
//!   drops events and counts them in `trace_drops` instead of stalling a
//!   step boundary; `seq` gaps in a drain are exactly those drops.
//! * **`"trace": true` on a `generate` request** — the response gains a
//!   `reuse_timeline` array of `{step, site, action, lambda}` objects:
//!   the policy's planned branch-0 decision per measured site per step
//!   (`action` ∈ `predict`/`reuse`/`compute`; `predict` = the site's
//!   output is forecast from its history ring instead of replayed) with
//!   the λ threshold the decision compared against (omitted when the
//!   policy records none). Works
//!   whether or not the tracer is enabled — the timeline comes from the
//!   session's own `RunResult`, not the ring. The timeline's `reuse`
//!   count is the *planned* branch-0 reuse total; it never exceeds the
//!   response's `reused_units + fallback_units` (a planned reuse either
//!   executed or fell back on a cold cache).
//! * **`{"op":"metrics"}`** — the full `stats` surface in Prometheus
//!   text exposition format. **Naming scheme**: every scalar stats key
//!   `k` exports as gauge `foresight_<k>` (e.g. `foresight_requests`,
//!   `foresight_latency_p99_s`); with `devices > 1` the `per_device`
//!   breakdown exports as `foresight_device_<k>{device="<ordinal>"}`.
//!   The table driving the rendering ([`PROM_METRICS`]) is cross-checked
//!   against [`Telemetry`] by the `analysis::lint` ledger pass, so a new
//!   counter cannot ship without a scrape line.
//!
//! # `policy=auto` resolution
//!
//! With a [`crate::autotune::ProfileStore`] loaded
//! ([`ServerConfig::profiles`], CLI `serve --profiles <path>`), a
//! `generate` request may send `policy: "auto"`. The connection handler
//! resolves it to a concrete spec **at enqueue time**: the payload's
//! `policy` field is rewritten to the tuned spec before the job is
//! queued, so the scheduler and the response only ever see concrete
//! specs. (Under continuous batching the policy no longer gates pass
//! sharing at all — auto requests batch with any same-(model, bucket)
//! traffic.) Resolution follows
//! [`crate::autotune::ProfileStore::lookup`]: exact
//! (model, bucket, sampler, steps) profile, else the nearest profile of
//! the same (model, sampler), else [`DEFAULT_POLICY`] with a counted
//! fallback. A matched profile whose spec this build cannot parse (a
//! hand-edited or newer-format store) also falls back to the default with
//! a counted fallback rather than failing every auto request at dispatch.
//! Auto responses additionally echo `policy_requested: "auto"`,
//! the `resolved_policy`, the `profile_version`/`profile_store_version`
//! behind it, the `profile_match` kind (`exact`/`nearest`/`default`) and
//! `profile_fallback`; the `stats` op reports `profile_store_version`,
//! `profiles_loaded`, `auto_resolved` and `auto_fallbacks` so operators
//! can see when `auto` traffic is served untuned. Resolution happens
//! before wire validation (it only needs a concrete spec), so a request
//! that later fails validation may still tick the resolution counters.
//!
//! # Continuous batching
//!
//! Workers batch at **step granularity**, not request granularity (the
//! `scheduler` submodule). A worker blocks for the first `generate` job —
//! an empty queue waits on a condvar, never out a window — starts a
//! [`crate::engine::session::Session`] for it, and then advances its
//! cohort one denoising step per pass. At every step boundary it admits
//! queued *compatible* jobs (same raw `model`/`bucket` — the only fields
//! that pin the shared device pass) up to [`ServerConfig::max_batch`],
//! and retires finished lanes immediately: requests with **different**
//! `steps`, `cfg_scale` or `policy` now share passes, a late arrival
//! joins an in-flight batch at the next boundary, and a short request
//! never waits for a long batchmate to finish. A job whose routing
//! fields cannot be keyed (wrong types) dispatches solo so validation
//! fails it individually; seeds and prompts are deliberately never part
//! of the key — per-request latents, text conditioning, policy state and
//! drift measurements are per-session inside the engine, and each
//! response's transfer meters report the request's standalone cost
//! (unchanged by batching; see the `engine::session` docs §Byte model).
//! Every `generate` response echoes `batch_size`: the largest cohort the
//! request ever shared a device pass with. [`ServerConfig::admit_window_ms`]
//! (default 0) optionally lets a *fresh* cohort linger for batchmates
//! before its first step.
//!
//! # Overload control
//!
//! Three mechanisms keep the server answering in bounded time instead of
//! queueing without limit (the scheduler module docs describe the
//! enforcement points):
//!
//! * **Bounded admission** ([`ServerConfig::max_queue`], CLI
//!   `--max-queue`; 0 = unbounded): a `generate` whose routed device
//!   queue *and* the globally shortest queue are both at the bound is
//!   answered `{"status":"error", "overloaded":true,
//!   "retry_after_ms":.., "queue_depth":..}` immediately — never queued,
//!   never blocking the connection. `retry_after_ms` estimates one drain
//!   of the shortest queue from the observed mean latency. Rejects count
//!   in the `stats` op's `rejects` (deliberately *not* in
//!   `requests`/`errors`: the job was never admitted);
//!   `queue_depth`/`queue_depth_peak` expose current and high-water
//!   depths (per-device `queue_depth` under `per_device`). [`Client`]
//!   retries overloaded responses transparently with capped exponential
//!   backoff + jitter honoring the hint ([`Client::call_retrying`],
//!   [`Backoff`]; [`Backoff::none`] opts out).
//! * **Deadlines** (wire `deadline_ms`, a positive integer of
//!   milliseconds from arrival): checked at admission and at every
//!   cohort step boundary — both for queued jobs and for in-flight
//!   lanes, which retire early ([`crate::engine::Session::abandon`])
//!   rather than spending further device passes on a result nobody is
//!   waiting for. Expired requests are answered `{"status":"error",
//!   "deadline_exceeded":true}` and counted in `deadline_misses` (and
//!   `errors`).
//! * **Quality-for-latency degradation**
//!   ([`ServerConfig::degrade_threshold`], CLI `--degrade`; 0 =
//!   disabled): when every device queue holds ≥ threshold jobs, a
//!   `policy=auto` request resolves to the matched profile's fastest
//!   frontier point still within its **own min-PSNR budget**
//!   ([`crate::autotune::degrade_select`]) instead of the tuned spec —
//!   the Foresight quality/latency dial used as an overload valve, never
//!   below the tuned quality contract. Note stores written by `foresight
//!   autotune` already persist the fastest in-budget point as the spec,
//!   so a real swap needs a store with quality headroom (a stricter
//!   serve-time budget or hand-tuned spec). Swapped responses echo
//!   `degraded:true` + `degraded_from`; `stats` counts `degrade_swaps`
//!   and `degrade_headroom_s` (profiled wall-clock recovered).
//!
//! `generate` payloads are validated before a sampler is built: `steps`
//! must be a positive integer no larger than the preset's training
//! schedule, `seed` must be a non-negative **integer** (fractional seeds
//! used to truncate silently), `cfg_scale` must be a finite number. A
//! malformed field is a per-request `{"status":"error"}` response, never a
//! worker panic — and never poisons the rest of its batch.
//!
//! # Robustness
//!
//! The accept loop retries transient `accept(2)` failures (connection
//! aborts/resets, EMFILE/ENFILE/ENOBUFS/ENOMEM under load) with capped
//! exponential backoff instead of silently killing the listener, counting
//! them in the `stats` op's `accept_errors`; only genuinely fatal errors
//! (the listener itself is gone) stop it. Latency/queue telemetry lives in
//! bounded [`Reservoir`]s (exact until [`ServerConfig::telemetry_reservoir`]
//! samples, then uniform reservoir sampling), so sustained traffic cannot
//! grow server memory without bound; the `stats` op reports p50/p95/p99
//! latency, mean/p95 queueing, and the reservoir's `latency_samples` /
//! `latency_seen` accounting. Scheduler occupancy is observable the same
//! way: `lanes_active` (gauge), `occupancy_mean`/`occupancy_max` (per-step
//! cohort size over a reservoir), and the `joins` / `retires` / `regroups`
//! counters expose how much continuous batching is actually happening.
//!
//! [`Client`] sets socket read/write timeouts
//! ([`Client::DEFAULT_TIMEOUT`], overridable via
//! [`Client::connect_with_timeout`]) so a hung server fails a bench or
//! the autotune CLI with an error instead of stalling it forever.

use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use crate::autotune::ProfileStore;
use crate::config::Manifest;
use crate::engine::{Engine, Request, RunResult};
use crate::model::LoadedModel;
use crate::policy::build_policy;
use crate::runtime::{DevicePool, Runtime};
use crate::trace;
use crate::util::json::{self, Json};
use crate::util::stats::{self, Reservoir};
use crate::util::sync::{
    OrderedMutex, RANK_DEVICE_OCCUPANCY, RANK_TELEMETRY_LATENCY, RANK_TELEMETRY_OCCUPANCY,
    RANK_TELEMETRY_QUEUE,
};

mod scheduler;

/// Wire-level defaults applied when a `generate` payload omits a field
/// (shared by validation and the batch key so they can never disagree).
const DEFAULT_MODEL: &str = "opensora-sim";
const DEFAULT_BUCKET: &str = "240p-2s";
pub const DEFAULT_POLICY: &str = "foresight";
/// The sentinel spec resolved through the profile store (module docs
/// §`policy=auto` resolution).
pub const AUTO_POLICY: &str = "auto";

/// Engines per (model, bucket) **per device replica**, loaded once and
/// shared by all workers. Each (model, bucket) pair gets one independent
/// [`Engine`] on every device of the pool (module docs §Sharded
/// topology); index `d` of a pair's vector is pinned to pool ordinal `d`.
pub struct EngineRegistry {
    pool: Arc<DevicePool>,
    engines: BTreeMap<(String, String), Vec<Arc<Engine>>>,
}

impl EngineRegistry {
    /// Load the given (model, bucket) pairs from the artifact manifest
    /// onto a single runtime (device 0). The single-device entry point
    /// every pre-sharding caller keeps using.
    pub fn load(rt: Arc<Runtime>, manifest: &Manifest, pairs: &[(String, String)]) -> Result<Self> {
        Self::load_pool(Arc::new(DevicePool::from_runtimes(vec![rt])?), manifest, pairs)
    }

    /// Load every (model, bucket) pair once per device of the pool.
    pub fn load_pool(
        pool: Arc<DevicePool>,
        manifest: &Manifest,
        pairs: &[(String, String)],
    ) -> Result<Self> {
        let mut engines = BTreeMap::new();
        for (model, bucket) in pairs {
            let mut per_dev = Vec::with_capacity(pool.len());
            for rt in pool.devices() {
                let lm = Arc::new(LoadedModel::load(rt.clone(), manifest, model, bucket)?);
                per_dev.push(Arc::new(Engine::new(lm, manifest.schedule)));
            }
            engines.insert((model.clone(), bucket.clone()), per_dev);
        }
        Ok(Self { pool, engines })
    }

    /// The device-0 replica (single-device callers).
    pub fn get(&self, model: &str, bucket: &str) -> Result<&Arc<Engine>> {
        self.get_on(model, bucket, 0)
    }

    /// The replica pinned to device ordinal `device`.
    pub fn get_on(&self, model: &str, bucket: &str, device: usize) -> Result<&Arc<Engine>> {
        let per_dev = self
            .engines
            .get(&(model.to_string(), bucket.to_string()))
            .ok_or_else(|| anyhow!("no engine loaded for {model}/{bucket}"))?;
        per_dev
            .get(device)
            .ok_or_else(|| anyhow!("no device-{device} replica for {model}/{bucket}"))
    }

    /// Number of device replicas behind this registry.
    pub fn devices(&self) -> usize {
        self.pool.len()
    }

    pub fn pool(&self) -> &Arc<DevicePool> {
        &self.pool
    }

    pub fn keys(&self) -> Vec<(String, String)> {
        self.engines.keys().cloned().collect()
    }
}

struct Job {
    payload: Json,
    enqueued: Instant,
    /// Absolute completion deadline (wire `deadline_ms`, measured from
    /// arrival). Enforced by the scheduler at admission and at every step
    /// boundary; `None` = no deadline.
    deadline: Option<Instant>,
    reply: mpsc::Sender<Json>,
    /// Present when the request sent `policy:"auto"` (the payload's policy
    /// field has already been rewritten to `auto.spec`).
    auto: Option<AutoInfo>,
    /// Request span id allocated at the wire front; every scheduler/
    /// session/runtime event this job causes is tagged with it
    /// (module docs §Observability).
    trace_id: u64,
    /// The request sent `"trace": true` — its response gets the compact
    /// per-step `reuse_timeline`.
    want_trace: bool,
}

/// Outcome of resolving a `policy:"auto"` request at enqueue time.
#[derive(Debug, Clone)]
struct AutoInfo {
    /// The concrete spec `auto` resolved to.
    spec: String,
    /// Generation counter of the store that resolved it (0 = no store).
    store_version: u64,
    /// `profile_version` of the matched profile (0 on fallback).
    profile_version: u64,
    /// `exact` | `nearest` | `default`.
    matched: &'static str,
    /// True when no profile matched and [`DEFAULT_POLICY`] was served.
    fallback: bool,
    /// True when queue pressure degraded the resolution to a faster
    /// in-budget frontier point (module docs §Overload control).
    degraded: bool,
    /// The spec the profile would have served without pressure (set only
    /// when `degraded`).
    degraded_from: Option<String>,
}

/// Resolve `policy:"auto"` against the loaded profile store, rewriting the
/// payload's `policy` field to the concrete spec so the batch key and wire
/// validation only ever see concrete specs. Returns `None` for non-auto
/// payloads. Counts the resolution (or fallback) in the telemetry.
fn resolve_auto(payload: &mut Json, ctx: &ServeCtx) -> Option<AutoInfo> {
    if payload.get("policy").and_then(|p| p.as_str()) != Some(AUTO_POLICY) {
        return None;
    }
    let str_field = |k: &str, default: &str| -> String {
        // A wrong-typed field resolves via the default here; the request
        // still fails wire validation at dispatch, this just guarantees a
        // concrete spec.
        payload
            .get(k)
            .and_then(|v| v.as_str())
            .unwrap_or(default)
            .to_string()
    };
    let model = str_field("model", DEFAULT_MODEL);
    let bucket = str_field("bucket", DEFAULT_BUCKET);
    let resolved = ctx.registry.get(&model, &bucket).ok().and_then(|engine| {
        let info = &engine.model().info;
        let steps = match payload.get("steps").and_then(|v| v.as_f64()) {
            Some(s) if s.is_finite() && s >= 1.0 && s.fract() == 0.0 => s as usize,
            // absent (or malformed — rejected later anyway): the preset
            // default, which is what the request would run at.
            _ => info.steps,
        };
        let store = ctx.profiles.as_deref()?;
        let m = store.lookup(&model, &bucket, info.sampler.name(), steps)?;
        // A stored spec this build cannot parse (hand-edited store, or a
        // newer writer's syntax) must not poison every auto request with a
        // dispatch error counted as a successful resolution — serve the
        // default and count the fallback instead.
        build_policy(&m.profile().spec, info, steps).ok()?;
        let mut spec = m.profile().spec.clone();
        let mut degraded = false;
        let mut degraded_from = None;
        // Load-adaptive degradation (module docs §Overload control): under
        // queue pressure, serve the profile's fastest frontier point that
        // still meets its own min-PSNR budget. The *minimum* queue depth
        // is the pressure signal — with job steals live, one empty queue
        // means the next arrival need not wait. A swap only happens when
        // the tier differs from the tuned spec and parses in this build;
        // the recovered headroom is the frontier's measured wall delta.
        if ctx.degrade_threshold > 0
            && ctx
                .router
                .queue_depths()
                .iter()
                .min()
                .is_some_and(|&d| d >= ctx.degrade_threshold)
        {
            if let Some(tier) = crate::autotune::degrade_select(m.profile()) {
                if tier.spec != spec && build_policy(&tier.spec, info, steps).is_ok() {
                    let normal_wall = m
                        .profile()
                        .frontier
                        .iter()
                        .find(|p| p.spec == spec)
                        .map_or(tier.wall_s, |p| p.wall_s);
                    let headroom_us = ((normal_wall - tier.wall_s).max(0.0) * 1e6) as u64;
                    ctx.telemetry.degrade_swaps.fetch_add(1, Ordering::Relaxed);
                    ctx.telemetry
                        .degrade_headroom_us
                        .fetch_add(headroom_us, Ordering::Relaxed);
                    degraded_from = Some(std::mem::replace(&mut spec, tier.spec.clone()));
                    degraded = true;
                }
            }
        }
        Some(AutoInfo {
            spec,
            store_version: store.version(),
            profile_version: m.profile().profile_version,
            matched: m.kind(),
            fallback: false,
            degraded,
            degraded_from,
        })
    });
    let auto = resolved.unwrap_or_else(|| AutoInfo {
        spec: DEFAULT_POLICY.to_string(),
        store_version: ctx.profiles.as_deref().map_or(0, |s| s.version()),
        profile_version: 0,
        matched: "default",
        fallback: true,
        degraded: false,
        degraded_from: None,
    });
    if auto.fallback {
        ctx.telemetry.auto_fallbacks.fetch_add(1, Ordering::Relaxed);
    } else {
        ctx.telemetry.auto_resolved.fetch_add(1, Ordering::Relaxed);
    }
    if let Json::Obj(o) = payload {
        o.insert("policy".to_string(), Json::Str(auto.spec.clone()));
    }
    Some(auto)
}

/// Cohort compatibility key (module docs §Continuous batching): only the
/// fields that pin the shared device pass — the engine a session runs on.
/// `steps`, `cfg_scale` and `policy` are per-session state and batch
/// freely. Compared on the **raw** wire values; `None` when the payload
/// cannot be keyed (non-generate op, wrong-typed routing fields — those
/// dispatch solo and fail validation individually).
fn cohort_key(payload: &Json) -> Option<(String, String)> {
    if payload.get("op").and_then(|o| o.as_str()) != Some("generate") {
        return None;
    }
    let get_str = |k: &str, default: &str| -> Option<String> {
        match payload.get(k) {
            None => Some(default.to_string()),
            Some(v) => v.as_str().map(str::to_string),
        }
    };
    let model = get_str("model", DEFAULT_MODEL)?;
    let bucket = get_str("bucket", DEFAULT_BUCKET)?;
    Some((model, bucket))
}

struct Telemetry {
    /// Jobs admitted for processing (including ones answered with a
    /// validation or deadline error; excluding capacity `rejects`).
    requests: AtomicU64,
    /// Admitted jobs answered with an error response of any kind.
    errors: AtomicU64,
    /// Transient accept(2) failures retried by the listener loop.
    accept_errors: AtomicU64,
    /// Cohorts started (a cohort of any size counts once).
    batches: AtomicU64,
    /// Requests that shared a device pass with at least one other.
    batched_requests: AtomicU64,
    /// Sessions currently in flight across all workers (gauge).
    lanes_active: AtomicU64,
    /// Sessions admitted into an already-stepping cohort (mid-flight).
    joins: AtomicU64,
    /// Sessions finished and answered.
    retires: AtomicU64,
    /// Cohort steps that rebuilt/compacted the resident stack because
    /// membership changed since the previous step.
    regroups: AtomicU64,
    /// Largest per-step cohort occupancy ever observed (a true running
    /// max — the reservoir below is a uniform sample and cannot carry a
    /// max statistic once it evicts).
    occupancy_peak: AtomicU64,
    /// Per-step cohort occupancy (lanes advanced per pass).
    occupancy: OrderedMutex<Reservoir>,
    /// `policy=auto` requests resolved to a tuned profile.
    auto_resolved: AtomicU64,
    /// `policy=auto` requests served [`DEFAULT_POLICY`] because no profile
    /// matched (or no store was loaded) — untuned traffic.
    auto_fallbacks: AtomicU64,
    /// Sessions migrated between devices by work stealing (total; each is
    /// also credited to the *target* device's [`DeviceTelemetry`]).
    steals: AtomicU64,
    /// Reuse units served by linear-multistep forecast (`lms_combine`)
    /// instead of verbatim replay, summed over retired sessions.
    forecasts: AtomicU64,
    /// Planned forecasts that replayed verbatim because the site's
    /// history ring was shallower than the predictor order, summed over
    /// retired sessions.
    forecast_fallbacks: AtomicU64,
    /// `generate` jobs refused at admission because every candidate queue
    /// sat at `--max-queue` (the `overloaded` wire response). Rejected
    /// jobs are **not** counted in `requests`/`errors` — they were never
    /// admitted.
    rejects: AtomicU64,
    /// Admitted jobs answered with the deadline-exceeded error (expired
    /// while queued or in flight). Each also counts in `errors`.
    deadline_misses: AtomicU64,
    /// `policy=auto` resolutions swapped to a faster in-budget frontier
    /// point under queue pressure (module docs §Overload control).
    degrade_swaps: AtomicU64,
    /// Cumulative profiled wall-clock recovered by those swaps, in µs
    /// (the frontier's measured per-request delta, not a live wall
    /// measurement).
    degrade_headroom_us: AtomicU64,
    /// Deepest any device queue has ever been at enqueue time.
    queue_depth_peak: AtomicU64,
    /// Events ring-buffered by the process-wide tracer (monotonic mirror
    /// of [`crate::trace::Tracer::events_total`], refreshed on `stats`).
    trace_events: AtomicU64,
    /// Trace events dropped by shard contention or ring overflow instead
    /// of blocking a hot path (mirror of
    /// [`crate::trace::Tracer::drops_total`], refreshed on `stats`).
    trace_drops: AtomicU64,
    /// `trace` wire-op drains served.
    traces_served: AtomicU64,
    /// One entry per device ordinal (module docs §Per-device stats).
    per_device: Vec<DeviceTelemetry>,
    /// Per-request wall-clock latency samples, in seconds.
    latencies_s: OrderedMutex<Reservoir>,
    /// Per-request queue wait (enqueue → session start) samples, in
    /// seconds.
    queue_s: OrderedMutex<Reservoir>,
}

/// Per-device slice of the scheduler telemetry. The aggregate counters
/// above keep their exact pre-sharding meaning; these split the same
/// events by the device ordinal whose worker performed them.
struct DeviceTelemetry {
    /// Sessions resident on this device right now (gauge).
    lanes_active: AtomicU64,
    /// Mid-flight admissions into this device's cohorts.
    joins: AtomicU64,
    /// Sessions finished and answered by this device's worker.
    retires: AtomicU64,
    /// Sessions migrated *onto* this device by work stealing.
    steals: AtomicU64,
    /// Largest per-step cohort occupancy seen on this device.
    occupancy_peak: AtomicU64,
    /// Per-step cohort occupancy on this device.
    occupancy: OrderedMutex<Reservoir>,
}

impl Telemetry {
    fn new(reservoir_cap: usize, devices: usize) -> Self {
        Self {
            requests: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            accept_errors: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_requests: AtomicU64::new(0),
            lanes_active: AtomicU64::new(0),
            joins: AtomicU64::new(0),
            retires: AtomicU64::new(0),
            regroups: AtomicU64::new(0),
            occupancy_peak: AtomicU64::new(0),
            occupancy: OrderedMutex::new(
                "telemetry.occupancy",
                RANK_TELEMETRY_OCCUPANCY,
                Reservoir::new(reservoir_cap),
            ),
            auto_resolved: AtomicU64::new(0),
            auto_fallbacks: AtomicU64::new(0),
            steals: AtomicU64::new(0),
            forecasts: AtomicU64::new(0),
            forecast_fallbacks: AtomicU64::new(0),
            rejects: AtomicU64::new(0),
            deadline_misses: AtomicU64::new(0),
            degrade_swaps: AtomicU64::new(0),
            degrade_headroom_us: AtomicU64::new(0),
            queue_depth_peak: AtomicU64::new(0),
            trace_events: AtomicU64::new(0),
            trace_drops: AtomicU64::new(0),
            traces_served: AtomicU64::new(0),
            per_device: (0..devices.max(1))
                .map(|_| DeviceTelemetry {
                    lanes_active: AtomicU64::new(0),
                    joins: AtomicU64::new(0),
                    retires: AtomicU64::new(0),
                    steals: AtomicU64::new(0),
                    occupancy_peak: AtomicU64::new(0),
                    occupancy: OrderedMutex::new(
                        "device.occupancy",
                        RANK_DEVICE_OCCUPANCY,
                        Reservoir::new(reservoir_cap),
                    ),
                })
                .collect(),
            latencies_s: OrderedMutex::new(
                "telemetry.latencies_s",
                RANK_TELEMETRY_LATENCY,
                Reservoir::new(reservoir_cap),
            ),
            queue_s: OrderedMutex::new(
                "telemetry.queue_s",
                RANK_TELEMETRY_QUEUE,
                Reservoir::new(reservoir_cap),
            ),
        }
    }
}

/// Shared context a connection handler needs to route one protocol line.
struct ServeCtx {
    router: Arc<scheduler::Router>,
    stop: Arc<AtomicBool>,
    telemetry: Arc<Telemetry>,
    registry: Arc<EngineRegistry>,
    profiles: Option<Arc<ProfileStore>>,
    /// Scheduler shards (`devices > 1` adds per-device stats fields).
    devices: usize,
    /// Queue-pressure threshold for auto degradation
    /// ([`ServerConfig::degrade_threshold`]); 0 = disabled.
    degrade_threshold: usize,
}

/// The running server; dropping it (or calling [`Server::shutdown`]) stops
/// the listener and workers. Shutdown broadcasts on the router condvar so
/// idle workers on every device wake and exit immediately instead of
/// polling (see [`scheduler::Router::signal_stop`]).
pub struct Server {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    router: Arc<scheduler::Router>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

/// Server configuration.
pub struct ServerConfig {
    /// Bind address; use port 0 for an ephemeral port (tests).
    pub addr: String,
    /// Scheduler threads **at `devices: 1`** (the classic worker pool all
    /// sharing device 0). With `devices > 1` the scheduler is sharded —
    /// exactly one worker per device — and this field is ignored.
    pub workers: usize,
    /// Runtime replicas to serve across (module docs §Sharded topology).
    /// The registry must have been loaded with at least this many devices
    /// ([`EngineRegistry::load_pool`]). 1 (default): single-device server,
    /// byte-compatible behavior and wire responses.
    pub devices: usize,
    /// Maximum sessions sharing one cohort's device pass (1 disables
    /// batching entirely).
    pub max_batch: usize,
    /// Optional wait before a *fresh* cohort's first step for batchmates,
    /// in milliseconds (module docs §Continuous batching). 0 (default):
    /// start stepping immediately — late arrivals join at step boundaries
    /// anyway, so unlike the retired gather window this costs a lone
    /// request nothing. (The retired `--gather-ms` alias is gone; the CLI
    /// flag is `--admit-ms`.)
    pub admit_window_ms: u64,
    /// Latency/queue telemetry reservoir capacity: exact percentiles below
    /// this many samples, uniform reservoir sampling above.
    pub telemetry_reservoir: usize,
    /// Tuned reuse profiles for `policy=auto` resolution (module docs
    /// §`policy=auto` resolution). `None`: every `auto` request falls back
    /// to [`DEFAULT_POLICY`] and is counted in `auto_fallbacks`.
    pub profiles: Option<Arc<ProfileStore>>,
    /// Per-device queue bound (CLI `--max-queue`). A `generate` arriving
    /// when both its routed queue and the globally shortest queue sit at
    /// this bound is refused with the `overloaded` wire response instead
    /// of queued (module docs §Overload control). 0 (default): unbounded.
    pub max_queue: usize,
    /// Queue-pressure threshold for load-adaptive `policy=auto`
    /// degradation (CLI `--degrade`): when **every** device queue holds at
    /// least this many jobs, auto requests resolve to the matched
    /// profile's fastest frontier point still within its min-PSNR budget
    /// instead of the tuned spec. 0 (default): disabled.
    pub degrade_threshold: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:0".to_string(),
            workers: 2,
            devices: 1,
            max_batch: 4,
            admit_window_ms: 0,
            telemetry_reservoir: 4096,
            profiles: None,
            max_queue: 0,
            degrade_threshold: 0,
        }
    }
}

/// Transient accept(2) failures worth retrying: per-connection errors the
/// kernel reports on the listening socket (the peer aborted before we
/// accepted) and resource-pressure conditions that clear on their own —
/// EMFILE/ENFILE when a loaded server briefly exhausts file descriptors,
/// ENOBUFS/ENOMEM under memory pressure. Anything else means the listener
/// itself is broken (EBADF, EINVAL, ...) and retrying would spin forever.
fn accept_should_retry(e: &std::io::Error) -> bool {
    use std::io::ErrorKind;
    if matches!(
        e.kind(),
        ErrorKind::ConnectionAborted
            | ErrorKind::ConnectionReset
            | ErrorKind::Interrupted
            | ErrorKind::TimedOut
    ) {
        return true;
    }
    // ENOMEM(12)/ENFILE(23)/EMFILE(24)/ENOBUFS(105) have no stable
    // ErrorKind mapping across Rust versions; match the raw errno.
    matches!(e.raw_os_error(), Some(12 | 23 | 24 | 105))
}

impl Server {
    /// Start the listener + worker pool.
    pub fn start(registry: Arc<EngineRegistry>, cfg: ServerConfig) -> Result<Server> {
        let devices = cfg.devices.max(1);
        if registry.devices() < devices {
            return Err(anyhow!(
                "server configured for {devices} devices but the registry loaded {}",
                registry.devices()
            ));
        }
        let listener = TcpListener::bind(&cfg.addr).context("bind")?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let max_batch = cfg.max_batch.max(1);
        let admit_window = Duration::from_millis(cfg.admit_window_ms);
        let router = Arc::new(scheduler::Router::new(devices, max_batch, cfg.max_queue));
        let telemetry = Arc::new(Telemetry::new(cfg.telemetry_reservoir, devices));
        let mut handles = Vec::new();

        // Scheduler shards: at devices == 1 keep the classic pool —
        // cfg.workers threads all draining device 0's queue; with
        // devices > 1 spawn exactly one worker per device (scheduler
        // module docs §Sharding).
        let worker_devices: Vec<usize> = if devices == 1 {
            vec![0; cfg.workers.max(1)]
        } else {
            (0..devices).collect()
        };
        for (wid, device) in worker_devices.into_iter().enumerate() {
            let wctx = scheduler::WorkerCtx {
                router: Arc::clone(&router),
                stop: Arc::clone(&stop),
                registry: Arc::clone(&registry),
                telemetry: Arc::clone(&telemetry),
                cfg: scheduler::SchedConfig { max_batch, admit_window },
                device,
            };
            let spawned = std::thread::Builder::new()
                .name(format!("foresight-server-worker-{wid}"))
                .spawn(move || scheduler::run_worker(&wctx));
            match spawned {
                Ok(h) => handles.push(h),
                Err(e) => {
                    // Wake and join the workers already spawned before
                    // reporting the failure — no leaked threads.
                    router.signal_stop(&stop);
                    for h in handles.drain(..) {
                        let _ = h.join();
                    }
                    return Err(anyhow!("spawn scheduler worker {wid}: {e}"));
                }
            }
        }

        // accept loop
        {
            let stop_accept = Arc::clone(&stop);
            let ctx = Arc::new(ServeCtx {
                router: Arc::clone(&router),
                stop: Arc::clone(&stop),
                telemetry: Arc::clone(&telemetry),
                registry: Arc::clone(&registry),
                profiles: cfg.profiles.clone(),
                devices,
                degrade_threshold: cfg.degrade_threshold,
            });
            let spawned = std::thread::Builder::new()
                .name("foresight-server-accept".to_string())
                .spawn(move || {
                    let mut conn_handles: Vec<std::thread::JoinHandle<()>> = Vec::new();
                    let mut consecutive_errs = 0u32;
                    while !stop_accept.load(Ordering::SeqCst) {
                        // Reap finished connection handlers each pass so
                        // the handle list tracks live connections instead
                        // of growing for the server's lifetime.
                        let mut i = 0;
                        while i < conn_handles.len() {
                            if conn_handles[i].is_finished() {
                                let _ = conn_handles.swap_remove(i).join();
                            } else {
                                i += 1;
                            }
                        }
                        match listener.accept() {
                            Ok((stream, _peer)) => {
                                consecutive_errs = 0;
                                let ctx = Arc::clone(&ctx);
                                conn_handles.push(std::thread::spawn(move || {
                                    let _ = handle_conn(stream, ctx);
                                }));
                            }
                            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                                std::thread::sleep(Duration::from_millis(10));
                            }
                            Err(e) if accept_should_retry(&e) => {
                                // Transient (ECONNABORTED, EMFILE under
                                // load, ...): back off exponentially —
                                // capped so shutdown stays prompt — and
                                // keep listening rather than silently
                                // killing the accept loop.
                                telemetry.accept_errors.fetch_add(1, Ordering::Relaxed);
                                let delay = Duration::from_millis(
                                    5u64.saturating_mul(1 << consecutive_errs.min(6)),
                                );
                                consecutive_errs = consecutive_errs.saturating_add(1);
                                std::thread::sleep(delay.min(Duration::from_millis(250)));
                            }
                            Err(e) => {
                                // Fatal: the listening socket itself is
                                // gone; existing connections keep
                                // draining through their own threads.
                                telemetry.accept_errors.fetch_add(1, Ordering::Relaxed);
                                eprintln!("[server] accept loop stopping: {e}");
                                break;
                            }
                        }
                    }
                    for h in conn_handles {
                        let _ = h.join();
                    }
                });
            match spawned {
                Ok(h) => handles.push(h),
                Err(e) => {
                    // Same rollback as a failed worker spawn: the workers
                    // are already serving queues, so stop and join them
                    // before reporting the failure.
                    router.signal_stop(&stop);
                    for h in handles.drain(..) {
                        let _ = h.join();
                    }
                    return Err(anyhow!("spawn accept loop: {e}"));
                }
            }
        }

        Ok(Server { addr, stop, router, handles })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting and join all threads — including every per-device
    /// scheduler worker, even one mid-cohort (it finishes answering its
    /// in-flight lanes first; see [`scheduler::Router::signal_stop`]).
    pub fn shutdown(mut self) {
        self.router.signal_stop(&self.stop);
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.router.signal_stop(&self.stop);
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn err_json(msg: &str) -> Json {
    Json::obj(vec![("status", Json::str("error")), ("error", Json::str(msg))])
}

/// The deadline-exceeded error (module docs §Overload control): a normal
/// `{"status":"error"}` plus the machine-readable `deadline_exceeded`
/// marker so clients can distinguish it from validation or engine errors.
pub(crate) fn deadline_err_json() -> Json {
    Json::obj(vec![
        ("status", Json::str("error")),
        ("error", Json::str("deadline exceeded before completion")),
        ("deadline_exceeded", Json::Bool(true)),
    ])
}

/// The `overloaded` backpressure response (module docs §Overload
/// control): `retry_after_ms` is a drain-time hint, `queue_depth` the
/// shortest queue the client is competing for.
fn overloaded_json(retry_after_ms: u64, depth: usize) -> Json {
    Json::obj(vec![
        ("status", Json::str("error")),
        ("error", Json::str("overloaded: every device queue is at capacity")),
        ("overloaded", Json::Bool(true)),
        ("retry_after_ms", Json::num(retry_after_ms as f64)),
        ("queue_depth", Json::num(depth as f64)),
    ])
}

/// Retry-after hint for an `overloaded` response: roughly one drain of
/// the shortest queue — mean observed request latency × depth ÷ devices —
/// clamped to [25 ms, 5 s]. Before any latency sample exists, 50 ms per
/// queued job.
fn retry_after_hint(telemetry: &Telemetry, depth: usize, devices: usize) -> u64 {
    let lat = telemetry.latencies_s.lock().samples().to_vec();
    let est_ms = if lat.is_empty() {
        50.0 * depth.max(1) as f64
    } else {
        stats::mean(&lat) * 1000.0 * depth.max(1) as f64 / devices.max(1) as f64
    };
    (est_ms as u64).clamp(25, 5000)
}

/// Wire validation for `deadline_ms`: a positive integer number of
/// milliseconds, measured from arrival (the same shape rules as `steps` —
/// fractional or non-finite values are rejected, never truncated).
/// Absent = no deadline.
fn parse_deadline_ms(payload: &Json) -> Result<Option<Duration>> {
    match payload.get("deadline_ms") {
        None => Ok(None),
        Some(v) => {
            let d = v
                .as_f64()
                .ok_or_else(|| anyhow!("deadline_ms must be a positive integer"))?;
            if !d.is_finite() || d < 1.0 || d.fract() != 0.0 {
                return Err(anyhow!("deadline_ms must be a positive integer, got {d}"));
            }
            Ok(Some(Duration::from_millis(d as u64)))
        }
    }
}

fn handle_conn(mut stream: TcpStream, ctx: Arc<ServeCtx>) -> Result<()> {
    use std::io::Read;
    // Poll with a read timeout so idle connections notice server shutdown
    // instead of blocking forever in a read (which would deadlock
    // Server::shutdown's thread joins).
    stream.set_read_timeout(Some(std::time::Duration::from_millis(100)))?;
    let mut writer = stream.try_clone()?;
    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 4096];
    'conn: loop {
        // extract complete lines already buffered
        while let Some(pos) = buf.iter().position(|&b| b == b'\n') {
            let line_bytes: Vec<u8> = buf.drain(..=pos).collect();
            let line = String::from_utf8_lossy(&line_bytes).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if !handle_line(&line, &mut writer, &ctx)? {
                break 'conn;
            }
        }
        if ctx.stop.load(Ordering::SeqCst) {
            break;
        }
        match stream.read(&mut chunk) {
            Ok(0) => break, // peer closed
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue;
            }
            Err(_) => break,
        }
    }
    Ok(())
}

/// Process one protocol line; returns false when the connection should end.
fn handle_line(line: &str, writer: &mut TcpStream, ctx: &ServeCtx) -> Result<bool> {
    {
        let telemetry = &ctx.telemetry;
        let mut payload = match json::parse(line) {
            Ok(j) => j,
            Err(e) => {
                writeln!(writer, "{}", err_json(&format!("bad json: {e}")))?;
                return Ok(true);
            }
        };
        let op = payload
            .get("op")
            .and_then(|o| o.as_str())
            .unwrap_or("")
            .to_string();
        let resp = match op.as_str() {
            "ping" => Json::obj(vec![("status", Json::str("ok")), ("pong", Json::Bool(true))]),
            // Test-only, env-gated: panic mid-handler *while holding* the
            // latency reservoir. Exists so the test suite can prove a
            // panicking handler leaves telemetry poison-tolerant — the
            // `stats` op must keep serving afterwards. Unknown op in
            // production (the env var is never set there).
            "__panic" if std::env::var("FORESIGHT_TEST_PANIC_OP").is_ok() => {
                let _guard = telemetry.latencies_s.lock();
                panic!("deliberate test panic (__panic op)");
            }
            "stats" => stats_json(ctx),
            // The same surface for Prometheus scrapers (module docs
            // §Observability): every scalar stats key renders as a
            // `foresight_<key>` gauge line, per-device values with a
            // `{device="N"}` label, inside a JSON envelope so the
            // one-line-per-response protocol holds.
            "metrics" => {
                let body = prometheus_text(&stats_json(ctx));
                Json::obj(vec![
                    ("status", Json::str("ok")),
                    ("content_type", Json::str("text/plain; version=0.0.4")),
                    ("body", Json::str(body)),
                ])
            }
            // Incremental tracer drain (module docs §Observability):
            // non-destructive, cursor-based via `since`; the optional
            // `enable` flag toggles recording at runtime.
            "trace" => {
                let t = trace::global();
                if let Some(on) = payload.get("enable").and_then(|v| v.as_bool()) {
                    t.enable(on);
                }
                let since = payload.get("since").and_then(|v| v.as_u64()).unwrap_or(0);
                let d = t.drain(since);
                telemetry.traces_served.fetch_add(1, Ordering::Relaxed);
                Json::obj(vec![
                    ("status", Json::str("ok")),
                    ("enabled", Json::Bool(d.enabled)),
                    ("next", Json::num(d.next as f64)),
                    ("emitted", Json::num(d.emitted as f64)),
                    ("dropped", Json::num(d.dropped as f64)),
                    ("events", Json::arr(d.events.iter().map(trace::chrome::event_json).collect())),
                ])
            }
            "shutdown" => {
                ctx.router.signal_stop(&ctx.stop);
                let r = Json::obj(vec![("status", Json::str("ok")), ("stopping", Json::Bool(true))]);
                writeln!(writer, "{r}")?;
                return Ok(false);
            }
            "generate" => {
                // `deadline_ms` is validated before enqueue (the absolute
                // deadline rides on the Job, not the payload); a malformed
                // value is a counted per-request error like any other
                // wire-validation failure.
                let deadline_in = match parse_deadline_ms(&payload) {
                    Ok(d) => d,
                    Err(e) => {
                        telemetry.requests.fetch_add(1, Ordering::Relaxed);
                        telemetry.errors.fetch_add(1, Ordering::Relaxed);
                        writeln!(writer, "{}", err_json(&format!("{e:#}")))?;
                        return Ok(true);
                    }
                };
                // Request span: one trace id per accepted generate line;
                // every downstream event (scheduler, session, runtime)
                // carries it (module docs §Observability).
                let trace_id = trace::global().next_trace_id();
                let want_trace = payload.get("trace").and_then(|v| v.as_bool()).unwrap_or(false);
                trace::emit(trace_id, trace::Payload::Begin);
                // Resolve `policy:"auto"` to a concrete spec before the
                // job is queued, so the batch key (derived from the raw
                // payload) groups identically-resolved requests.
                let auto = resolve_auto(&mut payload, ctx);
                if auto.as_ref().map_or(false, |a| a.degraded) {
                    trace::emit(trace_id, trace::Payload::Degrade);
                }
                let (tx, rx) = mpsc::channel();
                let enqueued = Instant::now();
                // Routing front: the router picks the device queue under
                // its own lock and checks `stop` there — workers only
                // exit after observing `stop` (set under the same lock),
                // so a routed job is guaranteed a live worker;
                // enqueueing after shutdown would otherwise block
                // rx.recv() forever and deadlock Server::shutdown's join.
                let job = Job {
                    payload,
                    enqueued,
                    deadline: deadline_in.map(|d| enqueued + d),
                    reply: tx,
                    auto,
                    trace_id,
                    want_trace,
                };
                let resp = match ctx.router.enqueue(job, &ctx.stop) {
                    scheduler::EnqueueOutcome::Queued { depth } => {
                        telemetry
                            .queue_depth_peak
                            .fetch_max(depth as u64, Ordering::Relaxed);
                        rx.recv().unwrap_or_else(|_| err_json("worker dropped"))
                    }
                    scheduler::EnqueueOutcome::Overloaded { depth } => {
                        // Bounded admission (module docs §Overload
                        // control): refused *before* counting as an
                        // admitted request — `rejects` is its own ledger.
                        telemetry.rejects.fetch_add(1, Ordering::Relaxed);
                        trace::emit(trace_id, trace::Payload::Reject { depth: depth as u64 });
                        overloaded_json(retry_after_hint(telemetry, depth, ctx.devices), depth)
                    }
                    scheduler::EnqueueOutcome::Stopping => err_json("server is shutting down"),
                };
                // Close the request span with the final disposition —
                // rejects and deadline misses end the span `ok:false`.
                let ok = resp.get("status").and_then(|v| v.as_str()) == Some("ok");
                trace::emit(trace_id, trace::Payload::End { ok });
                resp
            }
            other => err_json(&format!("unknown op '{other}'")),
        };
        writeln!(writer, "{resp}")?;
    }
    Ok(true)
}

/// The full `stats` response object — also the single source feed for
/// the `metrics` Prometheus rendering, so the two surfaces can never
/// disagree. Refreshes the [`Telemetry`] mirrors of the process-wide
/// tracer counters first (they are monotonic, hence `fetch_max`).
fn stats_json(ctx: &ServeCtx) -> Json {
    let telemetry = &ctx.telemetry;
    let trc = trace::global();
    telemetry.trace_events.fetch_max(trc.events_total(), Ordering::Relaxed);
    telemetry.trace_drops.fetch_max(trc.drops_total(), Ordering::Relaxed);
    let (lat, lat_seen) = {
        let r = telemetry.latencies_s.lock();
        (r.samples().to_vec(), r.seen())
    };
    let qs = telemetry.queue_s.lock().samples().to_vec();
    let occ = telemetry.occupancy.lock().samples().to_vec();
    let occ_max = telemetry.occupancy_peak.load(Ordering::Relaxed) as f64;
    let depths = ctx.router.queue_depths();
    let mut fields = vec![
        ("status", Json::str("ok")),
        ("requests", Json::num(telemetry.requests.load(Ordering::Relaxed) as f64)),
        ("errors", Json::num(telemetry.errors.load(Ordering::Relaxed) as f64)),
        (
            "accept_errors",
            Json::num(telemetry.accept_errors.load(Ordering::Relaxed) as f64),
        ),
        ("batches", Json::num(telemetry.batches.load(Ordering::Relaxed) as f64)),
        (
            "batched_requests",
            Json::num(telemetry.batched_requests.load(Ordering::Relaxed) as f64),
        ),
        (
            "lanes_active",
            Json::num(telemetry.lanes_active.load(Ordering::Relaxed) as f64),
        ),
        ("joins", Json::num(telemetry.joins.load(Ordering::Relaxed) as f64)),
        ("retires", Json::num(telemetry.retires.load(Ordering::Relaxed) as f64)),
        ("regroups", Json::num(telemetry.regroups.load(Ordering::Relaxed) as f64)),
        ("occupancy_mean", Json::num(stats::mean(&occ))),
        ("occupancy_max", Json::num(occ_max)),
        (
            "profile_store_version",
            Json::num(ctx.profiles.as_deref().map_or(0, |s| s.version()) as f64),
        ),
        (
            "profiles_loaded",
            Json::num(ctx.profiles.as_deref().map_or(0, |s| s.len()) as f64),
        ),
        (
            "auto_resolved",
            Json::num(telemetry.auto_resolved.load(Ordering::Relaxed) as f64),
        ),
        (
            "auto_fallbacks",
            Json::num(telemetry.auto_fallbacks.load(Ordering::Relaxed) as f64),
        ),
        (
            "forecasts",
            Json::num(telemetry.forecasts.load(Ordering::Relaxed) as f64),
        ),
        (
            "forecast_fallbacks",
            Json::num(telemetry.forecast_fallbacks.load(Ordering::Relaxed) as f64),
        ),
        ("rejects", Json::num(telemetry.rejects.load(Ordering::Relaxed) as f64)),
        (
            "deadline_misses",
            Json::num(telemetry.deadline_misses.load(Ordering::Relaxed) as f64),
        ),
        (
            "degrade_swaps",
            Json::num(telemetry.degrade_swaps.load(Ordering::Relaxed) as f64),
        ),
        (
            "degrade_headroom_s",
            Json::num(telemetry.degrade_headroom_us.load(Ordering::Relaxed) as f64 / 1e6),
        ),
        ("queue_depth", Json::num(depths.iter().sum::<usize>() as f64)),
        (
            "queue_depth_peak",
            Json::num(telemetry.queue_depth_peak.load(Ordering::Relaxed) as f64),
        ),
        (
            "trace_events",
            Json::num(telemetry.trace_events.load(Ordering::Relaxed) as f64),
        ),
        (
            "trace_drops",
            Json::num(telemetry.trace_drops.load(Ordering::Relaxed) as f64),
        ),
        (
            "traces_served",
            Json::num(telemetry.traces_served.load(Ordering::Relaxed) as f64),
        ),
        ("latency_p50_s", Json::num(stats::percentile(&lat, 50.0))),
        ("latency_p95_s", Json::num(stats::percentile(&lat, 95.0))),
        ("latency_p99_s", Json::num(stats::percentile(&lat, 99.0))),
        ("latency_mean_s", Json::num(stats::mean(&lat))),
        ("latency_samples", Json::num(lat.len() as f64)),
        ("latency_seen", Json::num(lat_seen as f64)),
        ("queue_mean_s", Json::num(stats::mean(&qs))),
        ("queue_p95_s", Json::num(stats::percentile(&qs, 95.0))),
    ];
    // Sharded-only fields (module docs §Per-device stats):
    // gated on devices > 1 so the single-device response stays
    // byte-identical to the pre-sharding server.
    if ctx.devices > 1 {
        let xfer = ctx.registry.pool().transfer_snapshots();
        let per_device: Vec<Json> = telemetry
            .per_device
            .iter()
            .enumerate()
            .map(|(d, t)| {
                let occ = t.occupancy.lock().samples().to_vec();
                let x = &xfer[d];
                Json::obj(vec![
                    ("device", Json::num(d as f64)),
                    (
                        "lanes_active",
                        Json::num(t.lanes_active.load(Ordering::Relaxed) as f64),
                    ),
                    ("occupancy_mean", Json::num(stats::mean(&occ))),
                    (
                        "occupancy_max",
                        Json::num(t.occupancy_peak.load(Ordering::Relaxed) as f64),
                    ),
                    ("joins", Json::num(t.joins.load(Ordering::Relaxed) as f64)),
                    ("retires", Json::num(t.retires.load(Ordering::Relaxed) as f64)),
                    ("steals", Json::num(t.steals.load(Ordering::Relaxed) as f64)),
                    ("queue_depth", Json::num(depths[d] as f64)),
                    ("h2d_bytes", Json::num(x.h2d_bytes as f64)),
                    ("h2d_calls", Json::num(x.h2d_calls as f64)),
                    ("d2h_bytes", Json::num(x.d2h_bytes as f64)),
                    ("d2h_calls", Json::num(x.d2h_calls as f64)),
                ])
            })
            .collect();
        fields.extend([
            ("devices", Json::num(ctx.devices as f64)),
            ("steals", Json::num(telemetry.steals.load(Ordering::Relaxed) as f64)),
            ("per_device", Json::Arr(per_device)),
        ]);
    }
    Json::obj(fields)
}

/// Prometheus exposition table: `(stats key, HELP text)`, one row per
/// scalar key the `stats` op can emit. The `metrics` op renders each
/// present key as gauge `foresight_<key>`; the `analysis::lint` ledger
/// pass cross-checks this table against [`Telemetry`]'s wire names so a
/// new counter cannot ship without a scrape line (module docs
/// §Observability).
const PROM_METRICS: &[(&str, &str)] = &[
    ("requests", "Generate requests admitted off the wire"),
    ("errors", "Per-request errors (validation, dispatch, engine)"),
    ("accept_errors", "Listener accept()/handshake failures"),
    ("batches", "Fused cohort passes executed"),
    ("batched_requests", "Requests that ever shared a cohort"),
    ("lanes_active", "Lanes occupied right now, all devices"),
    ("joins", "Sessions that joined an in-flight cohort"),
    ("retires", "Sessions retired at a step boundary"),
    ("regroups", "Cohort regroups (lane set changed between passes)"),
    ("occupancy_mean", "Mean lanes advanced per fused pass"),
    ("occupancy_max", "Peak lanes advanced by any fused pass"),
    ("profile_store_version", "Version of the loaded autotune profile store"),
    ("profiles_loaded", "Profiles in the loaded autotune store"),
    ("auto_resolved", "policy=auto requests resolved from a profile"),
    ("auto_fallbacks", "policy=auto requests that fell back to the default"),
    ("forecasts", "Reuse units served by linear-multistep forecast"),
    ("forecast_fallbacks", "Planned forecasts replayed verbatim (shallow history)"),
    ("rejects", "Requests refused by bounded admission"),
    ("deadline_misses", "Requests dropped past their deadline"),
    ("degrade_swaps", "policy=auto requests degraded under queue pressure"),
    ("degrade_headroom_s", "Cumulative seconds of estimated work shed by degrades"),
    ("queue_depth", "Jobs queued across all device queues right now"),
    ("queue_depth_peak", "Deepest any device queue has ever been"),
    ("trace_events", "Events ring-buffered by the process-wide tracer"),
    ("trace_drops", "Trace events dropped instead of blocking a hot path"),
    ("traces_served", "trace wire-op drains served"),
    ("latency_p50_s", "Median request wall-clock latency (seconds)"),
    ("latency_p95_s", "p95 request wall-clock latency (seconds)"),
    ("latency_p99_s", "p99 request wall-clock latency (seconds)"),
    ("latency_mean_s", "Mean request wall-clock latency (seconds)"),
    ("latency_samples", "Latency samples currently in the reservoir"),
    ("latency_seen", "Latency samples ever offered to the reservoir"),
    ("queue_mean_s", "Mean queue wait (seconds)"),
    ("queue_p95_s", "p95 queue wait (seconds)"),
    ("devices", "Runtime device replicas serving this process"),
    ("steals", "Queued jobs pulled by an idle non-home device"),
];

/// Render a `stats` response as Prometheus text exposition (version
/// 0.0.4). Scalar keys follow [`PROM_METRICS`]; keys absent from the
/// response (e.g. sharded-only fields on a single-device server) are
/// skipped; the `per_device` breakdown renders as
/// `foresight_device_<key>{device="N"}` gauges grouped per metric name.
fn prometheus_text(stats: &Json) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    for (key, help) in PROM_METRICS {
        let Some(v) = stats.get(key).and_then(|v| v.as_f64()) else {
            continue;
        };
        let _ = writeln!(out, "# HELP foresight_{key} {help}");
        let _ = writeln!(out, "# TYPE foresight_{key} gauge");
        let _ = writeln!(out, "foresight_{key} {}", fmt_prom(v));
    }
    if let Some(devs) = stats.get("per_device").and_then(|v| v.as_arr()) {
        // Key-major so all samples of one metric family stay contiguous
        // (the exposition format requires grouping).
        let keys: Vec<&String> = devs
            .first()
            .and_then(|d| d.as_obj())
            .map(|o| o.keys().filter(|k| k.as_str() != "device").collect())
            .unwrap_or_default();
        for k in keys {
            let _ = writeln!(out, "# TYPE foresight_device_{k} gauge");
            for d in devs {
                let ord = d.get("device").and_then(|v| v.as_f64()).unwrap_or(0.0);
                if let Some(v) = d.get(k).and_then(|v| v.as_f64()) {
                    let _ = writeln!(
                        out,
                        "foresight_device_{k}{{device=\"{}\"}} {}",
                        fmt_prom(ord),
                        fmt_prom(v)
                    );
                }
            }
        }
    }
    out
}

/// Format a sample value: counters print as integers, everything else in
/// Rust's shortest-roundtrip float form (both valid exposition values).
fn fmt_prom(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

/// The compact per-step reuse timeline echoed on `"trace": true` requests
/// (module docs §Observability): one `{step, site, action, lambda}`
/// object per planned branch-0 decision, straight from the session's
/// [`RunResult`] (`lambda` omitted when the policy records no threshold
/// for that site).
fn reuse_timeline(r: &RunResult) -> Json {
    let mut entries = Vec::new();
    for (step, row) in r.reuse_map.iter().enumerate() {
        for (site, &decision) in row.iter().enumerate() {
            let mut f = vec![
                ("step", Json::num(step as f64)),
                ("site", Json::num(site as f64)),
                ("action", Json::str(decision.name())),
            ];
            if let Some(l) = r.site_lambdas.as_ref().and_then(|ls| ls.get(site)) {
                if l.is_finite() && *l >= 0.0 {
                    f.push(("lambda", Json::num(*l)));
                }
            }
            entries.push(Json::obj(f));
        }
    }
    Json::arr(entries)
}

/// A `generate` payload after wire validation, ready for dispatch.
#[derive(Debug)]
struct GenerateParams {
    model: String,
    bucket: String,
    policy_spec: String,
    req: Request,
}

/// Wire validation before any sampler is built: a `steps: 0` (or
/// out-of-schedule DDIM step count) used to trip the sampler
/// constructor's assert, panic the worker, and turn every later request
/// on that worker into "worker dropped"; a fractional seed used to
/// truncate silently. (The schedule upper bound on `steps` needs the
/// engine and is checked at dispatch.)
fn parse_generate(payload: &Json) -> Result<GenerateParams> {
    // Routing fields must be strings when present (absent = default). A
    // wrong-typed field is unkeyable for the batch scheduler, so it must
    // also fail validation here — silently substituting the default would
    // serve the wrong model.
    let field_str = |k: &str, default: &str| -> Result<String> {
        match payload.get(k) {
            None => Ok(default.to_string()),
            Some(v) => v
                .as_str()
                .map(str::to_string)
                .ok_or_else(|| anyhow!("{k} must be a string")),
        }
    };
    let model = field_str("model", DEFAULT_MODEL)?;
    let bucket = field_str("bucket", DEFAULT_BUCKET)?;
    let policy_spec = field_str("policy", DEFAULT_POLICY)?;
    let prompt = payload
        .get("prompt")
        .and_then(|v| v.as_str())
        .unwrap_or_default()
        .to_string();

    let seed = match payload.get("seed") {
        None => 0,
        Some(v) => {
            let s = v.as_f64().ok_or_else(|| anyhow!("seed must be a number"))?;
            // Reject fractions the same way `steps` does: `1.5 as u64`
            // would silently truncate to 1 and serve the wrong video.
            if !s.is_finite() || s < 0.0 || s.fract() != 0.0 {
                return Err(anyhow!(
                    "seed must be a finite non-negative integer, got {s}"
                ));
            }
            s as u64
        }
    };
    let steps = match payload.get("steps") {
        None => None,
        Some(v) => {
            let s = v
                .as_f64()
                .ok_or_else(|| anyhow!("steps must be a positive integer"))?;
            if !s.is_finite() || s < 1.0 || s.fract() != 0.0 {
                return Err(anyhow!("steps must be a positive integer, got {s}"));
            }
            Some(s as usize)
        }
    };
    let cfg_scale = match payload.get("cfg_scale") {
        None => None,
        Some(v) => {
            let c = v.as_f64().ok_or_else(|| anyhow!("cfg_scale must be a number"))?;
            if !c.is_finite() {
                return Err(anyhow!("cfg_scale must be finite, got {c}"));
            }
            Some(c)
        }
    };

    let mut req = Request::new(&prompt, seed);
    req.steps = steps;
    req.cfg_scale = cfg_scale;
    Ok(GenerateParams { model, bucket, policy_spec, req })
}

/// One `generate` response object (module docs list the fields).
/// `policy_spec` is the concrete spec that was executed (post-`auto`
/// resolution); `auto` adds the resolution echo fields when the request
/// asked for `policy=auto`.
fn generate_response(
    model: &str,
    bucket: &str,
    r: &RunResult,
    queue_s: f64,
    batch_size: usize,
    policy_spec: &str,
    auto: Option<&AutoInfo>,
) -> Json {
    let s = &r.stats;
    let latent_l2 = r
        .latents
        .data
        .iter()
        .map(|&v| v as f64 * v as f64)
        .sum::<f64>()
        .sqrt();
    let mut fields = vec![
        ("status", Json::str("ok")),
        ("model", Json::str(model)),
        ("bucket", Json::str(bucket)),
        ("policy", Json::str(&s.policy)),
        ("policy_spec", Json::str(policy_spec)),
        ("wall_s", Json::num(s.wall_s)),
        ("queue_s", Json::num(queue_s)),
        // Explicit alias so clients never have to guess which of the two
        // wall-clock fields is the queue wait (satellite of the tracing
        // work — every response echoes it).
        ("queue_wait_s", Json::num(queue_s)),
        ("steps", Json::num(s.per_step_s.len() as f64)),
        ("computed_units", Json::num(s.computed_units as f64)),
        ("reused_units", Json::num(s.reused_units as f64)),
        ("fallback_units", Json::num(s.fallback_units as f64)),
        ("forecast_units", Json::num(s.forecast_units as f64)),
        ("forecast_fallback_units", Json::num(s.forecast_fallback_units as f64)),
        ("reuse_fraction", Json::num(s.reuse_fraction())),
        ("cache_peak_bytes", Json::num(s.cache_peak_bytes as f64)),
        ("h2d_bytes", Json::num(s.h2d_bytes as f64)),
        ("h2d_calls", Json::num(s.h2d_calls as f64)),
        ("d2h_bytes", Json::num(s.d2h_bytes as f64)),
        ("d2h_calls", Json::num(s.d2h_calls as f64)),
        ("batch_size", Json::num(batch_size as f64)),
        ("latent_l2", Json::num(latent_l2)),
    ];
    if let Some(a) = auto {
        fields.extend([
            ("policy_requested", Json::str(AUTO_POLICY)),
            ("resolved_policy", Json::str(&a.spec)),
            ("profile_version", Json::num(a.profile_version as f64)),
            ("profile_store_version", Json::num(a.store_version as f64)),
            ("profile_match", Json::str(a.matched)),
            ("profile_fallback", Json::Bool(a.fallback)),
            ("degraded", Json::Bool(a.degraded)),
        ]);
        if let Some(from) = &a.degraded_from {
            fields.push(("degraded_from", Json::str(from)));
        }
    }
    Json::obj(fields)
}

/// True when a response is the server's `overloaded` backpressure reply
/// (module docs §Overload control).
pub fn is_overloaded(resp: &Json) -> bool {
    resp.get("overloaded").and_then(|v| v.as_bool()).unwrap_or(false)
}

/// Client-side retry policy for `overloaded` responses
/// ([`Client::call_retrying`]): capped exponential backoff with jitter,
/// honoring the server's `retry_after_ms` hint as the floor of each
/// delay. [`Backoff::none`] opts out entirely (one attempt, the
/// overloaded response returned as-is).
#[derive(Debug, Clone)]
pub struct Backoff {
    /// Total attempts (the initial call counts as one); 0 behaves as 1.
    pub attempts: u32,
    /// First retry delay; doubles per retry.
    pub base: Duration,
    /// Upper bound on any single delay (applied after the hint floor, so
    /// a hostile or buggy hint cannot stall a client for minutes).
    pub cap: Duration,
    /// Randomize each delay uniformly in [delay/2, delay] — decorrelates
    /// clients that got the same hint. Disable for deterministic tests.
    pub jitter: bool,
    /// Seed for the jitter PRNG (deterministic per client).
    pub seed: u64,
}

impl Default for Backoff {
    fn default() -> Self {
        Self {
            attempts: 5,
            base: Duration::from_millis(25),
            cap: Duration::from_secs(2),
            jitter: true,
            seed: 0,
        }
    }
}

impl Backoff {
    /// Opt out of retrying: a single attempt, overloaded responses
    /// returned to the caller untouched.
    pub fn none() -> Self {
        Self { attempts: 1, ..Self::default() }
    }

    /// Delay before 0-based retry `retry`: `max(hint, base · 2^retry)`
    /// capped at `cap`, then jittered into [delay/2, delay].
    fn delay(&self, retry: u32, hint_ms: Option<u64>, rng: &mut crate::util::prng::Rng) -> Duration {
        let exp = self
            .base
            .saturating_mul(1u32.checked_shl(retry).unwrap_or(u32::MAX));
        let hinted = hint_ms.map_or(exp, |h| exp.max(Duration::from_millis(h)));
        let capped = hinted.min(self.cap);
        if !self.jitter || capped.is_zero() {
            return capped;
        }
        let half = capped / 2;
        let span_ms = (capped - half).as_millis() as usize;
        half + Duration::from_millis(rng.next_below(span_ms + 1) as u64)
    }
}

/// Blocking JSON-lines client for the server (used by examples and tests).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    /// Default socket read/write timeout: generous enough for a queued
    /// full-schedule generation under load, finite so a hung server fails
    /// a bench or the autotune CLI instead of stalling it forever.
    pub const DEFAULT_TIMEOUT: Duration = Duration::from_secs(120);

    pub fn connect(addr: &SocketAddr) -> Result<Self> {
        Self::connect_with_timeout(addr, Some(Self::DEFAULT_TIMEOUT))
    }

    /// Connect with an explicit socket timeout (`None` = block forever,
    /// the pre-timeout behavior).
    pub fn connect_with_timeout(addr: &SocketAddr, timeout: Option<Duration>) -> Result<Self> {
        let stream = TcpStream::connect(addr).context("connect")?;
        stream.set_read_timeout(timeout)?;
        stream.set_write_timeout(timeout)?;
        let writer = stream.try_clone()?;
        Ok(Self { reader: BufReader::new(stream), writer })
    }

    /// Adjust the socket timeout of an existing connection.
    pub fn set_timeout(&mut self, timeout: Option<Duration>) -> Result<()> {
        let stream = self.reader.get_ref();
        stream.set_read_timeout(timeout)?;
        stream.set_write_timeout(timeout)?;
        Ok(())
    }

    /// Send one request object; wait for one response line.
    pub fn call(&mut self, req: &Json) -> Result<Json> {
        writeln!(self.writer, "{req}")?;
        let mut line = String::new();
        match self.reader.read_line(&mut line) {
            Ok(0) => return Err(anyhow!("server closed connection")),
            Ok(_) => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                return Err(anyhow!("timed out waiting for server response"));
            }
            Err(e) => return Err(e.into()),
        }
        if line.trim().is_empty() {
            return Err(anyhow!("server closed connection"));
        }
        json::parse(line.trim()).map_err(|e| anyhow!("bad response: {e}"))
    }

    /// [`Client::call`], retrying `overloaded` responses per `backoff`
    /// (module docs §Overload control). Any non-overloaded response — ok,
    /// deadline-exceeded, validation error — returns immediately; once
    /// the attempt budget is spent the last overloaded response is
    /// returned as a value (not an `Err`) so callers can inspect
    /// `retry_after_ms`/`queue_depth`. Transport errors still `Err`.
    pub fn call_retrying(&mut self, req: &Json, backoff: &Backoff) -> Result<Json> {
        let attempts = backoff.attempts.max(1);
        let mut rng = crate::util::prng::Rng::from_seed_and_label(backoff.seed, "client-backoff");
        let mut last = self.call(req)?;
        for retry in 0..attempts.saturating_sub(1) {
            if !is_overloaded(&last) {
                return Ok(last);
            }
            let hint = last
                .get("retry_after_ms")
                .and_then(|v| v.as_f64())
                .filter(|h| h.is_finite() && *h >= 0.0)
                .map(|h| h as u64);
            std::thread::sleep(backoff.delay(retry, hint, &mut rng));
            last = self.call(req)?;
        }
        Ok(last)
    }

    pub fn ping(&mut self) -> Result<bool> {
        let r = self.call(&Json::obj(vec![("op", Json::str("ping"))]))?;
        Ok(r.get("pong").and_then(|v| v.as_bool()).unwrap_or(false))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gen_payload(fields: Vec<(&str, Json)>) -> Json {
        let mut all = vec![("op", Json::str("generate"))];
        all.extend(fields);
        Json::obj(all)
    }

    #[test]
    fn cohort_key_groups_across_steps_cfg_policy_seed_prompt() {
        // Only (model, bucket) pin the shared device pass: sessions carry
        // their own schedule cursor, CFG scalar and policy, so everything
        // else batches freely under the continuous scheduler.
        let a = gen_payload(vec![
            ("policy", Json::str("foresight")),
            ("steps", Json::num(12.0)),
            ("cfg_scale", Json::num(3.0)),
            ("seed", Json::num(1.0)),
            ("prompt", Json::str("a lake")),
        ]);
        let b = gen_payload(vec![
            ("policy", Json::str("static")),
            ("steps", Json::num(7.0)),
            ("seed", Json::num(999.0)),
            ("prompt", Json::str("a storm")),
        ]);
        assert_eq!(cohort_key(&a), cohort_key(&b));
        assert!(cohort_key(&a).is_some());
        // absent routing fields resolve to the wire defaults
        assert_eq!(
            cohort_key(&gen_payload(vec![])),
            Some((DEFAULT_MODEL.to_string(), DEFAULT_BUCKET.to_string()))
        );
    }

    #[test]
    fn cohort_key_separates_models_and_buckets() {
        let base = gen_payload(vec![]);
        for other in [
            gen_payload(vec![("bucket", Json::str("other"))]),
            gen_payload(vec![("model", Json::str("latte-sim"))]),
        ] {
            assert_ne!(cohort_key(&base), cohort_key(&other), "{other}");
        }
    }

    #[test]
    fn cohort_key_rejects_unkeyable_payloads() {
        // wrong-typed routing fields dispatch solo (validation fails them)
        assert!(cohort_key(&gen_payload(vec![("model", Json::num(4.0))])).is_none());
        assert!(cohort_key(&gen_payload(vec![("bucket", Json::num(4.0))])).is_none());
        assert!(cohort_key(&Json::obj(vec![("op", Json::str("ping"))])).is_none());
    }

    #[test]
    fn client_call_times_out_against_unresponsive_server() {
        // A listener that accepts but never replies must fail a call
        // within the configured timeout instead of hanging the caller
        // forever (the pre-timeout behavior this regression test pins).
        use std::net::TcpListener;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let hold = std::thread::spawn(move || {
            // Keep the accepted connection open, silently, long enough to
            // outlive the client's timeout.
            let conn = listener.accept();
            std::thread::sleep(Duration::from_millis(1200));
            drop(conn);
        });
        let mut c = Client::connect_with_timeout(&addr, Some(Duration::from_millis(150))).unwrap();
        let t0 = Instant::now();
        let err = c
            .call(&Json::obj(vec![("op", Json::str("ping"))]))
            .unwrap_err()
            .to_string();
        let took = t0.elapsed();
        assert!(err.contains("timed out"), "{err}");
        assert!(
            took < Duration::from_millis(1000),
            "timeout did not bound the call: {took:?}"
        );
        let _ = hold.join();
    }

    #[test]
    fn parse_generate_rejects_fractional_seed() {
        let err = parse_generate(&gen_payload(vec![("seed", Json::num(1.5))]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("seed"), "{err}");
        let err = parse_generate(&gen_payload(vec![("seed", Json::num(-3.0))]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("seed"), "{err}");
        // integral-valued floats are fine
        let p = parse_generate(&gen_payload(vec![("seed", Json::num(7.0))])).unwrap();
        assert_eq!(p.req.seed, 7);
    }

    #[test]
    fn parse_generate_rejects_wrong_typed_routing_fields() {
        // Unkeyable for the batch scheduler ⇒ must also fail validation
        // (not silently fall back to the default model).
        for k in ["model", "bucket", "policy"] {
            let err = parse_generate(&gen_payload(vec![(k, Json::num(4.0))]))
                .unwrap_err()
                .to_string();
            assert!(err.contains(k), "{k}: {err}");
        }
        // absent routing fields still default
        let p = parse_generate(&gen_payload(vec![])).unwrap();
        assert_eq!(p.model, DEFAULT_MODEL);
        assert_eq!(p.policy_spec, DEFAULT_POLICY);
    }

    #[test]
    fn parse_deadline_ms_validates_shape() {
        for bad in [0.0, -5.0, 1.5, f64::NAN, f64::INFINITY] {
            let err = parse_deadline_ms(&gen_payload(vec![("deadline_ms", Json::num(bad))]))
                .unwrap_err()
                .to_string();
            assert!(err.contains("deadline_ms"), "{bad}: {err}");
        }
        let err = parse_deadline_ms(&gen_payload(vec![("deadline_ms", Json::str("soon"))]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("deadline_ms"), "{err}");
        assert_eq!(
            parse_deadline_ms(&gen_payload(vec![("deadline_ms", Json::num(2000.0))])).unwrap(),
            Some(Duration::from_millis(2000))
        );
        assert_eq!(parse_deadline_ms(&gen_payload(vec![])).unwrap(), None);
    }

    #[test]
    fn overloaded_response_shape_and_detection() {
        let r = overloaded_json(120, 7);
        assert_eq!(r.get("status").and_then(|v| v.as_str()), Some("error"));
        assert!(is_overloaded(&r));
        assert_eq!(r.get("retry_after_ms").and_then(|v| v.as_f64()), Some(120.0));
        assert_eq!(r.get("queue_depth").and_then(|v| v.as_f64()), Some(7.0));
        // ordinary errors and ok responses are not overloaded
        assert!(!is_overloaded(&err_json("boom")));
        assert!(!is_overloaded(&deadline_err_json()));
        assert!(deadline_err_json()
            .get("deadline_exceeded")
            .and_then(|v| v.as_bool())
            .unwrap_or(false));
    }

    #[test]
    fn backoff_delay_honors_hint_cap_and_jitter_bounds() {
        let mut rng = crate::util::prng::Rng::from_seed_and_label(1, "t");
        let b = Backoff {
            attempts: 5,
            base: Duration::from_millis(10),
            cap: Duration::from_millis(100),
            jitter: false,
            seed: 0,
        };
        // pure exponential without a hint
        assert_eq!(b.delay(0, None, &mut rng), Duration::from_millis(10));
        assert_eq!(b.delay(2, None, &mut rng), Duration::from_millis(40));
        // the hint floors the delay...
        assert_eq!(b.delay(0, Some(60), &mut rng), Duration::from_millis(60));
        // ...but the cap still bounds a hostile hint and deep retries
        assert_eq!(b.delay(0, Some(60_000), &mut rng), Duration::from_millis(100));
        assert_eq!(b.delay(30, None, &mut rng), Duration::from_millis(100));
        // jitter stays within [delay/2, delay]
        let j = Backoff { jitter: true, ..b.clone() };
        for retry in 0..4 {
            let d = j.delay(retry, Some(80), &mut rng);
            assert!(
                d >= Duration::from_millis(40) && d <= Duration::from_millis(100),
                "retry {retry}: {d:?}"
            );
        }
    }

    #[test]
    fn call_retrying_backs_off_against_saturated_listener_and_opts_out() {
        // A permanently saturated server: every generate is answered with
        // the overloaded backpressure response. The retrying client must
        // make exactly `attempts` calls and then surface the overloaded
        // response as a value; Backoff::none() must make exactly one.
        use std::net::TcpListener;
        use std::sync::atomic::AtomicUsize;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let served = Arc::new(AtomicUsize::new(0));
        let served_srv = Arc::clone(&served);
        let srv = std::thread::spawn(move || {
            for conn in listener.incoming().take(2) {
                let stream = conn.unwrap();
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let mut writer = stream;
                let mut line = String::new();
                while {
                    line.clear();
                    reader.read_line(&mut line).map(|n| n > 0).unwrap_or(false)
                } {
                    served_srv.fetch_add(1, Ordering::SeqCst);
                    writeln!(writer, "{}", overloaded_json(1, 3)).unwrap();
                }
            }
        });
        let req = gen_payload(vec![]);
        let backoff = Backoff {
            attempts: 3,
            base: Duration::from_millis(1),
            cap: Duration::from_millis(5),
            jitter: false,
            seed: 0,
        };
        let mut c = Client::connect(&addr).unwrap();
        let resp = c.call_retrying(&req, &backoff).unwrap();
        assert!(is_overloaded(&resp), "{resp}");
        drop(c);
        assert_eq!(served.load(Ordering::SeqCst), 3, "3 attempts expected");
        let mut c = Client::connect(&addr).unwrap();
        let resp = c.call_retrying(&req, &Backoff::none()).unwrap();
        assert!(is_overloaded(&resp), "{resp}");
        drop(c);
        assert_eq!(served.load(Ordering::SeqCst), 4, "opt-out must not retry");
        let _ = srv.join();
    }

    #[test]
    fn accept_retry_classification() {
        use std::io::{Error, ErrorKind};
        assert!(accept_should_retry(&Error::new(ErrorKind::ConnectionAborted, "x")));
        assert!(accept_should_retry(&Error::new(ErrorKind::ConnectionReset, "x")));
        assert!(accept_should_retry(&Error::from_raw_os_error(24))); // EMFILE
        assert!(accept_should_retry(&Error::from_raw_os_error(23))); // ENFILE
        assert!(!accept_should_retry(&Error::from_raw_os_error(9))); // EBADF
        assert!(!accept_should_retry(&Error::new(ErrorKind::InvalidInput, "x")));
    }
}
