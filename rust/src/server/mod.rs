//! TCP JSON-lines serving front-end: router, request queue, worker pool.
//!
//! This is the L3 deployment surface: a newline-delimited JSON protocol
//! over TCP (one request object per line, one response object per line),
//! a FIFO queue with a fixed worker pool executing generations, and
//! aggregate latency telemetry. Python is never involved; workers drive
//! the PJRT executables directly.
//!
//! Protocol ops:
//! * `{"op":"ping"}` → `{"status":"ok","pong":true}`
//! * `{"op":"generate","model":..,"bucket":..,"policy":..,"prompt":..,
//!    "seed":..,"steps"?:..,"cfg_scale"?:..}` → run stats (including the
//!    `h2d_bytes`/`h2d_calls`/`d2h_bytes`/`d2h_calls` transfer meters)
//! * `{"op":"stats"}` → server-level counters + latency percentiles
//! * `{"op":"shutdown"}` → stops the server
//!
//! `generate` payloads are validated before a sampler is built: `steps`
//! must be a positive integer no larger than the preset's training
//! schedule, `seed` and `cfg_scale` must be finite numbers. A malformed
//! field is a per-request `{"status":"error"}` response, never a worker
//! panic.

use anyhow::{anyhow, Context, Result};
use std::collections::{BTreeMap, VecDeque};
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::Instant;

use crate::config::Manifest;
use crate::engine::{Engine, Request};
use crate::model::LoadedModel;
use crate::policy::build_policy;
use crate::runtime::Runtime;
use crate::util::json::{self, Json};
use crate::util::stats;

/// Engines per (model, bucket), loaded once and shared by all workers.
pub struct EngineRegistry {
    engines: BTreeMap<(String, String), Arc<Engine>>,
}

impl EngineRegistry {
    /// Load the given (model, bucket) pairs from the artifact manifest.
    pub fn load(rt: Arc<Runtime>, manifest: &Manifest, pairs: &[(String, String)]) -> Result<Self> {
        let mut engines = BTreeMap::new();
        for (model, bucket) in pairs {
            let lm = Arc::new(LoadedModel::load(rt.clone(), manifest, model, bucket)?);
            engines.insert(
                (model.clone(), bucket.clone()),
                Arc::new(Engine::new(lm, manifest.schedule)),
            );
        }
        Ok(Self { engines })
    }

    pub fn get(&self, model: &str, bucket: &str) -> Result<&Arc<Engine>> {
        self.engines
            .get(&(model.to_string(), bucket.to_string()))
            .ok_or_else(|| anyhow!("no engine loaded for {model}/{bucket}"))
    }

    pub fn keys(&self) -> Vec<(String, String)> {
        self.engines.keys().cloned().collect()
    }
}

struct Job {
    payload: Json,
    enqueued: Instant,
    reply: mpsc::Sender<Json>,
}

#[derive(Default)]
struct Telemetry {
    requests: AtomicU64,
    errors: AtomicU64,
    latencies_s: Mutex<Vec<f64>>,
    queue_s: Mutex<Vec<f64>>,
}

/// The running server; dropping it (or calling [`Server::shutdown`]) stops
/// the listener and workers. Shutdown broadcasts on the queue condvar so
/// idle workers wake and exit immediately instead of polling.
pub struct Server {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    queue: Queue,
    handles: Vec<std::thread::JoinHandle<()>>,
}

/// Server configuration.
pub struct ServerConfig {
    /// Bind address; use port 0 for an ephemeral port (tests).
    pub addr: String,
    pub workers: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self { addr: "127.0.0.1:0".to_string(), workers: 2 }
    }
}

type Queue = Arc<(Mutex<VecDeque<Job>>, Condvar)>;

/// Set the stop flag under the queue lock and wake every waiting worker.
/// Taking the lock first closes the race where a worker has checked `stop`
/// but not yet parked on the condvar (the notify would otherwise be lost
/// and shutdown's joins would hang). Shared by [`Server::shutdown`]/drop
/// and the wire-level `shutdown` op so the protocol exists once.
fn signal_stop(queue: &Queue, stop: &AtomicBool) {
    let (lock, cv) = &**queue;
    let _guard = lock.lock().unwrap();
    stop.store(true, Ordering::SeqCst);
    cv.notify_all();
}

impl Server {
    /// Start the listener + worker pool.
    pub fn start(registry: Arc<EngineRegistry>, cfg: ServerConfig) -> Result<Server> {
        let listener = TcpListener::bind(&cfg.addr).context("bind")?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let queue: Queue = Arc::new((Mutex::new(VecDeque::new()), Condvar::new()));
        let telemetry = Arc::new(Telemetry::default());
        let mut handles = Vec::new();

        // worker pool
        for wid in 0..cfg.workers.max(1) {
            let queue = Arc::clone(&queue);
            let stop = Arc::clone(&stop);
            let registry = Arc::clone(&registry);
            let telemetry = Arc::clone(&telemetry);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("foresight-server-worker-{wid}"))
                    .spawn(move || loop {
                        let job = {
                            let (lock, cv) = &*queue;
                            let mut q = lock.lock().unwrap();
                            // Plain wait (no timeout): enqueue notifies one
                            // worker, shutdown sets `stop` under the queue
                            // lock and notifies all, so no wakeup is lost
                            // and idle workers never spin.
                            loop {
                                if let Some(j) = q.pop_front() {
                                    break j;
                                }
                                if stop.load(Ordering::SeqCst) {
                                    return;
                                }
                                q = cv.wait(q).unwrap();
                            }
                        };
                        let queue_s = job.enqueued.elapsed().as_secs_f64();
                        let resp = handle_generate(&registry, &job.payload, queue_s, &telemetry);
                        let _ = job.reply.send(resp);
                    })
                    .expect("spawn worker"),
            );
        }

        // accept loop
        {
            let stop_accept = Arc::clone(&stop);
            let queue = Arc::clone(&queue);
            let telemetry = Arc::clone(&telemetry);
            handles.push(
                std::thread::Builder::new()
                    .name("foresight-server-accept".to_string())
                    .spawn(move || {
                        let mut conn_handles: Vec<std::thread::JoinHandle<()>> = Vec::new();
                        while !stop_accept.load(Ordering::SeqCst) {
                            // Reap finished connection handlers each pass so
                            // the handle list tracks live connections instead
                            // of growing for the server's lifetime.
                            let mut i = 0;
                            while i < conn_handles.len() {
                                if conn_handles[i].is_finished() {
                                    let _ = conn_handles.swap_remove(i).join();
                                } else {
                                    i += 1;
                                }
                            }
                            match listener.accept() {
                                Ok((stream, _peer)) => {
                                    let queue = Arc::clone(&queue);
                                    let stop = Arc::clone(&stop_accept);
                                    let telemetry = Arc::clone(&telemetry);
                                    conn_handles.push(std::thread::spawn(move || {
                                        let _ = handle_conn(stream, queue, stop, telemetry);
                                    }));
                                }
                                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                                    std::thread::sleep(std::time::Duration::from_millis(10));
                                }
                                Err(_) => break,
                            }
                        }
                        for h in conn_handles {
                            let _ = h.join();
                        }
                    })
                    .expect("spawn accept"),
            );
        }

        Ok(Server { addr, stop, queue, handles })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting and join all threads.
    pub fn shutdown(mut self) {
        signal_stop(&self.queue, &self.stop);
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        signal_stop(&self.queue, &self.stop);
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn err_json(msg: &str) -> Json {
    Json::obj(vec![("status", Json::str("error")), ("error", Json::str(msg))])
}

fn handle_conn(
    mut stream: TcpStream,
    queue: Queue,
    stop: Arc<AtomicBool>,
    telemetry: Arc<Telemetry>,
) -> Result<()> {
    use std::io::Read;
    // Poll with a read timeout so idle connections notice server shutdown
    // instead of blocking forever in a read (which would deadlock
    // Server::shutdown's thread joins).
    stream.set_read_timeout(Some(std::time::Duration::from_millis(100)))?;
    let mut writer = stream.try_clone()?;
    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 4096];
    'conn: loop {
        // extract complete lines already buffered
        while let Some(pos) = buf.iter().position(|&b| b == b'\n') {
            let line_bytes: Vec<u8> = buf.drain(..=pos).collect();
            let line = String::from_utf8_lossy(&line_bytes).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if !handle_line(&line, &mut writer, &queue, &stop, &telemetry)? {
                break 'conn;
            }
        }
        if stop.load(Ordering::SeqCst) {
            break;
        }
        match stream.read(&mut chunk) {
            Ok(0) => break, // peer closed
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue;
            }
            Err(_) => break,
        }
    }
    Ok(())
}

/// Process one protocol line; returns false when the connection should end.
fn handle_line(
    line: &str,
    writer: &mut TcpStream,
    queue: &Queue,
    stop: &Arc<AtomicBool>,
    telemetry: &Arc<Telemetry>,
) -> Result<bool> {
    {
        let payload = match json::parse(line) {
            Ok(j) => j,
            Err(e) => {
                writeln!(writer, "{}", err_json(&format!("bad json: {e}")))?;
                return Ok(true);
            }
        };
        let op = payload.get("op").and_then(|o| o.as_str()).unwrap_or("");
        let resp = match op {
            "ping" => Json::obj(vec![("status", Json::str("ok")), ("pong", Json::Bool(true))]),
            "stats" => {
                let lat = telemetry.latencies_s.lock().unwrap().clone();
                let qs = telemetry.queue_s.lock().unwrap().clone();
                Json::obj(vec![
                    ("status", Json::str("ok")),
                    ("requests", Json::num(telemetry.requests.load(Ordering::Relaxed) as f64)),
                    ("errors", Json::num(telemetry.errors.load(Ordering::Relaxed) as f64)),
                    ("latency_p50_s", Json::num(stats::percentile(&lat, 50.0))),
                    ("latency_p95_s", Json::num(stats::percentile(&lat, 95.0))),
                    ("latency_mean_s", Json::num(stats::mean(&lat))),
                    ("queue_mean_s", Json::num(stats::mean(&qs))),
                ])
            }
            "shutdown" => {
                signal_stop(queue, stop);
                let r = Json::obj(vec![("status", Json::str("ok")), ("stopping", Json::Bool(true))]);
                writeln!(writer, "{r}")?;
                return Ok(false);
            }
            "generate" => {
                let (tx, rx) = mpsc::channel();
                // Check `stop` under the queue lock: workers only exit
                // after observing `stop` (set under the same lock), so a
                // job pushed while `stop` is still false here is
                // guaranteed a live worker — enqueueing after shutdown
                // would otherwise block rx.recv() forever and deadlock
                // the join in Server::shutdown.
                let enqueued = {
                    let (lock, cv) = &**queue;
                    let mut q = lock.lock().unwrap();
                    if stop.load(Ordering::SeqCst) {
                        false
                    } else {
                        q.push_back(Job { payload, enqueued: Instant::now(), reply: tx });
                        cv.notify_one();
                        true
                    }
                };
                if enqueued {
                    rx.recv().unwrap_or_else(|_| err_json("worker dropped"))
                } else {
                    err_json("server is shutting down")
                }
            }
            other => err_json(&format!("unknown op '{other}'")),
        };
        writeln!(writer, "{resp}")?;
    }
    Ok(true)
}

fn handle_generate(
    registry: &EngineRegistry,
    payload: &Json,
    queue_s: f64,
    telemetry: &Telemetry,
) -> Json {
    telemetry.requests.fetch_add(1, Ordering::Relaxed);
    let get_str = |k: &str| payload.get(k).and_then(|v| v.as_str()).map(str::to_string);
    let model = get_str("model").unwrap_or_else(|| "opensora-sim".to_string());
    let bucket = get_str("bucket").unwrap_or_else(|| "240p-2s".to_string());
    let policy_spec = get_str("policy").unwrap_or_else(|| "foresight".to_string());
    let prompt = get_str("prompt").unwrap_or_default();

    let run = (|| -> Result<Json> {
        // Wire validation before any sampler is built: a `steps: 0` (or
        // out-of-schedule DDIM step count) used to trip the sampler
        // constructor's assert, panic the worker, and turn every later
        // request on that worker into "worker dropped".
        let seed = match payload.get("seed") {
            None => 0,
            Some(v) => {
                let s = v.as_f64().ok_or_else(|| anyhow!("seed must be a number"))?;
                if !s.is_finite() || s < 0.0 {
                    return Err(anyhow!("seed must be a finite non-negative number, got {s}"));
                }
                s as u64
            }
        };
        let steps = match payload.get("steps") {
            None => None,
            Some(v) => {
                let s = v
                    .as_f64()
                    .ok_or_else(|| anyhow!("steps must be a positive integer"))?;
                if !s.is_finite() || s < 1.0 || s.fract() != 0.0 {
                    return Err(anyhow!("steps must be a positive integer, got {s}"));
                }
                Some(s as usize)
            }
        };
        let cfg_scale = match payload.get("cfg_scale") {
            None => None,
            Some(v) => {
                let c = v.as_f64().ok_or_else(|| anyhow!("cfg_scale must be a number"))?;
                if !c.is_finite() {
                    return Err(anyhow!("cfg_scale must be finite, got {c}"));
                }
                Some(c)
            }
        };

        let engine = registry.get(&model, &bucket)?;
        let info = &engine.model().info;
        if let Some(s) = steps {
            // One bound for both samplers: DDIM's constructor asserts it,
            // and an absurd rflow step count would only allocate
            // gigabyte-scale sigma tables before doing useless work.
            let t_train = engine.schedule().train_timesteps;
            if s > t_train {
                return Err(anyhow!(
                    "steps must be <= {t_train} (the training schedule length), got {s}"
                ));
            }
        }
        let mut policy = build_policy(&policy_spec, info, steps.unwrap_or(info.steps))?;
        let mut req = Request::new(&prompt, seed);
        req.steps = steps;
        req.cfg_scale = cfg_scale;
        let result = engine.generate(&req, policy.as_mut(), None)?;
        let s = &result.stats;
        Ok(Json::obj(vec![
            ("status", Json::str("ok")),
            ("model", Json::str(&model)),
            ("bucket", Json::str(&bucket)),
            ("policy", Json::str(&s.policy)),
            ("wall_s", Json::num(s.wall_s)),
            ("queue_s", Json::num(queue_s)),
            ("steps", Json::num(s.per_step_s.len() as f64)),
            ("computed_units", Json::num(s.computed_units as f64)),
            ("reused_units", Json::num(s.reused_units as f64)),
            ("reuse_fraction", Json::num(s.reuse_fraction())),
            ("cache_peak_bytes", Json::num(s.cache_peak_bytes as f64)),
            ("h2d_bytes", Json::num(s.h2d_bytes as f64)),
            ("h2d_calls", Json::num(s.h2d_calls as f64)),
            ("d2h_bytes", Json::num(s.d2h_bytes as f64)),
            ("d2h_calls", Json::num(s.d2h_calls as f64)),
        ]))
    })();

    match run {
        Ok(resp) => {
            if let Some(w) = resp.get("wall_s").and_then(|v| v.as_f64()) {
                telemetry.latencies_s.lock().unwrap().push(w);
                telemetry.queue_s.lock().unwrap().push(queue_s);
            }
            resp
        }
        Err(e) => {
            telemetry.errors.fetch_add(1, Ordering::Relaxed);
            err_json(&format!("{e:#}"))
        }
    }
}

/// Blocking JSON-lines client for the server (used by examples and tests).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &SocketAddr) -> Result<Self> {
        let stream = TcpStream::connect(addr).context("connect")?;
        let writer = stream.try_clone()?;
        Ok(Self { reader: BufReader::new(stream), writer })
    }

    /// Send one request object; wait for one response line.
    pub fn call(&mut self, req: &Json) -> Result<Json> {
        writeln!(self.writer, "{req}")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        if line.is_empty() {
            return Err(anyhow!("server closed connection"));
        }
        json::parse(line.trim()).map_err(|e| anyhow!("bad response: {e}"))
    }

    pub fn ping(&mut self) -> Result<bool> {
        let r = self.call(&Json::obj(vec![("op", Json::str("ping"))]))?;
        Ok(r.get("pong").and_then(|v| v.as_bool()).unwrap_or(false))
    }
}
