//! Continuous (step-level) batching scheduler, sharded across device
//! replicas — the server's routing front and worker loop.
//!
//! # Worker loop
//!
//! Each worker is pinned to one device ordinal and drives one **cohort**
//! of generation sessions per iteration instead of dispatching whole
//! requests: it blocks for work (an empty queue waits on the router
//! condvar, never out a window), starts a session for the first job, and
//! then advances the cohort one denoising step at a time via
//! [`session::step_many_refs`]. At every step boundary it non-blockingly
//! admits queued compatible jobs — same (model, bucket), the only fields
//! that pin the device pass; `steps`, `cfg_scale` and `policy` are
//! per-session state — up to `max_batch` lanes, and retires finished
//! lanes **immediately**: a short request that joined a long batch
//! returns as soon as its own schedule completes, and a request that
//! arrives `k` steps into an in-flight batch joins at the next boundary
//! instead of waiting a full request out.
//!
//! Boundary admission takes only the FIFO **prefix** of compatible jobs
//! from the worker's own queue: the moment a different-(model, bucket)
//! job reaches that queue's head, the cohort stops admitting and drains
//! within its lanes' remaining schedules — sustained compatible traffic
//! cannot starve a queued request for another engine behind a
//! forever-refilled cohort. The fence is per-device now, and the routing
//! front only ever *appends* to a device's queue in arrival order, so a
//! job routed to device `d` is never reordered behind later arrivals
//! for `d`.
//!
//! An optional admission window (`ServerConfig::admit_window_ms`,
//! default 0) lets a *fresh* cohort linger briefly for batchmates before
//! its first step. It never applies to an in-flight cohort, ends early
//! when the cohort fills, and at the default of 0 a lone request starts
//! stepping immediately.
//!
//! Per-job validation failures are answered individually at admission and
//! never poison the cohort; a step error fails every in-flight lane (the
//! cohort's shared pass is poisoned — see the `session` module docs) but
//! leaves the worker serving.
//!
//! # Sharding (the [`Router`])
//!
//! The router owns one FIFO queue **per device** plus each device's
//! advertised state (active lanes, in-flight cohort key, steal
//! requests), all behind a single mutex with a **single shared condvar**.
//! One condvar instead of per-device condvars is deliberate: every
//! wait-site (idle workers of all devices, admission windows, steal
//! parks) shares it, so `notify_all` under the router lock *is* the
//! wake-every-device broadcast — shutdown cannot miss a parked worker,
//! and an arrival on one queue also wakes thieves on the others. At
//! `devices == 1` the classic worker pool (several workers, one queue)
//! runs unchanged through the same code paths.
//!
//! Admit-time routing ([`route`]): cohort affinity first — a device whose
//! in-flight cohort has the job's key and a spare lane absorbs it at its
//! next step boundary (fewest lanes, ties to the lowest ordinal) — else
//! least-loaded: fewest active lanes, ties by shortest queue, then lowest
//! ordinal.
//!
//! Work stealing happens only at step boundaries, in two tiers:
//!
//! 1. **Job steal** (free): a worker with an empty queue takes the
//!    *front* job of the most-loaded other device's queue — the oldest
//!    queued job starts earlier than it would have, preserving per-key
//!    FIFO order. A front the owner can still coalesce (its key matches
//!    the owner's in-flight cohort with a spare lane) is never stolen:
//!    joining that cohort at the owner's next boundary beats a lone pass
//!    elsewhere. Mid-cohort, a device with spare lanes and an empty
//!    queue pulls only front jobs matching its cohort key.
//! 2. **Session migration** (one lane download + one upload): when every
//!    queue is empty, a fully idle worker raises `wants_work` and parks
//!    (a device whose advertised cohort grows to ≥ 2 lanes broadcasts on
//!    the condvar, so a worker that parked before lanes existed to spare
//!    re-evaluates without polling);
//!    the most-loaded device holding ≥ 2 lanes reserves the request at
//!    its next boundary (under the router lock, so no double-give),
//!    migrates one session off-lock via [`Session::migrate`], and
//!    deposits the lane in the thief's `incoming` slot. The migration
//!    charges the request's `RunStats` exactly one extra lane
//!    download+upload; cache/conditioning round-trips are metered by the
//!    two runtimes' `TransferStats`. The `steals` counters (global and
//!    per-device, credited to the *target*) count these migrations only.
//!
//! Shutdown: the stop flag is set under the router lock and broadcast on
//! the shared condvar, so workers parked anywhere wake immediately. A
//! worker drains its own queue and any deposited lanes before exiting —
//! every job enqueued before the stop flag was raised is answered — and a
//! worker mid-cohort finishes stepping its admitted lanes (no new
//! admissions) so in-flight requests complete rather than erroring.
//! Expired-deadline jobs met during the drain are answered with the
//! deadline error like any other admission.
//!
//! # Overload control
//!
//! Queues are bounded (`--max-queue`, 0 = unbounded): [`Router::enqueue`]
//! refuses a job — [`EnqueueOutcome::Overloaded`], turned into the wire
//! `overloaded` backpressure response by the connection handler — only
//! when the routed queue *and* the globally shortest queue are both at
//! the bound, so capacity anywhere in the fleet is used before a reject.
//!
//! Per-request deadlines (`deadline_ms`) are enforced at three points,
//! all without consuming a device pass: at admission ([`admit`] answers
//! an already-expired job before starting its session), at every step
//! boundary for the device's own queue ([`sweep_expired_queue`]), and at
//! every step boundary for in-flight lanes ([`sweep_dead_lanes`] — an
//! expired session retires early via [`Session::abandon`], freeing its
//! lane for the next intake in the same boundary). Expirations count in
//! `deadline_misses` (and `errors`); rejected-at-capacity jobs count in
//! `rejects` only — they were never admitted.

use anyhow::{anyhow, Result};
use std::cmp::Reverse;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar};
use std::time::{Duration, Instant};

use crate::engine::{session, Session};
use crate::policy::build_policy;
use crate::trace;
use crate::util::json::Json;
use crate::util::sync::{OrderedMutex, RANK_ROUTER_STATE};

use super::{
    cohort_key, deadline_err_json, err_json, generate_response, parse_generate, reuse_timeline,
    EngineRegistry, GenerateParams, Job, Telemetry,
};

/// Scheduler knobs (from `ServerConfig`).
pub(super) struct SchedConfig {
    pub max_batch: usize,
    pub admit_window: Duration,
}

/// Everything one scheduler worker thread needs.
pub(super) struct WorkerCtx {
    pub router: Arc<Router>,
    pub stop: Arc<AtomicBool>,
    pub registry: Arc<EngineRegistry>,
    pub telemetry: Arc<Telemetry>,
    pub cfg: SchedConfig,
    /// Device ordinal this worker is pinned to.
    pub device: usize,
}

/// One in-flight lane: a started session plus everything needed to answer
/// its client when it retires.
struct Lane {
    session: Session<'static>,
    job: Job,
    /// Queue wait measured at admission (time to *join* a pass, not to
    /// finish one).
    queue_s: f64,
    params: GenerateParams,
}

/// Per-device state the router tracks for routing and stealing. `lanes`
/// and `cohort` are advertised by the device's worker at step boundaries
/// ([`publish`]); `wants_work`/`incoming` implement session migration.
#[derive(Default)]
struct DevState {
    /// Active lanes on this device (worker-published; includes deposited
    /// but not-yet-absorbed migrated lanes).
    lanes: usize,
    /// The in-flight (or forming) cohort's (model, bucket) key.
    cohort: Option<(String, String)>,
    /// Raised by the device's idle worker to request a migrated session;
    /// cleared (under the router lock) by whoever hands it work.
    wants_work: bool,
    /// Migrated lanes deposited by a victim, absorbed by this device's
    /// worker at its next wakeup or step boundary.
    incoming: Vec<Lane>,
}

struct RouterState {
    queues: Vec<VecDeque<Job>>,
    devs: Vec<DevState>,
}

/// What [`Router::enqueue`] did with a job. `Overloaded` and `Stopping`
/// mean the job was **not** enqueued — the caller still owns its reply
/// channel and must answer the client itself.
pub(super) enum EnqueueOutcome {
    /// Queued; `depth` is the chosen device queue's length after the push.
    Queued { depth: usize },
    /// Every candidate queue sits at `--max-queue`; `depth` is the
    /// shortest queue's length (what the client is behind if it retries).
    Overloaded { depth: usize },
    /// The server is stopping.
    Stopping,
}

/// The routing front: per-device FIFO queues + device state behind one
/// mutex and one shared condvar (module docs §Sharding — the single
/// condvar makes `notify_all` a wake-every-device broadcast).
pub(super) struct Router {
    devices: usize,
    max_batch: usize,
    /// Per-device queue bound (`--max-queue`); 0 = unbounded.
    max_queue: usize,
    /// Rank-10 `router.state` in the canonical lock order
    /// (`util::sync`): every other lock in the stack ranks above it, so
    /// replies, session steps and migrations all happen off this lock —
    /// the `analysis::lint` io-under-lock pass enforces exactly that.
    state: OrderedMutex<RouterState>,
    cv: Condvar,
}

impl Router {
    pub(super) fn new(devices: usize, max_batch: usize, max_queue: usize) -> Self {
        let devices = devices.max(1);
        Router {
            devices,
            max_batch: max_batch.max(1),
            max_queue,
            state: OrderedMutex::new(
                "router.state",
                RANK_ROUTER_STATE,
                RouterState {
                    queues: (0..devices).map(|_| VecDeque::new()).collect(),
                    devs: (0..devices).map(|_| DevState::default()).collect(),
                },
            ),
            cv: Condvar::new(),
        }
    }

    pub(super) fn devices(&self) -> usize {
        self.devices
    }

    /// Current per-device queue depths (the `stats` op's `queue_depth`
    /// and the degradation pressure signal). The pressure read uses the
    /// **minimum**: with job steals live, a single empty queue means the
    /// next arrival need not wait, whatever the others hold.
    pub(super) fn queue_depths(&self) -> Vec<usize> {
        let st = self.state.lock();
        st.queues.iter().map(|q| q.len()).collect()
    }

    /// Route and enqueue one job (module docs §Sharding and §Overload).
    /// Admission is bounded: when the routed device's queue is at
    /// `max_queue`, the job falls back to the globally shortest queue
    /// (steals make any queue a valid home), and only if *that* is full
    /// too is the job refused with [`EnqueueOutcome::Overloaded`].
    /// `stop` is checked under the router lock, and workers only exit
    /// after observing `stop` under the same lock *with their queue
    /// empty*, so a `Queued` job is guaranteed to be answered.
    pub(super) fn enqueue(&self, job: Job, stop: &AtomicBool) -> EnqueueOutcome {
        let mut st = self.state.lock();
        if stop.load(Ordering::SeqCst) {
            return EnqueueOutcome::Stopping;
        }
        let key = cohort_key(&job.payload);
        let lens: Vec<usize> = st.queues.iter().map(|q| q.len()).collect();
        let mut d = route(&st.devs, &lens, key.as_ref(), self.max_batch);
        if self.max_queue > 0 && lens[d] >= self.max_queue {
            let shortest = (0..lens.len()).min_by_key(|&i| (lens[i], i)).unwrap_or(d);
            if lens[shortest] >= self.max_queue {
                return EnqueueOutcome::Overloaded { depth: lens[shortest] };
            }
            d = shortest;
        }
        let tid = job.trace_id;
        st.queues[d].push_back(job);
        let depth = st.queues[d].len();
        trace::emit(tid, trace::Payload::Enqueue { device: d as u64, depth: depth as u64 });
        // notify_all, not notify_one: a gathering worker parked on the
        // shared condvar must also see new arrivals inside its window,
        // and idle workers on other devices must re-check for steals.
        self.cv.notify_all();
        EnqueueOutcome::Queued { depth }
    }

    /// Set the stop flag under the router lock and wake every waiting
    /// worker on every device — the single shared condvar makes this one
    /// `notify_all` the whole-fleet broadcast. Taking the lock first
    /// closes the race where a worker has checked `stop` but not yet
    /// parked (the notify would otherwise be lost and shutdown's joins
    /// would hang). Shared by `Server::shutdown`/drop and the wire-level
    /// `shutdown` op so the protocol exists once.
    pub(super) fn signal_stop(&self, stop: &AtomicBool) {
        let _guard = self.state.lock();
        stop.store(true, Ordering::SeqCst);
        self.cv.notify_all();
    }
}

/// Admit-time routing (module docs §Sharding): cohort affinity — the
/// device whose advertised in-flight cohort matches `key` and has a
/// spare lane, fewest lanes first, ties to the lowest ordinal — else
/// least-loaded by (active lanes, queue length, ordinal).
fn route(
    devs: &[DevState],
    queue_lens: &[usize],
    key: Option<&(String, String)>,
    max_batch: usize,
) -> usize {
    if let Some(key) = key {
        let affine = devs
            .iter()
            .enumerate()
            .filter(|(_, d)| d.cohort.as_ref() == Some(key) && d.lanes < max_batch)
            .min_by_key(|&(i, d)| (d.lanes, i))
            .map(|(i, _)| i);
        if let Some(i) = affine {
            return i;
        }
    }
    devs.iter()
        .enumerate()
        .min_by_key(|&(i, d)| (d.lanes, queue_lens[i], i))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// What [`acquire_work`] hands the worker loop.
enum Work {
    /// A fresh job popped from a queue (own, or stolen from another
    /// device's front).
    Job(Job),
    /// Migrated lanes deposited for this device (all one cohort key).
    Migrated(Vec<Lane>),
}

/// Advertise this device's boundary state to the router (lane count and
/// cohort key drive affinity routing and steal decisions). Growing to a
/// stealable cohort (≥ 2 lanes) broadcasts on the shared condvar: an idle
/// worker that parked while no device had lanes to spare re-evaluates and
/// raises `wants_work`, so session migration stays live without polling.
fn publish(ctx: &WorkerCtx, lanes: usize, key: Option<&(String, String)>) {
    let mut st = ctx.router.state.lock();
    let grew = lanes > st.devs[ctx.device].lanes;
    st.devs[ctx.device].lanes = lanes;
    st.devs[ctx.device].cohort = key.cloned();
    if grew && lanes >= 2 && ctx.router.devices() > 1 {
        ctx.router.cv.notify_all();
    }
}

/// The worker loop: serve cohorts until shutdown.
pub(super) fn run_worker(ctx: &WorkerCtx) {
    loop {
        let (mut lanes, key) = match acquire_work(ctx) {
            None => return,
            Some(Work::Job(first)) => start_cohort(ctx, first),
            Some(Work::Migrated(lanes)) => {
                // Continue migrated sessions as a cohort of their own (no
                // `batches` tick — their requests' cohorts were already
                // counted on the source device).
                let key = Some((
                    lanes[0].params.model.clone(),
                    lanes[0].params.bucket.clone(),
                ));
                (lanes, key)
            }
        };

        // Drive the cohort: join at boundaries, retire eagerly.
        let mut stepped = false;
        while !lanes.is_empty() {
            // Deadline/poison sweeps run before intake so freed lanes are
            // immediately re-fillable in the same boundary.
            sweep_dead_lanes(ctx, &mut lanes);
            if let Some(key) = key.as_ref() {
                if !ctx.stop.load(Ordering::SeqCst) {
                    sweep_expired_queue(ctx);
                    if lanes.len() < ctx.cfg.max_batch {
                        let room = ctx.cfg.max_batch - lanes.len();
                        let (jobs, migrated) = boundary_intake(ctx, key, room);
                        for job in jobs {
                            admit(ctx, job, &mut lanes, stepped);
                        }
                        lanes.extend(migrated);
                    }
                    maybe_give_lane(ctx, &mut lanes);
                }
            }
            // The sweeps may have emptied the cohort (every lane expired,
            // nothing admitted): `step_many_refs` rejects an empty slice,
            // so fall back to acquiring fresh work instead.
            if lanes.is_empty() {
                break;
            }
            publish(ctx, lanes.len(), key.as_ref());
            let t_pass = Instant::now();
            let report = {
                let mut refs: Vec<&mut Session<'static>> =
                    lanes.iter_mut().map(|l| &mut l.session).collect();
                session::step_many_refs(&mut refs)
            };
            match report {
                Ok(rep) => {
                    // One complete trace event per fused cohort pass:
                    // wall time, device ordinal, lanes advanced. Cohort
                    // scope, so it carries no single request's span id.
                    trace::emit_dur(
                        0,
                        t_pass.elapsed().as_micros() as u64,
                        trace::Payload::Pass {
                            device: ctx.device as u64,
                            occupancy: rep.occupancy as u64,
                        },
                    );
                    let dt = &ctx.telemetry.per_device[ctx.device];
                    ctx.telemetry.occupancy.lock().push(rep.occupancy as f64);
                    ctx.telemetry
                        .occupancy_peak
                        .fetch_max(rep.occupancy as u64, Ordering::Relaxed);
                    dt.occupancy.lock().push(rep.occupancy as f64);
                    dt.occupancy_peak
                        .fetch_max(rep.occupancy as u64, Ordering::Relaxed);
                    // A fresh cohort's very first stack build is not a
                    // membership change; only count regroups after a
                    // previous step existed.
                    if stepped && rep.restacked && rep.occupancy > 1 {
                        ctx.telemetry.regroups.fetch_add(1, Ordering::Relaxed);
                    }
                    stepped = true;
                }
                Err(e) => {
                    // A step error poisons the cohort's shared pass:
                    // answer every in-flight lane, drop the sessions
                    // (their worker threads are reaped on drop), keep
                    // serving.
                    let msg = format!("{e:#}");
                    let n = lanes.len() as u64;
                    ctx.telemetry.errors.fetch_add(n, Ordering::Relaxed);
                    ctx.telemetry.lanes_active.fetch_sub(n, Ordering::Relaxed);
                    ctx.telemetry.per_device[ctx.device]
                        .lanes_active
                        .fetch_sub(n, Ordering::Relaxed);
                    for lane in lanes.drain(..) {
                        let _ = lane.job.reply.send(err_json(&msg));
                    }
                    break;
                }
            }
            let mut i = 0;
            while i < lanes.len() {
                if lanes[i].session.is_done() {
                    let lane = lanes.remove(i);
                    retire(ctx, lane);
                } else {
                    i += 1;
                }
            }
        }
        publish(ctx, 0, None);
    }
}

/// Block until this device has work (or shutdown). Priority order: own
/// queue front, deposited migrated lanes, a job steal from the
/// most-loaded other queue's front; otherwise raise `wants_work` when a
/// session migration could help and park on the shared condvar.
///
/// The stop flag is only honored once the own queue and deposit slot are
/// empty, so every job routed here before shutdown is answered (the
/// enqueue-side guarantee in [`Router::enqueue`]).
fn acquire_work(ctx: &WorkerCtx) -> Option<Work> {
    let me = ctx.device;
    let n = ctx.router.devices();
    let mut st = ctx.router.state.lock();
    loop {
        // 1. own queue
        if let Some(job) = st.queues[me].pop_front() {
            st.devs[me].wants_work = false;
            return Some(Work::Job(job));
        }
        // 2. migrated lanes deposited for us: absorb the subset sharing
        //    the first lane's cohort key (different-key leftovers stay
        //    for the next pass).
        if !st.devs[me].incoming.is_empty() {
            let all = std::mem::take(&mut st.devs[me].incoming);
            let mut taken: Vec<Lane> = Vec::new();
            for lane in all {
                let compatible = taken.is_empty()
                    || (lane.params.model == taken[0].params.model
                        && lane.params.bucket == taken[0].params.bucket);
                if compatible {
                    taken.push(lane);
                } else {
                    st.devs[me].incoming.push(lane);
                }
            }
            st.devs[me].wants_work = false;
            return Some(Work::Migrated(taken));
        }
        if ctx.stop.load(Ordering::SeqCst) {
            // Nothing owed locally. Deposits cannot race this exit:
            // victims re-check `stop` under this same lock before
            // depositing, so the slot drained above stays empty.
            st.devs[me].wants_work = false;
            return None;
        }
        if n > 1 {
            // 3. job steal: the front job of the most-loaded other
            //    device's queue (free — the oldest queued job starts
            //    earlier than it would have; FIFO order is preserved).
            //    A front the owner can still coalesce — its key matches
            //    the owner's advertised cohort with a spare lane — is
            //    left alone: it joins that cohort at the owner's next
            //    boundary, which beats starting a lone pass here.
            let victim = (0..n)
                .filter(|&d| {
                    d != me
                        && st.queues[d].front().is_some_and(|j| {
                            let k = cohort_key(&j.payload);
                            k.is_none()
                                || st.devs[d].cohort != k
                                || st.devs[d].lanes >= ctx.cfg.max_batch
                        })
                })
                .max_by_key(|&d| (st.devs[d].lanes + st.queues[d].len(), Reverse(d)));
            if let Some(v) = victim {
                if let Some(job) = st.queues[v].pop_front() {
                    trace::emit(
                        job.trace_id,
                        trace::Payload::Steal { device: me as u64, victim: v as u64 },
                    );
                    st.devs[me].wants_work = false;
                    return Some(Work::Job(job));
                }
            }
            // 4. every queue is empty: ask for a session migration when
            //    some other device holds enough lanes to spare one.
            st.devs[me].wants_work = (0..n).any(|d| d != me && st.devs[d].lanes >= 2);
        }
        st = st.wait(&ctx.router.cv);
    }
}

/// Start a fresh cohort from its first job: advertise the forming
/// cohort's key (so admit-time affinity routes same-key arrivals to this
/// device during the window), optionally gather batchmates for the
/// admission window, then admit everything collected.
fn start_cohort(ctx: &WorkerCtx, first: Job) -> (Vec<Lane>, Option<(String, String)>) {
    let key = cohort_key(&first.payload);
    let mut jobs = vec![first];
    if let Some(key) = key.as_ref() {
        publish(ctx, 0, Some(key));
        // Jobs are only *gathered* during the window — nobody's session
        // starts until it closes, so the wait lands in every member's
        // queue_s (as the retired gather window did), never in wall_s.
        if ctx.cfg.max_batch > 1 && !ctx.cfg.admit_window.is_zero() {
            let deadline = Instant::now() + ctx.cfg.admit_window;
            let mut st = ctx.router.state.lock();
            loop {
                let q = &mut st.queues[ctx.device];
                let mut i = 0;
                while i < q.len() && jobs.len() < ctx.cfg.max_batch {
                    if cohort_key(&q[i].payload).as_ref() != Some(key) {
                        i += 1;
                    } else if let Some(job) = q.remove(i) {
                        jobs.push(job);
                    } else {
                        break; // i < q.len() makes this unreachable
                    }
                }
                if jobs.len() >= ctx.cfg.max_batch || ctx.stop.load(Ordering::SeqCst) {
                    break;
                }
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (guard, _timed_out) = st.wait_timeout(&ctx.router.cv, deadline - now);
                st = guard;
            }
        }
    }
    let mut lanes = Vec::new();
    for job in jobs {
        admit(ctx, job, &mut lanes, false);
    }
    if !lanes.is_empty() {
        ctx.telemetry.batches.fetch_add(1, Ordering::Relaxed);
    }
    (lanes, key)
}

/// Step-boundary intake for an in-flight cohort, all under one router
/// lock: (a) the FIFO **prefix** of compatible jobs from this device's
/// own queue — the fence stops at the first different-key job, so a
/// routed job is never reordered behind later arrivals for this device;
/// (b) deposited migrated lanes matching this cohort; (c) with an empty
/// own queue, matching *front* jobs stolen from the most-loaded other
/// devices (module docs §Sharding tier 1).
fn boundary_intake(
    ctx: &WorkerCtx,
    key: &(String, String),
    room: usize,
) -> (Vec<Job>, Vec<Lane>) {
    let me = ctx.device;
    let mut jobs = Vec::new();
    let mut migrated = Vec::new();
    if room == 0 {
        return (jobs, migrated);
    }
    let mut st = ctx.router.state.lock();
    while jobs.len() < room {
        let front_matches = st.queues[me]
            .front()
            .is_some_and(|j| cohort_key(&j.payload).as_ref() == Some(key));
        if !front_matches {
            break;
        }
        let Some(job) = st.queues[me].pop_front() else {
            break;
        };
        jobs.push(job);
    }
    if !st.devs[me].incoming.is_empty() {
        let all = std::mem::take(&mut st.devs[me].incoming);
        for lane in all {
            if jobs.len() + migrated.len() < room
                && lane.params.model == key.0
                && lane.params.bucket == key.1
            {
                migrated.push(lane);
            } else {
                st.devs[me].incoming.push(lane);
            }
        }
    }
    if st.queues[me].is_empty() {
        while jobs.len() + migrated.len() < room {
            let victim = (0..ctx.router.devices())
                .filter(|&d| {
                    d != me
                        && st.queues[d]
                            .front()
                            .is_some_and(|j| cohort_key(&j.payload).as_ref() == Some(key))
                })
                .max_by_key(|&d| (st.devs[d].lanes + st.queues[d].len(), Reverse(d)));
            match victim.and_then(|v| st.queues[v].pop_front().map(|j| (v, j))) {
                Some((v, job)) => {
                    trace::emit(
                        job.trace_id,
                        trace::Payload::Steal { device: me as u64, victim: v as u64 },
                    );
                    jobs.push(job);
                }
                None => break,
            }
        }
    }
    (jobs, migrated)
}

/// Victim side of session migration (module docs §Sharding tier 2): at a
/// step boundary, holding ≥ 2 lanes and at least as loaded as every
/// other device, hand one session to a device that raised `wants_work`.
/// The thief is reserved under the router lock (no double-give), the
/// migration itself runs off-lock, and the lane lands in the thief's
/// deposit slot — unless the server began stopping meanwhile, in which
/// case the request is answered with an error rather than stranded on a
/// worker that may already have exited.
fn maybe_give_lane(ctx: &WorkerCtx, lanes: &mut Vec<Lane>) {
    let me = ctx.device;
    let n = ctx.router.devices();
    if lanes.len() < 2 || n == 1 {
        return;
    }
    let thief = {
        let mut st = ctx.router.state.lock();
        let my_load = lanes.len() + st.queues[me].len();
        let busier = (0..n).any(|d| d != me && st.devs[d].lanes + st.queues[d].len() > my_load);
        if busier {
            return; // not the most-loaded device; its worker should give
        }
        match (0..n).find(|&d| d != me && st.devs[d].wants_work) {
            Some(t) => {
                st.devs[t].wants_work = false; // reserved
                t
            }
            None => return,
        }
    };
    // Any lane is correct to move; take the newest (its remaining
    // schedule is typically the longest, amortizing the transfer).
    let Some(mut lane) = lanes.pop() else {
        return;
    };
    let moved = ctx
        .registry
        .get_on(&lane.params.model, &lane.params.bucket, thief)
        .and_then(|engine| lane.session.migrate(engine));
    match moved {
        Ok(()) => {
            ctx.telemetry.per_device[me]
                .lanes_active
                .fetch_sub(1, Ordering::Relaxed);
            let mut st = ctx.router.state.lock();
            st.devs[me].lanes = st.devs[me].lanes.saturating_sub(1);
            if ctx.stop.load(Ordering::SeqCst) {
                // The thief may already have drained its deposit slot and
                // exited; answer the client instead of stranding the job.
                drop(st);
                ctx.telemetry.errors.fetch_add(1, Ordering::Relaxed);
                ctx.telemetry.lanes_active.fetch_sub(1, Ordering::Relaxed);
                let _ = lane.job.reply.send(err_json("server is shutting down"));
                return;
            }
            ctx.telemetry.steals.fetch_add(1, Ordering::Relaxed);
            let dt = &ctx.telemetry.per_device[thief];
            dt.steals.fetch_add(1, Ordering::Relaxed);
            dt.lanes_active.fetch_add(1, Ordering::Relaxed);
            st.devs[thief].lanes += 1;
            trace::emit(
                lane.job.trace_id,
                trace::Payload::Migrate { from: me as u64, to: thief as u64 },
            );
            st.devs[thief].incoming.push(lane);
            ctx.router.cv.notify_all();
        }
        Err(e) => {
            if lane.session.is_poisoned() {
                // The transfer itself failed (`migrate_inner`): the
                // session is unusable on either device — answer the
                // client now (we are at a step boundary) and wake the
                // thief so it can re-request elsewhere.
                ctx.telemetry.errors.fetch_add(1, Ordering::Relaxed);
                ctx.telemetry.lanes_active.fetch_sub(1, Ordering::Relaxed);
                ctx.telemetry.per_device[me]
                    .lanes_active
                    .fetch_sub(1, Ordering::Relaxed);
                lane.session.abandon();
                let _ = lane.job.reply.send(err_json(&format!("{e:#}")));
                let mut st = ctx.router.state.lock();
                st.devs[me].lanes = st.devs[me].lanes.saturating_sub(1);
                ctx.router.cv.notify_all();
            } else {
                // A precheck refusal (mismatched engine, sampler, …)
                // leaves the session untouched and healthy: keep serving
                // it locally rather than failing a correct request. The
                // broadcast lets the parked thief re-evaluate other
                // victims.
                lanes.push(lane);
                let _guard = ctx.router.state.lock();
                ctx.router.cv.notify_all();
            }
        }
    }
}

/// Step-boundary sweep of lanes that must stop consuming device passes:
/// sessions past their request deadline (answered with the
/// deadline-exceeded error, counted in `deadline_misses`) and sessions
/// poisoned outside the step path (e.g. a failed migration transfer) that
/// would otherwise error the whole cohort on the next step. Both retire
/// their branch workers eagerly via [`Session::abandon`].
fn sweep_dead_lanes(ctx: &WorkerCtx, lanes: &mut Vec<Lane>) {
    let now = Instant::now();
    let mut i = 0;
    while i < lanes.len() {
        let expired = lanes[i].job.deadline.is_some_and(|d| d <= now);
        if !expired && !lanes[i].session.is_poisoned() {
            i += 1;
            continue;
        }
        let lane = lanes.remove(i);
        ctx.telemetry.errors.fetch_add(1, Ordering::Relaxed);
        ctx.telemetry.lanes_active.fetch_sub(1, Ordering::Relaxed);
        ctx.telemetry.per_device[ctx.device]
            .lanes_active
            .fetch_sub(1, Ordering::Relaxed);
        let resp = if expired {
            ctx.telemetry.deadline_misses.fetch_add(1, Ordering::Relaxed);
            trace::emit(lane.job.trace_id, trace::Payload::DeadlineMiss { at: "lane" });
            deadline_err_json()
        } else {
            err_json("session poisoned (failed migration); request aborted")
        };
        lane.session.abandon();
        let _ = lane.job.reply.send(resp);
    }
}

/// Step-boundary sweep of this device's **queue**: jobs whose deadline
/// passed while waiting are answered with the deadline-exceeded error
/// right away instead of occupying a lane first. Removal preserves the
/// FIFO order of the surviving jobs. The replies go out off-lock.
fn sweep_expired_queue(ctx: &WorkerCtx) {
    let mut expired = Vec::new();
    {
        let mut st = ctx.router.state.lock();
        let q = &mut st.queues[ctx.device];
        if q.is_empty() {
            return;
        }
        let now = Instant::now();
        let mut i = 0;
        while i < q.len() {
            if !q[i].deadline.is_some_and(|d| d <= now) {
                i += 1;
            } else if let Some(job) = q.remove(i) {
                expired.push(job);
            } else {
                break; // i < q.len() makes this unreachable
            }
        }
    }
    for job in expired {
        ctx.telemetry.requests.fetch_add(1, Ordering::Relaxed);
        ctx.telemetry.errors.fetch_add(1, Ordering::Relaxed);
        ctx.telemetry.deadline_misses.fetch_add(1, Ordering::Relaxed);
        trace::emit(job.trace_id, trace::Payload::DeadlineMiss { at: "queue" });
        let _ = job.reply.send(deadline_err_json());
    }
}

/// Validate one job and start its session; answer the client directly on
/// failure (a bad request never poisons its batchmates).
///
/// Admission runs synchronously on the worker, so a mid-flight join
/// stalls the in-flight lanes for one request startup (text/K-V
/// precompute + uploads). Overlapping admission with the in-flight step
/// is a known follow-up optimization; at today's request-startup cost it
/// is well under one denoising step.
fn admit(ctx: &WorkerCtx, job: Job, lanes: &mut Vec<Lane>, midflight: bool) {
    ctx.telemetry.requests.fetch_add(1, Ordering::Relaxed);
    if job.deadline.is_some_and(|d| d <= Instant::now()) {
        // Expired while queued (or the client sent an already-hopeless
        // deadline): answer without spending a session start on it.
        ctx.telemetry.errors.fetch_add(1, Ordering::Relaxed);
        ctx.telemetry.deadline_misses.fetch_add(1, Ordering::Relaxed);
        trace::emit(job.trace_id, trace::Payload::DeadlineMiss { at: "admit" });
        let _ = job.reply.send(deadline_err_json());
        return;
    }
    let queue_s = job.enqueued.elapsed().as_secs_f64();
    // Attribute admission-time runtime transfers (text conditioning,
    // initial latent, per-step scalars) to this request's span.
    let _span = trace::scope(job.trace_id);
    match try_start(ctx, &job) {
        Ok((session, params)) => {
            let dt = &ctx.telemetry.per_device[ctx.device];
            ctx.telemetry.lanes_active.fetch_add(1, Ordering::Relaxed);
            dt.lanes_active.fetch_add(1, Ordering::Relaxed);
            trace::emit(
                job.trace_id,
                trace::Payload::Admit {
                    device: ctx.device as u64,
                    queue_us: (queue_s * 1e6) as u64,
                },
            );
            if midflight {
                ctx.telemetry.joins.fetch_add(1, Ordering::Relaxed);
                dt.joins.fetch_add(1, Ordering::Relaxed);
                trace::emit(
                    job.trace_id,
                    trace::Payload::Join {
                        device: ctx.device as u64,
                        lanes: (lanes.len() + 1) as u64,
                    },
                );
            }
            lanes.push(Lane { session, job, queue_s, params });
        }
        Err(e) => {
            ctx.telemetry.errors.fetch_add(1, Ordering::Relaxed);
            let _ = job.reply.send(err_json(&format!("{e:#}")));
        }
    }
}

/// Wire validation + policy construction + session admission, on this
/// worker's device replica.
fn try_start(ctx: &WorkerCtx, job: &Job) -> Result<(Session<'static>, GenerateParams)> {
    let mut p = parse_generate(&job.payload)?;
    // Thread the request span into the session so its branch workers and
    // per-step policy events attribute correctly.
    p.req.trace_id = job.trace_id;
    let engine = ctx.registry.get_on(&p.model, &p.bucket, ctx.device)?;
    let info = &engine.model().info;
    if let Some(s) = p.req.steps {
        // One bound for both samplers: DDIM's constructor asserts it, and
        // an absurd rflow step count would only allocate gigabyte-scale
        // sigma tables before doing useless work.
        let t_train = engine.schedule().train_timesteps;
        if s > t_train {
            return Err(anyhow!(
                "steps must be <= {t_train} (the training schedule length), got {s}"
            ));
        }
    }
    let steps = p.req.steps.unwrap_or(info.steps);
    let policy = build_policy(&p.policy_spec, info, steps)?;
    let session = engine.admit(&p.req, policy)?;
    Ok((session, p))
}

/// Finish a completed lane and answer its client. `batch_size` in the
/// response reports the largest cohort the request ever shared a device
/// pass with (on any device, for a migrated session).
fn retire(ctx: &WorkerCtx, lane: Lane) {
    let dt = &ctx.telemetry.per_device[ctx.device];
    ctx.telemetry.lanes_active.fetch_sub(1, Ordering::Relaxed);
    dt.lanes_active.fetch_sub(1, Ordering::Relaxed);
    let peak = lane.session.peak_lanes();
    let steps = lane.session.cursor() as u64;
    // Attribute the final-latent download inside `finish` to the span.
    let _span = trace::scope(lane.job.trace_id);
    trace::emit(
        lane.job.trace_id,
        trace::Payload::Retire { device: ctx.device as u64, steps },
    );
    match lane.session.finish() {
        Ok(r) => {
            let mut resp = generate_response(
                &lane.params.model,
                &lane.params.bucket,
                &r,
                lane.queue_s,
                peak,
                &lane.params.policy_spec,
                lane.job.auto.as_ref(),
            );
            // `"trace": true` requests get the compact per-step reuse
            // timeline straight off the RunResult (module docs
            // §Observability) — independent of the tracer being enabled.
            if lane.job.want_trace {
                if let Json::Obj(map) = &mut resp {
                    map.insert("reuse_timeline".to_string(), reuse_timeline(&r));
                }
            }
            ctx.telemetry.retires.fetch_add(1, Ordering::Relaxed);
            dt.retires.fetch_add(1, Ordering::Relaxed);
            ctx.telemetry
                .forecasts
                .fetch_add(r.stats.forecast_units, Ordering::Relaxed);
            ctx.telemetry
                .forecast_fallbacks
                .fetch_add(r.stats.forecast_fallback_units, Ordering::Relaxed);
            if peak >= 2 {
                ctx.telemetry.batched_requests.fetch_add(1, Ordering::Relaxed);
            }
            ctx.telemetry.latencies_s.lock().push(r.stats.wall_s);
            ctx.telemetry.queue_s.lock().push(lane.queue_s);
            let _ = lane.job.reply.send(resp);
        }
        Err(e) => {
            ctx.telemetry.errors.fetch_add(1, Ordering::Relaxed);
            let _ = lane.job.reply.send(err_json(&format!("{e:#}")));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dev(lanes: usize, cohort: Option<(&str, &str)>) -> DevState {
        DevState {
            lanes,
            cohort: cohort.map(|(m, b)| (m.to_string(), b.to_string())),
            wants_work: false,
            incoming: Vec::new(),
        }
    }

    fn key(m: &str, b: &str) -> (String, String) {
        (m.to_string(), b.to_string())
    }

    #[test]
    fn route_prefers_matching_cohort_with_room() {
        let devs = [
            dev(1, None),
            dev(3, Some(("m", "b"))),
            dev(2, Some(("m", "b"))),
        ];
        // both device 1 and 2 are affine; fewest lanes (device 2) wins
        // even though device 0 is globally least-loaded.
        assert_eq!(route(&devs, &[0, 0, 0], Some(&key("m", "b")), 4), 2);
        // a different key has no affine cohort: least-loaded device 0.
        assert_eq!(route(&devs, &[0, 0, 0], Some(&key("m", "other")), 4), 0);
    }

    #[test]
    fn route_full_cohort_falls_through_to_least_loaded() {
        let devs = [dev(4, Some(("m", "b"))), dev(2, None)];
        // the affine cohort has no spare lane (max_batch = 4)
        assert_eq!(route(&devs, &[0, 0], Some(&key("m", "b")), 4), 1);
    }

    #[test]
    fn route_least_loaded_ties_by_queue_then_ordinal() {
        let devs = [dev(1, None), dev(1, None), dev(1, None)];
        // equal lanes: shortest queue wins
        assert_eq!(route(&devs, &[2, 0, 1], None, 4), 1);
        // full tie: lowest ordinal
        assert_eq!(route(&devs, &[1, 1, 1], None, 4), 0);
    }

    #[test]
    fn route_affinity_ties_break_to_lowest_ordinal() {
        let devs = [
            dev(5, None),
            dev(2, Some(("m", "b"))),
            dev(2, Some(("m", "b"))),
        ];
        assert_eq!(route(&devs, &[0, 0, 0], Some(&key("m", "b")), 4), 1);
    }
}
