//! Continuous (step-level) batching scheduler — the server's worker loop.
//!
//! Each worker drives one **cohort** of generation sessions per iteration
//! instead of dispatching whole requests: it blocks for the first
//! `generate` job (no window is waited out on an empty queue), starts a
//! session for it, and then advances the cohort one denoising step at a
//! time via [`session::step_many_refs`]. At every step boundary it
//! non-blockingly admits queued compatible jobs — same (model, bucket),
//! the only fields that pin the device pass; `steps`, `cfg_scale` and
//! `policy` are per-session state — up to `max_batch` lanes, and retires
//! finished lanes **immediately**: a short request that joined a long
//! batch returns as soon as its own schedule completes, and a request
//! that arrives `k` steps into an in-flight batch joins at the next
//! boundary instead of waiting a full request out.
//!
//! Boundary admission takes only the FIFO **prefix** of compatible jobs:
//! the moment a different-(model, bucket) job reaches the queue head, the
//! cohort stops admitting and drains within its lanes' remaining
//! schedules — sustained compatible traffic cannot starve a queued
//! request for another engine behind a forever-refilled cohort.
//!
//! An optional admission window (`ServerConfig::admit_window_ms`,
//! default 0) lets a *fresh* cohort linger briefly for batchmates before
//! its first step — the continuous analogue of the retired gather window,
//! kept for deployments that prefer fuller first stacks over first-step
//! latency. It never applies to an in-flight cohort, ends early when the
//! cohort fills, and at the default of 0 a lone request starts stepping
//! immediately — the old always-paid gather wait is opt-in now.
//!
//! Per-job validation failures are answered individually at admission and
//! never poison the cohort; a step error fails every in-flight lane (the
//! cohort's shared pass is poisoned — see the `session` module docs) but
//! leaves the worker serving.

use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::engine::{session, Session};
use crate::policy::build_policy;

use super::{
    cohort_key, err_json, generate_response, parse_generate, EngineRegistry, GenerateParams, Job,
    Queue, Telemetry,
};

/// Scheduler knobs (from `ServerConfig`).
pub(super) struct SchedConfig {
    pub max_batch: usize,
    pub admit_window: Duration,
}

/// Everything one scheduler worker thread needs.
pub(super) struct WorkerCtx {
    pub queue: Queue,
    pub stop: Arc<AtomicBool>,
    pub registry: Arc<EngineRegistry>,
    pub telemetry: Arc<Telemetry>,
    pub cfg: SchedConfig,
}

/// One in-flight lane: a started session plus everything needed to answer
/// its client when it retires.
struct Lane {
    session: Session<'static>,
    job: Job,
    /// Queue wait measured at admission (time to *join* a pass, not to
    /// finish one).
    queue_s: f64,
    params: GenerateParams,
}

/// The worker loop: serve cohorts until shutdown.
pub(super) fn run_worker(ctx: &WorkerCtx) {
    loop {
        // Block for the first job — a plain condvar wait, so an empty
        // queue costs nothing and shutdown wakes us immediately.
        let first = {
            let (lock, cv) = &*ctx.queue;
            let mut q = lock.lock().unwrap();
            loop {
                if let Some(j) = q.pop_front() {
                    break j;
                }
                if ctx.stop.load(Ordering::SeqCst) {
                    return;
                }
                q = cv.wait(q).unwrap();
            }
        };
        let key = cohort_key(&first.payload);

        // Optional admission window before the fresh cohort's first step.
        // Jobs are only *gathered* here — nobody's session starts until
        // the window closes, so the wait lands in every member's queue_s
        // (as the retired gather window did), never in wall_s.
        let mut jobs = vec![first];
        if let Some(key) = key.as_ref() {
            if ctx.cfg.max_batch > 1 && !ctx.cfg.admit_window.is_zero() {
                let deadline = Instant::now() + ctx.cfg.admit_window;
                let (lock, cv) = &*ctx.queue;
                let mut q = lock.lock().unwrap();
                loop {
                    let mut i = 0;
                    while i < q.len() && jobs.len() < ctx.cfg.max_batch {
                        if cohort_key(&q[i].payload).as_ref() == Some(key) {
                            jobs.push(q.remove(i).expect("index in bounds"));
                        } else {
                            i += 1;
                        }
                    }
                    if jobs.len() >= ctx.cfg.max_batch || ctx.stop.load(Ordering::SeqCst) {
                        break;
                    }
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    let (guard, _timed_out) = cv.wait_timeout(q, deadline - now).unwrap();
                    q = guard;
                }
            }
        }
        let mut lanes: Vec<Lane> = Vec::new();
        for job in jobs {
            admit(ctx, job, &mut lanes, false);
        }
        if !lanes.is_empty() {
            ctx.telemetry.batches.fetch_add(1, Ordering::Relaxed);
        }

        // Drive the cohort: join at boundaries, retire eagerly.
        let mut stepped = false;
        while !lanes.is_empty() {
            if let Some(key) = key.as_ref() {
                if !ctx.stop.load(Ordering::SeqCst) && lanes.len() < ctx.cfg.max_batch {
                    for job in pull_compatible_prefix(ctx, key, ctx.cfg.max_batch - lanes.len()) {
                        admit(ctx, job, &mut lanes, stepped);
                    }
                }
            }
            let report = {
                let mut refs: Vec<&mut Session<'static>> =
                    lanes.iter_mut().map(|l| &mut l.session).collect();
                session::step_many_refs(&mut refs)
            };
            match report {
                Ok(rep) => {
                    ctx.telemetry
                        .occupancy
                        .lock()
                        .unwrap()
                        .push(rep.occupancy as f64);
                    ctx.telemetry
                        .occupancy_peak
                        .fetch_max(rep.occupancy as u64, Ordering::Relaxed);
                    // A fresh cohort's very first stack build is not a
                    // membership change; only count regroups after a
                    // previous step existed.
                    if stepped && rep.restacked && rep.occupancy > 1 {
                        ctx.telemetry.regroups.fetch_add(1, Ordering::Relaxed);
                    }
                    stepped = true;
                }
                Err(e) => {
                    // A step error poisons the cohort's shared pass:
                    // answer every in-flight lane, drop the sessions
                    // (their worker threads are reaped on drop), keep
                    // serving.
                    let msg = format!("{e:#}");
                    let n = lanes.len() as u64;
                    ctx.telemetry.errors.fetch_add(n, Ordering::Relaxed);
                    ctx.telemetry.lanes_active.fetch_sub(n, Ordering::Relaxed);
                    for lane in lanes.drain(..) {
                        let _ = lane.job.reply.send(err_json(&msg));
                    }
                    break;
                }
            }
            let mut i = 0;
            while i < lanes.len() {
                if lanes[i].session.is_done() {
                    let lane = lanes.remove(i);
                    retire(ctx, lane);
                } else {
                    i += 1;
                }
            }
        }
    }
}

/// Pull up to `n` jobs with the given cohort key from the **front** of
/// the queue, stopping at the first incompatible job. The fence is the
/// fairness guarantee: once a different-key job reaches the queue head,
/// this cohort admits nothing more and drains within its lanes' remaining
/// schedules, so sustained compatible traffic can never starve a queued
/// request for another (model, bucket) behind a forever-refilled cohort.
/// Non-blocking.
fn pull_compatible_prefix(ctx: &WorkerCtx, key: &(String, String), n: usize) -> Vec<Job> {
    if n == 0 {
        return Vec::new();
    }
    let (lock, _cv) = &*ctx.queue;
    let mut q = lock.lock().unwrap();
    let mut out = Vec::new();
    while out.len() < n {
        match q.front() {
            Some(job) if cohort_key(&job.payload).as_ref() == Some(key) => {
                out.push(q.pop_front().expect("front checked"));
            }
            _ => break,
        }
    }
    out
}

/// Validate one job and start its session; answer the client directly on
/// failure (a bad request never poisons its batchmates).
///
/// Admission runs synchronously on the worker, so a mid-flight join
/// stalls the in-flight lanes for one request startup (text/K-V
/// precompute + uploads). Overlapping admission with the in-flight step
/// is a known follow-up optimization; at today's request-startup cost it
/// is well under one denoising step.
fn admit(ctx: &WorkerCtx, job: Job, lanes: &mut Vec<Lane>, midflight: bool) {
    ctx.telemetry.requests.fetch_add(1, Ordering::Relaxed);
    let queue_s = job.enqueued.elapsed().as_secs_f64();
    match try_start(ctx, &job) {
        Ok((session, params)) => {
            ctx.telemetry.lanes_active.fetch_add(1, Ordering::Relaxed);
            if midflight {
                ctx.telemetry.joins.fetch_add(1, Ordering::Relaxed);
            }
            lanes.push(Lane { session, job, queue_s, params });
        }
        Err(e) => {
            ctx.telemetry.errors.fetch_add(1, Ordering::Relaxed);
            let _ = job.reply.send(err_json(&format!("{e:#}")));
        }
    }
}

/// Wire validation + policy construction + session admission.
fn try_start(ctx: &WorkerCtx, job: &Job) -> Result<(Session<'static>, GenerateParams)> {
    let p = parse_generate(&job.payload)?;
    let engine = ctx.registry.get(&p.model, &p.bucket)?;
    let info = &engine.model().info;
    if let Some(s) = p.req.steps {
        // One bound for both samplers: DDIM's constructor asserts it, and
        // an absurd rflow step count would only allocate gigabyte-scale
        // sigma tables before doing useless work.
        let t_train = engine.schedule().train_timesteps;
        if s > t_train {
            return Err(anyhow!(
                "steps must be <= {t_train} (the training schedule length), got {s}"
            ));
        }
    }
    let steps = p.req.steps.unwrap_or(info.steps);
    let policy = build_policy(&p.policy_spec, info, steps)?;
    let session = engine.admit(&p.req, policy)?;
    Ok((session, p))
}

/// Finish a completed lane and answer its client. `batch_size` in the
/// response reports the largest cohort the request ever shared a device
/// pass with.
fn retire(ctx: &WorkerCtx, lane: Lane) {
    ctx.telemetry.lanes_active.fetch_sub(1, Ordering::Relaxed);
    let peak = lane.session.peak_lanes();
    match lane.session.finish() {
        Ok(r) => {
            let resp = generate_response(
                &lane.params.model,
                &lane.params.bucket,
                &r,
                lane.queue_s,
                peak,
                &lane.params.policy_spec,
                lane.job.auto.as_ref(),
            );
            ctx.telemetry.retires.fetch_add(1, Ordering::Relaxed);
            if peak >= 2 {
                ctx.telemetry.batched_requests.fetch_add(1, Ordering::Relaxed);
            }
            ctx.telemetry.latencies_s.lock().unwrap().push(r.stats.wall_s);
            ctx.telemetry.queue_s.lock().unwrap().push(lane.queue_s);
            let _ = lane.job.reply.send(resp);
        }
        Err(e) => {
            ctx.telemetry.errors.fetch_add(1, Ordering::Relaxed);
            let _ = lane.job.reply.send(err_json(&format!("{e:#}")));
        }
    }
}
