//! Versioned, JSON-persisted profile store — the artifact the offline
//! profiler writes and the server loads at startup (`--profiles`).
//!
//! One [`TunedProfile`] per (model, bucket, sampler, steps) generation
//! configuration: the chosen policy spec, the quality budget it was tuned
//! under, and the full Pareto frontier the selection was made from (kept so
//! operators can re-pick under a different budget without re-profiling).
//!
//! # Schema compatibility
//!
//! The on-disk document carries a `schema_version`. Loading is
//! forward-compatible within a schema version — unknown fields anywhere in
//! the document are ignored, so newer writers can add fields without
//! breaking older readers — while a different `schema_version` (or a
//! missing one) is rejected with a clean error instead of being
//! misinterpreted. The store-level `version` is a monotonic generation
//! counter bumped on every mutation; servers echo it so operators can tell
//! which profile generation served a request.

use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

use crate::util::json::{self, Json};

/// On-disk schema version this build reads and writes.
pub const SCHEMA_VERSION: u64 = 1;

/// One generation configuration: the granularity profiles are keyed at.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct ProfileKey {
    pub model: String,
    pub bucket: String,
    /// Sampler family name (`rflow` / `ddim`, [`crate::config::SamplerKind`]).
    pub sampler: String,
    pub steps: usize,
}

impl std::fmt::Display for ProfileKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}/{}/{}@{}",
            self.model, self.bucket, self.sampler, self.steps
        )
    }
}

/// One measured policy configuration: mean metrics over the prompt panel.
/// Quality metrics compare against the NoReuse baseline of the same run.
#[derive(Debug, Clone, PartialEq)]
pub struct ProfilePoint {
    /// Concrete policy spec, parseable by [`crate::policy::build_policy`].
    pub spec: String,
    pub wall_s: f64,
    pub reuse_fraction: f64,
    pub psnr: f64,
    pub ssim: f64,
    pub lpips: f64,
}

/// The tuned outcome for one [`ProfileKey`].
#[derive(Debug, Clone, PartialEq)]
pub struct TunedProfile {
    pub key: ProfileKey,
    /// The chosen spec: fastest frontier point within the quality budget.
    pub spec: String,
    /// Quality budget (minimum mean PSNR vs the NoReuse baseline, dB) the
    /// selection was made under.
    pub min_psnr: f64,
    /// Bumped every time this key is re-profiled into the same store.
    pub profile_version: u64,
    /// The Pareto frontier of the sweep (speed × quality), sorted fastest
    /// first.
    pub frontier: Vec<ProfilePoint>,
}

/// How a [`ProfileStore::lookup`] matched.
#[derive(Debug, Clone, Copy)]
pub enum ProfileMatch<'a> {
    /// The exact (model, bucket, sampler, steps) key was profiled.
    Exact(&'a TunedProfile),
    /// No exact key; the nearest profile of the same (model, sampler) —
    /// closest step count, deterministic tie-breaks — was substituted.
    Nearest(&'a TunedProfile),
}

impl<'a> ProfileMatch<'a> {
    pub fn profile(&self) -> &'a TunedProfile {
        match *self {
            ProfileMatch::Exact(p) | ProfileMatch::Nearest(p) => p,
        }
    }

    pub fn kind(&self) -> &'static str {
        match self {
            ProfileMatch::Exact(_) => "exact",
            ProfileMatch::Nearest(_) => "nearest",
        }
    }
}

/// The profile collection: load/save/merge plus lookup-with-fallback.
#[derive(Debug, Clone, Default)]
pub struct ProfileStore {
    version: u64,
    profiles: BTreeMap<ProfileKey, TunedProfile>,
}

impl ProfileStore {
    pub fn new() -> Self {
        Self { version: 0, profiles: BTreeMap::new() }
    }

    /// Store generation counter (bumped on every mutation; echoed by the
    /// server's `stats` op and `generate` responses).
    pub fn version(&self) -> u64 {
        self.version
    }

    pub fn len(&self) -> usize {
        self.profiles.len()
    }

    pub fn is_empty(&self) -> bool {
        self.profiles.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = &TunedProfile> {
        self.profiles.values()
    }

    /// Insert (or re-profile) one key. An existing entry's
    /// `profile_version` is continued (`old + 1`) so repeat profiling is
    /// visible in responses; fresh entries keep the caller's version
    /// (minimum 1). Bumps the store generation.
    pub fn insert(&mut self, mut profile: TunedProfile) {
        profile.profile_version = match self.profiles.get(&profile.key) {
            Some(old) => old.profile_version + 1,
            None => profile.profile_version.max(1),
        };
        self.profiles.insert(profile.key.clone(), profile);
        self.version += 1;
    }

    /// Merge another store into this one: per key, the higher
    /// `profile_version` wins (ties keep the incoming entry — the caller
    /// merges the fresher store *in*). The generation advances past both
    /// inputs so a merged store never reports an older version than either
    /// source.
    pub fn merge(&mut self, other: &ProfileStore) {
        for (key, incoming) in &other.profiles {
            let keep_existing = self
                .profiles
                .get(key)
                .is_some_and(|have| have.profile_version > incoming.profile_version);
            if !keep_existing {
                self.profiles.insert(key.clone(), incoming.clone());
            }
        }
        self.version = self.version.max(other.version) + 1;
    }

    /// Lookup with fallback: exact key first, then the nearest profile of
    /// the same (model, sampler) — minimum |Δsteps|, ties broken toward
    /// fewer steps then lexicographic bucket, so resolution is
    /// deterministic. `None` means the caller should serve its built-in
    /// default (and count the fallback).
    pub fn lookup(
        &self,
        model: &str,
        bucket: &str,
        sampler: &str,
        steps: usize,
    ) -> Option<ProfileMatch<'_>> {
        let exact = ProfileKey {
            model: model.to_string(),
            bucket: bucket.to_string(),
            sampler: sampler.to_string(),
            steps,
        };
        if let Some(p) = self.profiles.get(&exact) {
            return Some(ProfileMatch::Exact(p));
        }
        self.profiles
            .values()
            .filter(|p| p.key.model == model && p.key.sampler == sampler)
            .min_by_key(|p| {
                (
                    (p.key.steps as i64 - steps as i64).unsigned_abs(),
                    p.key.steps,
                    p.key.bucket.clone(),
                )
            })
            .map(ProfileMatch::Nearest)
    }

    // --- JSON (de)serialization -------------------------------------------

    pub fn to_json(&self) -> Json {
        let profiles = self.profiles.values().map(profile_to_json).collect();
        Json::obj(vec![
            ("schema_version", Json::num(SCHEMA_VERSION as f64)),
            ("version", Json::num(self.version as f64)),
            ("profiles", Json::Arr(profiles)),
        ])
    }

    pub fn to_json_string(&self) -> String {
        self.to_json().to_string()
    }

    /// Parse a store document. Unknown fields are ignored (forward
    /// compatibility); a missing or different `schema_version` is a clean
    /// error.
    pub fn from_json_str(text: &str) -> Result<Self> {
        let j = json::parse(text).map_err(|e| anyhow!("profile store: {e}"))?;
        let schema = j
            .get("schema_version")
            .ok_or_else(|| anyhow!("profile store: missing schema_version"))?
            .as_u64()
            .ok_or_else(|| anyhow!("profile store: schema_version is not an integer"))?;
        if schema != SCHEMA_VERSION {
            return Err(anyhow!(
                "profile store schema_version {schema} is not supported \
                 (this build reads version {SCHEMA_VERSION})"
            ));
        }
        // Absent `version` is forward-compatible (generation 0); a present
        // but non-integer one is corruption and must not silently reset
        // the monotonic generation lineage.
        let version = match j.get("version") {
            None => 0,
            Some(v) => v.as_u64().ok_or_else(|| {
                anyhow!("profile store: version is not a non-negative integer")
            })?,
        };
        let mut profiles = BTreeMap::new();
        if let Some(pj) = j.get("profiles") {
            // Present but wrong-typed is corruption (a truncated edit),
            // not an empty store.
            let arr = pj
                .as_arr()
                .ok_or_else(|| anyhow!("profile store: profiles is not an array"))?;
            for (i, pj) in arr.iter().enumerate() {
                let p = profile_from_json(pj)
                    .with_context(|| format!("profile store: profiles[{i}]"))?;
                profiles.insert(p.key.clone(), p);
            }
        }
        Ok(Self { version, profiles })
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read profile store {}", path.display()))?;
        Self::from_json_str(&text)
            .with_context(|| format!("parse profile store {}", path.display()))
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("create {}", dir.display()))?;
            }
        }
        std::fs::write(path, self.to_json_string())
            .with_context(|| format!("write profile store {}", path.display()))
    }
}

fn point_to_json(p: &ProfilePoint) -> Json {
    Json::obj(vec![
        ("spec", Json::str(&p.spec)),
        ("wall_s", Json::num(p.wall_s)),
        ("reuse_fraction", Json::num(p.reuse_fraction)),
        ("psnr", Json::num(p.psnr)),
        ("ssim", Json::num(p.ssim)),
        ("lpips", Json::num(p.lpips)),
    ])
}

fn profile_to_json(p: &TunedProfile) -> Json {
    Json::obj(vec![
        ("model", Json::str(&p.key.model)),
        ("bucket", Json::str(&p.key.bucket)),
        ("sampler", Json::str(&p.key.sampler)),
        ("steps", Json::num(p.key.steps as f64)),
        ("spec", Json::str(&p.spec)),
        ("min_psnr", Json::num(p.min_psnr)),
        ("profile_version", Json::num(p.profile_version as f64)),
        ("frontier", Json::Arr(p.frontier.iter().map(point_to_json).collect())),
    ])
}

fn req_str(j: &Json, key: &str) -> Result<String> {
    j.get(key)
        .and_then(|v| v.as_str())
        .map(str::to_string)
        .ok_or_else(|| anyhow!("missing or non-string field '{key}'"))
}

fn req_f64(j: &Json, key: &str) -> Result<f64> {
    j.get(key)
        .and_then(|v| v.as_f64())
        .ok_or_else(|| anyhow!("missing or non-numeric field '{key}'"))
}

fn point_from_json(j: &Json) -> Result<ProfilePoint> {
    Ok(ProfilePoint {
        spec: req_str(j, "spec")?,
        wall_s: req_f64(j, "wall_s")?,
        reuse_fraction: req_f64(j, "reuse_fraction")?,
        psnr: req_f64(j, "psnr")?,
        ssim: req_f64(j, "ssim")?,
        lpips: req_f64(j, "lpips")?,
    })
}

fn profile_from_json(j: &Json) -> Result<TunedProfile> {
    let steps = j
        .get("steps")
        .and_then(|v| v.as_u64())
        .ok_or_else(|| anyhow!("missing or non-integer field 'steps'"))? as usize;
    let profile_version = j
        .get("profile_version")
        .and_then(|v| v.as_u64())
        .unwrap_or(1)
        .max(1);
    let mut frontier = Vec::new();
    if let Some(arr) = j.get("frontier").and_then(|f| f.as_arr()) {
        for (i, fj) in arr.iter().enumerate() {
            frontier.push(point_from_json(fj).with_context(|| format!("frontier[{i}]"))?);
        }
    }
    Ok(TunedProfile {
        key: ProfileKey {
            model: req_str(j, "model")?,
            bucket: req_str(j, "bucket")?,
            sampler: req_str(j, "sampler")?,
            steps,
        },
        spec: req_str(j, "spec")?,
        min_psnr: req_f64(j, "min_psnr")?,
        profile_version,
        frontier,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(model: &str, bucket: &str, steps: usize) -> ProfileKey {
        ProfileKey {
            model: model.into(),
            bucket: bucket.into(),
            sampler: "rflow".into(),
            steps,
        }
    }

    fn profile(model: &str, bucket: &str, steps: usize, spec: &str) -> TunedProfile {
        TunedProfile {
            key: key(model, bucket, steps),
            spec: spec.into(),
            min_psnr: 30.0,
            profile_version: 1,
            frontier: vec![ProfilePoint {
                spec: spec.into(),
                wall_s: 1.25,
                reuse_fraction: 0.5,
                psnr: 38.5,
                ssim: 0.99,
                lpips: 0.01,
            }],
        }
    }

    #[test]
    fn json_roundtrip_preserves_lookups() {
        let mut store = ProfileStore::new();
        store.insert(profile("m", "b1", 30, "foresight:n=1,r=2,gamma=1,warmup=0.15"));
        store.insert(profile("m", "b2", 12, "static:n=1,r=2"));
        let back = ProfileStore::from_json_str(&store.to_json_string()).unwrap();
        assert_eq!(back.version(), store.version());
        assert_eq!(back.len(), 2);
        for (model, bucket, steps) in [("m", "b1", 30), ("m", "b2", 12)] {
            let a = store.lookup(model, bucket, "rflow", steps).unwrap();
            let b = back.lookup(model, bucket, "rflow", steps).unwrap();
            assert_eq!(a.profile(), b.profile(), "{model}/{bucket}@{steps}");
            assert_eq!(a.kind(), "exact");
        }
    }

    #[test]
    fn forecast_specs_roundtrip_verbatim() {
        // Forecast wrapper specs embed `:` and `,` and an `=` inside the
        // inner spec — the JSON writer/reader must carry them verbatim so
        // `policy=auto` can serve a tuned forecast configuration.
        let spec = "forecast:k=2,inner=foresight:n=1,r=2,gamma=0.5,warmup=0.15";
        let mut store = ProfileStore::new();
        store.insert(profile("m", "b", 30, spec));
        let back = ProfileStore::from_json_str(&store.to_json_string()).unwrap();
        let got = back.lookup("m", "b", "rflow", 30).unwrap();
        assert_eq!(got.kind(), "exact");
        assert_eq!(got.profile().spec, spec);
        assert_eq!(got.profile().frontier[0].spec, spec);
    }

    #[test]
    fn rejects_incompatible_schema_versions_cleanly() {
        let err = ProfileStore::from_json_str(r#"{"schema_version": 99, "profiles": []}"#)
            .unwrap_err()
            .to_string();
        assert!(err.contains("schema_version 99"), "{err}");
        assert!(err.contains(&SCHEMA_VERSION.to_string()), "{err}");
        let err = ProfileStore::from_json_str(r#"{"profiles": []}"#)
            .unwrap_err()
            .to_string();
        assert!(err.contains("missing schema_version"), "{err}");
        // fractional versions are not integers
        assert!(ProfileStore::from_json_str(r#"{"schema_version": 1.5}"#).is_err());
        // a present but corrupt store generation must error, not reset to 0
        let err = ProfileStore::from_json_str(r#"{"schema_version": 1, "version": 2.5}"#)
            .unwrap_err()
            .to_string();
        assert!(err.contains("version"), "{err}");
        // absent version stays forward-compatible
        let ok = ProfileStore::from_json_str(r#"{"schema_version": 1, "profiles": []}"#).unwrap();
        assert_eq!(ok.version(), 0);
        // present but wrong-typed profiles is corruption, not an empty store
        let err = ProfileStore::from_json_str(r#"{"schema_version": 1, "profiles": {}}"#)
            .unwrap_err()
            .to_string();
        assert!(err.contains("profiles"), "{err}");
    }

    #[test]
    fn unknown_fields_are_ignored() {
        let mut store = ProfileStore::new();
        store.insert(profile("m", "b", 30, "static:n=1,r=2"));
        let mut text = store.to_json_string();
        // simulate a newer writer: extra top-level and per-profile fields
        text = text.replacen('{', r#"{"future_top_level": {"x": 1},"#, 1);
        text = text.replacen(r#""bucket""#, r#""future_field": [1, 2], "bucket""#, 1);
        let back = ProfileStore::from_json_str(&text).unwrap();
        assert_eq!(back.len(), 1);
        assert_eq!(
            back.lookup("m", "b", "rflow", 30).unwrap().profile().spec,
            "static:n=1,r=2"
        );
    }

    #[test]
    fn lookup_falls_back_to_nearest_same_model_sampler() {
        let mut store = ProfileStore::new();
        store.insert(profile("m", "b1", 10, "static:n=1,r=2"));
        store.insert(profile("m", "b1", 30, "foresight:n=1,r=2,gamma=1,warmup=0.15"));
        // exact
        assert_eq!(store.lookup("m", "b1", "rflow", 30).unwrap().kind(), "exact");
        // nearest by |Δsteps|: 26 → 30
        let m = store.lookup("m", "b2", "rflow", 26).unwrap();
        assert_eq!(m.kind(), "nearest");
        assert_eq!(m.profile().key.steps, 30);
        // equidistant 20 → tie toward fewer steps (10)
        assert_eq!(store.lookup("m", "b2", "rflow", 20).unwrap().profile().key.steps, 10);
        // other model or sampler: no match at all
        assert!(store.lookup("other", "b1", "rflow", 30).is_none());
        assert!(store.lookup("m", "b1", "ddim", 30).is_none());
    }

    #[test]
    fn insert_continues_profile_versions_and_bumps_generation() {
        let mut store = ProfileStore::new();
        store.insert(profile("m", "b", 30, "static:n=1,r=2"));
        let v1 = store.version();
        store.insert(profile("m", "b", 30, "foresight:n=1,r=2,gamma=1,warmup=0.15"));
        let p = store.lookup("m", "b", "rflow", 30).unwrap();
        assert_eq!(p.profile().profile_version, 2, "re-profiling continues the version");
        assert_eq!(p.profile().spec, "foresight:n=1,r=2,gamma=1,warmup=0.15");
        assert!(store.version() > v1);
    }

    #[test]
    fn merge_keeps_higher_profile_versions() {
        let mut a = ProfileStore::new();
        a.insert(profile("m", "b", 30, "static:n=1,r=2"));
        a.insert(profile("m", "b", 30, "static:n=2,r=3")); // version 2

        let mut b = ProfileStore::new();
        b.insert(profile("m", "b", 30, "foresight:n=1,r=2,gamma=1,warmup=0.15")); // version 1
        b.insert(profile("m", "other", 12, "static:n=1,r=2"));

        let va = a.version();
        a.merge(&b);
        // existing v2 beats incoming v1; the new key arrives
        assert_eq!(a.lookup("m", "b", "rflow", 30).unwrap().profile().spec, "static:n=2,r=3");
        assert_eq!(a.lookup("m", "other", "rflow", 12).unwrap().kind(), "exact");
        assert!(a.version() > va.max(b.version()));
    }

    #[test]
    fn empty_store_roundtrip() {
        let store = ProfileStore::new();
        let back = ProfileStore::from_json_str(&store.to_json_string()).unwrap();
        assert!(back.is_empty());
        assert!(back.lookup("m", "b", "rflow", 30).is_none());
    }
}
