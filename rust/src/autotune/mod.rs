//! Profile-guided autotuning: sweep reuse-policy configurations offline,
//! pick the fastest one inside a quality budget, persist the result, and
//! serve it when a request asks for `policy=auto`.
//!
//! The paper's pitch is that Foresight "adapts to generation parameters
//! such as resolution and denoising schedules" — but a static spec string
//! with fixed γ/warmup serves every bucket and schedule with the same
//! knobs. AdaCache (Kahatapitiya et al., 2024) and "Model Reveals What to
//! Cache" (Ma et al., 2025) both make the case for closing that gap by
//! *profiling*: the trade-off between reuse aggressiveness and quality is
//! stable per generation configuration, so it can be measured once and
//! reused for every request with that configuration.
//!
//! The lifecycle has three stages:
//!
//! 1. **Profile** ([`profile_engine`], CLI `foresight autotune`): run a
//!    small [`crate::workload`] prompt panel through the [`Engine`] under
//!    every candidate configuration of a [`GridSpec`] — Foresight
//!    (γ, warmup) × (N, R) points plus the static baseline's knobs —
//!    scoring each with mean wall-clock and
//!    [`crate::engine::RunStats::reuse_fraction`] on the speed axis and
//!    PSNR/SSIM/LPIPS vs the NoReuse baseline (the
//!    [`crate::metrics::QualityReport`] columns) on the quality axis.
//! 2. **Select + persist**: [`pareto_frontier`] keeps the undominated
//!    (speed × quality) points; [`select`] picks the fastest one whose
//!    PSNR meets the budget (deterministic tie-breaks), and the result —
//!    chosen spec, budget, full frontier — lands in a schema-versioned
//!    [`ProfileStore`] keyed by (model, bucket, sampler, steps).
//! 3. **Serve**: the server loads the store at startup (`--profiles`) and
//!    resolves `policy=auto` requests through
//!    [`ProfileStore::lookup`] — exact key, else nearest same
//!    (model, sampler) profile, else the built-in default with a counted
//!    fallback — *before* batch-key construction, so identically-resolved
//!    requests still micro-batch together.
//!
//! Every spec the grid can emit round-trips through
//! [`crate::policy::build_policy`] to an identical policy (property-tested
//! in `tests/integration_policies.rs`); `benches/fig19_autotune.rs` proves
//! the tuned profile Pareto-dominates or matches the fixed default.

pub mod store;

pub use store::{
    ProfileKey, ProfileMatch, ProfilePoint, ProfileStore, TunedProfile, SCHEMA_VERSION,
};

use anyhow::{Context, Result};
use std::cmp::Ordering;

use crate::engine::{Engine, Request};
use crate::metrics::{self, Decoder, FeatureNet, Frames};
use crate::policy::build_policy;
use crate::util::benchkit::MdTable;
use crate::util::stats;
use crate::workload;

/// One policy configuration the autotuner can try. `spec()` renders the
/// canonical spec string; parsing it back via
/// [`crate::policy::build_policy`] yields an identical policy.
#[derive(Debug, Clone, PartialEq)]
pub enum Knobs {
    NoReuse,
    Static { n: usize, r: usize },
    Foresight { n: usize, r: usize, gamma: f64, warmup: f64 },
    /// A [`crate::policy::Forecast`] wrapper layered over another
    /// candidate: same reuse schedule as `inner`, but each reuse step is
    /// served by an order-`k` linear-multistep forecast instead of a
    /// verbatim replay.
    Forecast { k: usize, inner: Box<Knobs> },
}

/// The serving default (`policy=foresight` with no args): N=1, R=2, γ=0.5,
/// warmup 15%. Always part of the sweep so the tuned pick is provably no
/// worse than what a config-less request gets today.
pub const DEFAULT_KNOBS: Knobs = Knobs::Foresight { n: 1, r: 2, gamma: 0.5, warmup: 0.15 };

impl Knobs {
    /// Canonical spec string (`build_policy` input).
    pub fn spec(&self) -> String {
        match self {
            Knobs::NoReuse => "none".to_string(),
            Knobs::Static { n, r } => format!("static:n={n},r={r}"),
            Knobs::Foresight { n, r, gamma, warmup } => {
                format!("foresight:n={n},r={r},gamma={gamma},warmup={warmup}")
            }
            Knobs::Forecast { k, inner } => format!("forecast:k={k},inner={}", inner.spec()),
        }
    }

    /// The predictor order a spec runs at: `k` for forecast wrappers,
    /// 1 (verbatim replay) for everything else.
    pub fn order(&self) -> usize {
        match self {
            Knobs::Forecast { k, .. } => *k,
            _ => 1,
        }
    }
}

/// Predictor order of a rendered spec string (the sweep-table column):
/// `forecast:k=<k>,…` → k, anything else → 1. Falls back to 1 on a
/// malformed head rather than erroring — the table is reporting, not
/// validation ([`crate::policy::build_policy`] is the validator).
pub fn spec_order(spec: &str) -> usize {
    spec.strip_prefix("forecast:k=")
        .and_then(|rest| rest.split(',').next())
        .and_then(|k| k.trim().parse::<usize>().ok())
        .unwrap_or(1)
}

/// Sweep bounds for one profiling run.
#[derive(Debug, Clone)]
pub struct GridSpec {
    /// Foresight (N, R) cycle shapes.
    pub nr: Vec<(usize, usize)>,
    /// Foresight threshold scalings γ (Eq. 7).
    pub gammas: Vec<f64>,
    /// Foresight warmup fractions.
    pub warmups: Vec<f64>,
    /// Static baseline (N, R) points.
    pub static_nr: Vec<(usize, usize)>,
    /// Forecast predictor orders k to layer over each Foresight point.
    /// Orders ≥ 2 emit `forecast:k=...,inner=foresight:...` candidates;
    /// k = 1 is verbatim replay and is already swept as the bare inner,
    /// so it never emits a wrapper.
    pub orders: Vec<usize>,
}

impl GridSpec {
    /// The paper's ablation ranges (Tables 2-3): a laptop-scale sweep.
    pub fn paper_default() -> Self {
        Self {
            nr: vec![(1, 2), (2, 3)],
            gammas: vec![0.25, 0.5, 1.0, 2.0],
            warmups: vec![0.15],
            static_nr: vec![(1, 2), (2, 3)],
            orders: vec![1, 2, 3],
        }
    }

    /// Minimal grid for smoke runs (CI, `fig19` reduced mode).
    pub fn tiny() -> Self {
        Self {
            nr: vec![(1, 2)],
            gammas: vec![0.5, 1.0],
            warmups: vec![0.15],
            static_nr: vec![(1, 2)],
            orders: vec![1, 2],
        }
    }

    /// Every candidate configuration, deduplicated by spec, with the
    /// serving default always included. `NoReuse` is *not* listed — the
    /// profiler measures it as the quality baseline and adds its point
    /// itself.
    pub fn candidates(&self) -> Vec<Knobs> {
        let mut out = vec![DEFAULT_KNOBS];
        for &(n, r) in &self.static_nr {
            out.push(Knobs::Static { n, r });
        }
        let mut foresight = Vec::new();
        for &(n, r) in &self.nr {
            for &gamma in &self.gammas {
                for &warmup in &self.warmups {
                    foresight.push(Knobs::Foresight { n, r, gamma, warmup });
                }
            }
        }
        out.extend(foresight.iter().cloned());
        for &k in &self.orders {
            if k < 2 {
                continue; // verbatim replay == the bare inner, already listed
            }
            for f in &foresight {
                out.push(Knobs::Forecast { k, inner: Box::new(f.clone()) });
            }
        }
        let mut seen = std::collections::BTreeSet::new();
        out.retain(|k| seen.insert(k.spec()));
        out
    }
}

/// Options for one profiling run.
#[derive(Debug, Clone)]
pub struct ProfileOptions {
    /// Denoising steps to profile at (`None` = model preset).
    pub steps: Option<usize>,
    /// Prompt-panel size (minimum 2; see [`prompt_panel`]).
    pub prompts: usize,
    /// Quality budget: minimum mean PSNR (dB) vs the NoReuse baseline.
    pub min_psnr: f64,
    pub grid: GridSpec,
}

impl Default for ProfileOptions {
    fn default() -> Self {
        Self {
            steps: None,
            prompts: 4,
            min_psnr: 30.0,
            grid: GridSpec::paper_default(),
        }
    }
}

/// Everything one profiling run produced: the tuned profile (chosen spec +
/// frontier) plus the full sweep for reporting.
#[derive(Debug, Clone)]
pub struct ProfileOutcome {
    pub profile: TunedProfile,
    /// All measured points (`none` baseline first), not just the frontier.
    pub points: Vec<ProfilePoint>,
}

/// The profiling prompt panel: `n` prompts (minimum 2) from the
/// VBench-proxy generator, drawn at least two deep per category so both
/// the static and the dynamic end of the reuse-potential spectrum are
/// represented (the template generator alternates styles by parity), and
/// deepened per category for panels larger than the 11 categories.
pub fn prompt_panel(n: usize) -> Vec<workload::PromptSpec> {
    let n = n.max(2);
    let cats = workload::VBENCH_CATEGORIES.len();
    let per_category = ((n + cats - 1) / cats).max(2);
    workload::vbench_prompts(per_category)
        .into_iter()
        .take(n)
        .collect()
}

/// Render one sweep as a markdown table (shared by `foresight autotune`
/// and `benches/fig19_autotune.rs` so the two reports cannot drift):
/// one row per measured point, `*` marking the Pareto frontier and `<==`
/// the chosen configuration.
pub fn sweep_table(outcome: &ProfileOutcome) -> MdTable {
    let frontier: std::collections::BTreeSet<&str> = outcome
        .profile
        .frontier
        .iter()
        .map(|f| f.spec.as_str())
        .collect();
    let mut t = MdTable::new(&[
        "spec", "order", "wall(s)", "reuse", "PSNR", "SSIM", "LPIPS", "frontier", "chosen",
    ]);
    for pt in &outcome.points {
        t.row(vec![
            pt.spec.clone(),
            spec_order(&pt.spec).to_string(),
            format!("{:.3}", pt.wall_s),
            format!("{:.0}%", 100.0 * pt.reuse_fraction),
            format!("{:.2}", pt.psnr),
            format!("{:.4}", pt.ssim),
            format!("{:.4}", pt.lpips),
            if frontier.contains(pt.spec.as_str()) {
                "*".into()
            } else {
                "".into()
            },
            if pt.spec == outcome.profile.spec {
                "<==".into()
            } else {
                "".into()
            },
        ]);
    }
    t
}

/// `q` strictly Pareto-dominates `p` on (wall ↓, psnr ↑).
fn dominates(q: &ProfilePoint, p: &ProfilePoint) -> bool {
    q.spec != p.spec
        && q.wall_s <= p.wall_s
        && q.psnr >= p.psnr
        && (q.wall_s < p.wall_s || q.psnr > p.psnr)
}

fn by_wall_then_spec(a: &ProfilePoint, b: &ProfilePoint) -> Ordering {
    a.wall_s
        .partial_cmp(&b.wall_s)
        .unwrap_or(Ordering::Equal)
        .then_with(|| a.spec.cmp(&b.spec))
}

/// The undominated (speed × quality) points, fastest first; ties resolved
/// by spec so the frontier is deterministic.
pub fn pareto_frontier(points: &[ProfilePoint]) -> Vec<ProfilePoint> {
    let mut out: Vec<ProfilePoint> = points
        .iter()
        .filter(|p| !points.iter().any(|q| dominates(q, p)))
        .cloned()
        .collect();
    out.sort_by(by_wall_then_spec);
    out.dedup_by(|a, b| a.spec == b.spec);
    out
}

/// Deterministic budgeted selection: the fastest point whose PSNR meets
/// `min_psnr` (ties → lexicographically smallest spec). When nothing meets
/// the budget — only possible if the baseline itself was excluded — the
/// highest-quality point wins, speed then spec as tie-breaks.
pub fn select(points: &[ProfilePoint], min_psnr: f64) -> Option<&ProfilePoint> {
    let within = points.iter().filter(|p| p.psnr >= min_psnr);
    if let Some(best) = within.min_by(|a, b| by_wall_then_spec(a, b)) {
        return Some(best);
    }
    points.iter().min_by(|a, b| {
        b.psnr
            .partial_cmp(&a.psnr)
            .unwrap_or(Ordering::Equal)
            .then_with(|| by_wall_then_spec(a, b))
    })
}

/// The overload tier (server `--degrade`): the fastest frontier point of a
/// tuned profile that still meets the profile's **own** min-PSNR budget.
///
/// Under queue pressure the server may serve `policy=auto` requests at this
/// point instead of the stored spec, trading measured PSNR headroom for
/// latency — but never *below* the budget the operator tuned with, so a
/// degraded response is still within the quality contract. Stores written
/// by `foresight autotune` record the fastest in-budget point as the spec
/// itself, so degradation is only a real swap for stores whose spec was
/// chosen conservatively (a stricter serve-time budget, a hand-edited
/// store, or a merged older profile); callers detect that by comparing the
/// returned spec against [`TunedProfile::spec`].
///
/// Returns `None` when no frontier point meets the budget (the profile
/// then has no in-budget tier to fall to, degraded or otherwise).
pub fn degrade_select(profile: &TunedProfile) -> Option<&ProfilePoint> {
    // The frontier is persisted fastest-first, but hand-edited or merged
    // stores may not honor that — select defensively rather than trusting
    // order, with the same (wall, spec) determinism as `select`.
    profile
        .frontier
        .iter()
        .filter(|p| p.psnr >= profile.min_psnr)
        .min_by(|a, b| by_wall_then_spec(a, b))
}

/// Profile one engine (= one loaded (model, bucket)) at one step count:
/// baseline first, then every grid candidate, then Pareto selection. The
/// returned [`ProfileOutcome`] carries both the tuned profile (ready for
/// [`ProfileStore::insert`]) and the full sweep for reporting.
pub fn profile_engine(engine: &Engine, opts: &ProfileOptions) -> Result<ProfileOutcome> {
    let info = engine.model().info.clone();
    let bucket = engine.model().bucket.clone();
    let steps = opts.steps.unwrap_or(info.steps);
    // Same bound the server enforces at the wire: the sampler constructors
    // assert on out-of-schedule step counts, and a profiling run must fail
    // cleanly, not panic (`foresight autotune --steps 0`).
    let t_train = engine.schedule().train_timesteps;
    if !(1..=t_train).contains(&steps) {
        return Err(anyhow::anyhow!(
            "autotune: steps must be in 1..={t_train} (the training schedule length), got {steps}"
        ));
    }
    // A nan/inf budget would silently select the NoReuse baseline and then
    // serialize as invalid JSON (the minimal writer has no non-finite
    // representation) — reject it up front.
    if !opts.min_psnr.is_finite() {
        return Err(anyhow::anyhow!(
            "autotune: min_psnr budget must be finite, got {}",
            opts.min_psnr
        ));
    }
    let panel = prompt_panel(opts.prompts);
    let dec = Decoder::new(bucket.ph, bucket.pw, info.latent_channels);
    let net = FeatureNet::new();

    let run = |spec: &str, prompt: &str, seed: u64, run_steps: usize| {
        let mut policy = build_policy(spec, &info, run_steps)
            .with_context(|| format!("autotune candidate '{spec}'"))?;
        let mut req = Request::new(prompt, seed);
        req.steps = Some(run_steps);
        engine.generate(&req, policy.as_mut(), None)
    };

    // Warm the fused-executable caches so the first measured candidate is
    // not charged the compile time.
    let _ = run("none", "autotune warmup prompt", 0, steps.min(2).max(1))?;

    // NoReuse baseline: the quality reference and the first sweep point
    // (PSNR vs itself saturates at the metric cap, so it always satisfies
    // any sensible budget — selection can never come up empty).
    let mut base_wall = Vec::with_capacity(panel.len());
    let mut base_frames: Vec<Frames> = Vec::with_capacity(panel.len());
    for p in &panel {
        let r = run("none", &p.text, p.id as u64, steps)?;
        base_wall.push(r.stats.wall_s);
        base_frames.push(dec.decode(&r.latents));
    }
    let mut points = vec![ProfilePoint {
        spec: Knobs::NoReuse.spec(),
        wall_s: stats::mean(&base_wall),
        reuse_fraction: 0.0,
        psnr: 100.0,
        ssim: 1.0,
        lpips: 0.0,
    }];

    for knobs in opts.grid.candidates() {
        let spec = knobs.spec();
        let mut wall = Vec::with_capacity(panel.len());
        let mut reuse = stats::Welford::new();
        let mut psnr = stats::Welford::new();
        let mut ssim = stats::Welford::new();
        let mut lpips = stats::Welford::new();
        for (i, p) in panel.iter().enumerate() {
            let r = run(&spec, &p.text, p.id as u64, steps)?;
            wall.push(r.stats.wall_s);
            reuse.push(r.stats.reuse_fraction());
            let fr = dec.decode(&r.latents);
            psnr.push(metrics::psnr(&base_frames[i], &fr));
            ssim.push(metrics::ssim(&base_frames[i], &fr));
            lpips.push(metrics::lpips(&net, &base_frames[i], &fr));
        }
        points.push(ProfilePoint {
            spec,
            wall_s: stats::mean(&wall),
            reuse_fraction: reuse.mean(),
            psnr: psnr.mean(),
            ssim: ssim.mean(),
            lpips: lpips.mean(),
        });
    }

    let frontier = pareto_frontier(&points);
    let chosen = select(&points, opts.min_psnr)
        .expect("sweep contains the baseline point")
        .clone();
    Ok(ProfileOutcome {
        profile: TunedProfile {
            key: ProfileKey {
                model: info.name.clone(),
                bucket: bucket.name.clone(),
                sampler: info.sampler.name().to_string(),
                steps,
            },
            spec: chosen.spec,
            min_psnr: opts.min_psnr,
            profile_version: 1,
            frontier,
        },
        points,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelInfo, SamplerKind};
    use std::collections::BTreeMap;

    fn point(spec: &str, wall_s: f64, psnr: f64) -> ProfilePoint {
        ProfilePoint { spec: spec.into(), wall_s, reuse_fraction: 0.0, psnr, ssim: 1.0, lpips: 0.0 }
    }

    fn model() -> ModelInfo {
        ModelInfo {
            name: "m".into(),
            layers: 6,
            d_model: 96,
            n_heads: 4,
            d_text: 64,
            text_len: 16,
            latent_channels: 8,
            mlp_ratio: 4,
            t_freq_dim: 128,
            sampler: SamplerKind::Rflow,
            steps: 30,
            cfg_scale: 7.5,
            weights_dir: "w".into(),
            piece_params: BTreeMap::new(),
            buckets: BTreeMap::new(),
        }
    }

    #[test]
    fn every_grid_candidate_parses_via_build_policy() {
        let m = model();
        for knobs in GridSpec::paper_default().candidates() {
            let spec = knobs.spec();
            let p = build_policy(&spec, &m, 30).unwrap_or_else(|e| panic!("{spec}: {e}"));
            assert!(!p.name().is_empty());
        }
        assert!(build_policy(&Knobs::NoReuse.spec(), &m, 30).is_ok());
    }

    #[test]
    fn grid_includes_serving_default_and_dedupes() {
        let grid = GridSpec {
            nr: vec![(1, 2), (1, 2)],
            gammas: vec![0.5, 0.5],
            warmups: vec![0.15],
            static_nr: vec![(1, 2)],
            orders: vec![1, 1],
        };
        let cands = grid.candidates();
        let specs: Vec<String> = cands.iter().map(|k| k.spec()).collect();
        let unique: std::collections::BTreeSet<_> = specs.iter().collect();
        assert_eq!(specs.len(), unique.len(), "duplicates survived: {specs:?}");
        assert!(specs.contains(&DEFAULT_KNOBS.spec()));
        // the duplicated grid axes collapse to default + static
        assert_eq!(specs.len(), 2, "{specs:?}");
    }

    #[test]
    fn grid_orders_emit_forecast_wrappers_for_k_ge_2() {
        let grid = GridSpec {
            nr: vec![(1, 2)],
            gammas: vec![0.5],
            warmups: vec![0.15],
            static_nr: vec![],
            orders: vec![1, 2, 3],
        };
        let specs: Vec<String> = grid.candidates().iter().map(|k| k.spec()).collect();
        // k=1 emits no wrapper (it IS the bare inner); k=2 and k=3 each
        // wrap the single foresight point. Default == that point, so:
        // [foresight default, forecast k=2, forecast k=3].
        assert_eq!(
            specs,
            vec![
                "foresight:n=1,r=2,gamma=0.5,warmup=0.15".to_string(),
                "forecast:k=2,inner=foresight:n=1,r=2,gamma=0.5,warmup=0.15".to_string(),
                "forecast:k=3,inner=foresight:n=1,r=2,gamma=0.5,warmup=0.15".to_string(),
            ]
        );
    }

    #[test]
    fn spec_order_parses_forecast_heads() {
        assert_eq!(spec_order("none"), 1);
        assert_eq!(spec_order("foresight:n=1,r=2,gamma=0.5,warmup=0.15"), 1);
        assert_eq!(spec_order("forecast:k=3,inner=static:n=1,r=2"), 3);
        assert_eq!(spec_order("forecast:k=oops,inner=none"), 1);
        assert_eq!(
            Knobs::Forecast { k: 2, inner: Box::new(DEFAULT_KNOBS) }.order(),
            2
        );
        assert_eq!(DEFAULT_KNOBS.order(), 1);
    }

    #[test]
    fn pareto_frontier_drops_dominated_points() {
        let points = vec![
            point("a", 1.0, 40.0), // frontier: fastest
            point("b", 2.0, 45.0), // frontier: best quality
            point("c", 1.5, 39.0), // dominated by a (slower, worse)
            point("d", 1.0, 40.0), // metric tie with a: both kept
        ];
        let f = pareto_frontier(&points);
        let specs: Vec<&str> = f.iter().map(|p| p.spec.as_str()).collect();
        assert_eq!(specs, vec!["a", "d", "b"]);
    }

    #[test]
    fn select_is_budgeted_and_deterministic() {
        let points = vec![
            point("none", 3.0, 100.0),
            point("fast-bad", 1.0, 20.0),
            point("mid", 1.5, 35.0),
            point("mid-tie", 1.5, 36.0),
        ];
        // fastest within budget; wall tie broken by spec ("mid" < "mid-tie")
        assert_eq!(select(&points, 30.0).unwrap().spec, "mid");
        // generous budget: the overall fastest wins
        assert_eq!(select(&points, 10.0).unwrap().spec, "fast-bad");
        // impossible budget: best quality wins
        assert_eq!(select(&points, 1000.0).unwrap().spec, "none");
        assert!(select(&[], 30.0).is_none());
    }

    fn tuned(spec: &str, min_psnr: f64, frontier: Vec<ProfilePoint>) -> TunedProfile {
        TunedProfile {
            key: ProfileKey {
                model: "m".into(),
                bucket: "240p-2s".into(),
                sampler: "rflow".into(),
                steps: 30,
            },
            spec: spec.into(),
            min_psnr,
            profile_version: 1,
            frontier,
        }
    }

    #[test]
    fn degrade_select_picks_fastest_in_budget_tier() {
        // A store with quality headroom: the stored spec is the conservative
        // point, and a faster point still meets the budget. A faster-still
        // point *below* budget must never be selected.
        let p = tuned(
            "tuned",
            30.0,
            vec![
                point("fast-bad", 0.5, 22.0), // below budget: forbidden
                point("fast-good", 1.0, 31.0),
                point("tuned", 2.0, 38.0),
            ],
        );
        assert_eq!(degrade_select(&p).unwrap().spec, "fast-good");
    }

    #[test]
    fn degrade_select_is_order_independent_and_deterministic() {
        // Frontier order reversed (merged/hand-edited stores may not be
        // sorted) and a wall tie: same answer, spec tie-break.
        let p = tuned(
            "tuned",
            30.0,
            vec![
                point("tuned", 2.0, 38.0),
                point("b-tie", 1.0, 33.0),
                point("a-tie", 1.0, 31.0),
            ],
        );
        assert_eq!(degrade_select(&p).unwrap().spec, "a-tie");
    }

    #[test]
    fn degrade_select_none_when_nothing_meets_budget() {
        let p = tuned("tuned", 50.0, vec![point("fast-bad", 0.5, 22.0), point("tuned", 2.0, 38.0)]);
        assert!(degrade_select(&p).is_none());
        assert!(degrade_select(&tuned("tuned", 30.0, vec![])).is_none());
    }

    #[test]
    fn degrade_select_matches_spec_for_autotune_written_stores() {
        // `foresight autotune` stores the fastest in-budget point as the
        // spec itself — degradation must then be a no-op (same spec back),
        // never a below-budget escape hatch.
        let frontier =
            vec![point("fast-bad", 0.5, 22.0), point("tuned", 1.0, 35.0), point("hq", 2.0, 40.0)];
        let chosen = select(&frontier, 30.0).unwrap().spec.clone();
        let p = tuned(&chosen, 30.0, frontier);
        assert_eq!(degrade_select(&p).unwrap().spec, p.spec);
    }

    #[test]
    fn prompt_panel_mixes_static_and_dynamic_prompts() {
        for n in [1, 2, 4, 11, 26] {
            let panel = prompt_panel(n);
            assert_eq!(panel.len(), n.max(2), "panel size for n={n}");
            let complexities: Vec<f64> = panel
                .iter()
                .map(|p| crate::workload::motion_complexity(&p.text))
                .collect();
            let min = complexities.iter().cloned().fold(f64::INFINITY, f64::min);
            let max = complexities.iter().cloned().fold(0.0, f64::max);
            assert!(
                min < max,
                "panel of {n} must span static and dynamic prompts \
                 (complexities {complexities:?})"
            );
        }
    }

    #[test]
    fn knobs_specs_are_canonical() {
        assert_eq!(Knobs::NoReuse.spec(), "none");
        assert_eq!(Knobs::Static { n: 2, r: 3 }.spec(), "static:n=2,r=3");
        assert_eq!(
            Knobs::Foresight { n: 1, r: 2, gamma: 0.5, warmup: 0.15 }.spec(),
            "foresight:n=1,r=2,gamma=0.5,warmup=0.15"
        );
        assert_eq!(
            Knobs::Forecast { k: 2, inner: Box::new(Knobs::Static { n: 1, r: 2 }) }.spec(),
            "forecast:k=2,inner=static:n=1,r=2"
        );
    }
}
