//! Step-driven generation sessions — the one denoising-step
//! implementation behind every generate path.
//!
//! A [`Session`] is a started request: its resident latent, per-branch
//! [`FeatureCache`]s (owned by the session's persistent branch workers),
//! its reuse policy, the precomputed timestep embeddings and sampler
//! coefficients for its whole schedule, and a **cursor**. The engine's
//! public paths are thin drivers over this module:
//!
//! * [`crate::engine::Engine::generate`] admits one session and steps it
//!   to completion (inline-sequential when an observer is attached or
//!   under [`HotPath::Host`], parallel branch workers otherwise);
//! * [`crate::engine::Engine::generate_batch`] admits `B` compatible
//!   sessions and drives them in lockstep through [`step_many`] — the
//!   ≤1e-6 equivalence oracle for the batched pass;
//! * the server's continuous scheduler
//!   (`crate::server`, `scheduler` submodule) admits and retires sessions
//!   at **step boundaries**, so requests with different step counts, CFG
//!   scales or policies share device passes without waiting for each
//!   other.
//!
//! # Cohort stepping
//!
//! [`step_many`] advances any set of same-(model, bucket, sampler)
//! sessions one step in one fused device pass. The cohort's latents live
//! stacked as one `[B, F, P, C]` resident tensor; when membership is
//! unchanged since the previous step the stacked tensor is **reused**
//! as-is, when lanes retired it is compacted in one dispatch
//! ([`crate::runtime::Runtime::regroup`]), and on joins it is restacked
//! from lane tensors via the existing
//! [`crate::runtime::Runtime::stack`]/[`crate::runtime::Runtime::lane`]
//! ops. Each step then runs per-lane patch embeddings, `2B` concurrent
//! branch sweeps on the sessions' persistent workers, and **one** fused
//! multi-lane advance (`cohort_rflow_step`/`cohort_ddim_step`) whose
//! per-lane rank-0 arguments are each session's own CFG scale and the
//! sampler coefficients at each session's own cursor — which is what lets
//! mixed `steps`/`cfg_scale` requests share a pass.
//!
//! # Policy-free branch workers
//!
//! Branch workers never touch the policy. Decisions for step `t` depend
//! only on observations from steps `< t` (the engine's long-standing
//! branch-interleaving contract, and policy state is keyed per site), so
//! the coordinator precomputes the whole step's actions for both CFG
//! branches before dispatch and applies the returned drift observations
//! after both branches join. This keeps the policy borrowed at the driver
//! (no locking on the sweep path) while the workers own their caches for
//! the session's whole life and are plain `'static` threads that survive
//! across scheduler calls.
//!
//! # Byte model
//!
//! A session charges exactly the standalone cost of its request: text
//! conditioning, CFG scale, sampler setup, the initial latent and the
//! per-step scalars at admit; 4-byte drift scalars per measured site
//! while stepping; one final-latent download at [`Session::finish`]. The
//! former micro-batch "as-if-standalone" byte model is therefore now the
//! *actual* per-session transfer behavior — per-request [`RunStats`]
//! meters are unchanged and independent of cohort size.

use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::cache::{CacheKey, FeatureCache, Unit};
use crate::config::SamplerKind;
use crate::model::{BlockKind, LoadedModel, SubUnit};
use crate::policy::{sites_for, Action, CacheMode, Granularity, ReusePolicy, Site};
use crate::runtime::{lms_coefficients, DeviceTensor, Executable, HostTensor, Runtime};
use crate::sampler::{self, DeviceCoeffs, DeviceStepper, Sampler};
use crate::trace;
use crate::util::prng::Rng;
use crate::util::stats::mse_f32;
use crate::workload;

use super::{Engine, HotPath, Request, RunResult, RunStats, StepDecision, StepObserver};

/// Per-branch request context (precomputed cross-attention K/V).
pub(crate) struct BranchCtx {
    text_kv: Vec<[(Arc<DeviceTensor>, Arc<DeviceTensor>); 2]>,
}

/// Precompute one branch's text conditioning (projection + per-layer
/// cross-attention K/V).
fn branch_ctx(m: &LoadedModel, raw: &HostTensor) -> Result<BranchCtx> {
    let text = Arc::new(m.text_proj(raw)?);
    let mut text_kv = Vec::with_capacity(m.info.layers);
    for layer in 0..m.info.layers {
        let mut pair = Vec::with_capacity(2);
        for kind in BlockKind::ALL {
            let tk = Arc::new(m.text_k(layer, kind, &text)?);
            let tv = Arc::new(m.text_v(layer, kind, &text)?);
            pair.push((tk, tv));
        }
        let pair: [(Arc<DeviceTensor>, Arc<DeviceTensor>); 2] =
            pair.try_into().map_err(|_| anyhow!("kv pair"))?;
        text_kv.push(pair);
    }
    Ok(BranchCtx { text_kv })
}

/// Request-constant knobs shared by every step of one session.
#[derive(Clone, Copy)]
struct RunParams {
    steps: usize,
    cfg_scale: f32,
    granularity: Granularity,
    cache_mode: CacheMode,
    needs_measure: bool,
    /// Cached outputs retained per site ([`ReusePolicy::history_depth`]);
    /// ≥ 2 enables the forecasting (`Action::Predict`) arm.
    history_depth: usize,
}

/// Step-constant inputs shared by both branch sweeps.
struct StepCtx<'a> {
    step: usize,
    granularity: Granularity,
    cache_mode: CacheMode,
    needs_measure: bool,
    c: &'a Arc<DeviceTensor>,
    h0: &'a Arc<DeviceTensor>,
    /// Predictor coefficients c₀..c₍ₖ₋₁₎ as resident rank-0 tensors,
    /// uploaded once at admit (empty unless `history_depth ≥ 2`).
    lms: &'a [Arc<DeviceTensor>],
}

/// Per-branch counters, merged into [`RunStats`] after the branches join.
#[derive(Debug, Default)]
struct BranchStats {
    computed: u64,
    reused: u64,
    fallback: u64,
    /// Reuse units served by `lms_combine` forecast (subset of `reused`).
    forecast: u64,
    /// Planned forecasts replayed verbatim instead — history ring was
    /// shallower than the predictor order (subset of `reused`).
    forecast_fallback: u64,
    d2h_bytes: u64,
    d2h_calls: u64,
}

impl BranchStats {
    fn merge_into(&self, s: &mut RunStats) {
        s.computed_units += self.computed;
        s.reused_units += self.reused;
        s.fallback_units += self.fallback;
        s.forecast_units += self.forecast;
        s.forecast_fallback_units += self.forecast_fallback;
        s.d2h_bytes += self.d2h_bytes;
        s.d2h_calls += self.d2h_calls;
    }
}

/// What one CFG branch produces for one step: its epsilon, counters, and
/// the drift observations for the coordinator to feed back to the policy.
struct BranchOut {
    eps: DeviceTensor,
    stats: BranchStats,
    observations: Vec<(Site, f64)>,
}

/// Host mirrors of measured activations ([`HotPath::Host`] only).
type HostMirror = BTreeMap<CacheKey, Vec<f32>>;

/// What a branch worker receives per step:
/// (step, t-embedding, h0, precomputed site actions in sweep order).
type WorkerJob = (usize, Arc<DeviceTensor>, Arc<DeviceTensor>, Vec<Action>);

/// One persistent policy-free branch executor thread. Owns its
/// [`FeatureCache`] for the session's whole life and hands it back at
/// [`BranchWorker::shutdown`]; dropping the worker (error paths) still
/// disconnects and joins so no thread leaks.
struct BranchWorker {
    tx: Option<mpsc::Sender<WorkerJob>>,
    rx: mpsc::Receiver<Result<BranchOut>>,
    handle: Option<JoinHandle<FeatureCache>>,
}

impl BranchWorker {
    fn spawn(
        model: Arc<LoadedModel>,
        bctx: Arc<BranchCtx>,
        branch: usize,
        rp: RunParams,
        trace_id: u64,
        lms: Vec<Arc<DeviceTensor>>,
    ) -> Self {
        let cache = FeatureCache::with_history(rp.history_depth);
        Self::spawn_with_cache(model, bctx, branch, rp, trace_id, lms, cache)
    }

    /// Spawn with a pre-populated cache — the device-migration path seeds
    /// the new worker with the entries transferred from the old device so
    /// the policy sees exactly the cache state it would have seen had the
    /// session never moved.
    fn spawn_with_cache(
        model: Arc<LoadedModel>,
        bctx: Arc<BranchCtx>,
        branch: usize,
        rp: RunParams,
        trace_id: u64,
        lms: Vec<Arc<DeviceTensor>>,
        cache: FeatureCache,
    ) -> Self {
        let (tx_job, rx_job) = mpsc::channel::<WorkerJob>();
        let (tx_res, rx_res) = mpsc::channel::<Result<BranchOut>>();
        let handle = std::thread::Builder::new()
            .name(format!("foresight-session-branch-{branch}"))
            .spawn(move || {
                // Attribute this worker's runtime transfer events (drift
                // scalar downloads etc.) to the owning request's span.
                trace::set_current(trace_id);
                let mut cache = cache;
                let mut mirror: HostMirror = BTreeMap::new();
                while let Ok((step, c, h0, actions)) = rx_job.recv() {
                    let ctx = StepCtx {
                        step,
                        granularity: rp.granularity,
                        cache_mode: rp.cache_mode,
                        needs_measure: rp.needs_measure,
                        c: &c,
                        h0: &h0,
                        lms: &lms,
                    };
                    let r = sweep_branch(
                        &model,
                        HotPath::Device,
                        &ctx,
                        branch,
                        &bctx,
                        &actions,
                        &mut cache,
                        &mut mirror,
                        None,
                    );
                    let failed = r.is_err();
                    if tx_res.send(r).is_err() || failed {
                        break;
                    }
                }
                cache
            })
            .expect("spawn session branch worker");
        Self { tx: Some(tx_job), rx: rx_res, handle: Some(handle) }
    }

    fn send(&self, job: WorkerJob) -> Result<()> {
        self.tx
            .as_ref()
            .ok_or_else(|| anyhow!("session branch worker already shut down"))?
            .send(job)
            .map_err(|_| anyhow!("session branch worker exited early"))
    }

    fn recv(&self) -> Result<BranchOut> {
        self.rx
            .recv()
            .map_err(|_| anyhow!("session branch worker disconnected"))?
    }

    /// Disconnect, join, and recover the branch's cache. A panic inside
    /// the worker surfaces as an `Err`, never a re-raised panic.
    fn shutdown(&mut self) -> Result<FeatureCache> {
        self.tx.take();
        match self.handle.take() {
            Some(h) => h
                .join()
                .map_err(|_| anyhow!("session CFG branch worker panicked")),
            None => Err(anyhow!("session branch worker already joined")),
        }
    }
}

impl Drop for BranchWorker {
    fn drop(&mut self) {
        self.tx.take();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// How a session executes its two CFG branch sweeps.
enum Exec {
    /// Two persistent policy-free worker threads (cond, uncond) — the
    /// device hot path, cohort-capable.
    Workers([BranchWorker; 2]),
    /// Sequential sweeps on the caller's thread — observer runs and
    /// [`HotPath::Host`]; caches and mirrors live in the session.
    Inline {
        caches: [FeatureCache; 2],
        mirrors: [HostMirror; 2],
    },
}

/// Where a session's denoising state currently lives.
enum Latent {
    /// Device-resident, this session alone (`[F, P, C]`).
    DeviceOwn(DeviceTensor),
    /// One lane of a cohort's shared stacked tensor (`[B, F, P, C]`).
    DeviceStacked { stack: Arc<DeviceTensor>, lane: usize },
    /// Host-resident (seed-era [`HotPath::Host`] staging).
    Host(Vec<f32>),
}

/// Device-path request-constant executables and uploads.
struct DeviceGear {
    stepper: DeviceStepper,
    cfg_exec: Arc<Executable>,
    cfg_scale_dev: DeviceTensor,
    /// Timestep embeddings for every step, uploaded at admit.
    c_steps: Vec<Arc<DeviceTensor>>,
    /// Sampler step coefficients for every step, uploaded at admit.
    coeffs: Vec<DeviceCoeffs>,
}

/// A started generation request (see module docs).
pub struct Session<'p> {
    model: Arc<LoadedModel>,
    /// Request prompt, kept so device migration can recompute the text
    /// conditioning on the target runtime (the embedding is deterministic;
    /// the session stores no other copy of the request).
    prompt: String,
    hot_path: HotPath,
    policy: Box<dyn ReusePolicy + 'p>,
    rp: RunParams,
    smp: Box<dyn Sampler>,
    gear: Option<DeviceGear>,
    exec: Exec,
    latent: Latent,
    branches: [Arc<BranchCtx>; 2],
    /// Decision sites per CFG branch, in sweep order.
    sites: [Vec<Site>; 2],
    cursor: usize,
    stats: RunStats,
    reuse_map: Vec<Vec<StepDecision>>,
    /// Predictor coefficients as resident rank-0 tensors (uploaded once
    /// at admit; empty unless the policy's history depth is ≥ 2). Workers
    /// hold clones; this copy feeds the inline path and is rebuilt —
    /// unmetered, like the rest of [`DeviceGear`] — on device migration.
    lms: Vec<Arc<DeviceTensor>>,
    dims: [usize; 3],
    latent_elems: usize,
    /// Largest cohort this session ever shared a step with (≥ 1).
    peak_lanes: usize,
    /// Set on any step error: a failed step may have already swept its
    /// branches (mutating caches and policy state), so retrying the same
    /// cursor would double-run `policy.action` and measure drift against
    /// the just-refreshed cache — silently corrupting decisions instead
    /// of failing. Poisoned sessions refuse further steps.
    poisoned: bool,
    /// Request span for the event tracer (0 = unattributed).
    trace_id: u64,
    t_start: Instant,
}

/// What one [`step_many`] call did (scheduler telemetry).
#[derive(Debug, Clone, Copy)]
pub struct StepReport {
    /// Lanes advanced by this pass.
    pub occupancy: usize,
    /// True when the resident stack had to be rebuilt or compacted
    /// (cohort membership changed since the previous step).
    pub restacked: bool,
}

impl<'p> Session<'p> {
    /// Start a request: reset the policy, precompute both branches' text
    /// conditioning (concurrently), upload the request-constant device
    /// state, and — on the parallel device path — spawn the two
    /// persistent branch workers.
    pub(crate) fn admit_full(
        engine: &Engine,
        req: &Request,
        mut policy: Box<dyn ReusePolicy + 'p>,
        parallel: bool,
    ) -> Result<Session<'p>> {
        let m = engine.model.clone();
        let info = &m.info;
        let steps = req.steps.unwrap_or(info.steps);
        let cfg_scale = req.cfg_scale.unwrap_or(info.cfg_scale) as f32;
        let smp = sampler::build(info.sampler, &engine.schedule, steps);

        policy.begin_request(info.layers, steps);
        let mut stats = RunStats { policy: policy.name(), ..Default::default() };
        let rp = RunParams {
            steps,
            cfg_scale,
            granularity: policy.granularity(),
            cache_mode: policy.cache_mode(),
            needs_measure: policy.needs_measurement(),
            history_depth: policy.history_depth(),
        };
        let sites = [
            sites_for(info.layers, rp.granularity, 0),
            sites_for(info.layers, rp.granularity, 1),
        ];

        // Request-constant conditioning: the two branch contexts are
        // independent executable chains, so they precompute concurrently.
        let cond_raw = workload::embed_prompt(&req.prompt, info.d_text, info.text_len);
        let uncond_raw = HostTensor::zeros(vec![info.text_len, info.d_text]);
        let (rc, ru) = std::thread::scope(|sc| {
            let hu = sc.spawn(|| branch_ctx(&m, &uncond_raw));
            let rc = branch_ctx(&m, &cond_raw);
            let ru = match hu.join() {
                Ok(r) => r,
                Err(_) => Err(anyhow!("uncond branch-ctx thread panicked")),
            };
            (rc, ru)
        });
        let branches = [Arc::new(rc?), Arc::new(ru?)];
        stats.h2d_bytes += 2 * (info.text_len * info.d_text * 4) as u64;
        stats.h2d_calls += 2;

        let [f, p, _d] = m.state_dims();
        let [_, _, c_lat] = m.latent_dims();
        let dims = [f, p, c_lat];
        let latent_elems = f * p * c_lat;
        let rt = m.runtime().clone();

        // Forecasting: the predictor's k fixed coefficients upload once
        // at admit as resident rank-0 scalars, so a later Predict step
        // dispatches `lms_combine` with zero additional transfers.
        let mut lms: Vec<Arc<DeviceTensor>> = Vec::new();
        if rp.history_depth >= 2 {
            for c in lms_coefficients(rp.history_depth)? {
                lms.push(Arc::new(rt.upload(&[c], &[])?));
                stats.h2d_bytes += 4;
                stats.h2d_calls += 1;
            }
        }

        let (gear, latent) = match engine.hot_path {
            HotPath::Device => {
                let cfg_exec = rt.cfg_combine(&dims)?;
                let cfg_scale_dev = rt.upload(&[rp.cfg_scale], &[])?;
                stats.h2d_bytes += 4;
                stats.h2d_calls += 1;
                let stepper = DeviceStepper::new(&rt, smp.kind(), &dims)?;
                stats.h2d_bytes += stepper.setup_h2d_bytes();
                stats.h2d_calls += stepper.setup_h2d_calls();

                // Initial latent: uploaded once, resident until finish.
                let mut latent_rng = Rng::from_seed_and_label(req.seed, "latents");
                let x_init = latent_rng.normal_vec(latent_elems);
                let x_dev = rt.upload(&x_init, &dims)?;
                stats.h2d_bytes += (latent_elems * 4) as u64;
                stats.h2d_calls += 1;

                // Every t_value and step coefficient is known up front, so
                // the timestep embeddings and per-step sampler scalars
                // upload once at admit (4 bytes per scalar).
                let t_values: Vec<f32> = (0..steps).map(|i| smp.t_value(i)).collect();
                let c_steps = m.t_embeds(&t_values)?;
                stats.h2d_bytes += 4 * steps as u64;
                stats.h2d_calls += steps as u64;
                let mut coeffs = Vec::with_capacity(steps);
                for i in 0..steps {
                    let cf = stepper.upload_coeffs(&smp.step_coeffs(i))?;
                    stats.h2d_bytes += 4 * cf.len() as u64;
                    stats.h2d_calls += cf.len() as u64;
                    coeffs.push(cf);
                }
                (
                    Some(DeviceGear { stepper, cfg_exec, cfg_scale_dev, c_steps, coeffs }),
                    Latent::DeviceOwn(x_dev),
                )
            }
            HotPath::Host => {
                let mut latent_rng = Rng::from_seed_and_label(req.seed, "latents");
                (None, Latent::Host(latent_rng.normal_vec(latent_elems)))
            }
        };

        let exec = if parallel && engine.hot_path == HotPath::Device {
            Exec::Workers([
                BranchWorker::spawn(m.clone(), branches[0].clone(), 0, rp, req.trace_id, lms.clone()),
                BranchWorker::spawn(m.clone(), branches[1].clone(), 1, rp, req.trace_id, lms.clone()),
            ])
        } else {
            Exec::Inline {
                caches: [
                    FeatureCache::with_history(rp.history_depth),
                    FeatureCache::with_history(rp.history_depth),
                ],
                mirrors: [BTreeMap::new(), BTreeMap::new()],
            }
        };

        Ok(Session {
            model: m,
            prompt: req.prompt.clone(),
            hot_path: engine.hot_path,
            policy,
            rp,
            smp,
            gear,
            exec,
            latent,
            branches,
            sites,
            cursor: 0,
            stats,
            reuse_map: Vec::with_capacity(steps),
            lms,
            dims,
            latent_elems,
            peak_lanes: 1,
            poisoned: false,
            trace_id: req.trace_id,
            t_start: Instant::now(),
        })
    }

    /// Total denoising steps in this session's schedule.
    pub fn steps(&self) -> usize {
        self.rp.steps
    }

    /// Next step to execute (== [`Session::steps`] when done).
    pub fn cursor(&self) -> usize {
        self.cursor
    }

    /// The request span this session's trace events carry (0 = none).
    pub fn trace_id(&self) -> u64 {
        self.trace_id
    }

    pub fn is_done(&self) -> bool {
        self.cursor >= self.rp.steps
    }

    /// Largest cohort this session ever shared a device pass with.
    pub fn peak_lanes(&self) -> usize {
        self.peak_lanes
    }

    /// True once a step or migration error has poisoned this session:
    /// caches/policy state may have advanced past the cursor (or be split
    /// across devices), so it refuses further steps and callers must
    /// answer the client and drop it. The server's scheduler checks this
    /// at step boundaries so a poisoned lane can never poison a shared
    /// cohort pass.
    pub fn is_poisoned(&self) -> bool {
        self.poisoned
    }

    /// Early-retire an unfinished session (deadline expiry, shutdown,
    /// poisoning): reap both persistent branch workers *now* instead of
    /// waiting for `Drop`, without downloading the latent or assembling a
    /// [`RunResult`]. The freed lane (device tensors, caches, worker
    /// threads) is released before this returns, so a scheduler that
    /// abandons an expired lane immediately recovers its capacity.
    pub fn abandon(mut self) {
        if let Exec::Workers(ws) = &mut self.exec {
            for w in ws {
                let _ = w.shutdown();
            }
        }
    }

    /// Precompute both branches' site actions for the current step. Safe
    /// before the sweeps because decisions for step `t` depend only on
    /// observations from steps `< t` (module docs §Policy-free workers).
    fn plan_step(&mut self) -> (Vec<Action>, Vec<Action>, Vec<StepDecision>) {
        let step = self.cursor;
        let pol = &mut self.policy;
        let actions0: Vec<Action> =
            self.sites[0].iter().map(|site| pol.action(step, *site)).collect();
        let actions1: Vec<Action> =
            self.sites[1].iter().map(|site| pol.action(step, *site)).collect();
        let decisions: Vec<StepDecision> = actions0
            .iter()
            .map(|a| match a {
                Action::Predict { .. } => StepDecision::Predict,
                a if a.is_reuse() => StepDecision::Reuse,
                _ => StepDecision::Compute,
            })
            .collect();
        (actions0, actions1, decisions)
    }

    /// Feed the branches' drift observations back to the policy (cond
    /// branch first, then uncond — per-site state makes the cross-branch
    /// order immaterial, see the engine docs' interleaving argument).
    fn absorb(&mut self, oc: &BranchOut, ou: &BranchOut, decisions: Vec<StepDecision>) {
        let step = self.cursor;
        for (site, mse) in oc.observations.iter().chain(ou.observations.iter()) {
            self.policy.observe_mse(step, *site, *mse);
        }
        oc.stats.merge_into(&mut self.stats);
        ou.stats.merge_into(&mut self.stats);
        self.emit_policy_events(step, &decisions, oc, ou);
        self.reuse_map.push(decisions);
    }

    /// One [`trace::Payload::Policy`] instant per branch-0 decision site
    /// (plus one per measured uncond site) for this step: the planned
    /// action, the observed drift MSE (−1 = unmeasured), and the policy's
    /// λ threshold (−1 = none yet, e.g. during warmup). Gated on the
    /// tracer so the untraced hot path pays one relaxed atomic load.
    fn emit_policy_events(
        &self,
        step: usize,
        decisions: &[StepDecision],
        oc: &BranchOut,
        ou: &BranchOut,
    ) {
        if self.trace_id == 0 || !trace::global().enabled() {
            return;
        }
        let lambdas = self.policy.thresholds();
        let lam = |site: &Site| {
            lambdas
                .as_ref()
                .and_then(|t| t.get(&(site.layer, site.kind, site.branch)))
                .copied()
                .unwrap_or(-1.0)
        };
        let mse_of = |obs: &[(Site, f64)], site: &Site| {
            obs.iter().find(|(s, _)| s == site).map_or(-1.0, |(_, m)| *m)
        };
        for (i, site) in self.sites[0].iter().enumerate() {
            let d = decisions.get(i).copied().unwrap_or(StepDecision::Compute);
            trace::emit(
                self.trace_id,
                trace::Payload::Policy {
                    step: step as u32,
                    branch: 0,
                    site: i as u32,
                    reuse: d.is_reuse(),
                    predict: d == StepDecision::Predict,
                    mse: mse_of(&oc.observations, site),
                    lambda: lam(site),
                },
            );
        }
        // The uncond branch's planned actions aren't retained past the
        // sweep, but a drift observation implies the site computed — so
        // its measured sites still get a per-branch event.
        for (site, mse) in ou.observations.iter() {
            let idx = self.sites[1].iter().position(|s| s == site).unwrap_or(0);
            trace::emit(
                self.trace_id,
                trace::Payload::Policy {
                    step: step as u32,
                    branch: 1,
                    site: idx as u32,
                    reuse: false,
                    predict: false,
                    mse: *mse,
                    lambda: lam(site),
                },
            );
        }
    }

    /// Advance this session one step on its own (no cohort). Drives all
    /// three historical loop bodies: the resident device path (parallel
    /// workers or inline for observer runs) and the seed-era host staging.
    ///
    /// A step error **poisons** the session (caches/policy state may have
    /// advanced past the cursor): further steps refuse, and callers
    /// should drop it rather than retry.
    pub fn step(&mut self, observer: Option<&mut dyn StepObserver>) -> Result<()> {
        if self.poisoned {
            return Err(anyhow!("session poisoned by an earlier step error"));
        }
        if self.is_done() {
            return Err(anyhow!("session already finished its schedule"));
        }
        let r = match self.hot_path {
            HotPath::Device => self.step_device_single(observer),
            HotPath::Host => self.step_host(observer),
        };
        if r.is_err() {
            self.poisoned = true;
        }
        r
    }

    /// One resident-latent step for a lone session: embed, both branch
    /// sweeps, fused `cfg_combine` → fused sampler step. No latent byte
    /// crosses the bus.
    fn step_device_single(&mut self, observer: Option<&mut dyn StepObserver>) -> Result<()> {
        let t_step = Instant::now();
        let step = self.cursor;

        // A session left stacked by a shrunken cohort owns its lane again.
        if let Latent::DeviceStacked { .. } = &self.latent {
            let own = match &self.latent {
                Latent::DeviceStacked { stack, lane } => {
                    let rt = self.model.runtime();
                    rt.lane(stack.dims(), *lane)?.run(&[stack.as_ref()])?
                }
                _ => unreachable!("matched above"),
            };
            self.latent = Latent::DeviceOwn(own);
        }

        let c = self
            .gear
            .as_ref()
            .ok_or_else(|| anyhow!("device step on a host session"))?
            .c_steps[step]
            .clone();
        let (actions0, actions1, decisions) = self.plan_step();

        let x = match &self.latent {
            Latent::DeviceOwn(t) => t,
            _ => return Err(anyhow!("device step without a resident latent")),
        };
        let h0 = Arc::new(self.model.embed(x)?);

        let (oc, ou) = match &mut self.exec {
            Exec::Workers(ws) => {
                if observer.is_some() {
                    return Err(anyhow!("observer runs require an inline session"));
                }
                // Feed both workers before waiting so the branches overlap.
                ws[0].send((step, c.clone(), h0.clone(), actions0))?;
                ws[1].send((step, c.clone(), h0.clone(), actions1))?;
                (ws[0].recv()?, ws[1].recv()?)
            }
            Exec::Inline { caches, mirrors } => {
                let ctx = StepCtx {
                    step,
                    granularity: self.rp.granularity,
                    cache_mode: self.rp.cache_mode,
                    needs_measure: self.rp.needs_measure,
                    c: &c,
                    h0: &h0,
                    lms: &self.lms,
                };
                let [cache_c, cache_u] = caches;
                let [mir_c, mir_u] = mirrors;
                let mut observer = observer;
                let oc = sweep_branch(
                    &self.model,
                    self.hot_path,
                    &ctx,
                    0,
                    &self.branches[0],
                    &actions0,
                    cache_c,
                    mir_c,
                    observer.as_deref_mut(),
                )?;
                let ou = sweep_branch(
                    &self.model,
                    self.hot_path,
                    &ctx,
                    1,
                    &self.branches[1],
                    &actions1,
                    cache_u,
                    mir_u,
                    observer.as_deref_mut(),
                )?;
                (oc, ou)
            }
        };

        // eps = uncond + s·(cond − uncond), then the sampler step — both
        // fused over the resident latent.
        let next = {
            let gear = self.gear.as_ref().expect("device gear checked above");
            let eps = gear.cfg_exec.run(&[&ou.eps, &oc.eps, &gear.cfg_scale_dev])?;
            let x = match &self.latent {
                Latent::DeviceOwn(t) => t,
                _ => unreachable!("materialized above"),
            };
            self.smp
                .step_device(&gear.stepper, x, &eps, &gear.coeffs[step])?
        };
        self.latent = Latent::DeviceOwn(next);

        self.absorb(&oc, &ou, decisions);
        self.stats.per_step_s.push(t_step.elapsed().as_secs_f64());
        self.cursor += 1;
        Ok(())
    }

    /// One seed-era host-staged step, kept verbatim as the A/B oracle:
    /// per-step latent upload, sequential branches, both epsilons
    /// downloaded, host CFG combine, host sampler step.
    fn step_host(&mut self, observer: Option<&mut dyn StepObserver>) -> Result<()> {
        let t_step = Instant::now();
        let step = self.cursor;
        let rt = self.model.runtime().clone();

        let c = Arc::new(self.model.t_embed(self.smp.t_value(step))?);
        self.stats.h2d_bytes += 4;
        self.stats.h2d_calls += 1;
        let x_dev = match &self.latent {
            Latent::Host(x) => rt.upload(x, &self.dims)?,
            _ => return Err(anyhow!("host step on a device session")),
        };
        self.stats.h2d_bytes += (self.latent_elems * 4) as u64;
        self.stats.h2d_calls += 1;
        let h0 = Arc::new(self.model.embed(&x_dev)?);

        let (actions0, actions1, decisions) = self.plan_step();
        let ctx = StepCtx {
            step,
            granularity: self.rp.granularity,
            cache_mode: self.rp.cache_mode,
            needs_measure: self.rp.needs_measure,
            c: &c,
            h0: &h0,
            lms: &self.lms,
        };
        let Exec::Inline { caches, mirrors } = &mut self.exec else {
            return Err(anyhow!("host sessions run inline"));
        };
        let [cache_c, cache_u] = caches;
        let [mir_c, mir_u] = mirrors;
        let mut observer = observer;
        let oc = sweep_branch(
            &self.model,
            HotPath::Host,
            &ctx,
            0,
            &self.branches[0],
            &actions0,
            cache_c,
            mir_c,
            observer.as_deref_mut(),
        )?;
        let ou = sweep_branch(
            &self.model,
            HotPath::Host,
            &ctx,
            1,
            &self.branches[1],
            &actions1,
            cache_u,
            mir_u,
            observer.as_deref_mut(),
        )?;

        // Host CFG combine: eps = uncond + s·(cond − uncond).
        let mut eps_cond = vec![0.0f32; self.latent_elems];
        let mut eps = vec![0.0f32; self.latent_elems];
        rt.download_into(&oc.eps, &mut eps_cond)?;
        rt.download_into(&ou.eps, &mut eps)?;
        self.stats.d2h_bytes += 2 * (self.latent_elems * 4) as u64;
        self.stats.d2h_calls += 2;
        for i in 0..self.latent_elems {
            eps[i] += self.rp.cfg_scale * (eps_cond[i] - eps[i]);
        }
        let Latent::Host(x_host) = &mut self.latent else {
            unreachable!("checked above");
        };
        self.smp.step(x_host, &eps, step);

        self.absorb(&oc, &ou, decisions);
        self.stats.per_step_s.push(t_step.elapsed().as_secs_f64());
        self.cursor += 1;
        Ok(())
    }

    /// Download the final latent (exactly once), recover the branch
    /// caches from the workers, and assemble the [`RunResult`]. Valid at
    /// any cursor (the scheduler only calls it on done sessions).
    pub fn finish(mut self) -> Result<RunResult> {
        let rt = self.model.runtime().clone();
        let layers = self.model.info.layers;

        let x: Vec<f32> = match std::mem::replace(&mut self.latent, Latent::Host(Vec::new())) {
            Latent::DeviceOwn(t) => {
                let mut out = vec![0.0f32; self.latent_elems];
                rt.download_into(&t, &mut out)?;
                self.stats.d2h_bytes += (self.latent_elems * 4) as u64;
                self.stats.d2h_calls += 1;
                out
            }
            Latent::DeviceStacked { stack, lane } => {
                let t = rt.lane(stack.dims(), lane)?.run(&[stack.as_ref()])?;
                let mut out = vec![0.0f32; self.latent_elems];
                rt.download_into(&t, &mut out)?;
                self.stats.d2h_bytes += (self.latent_elems * 4) as u64;
                self.stats.d2h_calls += 1;
                out
            }
            Latent::Host(x) => x,
        };
        self.stats.wall_s = self.t_start.elapsed().as_secs_f64();

        let (cache_bytes, entries) = match &mut self.exec {
            Exec::Workers(ws) => {
                let cc = ws[0].shutdown()?;
                let cu = ws[1].shutdown()?;
                (
                    cc.peak_bytes() + cu.peak_bytes(),
                    cc.entries_per_layer(layers).max(cu.entries_per_layer(layers)),
                )
            }
            Exec::Inline { caches, mirrors } => {
                // Host mirrors count toward the measured footprint (they
                // stay empty under HotPath::Device).
                let mirror_bytes: usize = mirrors
                    .iter()
                    .map(|mm| mm.values().map(|v| v.len() * 4).sum::<usize>())
                    .sum();
                (
                    caches.iter().map(|c| c.peak_bytes()).sum::<usize>() + mirror_bytes,
                    caches
                        .iter()
                        .map(|c| c.entries_per_layer(layers))
                        .fold(0.0, f64::max),
                )
            }
        };
        self.stats.cache_peak_bytes = cache_bytes;
        self.stats.cache_entries_per_layer = entries;

        let [f, p, c_lat] = self.dims;
        // λ per branch-0 site index, aligned with `reuse_map` rows (the
        // server's `reuse_timeline` echo joins the two by index).
        let site_lambdas = self.policy.thresholds().map(|t| {
            self.sites[0]
                .iter()
                .map(|s| t.get(&(s.layer, s.kind, s.branch)).copied().unwrap_or(-1.0))
                .collect()
        });
        Ok(RunResult {
            latents: HostTensor::new(vec![f, p, c_lat], x),
            stats: std::mem::take(&mut self.stats),
            reuse_map: std::mem::take(&mut self.reuse_map),
            thresholds: self.policy.thresholds(),
            site_lambdas,
        })
    }

    /// Move this in-flight session to another device replica (work
    /// stealing at a step boundary — see the server scheduler docs).
    ///
    /// `target` must serve the same (model, bucket) from a *different*
    /// runtime. The resident lane latent is downloaded on the source and
    /// uploaded on the target — exactly one extra lane download + upload
    /// charged to [`RunStats`], the only deviation from the standalone
    /// byte model a migration introduces. Everything else is
    /// request-constant state, rebuilt or round-tripped outside the
    /// per-request meter (each runtime's `TransferStats` still records
    /// the true bus traffic): cached features move device→host→device
    /// bit-exactly with their accounting (peak/stores/hits) carried over,
    /// text conditioning is recomputed from the stored prompt, and the
    /// sampler gear is rebuilt from the sampler's own coefficients — so
    /// every subsequent decision, drift measurement and latent byte is
    /// identical to a never-migrated run (f32 round-trips are lossless).
    ///
    /// Any failure mid-transfer poisons the session (state may be split
    /// across devices); callers must drop it and answer the client.
    pub fn migrate(&mut self, target: &Engine) -> Result<()> {
        if self.poisoned {
            return Err(anyhow!("migrate on a session poisoned by an earlier error"));
        }
        if self.is_done() {
            return Err(anyhow!("migrate on a finished session"));
        }
        let dst_m = target.model.clone();
        if Arc::ptr_eq(&self.model, &dst_m) {
            return Err(anyhow!("migrate to the session's own device"));
        }
        if self.hot_path != HotPath::Device || target.hot_path != HotPath::Device {
            return Err(anyhow!("migration requires device-resident sessions"));
        }
        if !matches!(self.exec, Exec::Workers(_)) {
            return Err(anyhow!("migration requires parallel branch workers"));
        }
        if dst_m.info.name != self.model.info.name {
            return Err(anyhow!(
                "migrate across models: {} -> {}",
                self.model.info.name,
                dst_m.info.name
            ));
        }
        let [f, p, _d] = dst_m.state_dims();
        let [_, _, c_lat] = dst_m.latent_dims();
        if [f, p, c_lat] != self.dims {
            return Err(anyhow!("migrate across shape buckets"));
        }
        if dst_m.info.sampler != self.smp.kind() {
            return Err(anyhow!("migrate across sampler families"));
        }
        let r = self.migrate_inner(dst_m);
        if r.is_err() {
            self.poisoned = true;
        }
        r
    }

    fn migrate_inner(&mut self, dst_m: Arc<LoadedModel>) -> Result<()> {
        let src_rt = self.model.runtime().clone();
        let dst_rt = dst_m.runtime().clone();
        let info = &dst_m.info;

        // 1. Lane latent source→host: the metered lane download. A lane
        //    still stacked in a cohort tensor is extracted first (pure
        //    device data movement).
        let mut x_host = vec![0.0f32; self.latent_elems];
        match std::mem::replace(&mut self.latent, Latent::Host(Vec::new())) {
            Latent::DeviceOwn(t) => src_rt.download_into(&t, &mut x_host)?,
            Latent::DeviceStacked { stack, lane } => {
                let t = src_rt.lane(stack.dims(), lane)?.run(&[stack.as_ref()])?;
                src_rt.download_into(&t, &mut x_host)?;
            }
            Latent::Host(_) => return Err(anyhow!("migrate on a host-resident session")),
        }
        self.stats.d2h_bytes += (self.latent_elems * 4) as u64;
        self.stats.d2h_calls += 1;

        // 2. Recover the branch caches and round-trip every entry onto
        //    the target (bit-exact; accounting carried over).
        let (cache_c, cache_u) = match &mut self.exec {
            Exec::Workers(ws) => (ws[0].shutdown()?, ws[1].shutdown()?),
            Exec::Inline { .. } => unreachable!("validated by migrate"),
        };
        let cache_c = transfer_cache(&src_rt, &dst_rt, cache_c)?;
        let cache_u = transfer_cache(&src_rt, &dst_rt, cache_u)?;

        // 3. Recompute text conditioning on the target from the stored
        //    prompt (deterministic embedding + the target's identical
        //    weights ⇒ identical K/V).
        let cond_raw = workload::embed_prompt(&self.prompt, info.d_text, info.text_len);
        let uncond_raw = HostTensor::zeros(vec![info.text_len, info.d_text]);
        let rc = branch_ctx(&dst_m, &cond_raw)?;
        let ru = branch_ctx(&dst_m, &uncond_raw)?;
        self.branches = [Arc::new(rc), Arc::new(ru)];

        // 4. Rebuild the device gear: every t-value and step coefficient
        //    is recoverable from the sampler, so nothing numeric survives
        //    from the source copies.
        let cfg_exec = dst_rt.cfg_combine(&self.dims)?;
        let cfg_scale_dev = dst_rt.upload(&[self.rp.cfg_scale], &[])?;
        let stepper = DeviceStepper::new(&dst_rt, self.smp.kind(), &self.dims)?;
        let t_values: Vec<f32> = (0..self.rp.steps).map(|i| self.smp.t_value(i)).collect();
        let c_steps = dst_m.t_embeds(&t_values)?;
        let mut coeffs = Vec::with_capacity(self.rp.steps);
        for i in 0..self.rp.steps {
            coeffs.push(stepper.upload_coeffs(&self.smp.step_coeffs(i))?);
        }
        self.gear = Some(DeviceGear { stepper, cfg_exec, cfg_scale_dev, c_steps, coeffs });

        // Predictor coefficients are request-constant gear too: rebuilt on
        // the target from the same fixed formula, outside the per-request
        // meter (the admit-time charge already covered them once).
        self.lms = Vec::new();
        if self.rp.history_depth >= 2 {
            for c in lms_coefficients(self.rp.history_depth)? {
                self.lms.push(Arc::new(dst_rt.upload(&[c], &[])?));
            }
        }

        // 5. Latent host→target: the metered lane upload.
        let x_dev = dst_rt.upload(&x_host, &self.dims)?;
        self.stats.h2d_bytes += (self.latent_elems * 4) as u64;
        self.stats.h2d_calls += 1;
        self.latent = Latent::DeviceOwn(x_dev);

        // 6. Fresh workers on the target, seeded with the moved caches.
        self.exec = Exec::Workers([
            BranchWorker::spawn_with_cache(
                dst_m.clone(),
                self.branches[0].clone(),
                0,
                self.rp,
                self.trace_id,
                self.lms.clone(),
                cache_c,
            ),
            BranchWorker::spawn_with_cache(
                dst_m.clone(),
                self.branches[1].clone(),
                1,
                self.rp,
                self.trace_id,
                self.lms.clone(),
                cache_u,
            ),
        ]);
        self.model = dst_m;
        Ok(())
    }
}

/// Round-trip every cache entry `src`→host→`dst` (f32-lossless), restoring
/// into a fresh cache that adopts the predecessor's lifetime accounting.
/// Metered only by the runtimes' `TransferStats` — a migration is
/// infrastructure traffic, not part of the request's standalone byte model.
fn transfer_cache(src: &Runtime, dst: &Runtime, mut cache: FeatureCache) -> Result<FeatureCache> {
    let mut out = FeatureCache::with_history(cache.history_depth());
    for (key, entry) in cache.drain_entries() {
        let mut host = vec![0.0f32; entry.device.element_count()];
        src.download_into(&entry.device, &mut host)?;
        let dev = Arc::new(dst.upload(&host, entry.device.dims())?);
        out.restore(key, dev, entry.step);
    }
    // History rings ride along oldest-first so the target's rings replay
    // the source's exactly (a forecast after the hop sees identical h₀..hₖ).
    for (key, ring) in cache.drain_history() {
        for (t, step) in ring {
            let mut host = vec![0.0f32; t.element_count()];
            src.download_into(&t, &mut host)?;
            let dev = Arc::new(dst.upload(&host, t.dims())?);
            out.restore_history(key, dev, step);
        }
    }
    out.adopt_accounting(&cache);
    Ok(out)
}

/// Advance every session in the slice one step as one cohort (see module
/// docs §Cohort stepping). All sessions must share the loaded model and
/// sampler family, be device-resident with parallel workers, and not be
/// done; step counts, cursors, CFG scales and policies may differ freely.
///
/// An error **poisons every session in the cohort** (a partially-executed
/// step may have advanced caches and policy state past the cursors):
/// poisoned sessions refuse further steps, so callers must drop them.
pub fn step_many<'p>(sessions: &mut [Session<'p>]) -> Result<StepReport> {
    let mut refs: Vec<&mut Session<'p>> = sessions.iter_mut().collect();
    step_many_refs(&mut refs)
}

/// [`step_many`] over a slice of mutable session references (the form the
/// server's scheduler uses, where sessions live inside per-lane state).
pub fn step_many_refs<'p>(sessions: &mut [&mut Session<'p>]) -> Result<StepReport> {
    let r = step_many_inner(sessions);
    if r.is_err() {
        for s in sessions.iter_mut() {
            s.poisoned = true;
        }
    }
    r
}

fn step_many_inner<'p>(sessions: &mut [&mut Session<'p>]) -> Result<StepReport> {
    if sessions.is_empty() {
        return Err(anyhow!("step_many on an empty cohort"));
    }
    if sessions.len() == 1 {
        let restacked = matches!(sessions[0].latent, Latent::DeviceStacked { .. });
        sessions[0].step(None)?;
        return Ok(StepReport { occupancy: 1, restacked });
    }

    let nb = sessions.len();
    let model = sessions[0].model.clone();
    let dims = sessions[0].dims;
    let kind = sessions[0].smp.kind();
    for s in sessions.iter() {
        if !Arc::ptr_eq(&s.model, &model) {
            return Err(anyhow!("step_many: sessions must share one loaded model"));
        }
        if s.dims != dims {
            return Err(anyhow!("step_many: sessions must share one shape bucket"));
        }
        if s.smp.kind() != kind {
            return Err(anyhow!("step_many: sessions must share a sampler family"));
        }
        if s.hot_path != HotPath::Device || s.gear.is_none() {
            return Err(anyhow!("step_many: sessions must be device-resident"));
        }
        if !matches!(s.exec, Exec::Workers(_)) {
            return Err(anyhow!(
                "step_many: sessions must use parallel branch workers (no observer)"
            ));
        }
        if s.is_done() {
            return Err(anyhow!("step_many: session already finished its schedule"));
        }
        if s.poisoned {
            return Err(anyhow!("step_many: session poisoned by an earlier step error"));
        }
    }

    let rt = model.runtime().clone();
    let [f, p, c_lat] = dims;
    let bdims = [nb, f, p, c_lat];
    let t_step = Instant::now();

    // --- (re)assemble the resident stack ------------------------------
    // Unchanged membership: reuse the stacked tensor from the previous
    // step. Shrunken/reordered cohort over the same stack: one fused
    // regroup dispatch. Otherwise (joins, fresh cohort): restack from
    // lane tensors via the stack/lane ops.
    // The shared stack (and each member's lane) when every session sits
    // in the same stacked tensor; None as soon as any session owns its
    // latent or sits in a different stack.
    let same_stack: Option<(Arc<DeviceTensor>, Vec<usize>)> = match &sessions[0].latent {
        Latent::DeviceStacked { stack, .. } => {
            let st = stack.clone();
            sessions
                .iter()
                .map(|s| match &s.latent {
                    Latent::DeviceStacked { stack, lane } if Arc::ptr_eq(stack, &st) => {
                        Some(*lane)
                    }
                    _ => None,
                })
                .collect::<Option<Vec<usize>>>()
                .map(|lanes| (st, lanes))
        }
        _ => None,
    };
    let (stack_arc, restacked): (Arc<DeviceTensor>, bool) = if let Some((st, lanes)) = same_stack
    {
        if st.dims()[0] == nb && lanes.iter().enumerate().all(|(i, &l)| l == i) {
            // Membership unchanged since the previous step: reuse as-is.
            (st, false)
        } else {
            // Shrunken/permuted cohort over one stack: one fused
            // compaction dispatch.
            let compacted = rt.regroup(st.dims(), &lanes)?.run(&[st.as_ref()])?;
            (Arc::new(compacted), true)
        }
    } else {
        let mut extracted: Vec<Option<DeviceTensor>> = Vec::with_capacity(nb);
        for s in sessions.iter() {
            extracted.push(match &s.latent {
                Latent::DeviceStacked { stack, lane } => {
                    Some(rt.lane(stack.dims(), *lane)?.run(&[stack.as_ref()])?)
                }
                Latent::DeviceOwn(_) => None,
                Latent::Host(_) => {
                    return Err(anyhow!("step_many: host session in a device cohort"))
                }
            });
        }
        let refs: Vec<&DeviceTensor> = sessions
            .iter()
            .zip(&extracted)
            .map(|(s, e)| match (&s.latent, e) {
                (Latent::DeviceOwn(t), _) => t,
                (_, Some(t)) => t,
                _ => unreachable!("stacked lanes were extracted above"),
            })
            .collect();
        (Arc::new(rt.stack(&dims, nb)?.run(&refs)?), true)
    };

    // --- per-lane patch embeddings from the stacked latent ------------
    let mut h0s = Vec::with_capacity(nb);
    for i in 0..nb {
        let xi = rt.lane(&bdims, i)?.run(&[stack_arc.as_ref()])?;
        h0s.push(Arc::new(model.embed(&xi)?));
    }

    // --- dispatch all 2B branch sweeps, then collect in lane order ----
    let mut decisions_all: Vec<Vec<StepDecision>> = Vec::with_capacity(nb);
    for (i, s) in sessions.iter_mut().enumerate() {
        let step = s.cursor;
        let c = s.gear.as_ref().expect("validated device gear").c_steps[step].clone();
        let (actions0, actions1, decisions) = s.plan_step();
        decisions_all.push(decisions);
        let Exec::Workers(ws) = &mut s.exec else {
            unreachable!("validated workers");
        };
        ws[0].send((step, c.clone(), h0s[i].clone(), actions0))?;
        ws[1].send((step, c, h0s[i].clone(), actions1))?;
    }
    let mut eps_c: Vec<DeviceTensor> = Vec::with_capacity(nb);
    let mut eps_u: Vec<DeviceTensor> = Vec::with_capacity(nb);
    for (i, s) in sessions.iter_mut().enumerate() {
        let (oc, ou) = {
            let Exec::Workers(ws) = &mut s.exec else {
                unreachable!("validated workers");
            };
            (ws[0].recv()?, ws[1].recv()?)
        };
        s.absorb(&oc, &ou, std::mem::take(&mut decisions_all[i]));
        eps_c.push(oc.eps);
        eps_u.push(ou.eps);
    }

    // --- one fused multi-lane advance ---------------------------------
    // Per-lane scalars: each session's CFG scale and the coefficients at
    // each session's own cursor — mixed schedules share the dispatch.
    let stack_exec = rt.stack(&dims, nb)?;
    let u_refs: Vec<&DeviceTensor> = eps_u.iter().collect();
    let c_refs: Vec<&DeviceTensor> = eps_c.iter().collect();
    let u_stack = stack_exec.run(&u_refs)?;
    let c_stack = stack_exec.run(&c_refs)?;
    let new_stack = {
        let mut args: Vec<&DeviceTensor> = vec![stack_arc.as_ref(), &u_stack, &c_stack];
        for s in sessions.iter() {
            let gear = s.gear.as_ref().expect("validated device gear");
            args.push(&gear.cfg_scale_dev);
            for t in gear.coeffs[s.cursor].scalars() {
                args.push(t);
            }
        }
        let exec = match kind {
            SamplerKind::Rflow => rt.cohort_rflow_step(&dims, nb)?,
            SamplerKind::Ddim => {
                let (lo, hi) = sessions[0]
                    .gear
                    .as_ref()
                    .expect("validated device gear")
                    .stepper
                    .clamp_bounds()
                    .ok_or_else(|| anyhow!("ddim stepper missing clamp bounds"))?;
                args.push(lo);
                args.push(hi);
                rt.cohort_ddim_step(&dims, nb)?
            }
        };
        Arc::new(exec.run(&args)?)
    };

    let dt = t_step.elapsed().as_secs_f64();
    for (i, s) in sessions.iter_mut().enumerate() {
        s.latent = Latent::DeviceStacked { stack: new_stack.clone(), lane: i };
        s.stats.per_step_s.push(dt);
        s.cursor += 1;
        s.peak_lanes = s.peak_lanes.max(nb);
    }
    Ok(StepReport { occupancy: nb, restacked })
}

/// Borrow-bridging adapter: lets `Engine::generate`/`generate_batch` keep
/// their `&mut dyn ReusePolicy` signatures while sessions own a boxed
/// policy — every call forwards to (and mutates) the caller's instance.
pub(crate) struct PolicyShim<'a>(pub(crate) &'a mut dyn ReusePolicy);

impl ReusePolicy for PolicyShim<'_> {
    fn name(&self) -> String {
        self.0.name()
    }
    fn granularity(&self) -> Granularity {
        self.0.granularity()
    }
    fn cache_mode(&self) -> CacheMode {
        self.0.cache_mode()
    }
    fn needs_measurement(&self) -> bool {
        self.0.needs_measurement()
    }
    fn history_depth(&self) -> usize {
        self.0.history_depth()
    }
    fn begin_request(&mut self, layers: usize, steps: usize) {
        self.0.begin_request(layers, steps)
    }
    fn action(&mut self, step: usize, site: Site) -> Action {
        self.0.action(step, site)
    }
    fn observe_mse(&mut self, step: usize, site: Site, mse: f64) {
        self.0.observe_mse(step, site, mse)
    }
    fn thresholds(&self) -> Option<BTreeMap<(usize, BlockKind, usize), f64>> {
        self.0.thresholds()
    }
}

/// Execute one CFG branch of one step: every (layer, kind[, sublayer])
/// site in order — driven by the precomputed `actions` — then the final
/// projection to this branch's epsilon. Drift MSEs are *collected*, not
/// fed to the policy (the coordinator applies them after the join).
#[allow(clippy::too_many_arguments)]
fn sweep_branch(
    m: &LoadedModel,
    hot_path: HotPath,
    ctx: &StepCtx<'_>,
    branch: usize,
    bctx: &BranchCtx,
    actions: &[Action],
    cache: &mut FeatureCache,
    mirror: &mut HostMirror,
    mut observer: Option<&mut dyn StepObserver>,
) -> Result<BranchOut> {
    let info = &m.info;
    let mut h = ctx.h0.clone();
    let mut bs = BranchStats::default();
    let mut observations: Vec<(Site, f64)> = Vec::new();
    let mut obs_scratch: Vec<f32> = Vec::new();
    let mut ai = 0usize;
    for layer in 0..info.layers {
        for kind in BlockKind::ALL {
            let (tk, tv) = &bctx.text_kv[layer][kind.index()];
            match ctx.granularity {
                Granularity::Coarse => {
                    let site = Site { layer, kind, unit: Unit::Block, branch };
                    let action = *actions
                        .get(ai)
                        .ok_or_else(|| anyhow!("branch action list too short"))?;
                    ai += 1;
                    h = apply_coarse(
                        m,
                        hot_path,
                        ctx,
                        site,
                        action,
                        h,
                        tk,
                        tv,
                        cache,
                        mirror,
                        &mut observations,
                        &mut bs,
                    )?;
                }
                Granularity::Fine => {
                    for sub in SubUnit::ALL {
                        let site = Site { layer, kind, unit: Unit::Sub(sub), branch };
                        let action = *actions
                            .get(ai)
                            .ok_or_else(|| anyhow!("branch action list too short"))?;
                        ai += 1;
                        h = apply_fine(m, ctx, site, action, h, tk, tv, cache, &mut bs)?;
                    }
                }
            }
            if let Some(obs) = observer.as_deref_mut() {
                if obs.wants_branch(branch) {
                    obs_scratch.resize(h.element_count(), 0.0);
                    m.runtime().download_into(&h, &mut obs_scratch)?;
                    bs.d2h_bytes += (obs_scratch.len() * 4) as u64;
                    bs.d2h_calls += 1;
                    obs.on_block(ctx.step, layer, kind, &obs_scratch);
                }
            }
        }
    }
    if ai != actions.len() {
        return Err(anyhow!(
            "branch action list length mismatch: {} given, {} consumed",
            actions.len(),
            ai
        ));
    }
    let eps = m.final_proj(&h, ctx.c)?;
    Ok(BranchOut { eps, stats: bs, observations })
}

/// Execute / reuse one coarse (whole-block) site.
#[allow(clippy::too_many_arguments)]
fn apply_coarse(
    m: &LoadedModel,
    hot_path: HotPath,
    ctx: &StepCtx<'_>,
    site: Site,
    action: Action,
    h: Arc<DeviceTensor>,
    tk: &Arc<DeviceTensor>,
    tv: &Arc<DeviceTensor>,
    cache: &mut FeatureCache,
    mirror: &mut HostMirror,
    observations: &mut Vec<(Site, f64)>,
    bs: &mut BranchStats,
) -> Result<Arc<DeviceTensor>> {
    let key =
        CacheKey { branch: site.branch, layer: site.layer, kind: site.kind, unit: site.unit };

    let effective = match action {
        Action::Reuse | Action::ReuseResidual | Action::Predict { .. }
            if !cache.contains(&key) =>
        {
            bs.fallback += 1;
            Action::Compute { update_cache: true, measure: ctx.needs_measure }
        }
        a => a,
    };

    match effective {
        Action::Reuse => {
            bs.reused += 1;
            let e = cache.get(&key).expect("checked above");
            Ok(e.device.clone())
        }
        Action::Predict { order } => {
            // A Predict step is a reuse step (zero block dispatches, zero
            // transfers): the site's output is extrapolated from its last
            // `order` cached outputs in one fused dispatch against the
            // admit-time coefficient scalars. A ring still shallower than
            // `order` replays the live entry verbatim instead — per site,
            // with its own counter, so PSNR audits can attribute quality.
            bs.reused += 1;
            match cache.last_k(&key, order) {
                Some(hist) if ctx.lms.len() >= order => {
                    bs.forecast += 1;
                    let exe = m.runtime().lms_combine(hist[0].dims(), order)?;
                    let mut args: Vec<&DeviceTensor> =
                        hist.iter().map(|t| t.as_ref()).collect();
                    for c in &ctx.lms[..order] {
                        args.push(c.as_ref());
                    }
                    Ok(Arc::new(exe.run(&args)?))
                }
                _ => {
                    bs.forecast_fallback += 1;
                    let e = cache.get(&key).expect("checked above");
                    Ok(e.device.clone())
                }
            }
        }
        Action::ReuseResidual => {
            bs.reused += 1;
            let delta = cache.get(&key).expect("checked above").device.clone();
            Ok(Arc::new(m.add(&h, &delta)?))
        }
        Action::Compute { update_cache, measure } => {
            bs.computed += 1;
            let out = Arc::new(m.block_full(site.layer, site.kind, &h, ctx.c, tk, tv)?);
            // Drift is only meaningful against a cached *output* (Eq. 6
            // compares features, not residual deltas).
            if measure && ctx.cache_mode == CacheMode::Output {
                match hot_path {
                    HotPath::Device => {
                        // Eq. 5/6 drift as a fused on-device reduction
                        // against the cached activation: 4 bytes down.
                        if let Some(prev) = cache.peek(&key) {
                            let mse = m.state_mse(&out, &prev.device)?;
                            bs.d2h_bytes += 4;
                            bs.d2h_calls += 1;
                            observations.push((site, mse));
                        }
                    }
                    HotPath::Host => {
                        // Seed-era staging: pull the whole activation down
                        // and diff against a host mirror (F·P·D·4 bytes
                        // per measured site).
                        let mut scratch = vec![0.0f32; out.element_count()];
                        m.runtime().download_into(&out, &mut scratch)?;
                        bs.d2h_bytes += (scratch.len() * 4) as u64;
                        bs.d2h_calls += 1;
                        if let Some(prev) = mirror.get(&key) {
                            observations.push((site, mse_f32(&scratch, prev)));
                        }
                        if update_cache {
                            mirror.insert(key, scratch);
                        }
                    }
                }
            }
            if update_cache {
                let dev = match ctx.cache_mode {
                    CacheMode::Output => out.clone(),
                    CacheMode::Delta => Arc::new(m.sub(&out, &h)?),
                };
                cache.put(key, dev, ctx.step);
            }
            Ok(out)
        }
    }
}

/// Execute / reuse one fine (sublayer) site. Fine policies always cache
/// residual deltas.
#[allow(clippy::too_many_arguments)]
fn apply_fine(
    m: &LoadedModel,
    ctx: &StepCtx<'_>,
    site: Site,
    action: Action,
    h: Arc<DeviceTensor>,
    tk: &Arc<DeviceTensor>,
    tv: &Arc<DeviceTensor>,
    cache: &mut FeatureCache,
    bs: &mut BranchStats,
) -> Result<Arc<DeviceTensor>> {
    let Unit::Sub(sub) = site.unit else {
        return Err(anyhow!("fine path requires sub unit"));
    };
    let key =
        CacheKey { branch: site.branch, layer: site.layer, kind: site.kind, unit: site.unit };

    let effective = match action {
        Action::Reuse | Action::ReuseResidual if !cache.contains(&key) => {
            bs.fallback += 1;
            Action::Compute { update_cache: true, measure: false }
        }
        Action::Reuse => Action::ReuseResidual, // fine reuse is delta-based
        Action::Predict { .. } => {
            // Unreachable by construction: the Forecast wrapper rejects
            // fine-grained inners at build time.
            return Err(anyhow!("fine sites cannot forecast (coarse output-mode only)"));
        }
        a => a,
    };

    match effective {
        Action::Reuse | Action::Predict { .. } => {
            unreachable!("mapped away above: fine reuse is delta-based, forecast is coarse-only")
        }
        Action::ReuseResidual => {
            bs.reused += 1;
            let delta = cache.get(&key).expect("checked above").device.clone();
            Ok(Arc::new(m.add(&h, &delta)?))
        }
        Action::Compute { update_cache, .. } => {
            bs.computed += 1;
            let out = Arc::new(match sub {
                SubUnit::Attn => m.block_attn(site.layer, site.kind, &h, ctx.c)?,
                SubUnit::Cross => m.block_cross(site.layer, site.kind, &h, tk, tv)?,
                SubUnit::Mlp => m.block_mlp(site.layer, site.kind, &h, ctx.c)?,
            });
            if update_cache {
                let delta = Arc::new(m.sub(&out, &h)?);
                cache.put(key, delta, ctx.step);
            }
            Ok(out)
        }
    }
}
