//! Generation engine: the denoising loop with per-block reuse decisions.
//!
//! This is where the paper's system comes together. For every request the
//! engine runs `T` denoising steps with classifier-free guidance; at each
//! step, for each (layer, block, CFG-branch) — or sublayer for
//! fine-grained baselines — it asks the [`ReusePolicy`] whether to
//! dispatch the block executable or serve the activation from the
//! [`crate::cache::FeatureCache`]. Reused blocks cost zero FLOPs and zero
//! dispatches; that is the entire speedup mechanism of the paper.
//!
//! # Sessions: one step implementation for every path
//!
//! All denoising is step-driven through [`session::Session`]: a started
//! request holding its resident latent, per-branch feature caches (owned
//! by two persistent, policy-free branch worker threads), its policy
//! state, the precomputed timestep embeddings and sampler coefficients
//! for the whole schedule, and a cursor. [`Engine::generate`] and
//! [`Engine::generate_batch`] are thin drivers: admit → step to
//! completion → finish. [`session::step_many`] advances any set of
//! same-(model, bucket, sampler) sessions one step in **one fused device
//! pass** — sessions carry their own CFG scalar and schedule cursor, so
//! requests with different step counts and CFG scales can share a pass;
//! the server's continuous scheduler admits and retires lanes at step
//! boundaries. The lockstep [`Engine::generate_batch`] survives as the
//! ≤1e-6 equivalence oracle the fig18/fig20 benches and the engine tests
//! drive.
//!
//! # Hot path
//!
//! Under [`HotPath::Device`] the denoising state is **device-resident for
//! the whole request**: the initial latent uploads once at admit, every
//! step feeds `h0 = embed(x)` straight from the resident latent, the CFG
//! combine `uncond + s·(cond − uncond)` and the sampler update (a single
//! `axpy` for rflow Euler, the fused `ddim_step` for DDIM; their
//! multi-lane `cohort_*_step` forms for cohorts) chain as fused
//! executables over device buffers, and the final latent downloads
//! exactly once at [`session::Session::finish`].
//!
//! Request-start uploads (all amortized over the run): the text
//! conditioning, the CFG scale, the DDIM clamp bounds, and — because
//! `t_value(i)` and the step coefficients are known for all steps up
//! front — the per-step timestep scalars and sampler coefficients
//! (4 bytes each). Steady-state per-step bus traffic is therefore **zero
//! latent bytes**; the only recurring transfer is 4 bytes down per
//! measured site for measuring policies (Foresight's Eq. 5/6 drift MSE is
//! a fused on-device reduction against the cached activation), plus
//! observer downloads on analysis runs. This per-session byte model is
//! independent of cohort size — see the `session` module docs.
//!
//! The seed engine instead uploaded the full latent (`F·P·C·4` bytes) and
//! downloaded an epsilon of the same size every step and advanced `x` in
//! a host loop; that staging survives as [`HotPath::Host`] (an
//! inline-sequential session) so `benches/fig17_resident.rs` and
//! `benches/fig16_hotpath.rs` can A/B the two pipelines — final latents
//! agree to ≤1e-6 per element, decisions identically.
//!
//! # Branch parallelism without a policy lock
//!
//! Each device session owns one persistent worker thread per CFG branch,
//! fed per step over a channel. The workers never touch the policy:
//! decisions for step `t` depend only on observations from steps `< t`
//! and policy state is keyed per (layer, kind, branch), so the
//! coordinator precomputes both branches' actions before dispatch and
//! applies the returned drift observations after the join — the same
//! decisions as any branch interleaving, with zero locking on the sweep
//! path. Each branch owns its own cache (keys are branch-disjoint). Text
//! K/V precompute parallelizes the same way at admit. When a
//! [`StepObserver`] is attached (analysis runs) the session drops to
//! inline sequential branches so callbacks arrive in the deterministic
//! seed order.
//!
//! Other hot-path properties (EXPERIMENTS.md §Perf):
//! * text K/V are precomputed once per request per (layer, kind, branch);
//! * the patch embedding runs once per step per lane, shared across CFG
//!   branches;
//! * every engine-visible transfer is metered in [`RunStats`]
//!   (`h2d_bytes`/`d2h_bytes`), cross-checkable against the runtime's
//!   [`crate::runtime::TransferStats`].

use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::sync::Arc;

use crate::config::ScheduleConfig;
use crate::model::{BlockKind, LoadedModel};
use crate::policy::ReusePolicy;
use crate::runtime::HostTensor;

pub mod session;

pub use session::{step_many, step_many_refs, Session, StepReport};
use session::PolicyShim;

/// One generation request.
#[derive(Debug, Clone)]
pub struct Request {
    pub prompt: String,
    pub seed: u64,
    /// Override the preset's step count (paper ablations use T=60).
    pub steps: Option<usize>,
    /// Override the preset's CFG scale.
    pub cfg_scale: Option<f64>,
    /// Request span for the event tracer ([`crate::trace`]); 0 (the
    /// default) leaves the session's events unattributed.
    pub trace_id: u64,
}

impl Request {
    pub fn new(prompt: &str, seed: u64) -> Self {
        Self { prompt: prompt.to_string(), seed, steps: None, cfg_scale: None, trace_id: 0 }
    }
}

/// Where the denoising state lives and per-step reductions execute.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum HotPath {
    /// Device-resident (default): the latent uploads once per request,
    /// sampler steps / CFG combine / drift MSE run as fused executables,
    /// the final latent downloads once, and the CFG branches run on
    /// persistent worker threads.
    #[default]
    Device,
    /// Seed-era staging: per-step latent upload, full activation
    /// downloads for measurement, both branch epsilons downloaded, host
    /// combine and host sampler loop, sequential branches. Kept for A/B
    /// benchmarking (`fig16_hotpath`, `fig17_resident`) and equivalence
    /// tests.
    Host,
}

/// One planned per-site decision class, recorded per step in
/// [`RunResult::reuse_map`] (branch 0, policy site order). `Predict` and
/// `Reuse` are both reuse steps (zero block dispatches); they differ only
/// in what fills the site's output — a linear-multistep forecast over the
/// cached history vs a verbatim replay of the stale entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepDecision {
    /// The site dispatched its block executable.
    Compute,
    /// The site replayed its cached output verbatim.
    Reuse,
    /// The site's output was forecast from its cached history
    /// (`runtime::lms_combine`).
    Predict,
}

impl StepDecision {
    /// Whether this decision skipped the block compute.
    pub fn is_reuse(self) -> bool {
        !matches!(self, StepDecision::Compute)
    }

    /// Stable wire/display name: `compute` / `reuse` / `predict`.
    pub fn name(self) -> &'static str {
        match self {
            StepDecision::Compute => "compute",
            StepDecision::Reuse => "reuse",
            StepDecision::Predict => "predict",
        }
    }
}

/// Counters and timings for one run.
#[derive(Debug, Clone, Default)]
pub struct RunStats {
    pub policy: String,
    pub wall_s: f64,
    pub per_step_s: Vec<f64>,
    pub computed_units: u64,
    pub reused_units: u64,
    /// Reuse decisions that fell back to compute due to a cold cache.
    pub fallback_units: u64,
    /// Reuse units served by linear-multistep forecast (a subset of
    /// `reused_units`): the site's output was extrapolated from its
    /// history ring by one fused `lms_combine` dispatch.
    pub forecast_units: u64,
    /// Planned forecasts that fell back to verbatim replay because the
    /// site's history ring was still shallower than the predictor order
    /// (also counted in `reused_units`; disjoint from `forecast_units`).
    pub forecast_fallback_units: u64,
    pub cache_peak_bytes: usize,
    pub cache_entries_per_layer: f64,
    /// Host→device bytes moved by this run. Under [`HotPath::Device`]:
    /// text, CFG scale, the initial latent, and the per-step scalars
    /// (timesteps + sampler coefficients), all at admit. Under
    /// [`HotPath::Host`]: the full latent every step.
    pub h2d_bytes: u64,
    pub h2d_calls: u64,
    /// Device→host bytes moved by this run. Under [`HotPath::Device`]:
    /// 4-byte drift measurements, observer downloads, and one final
    /// latent. Under [`HotPath::Host`]: both branch epsilons every step
    /// plus full measured activations.
    pub d2h_bytes: u64,
    pub d2h_calls: u64,
}

impl RunStats {
    /// Fraction of reuse-eligible decisions that actually reused.
    pub fn reuse_fraction(&self) -> f64 {
        let total = self.computed_units + self.reused_units;
        if total == 0 {
            0.0
        } else {
            self.reused_units as f64 / total as f64
        }
    }

    /// Mean device→host bytes per denoising step.
    pub fn d2h_bytes_per_step(&self) -> f64 {
        if self.per_step_s.is_empty() {
            0.0
        } else {
            self.d2h_bytes as f64 / self.per_step_s.len() as f64
        }
    }

    /// Mean host→device bytes per denoising step.
    pub fn h2d_bytes_per_step(&self) -> f64 {
        if self.per_step_s.is_empty() {
            0.0
        } else {
            self.h2d_bytes as f64 / self.per_step_s.len() as f64
        }
    }
}

/// Full result of one generation.
pub struct RunResult {
    /// Final denoised latent video [F, P, C].
    pub latents: HostTensor,
    pub stats: RunStats,
    /// Per step, per site (branch 0, policy order): the planned decision
    /// class (Fig. 6). `Reuse` and `Predict` both skip the block compute;
    /// `Predict` fills the site from a linear-multistep forecast instead
    /// of a verbatim replay.
    pub reuse_map: Vec<Vec<StepDecision>>,
    /// Foresight's per-site λ after the run (Fig. 5).
    pub thresholds: Option<BTreeMap<(usize, BlockKind, usize), f64>>,
    /// λ aligned with each `reuse_map` row's site index (branch-0 policy
    /// order); `-1.0` = no threshold recorded for that site, `None` = the
    /// policy records no thresholds at all. Feeds the server's
    /// `reuse_timeline` echo.
    pub site_lambdas: Option<Vec<f64>>,
}

/// Observer hook for the feature-dynamics analyses (Figs. 2/3/11-14):
/// receives host copies of computed block outputs. Attaching an observer
/// switches the session to inline sequential CFG branches so callbacks
/// arrive in deterministic (branch, layer, kind) order.
pub trait StepObserver: Send {
    /// Which CFG branch to observe (downloads are expensive; default cond).
    fn wants_branch(&self, branch: usize) -> bool {
        branch == 0
    }

    fn on_block(&mut self, step: usize, layer: usize, kind: BlockKind, data: &[f32]);
}

/// The generation engine bound to one loaded model variant.
pub struct Engine {
    model: Arc<LoadedModel>,
    schedule: ScheduleConfig,
    hot_path: HotPath,
}

impl Engine {
    pub fn new(model: Arc<LoadedModel>, schedule: ScheduleConfig) -> Self {
        Self::with_hot_path(model, schedule, HotPath::Device)
    }

    /// Engine pinned to a specific hot-path mode (A/B benches, equivalence
    /// tests).
    pub fn with_hot_path(model: Arc<LoadedModel>, schedule: ScheduleConfig, hot_path: HotPath) -> Self {
        Self { model, schedule, hot_path }
    }

    pub fn model(&self) -> &Arc<LoadedModel> {
        &self.model
    }

    pub fn hot_path(&self) -> HotPath {
        self.hot_path
    }

    /// The denoising-schedule constants this engine samples under (the
    /// server validates wire-level step counts against these).
    pub fn schedule(&self) -> &ScheduleConfig {
        &self.schedule
    }

    /// Start a session for `req` owned by `policy` (the server's
    /// continuous scheduler calls this directly; `generate` wraps it for
    /// borrowed policies). Device engines get parallel branch workers;
    /// [`HotPath::Host`] engines get an inline-sequential session.
    pub fn admit<'p>(
        &self,
        req: &Request,
        policy: Box<dyn ReusePolicy + 'p>,
    ) -> Result<Session<'p>> {
        Session::admit_full(self, req, policy, self.hot_path == HotPath::Device)
    }

    /// Run one request under `policy`, optionally streaming block outputs
    /// to `observer`: admit one session, step it to completion, finish.
    pub fn generate(
        &self,
        req: &Request,
        policy: &mut dyn ReusePolicy,
        mut observer: Option<&mut dyn StepObserver>,
    ) -> Result<RunResult> {
        let parallel = observer.is_none() && self.hot_path == HotPath::Device;
        let mut s = Session::admit_full(self, req, Box::new(PolicyShim(policy)), parallel)?;
        while !s.is_done() {
            s.step(observer.as_deref_mut())?;
        }
        s.finish()
    }

    /// Run `B` compatible requests through one lockstep session cohort
    /// (the ≤1e-6 equivalence oracle for the batched pass — see the
    /// `session` module docs). `reqs[i]` is decided by `policies[i]`;
    /// policies may differ per request (per-session state is fully
    /// disjoint), but this lockstep driver requires every request to
    /// resolve to the same step count and CFG scale so all lanes start
    /// and finish together. (The server's continuous scheduler drives
    /// sessions directly and has no such restriction.) Returns one
    /// [`RunResult`] per request, in order.
    ///
    /// Falls back to sequential [`Engine::generate`] calls for `B <= 1`
    /// and under [`HotPath::Host`] (the host staging has no batched
    /// pipeline). Observers are a single-request analysis feature and are
    /// not supported here.
    pub fn generate_batch(
        &self,
        reqs: &[Request],
        policies: &mut [Box<dyn ReusePolicy>],
    ) -> Result<Vec<RunResult>> {
        if reqs.len() != policies.len() {
            return Err(anyhow!(
                "generate_batch: {} requests but {} policies",
                reqs.len(),
                policies.len()
            ));
        }
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        if reqs.len() == 1 || self.hot_path == HotPath::Host {
            let mut out = Vec::with_capacity(reqs.len());
            for (req, policy) in reqs.iter().zip(policies.iter_mut()) {
                out.push(self.generate(req, policy.as_mut(), None)?);
            }
            return Ok(out);
        }

        let info = &self.model.info;
        let steps = reqs[0].steps.unwrap_or(info.steps);
        let cfg_scale = reqs[0].cfg_scale.unwrap_or(info.cfg_scale) as f32;
        for r in reqs.iter().skip(1) {
            if r.steps.unwrap_or(info.steps) != steps {
                return Err(anyhow!(
                    "generate_batch: all requests must agree on steps \
                     (got {} and {})",
                    steps,
                    r.steps.unwrap_or(info.steps)
                ));
            }
            if r.cfg_scale.unwrap_or(info.cfg_scale) as f32 != cfg_scale {
                return Err(anyhow!(
                    "generate_batch: all requests must agree on cfg_scale"
                ));
            }
        }

        let mut sessions: Vec<Session<'_>> = Vec::with_capacity(reqs.len());
        for (req, policy) in reqs.iter().zip(policies.iter_mut()) {
            sessions.push(Session::admit_full(
                self,
                req,
                Box::new(PolicyShim(policy.as_mut())),
                true,
            )?);
        }
        // Identical step counts → strict lockstep: every session crosses
        // every boundary together and they all finish at once.
        while !sessions[0].is_done() {
            session::step_many(&mut sessions)?;
        }
        sessions.into_iter().map(|s| s.finish()).collect()
    }
}
