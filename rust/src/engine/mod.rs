//! Generation engine: the denoising loop with per-block reuse decisions.
//!
//! This is where the paper's system comes together. For every request the
//! engine runs `T` denoising steps with classifier-free guidance; at each
//! step, for each (layer, block, CFG-branch) — or sublayer for fine-grained
//! baselines — it asks the [`ReusePolicy`] whether to dispatch the block
//! executable or serve the activation from the [`FeatureCache`]. Reused
//! blocks cost zero FLOPs and zero dispatches; that is the entire speedup
//! mechanism of the paper.
//!
//! # Hot path
//!
//! Under [`HotPath::Device`] the denoising state is **device-resident for
//! the whole request**: the initial latent uploads once, every step feeds
//! `h0 = embed(x)` straight from the resident latent, the CFG combine
//! `uncond + s·(cond − uncond)` and the sampler update (a single `axpy`
//! for rflow Euler, the fused `ddim_step` for DDIM) chain as fused
//! executables over device buffers, and the final latent downloads exactly
//! once after the last step.
//!
//! Request-start uploads (all amortized over the run): the text
//! conditioning, the CFG scale, the DDIM clamp bounds, and — because
//! `t_value(i)` and the step coefficients are known for all steps up
//! front — the per-step timestep scalars and sampler coefficients
//! (4 bytes each). Steady-state per-step bus traffic is therefore **zero
//! latent bytes**; the only recurring transfer is 4 bytes down per
//! measured site for measuring policies (Foresight's Eq. 5/6 drift MSE is
//! a fused on-device reduction against the cached activation), plus
//! observer downloads on analysis runs.
//!
//! The seed engine instead uploaded the full latent (`F·P·C·4` bytes) and
//! downloaded an epsilon of the same size every step and advanced `x` in a
//! host loop; that staging survives as [`HotPath::Host`] so
//! `benches/fig17_resident.rs` (steady-state traffic ≥100× lower) and
//! `benches/fig16_hotpath.rs` can A/B the two pipelines — final latents
//! agree to ≤1e-6 per element, decisions identically.
//!
//! # Branch parallelism
//!
//! Under [`HotPath::Device`] the uncond CFG branch runs on a **persistent
//! per-request worker thread** fed over a channel (one spawn per request,
//! not per step) while the cond branch runs on the caller's thread. Each
//! branch owns its own [`FeatureCache`] (keys are branch-disjoint) and the
//! policy is consulted through a mutex. Policy state is keyed per (layer,
//! kind, branch), so interleaving the branches never changes a decision —
//! decisions for step `t` depend only on observations from steps `< t`,
//! which both orderings deliver identically. Text K/V precompute
//! parallelizes the same way at request start. When a [`StepObserver`] is
//! attached (analysis runs) the engine drops to sequential branches so
//! observer callbacks arrive in the deterministic seed order.
//!
//! Other hot-path properties (EXPERIMENTS.md §Perf):
//! * text K/V are precomputed once per request per (layer, kind, branch);
//! * the patch embedding runs once per step, shared across CFG branches;
//! * every engine-visible transfer is metered in [`RunStats`]
//!   (`h2d_bytes`/`d2h_bytes`), cross-checkable against the runtime's
//!   [`crate::runtime::TransferStats`].
//!
//! # Micro-batching
//!
//! [`Engine::generate_batch`] runs `B` *compatible* requests (same step
//! count and CFG scale — the server's `BatchKey` guarantees this, the
//! engine re-validates) through **one resident step loop**. Each request
//! keeps its own reuse policy, [`FeatureCache`]s and drift observations,
//! so one request reusing a block while a neighbor recomputes stays
//! correct: the Eq. 5/6 drift MSE reduces **per request** against that
//! request's cached activation, never pooled across the batch.
//!
//! Per-request initial latents upload individually (one call each, as in
//! the sequential path) and are stacked on device into one `[B, F, P, C]`
//! resident tensor ([`crate::runtime::Runtime::stack`]). Per step, each
//! lane is sliced back out ([`crate::runtime::Runtime::lane`]) to feed the
//! fixed-shape patch embedding, the `2B` (lane, CFG-branch) site sweeps
//! run on persistent worker threads, and then a **single** batched
//! `cfg_combine` and a single batched sampler step advance all `B`
//! resident lanes in one dispatch each — the fused-op cache is
//! batch-shape-aware, so these are the same builders at `[B, F, P, C]`.
//! Timestep embeddings, sampler coefficients, the CFG scale and the
//! all-zeros uncond text context upload/precompute once per batch (they
//! are identical across compatible requests); only the cond text context
//! is per-lane.
//!
//! The batched trajectory is elementwise-identical to running each request
//! alone under [`HotPath::Device`] (stack/lane are pure data movement and
//! every batched op is elementwise), so per-request latents agree with the
//! sequential device path to f32 exactness; `benches/fig18_batching.rs`
//! asserts ≤1e-6. **Byte model:** each request's [`RunStats`] reports the
//! cost it would pay standalone (batch-shared scalar uploads are charged
//! to every lane), so per-request budgets stay comparable across batch
//! sizes; the runtime-level [`crate::runtime::TransferStats`] meter shows
//! the true, smaller batched totals — the difference is the amortization
//! win. `wall_s`/`per_step_s` report the whole batch's wall clock (the
//! lanes co-run).

use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

use crate::cache::{CacheKey, FeatureCache, Unit};
use crate::config::ScheduleConfig;
use crate::model::{BlockKind, LoadedModel, SubUnit};
use crate::policy::{Action, CacheMode, Granularity, ReusePolicy, Site};
use crate::runtime::{DeviceTensor, HostTensor};
use crate::sampler::{self, Sampler};
use crate::util::prng::Rng;
use crate::util::stats::mse_f32;
use crate::workload;

/// One generation request.
#[derive(Debug, Clone)]
pub struct Request {
    pub prompt: String,
    pub seed: u64,
    /// Override the preset's step count (paper ablations use T=60).
    pub steps: Option<usize>,
    /// Override the preset's CFG scale.
    pub cfg_scale: Option<f64>,
}

impl Request {
    pub fn new(prompt: &str, seed: u64) -> Self {
        Self { prompt: prompt.to_string(), seed, steps: None, cfg_scale: None }
    }
}

/// Where the denoising state lives and per-step reductions execute.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum HotPath {
    /// Device-resident (default): the latent uploads once per request,
    /// sampler steps / CFG combine / drift MSE run as fused executables,
    /// the final latent downloads once, and the CFG branches run on a
    /// persistent worker thread.
    #[default]
    Device,
    /// Seed-era staging: per-step latent upload, full activation downloads
    /// for measurement, both branch epsilons downloaded, host combine and
    /// host sampler loop, sequential branches. Kept for A/B benchmarking
    /// (`fig16_hotpath`, `fig17_resident`) and equivalence tests.
    Host,
}

/// Counters and timings for one run.
#[derive(Debug, Clone, Default)]
pub struct RunStats {
    pub policy: String,
    pub wall_s: f64,
    pub per_step_s: Vec<f64>,
    pub computed_units: u64,
    pub reused_units: u64,
    /// Reuse decisions that fell back to compute due to a cold cache.
    pub fallback_units: u64,
    pub cache_peak_bytes: usize,
    pub cache_entries_per_layer: f64,
    /// Host→device bytes moved by this run. Under [`HotPath::Device`]:
    /// text, CFG scale, the initial latent, and the per-step scalars
    /// (timesteps + sampler coefficients), all at request start. Under
    /// [`HotPath::Host`]: the full latent every step.
    pub h2d_bytes: u64,
    pub h2d_calls: u64,
    /// Device→host bytes moved by this run. Under [`HotPath::Device`]:
    /// 4-byte drift measurements, observer downloads, and one final
    /// latent. Under [`HotPath::Host`]: both branch epsilons every step
    /// plus full measured activations.
    pub d2h_bytes: u64,
    pub d2h_calls: u64,
}

impl RunStats {
    /// Fraction of reuse-eligible decisions that actually reused.
    pub fn reuse_fraction(&self) -> f64 {
        let total = self.computed_units + self.reused_units;
        if total == 0 {
            0.0
        } else {
            self.reused_units as f64 / total as f64
        }
    }

    /// Mean device→host bytes per denoising step.
    pub fn d2h_bytes_per_step(&self) -> f64 {
        if self.per_step_s.is_empty() {
            0.0
        } else {
            self.d2h_bytes as f64 / self.per_step_s.len() as f64
        }
    }

    /// Mean host→device bytes per denoising step.
    pub fn h2d_bytes_per_step(&self) -> f64 {
        if self.per_step_s.is_empty() {
            0.0
        } else {
            self.h2d_bytes as f64 / self.per_step_s.len() as f64
        }
    }
}

/// Full result of one generation.
pub struct RunResult {
    /// Final denoised latent video [F, P, C].
    pub latents: HostTensor,
    pub stats: RunStats,
    /// Per step, per site (branch 0, policy order): true = reused (Fig. 6).
    pub reuse_map: Vec<Vec<bool>>,
    /// Foresight's per-site λ after the run (Fig. 5).
    pub thresholds: Option<BTreeMap<(usize, BlockKind, usize), f64>>,
}

/// Observer hook for the feature-dynamics analyses (Figs. 2/3/11-14):
/// receives host copies of computed block outputs. Attaching an observer
/// switches the engine to sequential CFG branches so callbacks arrive in
/// deterministic (branch, layer, kind) order.
pub trait StepObserver: Send {
    /// Which CFG branch to observe (downloads are expensive; default cond).
    fn wants_branch(&self, branch: usize) -> bool {
        branch == 0
    }

    fn on_block(&mut self, step: usize, layer: usize, kind: BlockKind, data: &[f32]);
}

/// The generation engine bound to one loaded model variant.
pub struct Engine {
    model: Arc<LoadedModel>,
    schedule: ScheduleConfig,
    hot_path: HotPath,
}

/// Per-branch request context (text conditioning).
struct BranchCtx {
    /// Precomputed cross-attention K/V per (layer, kind-index).
    text_kv: Vec<[(Arc<DeviceTensor>, Arc<DeviceTensor>); 2]>,
}

/// Request-constant knobs shared by the host and device step loops.
#[derive(Clone, Copy)]
struct RunParams {
    steps: usize,
    cfg_scale: f32,
    granularity: Granularity,
    cache_mode: CacheMode,
    needs_measure: bool,
}

/// Step-constant inputs shared by both branch threads.
struct StepCtx<'a> {
    step: usize,
    granularity: Granularity,
    cache_mode: CacheMode,
    needs_measure: bool,
    c: &'a Arc<DeviceTensor>,
    h0: &'a Arc<DeviceTensor>,
}

/// Per-branch counters, merged into [`RunStats`] after the branches join.
#[derive(Debug, Default)]
struct BranchStats {
    computed: u64,
    reused: u64,
    fallback: u64,
    d2h_bytes: u64,
    d2h_calls: u64,
}

impl BranchStats {
    fn merge_into(&self, s: &mut RunStats) {
        s.computed_units += self.computed;
        s.reused_units += self.reused;
        s.fallback_units += self.fallback;
        s.d2h_bytes += self.d2h_bytes;
        s.d2h_calls += self.d2h_calls;
    }
}

/// What one CFG branch produces for one step.
struct BranchRun {
    eps: DeviceTensor,
    decisions: Vec<bool>,
    stats: BranchStats,
}

/// Host mirrors of measured activations ([`HotPath::Host`] only).
type HostMirror = BTreeMap<CacheKey, Vec<f32>>;

/// What the branch worker receives per step: (step, t-embedding, h0).
type BranchJob = (usize, Arc<DeviceTensor>, Arc<DeviceTensor>);

impl Engine {
    pub fn new(model: Arc<LoadedModel>, schedule: ScheduleConfig) -> Self {
        Self::with_hot_path(model, schedule, HotPath::Device)
    }

    /// Engine pinned to a specific hot-path mode (A/B benches, equivalence
    /// tests).
    pub fn with_hot_path(model: Arc<LoadedModel>, schedule: ScheduleConfig, hot_path: HotPath) -> Self {
        Self { model, schedule, hot_path }
    }

    pub fn model(&self) -> &Arc<LoadedModel> {
        &self.model
    }

    pub fn hot_path(&self) -> HotPath {
        self.hot_path
    }

    /// The denoising-schedule constants this engine samples under (the
    /// server validates wire-level step counts against these).
    pub fn schedule(&self) -> &ScheduleConfig {
        &self.schedule
    }

    /// Precompute one branch's text conditioning (projection + per-layer
    /// cross-attention K/V).
    fn branch_ctx(&self, raw: &HostTensor) -> Result<BranchCtx> {
        let m = &self.model;
        let text = Arc::new(m.text_proj(raw)?);
        let mut text_kv = Vec::with_capacity(m.info.layers);
        for layer in 0..m.info.layers {
            let mut pair = Vec::with_capacity(2);
            for kind in BlockKind::ALL {
                let tk = Arc::new(m.text_k(layer, kind, &text)?);
                let tv = Arc::new(m.text_v(layer, kind, &text)?);
                pair.push((tk, tv));
            }
            let pair: [(Arc<DeviceTensor>, Arc<DeviceTensor>); 2] =
                pair.try_into().map_err(|_| anyhow!("kv pair"))?;
            text_kv.push(pair);
        }
        Ok(BranchCtx { text_kv })
    }

    /// Run one request under `policy`, optionally streaming block outputs
    /// to `observer`.
    pub fn generate(
        &self,
        req: &Request,
        policy: &mut dyn ReusePolicy,
        observer: Option<&mut dyn StepObserver>,
    ) -> Result<RunResult> {
        let info = &self.model.info;
        let steps = req.steps.unwrap_or(info.steps);
        let cfg_scale = req.cfg_scale.unwrap_or(info.cfg_scale) as f32;
        let smp = sampler::build(info.sampler, &self.schedule, steps);

        policy.begin_request(info.layers, steps);
        let mut stats = RunStats { policy: policy.name(), ..Default::default() };
        let rp = RunParams {
            steps,
            cfg_scale,
            granularity: policy.granularity(),
            cache_mode: policy.cache_mode(),
            needs_measure: policy.needs_measurement(),
        };

        // --- request-constant conditioning --------------------------------
        // The two branch contexts are independent executable chains, so
        // they precompute concurrently (same thread-safety contract as the
        // per-step branch parallelism).
        let cond_raw = workload::embed_prompt(&req.prompt, info.d_text, info.text_len);
        let uncond_raw = HostTensor::zeros(vec![info.text_len, info.d_text]);
        let (ctx_cond, ctx_uncond) = std::thread::scope(|sc| {
            let hu = sc.spawn(|| self.branch_ctx(&uncond_raw));
            let rc = self.branch_ctx(&cond_raw);
            let ru = match hu.join() {
                Ok(r) => r,
                Err(_) => Err(anyhow!("uncond branch-ctx thread panicked")),
            };
            (rc, ru)
        });
        let branches = [ctx_cond?, ctx_uncond?];
        stats.h2d_bytes += 2 * (info.text_len * info.d_text * 4) as u64;
        stats.h2d_calls += 2;

        match self.hot_path {
            HotPath::Device => self.generate_device(req, rp, smp, branches, policy, observer, stats),
            HotPath::Host => self.generate_host(req, rp, smp, branches, policy, observer, stats),
        }
    }

    /// Run `B` compatible requests through one micro-batched resident step
    /// loop (see module docs §Micro-batching). `reqs[i]` is decided by
    /// `policies[i]`; policies may differ per request (per-lane state is
    /// fully disjoint), but every request must resolve to the same step
    /// count and CFG scale — the quantities baked into the shared batched
    /// executables. Returns one [`RunResult`] per request, in order.
    ///
    /// Falls back to sequential [`Engine::generate`] calls for `B <= 1`
    /// and under [`HotPath::Host`] (the host staging has no batched
    /// pipeline). Observers are a single-request analysis feature and are
    /// not supported here.
    pub fn generate_batch(
        &self,
        reqs: &[Request],
        policies: &mut [Box<dyn ReusePolicy>],
    ) -> Result<Vec<RunResult>> {
        if reqs.len() != policies.len() {
            return Err(anyhow!(
                "generate_batch: {} requests but {} policies",
                reqs.len(),
                policies.len()
            ));
        }
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        if reqs.len() == 1 || self.hot_path == HotPath::Host {
            let mut out = Vec::with_capacity(reqs.len());
            for (req, policy) in reqs.iter().zip(policies.iter_mut()) {
                out.push(self.generate(req, policy.as_mut(), None)?);
            }
            return Ok(out);
        }

        let m = &self.model;
        let info = &m.info;
        let nb = reqs.len();
        let steps = reqs[0].steps.unwrap_or(info.steps);
        let cfg_scale = reqs[0].cfg_scale.unwrap_or(info.cfg_scale) as f32;
        for r in reqs.iter().skip(1) {
            if r.steps.unwrap_or(info.steps) != steps {
                return Err(anyhow!(
                    "generate_batch: all requests must agree on steps \
                     (got {} and {})",
                    steps,
                    r.steps.unwrap_or(info.steps)
                ));
            }
            if r.cfg_scale.unwrap_or(info.cfg_scale) as f32 != cfg_scale {
                return Err(anyhow!(
                    "generate_batch: all requests must agree on cfg_scale"
                ));
            }
        }
        let smp = sampler::build(info.sampler, &self.schedule, steps);
        let rt = m.runtime().clone();
        let [f, p, _d] = m.state_dims();
        let [_, _, c_lat] = m.latent_dims();
        let dims = [f, p, c_lat];
        let bdims = [nb, f, p, c_lat];
        let latent_elems = f * p * c_lat;

        // Per-lane decision state + run params + as-if-standalone stats
        // (see module docs §Micro-batching for the byte model).
        let mut statses: Vec<RunStats> = Vec::with_capacity(nb);
        let mut rps: Vec<RunParams> = Vec::with_capacity(nb);
        for policy in policies.iter_mut() {
            policy.begin_request(info.layers, steps);
            statses.push(RunStats { policy: policy.name(), ..Default::default() });
            rps.push(RunParams {
                steps,
                cfg_scale,
                granularity: policy.granularity(),
                cache_mode: policy.cache_mode(),
                needs_measure: policy.needs_measurement(),
            });
        }

        // Text conditioning: the cond context is per-lane (per-prompt); the
        // uncond context is the same all-zeros embedding for every request,
        // so ONE shared context serves the whole batch (its K/V tensors are
        // read-only Arcs) and precomputes concurrently with the cond
        // chain. Each lane is still charged the standalone two text
        // uploads (the as-if byte model; the runtime meter records the
        // single shared upload).
        let uncond_raw = HostTensor::zeros(vec![info.text_len, info.d_text]);
        let cond_raws: Vec<HostTensor> = reqs
            .iter()
            .map(|r| workload::embed_prompt(&r.prompt, info.d_text, info.text_len))
            .collect();
        let (ru, rcs) = std::thread::scope(|sc| {
            let hu = sc.spawn(|| self.branch_ctx(&uncond_raw));
            let rcs: Vec<Result<BranchCtx>> =
                cond_raws.iter().map(|cr| self.branch_ctx(cr)).collect();
            let ru = match hu.join() {
                Ok(r) => r,
                Err(_) => Err(anyhow!("uncond branch-ctx thread panicked")),
            };
            (ru, rcs)
        });
        let uncond_ctx = ru?;
        let mut cond_ctxs: Vec<BranchCtx> = Vec::with_capacity(nb);
        for (i, rc) in rcs.into_iter().enumerate() {
            cond_ctxs.push(rc?);
            statses[i].h2d_bytes += 2 * (info.text_len * info.d_text * 4) as u64;
            statses[i].h2d_calls += 2;
        }

        // Batch-shared fused executables and device constants: the same
        // builders as the sequential path, asked for [B, F, P, C] shapes.
        let cfg_exec = rt.cfg_combine(&bdims)?;
        let cfg_scale_dev = rt.upload(&[cfg_scale], &[])?;
        let stepper = sampler::DeviceStepper::new(&rt, smp.kind(), &bdims)?;
        let stack_exec = rt.stack(&dims, nb)?;
        let mut lane_execs = Vec::with_capacity(nb);
        for i in 0..nb {
            lane_execs.push(rt.lane(&bdims, i)?);
        }

        // Initial latents: one upload per request, stacked on device.
        let mut x_dev = {
            let mut lane_latents = Vec::with_capacity(nb);
            for (i, req) in reqs.iter().enumerate() {
                let mut latent_rng = Rng::from_seed_and_label(req.seed, "latents");
                let x_init = latent_rng.normal_vec(latent_elems);
                lane_latents.push(rt.upload(&x_init, &dims)?);
                statses[i].h2d_bytes += (latent_elems * 4) as u64 + 4 + stepper.setup_h2d_bytes();
                statses[i].h2d_calls += 2 + stepper.setup_h2d_calls();
            }
            let lane_refs: Vec<&DeviceTensor> = lane_latents.iter().collect();
            stack_exec.run(&lane_refs)?
        };

        // Shared per-step scalars (identical across compatible requests):
        // uploaded once per batch, charged as-if-standalone per lane.
        let t_values: Vec<f32> = (0..steps).map(|i| smp.t_value(i)).collect();
        let c_steps = m.t_embeds(&t_values)?;
        let mut coeffs = Vec::with_capacity(steps);
        let mut coeff_scalars = 0u64;
        for i in 0..steps {
            let cf = stepper.upload_coeffs(&smp.step_coeffs(i))?;
            coeff_scalars += cf.len() as u64;
            coeffs.push(cf);
        }
        for s in statses.iter_mut() {
            s.h2d_bytes += 4 * steps as u64 + 4 * coeff_scalars;
            s.h2d_calls += steps as u64 + coeff_scalars;
        }

        let pols: Vec<Mutex<&mut dyn ReusePolicy>> =
            policies.iter_mut().map(|p| Mutex::new(p.as_mut())).collect();
        let mut reuse_maps: Vec<Vec<Vec<bool>>> =
            (0..nb).map(|_| Vec::with_capacity(steps)).collect();

        let t_start = Instant::now();
        // One persistent worker per (lane, CFG branch), lane-major order —
        // the batched generalization of the single-request uncond worker.
        // Each worker owns its lane-branch cache for the whole loop and
        // hands it back at join.
        let caches: Result<Vec<FeatureCache>> = std::thread::scope(|sc| {
            let mut tx_jobs: Vec<mpsc::Sender<BranchJob>> = Vec::with_capacity(2 * nb);
            let mut rx_ress: Vec<mpsc::Receiver<Result<BranchRun>>> = Vec::with_capacity(2 * nb);
            let mut workers = Vec::with_capacity(2 * nb);
            for lane in 0..nb {
                for branch in 0..2usize {
                    let (tx_job, rx_job) = mpsc::channel::<BranchJob>();
                    let (tx_res, rx_res) = mpsc::channel::<Result<BranchRun>>();
                    let bctx = if branch == 0 { &cond_ctxs[lane] } else { &uncond_ctx };
                    let policy_ref = &pols[lane];
                    let rp = rps[lane];
                    workers.push(sc.spawn(move || {
                        let mut cache = FeatureCache::new();
                        let mut mirror: HostMirror = BTreeMap::new();
                        while let Ok((step, c, h0)) = rx_job.recv() {
                            let ctx = StepCtx {
                                step,
                                granularity: rp.granularity,
                                cache_mode: rp.cache_mode,
                                needs_measure: rp.needs_measure,
                                c: &c,
                                h0: &h0,
                            };
                            let r = self.run_branch(
                                &ctx, branch, bctx, &mut cache, &mut mirror, policy_ref, None,
                            );
                            let failed = r.is_err();
                            if tx_res.send(r).is_err() || failed {
                                break;
                            }
                        }
                        cache
                    }));
                    tx_jobs.push(tx_job);
                    rx_ress.push(rx_res);
                }
            }

            // Same errors-break-out-then-join discipline as the
            // single-request loop: a worker panic must surface as an Err,
            // never a re-raised panic at scope exit.
            let mut loop_err: Option<anyhow::Error> = None;
            {
                let mut do_step = |step: usize| -> Result<()> {
                    let t_step = Instant::now();
                    let c = c_steps[step].clone();
                    // Per-lane patch embeddings from the stacked latent.
                    let mut h0s = Vec::with_capacity(nb);
                    for lane_exec in &lane_execs {
                        let xl = lane_exec.run(&[&x_dev])?;
                        h0s.push(Arc::new(m.embed(&xl)?));
                    }
                    for lane in 0..nb {
                        for branch in 0..2usize {
                            tx_jobs[2 * lane + branch]
                                .send((step, c.clone(), h0s[lane].clone()))
                                .map_err(|_| anyhow!("branch worker exited early"))?;
                        }
                    }
                    let mut eps_cond = Vec::with_capacity(nb);
                    let mut eps_uncond = Vec::with_capacity(nb);
                    for lane in 0..nb {
                        let bc = rx_ress[2 * lane]
                            .recv()
                            .map_err(|_| anyhow!("cond branch worker disconnected"))??;
                        let bu = rx_ress[2 * lane + 1]
                            .recv()
                            .map_err(|_| anyhow!("uncond branch worker disconnected"))??;
                        bc.stats.merge_into(&mut statses[lane]);
                        bu.stats.merge_into(&mut statses[lane]);
                        reuse_maps[lane].push(bc.decisions);
                        eps_cond.push(bc.eps);
                        eps_uncond.push(bu.eps);
                    }
                    // One batched CFG combine + one batched sampler step
                    // advance every resident lane; no latent byte crosses
                    // the bus.
                    let ur: Vec<&DeviceTensor> = eps_uncond.iter().collect();
                    let cr: Vec<&DeviceTensor> = eps_cond.iter().collect();
                    let u_stack = stack_exec.run(&ur)?;
                    let c_stack = stack_exec.run(&cr)?;
                    let eps_b = cfg_exec.run(&[&u_stack, &c_stack, &cfg_scale_dev])?;
                    x_dev = smp.step_device(&stepper, &x_dev, &eps_b, &coeffs[step])?;
                    let dt = t_step.elapsed().as_secs_f64();
                    for s in statses.iter_mut() {
                        s.per_step_s.push(dt);
                    }
                    Ok(())
                };
                for step in 0..steps {
                    if let Err(e) = do_step(step) {
                        loop_err = Some(e);
                        break;
                    }
                }
            }

            drop(tx_jobs);
            drop(rx_ress);
            let mut caches = Vec::with_capacity(2 * nb);
            let mut join_err: Option<anyhow::Error> = None;
            for w in workers {
                match w.join() {
                    Ok(cache) => caches.push(cache),
                    Err(_) => join_err = Some(anyhow!("CFG branch worker panicked")),
                }
            }
            match (loop_err, join_err) {
                (_, Some(e)) => Err(e),
                (Some(e), None) => Err(e),
                (None, None) => Ok(caches),
            }
        });
        let caches = caches?;

        // Final latents: one batched download, split per lane on the host;
        // each lane is charged its own latent (exactly the standalone
        // download it would have paid).
        let mut all = vec![0.0f32; nb * latent_elems];
        rt.download_into(&x_dev, &mut all)?;
        let wall = t_start.elapsed().as_secs_f64();

        let mut out = Vec::with_capacity(nb);
        for (lane, pol) in pols.into_iter().enumerate() {
            let policy = pol.into_inner().unwrap();
            let s = &mut statses[lane];
            s.d2h_bytes += (latent_elems * 4) as u64;
            s.d2h_calls += 1;
            s.wall_s = wall;
            let cache_cond = &caches[2 * lane];
            let cache_uncond = &caches[2 * lane + 1];
            s.cache_peak_bytes = cache_cond.peak_bytes() + cache_uncond.peak_bytes();
            s.cache_entries_per_layer = cache_cond
                .entries_per_layer(info.layers)
                .max(cache_uncond.entries_per_layer(info.layers));
            let data = all[lane * latent_elems..(lane + 1) * latent_elems].to_vec();
            out.push(RunResult {
                latents: HostTensor::new(vec![f, p, c_lat], data),
                stats: std::mem::take(s),
                reuse_map: std::mem::take(&mut reuse_maps[lane]),
                thresholds: policy.thresholds(),
            });
        }
        Ok(out)
    }

    /// The resident-latent step loop (see module docs §Hot path): the
    /// latent `x` is a [`DeviceTensor`] for the entire request.
    #[allow(clippy::too_many_arguments)]
    fn generate_device(
        &self,
        req: &Request,
        rp: RunParams,
        smp: Box<dyn Sampler>,
        branches: [BranchCtx; 2],
        policy: &mut dyn ReusePolicy,
        mut observer: Option<&mut dyn StepObserver>,
        mut stats: RunStats,
    ) -> Result<RunResult> {
        let m = &self.model;
        let info = &m.info;
        let rt = m.runtime().clone();
        let [f, p, _d] = m.state_dims();
        let [_, _, c_lat] = m.latent_dims();
        let dims = [f, p, c_lat];
        let latent_elems = f * p * c_lat;

        // Fused per-request executables: CFG combine + the sampler step
        // (scale / schedule scalars are rank-0 runtime arguments).
        let cfg_exec = rt.cfg_combine(&dims)?;
        let cfg_scale_dev = rt.upload(&[rp.cfg_scale], &[])?;
        stats.h2d_bytes += 4;
        stats.h2d_calls += 1;
        let stepper = sampler::DeviceStepper::new(&rt, smp.kind(), &dims)?;
        stats.h2d_bytes += stepper.setup_h2d_bytes();
        stats.h2d_calls += stepper.setup_h2d_calls();

        // --- initial latents: uploaded once, resident until the end -------
        let mut latent_rng = Rng::from_seed_and_label(req.seed, "latents");
        let x_init = latent_rng.normal_vec(latent_elems);
        let mut x_dev = rt.upload(&x_init, &dims)?;
        stats.h2d_bytes += (latent_elems * 4) as u64;
        stats.h2d_calls += 1;

        // Every t_value and step coefficient is known up front, so the
        // timestep embeddings and the per-step sampler scalars upload once
        // at request start (4 bytes per scalar).
        let t_values: Vec<f32> = (0..rp.steps).map(|i| smp.t_value(i)).collect();
        let c_steps = m.t_embeds(&t_values)?;
        stats.h2d_bytes += 4 * rp.steps as u64;
        stats.h2d_calls += rp.steps as u64;
        let mut coeffs = Vec::with_capacity(rp.steps);
        for i in 0..rp.steps {
            let cf = stepper.upload_coeffs(&smp.step_coeffs(i))?;
            stats.h2d_bytes += 4 * cf.len() as u64;
            stats.h2d_calls += cf.len() as u64;
            coeffs.push(cf);
        }

        let parallel = observer.is_none();
        let mut cache_cond = FeatureCache::new();
        // Host mirrors are a HotPath::Host concern (apply_coarse only
        // writes them in its Host arm); the resident loop passes empty
        // scratch maps to satisfy run_branch's shared signature.
        let mut mirror_scratch: HostMirror = BTreeMap::new();
        let mut reuse_map: Vec<Vec<bool>> = Vec::with_capacity(rp.steps);
        let policy_mx = Mutex::new(policy);

        let t_start = Instant::now();
        // The uncond branch runs on one persistent worker thread per
        // request, fed per step over a channel; the worker owns the uncond
        // cache for the whole loop and hands it back at join. (Replaces
        // the seed-era per-step thread::scope spawn.)
        let uncond_cache: Result<FeatureCache> = std::thread::scope(|sc| {
            let (worker, tx_job, rx_res) = if parallel {
                let (tx_job, rx_job) = mpsc::channel::<BranchJob>();
                let (tx_res, rx_res) = mpsc::channel::<Result<BranchRun>>();
                let bctx = &branches[1];
                let policy_ref = &policy_mx;
                let handle = sc.spawn(move || {
                    let mut cache = FeatureCache::new();
                    let mut mirror: HostMirror = BTreeMap::new();
                    while let Ok((step, c, h0)) = rx_job.recv() {
                        let ctx = StepCtx {
                            step,
                            granularity: rp.granularity,
                            cache_mode: rp.cache_mode,
                            needs_measure: rp.needs_measure,
                            c: &c,
                            h0: &h0,
                        };
                        let r = self.run_branch(
                            &ctx, 1, bctx, &mut cache, &mut mirror, policy_ref, None,
                        );
                        let failed = r.is_err();
                        if tx_res.send(r).is_err() || failed {
                            break;
                        }
                    }
                    cache
                });
                (Some(handle), Some(tx_job), Some(rx_res))
            } else {
                (None, None, None)
            };
            let mut seq_uncond_cache: Option<FeatureCache> =
                if parallel { None } else { Some(FeatureCache::new()) };
            let mut seq_uncond_mirror: HostMirror = BTreeMap::new();

            // The step loop proper. Errors break out (instead of `?`-ing
            // straight out of the scope closure) so the worker is always
            // joined below — a worker panic must surface as an Err from
            // generate, not as a re-raised panic at scope exit.
            let mut loop_err: Option<anyhow::Error> = None;
            {
                let mut do_step = |step: usize| -> Result<()> {
                    let t_step = Instant::now();
                    let c = c_steps[step].clone();
                    let h0 = Arc::new(m.embed(&x_dev)?);
                    // Feed the worker first so both branches overlap.
                    if let Some(tx) = &tx_job {
                        tx.send((step, c.clone(), h0.clone()))
                            .map_err(|_| anyhow!("uncond branch worker exited early"))?;
                    }
                    let ctx = StepCtx {
                        step,
                        granularity: rp.granularity,
                        cache_mode: rp.cache_mode,
                        needs_measure: rp.needs_measure,
                        c: &c,
                        h0: &h0,
                    };
                    let b_cond = self.run_branch(
                        &ctx,
                        0,
                        &branches[0],
                        &mut cache_cond,
                        &mut mirror_scratch,
                        &policy_mx,
                        observer.as_deref_mut(),
                    )?;
                    let b_uncond = if let Some(rx) = &rx_res {
                        rx.recv()
                            .map_err(|_| anyhow!("uncond branch worker disconnected"))??
                    } else {
                        let cu = seq_uncond_cache.as_mut().expect("sequential uncond cache");
                        self.run_branch(
                            &ctx,
                            1,
                            &branches[1],
                            cu,
                            &mut seq_uncond_mirror,
                            &policy_mx,
                            observer.as_deref_mut(),
                        )?
                    };
                    b_cond.stats.merge_into(&mut stats);
                    b_uncond.stats.merge_into(&mut stats);

                    // eps = uncond + s·(cond − uncond), then the sampler
                    // step — both fused; no latent byte crosses the bus.
                    let eps_dev =
                        cfg_exec.run(&[&b_uncond.eps, &b_cond.eps, &cfg_scale_dev])?;
                    x_dev = smp.step_device(&stepper, &x_dev, &eps_dev, &coeffs[step])?;

                    reuse_map.push(b_cond.decisions);
                    stats.per_step_s.push(t_step.elapsed().as_secs_f64());
                    Ok(())
                };
                for step in 0..rp.steps {
                    if let Err(e) = do_step(step) {
                        loop_err = Some(e);
                        break;
                    }
                }
            }

            // Disconnect, then join: the worker drains and returns its
            // cache state; a panic inside it becomes the root-cause Err.
            drop(tx_job);
            drop(rx_res);
            let joined: Result<FeatureCache> = match (worker, seq_uncond_cache) {
                (Some(h), _) => {
                    h.join().map_err(|_| anyhow!("uncond CFG branch worker panicked"))
                }
                (None, Some(cache)) => Ok(cache),
                (None, None) => Err(anyhow!("no uncond branch state")),
            };
            match (loop_err, joined) {
                (_, Err(e)) => Err(e),
                (Some(e), Ok(_)) => Err(e),
                (None, Ok(cache)) => Ok(cache),
            }
        });
        let cache_uncond = uncond_cache?;
        debug_assert!(
            mirror_scratch.is_empty(),
            "host mirrors must stay empty under HotPath::Device"
        );

        // --- final latent: downloaded exactly once per request -------------
        let mut x = vec![0.0f32; latent_elems];
        rt.download_into(&x_dev, &mut x)?;
        stats.d2h_bytes += (latent_elems * 4) as u64;
        stats.d2h_calls += 1;
        stats.wall_s = t_start.elapsed().as_secs_f64();

        stats.cache_peak_bytes = cache_cond.peak_bytes() + cache_uncond.peak_bytes();
        stats.cache_entries_per_layer = cache_cond
            .entries_per_layer(info.layers)
            .max(cache_uncond.entries_per_layer(info.layers));
        let policy = policy_mx.into_inner().unwrap();
        Ok(RunResult {
            latents: HostTensor::new(vec![f, p, c_lat], x),
            stats,
            reuse_map,
            thresholds: policy.thresholds(),
        })
    }

    /// The seed-era host-staged step loop, kept verbatim for A/B
    /// benchmarking and equivalence tests: per-step latent upload, both
    /// branch epsilons downloaded, host CFG combine, host sampler step,
    /// sequential branches.
    #[allow(clippy::too_many_arguments)]
    fn generate_host(
        &self,
        req: &Request,
        rp: RunParams,
        smp: Box<dyn Sampler>,
        branches: [BranchCtx; 2],
        policy: &mut dyn ReusePolicy,
        mut observer: Option<&mut dyn StepObserver>,
        mut stats: RunStats,
    ) -> Result<RunResult> {
        let m = &self.model;
        let info = &m.info;
        let rt = m.runtime().clone();
        let [f, p, _d] = m.state_dims();
        let [_, _, c_lat] = m.latent_dims();
        let latent_elems = f * p * c_lat;

        let mut latent_rng = Rng::from_seed_and_label(req.seed, "latents");
        let mut x = latent_rng.normal_vec(latent_elems);

        // One cache (and one measurement mirror) per CFG branch.
        let mut caches = [FeatureCache::new(), FeatureCache::new()];
        let mut mirrors: [HostMirror; 2] = [BTreeMap::new(), BTreeMap::new()];
        let mut reuse_map: Vec<Vec<bool>> = Vec::with_capacity(rp.steps);
        let mut eps = vec![0.0f32; latent_elems];
        let mut eps_cond = vec![0.0f32; latent_elems];
        let policy_mx = Mutex::new(policy);

        let t_start = Instant::now();
        for step in 0..rp.steps {
            let t_step = Instant::now();
            let c = Arc::new(m.t_embed(smp.t_value(step))?);
            stats.h2d_bytes += 4;
            stats.h2d_calls += 1;
            let x_dev = rt.upload(&x, &[f, p, c_lat])?;
            stats.h2d_bytes += (latent_elems * 4) as u64;
            stats.h2d_calls += 1;
            let h0 = Arc::new(m.embed(&x_dev)?);
            let ctx = StepCtx {
                step,
                granularity: rp.granularity,
                cache_mode: rp.cache_mode,
                needs_measure: rp.needs_measure,
                c: &c,
                h0: &h0,
            };

            let [cache_cond, cache_uncond] = &mut caches;
            let [mirror_cond, mirror_uncond] = &mut mirrors;
            let b_cond = self.run_branch(
                &ctx,
                0,
                &branches[0],
                cache_cond,
                mirror_cond,
                &policy_mx,
                observer.as_deref_mut(),
            )?;
            let b_uncond = self.run_branch(
                &ctx,
                1,
                &branches[1],
                cache_uncond,
                mirror_uncond,
                &policy_mx,
                observer.as_deref_mut(),
            )?;
            b_cond.stats.merge_into(&mut stats);
            b_uncond.stats.merge_into(&mut stats);

            // Host CFG combine: eps = uncond + s * (cond - uncond)
            rt.download_into(&b_cond.eps, &mut eps_cond)?;
            rt.download_into(&b_uncond.eps, &mut eps)?;
            stats.d2h_bytes += 2 * (latent_elems * 4) as u64;
            stats.d2h_calls += 2;
            for i in 0..latent_elems {
                eps[i] += rp.cfg_scale * (eps_cond[i] - eps[i]);
            }
            smp.step(&mut x, &eps, step);
            reuse_map.push(b_cond.decisions);
            stats.per_step_s.push(t_step.elapsed().as_secs_f64());
        }

        stats.wall_s = t_start.elapsed().as_secs_f64();
        let mirror_bytes: usize = mirrors
            .iter()
            .map(|mm| mm.values().map(|v| v.len() * 4).sum::<usize>())
            .sum();
        stats.cache_peak_bytes =
            caches.iter().map(|cc| cc.peak_bytes()).sum::<usize>() + mirror_bytes;
        stats.cache_entries_per_layer = caches
            .iter()
            .map(|cc| cc.entries_per_layer(info.layers))
            .fold(0.0, f64::max);
        let policy = policy_mx.into_inner().unwrap();
        Ok(RunResult {
            latents: HostTensor::new(vec![f, p, c_lat], x),
            stats,
            reuse_map,
            thresholds: policy.thresholds(),
        })
    }

    /// Execute one CFG branch of one step: every (layer, kind[, sublayer])
    /// site in order, then the final projection to this branch's epsilon.
    #[allow(clippy::too_many_arguments)]
    fn run_branch(
        &self,
        ctx: &StepCtx<'_>,
        branch: usize,
        bctx: &BranchCtx,
        cache: &mut FeatureCache,
        mirror: &mut HostMirror,
        policy: &Mutex<&mut dyn ReusePolicy>,
        mut observer: Option<&mut dyn StepObserver>,
    ) -> Result<BranchRun> {
        let m = &self.model;
        let info = &m.info;
        let mut h = ctx.h0.clone();
        let mut decisions: Vec<bool> = Vec::new();
        let mut bs = BranchStats::default();
        let mut obs_scratch: Vec<f32> = Vec::new();
        for layer in 0..info.layers {
            for kind in BlockKind::ALL {
                let (tk, tv) = &bctx.text_kv[layer][kind.index()];
                match ctx.granularity {
                    Granularity::Coarse => {
                        let site = Site { layer, kind, unit: Unit::Block, branch };
                        let action = policy.lock().unwrap().action(ctx.step, site);
                        if branch == 0 {
                            decisions.push(action.is_reuse());
                        }
                        h = self.apply_coarse(
                            ctx, site, action, h, tk, tv, cache, mirror, policy, &mut bs,
                        )?;
                    }
                    Granularity::Fine => {
                        for sub in SubUnit::ALL {
                            let site = Site { layer, kind, unit: Unit::Sub(sub), branch };
                            let action = policy.lock().unwrap().action(ctx.step, site);
                            if branch == 0 {
                                decisions.push(action.is_reuse());
                            }
                            h = self.apply_fine(ctx, site, action, h, tk, tv, cache, &mut bs)?;
                        }
                    }
                }
                if let Some(obs) = observer.as_deref_mut() {
                    if obs.wants_branch(branch) {
                        obs_scratch.resize(h.element_count(), 0.0);
                        m.runtime().download_into(&h, &mut obs_scratch)?;
                        bs.d2h_bytes += (obs_scratch.len() * 4) as u64;
                        bs.d2h_calls += 1;
                        obs.on_block(ctx.step, layer, kind, &obs_scratch);
                    }
                }
            }
        }
        let eps = m.final_proj(&h, ctx.c)?;
        Ok(BranchRun { eps, decisions, stats: bs })
    }

    /// Execute / reuse one coarse (whole-block) site.
    #[allow(clippy::too_many_arguments)]
    fn apply_coarse(
        &self,
        ctx: &StepCtx<'_>,
        site: Site,
        action: Action,
        h: Arc<DeviceTensor>,
        tk: &Arc<DeviceTensor>,
        tv: &Arc<DeviceTensor>,
        cache: &mut FeatureCache,
        mirror: &mut HostMirror,
        policy: &Mutex<&mut dyn ReusePolicy>,
        bs: &mut BranchStats,
    ) -> Result<Arc<DeviceTensor>> {
        let m = &self.model;
        let key =
            CacheKey { branch: site.branch, layer: site.layer, kind: site.kind, unit: site.unit };

        let effective = match action {
            Action::Reuse | Action::ReuseResidual if !cache.contains(&key) => {
                bs.fallback += 1;
                Action::Compute { update_cache: true, measure: ctx.needs_measure }
            }
            a => a,
        };

        match effective {
            Action::Reuse => {
                bs.reused += 1;
                let e = cache.get(&key).expect("checked above");
                Ok(e.device.clone())
            }
            Action::ReuseResidual => {
                bs.reused += 1;
                let delta = cache.get(&key).expect("checked above").device.clone();
                Ok(Arc::new(m.add(&h, &delta)?))
            }
            Action::Compute { update_cache, measure } => {
                bs.computed += 1;
                let out = Arc::new(m.block_full(site.layer, site.kind, &h, ctx.c, tk, tv)?);
                // Drift is only meaningful against a cached *output*
                // (Eq. 6 compares features, not residual deltas); a
                // measuring Delta-mode policy would otherwise observe
                // MSE(out, out_prev − h_prev) — garbage.
                if measure && ctx.cache_mode == CacheMode::Output {
                    match self.hot_path {
                        HotPath::Device => {
                            // Eq. 5/6 drift as a fused on-device reduction
                            // against the cached activation: 4 bytes down.
                            if let Some(prev) = cache.peek(&key) {
                                let mse = m.state_mse(&out, &prev.device)?;
                                bs.d2h_bytes += 4;
                                bs.d2h_calls += 1;
                                policy.lock().unwrap().observe_mse(ctx.step, site, mse);
                            }
                        }
                        HotPath::Host => {
                            // Seed-era staging: pull the whole activation
                            // down and diff against a host mirror (F·P·D·4
                            // bytes per measured site — the cost
                            // fig16_hotpath quantifies).
                            let mut scratch = vec![0.0f32; out.element_count()];
                            m.runtime().download_into(&out, &mut scratch)?;
                            bs.d2h_bytes += (scratch.len() * 4) as u64;
                            bs.d2h_calls += 1;
                            if let Some(prev) = mirror.get(&key) {
                                let mse = mse_f32(&scratch, prev);
                                policy.lock().unwrap().observe_mse(ctx.step, site, mse);
                            }
                            if update_cache {
                                mirror.insert(key, scratch);
                            }
                        }
                    }
                }
                if update_cache {
                    let dev = match ctx.cache_mode {
                        CacheMode::Output => out.clone(),
                        CacheMode::Delta => Arc::new(m.sub(&out, &h)?),
                    };
                    cache.put(key, dev, ctx.step);
                }
                Ok(out)
            }
        }
    }

    /// Execute / reuse one fine (sublayer) site. Fine policies always cache
    /// residual deltas.
    #[allow(clippy::too_many_arguments)]
    fn apply_fine(
        &self,
        ctx: &StepCtx<'_>,
        site: Site,
        action: Action,
        h: Arc<DeviceTensor>,
        tk: &Arc<DeviceTensor>,
        tv: &Arc<DeviceTensor>,
        cache: &mut FeatureCache,
        bs: &mut BranchStats,
    ) -> Result<Arc<DeviceTensor>> {
        let m = &self.model;
        let Unit::Sub(sub) = site.unit else {
            return Err(anyhow!("fine path requires sub unit"));
        };
        let key =
            CacheKey { branch: site.branch, layer: site.layer, kind: site.kind, unit: site.unit };

        let effective = match action {
            Action::Reuse | Action::ReuseResidual if !cache.contains(&key) => {
                bs.fallback += 1;
                Action::Compute { update_cache: true, measure: false }
            }
            Action::Reuse => Action::ReuseResidual, // fine reuse is delta-based
            a => a,
        };

        match effective {
            Action::ReuseResidual => {
                bs.reused += 1;
                let delta = cache.get(&key).expect("checked above").device.clone();
                Ok(Arc::new(m.add(&h, &delta)?))
            }
            Action::Compute { update_cache, .. } => {
                bs.computed += 1;
                let out = Arc::new(match sub {
                    SubUnit::Attn => m.block_attn(site.layer, site.kind, &h, ctx.c)?,
                    SubUnit::Cross => m.block_cross(site.layer, site.kind, &h, tk, tv)?,
                    SubUnit::Mlp => m.block_mlp(site.layer, site.kind, &h, ctx.c)?,
                });
                if update_cache {
                    let delta = Arc::new(m.sub(&out, &h)?);
                    cache.put(key, delta, ctx.step);
                }
                Ok(out)
            }
            Action::Reuse => unreachable!("mapped to ReuseResidual above"),
        }
    }
}
