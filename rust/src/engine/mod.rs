//! Generation engine: the denoising loop with per-block reuse decisions.
//!
//! This is where the paper's system comes together. For every request the
//! engine runs `T` denoising steps with classifier-free guidance; at each
//! step, for each (layer, block, CFG-branch) — or sublayer for fine-grained
//! baselines — it asks the [`ReusePolicy`] whether to dispatch the block
//! executable or serve the activation from the [`FeatureCache`]. Reused
//! blocks cost zero FLOPs and zero dispatches; that is the entire speedup
//! mechanism of the paper.
//!
//! Hot-path properties (EXPERIMENTS.md §Perf):
//! * activations stay device-resident across blocks and steps; the host
//!   only sees the per-step `eps` (for sampler math) and, for Foresight,
//!   the block outputs it must measure (Eq. 5/6 MSEs);
//! * text K/V are precomputed once per request per (layer, kind, branch);
//! * the patch embedding runs once per step, shared across CFG branches;
//! * measurement scratch buffers are allocated once per request.

use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use crate::cache::{CacheKey, FeatureCache, Unit};
use crate::config::ScheduleConfig;
use crate::model::{BlockKind, LoadedModel, SubUnit};
use crate::policy::{Action, CacheMode, Granularity, ReusePolicy, Site};
use crate::runtime::{DeviceTensor, HostTensor};
use crate::sampler;
use crate::util::prng::Rng;
use crate::util::stats::mse_f32;
use crate::workload;

/// One generation request.
#[derive(Debug, Clone)]
pub struct Request {
    pub prompt: String,
    pub seed: u64,
    /// Override the preset's step count (paper ablations use T=60).
    pub steps: Option<usize>,
    /// Override the preset's CFG scale.
    pub cfg_scale: Option<f64>,
}

impl Request {
    pub fn new(prompt: &str, seed: u64) -> Self {
        Self { prompt: prompt.to_string(), seed, steps: None, cfg_scale: None }
    }
}

/// Counters and timings for one run.
#[derive(Debug, Clone, Default)]
pub struct RunStats {
    pub policy: String,
    pub wall_s: f64,
    pub per_step_s: Vec<f64>,
    pub computed_units: u64,
    pub reused_units: u64,
    /// Reuse decisions that fell back to compute due to a cold cache.
    pub fallback_units: u64,
    pub cache_peak_bytes: usize,
    pub cache_entries_per_layer: f64,
}

impl RunStats {
    /// Fraction of reuse-eligible decisions that actually reused.
    pub fn reuse_fraction(&self) -> f64 {
        let total = self.computed_units + self.reused_units;
        if total == 0 {
            0.0
        } else {
            self.reused_units as f64 / total as f64
        }
    }
}

/// Full result of one generation.
pub struct RunResult {
    /// Final denoised latent video [F, P, C].
    pub latents: HostTensor,
    pub stats: RunStats,
    /// Per step, per site (branch 0, policy order): true = reused (Fig. 6).
    pub reuse_map: Vec<Vec<bool>>,
    /// Foresight's per-site λ after the run (Fig. 5).
    pub thresholds: Option<BTreeMap<(usize, BlockKind, usize), f64>>,
}

/// Observer hook for the feature-dynamics analyses (Figs. 2/3/11-14):
/// receives host copies of computed block outputs.
pub trait StepObserver: Send {
    /// Which CFG branch to observe (downloads are expensive; default cond).
    fn wants_branch(&self, branch: usize) -> bool {
        branch == 0
    }

    fn on_block(&mut self, step: usize, layer: usize, kind: BlockKind, data: &[f32]);
}

/// The generation engine bound to one loaded model variant.
pub struct Engine {
    model: Arc<LoadedModel>,
    schedule: ScheduleConfig,
}

/// Per-branch request context (text conditioning).
struct BranchCtx {
    /// Precomputed cross-attention K/V per (layer, kind-index).
    text_kv: Vec<[(Arc<DeviceTensor>, Arc<DeviceTensor>); 2]>,
}

impl Engine {
    pub fn new(model: Arc<LoadedModel>, schedule: ScheduleConfig) -> Self {
        Self { model, schedule }
    }

    pub fn model(&self) -> &Arc<LoadedModel> {
        &self.model
    }

    /// Run one request under `policy`, optionally streaming block outputs
    /// to `observer`.
    pub fn generate(
        &self,
        req: &Request,
        policy: &mut dyn ReusePolicy,
        mut observer: Option<&mut dyn StepObserver>,
    ) -> Result<RunResult> {
        let m = &self.model;
        let info = &m.info;
        let rt = m.runtime().clone();
        let steps = req.steps.unwrap_or(info.steps);
        let cfg_scale = req.cfg_scale.unwrap_or(info.cfg_scale) as f32;
        let smp = sampler::build(info.sampler, &self.schedule, steps);
        let [f, p, d] = m.state_dims();
        let [_, _, c_lat] = m.latent_dims();
        let state_elems = f * p * d;
        let latent_elems = f * p * c_lat;

        policy.begin_request(info.layers, steps);
        let granularity = policy.granularity();
        let cache_mode = policy.cache_mode();
        let needs_host = policy.needs_measurement();

        // --- request-constant conditioning --------------------------------
        let cond_raw = workload::embed_prompt(&req.prompt, info.d_text, info.text_len);
        let uncond_raw = HostTensor::zeros(vec![info.text_len, info.d_text]);
        let mut branches = Vec::with_capacity(2);
        for raw in [&cond_raw, &uncond_raw] {
            let text = Arc::new(m.text_proj(raw)?);
            let mut text_kv = Vec::with_capacity(info.layers);
            for layer in 0..info.layers {
                let mut pair = Vec::with_capacity(2);
                for kind in BlockKind::ALL {
                    let tk = Arc::new(m.text_k(layer, kind, &text)?);
                    let tv = Arc::new(m.text_v(layer, kind, &text)?);
                    pair.push((tk, tv));
                }
                let pair: [(Arc<DeviceTensor>, Arc<DeviceTensor>); 2] =
                    pair.try_into().map_err(|_| anyhow!("kv pair"))?;
                text_kv.push(pair);
            }
            branches.push(BranchCtx { text_kv });
        }

        // --- initial latents ----------------------------------------------
        let mut latent_rng = Rng::from_seed_and_label(req.seed, "latents");
        let mut x = latent_rng.normal_vec(latent_elems);

        // --- run state ------------------------------------------------------
        let mut cache = FeatureCache::new();
        let mut stats = RunStats { policy: policy.name(), ..Default::default() };
        let mut reuse_map: Vec<Vec<bool>> = Vec::with_capacity(steps);
        let mut scratch = vec![0.0f32; state_elems];
        let mut eps = vec![0.0f32; latent_elems];
        let mut eps_cond = vec![0.0f32; latent_elems];

        let t_start = Instant::now();
        for step in 0..steps {
            let t_step = Instant::now();
            let t_val = smp.t_value(step);
            let c = Arc::new(m.t_embed(t_val)?);
            let x_dev = rt.upload(&x, &[f, p, c_lat])?;
            let h0 = Arc::new(m.embed(&x_dev)?);

            let mut step_decisions: Vec<bool> = Vec::new();
            for branch in 0..2usize {
                let bctx = &branches[branch];
                let mut h = h0.clone();
                for layer in 0..info.layers {
                    for kind in BlockKind::ALL {
                        let (tk, tv) = &bctx.text_kv[layer][kind.index()];
                        match granularity {
                            Granularity::Coarse => {
                                let site = Site { layer, kind, unit: Unit::Block, branch };
                                let action = policy.action(step, site);
                                if branch == 0 {
                                    step_decisions.push(action.is_reuse());
                                }
                                h = self.apply_coarse(
                                    step, site, action, cache_mode, needs_host, h, &c, tk,
                                    tv, &mut cache, policy, &mut stats, &mut scratch,
                                )?;
                            }
                            Granularity::Fine => {
                                for sub in SubUnit::ALL {
                                    let site =
                                        Site { layer, kind, unit: Unit::Sub(sub), branch };
                                    let action = policy.action(step, site);
                                    if branch == 0 {
                                        step_decisions.push(action.is_reuse());
                                    }
                                    h = self.apply_fine(
                                        site, action, h, &c, tk, tv, &mut cache,
                                        &mut stats, step,
                                    )?;
                                }
                            }
                        }
                        if let Some(obs) = observer.as_deref_mut() {
                            if obs.wants_branch(branch) {
                                rt.download_into(&h, &mut scratch)?;
                                obs.on_block(step, layer, kind, &scratch);
                            }
                        }
                    }
                }
                let eps_dev = m.final_proj(&h, &c)?;
                let dst = if branch == 0 { &mut eps_cond } else { &mut eps };
                rt.download_into(&eps_dev, dst)?;
            }

            // CFG combine: eps = uncond + s * (cond - uncond)
            for i in 0..latent_elems {
                eps[i] += cfg_scale * (eps_cond[i] - eps[i]);
            }
            smp.step(&mut x, &eps, step);
            reuse_map.push(step_decisions);
            stats.per_step_s.push(t_step.elapsed().as_secs_f64());
        }

        stats.wall_s = t_start.elapsed().as_secs_f64();
        stats.cache_peak_bytes = cache.peak_bytes();
        stats.cache_entries_per_layer = cache.entries_per_layer(info.layers);
        Ok(RunResult {
            latents: HostTensor::new(vec![f, p, c_lat], x),
            stats,
            reuse_map,
            thresholds: policy.thresholds(),
        })
    }

    /// Execute / reuse one coarse (whole-block) site.
    #[allow(clippy::too_many_arguments)]
    fn apply_coarse(
        &self,
        step: usize,
        site: Site,
        action: Action,
        cache_mode: CacheMode,
        needs_host: bool,
        h: Arc<DeviceTensor>,
        c: &Arc<DeviceTensor>,
        tk: &Arc<DeviceTensor>,
        tv: &Arc<DeviceTensor>,
        cache: &mut FeatureCache,
        policy: &mut dyn ReusePolicy,
        stats: &mut RunStats,
        scratch: &mut [f32],
    ) -> Result<Arc<DeviceTensor>> {
        let m = &self.model;
        let key = CacheKey { branch: site.branch, layer: site.layer, kind: site.kind, unit: site.unit };

        let effective = match action {
            Action::Reuse | Action::ReuseResidual if !cache.contains(&key) => {
                stats.fallback_units += 1;
                Action::Compute { update_cache: true, measure: needs_host }
            }
            a => a,
        };

        match effective {
            Action::Reuse => {
                stats.reused_units += 1;
                let e = cache.get(&key).expect("checked above");
                Ok(e.device.clone())
            }
            Action::ReuseResidual => {
                stats.reused_units += 1;
                let delta = cache.get(&key).expect("checked above").device.clone();
                Ok(Arc::new(m.add(&h, &delta)?))
            }
            Action::Compute { update_cache, measure } => {
                stats.computed_units += 1;
                let out = Arc::new(m.block_full(site.layer, site.kind, &h, c, tk, tv)?);
                if measure {
                    m.runtime().download_into(&out, scratch)?;
                    if let Some(prev) = cache.peek_host(&key) {
                        let mse = mse_f32(scratch, prev);
                        policy.observe_mse(step, site, mse);
                    }
                }
                if update_cache {
                    let (dev, host) = match cache_mode {
                        CacheMode::Output => (
                            out.clone(),
                            if needs_host { Some(scratch.to_vec()) } else { None },
                        ),
                        CacheMode::Delta => {
                            (Arc::new(m.sub(&out, &h)?), None)
                        }
                    };
                    cache.put(key, dev, host, step);
                }
                Ok(out)
            }
        }
    }

    /// Execute / reuse one fine (sublayer) site. Fine policies always cache
    /// residual deltas.
    #[allow(clippy::too_many_arguments)]
    fn apply_fine(
        &self,
        site: Site,
        action: Action,
        h: Arc<DeviceTensor>,
        c: &Arc<DeviceTensor>,
        tk: &Arc<DeviceTensor>,
        tv: &Arc<DeviceTensor>,
        cache: &mut FeatureCache,
        stats: &mut RunStats,
        step: usize,
    ) -> Result<Arc<DeviceTensor>> {
        let m = &self.model;
        let Unit::Sub(sub) = site.unit else {
            return Err(anyhow!("fine path requires sub unit"));
        };
        let key = CacheKey { branch: site.branch, layer: site.layer, kind: site.kind, unit: site.unit };

        let effective = match action {
            Action::Reuse | Action::ReuseResidual if !cache.contains(&key) => {
                stats.fallback_units += 1;
                Action::Compute { update_cache: true, measure: false }
            }
            Action::Reuse => Action::ReuseResidual, // fine reuse is delta-based
            a => a,
        };

        match effective {
            Action::ReuseResidual => {
                stats.reused_units += 1;
                let delta = cache.get(&key).expect("checked above").device.clone();
                Ok(Arc::new(m.add(&h, &delta)?))
            }
            Action::Compute { update_cache, .. } => {
                stats.computed_units += 1;
                let out = Arc::new(match sub {
                    SubUnit::Attn => m.block_attn(site.layer, site.kind, &h, c)?,
                    SubUnit::Cross => m.block_cross(site.layer, site.kind, &h, tk, tv)?,
                    SubUnit::Mlp => m.block_mlp(site.layer, site.kind, &h, c)?,
                });
                if update_cache {
                    let delta = Arc::new(m.sub(&out, &h)?);
                    cache.put(key, delta, None, step);
                }
                Ok(out)
            }
            Action::Reuse => unreachable!("mapped to ReuseResidual above"),
        }
    }
}
