//! Foresight CLI — the leader entrypoint.
//!
//! Subcommands:
//! * `generate` — run one prompt through a policy, print run stats
//! * `serve`    — start the TCP JSON-lines serving front-end
//! * `autotune` — profile policy configurations, write tuned profiles
//! * `analyze`  — dump feature-dynamics statistics (Fig. 2-style CSV)
//! * `info`     — list models/buckets available in the artifact manifest
//! * `lint`     — project-invariant static analysis (see `analysis::lint`)
//! * `trace`    — drain trace events to Chrome trace-event JSON

use anyhow::{anyhow, Result};
use std::net::SocketAddr;
use std::path::Path;
use std::sync::Arc;

use foresight::analysis::lint::{collect_sources, run_all, Allowlist};
use foresight::analysis::DynamicsRecorder;
use foresight::autotune::{profile_engine, sweep_table, GridSpec, ProfileOptions, ProfileStore};
use foresight::config::Manifest;
use foresight::engine::{Engine, Request};
use foresight::model::{BlockKind, LoadedModel};
use foresight::policy::build_policy;
use foresight::runtime::{DevicePool, Runtime};
use foresight::server::{Client, EngineRegistry, Server, ServerConfig};
use foresight::trace;
use foresight::util::cli::Cli;
use foresight::util::json::{self, Json};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match args.split_first() {
        Some((c, r)) => (c.clone(), r.to_vec()),
        None => {
            eprintln!("{}", usage());
            std::process::exit(2);
        }
    };
    let run = match cmd.as_str() {
        "generate" => cmd_generate(&rest),
        "serve" => cmd_serve(&rest),
        "autotune" => cmd_autotune(&rest),
        "analyze" => cmd_analyze(&rest),
        "info" => cmd_info(&rest),
        "lint" => cmd_lint(&rest),
        "trace" => cmd_trace(&rest),
        "--help" | "-h" | "help" => {
            println!("{}", usage());
            Ok(())
        }
        other => Err(anyhow!("unknown command '{other}'\n\n{}", usage())),
    };
    if let Err(e) = run {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() -> String {
    "foresight — adaptive layer reuse for text-to-video DiT serving\n\n\
     Commands:\n\
     \x20 generate   run one prompt under a reuse policy\n\
     \x20 serve      start the TCP JSON-lines server\n\
     \x20 autotune   profile policy configurations, write tuned profiles\n\
     \x20 analyze    dump feature-dynamics CSV (Fig. 2 style)\n\
     \x20 info       list available models and buckets\n\
     \x20 lint       check project invariants (lock order, panic paths, ledger)\n\
     \x20 trace      drain trace events to Chrome trace JSON (chrome://tracing, Perfetto)\n\n\
     Run `foresight <command> --help` for options."
        .to_string()
}

fn load_engine(model: &str, bucket: &str) -> Result<Engine> {
    let manifest = Manifest::load(&Manifest::default_root())?;
    let rt = Arc::new(Runtime::cpu()?);
    let lm = Arc::new(LoadedModel::load(rt, &manifest, model, bucket)?);
    Ok(Engine::new(lm, manifest.schedule))
}

fn cmd_generate(args: &[String]) -> Result<()> {
    let p = Cli::new("foresight generate", "run one prompt under a reuse policy")
        .opt("model", "opensora-sim", "model preset")
        .opt("bucket", "240p-2s", "shape bucket")
        .opt("policy", "foresight", "policy spec, e.g. foresight:n=2,r=3,gamma=0.5")
        .opt("prompt", "a calm lake at dawn, soft golden light", "text prompt")
        .opt("seed", "0", "random seed")
        .opt("steps", "", "override denoising steps")
        .parse(args)
        .map_err(|e| anyhow!("{e}"))?;

    let engine = load_engine(p.get("model"), p.get("bucket"))?;
    let info = engine.model().info.clone();
    let steps = if p.get("steps").is_empty() {
        None
    } else {
        Some(p.get_usize("steps").map_err(|e| anyhow!(e))?)
    };
    let mut policy = build_policy(p.get("policy"), &info, steps.unwrap_or(info.steps))?;
    let mut req = Request::new(p.get("prompt"), p.get_u64("seed").map_err(|e| anyhow!(e))?);
    req.steps = steps;

    let result = engine.generate(&req, policy.as_mut(), None)?;
    let s = &result.stats;
    println!("model        : {} / {}", info.name, p.get("bucket"));
    println!("policy       : {}", s.policy);
    println!("steps        : {}", s.per_step_s.len());
    println!("wall time    : {:.3} s", s.wall_s);
    println!("computed     : {} block-units", s.computed_units);
    println!(
        "reused       : {} block-units ({:.1}%)",
        s.reused_units,
        100.0 * s.reuse_fraction()
    );
    println!("cache peak   : {:.1} KiB", s.cache_peak_bytes as f64 / 1024.0);
    println!("entries/layer: {:.1}", s.cache_entries_per_layer);
    println!(
        "host transfer: {:.1} KiB up / {:.1} KiB down ({:.2} KiB down/step)",
        s.h2d_bytes as f64 / 1024.0,
        s.d2h_bytes as f64 / 1024.0,
        s.d2h_bytes_per_step() / 1024.0
    );
    Ok(())
}

fn cmd_serve(args: &[String]) -> Result<()> {
    let p = Cli::new("foresight serve", "start the TCP JSON-lines server")
        .opt("addr", "127.0.0.1:7878", "bind address")
        .opt("workers", "2", "worker threads (single-device pool; ignored with --devices > 1)")
        .opt(
            "devices",
            "1",
            "runtime replicas to shard the scheduler across (1 = classic single-device server)",
        )
        .opt(
            "models",
            "opensora-sim:240p-2s",
            "comma list of model:bucket pairs to load",
        )
        .opt(
            "max-batch",
            "4",
            "max sessions sharing one cohort's device pass (1 disables)",
        )
        .opt(
            "admit-ms",
            "0",
            "wait before a fresh cohort's first step for batchmates, ms (default 0 = step immediately; late arrivals join at step boundaries)",
        )
        .opt(
            "max-queue",
            "0",
            "per-device queue bound; requests beyond it get the overloaded backpressure response (0 = unbounded)",
        )
        .opt(
            "degrade",
            "0",
            "queue-pressure threshold for policy=auto degradation to a faster in-budget profile point (0 = disabled)",
        )
        .opt(
            "profiles",
            "",
            "tuned profile store (autotune output) enabling policy=auto",
        )
        .parse(args)
        .map_err(|e| anyhow!("{e}"))?;

    let manifest = Manifest::load(&Manifest::default_root())?;
    let devices = p.get_usize("devices").map_err(|e| anyhow!(e))?.max(1);
    let pool = Arc::new(DevicePool::cpu(devices)?);
    let pairs: Vec<(String, String)> = p
        .get_list("models")
        .iter()
        .map(|s| {
            s.split_once(':')
                .map(|(m, b)| (m.to_string(), b.to_string()))
                .ok_or_else(|| anyhow!("--models entries must be model:bucket, got '{s}'"))
        })
        .collect::<Result<_>>()?;
    let profiles = match p.get("profiles") {
        "" => None,
        path => {
            let store = ProfileStore::load(Path::new(path))?;
            println!(
                "loaded {} tuned profile(s), store version {} ({path})",
                store.len(),
                store.version()
            );
            Some(Arc::new(store))
        }
    };
    let registry = Arc::new(EngineRegistry::load_pool(pool, &manifest, &pairs)?);
    let server = Server::start(
        registry,
        ServerConfig {
            addr: p.get("addr").to_string(),
            workers: p.get_usize("workers").map_err(|e| anyhow!(e))?,
            devices,
            max_batch: p.get_usize("max-batch").map_err(|e| anyhow!(e))?,
            admit_window_ms: p.get_u64("admit-ms").map_err(|e| anyhow!(e))?,
            profiles,
            max_queue: p.get_usize("max-queue").map_err(|e| anyhow!(e))?,
            degrade_threshold: p.get_usize("degrade").map_err(|e| anyhow!(e))?,
            ..ServerConfig::default()
        },
    )?;
    println!("foresight server listening on {}", server.addr());
    println!("loaded: {pairs:?} on {devices} device(s)");
    println!("press Ctrl-C to stop");
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

/// Parse `1:2,2:3` into (N, R) pairs.
fn parse_nr_list(raw: &[String], flag: &str) -> Result<Vec<(usize, usize)>> {
    raw.iter()
        .map(|s| {
            let (n, r) = s
                .split_once(':')
                .ok_or_else(|| anyhow!("--{flag} entries must be n:r, got '{s}'"))?;
            Ok((
                n.parse().map_err(|_| anyhow!("--{flag}: bad n in '{s}'"))?,
                r.parse().map_err(|_| anyhow!("--{flag}: bad r in '{s}'"))?,
            ))
        })
        .collect()
}

fn parse_f64_list(raw: &[String], flag: &str) -> Result<Vec<f64>> {
    raw.iter()
        .map(|s| {
            s.parse()
                .map_err(|_| anyhow!("--{flag}: '{s}' is not a number"))
        })
        .collect()
}

fn parse_usize_list(raw: &[String], flag: &str) -> Result<Vec<usize>> {
    raw.iter()
        .map(|s| {
            s.parse()
                .map_err(|_| anyhow!("--{flag}: '{s}' is not a non-negative integer"))
        })
        .collect()
}

fn cmd_autotune(args: &[String]) -> Result<()> {
    let p = Cli::new(
        "foresight autotune",
        "profile policy configurations for one (model, bucket, steps); write tuned profiles",
    )
    .opt("model", "opensora-sim", "model preset")
    .opt("bucket", "240p-2s", "shape bucket")
    .opt("steps", "", "denoising steps to profile at (default: preset)")
    .opt("gammas", "0.25,0.5,1,2", "comma list of Foresight gamma values")
    .opt("warmups", "0.15", "comma list of Foresight warmup fractions")
    .opt("nr", "1:2,2:3", "comma list of Foresight n:r cycle shapes")
    .opt("static-nr", "1:2,2:3", "comma list of static-baseline n:r points")
    .opt("orders", "1,2,3", "comma list of forecast predictor orders k (k>=2 wraps each Foresight point)")
    .opt("prompts", "4", "prompt-panel size")
    .opt("min-psnr", "30", "quality budget: min mean PSNR (dB) vs NoReuse")
    .opt("out", "results/profiles.json", "profile store output path")
    .flag("merge", "merge into an existing store at --out instead of replacing it")
    .parse(args)
    .map_err(|e| anyhow!("{e}"))?;

    // Like the fig benches: a clean SKIP (not an error) without the AOT
    // artifacts, so CI smoke runs pass on hosted runners.
    let root = Manifest::default_root();
    if !root.join("manifest.json").exists() {
        println!(
            "[autotune] SKIP: artifacts unavailable ({}); run `make artifacts`",
            root.display()
        );
        return Ok(());
    }

    let engine = load_engine(p.get("model"), p.get("bucket"))?;
    let opts = ProfileOptions {
        steps: if p.get("steps").is_empty() {
            None
        } else {
            Some(p.get_usize("steps").map_err(|e| anyhow!(e))?)
        },
        prompts: p.get_usize("prompts").map_err(|e| anyhow!(e))?,
        min_psnr: p.get_f64("min-psnr").map_err(|e| anyhow!(e))?,
        grid: GridSpec {
            nr: parse_nr_list(&p.get_list("nr"), "nr")?,
            gammas: parse_f64_list(&p.get_list("gammas"), "gammas")?,
            warmups: parse_f64_list(&p.get_list("warmups"), "warmups")?,
            static_nr: parse_nr_list(&p.get_list("static-nr"), "static-nr")?,
            orders: parse_usize_list(&p.get_list("orders"), "orders")?,
        },
    };
    let outcome = profile_engine(&engine, &opts)?;
    let profile = &outcome.profile;

    println!("profiled {} (budget: PSNR >= {} dB)\n", profile.key, profile.min_psnr);
    println!("{}", sweep_table(&outcome).to_markdown());

    let out = Path::new(p.get("out"));
    let mut store = if p.get_flag("merge") && out.exists() {
        ProfileStore::load(out)?
    } else {
        ProfileStore::new()
    };
    store.insert(outcome.profile);
    store.save(out)?;
    println!(
        "wrote {} ({} profile(s), store version {})",
        out.display(),
        store.len(),
        store.version()
    );
    println!("serve it with: foresight serve --profiles {} (requests: policy=auto)", out.display());
    Ok(())
}

fn cmd_analyze(args: &[String]) -> Result<()> {
    let p = Cli::new("foresight analyze", "dump feature-dynamics CSV")
        .opt("model", "analysis", "model preset (28 layer pairs)")
        .opt("bucket", "240p-2s", "shape bucket")
        .opt("prompt", "a calm lake at dawn, soft golden light", "text prompt")
        .opt("seed", "0", "random seed")
        .opt("out", "results/analyze_mse.csv", "output CSV path")
        .parse(args)
        .map_err(|e| anyhow!("{e}"))?;

    let engine = load_engine(p.get("model"), p.get("bucket"))?;
    let info = engine.model().info.clone();
    let mut policy = build_policy("none", &info, info.steps)?;
    let mut rec = DynamicsRecorder::new();
    let req = Request::new(p.get("prompt"), p.get_u64("seed").map_err(|e| anyhow!(e))?);
    engine.generate(&req, policy.as_mut(), Some(&mut rec))?;

    let mut csv = String::from("layer,step,mse_spatial,mse_temporal\n");
    for (step, row) in &rec.step_mse {
        for layer in 0..info.layers {
            let ms = row.get(&(layer, BlockKind::Spatial)).copied().unwrap_or(0.0);
            let mt = row.get(&(layer, BlockKind::Temporal)).copied().unwrap_or(0.0);
            csv.push_str(&format!("{layer},{step},{ms:.6e},{mt:.6e}\n"));
        }
    }
    let out = p.get("out");
    if let Some(dir) = std::path::Path::new(out).parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(out, csv)?;
    println!("wrote {out}");
    Ok(())
}

fn cmd_lint(args: &[String]) -> Result<()> {
    let p = Cli::new(
        "foresight lint",
        "project-invariant static analysis: lock order, I/O under lock, panic paths, ledger drift",
    )
    .opt("src", "", "source root to scan (default: ./src, else the crate's own src)")
    .opt("allow", "", "allowlist file (default: lint.allow next to the source root)")
    .flag("verbose", "also print allowlisted findings and their justifications")
    .parse(args)
    .map_err(|e| anyhow!("{e}"))?;

    let src = match p.get("src") {
        "" => {
            let local = Path::new("src");
            if local.is_dir() {
                local.to_path_buf()
            } else {
                Path::new(env!("CARGO_MANIFEST_DIR")).join("src")
            }
        }
        s => Path::new(s).to_path_buf(),
    };
    let allow_path = match p.get("allow") {
        "" => match src.parent() {
            Some(dir) => dir.join("lint.allow"),
            None => Path::new("lint.allow").to_path_buf(),
        },
        s => Path::new(s).to_path_buf(),
    };

    let files = collect_sources(&src)?;
    let allow = if allow_path.exists() {
        Allowlist::load(&allow_path)?
    } else {
        Allowlist::default()
    };

    let findings = run_all(&files);
    let mut used = vec![false; allow.entries.len()];
    let mut blocking = 0usize;
    let mut allowed = 0usize;
    for f in &findings {
        match allow.permits(f) {
            Some(i) => {
                used[i] = true;
                allowed += 1;
                if p.get_flag("verbose") {
                    println!("allowed: {f}\n         ({})", allow.entries[i].justification);
                }
            }
            None => {
                blocking += 1;
                println!("{f}");
            }
        }
    }
    for (i, e) in allow.entries.iter().enumerate() {
        if !used[i] {
            println!(
                "warning: {}:{}: allowlist entry `{}|{}|{}` matches nothing — remove it",
                allow_path.display(),
                e.line,
                e.pass,
                e.file_suffix,
                e.pattern
            );
        }
    }
    println!(
        "lint: {} file(s), {} finding(s) ({} allowlisted, {} blocking)",
        files.len(),
        findings.len(),
        allowed,
        blocking
    );
    if blocking > 0 {
        return Err(anyhow!(
            "{blocking} non-allowlisted finding(s); fix them or add a justified entry to {}",
            allow_path.display()
        ));
    }
    Ok(())
}

fn cmd_trace(args: &[String]) -> Result<()> {
    let p = Cli::new(
        "foresight trace",
        "drain trace events and write a Chrome trace-event JSON document",
    )
    .opt(
        "addr",
        "",
        "running server to drain via {\"op\":\"trace\"}, e.g. 127.0.0.1:7878",
    )
    .opt("since", "0", "drain events with seq >= since (a previous run's `next`)")
    .opt("out", "results/trace.json", "output path for the Chrome trace document")
    .flag(
        "demo",
        "no server: record a synthetic request span with the in-process tracer and export it",
    )
    .parse(args)
    .map_err(|e| anyhow!("{e}"))?;

    let since = p.get_u64("since").map_err(|e| anyhow!(e))?;
    let (events, next) = if p.get_flag("demo") {
        // Hermetic path (CI smoke): exercise the real tracer, renderer
        // and parser without artifacts or a live server.
        let t = trace::global();
        t.enable(true);
        let id = t.next_trace_id();
        trace::emit(id, trace::Payload::Begin);
        trace::emit(id, trace::Payload::Enqueue { device: 0, depth: 1 });
        trace::emit(id, trace::Payload::Admit { device: 0, queue_us: 120 });
        trace::emit_dur(id, 850, trace::Payload::Pass { device: 0, occupancy: 1 });
        trace::emit(
            id,
            trace::Payload::Policy {
                step: 0,
                branch: 0,
                site: 0,
                reuse: false,
                predict: false,
                mse: 0.01,
                lambda: 0.02,
            },
        );
        trace::emit(id, trace::Payload::Retire { device: 0, steps: 1 });
        trace::emit(id, trace::Payload::End { ok: true });
        let d = t.drain(since);
        let evs: Vec<Json> = d.events.iter().map(trace::chrome::event_json).collect();
        (evs, d.next)
    } else {
        let addr = p.get("addr");
        if addr.is_empty() {
            return Err(anyhow!(
                "pass --addr <host:port> (a running `foresight serve`) or --demo"
            ));
        }
        let sock: SocketAddr = addr
            .parse()
            .map_err(|e| anyhow!("--addr '{addr}': {e}"))?;
        let mut client = Client::connect(&sock)?;
        let resp = client.call(&Json::obj(vec![
            ("op", Json::str("trace")),
            ("since", Json::num(since as f64)),
        ]))?;
        if resp.get("status").and_then(|v| v.as_str()) != Some("ok") {
            return Err(anyhow!("trace op failed: {resp}"));
        }
        let evs = resp
            .get("events")
            .and_then(|v| v.as_arr())
            .map(|a| a.to_vec())
            .unwrap_or_default();
        let next = resp.get("next").and_then(|v| v.as_u64()).unwrap_or(since);
        if let Some(dropped) = resp.get("dropped").and_then(|v| v.as_u64()) {
            if dropped > 0 {
                eprintln!("note: the tracer has dropped {dropped} event(s) so far (bounded rings)");
            }
        }
        (evs, next)
    };

    let n = events.len();
    let doc = trace::chrome::document(events);
    let text = doc.to_string();
    // The export contract: the document must round-trip our own parser
    // (what the fig23 bench asserts; Chrome/Perfetto accept a superset).
    json::parse(&text).map_err(|e| anyhow!("internal: rendered trace does not re-parse: {e}"))?;
    let out = p.get("out");
    if let Some(dir) = Path::new(out).parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(out, &text)?;
    println!("wrote {out} ({n} event(s); resume with --since {next})");
    Ok(())
}

fn cmd_info(_args: &[String]) -> Result<()> {
    let manifest = Manifest::load(&Manifest::default_root())?;
    println!("artifacts: {}", manifest.root.display());
    println!(
        "schedule: T={} beta=[{}, {}]",
        manifest.schedule.train_timesteps,
        manifest.schedule.beta_start,
        manifest.schedule.beta_end
    );
    for (name, m) in &manifest.models {
        println!(
            "\n{name}: L={} D={} heads={} sampler={} steps={} cfg={}",
            m.layers,
            m.d_model,
            m.n_heads,
            m.sampler.name(),
            m.steps,
            m.cfg_scale
        );
        for (bname, b) in &m.buckets {
            println!("  bucket {bname}: {}x{} patches × {} frames", b.ph, b.pw, b.frames);
        }
    }
    Ok(())
}
