//! Chrome trace-event rendering for drained [`Event`]s.
//!
//! Produces the Trace Event Format consumed by Perfetto / `chrome://
//! tracing`: request spans render as async begin/end pairs (`ph:"b"` /
//! `ph:"e"`) keyed by `trace_id`, fused cohort passes as complete events
//! (`ph:"X"` with `dur`), and everything else as thread-scoped instants
//! (`ph:"i"`). Timestamps are the tracer-epoch microseconds the format
//! expects. [`document`] wraps a rendered batch in the standard
//! `{"traceEvents":[...]}` envelope, which [`crate::util::json::parse`]
//! round-trips — the fig23 bench and the `foresight trace` CLI both rely
//! on that.

use super::{Event, Payload};
use crate::util::json::Json;

/// Render one event as a Chrome trace-event object.
pub fn event_json(ev: &Event) -> Json {
    let mut fields: Vec<(&str, Json)> = vec![
        ("name", Json::str(ev.payload.name())),
        ("pid", Json::num(1.0)),
        ("tid", Json::num(ev.tid as f64)),
        ("ts", Json::num(ev.ts_us as f64)),
        ("seq", Json::num(ev.seq as f64)),
    ];
    let mut args: Vec<(&str, Json)> = Vec::new();
    if ev.trace_id != 0 {
        args.push(("trace_id", Json::num(ev.trace_id as f64)));
    }
    match ev.payload {
        Payload::Begin => {
            fields.push(("ph", Json::str("b")));
            fields.push(("cat", Json::str("request")));
            fields.push(("id", Json::num(ev.trace_id as f64)));
        }
        Payload::End { ok } => {
            fields.push(("ph", Json::str("e")));
            fields.push(("cat", Json::str("request")));
            fields.push(("id", Json::num(ev.trace_id as f64)));
            args.push(("ok", Json::Bool(ok)));
        }
        Payload::Pass { device, occupancy } => {
            fields.push(("ph", Json::str("X")));
            fields.push(("dur", Json::num(ev.dur_us as f64)));
            args.push(("device", Json::num(device as f64)));
            args.push(("occupancy", Json::num(occupancy as f64)));
        }
        Payload::Enqueue { device, depth } => {
            instant(&mut fields);
            args.push(("device", Json::num(device as f64)));
            args.push(("depth", Json::num(depth as f64)));
        }
        Payload::Reject { depth } => {
            instant(&mut fields);
            args.push(("depth", Json::num(depth as f64)));
        }
        Payload::DeadlineMiss { at } => {
            instant(&mut fields);
            args.push(("at", Json::str(at)));
        }
        Payload::Admit { device, queue_us } => {
            instant(&mut fields);
            args.push(("device", Json::num(device as f64)));
            args.push(("queue_us", Json::num(queue_us as f64)));
        }
        Payload::Join { device, lanes } => {
            instant(&mut fields);
            args.push(("device", Json::num(device as f64)));
            args.push(("lanes", Json::num(lanes as f64)));
        }
        Payload::Retire { device, steps } => {
            instant(&mut fields);
            args.push(("device", Json::num(device as f64)));
            args.push(("steps", Json::num(steps as f64)));
        }
        Payload::Steal { device, victim } => {
            instant(&mut fields);
            args.push(("device", Json::num(device as f64)));
            args.push(("victim", Json::num(victim as f64)));
        }
        Payload::Migrate { from, to } => {
            instant(&mut fields);
            args.push(("from", Json::num(from as f64)));
            args.push(("to", Json::num(to as f64)));
        }
        Payload::Degrade => {
            instant(&mut fields);
        }
        Payload::Policy { step, branch, site, reuse, predict, mse, lambda } => {
            instant(&mut fields);
            args.push(("step", Json::num(step as f64)));
            args.push(("branch", Json::num(branch as f64)));
            args.push(("site", Json::num(site as f64)));
            let action = if predict {
                "predict"
            } else if reuse {
                "reuse"
            } else {
                "compute"
            };
            args.push(("action", Json::str(action)));
            if mse >= 0.0 {
                args.push(("mse", Json::num(mse)));
            }
            if lambda >= 0.0 {
                args.push(("lambda", Json::num(lambda)));
            }
        }
        Payload::H2d { bytes } => {
            instant(&mut fields);
            args.push(("bytes", Json::num(bytes as f64)));
        }
        Payload::D2h { bytes } => {
            instant(&mut fields);
            args.push(("bytes", Json::num(bytes as f64)));
        }
    }
    fields.push(("args", Json::obj(args)));
    Json::obj(fields)
}

/// Mark the event under construction as a thread-scoped instant.
fn instant(fields: &mut Vec<(&'static str, Json)>) {
    fields.push(("ph", Json::str("i")));
    fields.push(("s", Json::str("t")));
}

/// Wrap rendered events in the Chrome trace envelope.
pub fn document(events: Vec<Json>) -> Json {
    Json::obj(vec![
        ("displayTimeUnit", Json::str("ms")),
        ("traceEvents", Json::arr(events)),
    ])
}

/// Render a drained batch straight to the envelope.
pub fn render(events: &[Event]) -> Json {
    document(events.iter().map(event_json).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::Tracer;
    use crate::util::json;

    #[test]
    fn rendered_document_reparses_with_span_pair() {
        let t = Tracer::new(true, 256);
        let id = t.next_trace_id();
        t.record(id, 0, Payload::Begin);
        t.record(id, 0, Payload::Enqueue { device: 0, depth: 1 });
        t.record(id, 0, Payload::Admit { device: 0, queue_us: 42 });
        t.record(id, 900, Payload::Pass { device: 0, occupancy: 2 });
        t.record(
            id,
            0,
            Payload::Policy {
                step: 1,
                branch: 0,
                site: 3,
                reuse: true,
                predict: false,
                mse: 0.25,
                lambda: 0.5,
            },
        );
        t.record(id, 0, Payload::Retire { device: 0, steps: 8 });
        t.record(id, 0, Payload::End { ok: true });

        let doc = render(&t.drain(0).events);
        let parsed = json::parse(&doc.to_string()).expect("chrome JSON must re-parse");
        let evs = parsed
            .get("traceEvents")
            .and_then(|v| v.as_arr())
            .expect("traceEvents array");
        assert_eq!(evs.len(), 7);

        // Exactly one async begin and one async end, both keyed by the
        // request's trace id.
        let phs: Vec<&str> =
            evs.iter().filter_map(|e| e.get("ph").and_then(|p| p.as_str())).collect();
        assert_eq!(phs.iter().filter(|p| **p == "b").count(), 1);
        assert_eq!(phs.iter().filter(|p| **p == "e").count(), 1);
        for e in evs {
            let ph = e.get("ph").and_then(|p| p.as_str()).expect("ph");
            match ph {
                "b" | "e" => {
                    assert_eq!(e.get("id").and_then(|v| v.as_u64()), Some(id));
                }
                "X" => {
                    assert_eq!(e.get("dur").and_then(|v| v.as_u64()), Some(900));
                    let args = e.get("args").expect("args");
                    assert_eq!(args.get("occupancy").and_then(|v| v.as_u64()), Some(2));
                }
                "i" => {
                    assert_eq!(e.get("s").and_then(|v| v.as_str()), Some("t"));
                }
                other => panic!("unexpected ph {other:?}"),
            }
        }

        // The policy instant carries the reuse decision and both scalars.
        let pol = evs
            .iter()
            .find(|e| e.get("name").and_then(|n| n.as_str()) == Some("policy"))
            .expect("policy event");
        let args = pol.get("args").expect("args");
        assert_eq!(args.get("action").and_then(|v| v.as_str()), Some("reuse"));
        assert_eq!(args.get("mse").and_then(|v| v.as_f64()), Some(0.25));
        assert_eq!(args.get("lambda").and_then(|v| v.as_f64()), Some(0.5));
    }

    #[test]
    fn unmeasured_policy_event_omits_scalars() {
        let ev = Event {
            seq: 0,
            ts_us: 10,
            dur_us: 0,
            tid: 1,
            trace_id: 5,
            payload: Payload::Policy {
                step: 2,
                branch: 1,
                site: 0,
                reuse: false,
                predict: false,
                mse: -1.0,
                lambda: -1.0,
            },
        };
        let j = event_json(&ev);
        let args = j.get("args").expect("args");
        assert!(args.get("mse").is_none());
        assert!(args.get("lambda").is_none());
        assert_eq!(args.get("action").and_then(|v| v.as_str()), Some("compute"));
    }

    #[test]
    fn forecast_policy_event_renders_predict_action() {
        let ev = Event {
            seq: 0,
            ts_us: 10,
            dur_us: 0,
            tid: 1,
            trace_id: 5,
            payload: Payload::Policy {
                step: 4,
                branch: 0,
                site: 2,
                reuse: true,
                predict: true,
                mse: -1.0,
                lambda: 0.5,
            },
        };
        let j = event_json(&ev);
        let args = j.get("args").expect("args");
        assert_eq!(args.get("action").and_then(|v| v.as_str()), Some("predict"));
    }
}
