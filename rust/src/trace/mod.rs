//! Structured per-step event tracing — request timelines, reuse-decision
//! timelines, and the drain behind the `trace` wire op / `foresight trace`
//! CLI.
//!
//! The serving stack's aggregate telemetry (`server::Telemetry`, the
//! `stats` op) answers "how is the fleet doing"; this module answers
//! "where did request X's wall-clock go" and "which sites did the policy
//! reuse at which steps, at what drift". Every layer emits [`Event`]s
//! tagged with a `trace_id` allocated per request at the wire front:
//!
//! * **server** — request span begin/end, enqueue depth, overload rejects,
//!   deadline misses;
//! * **scheduler** — cohort admit/join/retire, job steals, session
//!   migrations, degrade swaps, and one complete (`dur_us`) event per
//!   fused cohort pass carrying device ordinal and occupancy;
//! * **engine/session** — one [`Payload::Policy`] instant per measured
//!   site per step per CFG branch: predict / reuse / compute, observed
//!   drift MSE, and the policy's λ threshold at that site;
//! * **runtime** — h2d/d2h transfer events mirroring the
//!   `runtime::TransferStats` byte model, attributed to the emitting
//!   thread's current trace scope ([`scope`]).
//!
//! # Never block, never grow: drop instead
//!
//! Emission must be safe from under any lock in the system and from every
//! hot path, so the tracer is **always compiled, runtime-toggled**
//! ([`Tracer::enable`]; a single relaxed atomic load when off) and writes
//! into **bounded ring shards** guarded by `util::sync::OrderedMutex` at
//! [`RANK_TRACE_RING`] — the highest rank in the table, so holding any
//! other lock while emitting is rank-legal. The emit path only ever uses
//! `try_lock`: shard contention **drops the event and increments a drop
//! counter** instead of waiting, and a full ring **evicts its oldest
//! event** (also counted) instead of allocating. `trace_events` /
//! `trace_drops` surface through the `stats` and `metrics` ops.
//!
//! # Draining
//!
//! [`Tracer::drain`] is non-destructive and cursor-based: pass the `next`
//! sequence number returned by the previous drain to read incrementally
//! (the `{"op":"trace","since":N}` wire op is exactly this). Sequence
//! numbers are globally ordered; gaps are dropped events. [`chrome`]
//! renders drained events as Chrome trace-event JSON (Perfetto-loadable).
//!
//! # Environment
//!
//! * `FORESIGHT_TRACE` — `1`/`true`/`on` starts the process-wide tracer
//!   enabled (it can also be toggled at runtime, e.g. via the `trace`
//!   wire op's `enable` flag).
//! * `FORESIGHT_TRACE_RING` — per-shard ring capacity in events
//!   (default 16384; floor 2). Small values force overflow, which the
//!   fig23 bench uses to prove drops never stall a step boundary.

use std::cell::Cell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

use crate::util::sync::{OrderedMutex, RANK_TRACE_RING};

pub mod chrome;

/// Number of ring shards. Threads map to shards by their dense trace
/// ordinal, so two hot threads rarely contend on one shard.
const SHARDS: usize = 8;

/// Default per-shard ring capacity (events). At ~96 B/event the default
/// tracer retains ~12 MiB of history process-wide.
const DEFAULT_RING: usize = 16384;

/// Kind-specific data carried by an [`Event`]. Fixed-size and `Copy` so a
/// ring slot never owns heap memory.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Payload {
    /// Request span opens: the generate op was accepted off the wire.
    Begin,
    /// Request span closes: the reply was produced (`ok:false` = error,
    /// reject or deadline miss).
    End { ok: bool },
    /// Request routed into a device queue at the given depth.
    Enqueue { device: u64, depth: u64 },
    /// Request refused by bounded admission (every candidate queue full).
    Reject { depth: u64 },
    /// Deadline expired; `at` is the enforcement point
    /// (`"queue"` / `"admit"` / `"lane"`).
    DeadlineMiss { at: &'static str },
    /// Session started on a device after `queue_us` microseconds queued.
    Admit { device: u64, queue_us: u64 },
    /// Session joined an in-flight cohort (lane count after the join).
    Join { device: u64, lanes: u64 },
    /// Session finished and left the cohort after `steps` steps.
    Retire { device: u64, steps: u64 },
    /// Idle device pulled a queued job routed to `victim`.
    Steal { device: u64, victim: u64 },
    /// Running session moved between devices at a step boundary.
    Migrate { from: u64, to: u64 },
    /// Queue pressure swapped `policy:"auto"` to a faster frontier tier.
    Degrade,
    /// One fused cohort pass at a step boundary: a complete event whose
    /// `dur_us` is the pass wall time; occupancy = lanes advanced.
    Pass { device: u64, occupancy: u64 },
    /// One per-site reuse decision: at `step`, CFG `branch`, measured
    /// site index `site`, the policy chose reuse (true) or compute.
    /// `predict` refines a reuse: true means the site's output was
    /// forecast from its history (`lms_combine`) rather than replayed.
    /// `mse` is the observed drift (negative = not measured this step)
    /// and `lambda` the policy's threshold at that site (negative =
    /// no threshold recorded).
    Policy { step: u32, branch: u8, site: u32, reuse: bool, predict: bool, mse: f64, lambda: f64 },
    /// Host→device transfer (bytes), from `runtime::TransferStats`.
    H2d { bytes: u64 },
    /// Device→host transfer (bytes), from `runtime::TransferStats`.
    D2h { bytes: u64 },
}

impl Payload {
    /// Stable lowercase event name used in exports.
    pub fn name(&self) -> &'static str {
        match self {
            Payload::Begin | Payload::End { .. } => "request",
            Payload::Enqueue { .. } => "enqueue",
            Payload::Reject { .. } => "reject",
            Payload::DeadlineMiss { .. } => "deadline_miss",
            Payload::Admit { .. } => "admit",
            Payload::Join { .. } => "join",
            Payload::Retire { .. } => "retire",
            Payload::Steal { .. } => "steal",
            Payload::Migrate { .. } => "migrate",
            Payload::Degrade => "degrade",
            Payload::Pass { .. } => "pass",
            Payload::Policy { .. } => "policy",
            Payload::H2d { .. } => "h2d",
            Payload::D2h { .. } => "d2h",
        }
    }
}

/// One traced occurrence. `Copy` and pointer-free by construction.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    /// Global emission order; gaps in a drain mean dropped events.
    pub seq: u64,
    /// Microseconds since the tracer's epoch (monotonic process-wide,
    /// hence monotonic per thread).
    pub ts_us: u64,
    /// Wall duration for complete events ([`Payload::Pass`]); 0 otherwise.
    pub dur_us: u64,
    /// Dense per-thread ordinal (assigned at a thread's first emission).
    pub tid: u64,
    /// Request span this event belongs to; 0 = unattributed.
    pub trace_id: u64,
    pub payload: Payload,
}

/// Bounded event ring: push evicts the oldest entry when full.
struct Ring {
    buf: VecDeque<Event>,
    cap: usize,
}

impl Ring {
    fn new(cap: usize) -> Self {
        Ring { buf: VecDeque::with_capacity(cap.min(1024)), cap }
    }

    /// Append `ev`; returns false when an old event was evicted to make
    /// room (an overflow drop).
    fn push(&mut self, ev: Event) -> bool {
        let clean = self.buf.len() < self.cap;
        if !clean {
            self.buf.pop_front();
        }
        self.buf.push_back(ev);
        clean
    }
}

/// Result of a [`Tracer::drain`]: events at `seq >= since` still resident
/// in the rings, ordered by `seq`.
#[derive(Debug)]
pub struct Drained {
    pub events: Vec<Event>,
    /// Cursor for the next incremental drain (`last seq + 1`, or the
    /// `since` that was passed when nothing matched).
    pub next: u64,
    /// Total events ever ring-buffered by this tracer.
    pub emitted: u64,
    /// Total events lost to shard contention or ring overflow.
    pub dropped: u64,
    /// Whether the tracer is currently recording.
    pub enabled: bool,
}

/// Process-wide event tracer. See the module docs for the design; almost
/// all callers go through the free functions ([`emit`], [`scope`]) and
/// [`global`].
pub struct Tracer {
    enabled: AtomicBool,
    epoch: Instant,
    seq: AtomicU64,
    next_trace_id: AtomicU64,
    events_total: AtomicU64,
    drops_total: AtomicU64,
    shards: Vec<OrderedMutex<Ring>>,
}

impl Tracer {
    /// Build a tracer with an explicit initial state and per-shard ring
    /// capacity. Unit tests use private instances; production code shares
    /// [`global`].
    pub fn new(enabled: bool, ring_cap: usize) -> Self {
        let cap = ring_cap.max(2);
        let mut shards = Vec::with_capacity(SHARDS);
        for _ in 0..SHARDS {
            shards.push(OrderedMutex::new("trace.ring", RANK_TRACE_RING, Ring::new(cap)));
        }
        Tracer {
            enabled: AtomicBool::new(enabled),
            epoch: Instant::now(),
            seq: AtomicU64::new(0),
            next_trace_id: AtomicU64::new(1),
            events_total: AtomicU64::new(0),
            drops_total: AtomicU64::new(0),
            shards,
        }
    }

    fn from_env() -> Self {
        let enabled = std::env::var("FORESIGHT_TRACE")
            .map(|v| matches!(v.as_str(), "1" | "true" | "on"))
            .unwrap_or(false);
        let cap = std::env::var("FORESIGHT_TRACE_RING")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .unwrap_or(DEFAULT_RING);
        Tracer::new(enabled, cap)
    }

    /// Is the tracer currently recording?
    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Toggle recording at runtime. Disabling keeps already-buffered
    /// events drainable.
    pub fn enable(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    /// Allocate a fresh nonzero request trace id.
    pub fn next_trace_id(&self) -> u64 {
        self.next_trace_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Total events ring-buffered so far (monotonic; includes events that
    /// have since scrolled out of the rings).
    pub fn events_total(&self) -> u64 {
        self.events_total.load(Ordering::Relaxed)
    }

    /// Total events dropped so far (shard contention + ring overflow).
    pub fn drops_total(&self) -> u64 {
        self.drops_total.load(Ordering::Relaxed)
    }

    /// Record one event. Never blocks: a contended shard or full ring
    /// drops instead (see module docs). `dur_us` is nonzero only for
    /// complete events like [`Payload::Pass`].
    pub fn record(&self, trace_id: u64, dur_us: u64, payload: Payload) {
        if !self.enabled.load(Ordering::Relaxed) {
            return;
        }
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let ts_us = self.epoch.elapsed().as_micros() as u64;
        let tid = tid();
        let ev = Event { seq, ts_us, dur_us, tid, trace_id, payload };
        match self.shards[(tid as usize) % self.shards.len()].try_lock() {
            Some(mut guard) => {
                let clean = guard.push(ev);
                self.events_total.fetch_add(1, Ordering::Relaxed);
                if !clean {
                    self.drops_total.fetch_add(1, Ordering::Relaxed);
                }
            }
            None => {
                self.drops_total.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Non-destructive cursor drain: every resident event with
    /// `seq >= since`, ordered by `seq`. Pass the returned `next` back in
    /// to read incrementally.
    pub fn drain(&self, since: u64) -> Drained {
        let mut events = Vec::new();
        for ring in &self.shards {
            let guard = ring.lock();
            for ev in guard.buf.iter() {
                if ev.seq >= since {
                    events.push(*ev);
                }
            }
        }
        events.sort_by_key(|e| e.seq);
        let next = events.last().map_or(since, |e| e.seq + 1);
        Drained {
            events,
            next,
            emitted: self.events_total(),
            dropped: self.drops_total(),
            enabled: self.enabled(),
        }
    }
}

static GLOBAL: OnceLock<Tracer> = OnceLock::new();

/// The process-wide tracer, initialized from the environment on first use
/// (`FORESIGHT_TRACE`, `FORESIGHT_TRACE_RING`).
pub fn global() -> &'static Tracer {
    GLOBAL.get_or_init(Tracer::from_env)
}

/// Emit one instant event on the global tracer.
pub fn emit(trace_id: u64, payload: Payload) {
    global().record(trace_id, 0, payload);
}

/// Emit one complete event (with wall duration) on the global tracer.
pub fn emit_dur(trace_id: u64, dur_us: u64, payload: Payload) {
    global().record(trace_id, dur_us, payload);
}

/// Emit one instant event attributed to the thread's current scope
/// ([`scope`]); used by layers that don't carry a trace id explicitly
/// (e.g. runtime transfers).
pub fn emit_here(payload: Payload) {
    global().record(current(), 0, payload);
}

static NEXT_TID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static TID: u64 = NEXT_TID.fetch_add(1, Ordering::Relaxed);
    static CURRENT: Cell<u64> = const { Cell::new(0) };
}

/// This thread's dense trace ordinal (stable for the thread's lifetime).
fn tid() -> u64 {
    TID.with(|t| *t)
}

/// The trace id currently attributed to this thread (0 = none).
pub fn current() -> u64 {
    CURRENT.with(|c| c.get())
}

/// Set this thread's trace attribution directly. Prefer [`scope`] where
/// the attribution has a lexical extent; long-lived per-request worker
/// threads (session branch workers) set it once at startup.
pub fn set_current(id: u64) {
    CURRENT.with(|c| c.set(id));
}

/// RAII trace attribution: events emitted by this thread while the guard
/// lives (including [`emit_here`] from callees) belong to `id`; the
/// previous attribution is restored on drop.
#[must_use = "dropping the scope immediately restores the previous trace id"]
pub struct Scope {
    prev: u64,
}

pub fn scope(id: u64) -> Scope {
    let prev = current();
    set_current(id);
    Scope { prev }
}

impl Drop for Scope {
    fn drop(&mut self) {
        set_current(self.prev);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_tracer_records_nothing() {
        let t = Tracer::new(false, 64);
        t.record(1, 0, Payload::Begin);
        t.record(1, 0, Payload::End { ok: true });
        let d = t.drain(0);
        assert!(d.events.is_empty());
        assert_eq!(d.next, 0);
        assert_eq!(d.emitted, 0);
        assert_eq!(d.dropped, 0);
        assert!(!d.enabled);
    }

    #[test]
    fn drain_is_cursor_incremental_and_seq_ordered() {
        let t = Tracer::new(true, 1024);
        for i in 0..5 {
            t.record(i, 0, Payload::Enqueue { device: 0, depth: i });
        }
        let d1 = t.drain(0);
        assert_eq!(d1.events.len(), 5);
        assert!(d1.events.windows(2).all(|w| w[0].seq < w[1].seq));
        assert_eq!(d1.next, d1.events.last().map(|e| e.seq + 1).expect("nonempty"));
        // Nothing new: same cursor comes back.
        let d2 = t.drain(d1.next);
        assert!(d2.events.is_empty());
        assert_eq!(d2.next, d1.next);
        // New events appear after the cursor; old ones stay readable from 0.
        t.record(9, 0, Payload::Reject { depth: 3 });
        let d3 = t.drain(d1.next);
        assert_eq!(d3.events.len(), 1);
        assert_eq!(d3.events[0].trace_id, 9);
        assert_eq!(t.drain(0).events.len(), 6);
    }

    #[test]
    fn overflow_drops_oldest_and_counts() {
        // All events from one thread land in one shard, so a tiny cap
        // forces eviction deterministically.
        let t = Tracer::new(true, 4);
        for i in 0..10u64 {
            t.record(i, 0, Payload::H2d { bytes: i });
        }
        assert_eq!(t.events_total(), 10);
        assert_eq!(t.drops_total(), 6);
        let d = t.drain(0);
        assert_eq!(d.events.len(), 4);
        // The survivors are the newest four, in order.
        let ids: Vec<u64> = d.events.iter().map(|e| e.trace_id).collect();
        assert_eq!(ids, vec![6, 7, 8, 9]);
        assert_eq!(d.dropped, 6);
    }

    #[test]
    fn timestamps_are_monotonic_per_thread() {
        let t = Tracer::new(true, 1024);
        for _ in 0..100 {
            t.record(1, 0, Payload::D2h { bytes: 4 });
        }
        let d = t.drain(0);
        assert_eq!(d.events.len(), 100);
        assert!(d.events.windows(2).all(|w| w[0].ts_us <= w[1].ts_us));
        // Single-threaded test: every event shares this thread's tid.
        assert!(d.events.iter().all(|e| e.tid == d.events[0].tid));
    }

    #[test]
    fn scope_nests_and_restores() {
        assert_eq!(current(), 0);
        {
            let _a = scope(7);
            assert_eq!(current(), 7);
            {
                let _b = scope(8);
                assert_eq!(current(), 8);
            }
            assert_eq!(current(), 7);
        }
        assert_eq!(current(), 0);
    }

    #[test]
    fn trace_ids_are_fresh_and_nonzero() {
        let t = Tracer::new(true, 16);
        let a = t.next_trace_id();
        let b = t.next_trace_id();
        assert!(a > 0 && b > a);
    }

    #[test]
    fn enable_toggle_gates_recording() {
        let t = Tracer::new(false, 64);
        t.record(1, 0, Payload::Begin);
        t.enable(true);
        t.record(1, 0, Payload::Begin);
        t.enable(false);
        t.record(1, 0, Payload::Begin);
        let d = t.drain(0);
        assert_eq!(d.events.len(), 1);
        assert_eq!(d.emitted, 1);
    }
}
