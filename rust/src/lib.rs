//! # Foresight — adaptive layer reuse for text-to-video DiT serving
//!
//! Rust + JAX + Pallas reproduction of *"Foresight: Adaptive Layer Reuse for
//! Accelerated and High-Quality Text-to-Video Generation"* (NeurIPS 2025).
//!
//! Three layers (DESIGN.md):
//! * **L1** — Pallas kernels (flash attention, fused LN+modulate, fused MLP)
//!   authored in `python/compile/kernels/`, lowered at build time.
//! * **L2** — the ST-DiT model in JAX (`python/compile/model.py`), exported
//!   piece-by-piece to HLO text so each DiT block is an independently
//!   dispatchable executable.
//! * **L3** — this crate: the serving coordinator that makes the paper's
//!   per-layer, per-step reuse decisions on the request path, with Python
//!   never loaded at runtime.
//!
//! Start with [`engine::Engine`] for single requests or [`server`] for the
//! TCP serving front-end; `examples/quickstart.rs` shows the 20-line path.

pub mod analysis;
pub mod cache;
pub mod config;
pub mod engine;
pub mod metrics;
pub mod model;
pub mod policy;
pub mod runtime;
pub mod sampler;
pub mod server;
pub mod util;
pub mod workload;

pub mod bench_support;
