//! # Foresight — adaptive layer reuse for text-to-video DiT serving
//!
//! Rust + JAX + Pallas reproduction of *"Foresight: Adaptive Layer Reuse for
//! Accelerated and High-Quality Text-to-Video Generation"* (NeurIPS 2025).
//!
//! Three layers (DESIGN.md):
//! * **L1** — Pallas kernels (flash attention, fused LN+modulate, fused MLP)
//!   authored in `python/compile/kernels/`, lowered at build time.
//! * **L2** — the ST-DiT model in JAX (`python/compile/model.py`), exported
//!   piece-by-piece to HLO text so each DiT block is an independently
//!   dispatchable executable.
//! * **L3** — this crate: the serving coordinator that makes the paper's
//!   per-layer, per-step reuse decisions on the request path, with Python
//!   never loaded at runtime.
//!
//! Start with [`engine::Engine`] for single requests or [`server`] for the
//! TCP serving front-end; `examples/quickstart.rs` shows the 20-line path.
//!
//! # Hot path
//!
//! The denoising state is **device-resident for the whole request**: the
//! initial latent uploads once, every step runs entirely over device
//! buffers, and the final latent downloads once. In steady state no latent
//! byte crosses the host↔device bus at all.
//!
//! * The sampler itself steps on device: rflow Euler is a single fused
//!   `axpy` ([`runtime::Runtime::axpy`]) and DDIM a fused `ddim_step`
//!   ([`runtime::Runtime::ddim_step`]) — x0-prediction, clamp and
//!   re-noising in one dispatch — with the per-step schedule scalars
//!   uploaded as rank-0 runtime arguments at request start
//!   ([`sampler::DeviceStepper`]). Timestep embeddings precompute at
//!   request start too, since every `t_value(i)` is known up front.
//! * The classifier-free-guidance combine `uncond + s·(cond − uncond)` is
//!   a fused executable ([`runtime::Runtime::cfg_combine`]) feeding the
//!   sampler step directly; neither epsilon is ever downloaded.
//! * Foresight's Eq. 5/6 drift MSE runs as a fused on-device reduction
//!   ([`runtime::Runtime::mse`]) against the cached activation — a 4-byte
//!   scalar download per measured site instead of the seed's full
//!   `F·P·D·4` feature download. This is the only recurring per-step
//!   transfer, and only for measuring policies.
//! * The uncond CFG branch of each step runs on a persistent per-request
//!   worker thread fed over a channel, with branch-disjoint caches and
//!   policy state (see [`engine`] module docs for the determinism
//!   argument); the per-request text-K/V precompute parallelizes the same
//!   way.
//!
//! Every transfer is metered: per run in [`engine::RunStats`]
//! (`h2d_bytes`/`d2h_bytes`) and globally in
//! [`runtime::TransferStats`]. `benches/fig17_resident.rs` A/Bs the
//! resident loop against the seed-era host staging
//! ([`engine::HotPath::Host`], which still uploads the latent and
//! downloads both epsilons every step) and asserts a ≥100× steady-state
//! per-step transfer reduction for both sampler families with final
//! latents matching to ≤1e-6; `benches/fig16_hotpath.rs` covers the
//! measurement-traffic half of that story per policy.
//!
//! # Micro-batched serving
//!
//! Under load the [`server`]'s workers don't dispatch requests one at a
//! time: on dequeue they coalesce up to `max_batch` *compatible* pending
//! `generate` jobs — same model, bucket, policy spec, steps and CFG scale,
//! keyed by the scheduler's `BatchKey` over the raw wire fields — within a
//! short gather window and run them as **one**
//! [`engine::Engine::generate_batch`] pass. The engine stacks the
//! per-request resident latents along a leading batch axis
//! ([`runtime::Runtime::stack`] / [`runtime::Runtime::lane`]), advances
//! all lanes with a single batched `cfg_combine` and a single batched
//! sampler step per denoising step (the fused-op cache is
//! batch-shape-aware), and keeps every request's reuse policy, feature
//! caches and Eq. 5/6 drift observations fully per-lane — a request
//! reusing a block while its neighbor recomputes is the designed case,
//! and per-request latents match the sequential device path to ≤1e-6.
//! Responses echo the `batch_size` they were served at;
//! `benches/fig18_batching.rs` asserts the equivalence, the unchanged
//! per-request transfer budget, and the per-request wall-clock win at
//! B=4. See [`engine`] §Micro-batching for the batched byte model and
//! [`server`] §Batch scheduler for the compatibility rule.
//!
//! # Autotune
//!
//! Reuse knobs (γ, warmup, N/R) are not one-size-fits-all: the right
//! trade-off shifts with resolution bucket, sampler family and step count.
//! The [`autotune`] subsystem closes that loop in three stages:
//!
//! * **profile** — `foresight autotune` (or [`autotune::profile_engine`])
//!   sweeps a [`autotune::GridSpec`] of policy configurations over a small
//!   prompt panel, scoring wall-clock/reuse against PSNR/SSIM/LPIPS vs the
//!   NoReuse baseline, and keeps the Pareto frontier;
//! * **persist** — the fastest configuration within a PSNR budget is
//!   recorded (with the full frontier) in a schema-versioned JSON
//!   [`autotune::ProfileStore`] keyed by (model, bucket, sampler, steps);
//!   stores `load`/`save`/`merge` and tolerate unknown fields, so newer
//!   writers stay readable;
//! * **serve** — `foresight serve --profiles <path>` loads the store and
//!   the wire accepts `policy: "auto"`, resolved to the tuned concrete
//!   spec *before* the batch key is formed (identically-resolved requests
//!   still micro-batch); unmatched keys fall back to the nearest
//!   same-(model, sampler) profile, then to the built-in default, with
//!   resolution and fallback counts in the `stats` op and the resolved
//!   spec + profile version echoed per response.
//!
//! `benches/fig19_autotune.rs` asserts the tuned choice Pareto-dominates
//! or matches the fixed default; `examples/serve.rs` shows the
//! profile → persist → serve path end to end.

pub mod analysis;
pub mod autotune;
pub mod cache;
pub mod config;
pub mod engine;
pub mod metrics;
pub mod model;
pub mod policy;
pub mod runtime;
pub mod sampler;
pub mod server;
pub mod util;
pub mod workload;

pub mod bench_support;
