//! # Foresight — adaptive layer reuse for text-to-video DiT serving
//!
//! Rust + JAX + Pallas reproduction of *"Foresight: Adaptive Layer Reuse for
//! Accelerated and High-Quality Text-to-Video Generation"* (NeurIPS 2025).
//!
//! Three layers (DESIGN.md):
//! * **L1** — Pallas kernels (flash attention, fused LN+modulate, fused MLP)
//!   authored in `python/compile/kernels/`, lowered at build time.
//! * **L2** — the ST-DiT model in JAX (`python/compile/model.py`), exported
//!   piece-by-piece to HLO text so each DiT block is an independently
//!   dispatchable executable.
//! * **L3** — this crate: the serving coordinator that makes the paper's
//!   per-layer, per-step reuse decisions on the request path, with Python
//!   never loaded at runtime.
//!
//! Start with [`engine::Engine`] for single requests or [`server`] for the
//! TCP serving front-end; `examples/quickstart.rs` shows the 20-line path.
//!
//! # Hot path
//!
//! The denoising loop is **device-resident**: between the per-step latent
//! upload (`F·P·C·4` bytes) and the single combined-epsilon download
//! (`F·P·C·4` bytes), no activation crosses the host↔device bus.
//!
//! * Foresight's Eq. 5/6 drift MSE runs as a fused on-device reduction
//!   ([`runtime::Runtime::mse`]) against the cached activation — a 4-byte
//!   scalar download per measured site instead of the seed's full
//!   `F·P·D·4` feature download (`D ≫ C`, so this is the dominant term:
//!   ~`2·L·2` measured sites per step).
//! * The classifier-free-guidance combine `uncond + s·(cond − uncond)` is
//!   a fused executable ([`runtime::Runtime::cfg_combine`]), halving the
//!   epsilon traffic; `scale`/`axpy` primitives are in place for sampler
//!   offload.
//! * The two CFG branches of each step execute on concurrent scoped
//!   threads with branch-disjoint caches and policy state (see
//!   [`engine`] module docs for the determinism argument), as does the
//!   per-request text-K/V precompute.
//!
//! Every transfer is metered: per run in [`engine::RunStats`]
//! (`h2d_bytes`/`d2h_bytes`) and globally in
//! [`runtime::TransferStats`]. `benches/fig16_hotpath.rs` A/Bs this
//! pipeline against the seed-era host staging ([`engine::HotPath::Host`])
//! and asserts the ≥10× transfer reduction with bit-identical latents.

pub mod analysis;
pub mod cache;
pub mod config;
pub mod engine;
pub mod metrics;
pub mod model;
pub mod policy;
pub mod runtime;
pub mod sampler;
pub mod server;
pub mod util;
pub mod workload;

pub mod bench_support;
