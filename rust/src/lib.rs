//! # Foresight — adaptive layer reuse for text-to-video DiT serving
//!
//! Rust + JAX + Pallas reproduction of *"Foresight: Adaptive Layer Reuse for
//! Accelerated and High-Quality Text-to-Video Generation"* (NeurIPS 2025).
//!
//! Three layers (DESIGN.md):
//! * **L1** — Pallas kernels (flash attention, fused LN+modulate, fused MLP)
//!   authored in `python/compile/kernels/`, lowered at build time.
//! * **L2** — the ST-DiT model in JAX (`python/compile/model.py`), exported
//!   piece-by-piece to HLO text so each DiT block is an independently
//!   dispatchable executable.
//! * **L3** — this crate: the serving coordinator that makes the paper's
//!   per-layer, per-step reuse decisions on the request path, with Python
//!   never loaded at runtime.
//!
//! Start with [`engine::Engine`] for single requests or [`server`] for the
//! TCP serving front-end; `examples/quickstart.rs` shows the 20-line path.
//!
//! # Hot path
//!
//! The denoising state is **device-resident for the whole request**: the
//! initial latent uploads once, every step runs entirely over device
//! buffers, and the final latent downloads once. In steady state no latent
//! byte crosses the host↔device bus at all.
//!
//! * The sampler itself steps on device: rflow Euler is a single fused
//!   `axpy` ([`runtime::Runtime::axpy`]) and DDIM a fused `ddim_step`
//!   ([`runtime::Runtime::ddim_step`]) — x0-prediction, clamp and
//!   re-noising in one dispatch — with the per-step schedule scalars
//!   uploaded as rank-0 runtime arguments at request start
//!   ([`sampler::DeviceStepper`]). Timestep embeddings precompute at
//!   request start too, since every `t_value(i)` is known up front.
//! * The classifier-free-guidance combine `uncond + s·(cond − uncond)` is
//!   a fused executable ([`runtime::Runtime::cfg_combine`]) feeding the
//!   sampler step directly; neither epsilon is ever downloaded.
//! * Foresight's Eq. 5/6 drift MSE runs as a fused on-device reduction
//!   ([`runtime::Runtime::mse`]) against the cached activation — a 4-byte
//!   scalar download per measured site instead of the seed's full
//!   `F·P·D·4` feature download. This is the only recurring per-step
//!   transfer, and only for measuring policies.
//! * The uncond CFG branch of each step runs on a persistent per-request
//!   worker thread fed over a channel, with branch-disjoint caches and
//!   policy state (see [`engine`] module docs for the determinism
//!   argument); the per-request text-K/V precompute parallelizes the same
//!   way.
//!
//! Every transfer is metered: per run in [`engine::RunStats`]
//! (`h2d_bytes`/`d2h_bytes`) and globally in
//! [`runtime::TransferStats`]. `benches/fig17_resident.rs` A/Bs the
//! resident loop against the seed-era host staging
//! ([`engine::HotPath::Host`], which still uploads the latent and
//! downloads both epsilons every step) and asserts a ≥100× steady-state
//! per-step transfer reduction for both sampler families with final
//! latents matching to ≤1e-6; `benches/fig16_hotpath.rs` covers the
//! measurement-traffic half of that story per policy.
//!
//! # Serving: continuous step-level batching
//!
//! Every generate path is a thin driver over **sessions**
//! ([`engine::session::Session`]): a started request holding its resident
//! latent, per-branch caches (owned by two persistent policy-free branch
//! workers), policy state, precomputed per-step scalars, and a cursor.
//! [`engine::session::step_many`] advances any set of
//! same-(model, bucket, sampler) sessions one denoising step in one fused
//! device pass: the cohort's latents live stacked on device
//! ([`runtime::Runtime::stack`] / [`runtime::Runtime::lane`], compacted in
//! one dispatch by [`runtime::Runtime::regroup`] when lanes retire, and
//! the stacked tensor is reused across steps while membership holds), and
//! the multi-lane advance takes each session's **own** CFG scale and
//! sampler coefficients as per-lane rank-0 arguments — so requests with
//! different step counts, CFG scales and policies share passes.
//!
//! The [`server`] batches **continuously**: a worker never waits a gather
//! window out (an empty queue parks on a condvar); new compatible
//! requests join the in-flight cohort at the next step boundary up to
//! `max_batch`, and finished lanes retire and answer immediately instead
//! of waiting for batchmates. Responses echo `batch_size` (the largest
//! cohort the request shared a pass with), and the `stats` op exposes
//! `lanes_active`, per-step occupancy, `joins`/`retires`/`regroups`.
//! Per-request [`engine::RunStats`] transfer meters report the standalone
//! byte cost regardless of cohort size (the session byte model).
//! [`engine::Engine::generate_batch`] survives as the lockstep
//! equivalence oracle: `benches/fig18_batching.rs` asserts ≤1e-6 latents
//! and unchanged budgets, and `benches/fig20_continuous.rs` replays
//! staggered mixed-step arrivals to assert latency/throughput is no worse
//! than the retired gather-window discipline. See [`engine`] §Sessions
//! and [`server`] §Continuous batching.
//!
//! The scheduler **shards across device replicas**: `foresight serve
//! --devices N` builds a [`runtime::DevicePool`] of N independent
//! runtimes (each with its own PJRT client, executable caches and
//! [`runtime::TransferStats`]), loads every served (model, bucket) once
//! per ordinal, and pins one continuous-batching worker to each. A
//! routing front assigns each arrival cohort-affinity-first (a device
//! already running that key with a spare lane), else least-loaded; idle
//! devices steal queued jobs for free, and when every queue is empty a
//! fully idle device takes over a *running* session from the most-loaded
//! one via [`engine::session::Session::migrate`] — one metered lane
//! download + upload, bit-exact at the destination. The `stats` op grows
//! `devices`, `steals` and a `per_device` breakdown (lanes, occupancy,
//! joins/retires/steals, per-ordinal bus bytes); at the default
//! `--devices 1` the wire format and scheduler behavior are unchanged.
//! `benches/fig21_sharded.rs` replays an arrival trace at N ∈ {1, 2, 4}
//! asserting near-linear throughput scaling, placement-independent
//! latents (≤1e-6) and the exact one-lane steal charge. See [`server`]
//! §Sharded topology and the `server::scheduler` module docs.
//!
//! Under overload the server **degrades before it collapses**. Per-device
//! queues are bounded (`--max-queue`): a request arriving with every
//! candidate queue at the bound is refused immediately with the
//! `overloaded` backpressure response (`retry_after_ms` drain-time hint,
//! `queue_depth`), counted in the `stats` op's `rejects` — and
//! [`server::Client::call_retrying`] retries those transparently with
//! capped exponential backoff honoring the hint
//! ([`server::Backoff`]; `Backoff::none()` opts out). Requests may carry
//! a `deadline_ms` budget: a job whose deadline passes — in the queue, at
//! admission, or mid-flight — is answered with
//! `{"status":"error", "deadline_exceeded":true}` at the next step
//! boundary instead of consuming device passes (mid-flight lanes retire
//! early via [`engine::session::Session::abandon`]), counted in
//! `deadline_misses`. And under queue pressure (`--degrade` threshold),
//! `policy:"auto"` resolves to the profile's fastest frontier point still
//! inside its own autotune min-PSNR budget — responses echo
//! `degraded`/`degraded_from`, `stats` counts `degrade_swaps` and the
//! recovered `degrade_headroom_s`, and `queue_depth`/`queue_depth_peak`
//! expose the pressure itself. `benches/fig22_overload.rs` drives all
//! three valves with trace-driven open-loop load (bursty ramps and a
//! flash crowd via [`util::loadgen`]) against a live server, asserting
//! the queue never exceeds its bound, misses are answered early, the
//! degradation valve never picks an out-of-budget tier, and a mixed soak
//! drains to zero lanes with the ledger balancing. See [`server`]
//! §Overload control.
//!
//! # Autotune
//!
//! Reuse knobs (γ, warmup, N/R) are not one-size-fits-all: the right
//! trade-off shifts with resolution bucket, sampler family and step count.
//! The [`autotune`] subsystem closes that loop in three stages:
//!
//! * **profile** — `foresight autotune` (or [`autotune::profile_engine`])
//!   sweeps a [`autotune::GridSpec`] of policy configurations over a small
//!   prompt panel, scoring wall-clock/reuse against PSNR/SSIM/LPIPS vs the
//!   NoReuse baseline, and keeps the Pareto frontier;
//! * **persist** — the fastest configuration within a PSNR budget is
//!   recorded (with the full frontier) in a schema-versioned JSON
//!   [`autotune::ProfileStore`] keyed by (model, bucket, sampler, steps);
//!   stores `load`/`save`/`merge` and tolerate unknown fields, so newer
//!   writers stay readable;
//! * **serve** — `foresight serve --profiles <path>` loads the store and
//!   the wire accepts `policy: "auto"`, resolved to the tuned concrete
//!   spec *before* the batch key is formed (identically-resolved requests
//!   still micro-batch); unmatched keys fall back to the nearest
//!   same-(model, sampler) profile, then to the built-in default, with
//!   resolution and fallback counts in the `stats` op and the resolved
//!   spec + profile version echoed per response.
//!
//! `benches/fig19_autotune.rs` asserts the tuned choice Pareto-dominates
//! or matches the fixed default; `examples/serve.rs` shows the
//! profile → persist → serve path end to end.
//!
//! # Forecasting
//!
//! Verbatim replay serves a reuse step the activation from the *last*
//! compute — correct but stale, and staleness is exactly what caps how
//! aggressive a reuse schedule can get before quality collapses. The
//! forecasting layer replaces replay with a **linear-multistep
//! prediction**: each cache site keeps a bounded ring of its superseded
//! outputs ([`cache::FeatureCache`] history rings, byte-accounted and
//! migration-safe), and a reuse step is served `Σ cᵢ·hᵢ` over the k most
//! recent outputs in **one fused dispatch**
//! ([`runtime::Runtime::lms_combine`]) with the order-k coefficients
//! ([`runtime::lms_coefficients`]) uploaded once at admit as rank-0
//! scalars — a forecast moves zero additional bytes over the bus. The
//! coefficients target the midpoint of the reuse window (half-spacing
//! Lagrange extrapolation), since one forecast serves every reuse step
//! until the next compute refreshes the site.
//!
//! Policy-side this is a composable wrapper, not a new policy:
//! `forecast:k=2,inner=foresight:n=1,r=2,gamma=0.5`
//! ([`policy::Forecast`]) lets the inner policy decide *when* to reuse
//! and upgrades those decisions to `Predict`; history-starved sites
//! (fewer than k stored outputs) fall back to verbatim replay per site,
//! with exact `forecasts`/`forecast_fallbacks` accounting through
//! [`engine::RunStats`], the `stats` op and per-response
//! `forecast_units`. `forecast:k=1` is bit-identical to replay by
//! construction. The predictor order joins the [`autotune`] sweep grid
//! (`--orders`), so `policy:"auto"` serves tuned forecast specs
//! transparently. `benches/fig24_forecast.rs` pins the contract: higher
//! PSNR than replay at equal reuse fraction, a strictly faster tuned
//! pick at the same min-PSNR budget, k=1 bit-identity, transfer-free
//! forecast steps, and fallback counts matching a decision-map oracle;
//! `tests/integration_sharded.rs` proves the rings survive migration
//! bit-exact, charged at exactly their drained bytes on the bus meters.
//!
//! # Observability
//!
//! Aggregates alone cannot explain a single slow request or a single bad
//! frame, so the serving stack carries a structured per-step tracer
//! ([`trace`]): always compiled, runtime-toggled (`FORESIGHT_TRACE`, or
//! the `trace` wire op's `enable` flag), writing into bounded ring shards
//! that **drop (and count) instead of blocking** when contended or full —
//! emission is safe from under any lock because the ring holds the
//! highest rank in the [`util::sync`] table and only ever uses
//! `try_lock` on the hot path. Every request gets a `trace_id` at the
//! wire front; the span it opens collects enqueue/reject/deadline events
//! from the server, admit/join/retire/steal/migrate/degrade and
//! per-boundary fused-pass wall+occupancy from the scheduler, per-step
//! per-branch per-site reuse/compute decisions with observed drift MSE
//! and λ thresholds from the session, and h2d/d2h transfer events from
//! the runtime.
//!
//! Three export surfaces (see [`server`] wire-protocol docs):
//!
//! * `{"op":"trace","since":<seq>}` drains the rings incrementally as
//!   Chrome trace-event JSON objects ([`trace::chrome`]), and the
//!   `foresight trace` CLI subcommand writes a Perfetto-loadable
//!   `{"traceEvents":[...]}` file from them;
//! * a `trace:true` flag on any `generate` request returns that
//!   request's compact per-step reuse timeline (step, site, action, λ)
//!   inline in the response;
//! * `{"op":"metrics"}` renders the full `stats` surface in Prometheus
//!   text exposition format (`foresight_<stat>` gauges, per-device
//!   values labeled `{device="N"}`) for standard scrapers, with the
//!   `analysis::lint` ledger pass holding the metric table and the
//!   telemetry struct in lockstep.
//!
//! `benches/fig23_trace.rs` pins the overhead contract: tracing off costs
//! nothing measurable, tracing on stays bounded, and overload drops
//! events instead of stalling step boundaries.
//!
//! # Static analysis
//!
//! The concurrency above rests on three project invariants the type
//! system cannot see, so the repo checks them twice:
//!
//! * **Statically** — `foresight lint` ([`analysis::lint`]) scans
//!   `rust/src` for lock-order inversions and acquisition cycles against
//!   the canonical rank table in [`util::sync`], I/O or device work
//!   performed while the scheduler's `router.state` guard is live,
//!   `unwrap`/`expect`/`panic!` on serving paths (a handler must degrade
//!   to an error response, never take the process down), and telemetry
//!   ledger drift (every counter incremented, serialized in the `stats`
//!   op, and documented). Deliberate exceptions live in `rust/lint.allow`
//!   with one-line justifications; CI and `tests/integration_lint.rs`
//!   fail on any non-allowlisted finding and on stale allowlist rows.
//! * **Dynamically** — every lock in the serving stack is a
//!   [`util::sync::OrderedMutex`] carrying a (name, rank); debug builds
//!   (hence `cargo test` and the CI test legs) panic at the exact
//!   acquisition site of any out-of-rank nesting, and poisoning is
//!   tolerated everywhere so a panicking handler cannot take `stats`
//!   down with it (see `tests/integration_server.rs`
//!   `poisoned_telemetry_keeps_stats_serving`).

pub mod analysis;
pub mod autotune;
pub mod cache;
pub mod config;
pub mod engine;
pub mod metrics;
pub mod model;
pub mod policy;
pub mod runtime;
pub mod sampler;
pub mod server;
pub mod trace;
pub mod util;
pub mod workload;

pub mod bench_support;
