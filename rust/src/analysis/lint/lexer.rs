//! Minimal Rust token scanner for the lint passes.
//!
//! Hand-rolled in the house style of `util::json` (crates.io is
//! unreachable, so no `syn`): good enough to tokenize this repository,
//! not a general Rust lexer. It skips whitespace, line/doc comments,
//! (nested) block comments, char literals and lifetimes, and numeric
//! literals; it emits identifiers, ordinary string literals (with their
//! contents — the ledger pass keys on serialized wire names), and
//! single-character punctuation, each tagged with a 1-based line number.

/// One lexed token.
#[derive(Debug, Clone, PartialEq)]
pub enum Tok {
    Ident(String),
    Str(String),
    Punct(char),
}

/// Token plus the 1-based source line it starts on.
#[derive(Debug, Clone)]
pub struct Token {
    pub tok: Tok,
    pub line: usize,
}

impl Token {
    pub fn ident(&self) -> Option<&str> {
        match &self.tok {
            Tok::Ident(s) => Some(s),
            _ => None,
        }
    }

    pub fn is_punct(&self, c: char) -> bool {
        self.tok == Tok::Punct(c)
    }
}

struct Lexer<'a> {
    b: &'a [u8],
    pos: usize,
    line: usize,
}

impl<'a> Lexer<'a> {
    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn peek2(&self) -> Option<u8> {
        self.b.get(self.pos + 1).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if let Some(c) = c {
            self.pos += 1;
            if c == b'\n' {
                self.line += 1;
            }
        }
        c
    }

    fn skip_line_comment(&mut self) {
        while let Some(c) = self.peek() {
            if c == b'\n' {
                break;
            }
            self.bump();
        }
    }

    fn skip_block_comment(&mut self) {
        // Rust block comments nest.
        let mut depth = 1usize;
        self.bump(); // '*'
        while depth > 0 {
            match (self.peek(), self.peek2()) {
                (Some(b'/'), Some(b'*')) => {
                    self.bump();
                    self.bump();
                    depth += 1;
                }
                (Some(b'*'), Some(b'/')) => {
                    self.bump();
                    self.bump();
                    depth -= 1;
                }
                (Some(_), _) => {
                    self.bump();
                }
                (None, _) => break,
            }
        }
    }

    /// Ordinary `"…"` string body, opening quote already consumed.
    fn string_body(&mut self) -> String {
        let mut s = String::new();
        while let Some(c) = self.bump() {
            match c {
                b'"' => break,
                b'\\' => {
                    // Keep escapes opaque; the passes only substring-match.
                    if let Some(e) = self.bump() {
                        s.push('\\');
                        s.push(e as char);
                    }
                }
                _ => s.push(c as char),
            }
        }
        s
    }

    /// Raw string `r"…"` / `r#"…"#…`, cursor on the first `#` or `"`.
    fn skip_raw_string(&mut self) {
        let mut hashes = 0usize;
        while self.peek() == Some(b'#') {
            hashes += 1;
            self.bump();
        }
        if self.peek() != Some(b'"') {
            return; // not actually a raw string (e.g. `r#ident`)
        }
        self.bump();
        loop {
            match self.bump() {
                None => break,
                Some(b'"') => {
                    let mut seen = 0usize;
                    while seen < hashes && self.peek() == Some(b'#') {
                        seen += 1;
                        self.bump();
                    }
                    if seen == hashes {
                        break;
                    }
                }
                Some(_) => {}
            }
        }
    }

    /// `'…'` char literal or `'ident` lifetime, opening quote consumed.
    fn skip_char_or_lifetime(&mut self) {
        match self.peek() {
            Some(b'\\') => {
                // Escaped char literal: skip to the closing quote.
                self.bump();
                self.bump();
                while let Some(c) = self.bump() {
                    if c == b'\'' {
                        break;
                    }
                }
            }
            Some(c) if c.is_ascii_alphanumeric() || c == b'_' => {
                if self.b.get(self.pos + 1) == Some(&b'\'') {
                    // 'x' char literal.
                    self.bump();
                    self.bump();
                } else {
                    // Lifetime: consume the identifier, no closing quote.
                    while let Some(c) = self.peek() {
                        if c.is_ascii_alphanumeric() || c == b'_' {
                            self.bump();
                        } else {
                            break;
                        }
                    }
                }
            }
            _ => {
                // Something like '(' in a macro; treat as char literal.
                self.bump();
                if self.peek() == Some(b'\'') {
                    self.bump();
                }
            }
        }
    }
}

/// Tokenize `src`. Never fails: unknown bytes become punctuation.
pub fn lex(src: &str) -> Vec<Token> {
    let mut lx = Lexer { b: src.as_bytes(), pos: 0, line: 1 };
    let mut out = Vec::new();
    while let Some(c) = lx.peek() {
        let line = lx.line;
        match c {
            b' ' | b'\t' | b'\r' | b'\n' => {
                lx.bump();
            }
            b'/' if lx.peek2() == Some(b'/') => {
                lx.skip_line_comment();
            }
            b'/' if lx.peek2() == Some(b'*') => {
                lx.bump();
                lx.skip_block_comment();
            }
            b'"' => {
                lx.bump();
                let s = lx.string_body();
                out.push(Token { tok: Tok::Str(s), line });
            }
            b'\'' => {
                lx.bump();
                lx.skip_char_or_lifetime();
            }
            b'0'..=b'9' => {
                // Loose numeric literal: 0x1f, 1_000, 1.5 — exponent signs
                // fall out as punctuation, which the passes ignore.
                lx.bump();
                while let Some(c) = lx.peek() {
                    if c.is_ascii_alphanumeric() || c == b'_' {
                        lx.bump();
                    } else if c == b'.'
                        && lx.peek2().is_some_and(|d| d.is_ascii_digit())
                    {
                        lx.bump();
                    } else {
                        break;
                    }
                }
            }
            c if c.is_ascii_alphabetic() || c == b'_' => {
                let mut s = String::new();
                while let Some(c) = lx.peek() {
                    if c.is_ascii_alphanumeric() || c == b'_' {
                        s.push(c as char);
                        lx.bump();
                    } else {
                        break;
                    }
                }
                // Raw / byte string prefixes swallow their literal whole.
                let next = lx.peek();
                if (s == "r" || s == "br") && (next == Some(b'"') || next == Some(b'#')) {
                    lx.skip_raw_string();
                } else if s == "b" && next == Some(b'"') {
                    lx.bump();
                    lx.string_body();
                } else {
                    out.push(Token { tok: Tok::Ident(s), line });
                }
            }
            _ => {
                lx.bump();
                out.push(Token { tok: Tok::Punct(c as char), line });
            }
        }
    }
    out
}

/// Drop `#[cfg(test)]`- and `#[test]`-gated items from a token stream, so
/// the passes only see code that ships. The gated item is everything from
/// the attribute through the end of the following braced block (or the
/// first `;` for non-braced items like `use`).
pub fn strip_tests(toks: Vec<Token>) -> Vec<Token> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < toks.len() {
        if let Some(j) = test_attr_end(&toks, i) {
            i = skip_item(&toks, j);
        } else {
            out.push(toks[i].clone());
            i += 1;
        }
    }
    out
}

/// If `toks[i..]` starts a `#[cfg(test)]` or `#[test]` attribute, return
/// the index just past its closing `]`.
fn test_attr_end(toks: &[Token], i: usize) -> Option<usize> {
    if !toks.get(i)?.is_punct('#') || !toks.get(i + 1)?.is_punct('[') {
        return None;
    }
    if toks.get(i + 2)?.ident() == Some("test") && toks.get(i + 3)?.is_punct(']') {
        return Some(i + 4);
    }
    if toks.get(i + 2)?.ident() == Some("cfg")
        && toks.get(i + 3)?.is_punct('(')
        && toks.get(i + 4)?.ident() == Some("test")
        && toks.get(i + 5)?.is_punct(')')
        && toks.get(i + 6)?.is_punct(']')
    {
        return Some(i + 7);
    }
    None
}

/// Skip one item starting at `i` (past any further attributes): consume to
/// the first `{` at nesting level zero and through its matching `}`, or
/// past the first top-level `;` for items without a body.
fn skip_item(toks: &[Token], mut i: usize) -> usize {
    // Further attributes on the same item.
    while i < toks.len() {
        if toks[i].is_punct('#') && toks.get(i + 1).is_some_and(|t| t.is_punct('[')) {
            let mut depth = 0usize;
            while i < toks.len() {
                if toks[i].is_punct('[') {
                    depth += 1;
                } else if toks[i].is_punct(']') {
                    depth -= 1;
                    if depth == 0 {
                        i += 1;
                        break;
                    }
                }
                i += 1;
            }
        } else {
            break;
        }
    }
    // Body: to matching `}` of the first `{`, or past a bare `;`.
    let mut brace = 0usize;
    let mut paren = 0usize;
    while i < toks.len() {
        match toks[i].tok {
            Tok::Punct('{') => brace += 1,
            Tok::Punct('}') => {
                brace = brace.saturating_sub(1);
                if brace == 0 {
                    return i + 1;
                }
            }
            Tok::Punct('(') | Tok::Punct('[') => paren += 1,
            Tok::Punct(')') | Tok::Punct(']') => paren = paren.saturating_sub(1),
            Tok::Punct(';') if brace == 0 && paren == 0 => return i + 1,
            _ => {}
        }
        i += 1;
    }
    i
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .into_iter()
            .filter_map(|t| t.ident().map(|s| s.to_string()))
            .collect()
    }

    #[test]
    fn comments_and_strings() {
        let src = r##"
            // line comment with unwrap()
            /* block /* nested */ still comment unwrap() */
            let x = "string with unwrap()"; // tail
            let y = r#"raw "quoted" unwrap()"#;
            let z = 'c';
            let lt: &'static str = "s";
        "##;
        let ids = idents(src);
        assert!(!ids.contains(&"unwrap".to_string()));
        assert!(ids.contains(&"static".to_string()));
        let strs: Vec<String> = lex(src)
            .into_iter()
            .filter_map(|t| match t.tok {
                Tok::Str(s) => Some(s),
                _ => None,
            })
            .collect();
        assert_eq!(strs, vec!["string with unwrap()".to_string(), "s".to_string()]);
    }

    #[test]
    fn line_numbers() {
        let toks = lex("a\nb\n  c");
        let lines: Vec<(String, usize)> = toks
            .iter()
            .filter_map(|t| t.ident().map(|s| (s.to_string(), t.line)))
            .collect();
        assert_eq!(
            lines,
            vec![("a".into(), 1), ("b".into(), 2), ("c".into(), 3)]
        );
    }

    #[test]
    fn strips_cfg_test_mod_and_test_fn() {
        let src = r#"
            fn live() { x.unwrap(); }
            #[cfg(test)]
            mod tests {
                #[test]
                fn t() { y.unwrap(); }
            }
            #[test]
            fn stray() { z.unwrap(); }
            fn also_live() {}
        "#;
        let toks = strip_tests(lex(src));
        let ids: Vec<&str> = toks.iter().filter_map(|t| t.ident()).collect();
        assert!(ids.contains(&"live"));
        assert!(ids.contains(&"also_live"));
        assert!(!ids.contains(&"tests"));
        assert!(!ids.contains(&"stray"));
        assert_eq!(ids.iter().filter(|s| **s == "unwrap").count(), 1);
    }

    #[test]
    fn numeric_literals_do_not_eat_method_calls() {
        // `1.0f64.sqrt()` style chains and tuple indexing must keep the
        // following idents.
        let ids = idents("let x = pair.0.lock(); let y = 1.0e3; y.floor();");
        assert!(ids.contains(&"lock".to_string()));
        assert!(ids.contains(&"floor".to_string()));
    }
}
