//! `panic-path` pass: no `unwrap`/`expect`/`panic!`/`unreachable!` (or
//! `todo!`/`unimplemented!`) in non-test serving code.
//!
//! Scope: `server/`, `runtime/`, `trace/`, `util/threadpool.rs`,
//! `util/sync.rs` — the code a panicking request handler can take down. A
//! handler must degrade to an error response; shared state must stay
//! poison-tolerant.
//! Deliberate exceptions (e.g. the lock-order checker itself, which
//! panics by design) live in `rust/lint.allow` with justifications.

use super::lexer::{lex, strip_tests, Token};
use super::{Finding, SourceFile};

const PASS: &str = "panic-path";

/// Panic-family macros (flagged when followed by `!`).
const MACROS: [&str; 4] = ["panic", "unreachable", "todo", "unimplemented"];

fn in_scope(path: &str) -> bool {
    path.contains("server/")
        || path.contains("runtime/")
        || path.contains("trace/")
        || path.ends_with("util/threadpool.rs")
        || path.ends_with("util/sync.rs")
}

pub fn check(files: &[SourceFile]) -> Vec<Finding> {
    let mut out = Vec::new();
    for f in files {
        if in_scope(&f.path) {
            check_tokens(&f.path, &strip_tests(lex(&f.text)), &mut out);
        }
    }
    out
}

fn check_tokens(path: &str, toks: &[Token], out: &mut Vec<Finding>) {
    let mut current_fn = String::from("?");
    for (i, t) in toks.iter().enumerate() {
        if t.ident() == Some("fn") {
            if let Some(name) = toks.get(i + 1).and_then(|n| n.ident()) {
                current_fn = name.to_string();
            }
        }
        let Some(id) = t.ident() else { continue };
        let prev_dot = i > 0 && toks[i - 1].is_punct('.');
        let next = toks.get(i + 1);
        let method_call = prev_dot && next.is_some_and(|n| n.is_punct('('));
        let bang = next.is_some_and(|n| n.is_punct('!'));
        let what = match id {
            "unwrap" | "expect" if method_call => id.to_string(),
            m if MACROS.contains(&m) && bang => format!("{m}!"),
            _ => continue,
        };
        out.push(Finding {
            pass: PASS,
            file: path.to_string(),
            line: t.line,
            what,
            detail: format!("panic path in non-test serving code (fn `{current_fn}`)"),
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(path: &str, src: &str) -> Vec<Finding> {
        check(&[SourceFile::new(path, src)])
    }

    #[test]
    fn flags_seeded_unwrap_and_macros() {
        let src = r#"
            fn handler(x: Option<u32>) -> u32 {
                let v = x.unwrap();
                if v > 3 { panic!("boom"); }
                match v { 0 => unreachable!(), _ => v }
            }
        "#;
        let fs = run("server/fixture.rs", src);
        let whats: Vec<&str> = fs.iter().map(|f| f.what.as_str()).collect();
        assert_eq!(whats, vec!["unwrap", "panic!", "unreachable!"]);
        assert!(fs[0].detail.contains("handler"));
    }

    #[test]
    fn tolerant_variants_and_tests_pass() {
        let src = r#"
            fn ok(x: Option<u32>) -> u32 {
                x.unwrap_or_else(|| 0).max(x.unwrap_or_default())
            }
            #[cfg(test)]
            mod tests {
                #[test]
                fn t() { Some(1).unwrap(); panic!("fine in tests"); }
            }
        "#;
        assert!(run("server/fixture.rs", src).is_empty());
    }

    #[test]
    fn out_of_scope_files_are_ignored() {
        assert!(run("util/json.rs", "fn f() { x.unwrap(); }").is_empty());
        assert!(run("engine/mod.rs", "fn f() { x.unwrap(); }").is_empty());
    }

    #[test]
    fn trace_files_are_in_scope() {
        let fs = run("trace/mod.rs", "fn f(x: Option<u32>) { x.unwrap(); }");
        assert_eq!(fs.len(), 1);
        assert_eq!(fs[0].what, "unwrap");
    }
}
