//! `lock-order` and `io-under-lock` passes.
//!
//! A single scan per file tracks live mutex guards through each function:
//! `let g = x.lock()` binds a guard until its scope closes (or `drop(g)`),
//! while any chained `x.lock().op()` — bare or as a `let` initializer
//! (`let v = x.lock().samples().to_vec()` binds the *samples*, not the
//! guard) — is a statement-temporary dying at the `;`. The
//! guard's identity is the field name before `.lock(` — `state`,
//! `latencies_s`, `cache`, … — classified against the canonical rank
//! table in [`util::sync`](crate::util::sync).
//!
//! * **lock-order**: acquiring a lock whose rank is not strictly above
//!   every rank already held is an inversion; calls to `Router` methods
//!   that lock internally (`queue_depths`, `enqueue`, `signal_stop`)
//!   count as acquisitions of `router.state`. A `.lock(` on a field the
//!   table does not know is flagged too — the table and the code must
//!   not drift apart. Every nested acquisition also lands in a global
//!   acquisition graph; cycles (possible deadlocks the rank table can't
//!   see, e.g. between unranked locks) are reported after the scan.
//! * **io-under-lock**: while a `router.state` guard is live, any
//!   send/write/flush, blocking `recv`, or device-work dispatch
//!   (`step`/`step_many`/`migrate`/`abandon`/`finish`/`admit`/`call`)
//!   violates the off-lock-replies rule from the scheduler docs.
//!
//! The model is an approximation (no dataflow, single-file, guards from
//! field names): it is tuned to be conservative on this codebase —
//! chained temporaries inside one large expression are modeled as dying
//! at the statement end, which is why multi-guard expressions must be
//! written as separate scoped statements (the debug-build runtime
//! checker in `util::sync` covers whatever a static scan cannot see).

use std::collections::BTreeMap;

use super::lexer::{lex, strip_tests, Token};
use super::{Finding, SourceFile};
use crate::util::sync::{
    RANK_POOL_IN_FLIGHT, RANK_POOL_QUEUE, RANK_POOL_SLOTS, RANK_ROUTER_STATE,
    RANK_RUNTIME_EXEC_CACHE, RANK_RUNTIME_FUSED_CACHE, RANK_TELEMETRY_LATENCY,
    RANK_TELEMETRY_OCCUPANCY, RANK_TELEMETRY_QUEUE, RANK_TRACE_RING,
};

const PASS_ORDER: &str = "lock-order";
const PASS_IO: &str = "io-under-lock";

/// Map a `.lock()` receiver field to its canonical (rank, name). Must stay
/// in sync with the rank table in `util::sync`.
pub fn classify(field: &str) -> Option<(u32, &'static str)> {
    Some(match field {
        "state" => (RANK_ROUTER_STATE, "router.state"),
        "rx" => (RANK_POOL_QUEUE, "pool.queue"),
        "in_flight" => (RANK_POOL_IN_FLIGHT, "pool.in_flight"),
        "cache" => (RANK_RUNTIME_EXEC_CACHE, "runtime.cache"),
        "fused" => (RANK_RUNTIME_FUSED_CACHE, "runtime.fused"),
        "latencies_s" => (RANK_TELEMETRY_LATENCY, "telemetry.latencies_s"),
        "queue_s" => (RANK_TELEMETRY_QUEUE, "telemetry.queue_s"),
        // Server-wide and per-device occupancy reservoirs share a field
        // name; they are adjacent in rank and never nest, so the static
        // pass folds them (the runtime checker distinguishes by rank).
        "occupancy" => (RANK_TELEMETRY_OCCUPANCY, "telemetry.occupancy"),
        "slots" => (RANK_POOL_SLOTS, "pool.slots"),
        // Tracer ring shards; hot-path emission uses `try_lock` (invisible
        // to this scan by design — it cannot block), but the drain side
        // takes the lock outright.
        "ring" => (RANK_TRACE_RING, "trace.ring"),
        _ => return None,
    })
}

/// Methods that acquire `router.state` internally.
const ROUTER_LOCKING_FNS: [&str; 3] = ["queue_depths", "enqueue", "signal_stop"];

/// Method calls forbidden while `router.state` is held.
const IO_MARKERS: [&str; 13] = [
    "send", "write", "write_all", "writeln", "flush", "recv", "step", "step_many", "migrate",
    "abandon", "finish", "admit", "call",
];

/// Macros forbidden while `router.state` is held.
const IO_MACROS: [&str; 2] = ["write", "writeln"];

fn in_scope(path: &str) -> bool {
    path.contains("server/")
        || path.contains("runtime/")
        || path.contains("trace/")
        || path.ends_with("util/threadpool.rs")
}

#[derive(Debug, Clone)]
struct Guard {
    /// Canonical name (`router.state`) or raw field ident when unranked.
    key: String,
    rank: Option<u32>,
    /// Binding variable, `None` for statement temporaries.
    var: Option<String>,
    /// Brace depth at the binding; the guard dies when depth drops below.
    depth: usize,
}

/// First-example metadata for one acquisition-graph edge.
#[derive(Debug, Clone)]
struct EdgeAt {
    file: String,
    line: usize,
    func: String,
}

pub fn check(files: &[SourceFile]) -> Vec<Finding> {
    let mut out = Vec::new();
    let mut edges: BTreeMap<(String, String), EdgeAt> = BTreeMap::new();
    for f in files {
        if in_scope(&f.path) {
            scan(&f.path, &strip_tests(lex(&f.text)), &mut edges, &mut out);
        }
    }
    report_cycles(&edges, &mut out);
    out
}

fn scan(
    path: &str,
    toks: &[Token],
    edges: &mut BTreeMap<(String, String), EdgeAt>,
    out: &mut Vec<Finding>,
) {
    let mut depth = 0usize;
    let mut stmt_start = 0usize;
    let mut guards: Vec<Guard> = Vec::new();
    let mut current_fn = String::from("?");

    let mut i = 0;
    while i < toks.len() {
        let t = &toks[i];
        if t.ident() == Some("fn") {
            if let Some(name) = toks.get(i + 1).and_then(|n| n.ident()) {
                current_fn = name.to_string();
            }
        }
        if t.is_punct('{') {
            depth += 1;
            stmt_start = i + 1;
        } else if t.is_punct('}') {
            depth = depth.saturating_sub(1);
            guards.retain(|g| g.depth <= depth);
            stmt_start = i + 1;
        } else if t.is_punct(';') {
            guards.retain(|g| !(g.var.is_none() && g.depth == depth));
            stmt_start = i + 1;
        } else if t.is_punct('.')
            && toks.get(i + 1).and_then(|n| n.ident()) == Some("lock")
            && toks.get(i + 2).is_some_and(|n| n.is_punct('('))
        {
            let field = if i > 0 { toks[i - 1].ident().unwrap_or("<expr>") } else { "<expr>" };
            let line = toks[i + 1].line;
            let (rank, key) = match classify(field) {
                Some((r, name)) => (Some(r), name.to_string()),
                None => {
                    out.push(Finding {
                        pass: PASS_ORDER,
                        file: path.to_string(),
                        line,
                        what: field.to_string(),
                        detail: format!(
                            "unclassified lock in fn `{current_fn}` — add it to the \
                             util::sync rank table and lint::locks::classify"
                        ),
                    });
                    (None, field.to_string())
                }
            };
            // The binding holds the guard only when `.lock()` ends the
            // initializer chain (modulo `unwrap`/`expect`, which return
            // the guard): a further method call consumes the guard inside
            // the statement, so it dies at the `;` like any temporary.
            let chained = toks.get(i + 3).is_some_and(|t| t.is_punct(')'))
                && toks.get(i + 4).is_some_and(|t| t.is_punct('.'))
                && !matches!(toks.get(i + 5).and_then(|t| t.ident()), Some("unwrap" | "expect"));
            let var = if chained {
                None
            } else {
                detect_binding(&toks[stmt_start..i])
            };
            if let Some(v) = &var {
                // Rebinding releases the previous guard of the same name.
                guards.retain(|g| g.var.as_deref() != Some(v));
            }
            record_acquire(
                path,
                line,
                &current_fn,
                &guards,
                &key,
                rank,
                edges,
                out,
            );
            guards.push(Guard { key, rank, var, depth });
            i += 3;
            continue;
        } else if t.ident() == Some("drop")
            && toks.get(i + 1).is_some_and(|n| n.is_punct('('))
            && toks.get(i + 3).is_some_and(|n| n.is_punct(')'))
        {
            if let Some(name) = toks.get(i + 2).and_then(|n| n.ident()) {
                guards.retain(|g| g.var.as_deref() != Some(name));
            }
        } else if let Some(id) = t.ident() {
            let prev_dot = i > 0 && toks[i - 1].is_punct('.');
            let next_paren = toks.get(i + 1).is_some_and(|n| n.is_punct('('));
            let next_bang = toks.get(i + 1).is_some_and(|n| n.is_punct('!'));
            let state_held = guards.iter().any(|g| g.rank == Some(RANK_ROUTER_STATE));

            if prev_dot && next_paren && ROUTER_LOCKING_FNS.contains(&id) {
                // An internal acquisition of router.state.
                record_acquire(
                    path,
                    t.line,
                    &current_fn,
                    &guards,
                    "router.state",
                    Some(RANK_ROUTER_STATE),
                    edges,
                    out,
                );
            } else if state_held
                && ((prev_dot && next_paren && IO_MARKERS.contains(&id))
                    || (next_bang && IO_MACROS.contains(&id)))
            {
                out.push(Finding {
                    pass: PASS_IO,
                    file: path.to_string(),
                    line: t.line,
                    what: id.to_string(),
                    detail: format!(
                        "`{id}` while a router.state guard is live in fn `{current_fn}` \
                         — replies and device work must run off-lock"
                    ),
                });
            }
        }
        i += 1;
    }
}

/// Record one acquisition of `key` while `guards` are held: graph edges
/// from every live guard, plus an inversion finding when the new rank is
/// not strictly above the highest held rank.
#[allow(clippy::too_many_arguments)]
fn record_acquire(
    path: &str,
    line: usize,
    current_fn: &str,
    guards: &[Guard],
    key: &str,
    rank: Option<u32>,
    edges: &mut BTreeMap<(String, String), EdgeAt>,
    out: &mut Vec<Finding>,
) {
    for g in guards {
        edges.entry((g.key.clone(), key.to_string())).or_insert_with(|| EdgeAt {
            file: path.to_string(),
            line,
            func: current_fn.to_string(),
        });
    }
    let top = guards.iter().filter(|g| g.rank.is_some()).max_by_key(|g| g.rank);
    if let (Some(r), Some(t)) = (rank, top) {
        if let Some(tr) = t.rank {
            if r <= tr {
                out.push(Finding {
                    pass: PASS_ORDER,
                    file: path.to_string(),
                    line,
                    what: format!("{key} after {}", t.key),
                    detail: format!(
                        "fn `{current_fn}` acquires `{key}` (rank {r}) while holding \
                         `{}` (rank {tr}); ranks must strictly increase",
                        t.key
                    ),
                });
            }
        }
    }
}

/// DFS over the global acquisition graph; each cycle is a potential
/// deadlock the rank table cannot rule out.
fn report_cycles(edges: &BTreeMap<(String, String), EdgeAt>, out: &mut Vec<Finding>) {
    let mut adj: BTreeMap<&str, Vec<&str>> = BTreeMap::new();
    for (a, b) in edges.keys() {
        adj.entry(a.as_str()).or_default().push(b.as_str());
        adj.entry(b.as_str()).or_default();
    }
    let mut done: Vec<&str> = Vec::new();
    let mut reported: Vec<String> = Vec::new();
    let starts: Vec<&str> = adj.keys().copied().collect();
    for start in starts {
        let mut path: Vec<&str> = vec![start];
        dfs(start, &adj, &mut path, &mut done, &mut reported, edges, out);
    }
}

fn dfs<'a>(
    node: &'a str,
    adj: &BTreeMap<&'a str, Vec<&'a str>>,
    path: &mut Vec<&'a str>,
    done: &mut Vec<&'a str>,
    reported: &mut Vec<String>,
    edges: &BTreeMap<(String, String), EdgeAt>,
    out: &mut Vec<Finding>,
) {
    if done.contains(&node) {
        return;
    }
    for &next in adj.get(node).map(|v| v.as_slice()).unwrap_or(&[]) {
        if let Some(pos) = path.iter().position(|&n| n == next) {
            let mut cycle: Vec<&str> = path[pos..].to_vec();
            cycle.push(next);
            // Canonicalize so each cycle reports once regardless of entry.
            let mut names = cycle.clone();
            names.pop();
            names.sort_unstable();
            let sig = names.join("+");
            if !reported.contains(&sig) {
                reported.push(sig);
                let at = edges.get(&(node.to_string(), next.to_string()));
                out.push(Finding {
                    pass: PASS_ORDER,
                    file: at.map(|e| e.file.clone()).unwrap_or_default(),
                    line: at.map(|e| e.line).unwrap_or(0),
                    what: cycle.join(" -> "),
                    detail: format!(
                        "acquisition cycle (potential deadlock); closing edge in fn `{}`",
                        at.map(|e| e.func.clone()).unwrap_or_default()
                    ),
                });
            }
        } else {
            path.push(next);
            dfs(next, adj, path, done, reported, edges, out);
            path.pop();
        }
    }
    done.push(node);
}

/// `let [mut] name = …` / `name = …` at the head of the current statement
/// binds the guard to `name`; anything else is a temporary.
fn detect_binding(stmt: &[Token]) -> Option<String> {
    let mut k = 0;
    if stmt.first()?.ident() == Some("let") {
        k = 1;
        if stmt.get(k)?.ident() == Some("mut") {
            k += 1;
        }
        let name = stmt.get(k)?.ident()?.to_string();
        if stmt.get(k + 1)?.is_punct('=') {
            return Some(name);
        }
        return None;
    }
    let name = stmt.first()?.ident()?.to_string();
    if stmt.get(1)?.is_punct('=') && !stmt.get(2).is_some_and(|t| t.is_punct('=')) {
        return Some(name);
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(path: &str, src: &str) -> Vec<Finding> {
        check(&[SourceFile::new(path, src)])
    }

    #[test]
    fn ascending_nesting_is_clean() {
        let src = r#"
            fn worker(&self) {
                let mut st = self.router.state.lock();
                self.telemetry.latencies_s.lock().push(1.0);
                drop(st);
                self.telemetry.queue_s.lock().push(2.0);
            }
        "#;
        assert!(run("server/fixture.rs", src).is_empty());
    }

    #[test]
    fn flags_seeded_inversion() {
        let src = r#"
            fn stats(&self) {
                let l = self.telemetry.latencies_s.lock();
                let st = self.router.state.lock();
            }
        "#;
        let fs = run("server/fixture.rs", src);
        assert_eq!(fs.len(), 1);
        assert_eq!(fs[0].pass, "lock-order");
        assert!(fs[0].what.contains("router.state after telemetry.latencies_s"));
        assert_eq!(fs[0].line, 4);
    }

    #[test]
    fn flags_router_locking_call_under_guard() {
        let src = r#"
            fn resolve(&self) {
                let l = self.telemetry.latencies_s.lock();
                let d = self.router.queue_depths();
            }
        "#;
        let fs = run("server/fixture.rs", src);
        assert_eq!(fs.len(), 1, "{fs:?}");
        assert_eq!(fs[0].pass, "lock-order");
    }

    #[test]
    fn guard_scope_and_drop_end_liveness() {
        let src = r#"
            fn scoped(&self) {
                {
                    let st = self.state.lock();
                }
                let l = self.latencies_s.lock();
                drop(l);
                let st = self.state.lock();
            }
        "#;
        assert!(run("server/fixture.rs", src).is_empty());
    }

    #[test]
    fn temporaries_die_at_statement_end() {
        let src = r#"
            fn temp(&self) {
                self.latencies_s.lock().push(1.0);
                let st = self.state.lock();
            }
        "#;
        assert!(run("server/fixture.rs", src).is_empty());
    }

    #[test]
    fn chained_lock_reads_are_temporaries() {
        // The `let` binds the copied-out samples, not the guard — taking
        // router.state afterwards is fine.
        let src = r#"
            fn stats(&self) {
                let qs = self.queue_s.lock().samples().to_vec();
                let d = self.router.queue_depths();
            }
        "#;
        assert!(run("server/fixture.rs", src).is_empty());
    }

    #[test]
    fn flags_io_under_router_lock() {
        let src = r#"
            fn sweep(&self) {
                let mut st = self.state.lock();
                let _ = job.reply.send(resp);
                drop(st);
                let _ = late.reply.send(resp);
            }
        "#;
        let fs = run("server/scheduler_fixture.rs", src);
        assert_eq!(fs.len(), 1, "{fs:?}");
        assert_eq!(fs[0].pass, "io-under-lock");
        assert_eq!(fs[0].what, "send");
        assert_eq!(fs[0].line, 4);
    }

    #[test]
    fn flags_unclassified_lock_field() {
        let src = r#"
            fn rogue(&self) {
                let g = self.mystery.lock();
            }
        "#;
        let fs = run("runtime/fixture.rs", src);
        assert_eq!(fs.len(), 1);
        assert!(fs[0].detail.contains("unclassified"));
        assert_eq!(fs[0].what, "mystery");
    }

    #[test]
    fn reports_cross_function_cycle() {
        // Two unranked locks taken in opposite orders in two functions:
        // no single acquisition inverts a rank, only the graph sees it.
        let src = r#"
            fn ab(&self) {
                let a = self.alpha.lock();
                let b = self.beta.lock();
            }
            fn ba(&self) {
                let b = self.beta.lock();
                let a = self.alpha.lock();
            }
        "#;
        let fs = run("server/fixture.rs", src);
        let cycles: Vec<_> = fs
            .iter()
            .filter(|f| f.pass == "lock-order" && f.what.contains("->"))
            .collect();
        assert_eq!(cycles.len(), 1, "{fs:?}");
        assert!(cycles[0].what.contains("alpha") && cycles[0].what.contains("beta"));
    }

    #[test]
    fn out_of_scope_files_are_ignored() {
        let src = "fn f(&self) { let a = self.state.lock(); let b = self.mystery.lock(); }";
        assert!(run("engine/mod.rs", src).is_empty());
    }

    #[test]
    fn trace_ring_is_classified_and_nests_above_everything() {
        // trace/ is in scope and trace.ring (rank 70) may be taken under
        // any serving lock — emission inside a router.state section is
        // rank-legal.
        let src = r#"
            fn drain_under_state(&self) {
                let st = self.state.lock();
                let g = self.ring.lock();
            }
        "#;
        assert!(run("trace/fixture.rs", src).is_empty());
        // ...but the inverse order is an inversion like any other.
        let src = r#"
            fn inverted(&self) {
                let g = self.ring.lock();
                let st = self.state.lock();
            }
        "#;
        let fs = run("trace/fixture.rs", src);
        assert_eq!(fs.len(), 1, "{fs:?}");
        assert!(fs[0].what.contains("router.state after trace.ring"));
    }
}
