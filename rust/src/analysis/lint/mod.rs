//! Project-invariant static analysis (`foresight lint`).
//!
//! Four passes over `rust/src`, each a pure function from source text to
//! [`Finding`]s so unit tests can feed seeded-violation fixtures:
//!
//! * [`locks`] — `lock-order` (nested-guard acquisition graph, ranked
//!   against the canonical order in `util::sync`; inversions and cycles)
//!   and `io-under-lock` (socket/reply/device work while a
//!   `Router::state` guard is live);
//! * [`panics`] — `panic-path` (`unwrap`/`expect`/`panic!`/`unreachable!`
//!   in non-test serving code);
//! * [`ledger`] — `ledger-drift` (every telemetry counter incremented,
//!   serialized in the `stats` op, and documented).
//!
//! Findings are filtered through the checked-in allowlist
//! (`rust/lint.allow`): `pass|file-suffix|pattern|justification` per
//! line, justification mandatory. The CLI (`foresight lint`) exits
//! nonzero on any non-allowlisted finding and reports allowlist entries
//! that no longer match anything, so stale exemptions surface too.

pub mod ledger;
pub mod lexer;
pub mod locks;
pub mod panics;

use std::fmt;
use std::fs;
use std::path::Path;

use anyhow::{bail, Context, Result};

/// One source file as seen by the passes: a repo-relative path (used for
/// scoping and allowlist matching) plus its full text.
pub struct SourceFile {
    pub path: String,
    pub text: String,
}

impl SourceFile {
    pub fn new(path: impl Into<String>, text: impl Into<String>) -> Self {
        Self { path: path.into(), text: text.into() }
    }
}

/// One lint finding.
#[derive(Debug, Clone, PartialEq)]
pub struct Finding {
    /// Pass id: `lock-order`, `io-under-lock`, `panic-path`, `ledger-drift`.
    pub pass: &'static str,
    pub file: String,
    pub line: usize,
    /// The matched construct (e.g. `unwrap`, `telemetry.latencies_s`).
    pub what: String,
    pub detail: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}: {}:{}: `{}` — {}",
            self.pass, self.file, self.line, self.what, self.detail
        )
    }
}

/// Known pass ids (allowlist entries must name one).
pub const PASSES: [&str; 4] = ["lock-order", "io-under-lock", "panic-path", "ledger-drift"];

/// One `pass|file-suffix|pattern|justification` allowlist line.
#[derive(Debug, Clone)]
pub struct AllowEntry {
    pub pass: String,
    pub file_suffix: String,
    pub pattern: String,
    pub justification: String,
    /// 1-based line in the allowlist file, for diagnostics.
    pub line: usize,
}

impl AllowEntry {
    fn matches(&self, f: &Finding) -> bool {
        self.pass == f.pass
            && f.file.ends_with(&self.file_suffix)
            && (f.what.contains(&self.pattern) || f.detail.contains(&self.pattern))
    }
}

/// Parsed allowlist. `#`-lines and blank lines are comments; every entry
/// must carry a non-empty justification (that is the point of the file).
#[derive(Debug, Default)]
pub struct Allowlist {
    pub entries: Vec<AllowEntry>,
}

impl Allowlist {
    pub fn parse(text: &str) -> Result<Allowlist> {
        let mut entries = Vec::new();
        for (i, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.splitn(4, '|');
            let (pass, file_suffix, pattern, justification) = match (
                parts.next(),
                parts.next(),
                parts.next(),
                parts.next(),
            ) {
                (Some(a), Some(b), Some(c), Some(d)) => (a.trim(), b.trim(), c.trim(), d.trim()),
                _ => bail!(
                    "lint.allow:{}: expected `pass|file-suffix|pattern|justification`",
                    i + 1
                ),
            };
            if !PASSES.contains(&pass) {
                bail!("lint.allow:{}: unknown pass `{pass}`", i + 1);
            }
            if pattern.is_empty() {
                bail!("lint.allow:{}: empty pattern", i + 1);
            }
            if justification.is_empty() {
                bail!("lint.allow:{}: entry needs a justification", i + 1);
            }
            entries.push(AllowEntry {
                pass: pass.to_string(),
                file_suffix: file_suffix.to_string(),
                pattern: pattern.to_string(),
                justification: justification.to_string(),
                line: i + 1,
            });
        }
        Ok(Allowlist { entries })
    }

    pub fn load(path: &Path) -> Result<Allowlist> {
        let text = fs::read_to_string(path)
            .with_context(|| format!("read allowlist {}", path.display()))?;
        Self::parse(&text)
    }

    /// Index of the first entry permitting `f`, if any.
    pub fn permits(&self, f: &Finding) -> Option<usize> {
        self.entries.iter().position(|e| e.matches(f))
    }
}

/// Recursively collect `.rs` files under `root`, returning paths relative
/// to it with `/` separators, in a deterministic order.
pub fn collect_sources(root: &Path) -> Result<Vec<SourceFile>> {
    fn walk(root: &Path, dir: &Path, out: &mut Vec<SourceFile>) -> Result<()> {
        let mut entries: Vec<_> = fs::read_dir(dir)
            .with_context(|| format!("read dir {}", dir.display()))?
            .collect::<std::io::Result<_>>()?;
        entries.sort_by_key(|e| e.file_name());
        for e in entries {
            let p = e.path();
            if p.is_dir() {
                walk(root, &p, out)?;
            } else if p.extension().is_some_and(|x| x == "rs") {
                let rel = p
                    .strip_prefix(root)
                    .unwrap_or(&p)
                    .components()
                    .map(|c| c.as_os_str().to_string_lossy())
                    .collect::<Vec<_>>()
                    .join("/");
                let text = fs::read_to_string(&p)
                    .with_context(|| format!("read {}", p.display()))?;
                out.push(SourceFile { path: rel, text });
            }
        }
        Ok(())
    }
    let mut out = Vec::new();
    walk(root, root, &mut out)?;
    Ok(out)
}

/// Run every pass over `files` (findings are pre-allowlist).
pub fn run_all(files: &[SourceFile]) -> Vec<Finding> {
    let mut findings = Vec::new();
    findings.extend(locks::check(files));
    findings.extend(panics::check(files));
    findings.extend(ledger::check(files));
    findings
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allowlist_parses_and_matches() {
        let text = "\
# comment
panic-path|server/mod.rs|unwrap|invariant: channel outlives sender

io-under-lock | server/scheduler.rs | send | replies drained off-lock
";
        let allow = Allowlist::parse(text).unwrap();
        assert_eq!(allow.entries.len(), 2);
        let f = Finding {
            pass: "panic-path",
            file: "server/mod.rs".into(),
            line: 10,
            what: "unwrap".into(),
            detail: "x".into(),
        };
        assert_eq!(allow.permits(&f), Some(0));
        let other = Finding { file: "server/scheduler.rs".into(), ..f.clone() };
        assert_eq!(allow.permits(&other), None);
    }

    #[test]
    fn allowlist_requires_justification() {
        assert!(Allowlist::parse("panic-path|a.rs|unwrap|").is_err());
        assert!(Allowlist::parse("panic-path|a.rs|unwrap").is_err());
        assert!(Allowlist::parse("no-such-pass|a.rs|x|why").is_err());
    }
}
