//! `ledger-drift` pass: the telemetry ledger's four-legged contract.
//!
//! Every counter field in `server::Telemetry` / `server::DeviceTelemetry`
//! must have (1) an increment site somewhere under `server/`, (2) a
//! serialization site in the `stats` op (its wire key appears as a string
//! literal in `server/mod.rs`), and (3) a `///` doc comment on the field.
//! Aggregate (`Telemetry`) fields additionally need (4) a Prometheus
//! scrape row: one of their wire keys must appear in the `PROM_METRICS`
//! table that drives the `metrics` op, so a future counter cannot ship
//! without a scrape line. (Per-device fields are exempt — the renderer
//! derives `foresight_device_*` families generically from the
//! `per_device` objects, so they cannot drift.) A counter missing any
//! leg is drift: it either reads zero forever, is invisible on the wire,
//! or nobody knows what it means.
//!
//! Field kinds are classified by type: `Atomic*` fields are counters
//! (increment = `fetch_add`/`fetch_max`/`fetch_sub` near a `.field`
//! access), `Mutex<Reservoir>`/`OrderedMutex<Reservoir>` fields are
//! sample stores (increment = `push`). Other fields (`per_device`,
//! config) are not ledger entries. Aggregate and per-device fields that
//! share a name (`joins`, `occupancy`, …) are folded: one increment site
//! anywhere satisfies both, which matches how the scheduler credits both
//! ledgers at the same event.
//!
//! Wire keys that differ from the field name live in [`wire_names`]; add
//! a mapping there when serializing a counter under a transformed key
//! (`degrade_headroom_us` → `degrade_headroom_s`, reservoirs → their
//! derived percentile/mean keys).

use super::{Finding, SourceFile};

const PASS: &str = "ledger-drift";

/// The structs whose fields form the ledger.
const STRUCTS: [&str; 2] = ["Telemetry", "DeviceTelemetry"];

#[derive(Debug, Clone, Copy, PartialEq)]
enum Kind {
    Counter,
    Reservoir,
}

#[derive(Debug)]
struct Field {
    name: String,
    kind: Kind,
    line: usize,
    has_doc: bool,
}

/// Wire keys under which a field may legitimately surface in the `stats`
/// op. Defaults to the field name itself.
pub fn wire_names(field: &str) -> Vec<String> {
    match field {
        "occupancy" => vec!["occupancy_mean".into()],
        "occupancy_peak" => vec!["occupancy_max".into()],
        "degrade_headroom_us" => vec!["degrade_headroom_s".into()],
        "latencies_s" => vec!["latency_mean_s".into(), "latency_p50_s".into()],
        "queue_s" => vec!["queue_mean_s".into(), "queue_p95_s".into()],
        f => vec![f.to_string()],
    }
}

pub fn check(files: &[SourceFile]) -> Vec<Finding> {
    let Some(main) = files.iter().find(|f| f.path.ends_with("server/mod.rs")) else {
        return Vec::new();
    };
    let mut fields = Vec::new();
    for s in STRUCTS {
        parse_counters(&main.text, s, &mut fields);
    }
    // Fold same-named aggregate/per-device fields: keep the first.
    fields.dedup_by(|a, b| a.name == b.name);

    // Increment sites may live anywhere under server/ (the scheduler
    // credits most of the ledger); serialization keys must appear in the
    // stats op, i.e. in server/mod.rs itself.
    let hay: String = files
        .iter()
        .filter(|f| f.path.contains("server/"))
        .map(|f| f.text.replace(['\n', '\r'], " "))
        .collect::<Vec<_>>()
        .join(" ");

    let mut out = Vec::new();
    for f in &fields {
        let markers: &[&str] = match f.kind {
            Kind::Counter => &["fetch_add", "fetch_max", "fetch_sub"],
            Kind::Reservoir => &["push"],
        };
        if !has_increment(&hay, &f.name, markers) {
            out.push(finding(main, f, "no increment site", markers));
        }
        let serialized = wire_names(&f.name)
            .iter()
            .any(|w| main.text.contains(&format!("\"{w}\"")));
        if !serialized {
            out.push(Finding {
                pass: PASS,
                file: main.path.clone(),
                line: f.line,
                what: f.name.clone(),
                detail: format!(
                    "counter `{}` is never serialized in the stats op (expected one of {:?} \
                     as a wire key; see lint::ledger::wire_names)",
                    f.name,
                    wire_names(&f.name)
                ),
            });
        }
        if !f.has_doc {
            out.push(Finding {
                pass: PASS,
                file: main.path.clone(),
                line: f.line,
                what: f.name.clone(),
                detail: format!("counter `{}` has no /// doc comment", f.name),
            });
        }
    }

    // Leg 4: Prometheus coverage. Only aggregate (`Telemetry`) fields
    // need a PROM_METRICS row — the per-device families render
    // generically from the `per_device` objects.
    let mut tel_fields = Vec::new();
    parse_counters(&main.text, "Telemetry", &mut tel_fields);
    match parse_prom_keys(&main.text) {
        Some(prom) => {
            for f in &tel_fields {
                if !wire_names(&f.name).iter().any(|w| prom.contains(w)) {
                    out.push(Finding {
                        pass: PASS,
                        file: main.path.clone(),
                        line: f.line,
                        what: f.name.clone(),
                        detail: format!(
                            "counter `{}` has no Prometheus scrape row (expected one of {:?} \
                             as a PROM_METRICS key in server/mod.rs)",
                            f.name,
                            wire_names(&f.name)
                        ),
                    });
                }
            }
        }
        None => {
            if !tel_fields.is_empty() {
                out.push(Finding {
                    pass: PASS,
                    file: main.path.clone(),
                    line: 0,
                    what: "PROM_METRICS".to_string(),
                    detail: "no PROM_METRICS table in server/mod.rs — the metrics op \
                             cannot scrape the ledger"
                        .to_string(),
                });
            }
        }
    }
    out
}

/// Extract the metric keys from the `PROM_METRICS` table literal in
/// `server/mod.rs`: the first string of every `("key", "help")` tuple up
/// to the closing `];`. Anchors on the `const` declaration (doc comments
/// mention the name earlier). `None` when the table is absent entirely.
fn parse_prom_keys(text: &str) -> Option<Vec<String>> {
    let start = text.find("const PROM_METRICS")?;
    let rest = &text[start..];
    let body: Vec<char> = rest[..rest.find("];")?].chars().collect();
    let mut keys = Vec::new();
    let mut i = 0;
    while i < body.len() {
        if body[i] == '(' {
            let mut j = i + 1;
            while j < body.len() && body[j].is_whitespace() {
                j += 1;
            }
            if j < body.len() && body[j] == '"' {
                let mut e = j + 1;
                while e < body.len() && body[e] != '"' {
                    e += 1;
                }
                keys.push(body[j + 1..e].iter().collect());
                i = e;
            }
        }
        i += 1;
    }
    Some(keys)
}

fn finding(main: &SourceFile, f: &Field, leg: &str, markers: &[&str]) -> Finding {
    Finding {
        pass: PASS,
        file: main.path.clone(),
        line: f.line,
        what: f.name.clone(),
        detail: format!(
            "counter `{}` has {leg} (looked for `.{}` near {:?} under server/)",
            f.name, f.name, markers
        ),
    }
}

/// `.name` access followed by an increment marker within a short window —
/// tolerant of rustfmt line wrapping (the haystack is newline-flattened).
fn has_increment(hay: &str, name: &str, markers: &[&str]) -> bool {
    let needle = format!(".{name}");
    let mut from = 0;
    while let Some(at) = hay[from..].find(&needle) {
        let start = from + at + needle.len();
        // Reject partial-ident matches like `.requests_total`.
        let boundary = match hay[start..].chars().next() {
            Some(c) => !c.is_alphanumeric() && c != '_',
            None => true,
        };
        if boundary {
            let window = &hay[start..(start + 64).min(hay.len())];
            if markers.iter().any(|m| window.contains(m)) {
                return true;
            }
        }
        from = start;
    }
    false
}

/// Line-based parse of `struct <name> { … }`: collect Atomic/Reservoir
/// fields with their doc status. Field declarations in this codebase are
/// single-line (`name: AtomicU64,`), which the parser assumes.
fn parse_counters(text: &str, struct_name: &str, out: &mut Vec<Field>) {
    let header = format!("struct {struct_name} {{");
    let mut in_struct = false;
    let mut depth = 0i32;
    let mut doc_run = false;
    for (i, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if !in_struct {
            if line.contains(&header) {
                in_struct = true;
                depth = 1;
            }
            continue;
        }
        depth += line.matches('{').count() as i32;
        depth -= line.matches('}').count() as i32;
        if depth <= 0 {
            return;
        }
        if line.starts_with("///") {
            doc_run = true;
            continue;
        }
        if let Some((name, ty)) = split_field(line) {
            let kind = if ty.contains("Atomic") {
                Some(Kind::Counter)
            } else if ty.contains("Reservoir") {
                Some(Kind::Reservoir)
            } else {
                None
            };
            if let Some(kind) = kind {
                out.push(Field { name, kind, line: i + 1, has_doc: doc_run });
            }
        }
        doc_run = false;
    }
}

/// `pub name: Type,` → (name, type text). `None` for non-field lines.
fn split_field(line: &str) -> Option<(String, String)> {
    if line.starts_with("//") || line.starts_with('#') {
        return None;
    }
    let line = line.strip_prefix("pub ").unwrap_or(line);
    let (name, ty) = line.split_once(':')?;
    let name = name.trim();
    if name.is_empty() || !name.chars().all(|c| c.is_alphanumeric() || c == '_') {
        return None;
    }
    Some((name.to_string(), ty.trim().to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;

    const GOOD: &str = r#"
struct Telemetry {
    /// Requests served.
    requests: AtomicU64,
    /// Events ring-buffered by the tracer (mirrored by fetch_max).
    trace_events: AtomicU64,
    /// Per-request wall latency.
    latencies_s: Mutex<Reservoir>,
    per_device: Vec<DeviceTelemetry>,
}
fn serve(t: &Telemetry) {
    t.requests.fetch_add(1, Ordering::Relaxed);
    t.trace_events.fetch_max(7, Ordering::Relaxed);
    t.latencies_s.lock().push(0.5);
    let resp = vec![("requests", 1.0), ("trace_events", 7.0), ("latency_mean_s", 2.0)];
}
const PROM_METRICS: &[(&str, &str)] = &[
    ("requests", "Requests served"),
    ("trace_events", "Tracer events"),
    ("latency_mean_s", "Mean latency"),
];
"#;

    #[test]
    fn balanced_ledger_is_clean() {
        let fs = check(&[SourceFile::new("server/mod.rs", GOOD)]);
        assert!(fs.is_empty(), "{fs:?}");
    }

    #[test]
    fn flags_unincremented_counter() {
        let src = r#"
struct Telemetry {
    /// Added for a future subsystem; nothing bumps it.
    orphans: AtomicU64,
}
fn serve() {
    let resp = vec![("orphans", 0.0)];
}
const PROM_METRICS: &[(&str, &str)] = &[("orphans", "never bumped")];
"#;
        let fs = check(&[SourceFile::new("server/mod.rs", src)]);
        assert_eq!(fs.len(), 1, "{fs:?}");
        assert_eq!(fs[0].what, "orphans");
        assert!(fs[0].detail.contains("no increment site"));
    }

    #[test]
    fn flags_unserialized_and_undocumented() {
        let src = r#"
struct Telemetry {
    ghosts: AtomicU64,
}
fn serve(t: &Telemetry) {
    t.ghosts.fetch_add(1, Ordering::Relaxed);
}
const PROM_METRICS: &[(&str, &str)] = &[("unrelated", "x")];
"#;
        let fs = check(&[SourceFile::new("server/mod.rs", src)]);
        let details: Vec<&str> = fs.iter().map(|f| f.detail.as_str()).collect();
        assert_eq!(fs.len(), 3, "{fs:?}");
        assert!(details.iter().any(|d| d.contains("never serialized")));
        assert!(details.iter().any(|d| d.contains("no /// doc comment")));
        assert!(details.iter().any(|d| d.contains("no Prometheus scrape row")));
    }

    #[test]
    fn increments_found_across_server_files() {
        let main = r#"
struct Telemetry {
    /// Work stolen.
    steals: AtomicU64,
}
fn serve() {
    let resp = vec![("steals", 0.0)];
}
const PROM_METRICS: &[(&str, &str)] = &[("steals", "Work stolen")];
"#;
        let sched = "fn steal(t: &Telemetry) { t.steals.fetch_add(1, Ordering::Relaxed); }";
        let fs = check(&[
            SourceFile::new("server/mod.rs", main),
            SourceFile::new("server/scheduler.rs", sched),
        ]);
        assert!(fs.is_empty(), "{fs:?}");
    }

    #[test]
    fn partial_ident_matches_do_not_count() {
        let src = r#"
struct Telemetry {
    /// Never actually bumped.
    reject: AtomicU64,
}
fn serve(t: &Telemetry) {
    t.rejected_total.fetch_add(1, Ordering::Relaxed);
    let resp = vec![("reject", 0.0)];
}
const PROM_METRICS: &[(&str, &str)] = &[("reject", "Never bumped")];
"#;
        let fs = check(&[SourceFile::new("server/mod.rs", src)]);
        assert_eq!(fs.len(), 1, "{fs:?}");
        assert!(fs[0].detail.contains("no increment site"));
    }

    #[test]
    fn flags_counter_missing_prom_row() {
        // Healthy on the first three legs, but nothing scrapes it.
        let src = r#"
struct Telemetry {
    /// Fully wired, never exported to Prometheus.
    unscraped: AtomicU64,
}
fn serve(t: &Telemetry) {
    t.unscraped.fetch_add(1, Ordering::Relaxed);
    let resp = vec![("unscraped", 0.0)];
}
const PROM_METRICS: &[(&str, &str)] = &[("unrelated", "x")];
"#;
        let fs = check(&[SourceFile::new("server/mod.rs", src)]);
        assert_eq!(fs.len(), 1, "{fs:?}");
        assert_eq!(fs[0].what, "unscraped");
        assert!(fs[0].detail.contains("no Prometheus scrape row"));
    }

    #[test]
    fn missing_prom_table_is_drift() {
        let src = r#"
struct Telemetry {
    /// Served, incremented, documented — but the metrics table is gone.
    requests: AtomicU64,
}
fn serve(t: &Telemetry) {
    t.requests.fetch_add(1, Ordering::Relaxed);
    let resp = vec![("requests", 0.0)];
}
"#;
        let fs = check(&[SourceFile::new("server/mod.rs", src)]);
        assert_eq!(fs.len(), 1, "{fs:?}");
        assert_eq!(fs[0].what, "PROM_METRICS");
        assert!(fs[0].detail.contains("no PROM_METRICS table"));
    }

    #[test]
    fn device_only_fields_are_prom_exempt() {
        // Per-device families render generically from the `per_device`
        // objects, so a DeviceTelemetry-only counter needs no table row.
        let src = r#"
struct Telemetry {
    /// Requests served.
    requests: AtomicU64,
}
struct DeviceTelemetry {
    /// Host-to-device bytes for this replica alone.
    h2d_bytes: AtomicU64,
}
fn serve(t: &Telemetry, d: &DeviceTelemetry) {
    t.requests.fetch_add(1, Ordering::Relaxed);
    d.h2d_bytes.fetch_add(64, Ordering::Relaxed);
    let resp = vec![("requests", 1.0), ("h2d_bytes", 64.0)];
}
const PROM_METRICS: &[(&str, &str)] = &[("requests", "Requests served")];
"#;
        let fs = check(&[SourceFile::new("server/mod.rs", src)]);
        assert!(fs.is_empty(), "{fs:?}");
    }

    #[test]
    fn prom_keys_parse_wrapped_rows() {
        let src = r#"
const PROM_METRICS: &[(&str, &str)] = &[
    ("requests", "Requests served"),
    (
        "queue_depth",
        "Jobs queued right now",
    ),
];
"#;
        let keys = parse_prom_keys(src).expect("table present");
        assert_eq!(keys, vec!["requests".to_string(), "queue_depth".to_string()]);
        assert_eq!(parse_prom_keys("no table here"), None);
    }
}
