//! Analysis tooling: paper-figure instrumentation and project lints.
//!
//! Two unrelated-looking halves that share a purpose — mechanically
//! checking properties the repo otherwise enforces by convention:
//!
//! * [`dynamics`] — feature-dynamics instrumentation (paper Figs. 2, 3,
//!   11–14): a [`DynamicsRecorder`] streams block outputs into the
//!   statistics those figures plot.
//! * [`lint`] — project-invariant static analysis over `rust/src`, run as
//!   `foresight lint` and as a CI leg. The four passes and the invariants
//!   they pin down are below.
//!
//! # Concurrency invariants (CONTRIBUTING notes)
//!
//! The serving stack (PRs 5–7) is a sharded continuous scheduler: per-
//! device queues and a shared condvar behind `Router::state`, work
//! stealing, session migration, deadline sweeps, and a telemetry ledger.
//! Its correctness rests on three rules that `lint` now enforces:
//!
//! 1. **Canonical lock order.** Locks are acquired in strictly increasing
//!    rank. The order lives in one place — the rank table in
//!    [`util::sync`](crate::util::sync) — and is enforced twice: at
//!    runtime by [`OrderedMutex`](crate::util::sync::OrderedMutex)'s
//!    debug-build checker (panics at the offending acquisition), and
//!    statically by the `lock-order` pass, which extracts the
//!    nested-guard acquisition graph per function across `server/`,
//!    `runtime/` and `util/threadpool.rs`, ranks guards by field name,
//!    and reports inversions and cycles. When adding a lock: use
//!    `OrderedMutex`, pick a rank consistent with every existing nesting,
//!    extend the table in `util::sync`, and add the field name to
//!    `lint::locks::classify`.
//!
//! 2. **Off-lock replies (no I/O under `Router::state`).** A worker
//!    holding the router lock may only mutate queue/device bookkeeping.
//!    Socket writes, client replies (`reply.send`), and device work
//!    (`step_many`, `migrate`, `finish`, engine admission) all happen
//!    after the guard drops — anything else stalls every worker behind
//!    one slow reply. The `io-under-lock` pass flags send/write/step/
//!    migrate-family calls made while a `Router::state` guard is live.
//!
//! 3. **No panic paths in serving code.** `unwrap`/`expect`/`panic!`/
//!    `unreachable!` are forbidden in non-test `server/`, `runtime/`,
//!    `util/threadpool.rs` and `util/sync.rs` code: a panicking handler
//!    must degrade to an error response, never poison shared state (all
//!    shared locks are poison-tolerant, see `util::sync`). Deliberate
//!    exceptions live in `rust/lint.allow`, one per line as
//!    `pass|file-suffix|pattern|justification` — every entry carries a
//!    non-empty justification and unused entries are reported, so the
//!    allowlist cannot silently rot.
//!
//! # Telemetry ledger
//!
//! Every counter in `server::Telemetry` / `server::DeviceTelemetry` is a
//! three-legged contract: it must be **incremented** somewhere,
//! **serialized** in the `stats` op, and **documented** on the field.
//! The `ledger-drift` pass checks all three legs, so a counter added for
//! a new subsystem can't silently read zero forever (or be exposed under
//! a key nothing writes). When adding a counter: document the field with
//! `///`, increment it on the event path, serialize it in the `stats`
//! arm, and — if its wire key differs from the field name — add the
//! mapping to `lint::ledger::wire_names`.
//!
//! Run locally with `cargo run -- lint` (add `--verbose` for allowlisted
//! findings); CI runs the same command and fails on any non-allowlisted
//! finding.

pub mod dynamics;
pub mod lint;

pub use dynamics::DynamicsRecorder;
