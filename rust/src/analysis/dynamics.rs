//! Feature-dynamics instrumentation (paper Figs. 2, 3, 11-14).
//!
//! A [`DynamicsRecorder`] plugs into the engine as a [`StepObserver`] and
//! streams block outputs into the statistics the paper's analysis figures
//! plot, without retaining full feature histories:
//!
//! * MSE between consecutive *steps* per (layer, kind) — Fig. 2 heatmap,
//!   Fig. 3a, Fig. 11;
//! * cosine similarity between consecutive steps — Fig. 12/14;
//! * cosine similarity between consecutive *layers* within a step — Fig. 13.

use std::collections::BTreeMap;

use crate::engine::StepObserver;
use crate::model::BlockKind;
use crate::util::stats::{cosine_f32, mse_f32};

/// Streaming recorder of feature-change statistics.
#[derive(Default)]
pub struct DynamicsRecorder {
    /// Previous step's features per (layer, kind).
    prev_step: BTreeMap<(usize, BlockKind), Vec<f32>>,
    /// Previous layer's features within the current step, per kind.
    prev_layer: BTreeMap<BlockKind, (usize, Vec<f32>)>,
    current_step: Option<usize>,
    /// step → (layer, kind) → MSE vs previous step.
    pub step_mse: BTreeMap<usize, BTreeMap<(usize, BlockKind), f64>>,
    /// step → (layer, kind) → cosine vs previous step.
    pub step_cos: BTreeMap<usize, BTreeMap<(usize, BlockKind), f64>>,
    /// step → (layer, kind) → cosine vs previous layer (same kind).
    pub layer_cos: BTreeMap<usize, BTreeMap<(usize, BlockKind), f64>>,
}

impl DynamicsRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Mean consecutive-step MSE of one layer over all recorded steps
    /// (a Fig. 2 heatmap row aggregate).
    pub fn mean_step_mse(&self, layer: usize, kind: BlockKind) -> f64 {
        let vals: Vec<f64> = self
            .step_mse
            .values()
            .filter_map(|m| m.get(&(layer, kind)).copied())
            .collect();
        if vals.is_empty() {
            0.0
        } else {
            vals.iter().sum::<f64>() / vals.len() as f64
        }
    }

    /// The Fig. 2-style heatmap: rows = layers, cols = steps (MSE).
    pub fn heatmap(&self, layers: usize, kind: BlockKind) -> Vec<Vec<f64>> {
        let steps: Vec<usize> = self.step_mse.keys().copied().collect();
        (0..layers)
            .map(|l| {
                steps
                    .iter()
                    .map(|s| {
                        self.step_mse
                            .get(s)
                            .and_then(|m| m.get(&(l, kind)).copied())
                            .unwrap_or(0.0)
                    })
                    .collect()
            })
            .collect()
    }
}

impl StepObserver for DynamicsRecorder {
    fn on_block(&mut self, step: usize, layer: usize, kind: BlockKind, data: &[f32]) {
        if self.current_step != Some(step) {
            self.current_step = Some(step);
            self.prev_layer.clear();
        }
        // consecutive-step stats
        if let Some(prev) = self.prev_step.get(&(layer, kind)) {
            if prev.len() == data.len() {
                self.step_mse
                    .entry(step)
                    .or_default()
                    .insert((layer, kind), mse_f32(prev, data));
                self.step_cos
                    .entry(step)
                    .or_default()
                    .insert((layer, kind), cosine_f32(prev, data));
            }
        }
        // consecutive-layer stats (within the current step)
        if let Some((prev_l, prev_data)) = self.prev_layer.get(&kind) {
            if *prev_l + 1 == layer && prev_data.len() == data.len() {
                self.layer_cos
                    .entry(step)
                    .or_default()
                    .insert((layer, kind), cosine_f32(prev_data, data));
            }
        }
        self.prev_step.insert((layer, kind), data.to_vec());
        self.prev_layer.insert(kind, (layer, data.to_vec()));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_step_mse_from_second_step() {
        let mut r = DynamicsRecorder::new();
        let a = vec![0.0f32; 8];
        let b = vec![1.0f32; 8];
        r.on_block(0, 0, BlockKind::Spatial, &a);
        assert!(r.step_mse.is_empty());
        r.on_block(1, 0, BlockKind::Spatial, &b);
        let m = r.step_mse[&1][&(0, BlockKind::Spatial)];
        assert!((m - 1.0).abs() < 1e-12);
    }

    #[test]
    fn layer_cosine_within_step() {
        let mut r = DynamicsRecorder::new();
        let a = vec![1.0f32, 0.0, 0.0, 0.0];
        let b = vec![1.0f32, 0.0, 0.0, 0.0];
        r.on_block(0, 0, BlockKind::Spatial, &a);
        r.on_block(0, 1, BlockKind::Spatial, &b);
        let c = r.layer_cos[&0][&(1, BlockKind::Spatial)];
        assert!((c - 1.0).abs() < 1e-9);
    }

    #[test]
    fn kinds_tracked_separately() {
        let mut r = DynamicsRecorder::new();
        r.on_block(0, 0, BlockKind::Spatial, &[1.0, 2.0]);
        r.on_block(0, 0, BlockKind::Temporal, &[5.0, 6.0]);
        r.on_block(1, 0, BlockKind::Spatial, &[1.0, 2.0]);
        r.on_block(1, 0, BlockKind::Temporal, &[5.0, 6.0]);
        assert_eq!(r.step_mse[&1][&(0, BlockKind::Spatial)], 0.0);
        assert_eq!(r.step_mse[&1][&(0, BlockKind::Temporal)], 0.0);
    }

    #[test]
    fn heatmap_shape() {
        let mut r = DynamicsRecorder::new();
        for step in 0..3 {
            for layer in 0..2 {
                let v = vec![(step * 2 + layer) as f32; 4];
                r.on_block(step, layer, BlockKind::Spatial, &v);
            }
        }
        let hm = r.heatmap(2, BlockKind::Spatial);
        assert_eq!(hm.len(), 2);
        assert_eq!(hm[0].len(), 2); // steps 1 and 2 recorded
        assert!(hm[0][0] > 0.0);
        assert!(r.mean_step_mse(0, BlockKind::Spatial) > 0.0);
    }
}
