//! Host-side dense f32 tensor (row-major), the CPU counterpart of the
//! device-resident [`super::DeviceTensor`].

/// Row-major f32 tensor with explicit dims.
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn new(dims: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(
            dims.iter().product::<usize>(),
            data.len(),
            "dims {:?} do not match data length {}",
            dims,
            data.len()
        );
        Self { dims, data }
    }

    pub fn zeros(dims: Vec<usize>) -> Self {
        let n = dims.iter().product();
        Self { dims, data: vec![0.0; n] }
    }

    pub fn scalar(v: f32) -> Self {
        Self { dims: vec![], data: vec![v] }
    }

    pub fn element_count(&self) -> usize {
        self.data.len()
    }

    /// Reinterpret with new dims (same element count).
    pub fn reshaped(mut self, dims: Vec<usize>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), self.data.len());
        self.dims = dims;
        self
    }

    /// Elementwise a - b into a fresh tensor.
    pub fn sub(&self, other: &Self) -> Self {
        assert_eq!(self.dims, other.dims);
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a - b)
            .collect();
        Self { dims: self.dims.clone(), data }
    }

    /// Elementwise a + b into a fresh tensor.
    pub fn add(&self, other: &Self) -> Self {
        assert_eq!(self.dims, other.dims);
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a + b)
            .collect();
        Self { dims: self.dims.clone(), data }
    }

    /// In-place scale.
    pub fn scale(&mut self, s: f32) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    pub fn l2_norm(&self) -> f64 {
        self.data.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_arith() {
        let a = HostTensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = HostTensor::new(vec![2, 2], vec![4.0, 3.0, 2.0, 1.0]);
        assert_eq!(a.add(&b).data, vec![5.0; 4]);
        assert_eq!(a.sub(&a).data, vec![0.0; 4]);
        assert_eq!(a.element_count(), 4);
    }

    #[test]
    #[should_panic]
    fn mismatched_dims_panic() {
        HostTensor::new(vec![3], vec![1.0]);
    }

    #[test]
    fn zeros_scale_norm() {
        let mut z = HostTensor::zeros(vec![4]);
        assert_eq!(z.l2_norm(), 0.0);
        z.data = vec![3.0, 4.0, 0.0, 0.0];
        z.scale(2.0);
        assert!((z.l2_norm() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn reshape_keeps_data() {
        let a = HostTensor::new(vec![2, 3], (0..6).map(|i| i as f32).collect());
        let b = a.clone().reshaped(vec![3, 2]);
        assert_eq!(b.dims, vec![3, 2]);
        assert_eq!(b.data, a.data);
    }
}
