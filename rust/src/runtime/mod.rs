//! PJRT runtime: loads AOT HLO-text artifacts and executes them.
//!
//! This is the only module that touches the `xla` crate. The pattern follows
//! /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute_b` with
//! device-resident buffers. HLO **text** (not serialized proto) is the
//! interchange format — see python/compile/aot.py for why.
//!
//! Besides the AOT artifacts, the runtime builds small **fused executables**
//! at run time (cached per shape): elementwise add/sub for residual reuse,
//! an `mse` reduction so Foresight's drift measurement downloads one f32
//! instead of a full activation, a `cfg_combine` fusion so only fused
//! results ever leave the device, and the sampler-step primitives —
//! `scale`/`axpy` (rflow Euler is a single axpy) and the fused `ddim_step`
//! (x0-prediction, clamp, re-noising in one dispatch) — that let the
//! engine keep the latent device-resident for a whole request. The
//! forecast reuse path adds `lms_combine`: an order-k linear-multistep
//! extrapolation `Σ cᵢ·hᵢ` over a site's cached history in one dispatch,
//! coefficients as rank-0 runtime arguments (see [`lms_coefficients`]),
//! so a Predict step moves exactly as many bytes as verbatim replay:
//! none. Every
//! host↔device copy is metered in [`TransferStats`] (see `engine` module
//! docs §Hot path for the byte model).
//!
//! The fused-op cache is **batch-shape-aware**: because executables are
//! keyed by `(op, dims)` and every op is elementwise (or reduces over all
//! axes), the same builders serve a micro-batch of `B` stacked requests by
//! simply being asked for `[B, F, P, C]`-shaped variants. Two batching
//! primitives complete the set: [`Runtime::stack`] concatenates `B`
//! per-request tensors along a new leading batch axis and
//! [`Runtime::lane`] slices one request's lane back out (both pure device
//! data movement — no bytes cross the bus).
//!
//! Thread-safety: the PJRT CPU client and its loaded executables are
//! internally thread-safe, but the `xla` crate wraps raw pointers and so
//! doesn't declare `Send`/`Sync`. [`Runtime`] asserts those bounds via the
//! `Shared` wrapper below; the serving integration test exercises
//! concurrent execution from multiple workers, and the engine executes the
//! two CFG branches of one request on concurrent scoped threads.

pub mod tensor;

use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::trace;
use crate::util::sync::{OrderedMutex, RANK_RUNTIME_EXEC_CACHE, RANK_RUNTIME_FUSED_CACHE};

pub use tensor::HostTensor;

/// Wrapper asserting thread-safety of PJRT objects (see module docs).
struct Shared<T>(T);
// SAFETY: PJRT CPU client/executable/buffer handles are internally
// synchronised; the xla crate merely lacks the declarations.
unsafe impl<T> Send for Shared<T> {}
unsafe impl<T> Sync for Shared<T> {}

/// A device-resident tensor (opaque PJRT buffer + shape metadata).
pub struct DeviceTensor {
    buf: Shared<xla::PjRtBuffer>,
    dims: Vec<usize>,
}

impl DeviceTensor {
    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }

    fn raw(&self) -> &xla::PjRtBuffer {
        &self.buf.0
    }
}

/// Cumulative execution telemetry for one executable (drives the Fig. 9
/// operator-breakdown reproduction).
#[derive(Debug, Default)]
pub struct ExecStats {
    pub calls: AtomicU64,
    pub nanos: AtomicU64,
}

impl ExecStats {
    pub fn record(&self, dt: std::time::Duration) {
        self.calls.fetch_add(1, Ordering::Relaxed);
        self.nanos.fetch_add(dt.as_nanos() as u64, Ordering::Relaxed);
    }

    /// (call count, total seconds).
    pub fn snapshot(&self) -> (u64, f64) {
        (
            self.calls.load(Ordering::Relaxed),
            self.nanos.load(Ordering::Relaxed) as f64 * 1e-9,
        )
    }

    pub fn reset(&self) {
        self.calls.store(0, Ordering::Relaxed);
        self.nanos.store(0, Ordering::Relaxed);
    }
}

/// Cumulative host↔device transfer telemetry for one [`Runtime`]. Uploads
/// and downloads are the engine's only host-side costs once the hot path is
/// device-resident, so benches assert on these counters directly
/// (`benches/fig16_hotpath.rs`).
#[derive(Debug, Default)]
pub struct TransferStats {
    pub h2d_calls: AtomicU64,
    pub h2d_bytes: AtomicU64,
    pub d2h_calls: AtomicU64,
    pub d2h_bytes: AtomicU64,
}

impl TransferStats {
    fn record_h2d(&self, bytes: usize) {
        self.h2d_calls.fetch_add(1, Ordering::Relaxed);
        self.h2d_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    fn record_d2h(&self, bytes: usize) {
        self.d2h_calls.fetch_add(1, Ordering::Relaxed);
        self.d2h_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> TransferSnapshot {
        TransferSnapshot {
            h2d_calls: self.h2d_calls.load(Ordering::Relaxed),
            h2d_bytes: self.h2d_bytes.load(Ordering::Relaxed),
            d2h_calls: self.d2h_calls.load(Ordering::Relaxed),
            d2h_bytes: self.d2h_bytes.load(Ordering::Relaxed),
        }
    }

    pub fn reset(&self) {
        self.h2d_calls.store(0, Ordering::Relaxed);
        self.h2d_bytes.store(0, Ordering::Relaxed);
        self.d2h_calls.store(0, Ordering::Relaxed);
        self.d2h_bytes.store(0, Ordering::Relaxed);
    }
}

/// Point-in-time copy of [`TransferStats`] counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TransferSnapshot {
    pub h2d_calls: u64,
    pub h2d_bytes: u64,
    pub d2h_calls: u64,
    pub d2h_bytes: u64,
}

impl TransferSnapshot {
    /// Counter deltas accumulated since `earlier` was taken.
    pub fn delta_since(&self, earlier: &TransferSnapshot) -> TransferSnapshot {
        TransferSnapshot {
            h2d_calls: self.h2d_calls - earlier.h2d_calls,
            h2d_bytes: self.h2d_bytes - earlier.h2d_bytes,
            d2h_calls: self.d2h_calls - earlier.d2h_calls,
            d2h_bytes: self.d2h_bytes - earlier.d2h_bytes,
        }
    }
}

/// N independent runtime replicas — the unit of data-parallel scale-out.
///
/// Each ordinal owns a full [`Runtime`]: its own PJRT client, compiled- and
/// fused-executable caches, and [`TransferStats`]. Nothing is shared between
/// ordinals, so per-device transfer counters stay an honest account of what
/// crossed *that* device's bus, and a model must be loaded once per ordinal
/// (weights are device-resident state). The sharded server
/// (`server::scheduler`) runs one worker per ordinal and migrates sessions
/// between them; `DevicePool::cpu(1)` degenerates to exactly the old
/// single-runtime world.
pub struct DevicePool {
    devices: Vec<Arc<Runtime>>,
}

impl DevicePool {
    /// Construct `n.max(1)` independent CPU runtimes.
    pub fn cpu(n: usize) -> Result<Self> {
        let n = n.max(1);
        let mut devices = Vec::with_capacity(n);
        for _ in 0..n {
            devices.push(Arc::new(Runtime::cpu()?));
        }
        Ok(Self { devices })
    }

    /// Wrap pre-built runtimes (ordinal = index). Errors on an empty list:
    /// a pool with no devices can serve nothing.
    pub fn from_runtimes(devices: Vec<Arc<Runtime>>) -> Result<Self> {
        if devices.is_empty() {
            return Err(anyhow!("device pool needs at least one runtime"));
        }
        Ok(Self { devices })
    }

    pub fn len(&self) -> usize {
        self.devices.len()
    }

    pub fn is_empty(&self) -> bool {
        // Constructors reject empty pools.
        false
    }

    /// The runtime at `ordinal`; panics on an out-of-range ordinal (device
    /// counts are fixed at construction and validated at config time).
    pub fn device(&self, ordinal: usize) -> &Arc<Runtime> {
        &self.devices[ordinal]
    }

    pub fn devices(&self) -> &[Arc<Runtime>] {
        &self.devices
    }

    /// Per-ordinal transfer counters (index = device ordinal).
    pub fn transfer_snapshots(&self) -> Vec<TransferSnapshot> {
        self.devices.iter().map(|d| d.transfer_stats().snapshot()).collect()
    }
}

/// One compiled HLO module ready to execute.
pub struct Executable {
    name: String,
    exe: Shared<xla::PjRtLoadedExecutable>,
    /// Expected argument count (parsed from the HLO entry layout) so arity
    /// bugs fail with a readable error instead of a PJRT abort.
    arity: usize,
    pub stats: ExecStats,
}

impl Executable {
    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn arity(&self) -> usize {
        self.arity
    }

    /// Execute with device-resident inputs; returns the root output buffer.
    ///
    /// All artifacts are lowered with non-tuple roots (aot.py), so the
    /// result is always exactly one buffer.
    pub fn run(&self, args: &[&DeviceTensor]) -> Result<DeviceTensor> {
        if args.len() != self.arity {
            return Err(anyhow!(
                "{}: expected {} args, got {}",
                self.name,
                self.arity,
                args.len()
            ));
        }
        let t0 = Instant::now();
        let raw: Vec<&xla::PjRtBuffer> = args.iter().map(|a| a.raw()).collect();
        let out = self
            .exe
            .0
            .execute_b(&raw)
            .map_err(|e| anyhow!("execute {}: {e:?}", self.name))?;
        self.stats.record(t0.elapsed());
        let buf = out
            .into_iter()
            .next()
            .and_then(|replica| replica.into_iter().next())
            .ok_or_else(|| anyhow!("{}: no output buffer", self.name))?;
        let shape = buf
            .on_device_shape()
            .map_err(|e| anyhow!("{}: output shape: {e:?}", self.name))?;
        let dims = array_dims(&shape)?;
        Ok(DeviceTensor { buf: Shared(buf), dims })
    }
}

fn array_dims(shape: &xla::Shape) -> Result<Vec<usize>> {
    let ashape = xla::ArrayShape::try_from(shape)
        .map_err(|e| anyhow!("non-array output shape: {e:?}"))?;
    Ok(ashape.dims().iter().map(|&d| d as usize).collect())
}

/// Count parameters in the HLO entry computation layout line, e.g.
/// `entry_computation_layout={(f32[8,48]{1,0}, f32[96]{0})->f32[8,48]{1,0}}`.
///
/// Returns `None` when the text carries no entry layout at all — such an
/// artifact is malformed (aot.py always emits one) and must be rejected at
/// load time rather than aborting inside PJRT at dispatch time.
fn parse_entry_arity(hlo_text: &str) -> Option<usize> {
    let start = hlo_text.find("entry_computation_layout={(")?;
    let rest = &hlo_text[start + "entry_computation_layout={(".len()..];
    let end = rest.find(")->")?;
    let params = &rest[..end];
    if params.trim().is_empty() {
        return Some(0);
    }
    let mut depth = 0usize;
    let mut count = 1usize;
    for ch in params.chars() {
        match ch {
            '[' | '{' | '(' => depth += 1,
            ']' | '}' | ')' => depth = depth.saturating_sub(1),
            ',' if depth == 0 => count += 1,
            _ => {}
        }
    }
    Some(count)
}

/// Fixed linear-multistep extrapolation coefficients for predictor order
/// `k ∈ [1, 4]`, newest history term first: the Lagrange basis of `k`
/// equally-spaced past outputs evaluated **half a spacing ahead** of the
/// newest one (`Σ cᵢ = 1` for every order; order 1 degenerates to
/// verbatim replay `[1.0]`).
///
/// Half a spacing — not the full Adams-Bashforth step — because one
/// forecast serves the *whole* reuse window between two computes: the
/// cache is not refreshed on Predict steps, so every reuse in the window
/// extrapolates from the same history snapshot. Targeting the window
/// midpoint minimises the expected error over the window (a full-step
/// target overshoots the early reuse steps by as much as replay
/// undershoots the late ones, and its larger alternating weights amplify
/// history noise for nothing).
///
/// The engine uploads these once at admit as rank-0 device tensors so
/// [`Runtime::lms_combine`] dispatches with zero per-step host traffic.
pub fn lms_coefficients(order: usize) -> Result<Vec<f32>> {
    match order {
        1 => Ok(vec![1.0]),
        2 => Ok(vec![1.5, -0.5]),
        3 => Ok(vec![1.875, -1.25, 0.375]),
        4 => Ok(vec![2.1875, -2.1875, 1.3125, -0.3125]),
        other => Err(anyhow!("unsupported forecast order {other} (supported: 1..=4)")),
    }
}

/// The PJRT runtime: client + executable cache + fused-executable builder.
pub struct Runtime {
    client: Shared<xla::PjRtClient>,
    /// Compiled executables keyed by absolute artifact path.
    cache: OrderedMutex<BTreeMap<PathBuf, Arc<Executable>>>,
    /// Runtime-built fused executables keyed by (op, dims).
    fused: OrderedMutex<BTreeMap<(String, Vec<usize>), Arc<Executable>>>,
    /// Host↔device copy counters (see [`TransferStats`]).
    transfers: TransferStats,
}

impl Runtime {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu: {e:?}"))?;
        Ok(Self {
            client: Shared(client),
            cache: OrderedMutex::new("runtime.cache", RANK_RUNTIME_EXEC_CACHE, BTreeMap::new()),
            fused: OrderedMutex::new("runtime.fused", RANK_RUNTIME_FUSED_CACHE, BTreeMap::new()),
            transfers: TransferStats::default(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.0.platform_name()
    }

    /// Cumulative host↔device transfer counters for this runtime.
    pub fn transfer_stats(&self) -> &TransferStats {
        &self.transfers
    }

    /// Load + compile an HLO text artifact (cached by path).
    ///
    /// Fails at load time — with a readable error — when the artifact
    /// carries no `entry_computation_layout`, instead of compiling an
    /// executable whose arity check can never pass.
    pub fn load_hlo(&self, path: &Path) -> Result<Arc<Executable>> {
        if let Some(e) = self.cache.lock().get(path) {
            return Ok(e.clone());
        }
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read {}", path.display()))?;
        let arity = parse_entry_arity(&text).ok_or_else(|| {
            anyhow!(
                "{}: no entry_computation_layout in HLO text — artifact is \
                 malformed or truncated; regenerate with python/compile/aot.py",
                path.display()
            )
        })?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parse hlo {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .0
            .compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e:?}", path.display()))?;
        let name = path
            .file_name()
            .map(|s| {
                s.to_string_lossy()
                    .trim_end_matches(".hlo.txt")
                    .to_string()
            })
            .unwrap_or_default();
        let exec = Arc::new(Executable {
            name,
            exe: Shared(exe),
            arity,
            stats: ExecStats::default(),
        });
        self.cache.lock().insert(path.to_path_buf(), exec.clone());
        Ok(exec)
    }

    /// Upload host data to the device.
    pub fn upload(&self, data: &[f32], dims: &[usize]) -> Result<DeviceTensor> {
        let buf = self
            .client
            .0
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("upload: {e:?}"))?;
        self.transfers.record_h2d(data.len() * 4);
        trace::emit_here(trace::Payload::H2d { bytes: (data.len() * 4) as u64 });
        Ok(DeviceTensor { buf: Shared(buf), dims: dims.to_vec() })
    }

    pub fn upload_tensor(&self, t: &HostTensor) -> Result<DeviceTensor> {
        self.upload(&t.data, &t.dims)
    }

    /// Download a device tensor into a new host tensor.
    pub fn download(&self, t: &DeviceTensor) -> Result<HostTensor> {
        let mut out = HostTensor::zeros(t.dims.to_vec());
        self.download_into(t, &mut out.data)?;
        Ok(out)
    }

    /// Download into a pre-allocated host slice (hot path: no allocation).
    pub fn download_into(&self, t: &DeviceTensor, dst: &mut [f32]) -> Result<()> {
        if dst.len() != t.element_count() {
            return Err(anyhow!(
                "download size mismatch: {} vs {}",
                dst.len(),
                t.element_count()
            ));
        }
        // CPU PJRT does not implement CopyRawToHost; route through a
        // Literal (one extra host copy — measured as negligible vs. block
        // execution cost; see EXPERIMENTS.md §Perf).
        let lit = t
            .raw()
            .to_literal_sync()
            .map_err(|e| anyhow!("download (to_literal): {e:?}"))?;
        lit.copy_raw_to(dst)
            .map_err(|e| anyhow!("download (copy_raw): {e:?}"))?;
        self.transfers.record_d2h(dst.len() * 4);
        trace::emit_here(trace::Payload::D2h { bytes: (dst.len() * 4) as u64 });
        Ok(())
    }

    /// Download a single-element tensor as one f32 (4 bytes on the wire —
    /// the Foresight drift measurement path).
    pub fn read_scalar(&self, t: &DeviceTensor) -> Result<f32> {
        if t.element_count() != 1 {
            return Err(anyhow!(
                "read_scalar on tensor with {} elements",
                t.element_count()
            ));
        }
        let mut out = [0.0f32; 1];
        self.download_into(t, &mut out)?;
        Ok(out[0])
    }

    /// Get or build one fused executable. Supported ops and their argument
    /// contracts (all f32; `dims`-shaped unless noted):
    ///
    /// | op            | args                         | result            |
    /// |---------------|------------------------------|-------------------|
    /// | `add`         | `(x, y)`                     | `x + y`           |
    /// | `sub`         | `(x, y)`                     | `x - y`           |
    /// | `mse`         | `(x, y)`                     | `mean((x-y)²)` [] |
    /// | `cfg_combine` | `(uncond, cond, scale [])`   | `u + s·(c - u)`   |
    /// | `scale`       | `(x, alpha [])`              | `alpha·x`         |
    /// | `axpy`        | `(x, y, alpha [])`           | `alpha·x + y`     |
    /// | `ddim_step`   | `(x, eps, sqrt_at [], sqrt_1mat [], sqrt_aprev [], sqrt_1maprev [], lo [], hi [])` | eta-0 DDIM update |
    ///
    /// Scalars are passed as rank-0 parameters (implicit XLA broadcast), so
    /// one compiled executable serves every request regardless of CFG scale
    /// or schedule position — the denoising-schedule scalars are runtime
    /// arguments, not compile-time constants.
    ///
    /// The parametric batching primitives `stack{B}` / `lane{i}` live in
    /// [`Runtime::stack`] and [`Runtime::lane`] (same cache, parametric
    /// keys).
    fn fused_executable(&self, op: &str, dims: &[usize]) -> Result<Arc<Executable>> {
        let key = (op.to_string(), dims.to_vec());
        if let Some(e) = self.fused.lock().get(&key) {
            return Ok(e.clone());
        }
        let b = xla::XlaBuilder::new(&format!("fused_{op}"));
        let idims: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
        let param = |i: i64, pdims: &[i64], name: &str| {
            b.parameter(i, xla::ElementType::F32, pdims, name)
                .map_err(|e| anyhow!("fused {op} param {name}: {e:?}"))
        };
        // All xla builder calls share one error type, so the closure's
        // parameter type is inferred from the call sites.
        let err = |stage: &str, e| anyhow!("fused {op} {stage}: {e:?}");
        let (root, arity) = match op {
            "add" => {
                let x = param(0, &idims, "x")?;
                let y = param(1, &idims, "y")?;
                (x.add_(&y).map_err(|e| err("add", e))?, 2)
            }
            "sub" => {
                let x = param(0, &idims, "x")?;
                let y = param(1, &idims, "y")?;
                (x.sub_(&y).map_err(|e| err("sub", e))?, 2)
            }
            "mse" => {
                let x = param(0, &idims, "x")?;
                let y = param(1, &idims, "y")?;
                let d = x.sub_(&y).map_err(|e| err("sub", e))?;
                let sq = d.mul_(&d).map_err(|e| err("square", e))?;
                let all: Vec<i64> = (0..idims.len() as i64).collect();
                (sq.reduce_mean(&all, false).map_err(|e| err("mean", e))?, 2)
            }
            "cfg_combine" => {
                let u = param(0, &idims, "uncond")?;
                let c = param(1, &idims, "cond")?;
                let s = param(2, &[], "scale")?;
                let diff = c.sub_(&u).map_err(|e| err("sub", e))?;
                let scaled = diff.mul_(&s).map_err(|e| err("scale", e))?;
                (u.add_(&scaled).map_err(|e| err("add", e))?, 3)
            }
            "scale" => {
                let x = param(0, &idims, "x")?;
                let a = param(1, &[], "alpha")?;
                (x.mul_(&a).map_err(|e| err("mul", e))?, 2)
            }
            "axpy" => {
                let x = param(0, &idims, "x")?;
                let y = param(1, &idims, "y")?;
                let a = param(2, &[], "alpha")?;
                let ax = x.mul_(&a).map_err(|e| err("mul", e))?;
                (ax.add_(&y).map_err(|e| err("add", e))?, 3)
            }
            "ddim_step" => {
                // Fused deterministic DDIM update (eta = 0): x0-prediction,
                // the clamp, and re-noising in one dispatch. The schedule
                // scalars AND the clamp bounds are rank-0 runtime arguments
                // so one compiled executable serves every (schedule, step);
                // the op order mirrors sampler::Ddim::step exactly so host
                // and device trajectories agree to f32 rounding.
                let x = param(0, &idims, "x")?;
                let eps = param(1, &idims, "eps")?;
                let sqrt_at = param(2, &[], "sqrt_at")?;
                let sqrt_1mat = param(3, &[], "sqrt_1mat")?;
                let sqrt_aprev = param(4, &[], "sqrt_aprev")?;
                let sqrt_1maprev = param(5, &[], "sqrt_1maprev")?;
                let lo = param(6, &[], "clamp_lo")?;
                let hi = param(7, &[], "clamp_hi")?;
                let noise = eps.mul_(&sqrt_1mat).map_err(|e| err("noise", e))?;
                let num = x.sub_(&noise).map_err(|e| err("x0 numerator", e))?;
                let x0 = num.div_(&sqrt_at).map_err(|e| err("x0 divide", e))?;
                let x0 = x0.max_(&lo).map_err(|e| err("clamp lo", e))?;
                let x0 = x0.min_(&hi).map_err(|e| err("clamp hi", e))?;
                let signal = x0.mul_(&sqrt_aprev).map_err(|e| err("signal", e))?;
                let renoise = eps.mul_(&sqrt_1maprev).map_err(|e| err("renoise", e))?;
                (signal.add_(&renoise).map_err(|e| err("add", e))?, 8)
            }
            other => return Err(anyhow!("unknown fused op {other}")),
        };
        let comp = root.build().map_err(|e| err("build", e))?;
        let exe = self
            .client
            .0
            .compile(&comp)
            .map_err(|e| anyhow!("compile fused_{op}: {e:?}"))?;
        let exec = Arc::new(Executable {
            name: format!("fused_{op}{dims:?}"),
            exe: Shared(exe),
            arity,
            stats: ExecStats::default(),
        });
        self.fused.lock().insert(key, exec.clone());
        Ok(exec)
    }

    /// Runtime-built elementwise binary op over identically-shaped tensors
    /// (used for Δ-DiT / PAB residual-delta reuse so the add/sub stays on
    /// device instead of round-tripping through the host).
    pub fn elementwise_binary(&self, op: &str, dims: &[usize]) -> Result<Arc<Executable>> {
        match op {
            "add" | "sub" => self.fused_executable(op, dims),
            other => Err(anyhow!("unknown elementwise op {other}")),
        }
    }

    /// `mean((a−b)²)` over two `dims`-shaped tensors → rank-0 scalar.
    /// Foresight's Eq. 5/6 drift metric; pairs with [`Self::read_scalar`]
    /// so measurement costs a 4-byte download instead of the full feature.
    pub fn mse(&self, dims: &[usize]) -> Result<Arc<Executable>> {
        self.fused_executable("mse", dims)
    }

    /// Classifier-free-guidance combine `uncond + s·(cond − uncond)` with
    /// the scale as a rank-0 runtime argument (args: uncond, cond, scale).
    pub fn cfg_combine(&self, dims: &[usize]) -> Result<Arc<Executable>> {
        self.fused_executable("cfg_combine", dims)
    }

    /// `alpha·x` with scalar alpha as a runtime argument (args: x, alpha).
    pub fn scale(&self, dims: &[usize]) -> Result<Arc<Executable>> {
        self.fused_executable("scale", dims)
    }

    /// `alpha·x + y` with scalar alpha as a runtime argument (args: x, y,
    /// alpha) — one rflow Euler step over the resident latent
    /// (`x' = dt·v + x`; see [`crate::sampler::DeviceStepper`]).
    pub fn axpy(&self, dims: &[usize]) -> Result<Arc<Executable>> {
        self.fused_executable("axpy", dims)
    }

    /// One fused eta-0 DDIM step over the resident latent:
    /// `x' = sqrt_aprev·clamp((x − sqrt_1mat·eps)/sqrt_at, lo, hi)
    /// + sqrt_1maprev·eps`, with every scalar a rank-0 runtime argument
    /// (args: x, eps, sqrt_at, sqrt_1mat, sqrt_aprev, sqrt_1maprev, lo,
    /// hi). Pairs with `axpy` so neither sampler family ever round-trips
    /// the latent through the host (see [`crate::sampler::DeviceStepper`]).
    pub fn ddim_step(&self, dims: &[usize]) -> Result<Arc<Executable>> {
        self.fused_executable("ddim_step", dims)
    }

    /// Order-`k` linear-multistep feature extrapolation
    /// `Σᵢ cᵢ·hᵢ` over the `k` most recent cached outputs of one site, in
    /// **one** fused dispatch (args: `h0..h{k-1}` newest-first, then
    /// `c0..c{k-1}` rank-0 coefficients; result `dims`-shaped). The
    /// forecast reuse path (`policy::forecast`) uses this so a Predict
    /// step stays zero-download, like verbatim replay: the history
    /// tensors are already device-resident and the coefficients are
    /// uploaded once at admit (see [`lms_coefficients`]). Cached per
    /// `(k, dims)` like every fused op.
    pub fn lms_combine(&self, dims: &[usize], order: usize) -> Result<Arc<Executable>> {
        if order == 0 {
            return Err(anyhow!("lms_combine needs at least one history term"));
        }
        let key = (format!("lms{order}"), dims.to_vec());
        if let Some(e) = self.fused.lock().get(&key) {
            return Ok(e.clone());
        }
        let b = xla::XlaBuilder::new(&format!("fused_lms{order}"));
        let err = |stage: &str, e| anyhow!("fused lms{order} {stage}: {e:?}");
        let idims: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
        let param = |i: i64, pdims: &[i64], name: &str| {
            b.parameter(i, xla::ElementType::F32, pdims, name)
                .map_err(|e| anyhow!("fused lms{order} param {name}: {e:?}"))
        };
        let mut terms = Vec::with_capacity(order);
        for i in 0..order {
            let h = param(i as i64, &idims, &format!("h{i}"))?;
            let c = param((order + i) as i64, &[], &format!("c{i}"))?;
            terms.push(h.mul_(&c).map_err(|e| err("mul", e))?);
        }
        let mut iter = terms.into_iter();
        let mut root = match iter.next() {
            Some(t) => t,
            None => return Err(anyhow!("fused lms{order}: no terms were built")),
        };
        for t in iter {
            root = root.add_(&t).map_err(|e| err("add", e))?;
        }
        let comp = root.build().map_err(|e| err("build", e))?;
        let exe = self
            .client
            .0
            .compile(&comp)
            .map_err(|e| anyhow!("compile fused_lms{order}: {e:?}"))?;
        let exec = Arc::new(Executable {
            name: format!("fused_lms{order}{dims:?}"),
            exe: Shared(exe),
            arity: 2 * order,
            stats: ExecStats::default(),
        });
        self.fused.lock().insert(key, exec.clone());
        Ok(exec)
    }

    /// Stack `batch` identically-shaped `dims` tensors along a new leading
    /// batch axis (args: `x0..x{batch-1}`; result `[batch, dims...]`).
    /// Pure device-side data movement — the micro-batching engine uses it
    /// to assemble the `[B, F, P, C]` latent and epsilon stacks without
    /// any host round-trip. Cached per `(batch, dims)` like every fused op.
    pub fn stack(&self, dims: &[usize], batch: usize) -> Result<Arc<Executable>> {
        if batch == 0 {
            return Err(anyhow!("stack needs at least one input"));
        }
        let key = (format!("stack{batch}"), dims.to_vec());
        if let Some(e) = self.fused.lock().get(&key) {
            return Ok(e.clone());
        }
        let b = xla::XlaBuilder::new(&format!("fused_stack{batch}"));
        let idims: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
        let mut lane_dims: Vec<i64> = vec![1];
        lane_dims.extend_from_slice(&idims);
        let mut parts = Vec::with_capacity(batch);
        for i in 0..batch {
            let p = b
                .parameter(i as i64, xla::ElementType::F32, &idims, &format!("x{i}"))
                .map_err(|e| anyhow!("fused stack param x{i}: {e:?}"))?;
            parts.push(
                p.reshape(&lane_dims)
                    .map_err(|e| anyhow!("fused stack reshape: {e:?}"))?,
            );
        }
        let root = if batch == 1 {
            match parts.pop() {
                Some(p) => p,
                None => return Err(anyhow!("fused stack: no lane part was built")),
            }
        } else {
            match parts.split_first() {
                Some((first, rest)) => first
                    .concat_in_dim(rest, 0)
                    .map_err(|e| anyhow!("fused stack concat: {e:?}"))?,
                None => return Err(anyhow!("fused stack: no lane part was built")),
            }
        };
        let comp = root.build().map_err(|e| anyhow!("fused stack build: {e:?}"))?;
        let exe = self
            .client
            .0
            .compile(&comp)
            .map_err(|e| anyhow!("compile fused_stack{batch}: {e:?}"))?;
        let exec = Arc::new(Executable {
            name: format!("fused_stack{batch}{dims:?}"),
            exe: Shared(exe),
            arity: batch,
            stats: ExecStats::default(),
        });
        self.fused.lock().insert(key, exec.clone());
        Ok(exec)
    }

    /// Advance `batch` stacked rflow lanes in **one** fused dispatch, with
    /// per-lane scalars: for each lane `i`,
    /// `x'_i = dt_i·(u_i + s_i·(c_i − u_i)) + x_i` — the CFG combine and
    /// Euler update of the single-lane path, applied per lane so a cohort
    /// of sessions at *different* schedule cursors / CFG scales still
    /// shares one device pass. Args: `x`, `u`, `c` (each
    /// `[batch, dims...]`), then `(s_i, dt_i)` rank-0 pairs lane-major
    /// (arity `3 + 2·batch`). Built from slice/concat + elementwise ops,
    /// so each lane's arithmetic is the same f32 op sequence as
    /// `cfg_combine` + `axpy` on that lane alone.
    pub fn cohort_rflow_step(&self, dims: &[usize], batch: usize) -> Result<Arc<Executable>> {
        self.cohort_step("rflow", dims, batch)
    }

    /// Advance `batch` stacked eta-0 DDIM lanes in one fused dispatch with
    /// per-lane scalars. Args: `x`, `u`, `c` (each `[batch, dims...]`),
    /// then per lane `(s_i, sqrt_at_i, sqrt_1mat_i, sqrt_aprev_i,
    /// sqrt_1maprev_i)` lane-major, then the shared clamp bounds
    /// `(lo, hi)` (arity `3 + 5·batch + 2`). Per-lane op order mirrors
    /// [`Runtime::ddim_step`] exactly.
    pub fn cohort_ddim_step(&self, dims: &[usize], batch: usize) -> Result<Arc<Executable>> {
        self.cohort_step("ddim", dims, batch)
    }

    fn cohort_step(&self, family: &str, dims: &[usize], batch: usize) -> Result<Arc<Executable>> {
        if batch == 0 {
            return Err(anyhow!("cohort step needs at least one lane"));
        }
        let key = (format!("cohort_{family}{batch}"), dims.to_vec());
        if let Some(e) = self.fused.lock().get(&key) {
            return Ok(e.clone());
        }
        let b = xla::XlaBuilder::new(&format!("fused_cohort_{family}{batch}"));
        let err = |stage: &str, e| anyhow!("fused cohort_{family} {stage}: {e:?}");
        let mut bdims: Vec<i64> = vec![batch as i64];
        bdims.extend(dims.iter().map(|&d| d as i64));
        let param = |i: i64, pdims: &[i64], name: &str| {
            b.parameter(i, xla::ElementType::F32, pdims, name)
                .map_err(|e| anyhow!("fused cohort_{family} param {name}: {e:?}"))
        };
        let x = param(0, &bdims, "x")?;
        let u = param(1, &bdims, "u")?;
        let c = param(2, &bdims, "c")?;
        let per_lane = match family {
            "rflow" => 2usize,
            "ddim" => 5usize,
            other => return Err(anyhow!("unknown cohort step family {other}")),
        };
        // Per-lane rank-0 scalar parameters, lane-major.
        let mut scalars = Vec::with_capacity(batch * per_lane);
        for lane in 0..batch {
            for k in 0..per_lane {
                let idx = (3 + lane * per_lane + k) as i64;
                scalars.push(param(idx, &[], &format!("s{lane}_{k}"))?);
            }
        }
        // Shared trailing DDIM clamp bounds.
        let bounds = if family == "ddim" {
            let base = (3 + batch * per_lane) as i64;
            Some((param(base, &[], "clamp_lo")?, param(base + 1, &[], "clamp_hi")?))
        } else {
            None
        };
        let arity = 3 + batch * per_lane + if bounds.is_some() { 2 } else { 0 };

        let mut parts = Vec::with_capacity(batch);
        for lane in 0..batch {
            let (lo_i, hi_i) = (lane as i64, lane as i64 + 1);
            let xi = x.slice_in_dim(lo_i, hi_i, 1, 0).map_err(|e| err("slice x", e))?;
            let ui = u.slice_in_dim(lo_i, hi_i, 1, 0).map_err(|e| err("slice u", e))?;
            let ci = c.slice_in_dim(lo_i, hi_i, 1, 0).map_err(|e| err("slice c", e))?;
            let s = &scalars[lane * per_lane..(lane + 1) * per_lane];
            // CFG combine, same op order as `cfg_combine`.
            let diff = ci.sub_(&ui).map_err(|e| err("cfg sub", e))?;
            let scaled = diff.mul_(&s[0]).map_err(|e| err("cfg scale", e))?;
            let eps = ui.add_(&scaled).map_err(|e| err("cfg add", e))?;
            let next = match family {
                "rflow" => {
                    // Same op order as `axpy(eps, x, dt)`.
                    let ax = eps.mul_(&s[1]).map_err(|e| err("axpy mul", e))?;
                    ax.add_(&xi).map_err(|e| err("axpy add", e))?
                }
                _ => {
                    // Same op order as `ddim_step`.
                    let Some((lo, hi)) = bounds.as_ref() else {
                        return Err(anyhow!("fused cohort_{family}: missing clamp bounds"));
                    };
                    let noise = eps.mul_(&s[2]).map_err(|e| err("noise", e))?;
                    let num = xi.sub_(&noise).map_err(|e| err("x0 numerator", e))?;
                    let x0 = num.div_(&s[1]).map_err(|e| err("x0 divide", e))?;
                    let x0 = x0.max_(lo).map_err(|e| err("clamp lo", e))?;
                    let x0 = x0.min_(hi).map_err(|e| err("clamp hi", e))?;
                    let signal = x0.mul_(&s[3]).map_err(|e| err("signal", e))?;
                    let renoise = eps.mul_(&s[4]).map_err(|e| err("renoise", e))?;
                    signal.add_(&renoise).map_err(|e| err("add", e))?
                }
            };
            parts.push(next);
        }
        let root = if batch == 1 {
            match parts.pop() {
                Some(p) => p,
                None => return Err(anyhow!("fused cohort_{family}: no lane part was built")),
            }
        } else {
            match parts.split_first() {
                Some((first, rest)) => {
                    first.concat_in_dim(rest, 0).map_err(|e| err("concat", e))?
                }
                None => return Err(anyhow!("fused cohort_{family}: no lane part was built")),
            }
        };
        let comp = root.build().map_err(|e| err("build", e))?;
        let exe = self
            .client
            .0
            .compile(&comp)
            .map_err(|e| anyhow!("compile fused_cohort_{family}{batch}: {e:?}"))?;
        let exec = Arc::new(Executable {
            name: format!("fused_cohort_{family}{batch}{dims:?}"),
            exe: Shared(exe),
            arity,
            stats: ExecStats::default(),
        });
        self.fused.lock().insert(key, exec.clone());
        Ok(exec)
    }

    /// Regroup (compact / permute) the lanes of a `[batch, dims...]`
    /// stacked tensor in **one** dispatch: output lane `j` is input lane
    /// `keep[j]`, result `[keep.len(), dims...]`. The continuous scheduler
    /// uses this when a lane retires mid-cohort: the survivors' stacked
    /// state compacts without round-tripping each lane through
    /// [`Runtime::lane`] + [`Runtime::stack`] (one dispatch instead of
    /// `batch + 1`). Pure device-side data movement.
    pub fn regroup(&self, batched_dims: &[usize], keep: &[usize]) -> Result<Arc<Executable>> {
        if batched_dims.is_empty() || keep.is_empty() {
            return Err(anyhow!("regroup needs a batched tensor and at least one lane"));
        }
        let batch = batched_dims[0];
        if let Some(&bad) = keep.iter().find(|&&i| i >= batch) {
            return Err(anyhow!("regroup lane {bad} out of range for batch {batch}"));
        }
        let key = (format!("regroup{keep:?}"), batched_dims.to_vec());
        if let Some(e) = self.fused.lock().get(&key) {
            return Ok(e.clone());
        }
        let b = xla::XlaBuilder::new("fused_regroup");
        let idims: Vec<i64> = batched_dims.iter().map(|&d| d as i64).collect();
        let x = b
            .parameter(0, xla::ElementType::F32, &idims, "x")
            .map_err(|e| anyhow!("fused regroup param x: {e:?}"))?;
        let mut parts = Vec::with_capacity(keep.len());
        for &i in keep {
            parts.push(
                x.slice_in_dim(i as i64, i as i64 + 1, 1, 0)
                    .map_err(|e| anyhow!("fused regroup slice lane {i}: {e:?}"))?,
            );
        }
        let root = if parts.len() == 1 {
            match parts.pop() {
                Some(p) => p,
                None => return Err(anyhow!("fused regroup: no lane part was built")),
            }
        } else {
            match parts.split_first() {
                Some((first, rest)) => first
                    .concat_in_dim(rest, 0)
                    .map_err(|e| anyhow!("fused regroup concat: {e:?}"))?,
                None => return Err(anyhow!("fused regroup: no lane part was built")),
            }
        };
        let comp = root
            .build()
            .map_err(|e| anyhow!("fused regroup build: {e:?}"))?;
        let exe = self
            .client
            .0
            .compile(&comp)
            .map_err(|e| anyhow!("compile fused_regroup: {e:?}"))?;
        let exec = Arc::new(Executable {
            name: format!("fused_regroup{keep:?}{batched_dims:?}"),
            exe: Shared(exe),
            arity: 1,
            stats: ExecStats::default(),
        });
        self.fused.lock().insert(key, exec.clone());
        Ok(exec)
    }

    /// Slice lane `index` out of a `[batch, dims...]`-shaped tensor as a
    /// `dims...`-shaped tensor (args: `x`) — the inverse of
    /// [`Runtime::stack`], used per step to feed each request's resident
    /// lane to the fixed-shape patch-embedding executable.
    pub fn lane(&self, batched_dims: &[usize], index: usize) -> Result<Arc<Executable>> {
        if batched_dims.is_empty() || index >= batched_dims[0] {
            return Err(anyhow!(
                "lane {index} out of range for batched dims {batched_dims:?}"
            ));
        }
        let key = (format!("lane{index}"), batched_dims.to_vec());
        if let Some(e) = self.fused.lock().get(&key) {
            return Ok(e.clone());
        }
        let b = xla::XlaBuilder::new(&format!("fused_lane{index}"));
        let idims: Vec<i64> = batched_dims.iter().map(|&d| d as i64).collect();
        let inner: Vec<i64> = idims[1..].to_vec();
        let x = b
            .parameter(0, xla::ElementType::F32, &idims, "x")
            .map_err(|e| anyhow!("fused lane param x: {e:?}"))?;
        let sl = x
            .slice_in_dim(index as i64, index as i64 + 1, 1, 0)
            .map_err(|e| anyhow!("fused lane slice: {e:?}"))?;
        let root = sl
            .reshape(&inner)
            .map_err(|e| anyhow!("fused lane reshape: {e:?}"))?;
        let comp = root.build().map_err(|e| anyhow!("fused lane build: {e:?}"))?;
        let exe = self
            .client
            .0
            .compile(&comp)
            .map_err(|e| anyhow!("compile fused_lane{index}: {e:?}"))?;
        let exec = Arc::new(Executable {
            name: format!("fused_lane{index}{batched_dims:?}"),
            exe: Shared(exe),
            arity: 1,
            stats: ExecStats::default(),
        });
        self.fused.lock().insert(key, exec.clone());
        Ok(exec)
    }

    /// Number of compiled artifacts currently cached.
    pub fn cached_executables(&self) -> usize {
        self.cache.lock().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{prop_assert, prop_assert_close, proptest_cases};
    use crate::util::stats::mse_f32;
    use std::panic::AssertUnwindSafe;

    #[test]
    fn arity_parser_counts_params() {
        let h = "HloModule m, entry_computation_layout={(f32[8,48,96]{2,1,0}, f32[96]{0}, f32[16,96]{1,0})->f32[8,48,96]{2,1,0}}";
        assert_eq!(parse_entry_arity(h), Some(3));
        let h0 = "HloModule m, entry_computation_layout={()->f32[2]{0}}";
        assert_eq!(parse_entry_arity(h0), Some(0));
        let h1 = "HloModule m, entry_computation_layout={(f32[])->f32[]}";
        assert_eq!(parse_entry_arity(h1), Some(1));
    }

    #[test]
    fn arity_parser_rejects_missing_layout() {
        assert_eq!(parse_entry_arity("HloModule m\nENTRY e { ROOT c = f32[] constant(1) }"), None);
        assert_eq!(parse_entry_arity(""), None);
    }

    #[test]
    fn load_hlo_rejects_artifact_without_entry_layout() {
        let rt = Runtime::cpu().unwrap();
        let dir = std::env::temp_dir().join("foresight_rt_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("malformed.hlo.txt");
        std::fs::write(&path, "HloModule borked\n\nENTRY e { ROOT c = f32[] constant(1) }\n")
            .unwrap();
        let err = rt.load_hlo(&path).unwrap_err().to_string();
        assert!(
            err.contains("entry_computation_layout"),
            "expected a load-time arity diagnostic, got: {err}"
        );
    }

    #[test]
    fn transfer_counters_track_uploads_and_downloads() {
        let rt = Runtime::cpu().unwrap();
        let before = rt.transfer_stats().snapshot();
        let t = rt.upload(&[1.0, 2.0, 3.0, 4.0], &[4]).unwrap();
        let mut out = [0.0f32; 4];
        rt.download_into(&t, &mut out).unwrap();
        let d = rt.transfer_stats().snapshot().delta_since(&before);
        assert_eq!(d.h2d_bytes, 16);
        assert_eq!(d.h2d_calls, 1);
        assert_eq!(d.d2h_bytes, 16);
        assert_eq!(d.d2h_calls, 1);
        assert_eq!(out, [1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn device_mse_exact_on_grid_values() {
        // Multiples of 0.25 with a power-of-two element count sum exactly
        // in f32, so device and host must agree to the last bit.
        let rt = Runtime::cpu().unwrap();
        let dims = [4usize, 16];
        let n = 64;
        let a: Vec<f32> = (0..n).map(|i| (i % 7) as f32 * 0.25).collect();
        let b: Vec<f32> = (0..n).map(|i| (i % 5) as f32 * 0.25).collect();
        let da = rt.upload(&a, &dims).unwrap();
        let db = rt.upload(&b, &dims).unwrap();
        let exe = rt.mse(&dims).unwrap();
        let out = exe.run(&[&da, &db]).unwrap();
        assert_eq!(out.dims(), &[] as &[usize]);
        let dev = rt.read_scalar(&out).unwrap() as f64;
        let host = mse_f32(&a, &b);
        assert!((dev - host).abs() < 1e-12, "device {dev} vs host {host}");
    }

    #[test]
    fn prop_device_mse_matches_host_mse() {
        // Satellite property: the on-device `mse` executable matches the
        // host reference within 1e-6 across random shapes and values.
        let rt = Runtime::cpu().unwrap();
        let rt = AssertUnwindSafe(&rt);
        proptest_cases(80, |g| {
            let rank = g.usize_in(1..=3);
            let dims: Vec<usize> = (0..rank).map(|_| g.usize_in(1..=6)).collect();
            let n: usize = dims.iter().product();
            let a = g.vec_f32(n, -1.0, 1.0);
            let b = g.vec_f32(n, -1.0, 1.0);
            let da = rt.upload(&a, &dims).unwrap();
            let db = rt.upload(&b, &dims).unwrap();
            let exe = rt.mse(&dims).unwrap();
            let dev = rt.read_scalar(&exe.run(&[&da, &db]).unwrap()).unwrap() as f64;
            let host = mse_f32(&a, &b);
            prop_assert_close(dev, host, 1e-6, "device mse vs host mse_f32");
        });
    }

    #[test]
    fn prop_cfg_combine_matches_host_loop() {
        let rt = Runtime::cpu().unwrap();
        let rt = AssertUnwindSafe(&rt);
        proptest_cases(60, |g| {
            let n = g.usize_in(1..=64);
            let u = g.vec_f32(n, -2.0, 2.0);
            let c = g.vec_f32(n, -2.0, 2.0);
            let s = g.f32_in(0.0, 10.0);
            let du = rt.upload(&u, &[n]).unwrap();
            let dc = rt.upload(&c, &[n]).unwrap();
            let ds = rt.upload(&[s], &[]).unwrap();
            let exe = rt.cfg_combine(&[n]).unwrap();
            let out = exe.run(&[&du, &dc, &ds]).unwrap();
            let mut dev = vec![0.0f32; n];
            rt.download_into(&out, &mut dev).unwrap();
            for i in 0..n {
                let host = u[i] + s * (c[i] - u[i]);
                prop_assert_close(dev[i] as f64, host as f64, 1e-6, "cfg combine element");
            }
        });
    }

    #[test]
    fn scale_and_axpy_primitives() {
        let rt = Runtime::cpu().unwrap();
        let x = rt.upload(&[1.0, -2.0, 3.0], &[3]).unwrap();
        let y = rt.upload(&[10.0, 10.0, 10.0], &[3]).unwrap();
        let a = rt.upload(&[0.5], &[]).unwrap();

        let scaled = rt.scale(&[3]).unwrap().run(&[&x, &a]).unwrap();
        let mut out = [0.0f32; 3];
        rt.download_into(&scaled, &mut out).unwrap();
        assert_eq!(out, [0.5, -1.0, 1.5]);

        let axpy = rt.axpy(&[3]).unwrap().run(&[&x, &y, &a]).unwrap();
        rt.download_into(&axpy, &mut out).unwrap();
        assert_eq!(out, [10.5, 9.0, 11.5]);
    }

    #[test]
    fn ddim_step_fused_matches_host_formula() {
        let rt = Runtime::cpu().unwrap();
        let dims = [5usize];
        // x0 for the ±6-style clamp window is exercised by the large |x|
        // entries below.
        let x = [0.5f32, -7.5, 7.5, 1.0, -0.25];
        let eps = [0.1f32, -0.3, 0.2, 0.0, 0.7];
        let (sat, s1mat, saprev, s1maprev) = (0.9f32, 0.435f32, 0.95f32, 0.312f32);
        let (lo, hi) = (-6.0f32, 6.0f32);
        let dx = rt.upload(&x, &dims).unwrap();
        let de = rt.upload(&eps, &dims).unwrap();
        let scalars: Vec<_> = [sat, s1mat, saprev, s1maprev, lo, hi]
            .iter()
            .map(|&v| rt.upload(&[v], &[]).unwrap())
            .collect();
        let exe = rt.ddim_step(&dims).unwrap();
        assert_eq!(exe.arity(), 8);
        let out = exe
            .run(&[
                &dx, &de, &scalars[0], &scalars[1], &scalars[2], &scalars[3], &scalars[4],
                &scalars[5],
            ])
            .unwrap();
        let mut dev = [0.0f32; 5];
        rt.download_into(&out, &mut dev).unwrap();
        for i in 0..5 {
            let x0 = ((x[i] - s1mat * eps[i]) / sat).clamp(lo, hi);
            let host = saprev * x0 + s1maprev * eps[i];
            assert!(
                (dev[i] - host).abs() <= 1e-6 * (1.0 + host.abs()),
                "elem {i}: device {} vs host {host}",
                dev[i]
            );
        }
        // the clamp actually fired for the out-of-range elements
        let x0_unclamped = (x[1] - s1mat * eps[1]) / sat;
        assert!(x0_unclamped < lo, "test vector must exercise the clamp");
    }

    #[test]
    fn lms_coefficients_sum_to_one_and_bound_order() {
        for order in 1..=4 {
            let c = lms_coefficients(order).unwrap();
            assert_eq!(c.len(), order);
            let sum: f32 = c.iter().sum();
            assert!((sum - 1.0).abs() < 1e-6, "order {order} coefficients must sum to 1");
        }
        assert!(lms_coefficients(0).is_err());
        assert!(lms_coefficients(5).is_err());
    }

    #[test]
    fn lms_combine_matches_host_reference() {
        let rt = Runtime::cpu().unwrap();
        let dims = [2usize, 3];
        let n = 6;
        let hist: Vec<Vec<f32>> = (0..3)
            .map(|h| (0..n).map(|i| ((h * n + i) % 7) as f32 * 0.25 - 0.5).collect())
            .collect();
        let dh: Vec<_> = hist.iter().map(|v| rt.upload(v, &dims).unwrap()).collect();
        for order in 2..=3usize {
            let coeffs = lms_coefficients(order).unwrap();
            let dc: Vec<_> = coeffs.iter().map(|&c| rt.upload(&[c], &[]).unwrap()).collect();
            let exe = rt.lms_combine(&dims, order).unwrap();
            assert_eq!(exe.arity(), 2 * order);
            let mut args: Vec<&DeviceTensor> = dh[..order].iter().collect();
            args.extend(dc.iter());
            let out = exe.run(&args).unwrap();
            assert_eq!(out.dims(), &dims);
            let mut got = vec![0.0f32; n];
            rt.download_into(&out, &mut got).unwrap();
            for i in 0..n {
                let want: f32 = (0..order).map(|t| coeffs[t] * hist[t][i]).sum();
                assert!(
                    (got[i] - want).abs() <= 1e-6 * (1.0 + want.abs()),
                    "order {order} elem {i}: device {} vs host {want}",
                    got[i]
                );
            }
        }
        assert!(rt.lms_combine(&dims, 0).is_err());
    }

    #[test]
    fn lms_combine_order_one_is_identity() {
        let rt = Runtime::cpu().unwrap();
        let x = [0.25f32, -1.5, 3.0];
        let dx = rt.upload(&x, &[3]).unwrap();
        let c = rt.upload(&[1.0f32], &[]).unwrap();
        let out = rt.lms_combine(&[3], 1).unwrap().run(&[&dx, &c]).unwrap();
        let mut got = [0.0f32; 3];
        rt.download_into(&out, &mut got).unwrap();
        assert_eq!(got, x, "order-1 forecast must be bit-identical replay");
    }

    #[test]
    fn fused_executables_are_cached_per_shape() {
        let rt = Runtime::cpu().unwrap();
        let a = rt.mse(&[4, 4]).unwrap();
        let b = rt.mse(&[4, 4]).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "same (op, dims) must hit the cache");
        let c = rt.mse(&[8]).unwrap();
        assert!(!Arc::ptr_eq(&a, &c));
    }

    #[test]
    fn fused_arity_is_enforced() {
        let rt = Runtime::cpu().unwrap();
        let x = rt.upload(&[1.0, 2.0], &[2]).unwrap();
        let exe = rt.cfg_combine(&[2]).unwrap();
        assert_eq!(exe.arity(), 3);
        let err = exe.run(&[&x, &x]).unwrap_err().to_string();
        assert!(err.contains("expected 3 args"), "{err}");
    }

    #[test]
    fn stack_then_lane_roundtrips() {
        let rt = Runtime::cpu().unwrap();
        let dims = [2usize, 3];
        let a: Vec<f32> = (0..6).map(|i| i as f32).collect();
        let b: Vec<f32> = (0..6).map(|i| 10.0 + i as f32).collect();
        let c: Vec<f32> = (0..6).map(|i| -(i as f32)).collect();
        let da = rt.upload(&a, &dims).unwrap();
        let db = rt.upload(&b, &dims).unwrap();
        let dc = rt.upload(&c, &dims).unwrap();

        let stack = rt.stack(&dims, 3).unwrap();
        assert_eq!(stack.arity(), 3);
        let stacked = stack.run(&[&da, &db, &dc]).unwrap();
        assert_eq!(stacked.dims(), &[3, 2, 3]);

        // the stacked layout is lane-major: [a..., b..., c...]
        let mut all = vec![0.0f32; 18];
        rt.download_into(&stacked, &mut all).unwrap();
        assert_eq!(&all[0..6], &a[..]);
        assert_eq!(&all[6..12], &b[..]);
        assert_eq!(&all[12..18], &c[..]);

        // each lane slices back out exactly
        for (i, want) in [&a, &b, &c].into_iter().enumerate() {
            let lane = rt.lane(&[3, 2, 3], i).unwrap();
            let out = lane.run(&[&stacked]).unwrap();
            assert_eq!(out.dims(), &[2, 3]);
            let mut got = vec![0.0f32; 6];
            rt.download_into(&out, &mut got).unwrap();
            assert_eq!(&got, want, "lane {i}");
        }
    }

    #[test]
    fn stack_of_one_reshapes_and_lane_bounds_checked() {
        let rt = Runtime::cpu().unwrap();
        let x = rt.upload(&[1.0, 2.0], &[2]).unwrap();
        let s1 = rt.stack(&[2], 1).unwrap();
        let out = s1.run(&[&x]).unwrap();
        assert_eq!(out.dims(), &[1, 2]);
        assert!(rt.stack(&[2], 0).is_err());
        assert!(rt.lane(&[2, 4], 2).is_err(), "lane index must be < batch");
        assert!(rt.lane(&[], 0).is_err());
    }

    #[test]
    fn cohort_rflow_step_matches_per_lane_ops() {
        // One fused cohort dispatch with per-lane (scale, dt) must equal
        // chaining cfg_combine + axpy on each lane alone — the invariant
        // that lets sessions at different cursors share a device pass.
        let rt = Runtime::cpu().unwrap();
        let dims = [2usize, 3];
        let n = 6;
        let batch = 3;
        let lanes_x: Vec<Vec<f32>> = (0..batch)
            .map(|l| (0..n).map(|i| (l * n + i) as f32 * 0.25 - 1.0).collect())
            .collect();
        let lanes_u: Vec<Vec<f32>> = (0..batch)
            .map(|l| (0..n).map(|i| ((l + i) % 5) as f32 * 0.5 - 1.0).collect())
            .collect();
        let lanes_c: Vec<Vec<f32>> = (0..batch)
            .map(|l| (0..n).map(|i| ((l * 2 + i) % 7) as f32 * 0.3 - 0.9).collect())
            .collect();
        let scales = [7.5f32, 1.0, 3.25];
        let dts = [-0.1f32, -0.4, -0.02];

        let up = |v: &Vec<f32>| rt.upload(v, &dims).unwrap();
        let dx: Vec<_> = lanes_x.iter().map(up).collect();
        let du: Vec<_> = lanes_u.iter().map(up).collect();
        let dc: Vec<_> = lanes_c.iter().map(up).collect();
        let stack = rt.stack(&dims, batch).unwrap();
        let xs = stack.run(&dx.iter().collect::<Vec<_>>()).unwrap();
        let us = stack.run(&du.iter().collect::<Vec<_>>()).unwrap();
        let cs = stack.run(&dc.iter().collect::<Vec<_>>()).unwrap();

        let mut scalars = Vec::new();
        for l in 0..batch {
            scalars.push(rt.upload(&[scales[l]], &[]).unwrap());
            scalars.push(rt.upload(&[dts[l]], &[]).unwrap());
        }
        let exe = rt.cohort_rflow_step(&dims, batch).unwrap();
        assert_eq!(exe.arity(), 3 + 2 * batch);
        let mut args: Vec<&DeviceTensor> = vec![&xs, &us, &cs];
        args.extend(scalars.iter());
        let out = exe.run(&args).unwrap();
        assert_eq!(out.dims(), &[batch, 2, 3]);
        let mut got = vec![0.0f32; batch * n];
        rt.download_into(&out, &mut got).unwrap();

        // reference: per-lane cfg_combine + axpy
        let cfg = rt.cfg_combine(&dims).unwrap();
        let axpy = rt.axpy(&dims).unwrap();
        for l in 0..batch {
            let s = rt.upload(&[scales[l]], &[]).unwrap();
            let dt = rt.upload(&[dts[l]], &[]).unwrap();
            let eps = cfg.run(&[&du[l], &dc[l], &s]).unwrap();
            let next = axpy.run(&[&eps, &dx[l], &dt]).unwrap();
            let mut want = vec![0.0f32; n];
            rt.download_into(&next, &mut want).unwrap();
            assert_eq!(&got[l * n..(l + 1) * n], &want[..], "lane {l}");
        }
    }

    #[test]
    fn cohort_ddim_step_matches_per_lane_ops() {
        let rt = Runtime::cpu().unwrap();
        let dims = [4usize];
        let batch = 2;
        let lanes_x = [vec![0.5f32, -7.5, 7.5, 1.0], vec![-0.25f32, 2.0, -3.0, 0.0]];
        let lanes_u = [vec![0.1f32, -0.3, 0.2, 0.0], vec![0.7f32, 0.2, -0.1, 0.4]];
        let lanes_c = [vec![0.2f32, -0.1, 0.4, 0.9], vec![-0.5f32, 0.3, 0.2, -0.2]];
        // distinct per-lane schedules (different cursors)
        let per_lane = [
            [4.0f32, 0.9, 0.435, 0.95, 0.312],  // s, sqrt_at, sqrt_1mat, sqrt_aprev, sqrt_1maprev
            [7.5f32, 0.7, 0.714, 0.8, 0.6],
        ];
        let (lo_v, hi_v) = (-6.0f32, 6.0f32);

        let up = |v: &Vec<f32>| rt.upload(v, &dims).unwrap();
        let dx: Vec<_> = lanes_x.iter().map(up).collect();
        let du: Vec<_> = lanes_u.iter().map(up).collect();
        let dc: Vec<_> = lanes_c.iter().map(up).collect();
        let stack = rt.stack(&dims, batch).unwrap();
        let xs = stack.run(&dx.iter().collect::<Vec<_>>()).unwrap();
        let us = stack.run(&du.iter().collect::<Vec<_>>()).unwrap();
        let cs = stack.run(&dc.iter().collect::<Vec<_>>()).unwrap();
        let mut scalars = Vec::new();
        for l in 0..batch {
            for v in per_lane[l] {
                scalars.push(rt.upload(&[v], &[]).unwrap());
            }
        }
        let lo = rt.upload(&[lo_v], &[]).unwrap();
        let hi = rt.upload(&[hi_v], &[]).unwrap();
        let exe = rt.cohort_ddim_step(&dims, batch).unwrap();
        assert_eq!(exe.arity(), 3 + 5 * batch + 2);
        let mut args: Vec<&DeviceTensor> = vec![&xs, &us, &cs];
        args.extend(scalars.iter());
        args.push(&lo);
        args.push(&hi);
        let out = exe.run(&args).unwrap();
        let mut got = vec![0.0f32; batch * 4];
        rt.download_into(&out, &mut got).unwrap();

        let cfg = rt.cfg_combine(&dims).unwrap();
        let step = rt.ddim_step(&dims).unwrap();
        for l in 0..batch {
            let s = rt.upload(&[per_lane[l][0]], &[]).unwrap();
            let eps = cfg.run(&[&du[l], &dc[l], &s]).unwrap();
            let coeffs: Vec<_> = per_lane[l][1..]
                .iter()
                .map(|&v| rt.upload(&[v], &[]).unwrap())
                .collect();
            let next = step
                .run(&[&dx[l], &eps, &coeffs[0], &coeffs[1], &coeffs[2], &coeffs[3], &lo, &hi])
                .unwrap();
            let mut want = vec![0.0f32; 4];
            rt.download_into(&next, &mut want).unwrap();
            assert_eq!(&got[l * 4..(l + 1) * 4], &want[..], "lane {l}");
        }
    }

    #[test]
    fn regroup_compacts_and_permutes_lanes() {
        let rt = Runtime::cpu().unwrap();
        let dims = [2usize, 2];
        let lanes: Vec<Vec<f32>> = (0..4)
            .map(|l| (0..4).map(|i| (l * 10 + i) as f32).collect())
            .collect();
        let dl: Vec<_> = lanes.iter().map(|v| rt.upload(v, &dims).unwrap()).collect();
        let stacked = rt
            .stack(&dims, 4)
            .unwrap()
            .run(&dl.iter().collect::<Vec<_>>())
            .unwrap();
        let bdims = [4usize, 2, 2];

        // drop lane 1, keep order (retirement compaction)
        let rg = rt.regroup(&bdims, &[0, 2, 3]).unwrap();
        let out = rg.run(&[&stacked]).unwrap();
        assert_eq!(out.dims(), &[3, 2, 2]);
        let mut got = vec![0.0f32; 12];
        rt.download_into(&out, &mut got).unwrap();
        assert_eq!(&got[0..4], &lanes[0][..]);
        assert_eq!(&got[4..8], &lanes[2][..]);
        assert_eq!(&got[8..12], &lanes[3][..]);

        // single-lane keep and arbitrary permutation
        let one = rt.regroup(&bdims, &[2]).unwrap().run(&[&stacked]).unwrap();
        assert_eq!(one.dims(), &[1, 2, 2]);
        let mut g1 = vec![0.0f32; 4];
        rt.download_into(&one, &mut g1).unwrap();
        assert_eq!(&g1, &lanes[2]);
        let perm = rt.regroup(&bdims, &[3, 0]).unwrap().run(&[&stacked]).unwrap();
        let mut g2 = vec![0.0f32; 8];
        rt.download_into(&perm, &mut g2).unwrap();
        assert_eq!(&g2[0..4], &lanes[3][..]);
        assert_eq!(&g2[4..8], &lanes[0][..]);

        // bounds checking
        assert!(rt.regroup(&bdims, &[4]).is_err());
        assert!(rt.regroup(&bdims, &[]).is_err());
        assert!(rt.regroup(&[], &[0]).is_err());
    }

    #[test]
    fn prop_device_mse_sees_asymmetry() {
        // mse(a, b) == mse(b, a) and mse(a, a) == 0 on device.
        let rt = Runtime::cpu().unwrap();
        let rt = AssertUnwindSafe(&rt);
        proptest_cases(30, |g| {
            let n = g.usize_in(1..=32);
            let a = g.vec_f32(n, -1.0, 1.0);
            let b = g.vec_f32(n, -1.0, 1.0);
            let da = rt.upload(&a, &[n]).unwrap();
            let db = rt.upload(&b, &[n]).unwrap();
            let exe = rt.mse(&[n]).unwrap();
            let ab = rt.read_scalar(&exe.run(&[&da, &db]).unwrap()).unwrap();
            let ba = rt.read_scalar(&exe.run(&[&db, &da]).unwrap()).unwrap();
            let aa = rt.read_scalar(&exe.run(&[&da, &da]).unwrap()).unwrap();
            prop_assert((ab - ba).abs() < 1e-9, "mse must be symmetric");
            prop_assert(aa == 0.0, "mse(a, a) must be exactly zero");
        });
    }
}
