//! PJRT runtime: loads AOT HLO-text artifacts and executes them.
//!
//! This is the only module that touches the `xla` crate. The pattern follows
//! /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute_b` with
//! device-resident buffers. HLO **text** (not serialized proto) is the
//! interchange format — see python/compile/aot.py for why.
//!
//! Thread-safety: the PJRT CPU client and its loaded executables are
//! internally thread-safe, but the `xla` crate wraps raw pointers and so
//! doesn't declare `Send`/`Sync`. [`Runtime`] asserts those bounds via the
//! `Shared` wrapper below; the serving integration test exercises
//! concurrent execution from multiple workers.

pub mod tensor;

use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

pub use tensor::HostTensor;

/// Wrapper asserting thread-safety of PJRT objects (see module docs).
struct Shared<T>(T);
// SAFETY: PJRT CPU client/executable/buffer handles are internally
// synchronised; the xla crate merely lacks the declarations.
unsafe impl<T> Send for Shared<T> {}
unsafe impl<T> Sync for Shared<T> {}

/// A device-resident tensor (opaque PJRT buffer + shape metadata).
pub struct DeviceTensor {
    buf: Shared<xla::PjRtBuffer>,
    dims: Vec<usize>,
}

impl DeviceTensor {
    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }

    fn raw(&self) -> &xla::PjRtBuffer {
        &self.buf.0
    }
}

/// Cumulative execution telemetry for one executable (drives the Fig. 9
/// operator-breakdown reproduction).
#[derive(Debug, Default)]
pub struct ExecStats {
    pub calls: AtomicU64,
    pub nanos: AtomicU64,
}

impl ExecStats {
    pub fn record(&self, dt: std::time::Duration) {
        self.calls.fetch_add(1, Ordering::Relaxed);
        self.nanos.fetch_add(dt.as_nanos() as u64, Ordering::Relaxed);
    }

    /// (call count, total seconds).
    pub fn snapshot(&self) -> (u64, f64) {
        (
            self.calls.load(Ordering::Relaxed),
            self.nanos.load(Ordering::Relaxed) as f64 * 1e-9,
        )
    }

    pub fn reset(&self) {
        self.calls.store(0, Ordering::Relaxed);
        self.nanos.store(0, Ordering::Relaxed);
    }
}

/// One compiled HLO module ready to execute.
pub struct Executable {
    name: String,
    exe: Shared<xla::PjRtLoadedExecutable>,
    /// Expected argument count (parsed from the HLO entry layout) so arity
    /// bugs fail with a readable error instead of a PJRT abort.
    arity: usize,
    pub stats: ExecStats,
}

impl Executable {
    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn arity(&self) -> usize {
        self.arity
    }

    /// Execute with device-resident inputs; returns the root output buffer.
    ///
    /// All artifacts are lowered with non-tuple roots (aot.py), so the
    /// result is always exactly one buffer.
    pub fn run(&self, args: &[&DeviceTensor]) -> Result<DeviceTensor> {
        if args.len() != self.arity {
            return Err(anyhow!(
                "{}: expected {} args, got {}",
                self.name,
                self.arity,
                args.len()
            ));
        }
        let t0 = Instant::now();
        let raw: Vec<&xla::PjRtBuffer> = args.iter().map(|a| a.raw()).collect();
        let out = self
            .exe
            .0
            .execute_b(&raw)
            .map_err(|e| anyhow!("execute {}: {e:?}", self.name))?;
        self.stats.record(t0.elapsed());
        let buf = out
            .into_iter()
            .next()
            .and_then(|replica| replica.into_iter().next())
            .ok_or_else(|| anyhow!("{}: no output buffer", self.name))?;
        let shape = buf
            .on_device_shape()
            .map_err(|e| anyhow!("{}: output shape: {e:?}", self.name))?;
        let dims = array_dims(&shape)?;
        Ok(DeviceTensor { buf: Shared(buf), dims })
    }
}

fn array_dims(shape: &xla::Shape) -> Result<Vec<usize>> {
    let ashape = xla::ArrayShape::try_from(shape)
        .map_err(|e| anyhow!("non-array output shape: {e:?}"))?;
    Ok(ashape.dims().iter().map(|&d| d as usize).collect())
}

/// Count parameters in the HLO entry computation layout line, e.g.
/// `entry_computation_layout={(f32[8,48]{1,0}, f32[96]{0})->f32[8,48]{1,0}}`.
fn parse_entry_arity(hlo_text: &str) -> usize {
    if let Some(start) = hlo_text.find("entry_computation_layout={(") {
        let rest = &hlo_text[start + "entry_computation_layout={(".len()..];
        if let Some(end) = rest.find(")->") {
            let params = &rest[..end];
            if params.trim().is_empty() {
                return 0;
            }
            let mut depth = 0usize;
            let mut count = 1usize;
            for ch in params.chars() {
                match ch {
                    '[' | '{' | '(' => depth += 1,
                    ']' | '}' | ')' => depth = depth.saturating_sub(1),
                    ',' if depth == 0 => count += 1,
                    _ => {}
                }
            }
            return count;
        }
    }
    0
}

/// The PJRT runtime: client + executable cache + elementwise helpers.
pub struct Runtime {
    client: Shared<xla::PjRtClient>,
    /// Compiled executables keyed by absolute artifact path.
    cache: Mutex<BTreeMap<PathBuf, Arc<Executable>>>,
    /// Runtime-built elementwise binaries keyed by (op, dims).
    elementwise: Mutex<BTreeMap<(String, Vec<usize>), Arc<Executable>>>,
}

impl Runtime {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu: {e:?}"))?;
        Ok(Self {
            client: Shared(client),
            cache: Mutex::new(BTreeMap::new()),
            elementwise: Mutex::new(BTreeMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.0.platform_name()
    }

    /// Load + compile an HLO text artifact (cached by path).
    pub fn load_hlo(&self, path: &Path) -> Result<Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(path) {
            return Ok(e.clone());
        }
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read {}", path.display()))?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parse hlo {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .0
            .compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e:?}", path.display()))?;
        let name = path
            .file_name()
            .map(|s| {
                s.to_string_lossy()
                    .trim_end_matches(".hlo.txt")
                    .to_string()
            })
            .unwrap_or_default();
        let arity = parse_entry_arity(&text);
        let exec = Arc::new(Executable {
            name,
            exe: Shared(exe),
            arity,
            stats: ExecStats::default(),
        });
        self.cache
            .lock()
            .unwrap()
            .insert(path.to_path_buf(), exec.clone());
        Ok(exec)
    }

    /// Upload host data to the device.
    pub fn upload(&self, data: &[f32], dims: &[usize]) -> Result<DeviceTensor> {
        let buf = self
            .client
            .0
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("upload: {e:?}"))?;
        Ok(DeviceTensor { buf: Shared(buf), dims: dims.to_vec() })
    }

    pub fn upload_tensor(&self, t: &HostTensor) -> Result<DeviceTensor> {
        self.upload(&t.data, &t.dims)
    }

    /// Download a device tensor into a new host tensor.
    pub fn download(&self, t: &DeviceTensor) -> Result<HostTensor> {
        let mut out = HostTensor::zeros(t.dims.to_vec());
        self.download_into(t, &mut out.data)?;
        Ok(out)
    }

    /// Download into a pre-allocated host slice (hot path: no allocation).
    pub fn download_into(&self, t: &DeviceTensor, dst: &mut [f32]) -> Result<()> {
        if dst.len() != t.element_count() {
            return Err(anyhow!(
                "download size mismatch: {} vs {}",
                dst.len(),
                t.element_count()
            ));
        }
        // CPU PJRT does not implement CopyRawToHost; route through a
        // Literal (one extra host copy — measured as negligible vs. block
        // execution cost; see EXPERIMENTS.md §Perf).
        let lit = t
            .raw()
            .to_literal_sync()
            .map_err(|e| anyhow!("download (to_literal): {e:?}"))?;
        lit.copy_raw_to(dst)
            .map_err(|e| anyhow!("download (copy_raw): {e:?}"))
    }

    /// Runtime-built elementwise binary op over identically-shaped tensors
    /// (used for Δ-DiT / PAB residual-delta reuse so the add/sub stays on
    /// device instead of round-tripping through the host).
    pub fn elementwise_binary(&self, op: &str, dims: &[usize]) -> Result<Arc<Executable>> {
        let key = (op.to_string(), dims.to_vec());
        if let Some(e) = self.elementwise.lock().unwrap().get(&key) {
            return Ok(e.clone());
        }
        let b = xla::XlaBuilder::new(&format!("ew_{op}"));
        let idims: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
        let x = b
            .parameter(0, xla::ElementType::F32, &idims, "x")
            .map_err(|e| anyhow!("builder: {e:?}"))?;
        let y = b
            .parameter(1, xla::ElementType::F32, &idims, "y")
            .map_err(|e| anyhow!("builder: {e:?}"))?;
        let z = match op {
            "add" => x.add_(&y),
            "sub" => x.sub_(&y),
            _ => return Err(anyhow!("unknown elementwise op {op}")),
        }
        .map_err(|e| anyhow!("builder {op}: {e:?}"))?;
        let comp = z.build().map_err(|e| anyhow!("build: {e:?}"))?;
        let exe = self
            .client
            .0
            .compile(&comp)
            .map_err(|e| anyhow!("compile ew_{op}: {e:?}"))?;
        let exec = Arc::new(Executable {
            name: format!("ew_{op}{dims:?}"),
            exe: Shared(exe),
            arity: 2,
            stats: ExecStats::default(),
        });
        self.elementwise.lock().unwrap().insert(key, exec.clone());
        Ok(exec)
    }

    /// Number of compiled artifacts currently cached.
    pub fn cached_executables(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arity_parser_counts_params() {
        let h = "HloModule m, entry_computation_layout={(f32[8,48,96]{2,1,0}, f32[96]{0}, f32[16,96]{1,0})->f32[8,48,96]{2,1,0}}";
        assert_eq!(parse_entry_arity(h), 3);
        let h0 = "HloModule m, entry_computation_layout={()->f32[2]{0}}";
        assert_eq!(parse_entry_arity(h0), 0);
        let h1 = "HloModule m, entry_computation_layout={(f32[])->f32[]}";
        assert_eq!(parse_entry_arity(h1), 1);
    }
}
