//! Loaded model: compiled piece executables + device-resident weights.
//!
//! A [`LoadedModel`] binds one (preset, bucket) pair: it compiles the
//! exported HLO pieces once and uploads every weight array to the device
//! once, then exposes typed dispatch methods the engine calls per step.
//! Weight argument vectors are pre-assembled at load time in manifest
//! order, so a block dispatch on the hot path is a single `execute_b` with
//! borrowed device buffers — no maps, no copies, no Python.

use anyhow::{anyhow, Context, Result};
use std::path::Path;
use std::sync::Arc;

use crate::config::{BucketInfo, Manifest, ModelInfo};
use crate::runtime::{DeviceTensor, Executable, HostTensor, Runtime};
use crate::util::npy;

/// Spatial or temporal DiT block (the paper's two blocks per layer pair).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum BlockKind {
    Spatial,
    Temporal,
}

impl BlockKind {
    pub const ALL: [BlockKind; 2] = [BlockKind::Spatial, BlockKind::Temporal];

    pub fn index(self) -> usize {
        match self {
            BlockKind::Spatial => 0,
            BlockKind::Temporal => 1,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            BlockKind::Spatial => "spatial",
            BlockKind::Temporal => "temporal",
        }
    }
}

/// Sublayer units inside a DiT block (used by fine-grained baselines).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum SubUnit {
    Attn,
    Cross,
    Mlp,
}

impl SubUnit {
    pub const ALL: [SubUnit; 3] = [SubUnit::Attn, SubUnit::Cross, SubUnit::Mlp];

    pub fn name(self) -> &'static str {
        match self {
            SubUnit::Attn => "attn",
            SubUnit::Cross => "cross",
            SubUnit::Mlp => "mlp",
        }
    }
}

/// Compiled executables for one (preset, bucket).
struct Pieces {
    t_embed: Arc<Executable>,
    text_proj: Arc<Executable>,
    text_k: Arc<Executable>,
    text_v: Arc<Executable>,
    embed: Arc<Executable>,
    block: [Arc<Executable>; 2], // [spatial, temporal]
    sb_attn: [Arc<Executable>; 2],
    sb_cross: Arc<Executable>,
    sb_mlp: Arc<Executable>,
    final_: Arc<Executable>,
}

/// Per-(layer, kind) pre-assembled weight argument vectors.
struct BlockArgs {
    full: Vec<Arc<DeviceTensor>>,   // 14, spatial_block order
    attn: Vec<Arc<DeviceTensor>>,   // 6, sb_attn order
    cross: Vec<Arc<DeviceTensor>>,  // 4, sb_cross order
    mlp: Vec<Arc<DeviceTensor>>,    // 6, sb_mlp order
    text_k: Vec<Arc<DeviceTensor>>, // 2
    text_v: Vec<Arc<DeviceTensor>>, // 2
}

/// One ready-to-serve model variant.
pub struct LoadedModel {
    pub info: ModelInfo,
    pub bucket: BucketInfo,
    rt: Arc<Runtime>,
    pieces: Pieces,
    t_embed_w: Vec<Arc<DeviceTensor>>,
    text_proj_w: Vec<Arc<DeviceTensor>>,
    embed_w: Vec<Arc<DeviceTensor>>,
    final_w: Vec<Arc<DeviceTensor>>,
    blocks: Vec<[BlockArgs; 2]>, // [layer][kind]
    add_exec: Arc<Executable>,
    sub_exec: Arc<Executable>,
    mse_exec: Arc<Executable>,
}

fn load_weight_args(
    rt: &Runtime,
    wdir: &Path,
    piece_key: &str,
    names: &[String],
) -> Result<Vec<Arc<DeviceTensor>>> {
    names
        .iter()
        .map(|n| {
            let path = wdir.join(format!("{piece_key}.{n}.npy"));
            let arr = npy::load(&path)?;
            let dims = if arr.shape.is_empty() { vec![] } else { arr.shape.clone() };
            Ok(Arc::new(rt.upload(&arr.data, &dims)?))
        })
        .collect()
}

impl LoadedModel {
    /// Compile all pieces and upload all weights for (model, bucket).
    pub fn load(
        rt: Arc<Runtime>,
        manifest: &Manifest,
        model_name: &str,
        bucket_name: &str,
    ) -> Result<Self> {
        let info = manifest.model(model_name)?.clone();
        let bucket = info.bucket(bucket_name)?.clone();
        let root = &manifest.root;
        let mdir = root.join(&info.name);
        let bdir = root.join(&bucket.dir);
        let wdir = root.join(&info.weights_dir);

        let pieces = Pieces {
            t_embed: rt.load_hlo(&mdir.join("t_embed.hlo.txt"))?,
            text_proj: rt.load_hlo(&mdir.join("text_proj.hlo.txt"))?,
            text_k: rt.load_hlo(&mdir.join("text_k.hlo.txt"))?,
            text_v: rt.load_hlo(&mdir.join("text_v.hlo.txt"))?,
            embed: rt.load_hlo(&bdir.join("embed.hlo.txt"))?,
            block: [
                rt.load_hlo(&bdir.join("spatial_block.hlo.txt"))?,
                rt.load_hlo(&bdir.join("temporal_block.hlo.txt"))?,
            ],
            sb_attn: [
                rt.load_hlo(&bdir.join("sb_attn_spatial.hlo.txt"))?,
                rt.load_hlo(&bdir.join("sb_attn_temporal.hlo.txt"))?,
            ],
            sb_cross: rt.load_hlo(&bdir.join("sb_cross.hlo.txt"))?,
            sb_mlp: rt.load_hlo(&bdir.join("sb_mlp.hlo.txt"))?,
            final_: rt.load_hlo(&bdir.join("final.hlo.txt"))?,
        };

        let pp = |piece: &str| -> Result<&Vec<String>> {
            info.piece_params
                .get(piece)
                .ok_or_else(|| anyhow!("manifest missing piece_params.{piece}"))
        };

        let t_embed_w = load_weight_args(&rt, &wdir, "t_embed", pp("t_embed")?)?;
        let text_proj_w = load_weight_args(&rt, &wdir, "text_proj", pp("text_proj")?)?;
        let embed_w = load_weight_args(&rt, &wdir, "embed", pp("embed")?)?;
        let final_w = load_weight_args(&rt, &wdir, "final", pp("final")?)?;

        let mut blocks = Vec::with_capacity(info.layers);
        for layer in 0..info.layers {
            let mut pair = Vec::with_capacity(2);
            for kind in BlockKind::ALL {
                let key = format!("layer{layer:02}.{}", kind.name());
                pair.push(BlockArgs {
                    full: load_weight_args(&rt, &wdir, &key, pp("spatial_block")?)
                        .with_context(|| format!("weights for {key}"))?,
                    attn: load_weight_args(&rt, &wdir, &key, pp("sb_attn")?)?,
                    cross: load_weight_args(&rt, &wdir, &key, pp("sb_cross")?)?,
                    mlp: load_weight_args(&rt, &wdir, &key, pp("sb_mlp")?)?,
                    text_k: load_weight_args(&rt, &wdir, &key, pp("text_k")?)?,
                    text_v: load_weight_args(&rt, &wdir, &key, pp("text_v")?)?,
                });
            }
            let pair: [BlockArgs; 2] = pair
                .try_into()
                .map_err(|_| anyhow!("block pair assembly"))?;
            blocks.push(pair);
        }

        let dims = [bucket.frames, bucket.tokens, info.d_model];
        let add_exec = rt.elementwise_binary("add", &dims)?;
        let sub_exec = rt.elementwise_binary("sub", &dims)?;
        let mse_exec = rt.mse(&dims)?;

        Ok(Self {
            info,
            bucket,
            rt,
            pieces,
            t_embed_w,
            text_proj_w,
            embed_w,
            final_w,
            blocks,
            add_exec,
            sub_exec,
            mse_exec,
        })
    }

    pub fn runtime(&self) -> &Arc<Runtime> {
        &self.rt
    }

    /// Activation dims of one block state [F, P, D].
    pub fn state_dims(&self) -> [usize; 3] {
        [self.bucket.frames, self.bucket.tokens, self.info.d_model]
    }

    /// Latent dims [F, P, C].
    pub fn latent_dims(&self) -> [usize; 3] {
        [self.bucket.frames, self.bucket.tokens, self.info.latent_channels]
    }

    fn run_with_weights(
        &self,
        exe: &Executable,
        inputs: &[&DeviceTensor],
        weights: &[Arc<DeviceTensor>],
    ) -> Result<DeviceTensor> {
        let mut args: Vec<&DeviceTensor> = Vec::with_capacity(inputs.len() + weights.len());
        args.extend_from_slice(inputs);
        args.extend(weights.iter().map(|w| w.as_ref()));
        exe.run(&args)
    }

    /// Timestep scalar → conditioning vector c [D].
    pub fn t_embed(&self, t: f32) -> Result<DeviceTensor> {
        let ts = self.rt.upload(&[t], &[])?;
        self.run_with_weights(&self.pieces.t_embed, &[&ts], &self.t_embed_w)
    }

    /// Timestep embeddings for a whole schedule in one pass. The
    /// resident-latent engine calls this at request start — every
    /// `t_value(i)` is known up front, so the per-step scalar uploads
    /// (4 bytes each) all happen before the step loop begins.
    pub fn t_embeds(&self, ts: &[f32]) -> Result<Vec<Arc<DeviceTensor>>> {
        ts.iter().map(|&t| Ok(Arc::new(self.t_embed(t)?))).collect()
    }

    /// Raw prompt embedding [S, d_text] → text tokens [S, D].
    pub fn text_proj(&self, raw: &HostTensor) -> Result<DeviceTensor> {
        let raw = self.rt.upload_tensor(raw)?;
        self.run_with_weights(&self.pieces.text_proj, &[&raw], &self.text_proj_w)
    }

    /// Per-(layer, kind) cross-attention K (step-invariant, hoisted).
    pub fn text_k(&self, layer: usize, kind: BlockKind, text: &DeviceTensor) -> Result<DeviceTensor> {
        let ba = &self.blocks[layer][kind.index()];
        self.run_with_weights(&self.pieces.text_k, &[text], &ba.text_k)
    }

    /// Per-(layer, kind) cross-attention V.
    pub fn text_v(&self, layer: usize, kind: BlockKind, text: &DeviceTensor) -> Result<DeviceTensor> {
        let ba = &self.blocks[layer][kind.index()];
        self.run_with_weights(&self.pieces.text_v, &[text], &ba.text_v)
    }

    /// Latent [F, P, C] → token states [F, P, D].
    pub fn embed(&self, x: &DeviceTensor) -> Result<DeviceTensor> {
        self.run_with_weights(&self.pieces.embed, &[x], &self.embed_w)
    }

    /// Full DiT block dispatch (the Foresight coarse reuse unit).
    pub fn block_full(
        &self,
        layer: usize,
        kind: BlockKind,
        h: &DeviceTensor,
        c: &DeviceTensor,
        tk: &DeviceTensor,
        tv: &DeviceTensor,
    ) -> Result<DeviceTensor> {
        let ba = &self.blocks[layer][kind.index()];
        self.run_with_weights(&self.pieces.block[kind.index()], &[h, c, tk, tv], &ba.full)
    }

    /// Attention sublayer only (PAB / T-GATE granularity).
    pub fn block_attn(
        &self,
        layer: usize,
        kind: BlockKind,
        h: &DeviceTensor,
        c: &DeviceTensor,
    ) -> Result<DeviceTensor> {
        let ba = &self.blocks[layer][kind.index()];
        self.run_with_weights(&self.pieces.sb_attn[kind.index()], &[h, c], &ba.attn)
    }

    /// Cross-attention sublayer only.
    pub fn block_cross(
        &self,
        layer: usize,
        kind: BlockKind,
        h: &DeviceTensor,
        tk: &DeviceTensor,
        tv: &DeviceTensor,
    ) -> Result<DeviceTensor> {
        let ba = &self.blocks[layer][kind.index()];
        self.run_with_weights(&self.pieces.sb_cross, &[h, tk, tv], &ba.cross)
    }

    /// MLP sublayer only.
    pub fn block_mlp(
        &self,
        layer: usize,
        kind: BlockKind,
        h: &DeviceTensor,
        c: &DeviceTensor,
    ) -> Result<DeviceTensor> {
        let ba = &self.blocks[layer][kind.index()];
        self.run_with_weights(&self.pieces.sb_mlp, &[h, c], &ba.mlp)
    }

    /// Final projection → predicted noise / velocity [F, P, C].
    pub fn final_proj(&self, h: &DeviceTensor, c: &DeviceTensor) -> Result<DeviceTensor> {
        self.run_with_weights(&self.pieces.final_, &[h, c], &self.final_w)
    }

    /// Device-side elementwise add over block states (residual reuse).
    pub fn add(&self, a: &DeviceTensor, b: &DeviceTensor) -> Result<DeviceTensor> {
        self.add_exec.run(&[a, b])
    }

    /// Device-side elementwise sub over block states (delta extraction).
    pub fn sub(&self, a: &DeviceTensor, b: &DeviceTensor) -> Result<DeviceTensor> {
        self.sub_exec.run(&[a, b])
    }

    /// Device-side `mean((a−b)²)` over two block states, downloaded as one
    /// f32 (Foresight's Eq. 5/6 drift metric: 4 bytes on the wire instead
    /// of the full `F·P·D·4` activation).
    pub fn state_mse(&self, a: &DeviceTensor, b: &DeviceTensor) -> Result<f64> {
        let out = self.mse_exec.run(&[a, b])?;
        Ok(self.rt.read_scalar(&out)? as f64)
    }

    /// Per-executable (calls, seconds) snapshot for the Fig. 9 breakdown.
    pub fn op_stats(&self) -> Vec<(String, u64, f64)> {
        let mut out = Vec::new();
        let mut push = |e: &Executable| {
            let (calls, secs) = e.stats.snapshot();
            out.push((e.name().to_string(), calls, secs));
        };
        push(&self.pieces.t_embed);
        push(&self.pieces.text_proj);
        push(&self.pieces.text_k);
        push(&self.pieces.text_v);
        push(&self.pieces.embed);
        push(&self.pieces.block[0]);
        push(&self.pieces.block[1]);
        push(&self.pieces.sb_attn[0]);
        push(&self.pieces.sb_attn[1]);
        push(&self.pieces.sb_cross);
        push(&self.pieces.sb_mlp);
        push(&self.pieces.final_);
        push(&self.add_exec);
        push(&self.sub_exec);
        push(&self.mse_exec);
        out
    }

    /// Reset op telemetry (benches call this between phases).
    pub fn reset_op_stats(&self) {
        self.pieces.t_embed.stats.reset();
        self.pieces.text_proj.stats.reset();
        self.pieces.text_k.stats.reset();
        self.pieces.text_v.stats.reset();
        self.pieces.embed.stats.reset();
        for e in &self.pieces.block {
            e.stats.reset();
        }
        for e in &self.pieces.sb_attn {
            e.stats.reset();
        }
        self.pieces.sb_cross.stats.reset();
        self.pieces.sb_mlp.stats.reset();
        self.pieces.final_.stats.reset();
        self.add_exec.stats.reset();
        self.sub_exec.stats.reset();
        self.mse_exec.stats.reset();
    }

    /// Analytical FLOP count of one full DiT block dispatch (used by the
    /// Fig. 10 roofline reproduction and the speedup model in DESIGN.md).
    pub fn block_flops(&self, kind: BlockKind) -> f64 {
        let f = self.bucket.frames as f64;
        let p = self.bucket.tokens as f64;
        let d = self.info.d_model as f64;
        let s = self.info.text_len as f64;
        let hdim = (self.info.mlp_ratio * self.info.d_model) as f64;
        let tokens = f * p;
        // self/temporal attention: qkv proj + scores + weighted sum + out proj
        let (b_attn, s_attn) = match kind {
            BlockKind::Spatial => (f, p),
            BlockKind::Temporal => (p, f),
        };
        let attn = 2.0 * tokens * d * 3.0 * d          // qkv
            + 2.0 * b_attn * s_attn * s_attn * d * 2.0 // scores + pv
            + 2.0 * tokens * d * d;                    // out proj
        // cross attention
        let cross = 2.0 * tokens * d * d               // q proj
            + 2.0 * tokens * s * d * 2.0               // scores + pv
            + 2.0 * tokens * d * d;                    // out proj
        // mlp
        let mlp = 2.0 * tokens * d * hdim * 2.0;
        // adaLN + LN glue (linear in elements)
        let glue = 10.0 * tokens * d;
        attn + cross + mlp + glue
    }

    /// Bytes moved per full block dispatch (HBM traffic model for Fig. 10).
    pub fn block_bytes(&self, _kind: BlockKind) -> f64 {
        let f = self.bucket.frames as f64;
        let p = self.bucket.tokens as f64;
        let d = self.info.d_model as f64;
        let hdim = (self.info.mlp_ratio * self.info.d_model) as f64;
        let state = f * p * d * 4.0;
        let weights = (d * 6.0 * d + d * 3.0 * d + 2.0 * d * d + 2.0 * d * d
            + d * hdim + hdim * d) * 4.0;
        // activations in+out ~3 sublayer passes + weights once
        3.0 * 2.0 * state + weights
    }
}
