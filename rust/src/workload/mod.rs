//! Workloads: prompt sets and the text-encoder substitute.
//!
//! The paper evaluates on the VBench prompt suite (11 categories × 50
//! prompts), UCF-101 class prompts (101) and EvalCrafter (150). No T5
//! encoder exists in this environment, so prompts are embedded with a
//! deterministic hash-projection (DESIGN.md §1): each whitespace token maps
//! to a seeded Gaussian vector, mixed with its position; a motion-
//! complexity statistic extracted from the prompt's verb vocabulary scales
//! the embedding so "dynamic" prompts perturb cross-attention harder —
//! reproducing the paper's prompt-dependent reuse variance (Fig. 3a /
//! Fig. 15).

use crate::runtime::HostTensor;
use crate::util::prng::Rng;

/// Words that signal motion / rapid scene change. Counted (with stems) to
/// produce the complexity statistic in [0, 1].
const MOTION_WORDS: &[&str] = &[
    "run", "running", "dart", "darts", "crash", "crashing", "wave", "waves",
    "storm", "race", "racing", "fast", "rapid", "rapidly", "spin", "spinning",
    "jump", "jumping", "fly", "flying", "explode", "explosion", "dance",
    "dancing", "chase", "chasing", "gallop", "sprint", "swirl", "tumble",
    "bounce", "bounces", "frolic", "frolics", "surf", "surfing", "drone",
    "pan", "pans", "zoom", "circles", "splash", "flicker",
];

/// Motion/scene-dynamics statistic of a prompt, in [0, 1].
pub fn motion_complexity(prompt: &str) -> f64 {
    let words: Vec<String> = prompt
        .to_lowercase()
        .split(|c: char| !c.is_alphanumeric())
        .filter(|w| !w.is_empty())
        .map(str::to_string)
        .collect();
    if words.is_empty() {
        return 0.0;
    }
    let hits = words
        .iter()
        .filter(|w| MOTION_WORDS.contains(&w.as_str()))
        .count();
    (4.0 * hits as f64 / words.len() as f64).min(1.0)
}

/// FNV-1a hash of a token (stable across runs/platforms).
fn token_hash(tok: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in tok.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Deterministic prompt embedding `[text_len, d_text]` — the text-encoder
/// substitute. Same prompt → same embedding, always.
pub fn embed_prompt(prompt: &str, d_text: usize, text_len: usize) -> HostTensor {
    let tokens: Vec<&str> = prompt
        .split_whitespace()
        .filter(|t| !t.is_empty())
        .collect();
    let complexity = motion_complexity(prompt) as f32;
    // Dynamic prompts get larger embeddings → stronger cross-attention
    // perturbation of the denoising trajectory.
    let scale = 0.6 + 0.9 * complexity;

    let mut data = vec![0.0f32; text_len * d_text];
    for pos in 0..text_len {
        let row = &mut data[pos * d_text..(pos + 1) * d_text];
        if tokens.is_empty() {
            continue;
        }
        // Roll long prompts into the fixed token budget: position p mixes
        // tokens p, p+text_len, p+2*text_len, ...
        let mut k = pos;
        let mut n_mixed = 0.0f32;
        while k < tokens.len() {
            let mut rng = Rng::new(token_hash(tokens[k]) ^ (pos as u64).wrapping_mul(0x9E37));
            for v in row.iter_mut() {
                *v += rng.next_normal();
            }
            n_mixed += 1.0;
            k += text_len;
        }
        if n_mixed > 0.0 {
            let norm = scale / n_mixed.sqrt();
            for v in row.iter_mut() {
                *v *= norm;
            }
        }
    }
    HostTensor::new(vec![text_len, d_text], data)
}

/// One prompt in a benchmark set.
#[derive(Debug, Clone)]
pub struct PromptSpec {
    pub id: usize,
    pub category: String,
    pub text: String,
}

/// The 11 VBench prompt categories (paper §4.2 / Appendix A.5).
pub const VBENCH_CATEGORIES: [&str; 11] = [
    "animal", "architecture", "food", "human", "lifestyle", "plant",
    "scenery", "vehicles", "color", "spatial_relationship", "temporal_style",
];

/// Subject/scene banks the template generator draws from.
const SUBJECTS: &[&str] = &[
    "a playful black labrador", "an elderly painter", "a red vintage car",
    "a towering lighthouse", "a bowl of steaming ramen", "a cherry blossom tree",
    "a bustling night market", "a lone astronaut", "a school of silver fish",
    "a steam locomotive", "a glassblower", "a mountain goat",
];

const SCENES: &[&str] = &[
    "in a sunlit autumn garden", "on a rain-slicked city street",
    "beside a frozen alpine lake", "inside a neon-lit arcade",
    "under a violet dusk sky", "along the amalfi coast",
    "in a quiet library hall", "across rolling wheat fields",
    "near crashing ocean waves", "atop a foggy mountain ridge",
];

const STATIC_STYLES: &[&str] = &[
    "captured in golden-hour light, serene and still",
    "soft focus, gentle ambient glow, calm composition",
    "painterly detail with muted tones, tranquil mood",
];

const DYNAMIC_STYLES: &[&str] = &[
    "racing and spinning rapidly while waves crash around",
    "fast camera pans, the scene explodes with motion and dancing lights",
    "jumping and darting quickly as a storm swirls overhead",
];

fn template_prompt(category: &str, i: usize) -> String {
    let subject = SUBJECTS[(i * 7 + category.len()) % SUBJECTS.len()];
    let scene = SCENES[(i * 3 + category.len() * 5) % SCENES.len()];
    // Alternate static/dynamic so every category exercises both ends of the
    // reuse-potential spectrum (Fig. 3a).
    let style = if i % 2 == 0 {
        STATIC_STYLES[i / 2 % STATIC_STYLES.len()]
    } else {
        DYNAMIC_STYLES[i / 2 % DYNAMIC_STYLES.len()]
    };
    format!("{category} study: {subject} {scene}, {style}")
}

/// VBench-proxy prompt set: `per_category` prompts in each of the 11
/// categories (paper scale: 50 per category → 550 prompts).
pub fn vbench_prompts(per_category: usize) -> Vec<PromptSpec> {
    let mut out = Vec::with_capacity(11 * per_category);
    let mut id = 0;
    for cat in VBENCH_CATEGORIES {
        for i in 0..per_category {
            out.push(PromptSpec { id, category: cat.to_string(), text: template_prompt(cat, i) });
            id += 1;
        }
    }
    out
}

/// UCF-101-style action prompts (n ≤ 101).
pub fn ucf101_prompts(n: usize) -> Vec<PromptSpec> {
    const ACTIONS: &[&str] = &[
        "apply eye makeup", "archery", "baby crawling", "balance beam",
        "band marching", "baseball pitch", "basketball dunk", "bench press",
        "biking", "billiards", "blow dry hair", "blowing candles",
        "body weight squats", "bowling", "boxing punching bag", "breast stroke",
        "brushing teeth", "clean and jerk", "cliff diving", "cricket bowling",
        "cutting in kitchen", "diving", "drumming", "fencing",
        "field hockey penalty", "floor gymnastics", "frisbee catch",
        "front crawl", "golf swing", "haircut", "hammer throw", "handstand",
        "high jump", "horse race", "hula hoop", "ice dancing", "javelin throw",
        "juggling balls", "jump rope", "kayaking", "knitting", "long jump",
        "lunges", "military parade", "mixing batter", "mopping floor",
        "nunchucks", "parallel bars", "pizza tossing", "playing cello",
        "playing flute", "playing guitar", "playing piano", "playing sitar",
        "playing tabla", "playing violin", "pole vault", "pommel horse",
        "pull ups", "punch", "push ups", "rafting", "rock climbing indoor",
        "rope climbing", "rowing", "salsa spin", "shaving beard", "shotput",
        "skate boarding", "skiing", "skijet", "sky diving", "soccer juggling",
        "soccer penalty", "still rings", "sumo wrestling", "surfing", "swing",
        "table tennis shot", "tai chi", "tennis swing", "throw discus",
        "trampoline jumping", "typing", "uneven bars", "volleyball spiking",
        "walking with dog", "wall pushups", "writing on board", "yo yo",
        "archery contest", "street basketball", "marathon running",
        "speed skating", "water skiing", "wind surfing", "mountain biking",
        "trail running", "figure skating", "gym workout", "karate kata",
    ];
    (0..n.min(ACTIONS.len()))
        .map(|i| PromptSpec {
            id: i,
            category: "ucf101".to_string(),
            text: format!("a person performing {}, dynamic sports footage", ACTIONS[i]),
        })
        .collect()
}

/// EvalCrafter-style mixed prompt set (n ≤ 150).
pub fn evalcrafter_prompts(n: usize) -> Vec<PromptSpec> {
    let mut out = Vec::with_capacity(n);
    for i in 0..n.min(150) {
        let cat = VBENCH_CATEGORIES[i % VBENCH_CATEGORIES.len()];
        out.push(PromptSpec {
            id: i,
            category: format!("evalcrafter/{cat}"),
            text: template_prompt(cat, i * 5 + 1),
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn embedding_is_deterministic_and_prompt_sensitive() {
        let a1 = embed_prompt("a calm lake at dawn", 64, 16);
        let a2 = embed_prompt("a calm lake at dawn", 64, 16);
        let b = embed_prompt("a storm crashing over cliffs", 64, 16);
        assert_eq!(a1.data, a2.data);
        assert_ne!(a1.data, b.data);
        assert_eq!(a1.dims, vec![16, 64]);
    }

    #[test]
    fn motion_words_are_unique() {
        // A doubled entry ("crashing" shipped twice in the seed) is
        // harmless for the contains() lookup but signals a drifting word
        // bank; keep the list a set.
        let set: std::collections::BTreeSet<_> = MOTION_WORDS.iter().collect();
        assert_eq!(set.len(), MOTION_WORDS.len(), "MOTION_WORDS contains duplicates");
    }

    #[test]
    fn motion_complexity_orders_prompts() {
        let calm = motion_complexity("a serene painting of a quiet library");
        let wild = motion_complexity("a dog running jumping and darting fast through waves crashing");
        assert!(calm < wild, "{calm} vs {wild}");
        assert!((0.0..=1.0).contains(&calm));
        assert!((0.0..=1.0).contains(&wild));
        assert_eq!(motion_complexity(""), 0.0);
    }

    #[test]
    fn dynamic_prompts_have_larger_embeddings() {
        let calm = embed_prompt("a serene quiet still painting", 64, 16);
        let wild = embed_prompt("running jumping crashing spinning racing storm", 64, 16);
        assert!(wild.l2_norm() > calm.l2_norm());
    }

    #[test]
    fn vbench_set_shape() {
        let ps = vbench_prompts(3);
        assert_eq!(ps.len(), 33);
        let cats: std::collections::BTreeSet<_> =
            ps.iter().map(|p| p.category.clone()).collect();
        assert_eq!(cats.len(), 11);
        // ids unique
        let ids: std::collections::BTreeSet<_> = ps.iter().map(|p| p.id).collect();
        assert_eq!(ids.len(), ps.len());
    }

    #[test]
    fn ucf_and_evalcrafter_sizes() {
        assert_eq!(ucf101_prompts(101).len(), 101);
        assert_eq!(ucf101_prompts(300).len(), 101);
        assert_eq!(evalcrafter_prompts(150).len(), 150);
        assert_eq!(evalcrafter_prompts(9).len(), 9);
    }

    #[test]
    fn long_prompt_rolls_into_budget() {
        let long: String = (0..100).map(|i| format!("word{i} ")).collect();
        let e = embed_prompt(&long, 32, 8);
        assert_eq!(e.dims, vec![8, 32]);
        assert!(e.data.iter().any(|&v| v != 0.0));
    }
}
