//! Shared scaffolding for the paper-reproduction benches
//! (`rust/benches/*.rs`, one per paper table/figure — DESIGN.md §5).
//!
//! Benches run at a reduced default scale so `cargo bench` finishes on a
//! laptop-class CPU; set `FORESIGHT_BENCH_SCALE=paper` to use the paper's
//! prompt counts (550 VBench / 101 UCF / 150 EvalCrafter — hours of CPU).

use anyhow::Result;
use std::collections::BTreeMap;
use std::sync::Arc;

use crate::config::Manifest;
use crate::engine::{Engine, HotPath, Request, RunResult, RunStats};
use crate::metrics::{self, ClipProxy, Decoder, FeatureNet, Frames};
use crate::model::LoadedModel;
use crate::policy::build_policy;
use crate::runtime::Runtime;
use crate::util::stats;
use crate::workload::PromptSpec;

/// Scale knob for prompt counts.
pub fn bench_scale() -> f64 {
    match std::env::var("FORESIGHT_BENCH_SCALE").as_deref() {
        Ok("paper") => 1.0,
        Ok("medium") => 0.2,
        _ => 0.012, // quick default
    }
}

/// Scaled prompt count: paper count n → quick subset (min 2).
pub fn scaled(n: usize) -> usize {
    ((n as f64 * bench_scale()).round() as usize).clamp(2, n)
}

/// Lazily-loaded engines shared by a bench run.
pub struct BenchCtx {
    pub manifest: Manifest,
    rt: Arc<Runtime>,
    /// Engines keyed by (model, bucket, hot-path mode); the loaded model is
    /// shared between modes of the same (model, bucket).
    engines: BTreeMap<(String, String, String), Arc<Engine>>,
}

impl BenchCtx {
    pub fn new() -> Result<Self> {
        let manifest = Manifest::load(&Manifest::default_root())?;
        let rt = Arc::new(Runtime::cpu()?);
        Ok(Self { manifest, rt, engines: BTreeMap::new() })
    }

    /// The shared PJRT runtime (its [`crate::runtime::TransferStats`] is
    /// the ground truth for the fig16/fig17 transfer-volume assertions).
    pub fn runtime(&self) -> &Arc<Runtime> {
        &self.rt
    }

    pub fn engine(&mut self, model: &str, bucket: &str) -> Result<Arc<Engine>> {
        self.engine_hot(model, bucket, HotPath::Device)
    }

    /// Engine pinned to a hot-path mode (the fig16 A/B comparison).
    pub fn engine_hot(&mut self, model: &str, bucket: &str, hot: HotPath) -> Result<Arc<Engine>> {
        let key = (model.to_string(), bucket.to_string(), format!("{hot:?}"));
        if let Some(e) = self.engines.get(&key) {
            return Ok(e.clone());
        }
        // Reuse an already-loaded model from the other mode if present so
        // weights upload once per (model, bucket).
        let lm = self
            .engines
            .iter()
            .find(|((m, b, _), _)| m == model && b == bucket)
            .map(|(_, e)| e.model().clone());
        let lm = match lm {
            Some(lm) => lm,
            None => Arc::new(LoadedModel::load(self.rt.clone(), &self.manifest, model, bucket)?),
        };
        let e = Arc::new(Engine::with_hot_path(lm, self.manifest.schedule, hot));
        self.engines.insert(key, e.clone());
        Ok(e)
    }

    pub fn decoder_for(&self, engine: &Engine) -> Decoder {
        let b = &engine.model().bucket;
        Decoder::new(b.ph, b.pw, engine.model().info.latent_channels)
    }
}

/// Marginal per-step transfer bytes `(h2d, d2h)` between two runs of the
/// same request at different step counts. Differencing the two runs
/// cancels everything that does not scale with the step count (text
/// conditioning, the initial latent, the CFG scale, the final download),
/// isolating the steady-state per-step bus traffic — the quantity
/// `fig17_resident` A/Bs across [`HotPath`] modes. Per-step scalars that
/// upload at request start (timesteps, sampler coefficients) scale with
/// the step count and are correctly charged here.
pub fn steady_state_bytes_per_step(short: &RunStats, long: &RunStats) -> (f64, f64) {
    let ds = long.per_step_s.len().saturating_sub(short.per_step_s.len()).max(1) as f64;
    (
        long.h2d_bytes.saturating_sub(short.h2d_bytes) as f64 / ds,
        long.d2h_bytes.saturating_sub(short.d2h_bytes) as f64 / ds,
    )
}

/// First element pair violating the relative tolerance
/// `|a − b| ≤ tol·(1 + |b|)`, or `None` when the slices agree — the one
/// shared device-vs-host latent equivalence criterion (fig16, fig17 and
/// the engine equivalence test all call this so the tolerance cannot
/// drift apart between them). Panics on length mismatch.
pub fn first_latent_mismatch(a: &[f32], b: &[f32], tol: f64) -> Option<(usize, f32, f32)> {
    assert_eq!(a.len(), b.len(), "latent length mismatch");
    a.iter().zip(b).enumerate().find_map(|(i, (&x, &y))| {
        if ((x - y).abs() as f64) > tol * (1.0 + y.abs() as f64) {
            Some((i, x, y))
        } else {
            None
        }
    })
}

/// One generation under a policy spec.
pub fn run_one(
    engine: &Engine,
    spec: &str,
    prompt: &str,
    seed: u64,
    steps: Option<usize>,
) -> Result<RunResult> {
    let info = &engine.model().info;
    let mut policy = build_policy(spec, info, steps.unwrap_or(info.steps))?;
    let mut req = Request::new(prompt, seed);
    req.steps = steps;
    engine.generate(&req, policy.as_mut(), None)
}

/// Aggregated per-method results over a prompt set (a paper table row).
pub struct MethodRow {
    pub name: String,
    pub latencies: Vec<f64>,
    pub reuse_frac: f64,
    pub psnr: f64,
    pub ssim: f64,
    pub lpips: f64,
    pub vbench: f64,
    pub fvd: f64,
    pub cache_peak_bytes: usize,
}

impl MethodRow {
    pub fn latency_mean(&self) -> f64 {
        stats::mean(&self.latencies)
    }

    pub fn latency_cell(&self) -> String {
        stats::fmt_mean_pm_std(&self.latencies)
    }

    pub fn speedup_vs(&self, base: &MethodRow) -> f64 {
        base.latency_mean() / self.latency_mean()
    }
}

/// Run a full method-comparison suite over a prompt set: baseline first,
/// then each policy spec; quality metrics computed per prompt vs. the
/// baseline video (exactly the paper's Table 1 protocol).
pub fn run_suite(
    engine: &Engine,
    prompts: &[PromptSpec],
    specs: &[(&str, &str)], // (display name, policy spec)
    steps: Option<usize>,
) -> Result<(MethodRow, Vec<MethodRow>)> {
    let dec = {
        let b = &engine.model().bucket;
        Decoder::new(b.ph, b.pw, engine.model().info.latent_channels)
    };
    let net = FeatureNet::new();

    // warm the runtime so the first measured latency isn't compile-skewed
    let _ = run_one(engine, "none", "warmup prompt", 0, Some(2))?;

    let mut base_frames: Vec<Frames> = Vec::new();
    let mut base_lat = Vec::new();
    for p in prompts {
        let r = run_one(engine, "none", &p.text, p.id as u64, steps)?;
        base_lat.push(r.stats.wall_s);
        base_frames.push(dec.decode(&r.latents));
    }
    let baseline = MethodRow {
        name: "Baseline".into(),
        latencies: base_lat,
        reuse_frac: 0.0,
        psnr: f64::NAN,
        ssim: f64::NAN,
        lpips: f64::NAN,
        vbench: metrics::vbench_percent(&net, &base_frames),
        fvd: f64::NAN,
        cache_peak_bytes: 0,
    };

    let mut rows = Vec::new();
    for (name, spec) in specs {
        let mut lats = Vec::new();
        let mut reuse = stats::Welford::new();
        let mut psnr = stats::Welford::new();
        let mut ssim = stats::Welford::new();
        let mut lpips = stats::Welford::new();
        let mut frames = Vec::new();
        let mut cache_peak = 0usize;
        for p in prompts {
            let r = run_one(engine, spec, &p.text, p.id as u64, steps)?;
            lats.push(r.stats.wall_s);
            reuse.push(r.stats.reuse_fraction());
            cache_peak = cache_peak.max(r.stats.cache_peak_bytes);
            let fr = dec.decode(&r.latents);
            let i = frames.len();
            psnr.push(metrics::psnr(&base_frames[i], &fr));
            ssim.push(metrics::ssim(&base_frames[i], &fr));
            lpips.push(metrics::lpips(&net, &base_frames[i], &fr));
            frames.push(fr);
        }
        rows.push(MethodRow {
            name: name.to_string(),
            latencies: lats,
            reuse_frac: reuse.mean(),
            psnr: psnr.mean(),
            ssim: ssim.mean(),
            lpips: lpips.mean(),
            vbench: metrics::vbench_percent(&net, &frames),
            fvd: metrics::fvd(&net, &base_frames, &frames),
            cache_peak_bytes: cache_peak,
        });
    }
    Ok((baseline, rows))
}

/// CLIP/VQA metric bundle for Table 8.
pub struct ClipVqaRow {
    pub name: String,
    pub clipsim: f64,
    pub clip_temp: f64,
    pub vqa_aesthetic: f64,
    pub vqa_technical: f64,
    pub vqa_overall: f64,
    pub latencies: Vec<f64>,
}

/// Table 8 protocol: absolute CLIP/VQA scores per method over a prompt set.
pub fn run_clip_vqa_suite(
    engine: &Engine,
    prompts: &[PromptSpec],
    specs: &[(&str, &str)],
    steps: Option<usize>,
) -> Result<Vec<ClipVqaRow>> {
    let dec = {
        let b = &engine.model().bucket;
        Decoder::new(b.ph, b.pw, engine.model().info.latent_channels)
    };
    let clip = ClipProxy::new(engine.model().info.d_text);
    let _ = run_one(engine, "none", "warmup prompt", 0, Some(2))?;

    let mut rows = Vec::new();
    for (name, spec) in specs {
        let mut lats = Vec::new();
        let mut cs = stats::Welford::new();
        let mut ct = stats::Welford::new();
        let mut va = stats::Welford::new();
        let mut vt = stats::Welford::new();
        let mut vo = stats::Welford::new();
        for p in prompts {
            let r = run_one(engine, spec, &p.text, p.id as u64, steps)?;
            lats.push(r.stats.wall_s);
            let fr = dec.decode(&r.latents);
            let emb = crate::workload::embed_prompt(
                &p.text,
                engine.model().info.d_text,
                engine.model().info.text_len,
            );
            cs.push(clip.clipsim(&emb, &fr));
            ct.push(clip.clip_temp(&fr));
            va.push(metrics::vqa_aesthetic(&fr));
            vt.push(metrics::vqa_technical(&fr));
            vo.push(metrics::vqa_overall(&fr));
        }
        rows.push(ClipVqaRow {
            name: name.to_string(),
            clipsim: cs.mean(),
            clip_temp: ct.mean(),
            vqa_aesthetic: va.mean(),
            vqa_technical: vt.mean(),
            vqa_overall: vo.mean(),
            latencies: lats,
        });
    }
    Ok(rows)
}

/// The standard method set of Table 1.
pub const TABLE1_METHODS: [(&str, &str); 6] = [
    ("Static", "static"),
    ("Δ-DiT", "delta-dit"),
    ("T-GATE", "tgate"),
    ("PAB", "pab"),
    ("Foresight (N1R2)", "foresight:n=1,r=2,gamma=0.5"),
    ("Foresight (N2R3)", "foresight:n=2,r=3,gamma=0.5"),
];

/// The paper's three evaluation models with their buckets.
pub const PAPER_MODELS: [(&str, &str); 3] = [
    ("opensora-sim", "240p-2s"),
    ("latte-sim", "512sq-2s"),
    ("cogvideox-sim", "480x720-2s"),
];
