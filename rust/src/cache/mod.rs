//! Feature cache manager (paper Eq. 3 and §4.2 "Overhead: Memory").
//!
//! Stores DiT-block activations (or sublayer residual deltas for the
//! fine-grained baselines) per CFG branch, with byte-exact memory
//! accounting. Entries are **device-resident only**: Foresight's Eq. 5/6
//! drift measurement runs as a fused on-device `mse` reduction against the
//! cached buffer, so the host mirrors the seed engine kept per measured
//! site are gone (halving Foresight's cache footprint). Foresight's coarse
//! strategy caches 2 entries per layer pair (spatial + temporal block
//! outputs → the paper's `2LHWF`); PAB-style fine-grained caching stores up
//! to 6 (3 sublayers × 2 blocks → `6LHWF`), which is how the paper's 3×
//! memory-reduction claim is reproduced (asserted in tests and reported by
//! the Table 1 bench).
//!
//! The engine keeps one `FeatureCache` per CFG branch so the two guidance
//! branches can execute on concurrent threads without sharing mutable
//! state; keys still carry the branch index for stable telemetry.
//!
//! # History rings (feature forecasting)
//!
//! When built with [`FeatureCache::with_history`] depth `k >= 2`, the
//! cache additionally keeps the last `k-1` *superseded* outputs per site
//! in a bounded ring, so [`FeatureCache::last_k`] can serve the `k` most
//! recent outputs (live entry + ring) to the engine's linear-multistep
//! forecast (`runtime::lms_combine`) on a Predict step. Ring slots are
//! byte-accounted in `current_bytes`/`peak_bytes` exactly like live
//! entries, survive device migration bit-exactly through
//! [`FeatureCache::drain_history`]/[`FeatureCache::restore_history`], and
//! are never counted as policy stores or hits — the ring is data
//! retention, not a caching decision. Depth 0/1 (the default) keeps the
//! ring machinery entirely inert: `put` frees superseded buffers
//! immediately, as it always has.

use std::collections::{BTreeMap, VecDeque};
use std::sync::Arc;

use crate::model::{BlockKind, SubUnit};
use crate::runtime::DeviceTensor;

/// What a cache entry holds.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Unit {
    /// Whole DiT-block output (coarse; Foresight / Static / Δ-DiT).
    Block,
    /// One sublayer's residual delta (fine; PAB / T-GATE).
    Sub(SubUnit),
}

impl Unit {
    pub fn name(&self) -> String {
        match self {
            Unit::Block => "block".to_string(),
            Unit::Sub(s) => format!("sub.{}", s.name()),
        }
    }
}

/// Cache key: CFG branch × layer × block kind × unit.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct CacheKey {
    pub branch: usize,
    pub layer: usize,
    pub kind: BlockKind,
    pub unit: Unit,
}

/// One cached activation: a device buffer shared by reference for zero-copy
/// reuse and for on-device drift measurement.
pub struct CacheEntry {
    pub device: Arc<DeviceTensor>,
    /// Step at which this entry was written (staleness analytics).
    pub step: usize,
}

/// Per-request feature cache with memory accounting.
#[derive(Default)]
pub struct FeatureCache {
    entries: BTreeMap<CacheKey, CacheEntry>,
    /// Superseded outputs per site, oldest at the front, newest at the
    /// back. Bounded to `history_depth - 1` slots (the live entry is the
    /// k-th, newest, output). Empty unless `history_depth >= 2`.
    history: BTreeMap<CacheKey, VecDeque<(Arc<DeviceTensor>, usize)>>,
    /// How many outputs per site `last_k` can serve (live entry + ring).
    /// 0/1 disables the ring.
    history_depth: usize,
    current_bytes: usize,
    peak_bytes: usize,
    /// Lifetime counters.
    pub stores: u64,
    pub hits: u64,
}

impl FeatureCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// A cache whose sites retain the last `depth` outputs (live entry
    /// plus a ring of `depth - 1` superseded buffers) for feature
    /// forecasting. `depth <= 1` is identical to [`FeatureCache::new`].
    pub fn with_history(depth: usize) -> Self {
        Self { history_depth: depth, ..Self::default() }
    }

    /// The configured history depth (outputs retained per site).
    pub fn history_depth(&self) -> usize {
        self.history_depth
    }

    fn entry_bytes(e: &CacheEntry) -> usize {
        e.device.element_count() * 4
    }

    fn tensor_bytes(t: &DeviceTensor) -> usize {
        t.element_count() * 4
    }

    /// Insert or replace an entry. With history enabled, the superseded
    /// buffer moves into the site's ring (its bytes stay charged); ring
    /// slots beyond `history_depth - 1` are freed oldest-first.
    pub fn put(&mut self, key: CacheKey, device: Arc<DeviceTensor>, step: usize) {
        let entry = CacheEntry { device, step };
        let new_bytes = Self::entry_bytes(&entry);
        let old = self.entries.insert(key, entry);
        self.current_bytes += new_bytes;
        if let Some(old) = old {
            if self.history_depth >= 2 {
                // The new buffer is charged before the ring evicts: an
                // evicted slot is only freed after the new output exists
                // on device, so the high water includes both.
                self.peak_bytes = self.peak_bytes.max(self.current_bytes);
                let ring = self.history.entry(key).or_default();
                ring.push_back((old.device, old.step));
                while ring.len() > self.history_depth - 1 {
                    if let Some((evicted, _)) = ring.pop_front() {
                        self.current_bytes -= Self::tensor_bytes(&evicted);
                    }
                }
            } else {
                self.current_bytes -= Self::entry_bytes(&old);
            }
        }
        self.peak_bytes = self.peak_bytes.max(self.current_bytes);
        self.stores += 1;
    }

    /// How many outputs are available for this site right now: the live
    /// entry (if any) plus the ring of superseded outputs behind it.
    pub fn depth(&self, key: &CacheKey) -> usize {
        let live = usize::from(self.entries.contains_key(key));
        live + self.history.get(key).map_or(0, |r| r.len())
    }

    /// The `k` most recent outputs for this site, newest first (live
    /// entry, then ring back-to-front). `None` when fewer than `k`
    /// outputs are retained — the forecast caller falls back to verbatim
    /// replay. Not a policy hit: forecasting reads are accounted by the
    /// engine's own forecast counters.
    pub fn last_k(&self, key: &CacheKey, k: usize) -> Option<Vec<Arc<DeviceTensor>>> {
        if k == 0 || self.depth(key) < k {
            return None;
        }
        let mut out = Vec::with_capacity(k);
        out.push(self.entries.get(key)?.device.clone());
        if let Some(ring) = self.history.get(key) {
            out.extend(ring.iter().rev().take(k - 1).map(|(d, _)| d.clone()));
        }
        Some(out)
    }

    pub fn get(&mut self, key: &CacheKey) -> Option<&CacheEntry> {
        let e = self.entries.get(key);
        if e.is_some() {
            self.hits += 1;
        }
        e
    }

    /// Look at an entry without counting a hit (used by the measurement
    /// path, which compares a fresh activation against the cached one).
    pub fn peek(&self, key: &CacheKey) -> Option<&CacheEntry> {
        self.entries.get(key)
    }

    pub fn contains(&self, key: &CacheKey) -> bool {
        self.entries.contains_key(key)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn current_bytes(&self) -> usize {
        self.current_bytes
    }

    pub fn peak_bytes(&self) -> usize {
        self.peak_bytes
    }

    /// Entries per layer-pair (the paper's "2 vs 6 per layer" comparison),
    /// over the branch with the most entries.
    pub fn entries_per_layer(&self, layers: usize) -> f64 {
        if layers == 0 || self.entries.is_empty() {
            return 0.0;
        }
        let branches: std::collections::BTreeSet<usize> =
            self.entries.keys().map(|k| k.branch).collect();
        let max_per_branch = branches
            .iter()
            .map(|b| self.entries.keys().filter(|k| k.branch == *b).count())
            .max()
            .unwrap_or(0);
        max_per_branch as f64 / layers as f64
    }

    pub fn clear(&mut self) {
        self.entries.clear();
        self.history.clear();
        self.current_bytes = 0;
    }

    /// Total bytes currently held by history rings (excluded: live
    /// entries). Used by the migration path to predict the extra bus
    /// charge of moving forecast history.
    pub fn history_bytes(&self) -> usize {
        self.history
            .values()
            .flat_map(|r| r.iter())
            .map(|(d, _)| Self::tensor_bytes(d))
            .sum()
    }

    // --- device-migration support -------------------------------------
    //
    // Session migration (engine::Session::migrate) rebuilds a cache on
    // another runtime: entries are drained here, round-tripped
    // device→host→device by the caller, restored into a fresh cache, and
    // the lifetime accounting is adopted so the migrated request reports
    // the same policy behavior (stores/hits) and true peak footprint it
    // would have reported had it never moved.

    /// Remove and return every entry, in key order. Lifetime counters and
    /// the peak stay behind for [`FeatureCache::adopt_accounting`];
    /// history rings stay resident until [`FeatureCache::drain_history`].
    pub fn drain_entries(&mut self) -> Vec<(CacheKey, CacheEntry)> {
        let drained: Vec<(CacheKey, CacheEntry)> =
            std::mem::take(&mut self.entries).into_iter().collect();
        for (_, e) in &drained {
            self.current_bytes -= Self::entry_bytes(e);
        }
        drained
    }

    /// Remove and return every history ring, in key order; per ring the
    /// slots come out oldest first, matching the order
    /// [`FeatureCache::restore_history`] expects.
    pub fn drain_history(&mut self) -> Vec<(CacheKey, Vec<(Arc<DeviceTensor>, usize)>)> {
        let drained: Vec<(CacheKey, Vec<(Arc<DeviceTensor>, usize)>)> =
            std::mem::take(&mut self.history)
                .into_iter()
                .map(|(k, ring)| (k, ring.into_iter().collect()))
                .collect();
        for (_, ring) in &drained {
            for (d, _) in ring {
                self.current_bytes -= Self::tensor_bytes(d);
            }
        }
        drained
    }

    /// Append one transferred history slot (oldest-first call order)
    /// without counting a policy store.
    pub fn restore_history(&mut self, key: CacheKey, device: Arc<DeviceTensor>, step: usize) {
        self.current_bytes += Self::tensor_bytes(&device);
        self.history.entry(key).or_default().push_back((device, step));
        self.peak_bytes = self.peak_bytes.max(self.current_bytes);
    }

    /// Insert a transferred entry **without** counting a policy store —
    /// a migration rebuild is data movement, not a caching decision.
    pub fn restore(&mut self, key: CacheKey, device: Arc<DeviceTensor>, step: usize) {
        let entry = CacheEntry { device, step };
        let new_bytes = Self::entry_bytes(&entry);
        if let Some(old) = self.entries.insert(key, entry) {
            self.current_bytes -= Self::entry_bytes(&old);
        }
        self.current_bytes += new_bytes;
        self.peak_bytes = self.peak_bytes.max(self.current_bytes);
    }

    /// Carry a predecessor cache's lifetime counters and peak across a
    /// migration rebuild.
    pub fn adopt_accounting(&mut self, prev: &FeatureCache) {
        self.stores = prev.stores;
        self.hits = prev.hits;
        self.peak_bytes = self.peak_bytes.max(prev.peak_bytes);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Runtime;

    fn dev(rt: &Runtime, n: usize) -> Arc<DeviceTensor> {
        Arc::new(rt.upload(&vec![0.5f32; n], &[n]).unwrap())
    }

    fn key(branch: usize, layer: usize, unit: Unit) -> CacheKey {
        CacheKey { branch, layer, kind: BlockKind::Spatial, unit }
    }

    #[test]
    fn accounting_tracks_put_replace_peak() {
        let rt = Runtime::cpu().unwrap();
        let mut c = FeatureCache::new();
        c.put(key(0, 0, Unit::Block), dev(&rt, 100), 0);
        assert_eq!(c.current_bytes(), 400);
        // replace with a larger buffer: accounting follows the new size
        c.put(key(0, 0, Unit::Block), dev(&rt, 200), 1);
        assert_eq!(c.current_bytes(), 800);
        assert_eq!(c.peak_bytes(), 800);
        assert_eq!(c.len(), 1);
        // second entry
        c.put(key(0, 1, Unit::Block), dev(&rt, 50), 1);
        assert_eq!(c.current_bytes(), 1000);
        // replace back down: current shrinks, peak stays
        c.put(key(0, 0, Unit::Block), dev(&rt, 100), 2);
        assert_eq!(c.current_bytes(), 600);
        assert_eq!(c.peak_bytes(), 1000);
        c.clear();
        assert_eq!(c.current_bytes(), 0);
        assert_eq!(c.peak_bytes(), 1000, "peak survives clear");
    }

    #[test]
    fn coarse_vs_fine_entries_per_layer() {
        let rt = Runtime::cpu().unwrap();
        let layers = 4;
        // coarse: 2 per layer pair (spatial+temporal blocks)
        let mut coarse = FeatureCache::new();
        for l in 0..layers {
            for kind in BlockKind::ALL {
                coarse.put(
                    CacheKey { branch: 0, layer: l, kind, unit: Unit::Block },
                    dev(&rt, 10),
                    0,
                );
            }
        }
        assert!((coarse.entries_per_layer(layers) - 2.0).abs() < 1e-9);

        // fine: 3 sublayers × 2 kinds = 6 per layer pair
        let mut fine = FeatureCache::new();
        for l in 0..layers {
            for kind in BlockKind::ALL {
                for s in SubUnit::ALL {
                    fine.put(
                        CacheKey { branch: 0, layer: l, kind, unit: Unit::Sub(s) },
                        dev(&rt, 10),
                        0,
                    );
                }
            }
        }
        assert!((fine.entries_per_layer(layers) - 6.0).abs() < 1e-9);
        // the paper's 3× memory claim
        assert!(
            (fine.current_bytes() as f64 / coarse.current_bytes() as f64 - 3.0).abs() < 1e-9
        );
    }

    #[test]
    fn hits_stores_and_peek_counted() {
        let rt = Runtime::cpu().unwrap();
        let mut c = FeatureCache::new();
        let k = key(1, 2, Unit::Sub(SubUnit::Mlp));
        assert!(c.get(&k).is_none());
        assert_eq!(c.hits, 0);
        c.put(k, dev(&rt, 10), 3);
        assert!(c.peek(&k).is_some(), "peek sees the entry");
        assert_eq!(c.hits, 0, "peek must not count a hit");
        assert!(c.get(&k).is_some());
        assert_eq!(c.hits, 1);
        assert_eq!(c.stores, 1);
        assert_eq!(c.get(&k).unwrap().step, 3);
    }

    #[test]
    fn drain_restore_adopt_preserves_accounting() {
        let rt = Runtime::cpu().unwrap();
        let mut c = FeatureCache::new();
        c.put(key(0, 0, Unit::Block), dev(&rt, 100), 0);
        c.put(key(0, 1, Unit::Block), dev(&rt, 300), 1);
        c.put(key(0, 0, Unit::Block), dev(&rt, 50), 2); // shrink → peak > current
        let _ = c.get(&key(0, 1, Unit::Block));
        let (stores, hits, peak, cur) = (c.stores, c.hits, c.peak_bytes(), c.current_bytes());
        assert!(peak > cur);

        let entries = c.drain_entries();
        assert_eq!(entries.len(), 2);
        assert_eq!(c.current_bytes(), 0);

        let mut m = FeatureCache::new();
        for (k, e) in entries {
            m.restore(k, e.device, e.step);
        }
        m.adopt_accounting(&c);
        assert_eq!(m.len(), 2);
        assert_eq!(m.current_bytes(), cur, "byte-identical resident set");
        assert_eq!(m.peak_bytes(), peak, "peak carried across the rebuild");
        assert_eq!(m.stores, stores, "restore() is not a policy store");
        assert_eq!(m.hits, hits);
        assert_eq!(m.peek(&key(0, 0, Unit::Block)).unwrap().step, 2);
    }

    #[test]
    fn history_ring_bounds_depth_and_accounts_bytes() {
        let rt = Runtime::cpu().unwrap();
        let mut c = FeatureCache::with_history(3); // live + 2 ring slots
        let k = key(0, 0, Unit::Block);
        assert_eq!(c.depth(&k), 0);
        assert!(c.last_k(&k, 1).is_none());

        c.put(k, dev(&rt, 100), 0);
        assert_eq!(c.depth(&k), 1);
        assert_eq!(c.current_bytes(), 400);
        assert!(c.last_k(&k, 2).is_none(), "short history refuses");

        c.put(k, dev(&rt, 100), 1);
        c.put(k, dev(&rt, 100), 2);
        assert_eq!(c.depth(&k), 3);
        assert_eq!(c.current_bytes(), 1200, "live + 2 ring slots charged");
        assert_eq!(c.history_bytes(), 800);

        // fourth put evicts the oldest ring slot: depth and bytes hold
        c.put(k, dev(&rt, 100), 3);
        assert_eq!(c.depth(&k), 3);
        assert_eq!(c.current_bytes(), 1200);
        assert_eq!(c.peak_bytes(), 1600, "peak saw the pre-eviction high water");

        c.clear();
        assert_eq!(c.current_bytes(), 0);
        assert_eq!(c.depth(&k), 0);
    }

    #[test]
    fn last_k_orders_newest_first() {
        let rt = Runtime::cpu().unwrap();
        let mut c = FeatureCache::with_history(3);
        let k = key(0, 1, Unit::Block);
        for step in 0..3 {
            let d = Arc::new(rt.upload(&vec![step as f32; 4], &[4]).unwrap());
            c.put(k, d, step);
        }
        let h = c.last_k(&k, 3).unwrap();
        let vals: Vec<f32> = h.iter().map(|d| rt.download(d).unwrap().data[0]).collect();
        assert_eq!(vals, vec![2.0, 1.0, 0.0], "live entry, then ring newest→oldest");
        // k=2 serves the newest two
        let h2 = c.last_k(&k, 2).unwrap();
        assert_eq!(rt.download(&h2[1]).unwrap().data[0], 1.0);
    }

    #[test]
    fn depth_one_cache_keeps_ring_inert() {
        let rt = Runtime::cpu().unwrap();
        let mut c = FeatureCache::new();
        let k = key(0, 0, Unit::Block);
        c.put(k, dev(&rt, 100), 0);
        c.put(k, dev(&rt, 100), 1);
        assert_eq!(c.depth(&k), 1);
        assert_eq!(c.current_bytes(), 400, "superseded buffer freed immediately");
        assert_eq!(c.history_bytes(), 0);
        assert!(c.last_k(&k, 1).is_some());
        assert!(c.last_k(&k, 2).is_none());
    }

    #[test]
    fn drain_restore_history_round_trips_bytes_and_order() {
        let rt = Runtime::cpu().unwrap();
        let mut c = FeatureCache::with_history(3);
        let k = key(0, 2, Unit::Block);
        for step in 0..3 {
            let d = Arc::new(rt.upload(&vec![step as f32; 8], &[8]).unwrap());
            c.put(k, d, step);
        }
        let live_bytes = 32;
        let hist_bytes = c.history_bytes();
        assert_eq!(hist_bytes, 64);

        let entries = c.drain_entries();
        assert_eq!(c.current_bytes(), hist_bytes, "rings stay charged after entry drain");
        let rings = c.drain_history();
        assert_eq!(c.current_bytes(), 0);
        assert_eq!(rings.len(), 1);
        assert_eq!(rings[0].1.len(), 2);
        assert_eq!(rings[0].1[0].1, 0, "oldest first");

        let mut m = FeatureCache::with_history(3);
        for (key, e) in entries {
            m.restore(key, e.device, e.step);
        }
        for (key, ring) in rings {
            for (d, step) in ring {
                m.restore_history(key, d, step);
            }
        }
        m.adopt_accounting(&c);
        assert_eq!(m.current_bytes(), live_bytes + hist_bytes);
        assert_eq!(m.depth(&k), 3);
        let h = m.last_k(&k, 3).unwrap();
        let vals: Vec<f32> = h.iter().map(|d| rt.download(d).unwrap().data[0]).collect();
        assert_eq!(vals, vec![2.0, 1.0, 0.0], "order survives the hop");
        assert_eq!(m.stores, c.stores, "restores adopted the source counters, added none");
    }

    #[test]
    fn branches_are_isolated() {
        let rt = Runtime::cpu().unwrap();
        let mut c = FeatureCache::new();
        c.put(key(0, 0, Unit::Block), dev(&rt, 10), 0);
        assert!(!c.contains(&key(1, 0, Unit::Block)));
        assert!(c.contains(&key(0, 0, Unit::Block)));
    }
}
