//! Feature cache manager (paper Eq. 3 and §4.2 "Overhead: Memory").
//!
//! Stores DiT-block activations (or sublayer residual deltas for the
//! fine-grained baselines) per CFG branch, with byte-exact memory
//! accounting. Entries are **device-resident only**: Foresight's Eq. 5/6
//! drift measurement runs as a fused on-device `mse` reduction against the
//! cached buffer, so the host mirrors the seed engine kept per measured
//! site are gone (halving Foresight's cache footprint). Foresight's coarse
//! strategy caches 2 entries per layer pair (spatial + temporal block
//! outputs → the paper's `2LHWF`); PAB-style fine-grained caching stores up
//! to 6 (3 sublayers × 2 blocks → `6LHWF`), which is how the paper's 3×
//! memory-reduction claim is reproduced (asserted in tests and reported by
//! the Table 1 bench).
//!
//! The engine keeps one `FeatureCache` per CFG branch so the two guidance
//! branches can execute on concurrent threads without sharing mutable
//! state; keys still carry the branch index for stable telemetry.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::model::{BlockKind, SubUnit};
use crate::runtime::DeviceTensor;

/// What a cache entry holds.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Unit {
    /// Whole DiT-block output (coarse; Foresight / Static / Δ-DiT).
    Block,
    /// One sublayer's residual delta (fine; PAB / T-GATE).
    Sub(SubUnit),
}

impl Unit {
    pub fn name(&self) -> String {
        match self {
            Unit::Block => "block".to_string(),
            Unit::Sub(s) => format!("sub.{}", s.name()),
        }
    }
}

/// Cache key: CFG branch × layer × block kind × unit.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct CacheKey {
    pub branch: usize,
    pub layer: usize,
    pub kind: BlockKind,
    pub unit: Unit,
}

/// One cached activation: a device buffer shared by reference for zero-copy
/// reuse and for on-device drift measurement.
pub struct CacheEntry {
    pub device: Arc<DeviceTensor>,
    /// Step at which this entry was written (staleness analytics).
    pub step: usize,
}

/// Per-request feature cache with memory accounting.
#[derive(Default)]
pub struct FeatureCache {
    entries: BTreeMap<CacheKey, CacheEntry>,
    current_bytes: usize,
    peak_bytes: usize,
    /// Lifetime counters.
    pub stores: u64,
    pub hits: u64,
}

impl FeatureCache {
    pub fn new() -> Self {
        Self::default()
    }

    fn entry_bytes(e: &CacheEntry) -> usize {
        e.device.element_count() * 4
    }

    /// Insert or replace an entry.
    pub fn put(&mut self, key: CacheKey, device: Arc<DeviceTensor>, step: usize) {
        let entry = CacheEntry { device, step };
        let new_bytes = Self::entry_bytes(&entry);
        if let Some(old) = self.entries.insert(key, entry) {
            self.current_bytes -= Self::entry_bytes(&old);
        }
        self.current_bytes += new_bytes;
        self.peak_bytes = self.peak_bytes.max(self.current_bytes);
        self.stores += 1;
    }

    pub fn get(&mut self, key: &CacheKey) -> Option<&CacheEntry> {
        let e = self.entries.get(key);
        if e.is_some() {
            self.hits += 1;
        }
        e
    }

    /// Look at an entry without counting a hit (used by the measurement
    /// path, which compares a fresh activation against the cached one).
    pub fn peek(&self, key: &CacheKey) -> Option<&CacheEntry> {
        self.entries.get(key)
    }

    pub fn contains(&self, key: &CacheKey) -> bool {
        self.entries.contains_key(key)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn current_bytes(&self) -> usize {
        self.current_bytes
    }

    pub fn peak_bytes(&self) -> usize {
        self.peak_bytes
    }

    /// Entries per layer-pair (the paper's "2 vs 6 per layer" comparison),
    /// over the branch with the most entries.
    pub fn entries_per_layer(&self, layers: usize) -> f64 {
        if layers == 0 || self.entries.is_empty() {
            return 0.0;
        }
        let branches: std::collections::BTreeSet<usize> =
            self.entries.keys().map(|k| k.branch).collect();
        let max_per_branch = branches
            .iter()
            .map(|b| self.entries.keys().filter(|k| k.branch == *b).count())
            .max()
            .unwrap_or(0);
        max_per_branch as f64 / layers as f64
    }

    pub fn clear(&mut self) {
        self.entries.clear();
        self.current_bytes = 0;
    }

    // --- device-migration support -------------------------------------
    //
    // Session migration (engine::Session::migrate) rebuilds a cache on
    // another runtime: entries are drained here, round-tripped
    // device→host→device by the caller, restored into a fresh cache, and
    // the lifetime accounting is adopted so the migrated request reports
    // the same policy behavior (stores/hits) and true peak footprint it
    // would have reported had it never moved.

    /// Remove and return every entry, in key order. Lifetime counters and
    /// the peak stay behind for [`FeatureCache::adopt_accounting`].
    pub fn drain_entries(&mut self) -> Vec<(CacheKey, CacheEntry)> {
        self.current_bytes = 0;
        std::mem::take(&mut self.entries).into_iter().collect()
    }

    /// Insert a transferred entry **without** counting a policy store —
    /// a migration rebuild is data movement, not a caching decision.
    pub fn restore(&mut self, key: CacheKey, device: Arc<DeviceTensor>, step: usize) {
        let entry = CacheEntry { device, step };
        let new_bytes = Self::entry_bytes(&entry);
        if let Some(old) = self.entries.insert(key, entry) {
            self.current_bytes -= Self::entry_bytes(&old);
        }
        self.current_bytes += new_bytes;
        self.peak_bytes = self.peak_bytes.max(self.current_bytes);
    }

    /// Carry a predecessor cache's lifetime counters and peak across a
    /// migration rebuild.
    pub fn adopt_accounting(&mut self, prev: &FeatureCache) {
        self.stores = prev.stores;
        self.hits = prev.hits;
        self.peak_bytes = self.peak_bytes.max(prev.peak_bytes);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Runtime;

    fn dev(rt: &Runtime, n: usize) -> Arc<DeviceTensor> {
        Arc::new(rt.upload(&vec![0.5f32; n], &[n]).unwrap())
    }

    fn key(branch: usize, layer: usize, unit: Unit) -> CacheKey {
        CacheKey { branch, layer, kind: BlockKind::Spatial, unit }
    }

    #[test]
    fn accounting_tracks_put_replace_peak() {
        let rt = Runtime::cpu().unwrap();
        let mut c = FeatureCache::new();
        c.put(key(0, 0, Unit::Block), dev(&rt, 100), 0);
        assert_eq!(c.current_bytes(), 400);
        // replace with a larger buffer: accounting follows the new size
        c.put(key(0, 0, Unit::Block), dev(&rt, 200), 1);
        assert_eq!(c.current_bytes(), 800);
        assert_eq!(c.peak_bytes(), 800);
        assert_eq!(c.len(), 1);
        // second entry
        c.put(key(0, 1, Unit::Block), dev(&rt, 50), 1);
        assert_eq!(c.current_bytes(), 1000);
        // replace back down: current shrinks, peak stays
        c.put(key(0, 0, Unit::Block), dev(&rt, 100), 2);
        assert_eq!(c.current_bytes(), 600);
        assert_eq!(c.peak_bytes(), 1000);
        c.clear();
        assert_eq!(c.current_bytes(), 0);
        assert_eq!(c.peak_bytes(), 1000, "peak survives clear");
    }

    #[test]
    fn coarse_vs_fine_entries_per_layer() {
        let rt = Runtime::cpu().unwrap();
        let layers = 4;
        // coarse: 2 per layer pair (spatial+temporal blocks)
        let mut coarse = FeatureCache::new();
        for l in 0..layers {
            for kind in BlockKind::ALL {
                coarse.put(
                    CacheKey { branch: 0, layer: l, kind, unit: Unit::Block },
                    dev(&rt, 10),
                    0,
                );
            }
        }
        assert!((coarse.entries_per_layer(layers) - 2.0).abs() < 1e-9);

        // fine: 3 sublayers × 2 kinds = 6 per layer pair
        let mut fine = FeatureCache::new();
        for l in 0..layers {
            for kind in BlockKind::ALL {
                for s in SubUnit::ALL {
                    fine.put(
                        CacheKey { branch: 0, layer: l, kind, unit: Unit::Sub(s) },
                        dev(&rt, 10),
                        0,
                    );
                }
            }
        }
        assert!((fine.entries_per_layer(layers) - 6.0).abs() < 1e-9);
        // the paper's 3× memory claim
        assert!(
            (fine.current_bytes() as f64 / coarse.current_bytes() as f64 - 3.0).abs() < 1e-9
        );
    }

    #[test]
    fn hits_stores_and_peek_counted() {
        let rt = Runtime::cpu().unwrap();
        let mut c = FeatureCache::new();
        let k = key(1, 2, Unit::Sub(SubUnit::Mlp));
        assert!(c.get(&k).is_none());
        assert_eq!(c.hits, 0);
        c.put(k, dev(&rt, 10), 3);
        assert!(c.peek(&k).is_some(), "peek sees the entry");
        assert_eq!(c.hits, 0, "peek must not count a hit");
        assert!(c.get(&k).is_some());
        assert_eq!(c.hits, 1);
        assert_eq!(c.stores, 1);
        assert_eq!(c.get(&k).unwrap().step, 3);
    }

    #[test]
    fn drain_restore_adopt_preserves_accounting() {
        let rt = Runtime::cpu().unwrap();
        let mut c = FeatureCache::new();
        c.put(key(0, 0, Unit::Block), dev(&rt, 100), 0);
        c.put(key(0, 1, Unit::Block), dev(&rt, 300), 1);
        c.put(key(0, 0, Unit::Block), dev(&rt, 50), 2); // shrink → peak > current
        let _ = c.get(&key(0, 1, Unit::Block));
        let (stores, hits, peak, cur) = (c.stores, c.hits, c.peak_bytes(), c.current_bytes());
        assert!(peak > cur);

        let entries = c.drain_entries();
        assert_eq!(entries.len(), 2);
        assert_eq!(c.current_bytes(), 0);

        let mut m = FeatureCache::new();
        for (k, e) in entries {
            m.restore(k, e.device, e.step);
        }
        m.adopt_accounting(&c);
        assert_eq!(m.len(), 2);
        assert_eq!(m.current_bytes(), cur, "byte-identical resident set");
        assert_eq!(m.peak_bytes(), peak, "peak carried across the rebuild");
        assert_eq!(m.stores, stores, "restore() is not a policy store");
        assert_eq!(m.hits, hits);
        assert_eq!(m.peek(&key(0, 0, Unit::Block)).unwrap().step, 2);
    }

    #[test]
    fn branches_are_isolated() {
        let rt = Runtime::cpu().unwrap();
        let mut c = FeatureCache::new();
        c.put(key(0, 0, Unit::Block), dev(&rt, 10), 0);
        assert!(!c.contains(&key(1, 0, Unit::Block)));
        assert!(c.contains(&key(0, 0, Unit::Block)));
    }
}
