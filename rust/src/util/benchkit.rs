//! Benchmark harness (criterion substitute) + results writers.
//!
//! Each paper table/figure has a `[[bench]] harness = false` binary that
//! uses this module to time workloads (warmup + measured iterations,
//! mean/std/percentiles) and to emit the paper-shaped markdown table plus a
//! CSV series under `results/`.
//!
//! Every report additionally writes a machine-readable
//! `results/BENCH_<slug>.json` — the repo's in-repo perf trajectory. It
//! always carries the report's CSV series; benches register headline
//! numbers ([`Report::metric`]: wall/throughput/p50/p99) and their
//! configuration ([`Report::config`]) so successive runs can be diffed
//! without parsing markdown.

use std::fmt::Write as _;
use std::path::Path;
use std::time::Instant;

use super::json::Json;
use super::stats;

/// Timing result for one benchmark case.
#[derive(Debug, Clone)]
pub struct Timing {
    pub name: String,
    pub samples_s: Vec<f64>,
}

impl Timing {
    pub fn mean_s(&self) -> f64 {
        stats::mean(&self.samples_s)
    }
    pub fn std_s(&self) -> f64 {
        stats::std(&self.samples_s)
    }
    pub fn p50_s(&self) -> f64 {
        stats::percentile(&self.samples_s, 50.0)
    }
    pub fn p95_s(&self) -> f64 {
        stats::percentile(&self.samples_s, 95.0)
    }
    pub fn summary(&self) -> String {
        format!(
            "{}: mean {:.4}s ± {:.4}s (p50 {:.4}s, p95 {:.4}s, n={})",
            self.name,
            self.mean_s(),
            self.std_s(),
            self.p50_s(),
            self.p95_s(),
            self.samples_s.len()
        )
    }
}

/// Time `f` with `warmup` unmeasured and `iters` measured runs.
pub fn time_case<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    Timing { name: name.to_string(), samples_s: samples }
}

/// Markdown table builder matching the paper's table shapes.
#[derive(Debug, Default, Clone)]
pub struct MdTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl MdTable {
    pub fn new(header: &[&str]) -> Self {
        Self { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    pub fn to_markdown(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "| {} |", self.header.join(" | "));
        let _ = writeln!(
            s,
            "|{}|",
            self.header.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        );
        for r in &self.rows {
            let _ = writeln!(s, "| {} |", r.join(" | "));
        }
        s
    }

    pub fn to_csv(&self) -> String {
        let esc = |c: &str| {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let mut s = String::new();
        let _ = writeln!(s, "{}", self.header.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        for r in &self.rows {
            let _ = writeln!(s, "{}", r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        }
        s
    }
}

/// A report file under results/: title, commentary, tables, csv series,
/// and the machine-readable `BENCH_<slug>.json` companion.
pub struct Report {
    slug: String,
    md: String,
    csvs: Vec<(String, String)>,
    /// Bench configuration echoed into the JSON (steps, load, devices…).
    config: Vec<(String, Json)>,
    /// Headline numbers (wall/throughput/p50/p99…) for trajectory diffs.
    metrics: Vec<(String, f64)>,
    /// Structured copies of the CSV series for the JSON companion.
    tables: Vec<(String, MdTable)>,
}

impl Report {
    pub fn new(slug: &str, title: &str) -> Self {
        Self {
            slug: slug.to_string(),
            md: format!("# {title}\n\n"),
            csvs: Vec::new(),
            config: Vec::new(),
            metrics: Vec::new(),
            tables: Vec::new(),
        }
    }

    pub fn text(&mut self, t: &str) {
        self.md.push_str(t);
        self.md.push('\n');
    }

    pub fn table(&mut self, caption: &str, t: &MdTable) {
        let _ = writeln!(self.md, "\n**{caption}**\n\n{}", t.to_markdown());
    }

    pub fn csv(&mut self, name: &str, t: &MdTable) {
        self.csvs.push((name.to_string(), t.to_csv()));
        self.tables.push((name.to_string(), t.clone()));
    }

    /// Record one configuration value for the JSON companion.
    pub fn config(&mut self, key: &str, value: Json) {
        self.config.push((key.to_string(), value));
    }

    /// Record one headline metric for the JSON companion.
    pub fn metric(&mut self, key: &str, value: f64) {
        self.metrics.push((key.to_string(), value));
    }

    /// Write results/<slug>.md (+ any csvs) plus the machine-readable
    /// results/BENCH_<slug>.json, and echo the report to stdout.
    pub fn finish(self) -> std::io::Result<()> {
        let dir = Path::new("results");
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join(format!("{}.md", self.slug)), &self.md)?;
        for (name, csv) in &self.csvs {
            std::fs::write(dir.join(format!("{}_{}.csv", self.slug, name)), csv)?;
        }
        let json = bench_json(&self.slug, &self.config, &self.metrics, &self.tables);
        std::fs::write(
            dir.join(format!("BENCH_{}.json", self.slug)),
            format!("{json}\n"),
        )?;
        println!("{}", self.md);
        println!(
            "[benchkit] wrote results/{}.md and results/BENCH_{}.json",
            self.slug, self.slug
        );
        Ok(())
    }
}

/// Assemble the machine-readable bench record (pure; [`Report::finish`]
/// writes it to `results/BENCH_<slug>.json`).
pub fn bench_json(
    slug: &str,
    config: &[(String, Json)],
    metrics: &[(String, f64)],
    tables: &[(String, MdTable)],
) -> Json {
    let cfg: Vec<(&str, Json)> = config.iter().map(|(k, v)| (k.as_str(), v.clone())).collect();
    let mets: Vec<(&str, Json)> = metrics
        .iter()
        .map(|(k, v)| (k.as_str(), Json::num(*v)))
        .collect();
    let tbls: Vec<(&str, Json)> = tables
        .iter()
        .map(|(name, t)| {
            let header = Json::Arr(t.header.iter().map(|h| Json::str(h.as_str())).collect());
            let rows = Json::Arr(
                t.rows
                    .iter()
                    .map(|r| Json::Arr(r.iter().map(|c| Json::str(c.as_str())).collect()))
                    .collect(),
            );
            (
                name.as_str(),
                Json::obj(vec![("header", header), ("rows", rows)]),
            )
        })
        .collect();
    Json::obj(vec![
        ("bench", Json::str(slug)),
        ("config", Json::obj(cfg)),
        ("metrics", Json::obj(mets)),
        ("tables", Json::obj(tbls)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_measures_something() {
        let t = time_case("t", 1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(t.samples_s.len(), 5);
        assert!(t.mean_s() >= 0.0);
        assert!(t.p95_s() >= t.p50_s());
    }

    #[test]
    fn md_table_shape() {
        let mut t = MdTable::new(&["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let md = t.to_markdown();
        assert!(md.contains("| a | b |"));
        assert!(md.contains("| 1 | 2 |"));
        assert!(md.contains("|---|---|"));
    }

    #[test]
    #[should_panic]
    fn row_width_mismatch_panics() {
        let mut t = MdTable::new(&["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn csv_escapes_commas() {
        let mut t = MdTable::new(&["a"]);
        t.row(vec!["x,y".into()]);
        assert!(t.to_csv().contains("\"x,y\""));
    }

    #[test]
    fn bench_json_round_trips_config_metrics_tables() {
        let mut t = MdTable::new(&["n", "thr"]);
        t.row(vec!["1".into(), "2.5".into()]);
        let j = bench_json(
            "fig_test",
            &[("steps".to_string(), Json::num(8.0))],
            &[("p50_s".to_string(), 0.25)],
            &[("scaling".to_string(), t)],
        );
        // the record must survive its own wire format
        let back = crate::util::json::parse(&j.to_string()).unwrap();
        assert_eq!(back.get("bench").and_then(|v| v.as_str()), Some("fig_test"));
        assert_eq!(
            back.get("config").and_then(|c| c.get("steps")).and_then(|v| v.as_f64()),
            Some(8.0)
        );
        assert_eq!(
            back.get("metrics").and_then(|m| m.get("p50_s")).and_then(|v| v.as_f64()),
            Some(0.25)
        );
        let rows = back
            .get("tables")
            .and_then(|t| t.get("scaling"))
            .and_then(|t| t.get("rows"))
            .and_then(|r| r.as_arr())
            .expect("rows present");
        assert_eq!(rows.len(), 1);
    }
}
