//! Benchmark harness (criterion substitute) + results writers.
//!
//! Each paper table/figure has a `[[bench]] harness = false` binary that
//! uses this module to time workloads (warmup + measured iterations,
//! mean/std/percentiles) and to emit the paper-shaped markdown table plus a
//! CSV series under `results/`.

use std::fmt::Write as _;
use std::path::Path;
use std::time::Instant;

use super::stats;

/// Timing result for one benchmark case.
#[derive(Debug, Clone)]
pub struct Timing {
    pub name: String,
    pub samples_s: Vec<f64>,
}

impl Timing {
    pub fn mean_s(&self) -> f64 {
        stats::mean(&self.samples_s)
    }
    pub fn std_s(&self) -> f64 {
        stats::std(&self.samples_s)
    }
    pub fn p50_s(&self) -> f64 {
        stats::percentile(&self.samples_s, 50.0)
    }
    pub fn p95_s(&self) -> f64 {
        stats::percentile(&self.samples_s, 95.0)
    }
    pub fn summary(&self) -> String {
        format!(
            "{}: mean {:.4}s ± {:.4}s (p50 {:.4}s, p95 {:.4}s, n={})",
            self.name,
            self.mean_s(),
            self.std_s(),
            self.p50_s(),
            self.p95_s(),
            self.samples_s.len()
        )
    }
}

/// Time `f` with `warmup` unmeasured and `iters` measured runs.
pub fn time_case<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    Timing { name: name.to_string(), samples_s: samples }
}

/// Markdown table builder matching the paper's table shapes.
#[derive(Debug, Default, Clone)]
pub struct MdTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl MdTable {
    pub fn new(header: &[&str]) -> Self {
        Self { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    pub fn to_markdown(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "| {} |", self.header.join(" | "));
        let _ = writeln!(
            s,
            "|{}|",
            self.header.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        );
        for r in &self.rows {
            let _ = writeln!(s, "| {} |", r.join(" | "));
        }
        s
    }

    pub fn to_csv(&self) -> String {
        let esc = |c: &str| {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let mut s = String::new();
        let _ = writeln!(s, "{}", self.header.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        for r in &self.rows {
            let _ = writeln!(s, "{}", r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        }
        s
    }
}

/// A report file under results/: title, commentary, tables, csv series.
pub struct Report {
    slug: String,
    md: String,
    csvs: Vec<(String, String)>,
}

impl Report {
    pub fn new(slug: &str, title: &str) -> Self {
        Self { slug: slug.to_string(), md: format!("# {title}\n\n"), csvs: Vec::new() }
    }

    pub fn text(&mut self, t: &str) {
        self.md.push_str(t);
        self.md.push('\n');
    }

    pub fn table(&mut self, caption: &str, t: &MdTable) {
        let _ = writeln!(self.md, "\n**{caption}**\n\n{}", t.to_markdown());
    }

    pub fn csv(&mut self, name: &str, t: &MdTable) {
        self.csvs.push((name.to_string(), t.to_csv()));
    }

    /// Write results/<slug>.md (+ any csvs) and echo the report to stdout.
    pub fn finish(self) -> std::io::Result<()> {
        let dir = Path::new("results");
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join(format!("{}.md", self.slug)), &self.md)?;
        for (name, csv) in &self.csvs {
            std::fs::write(dir.join(format!("{}_{}.csv", self.slug, name)), csv)?;
        }
        println!("{}", self.md);
        println!("[benchkit] wrote results/{}.md", self.slug);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_measures_something() {
        let t = time_case("t", 1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(t.samples_s.len(), 5);
        assert!(t.mean_s() >= 0.0);
        assert!(t.p95_s() >= t.p50_s());
    }

    #[test]
    fn md_table_shape() {
        let mut t = MdTable::new(&["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let md = t.to_markdown();
        assert!(md.contains("| a | b |"));
        assert!(md.contains("| 1 | 2 |"));
        assert!(md.contains("|---|---|"));
    }

    #[test]
    #[should_panic]
    fn row_width_mismatch_panics() {
        let mut t = MdTable::new(&["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn csv_escapes_commas() {
        let mut t = MdTable::new(&["a"]);
        t.row(vec!["x,y".into()]);
        assert!(t.to_csv().contains("\"x,y\""));
    }
}
