//! Statistics helpers shared by metrics, benches and telemetry.

use crate::util::prng::Rng;

/// Online mean/variance accumulator (Welford).
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Sample standard deviation (n-1); 0 for n < 2.
    pub fn std(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }
}

/// Arithmetic mean; 0.0 (never NaN) on an empty slice, so stats surfaces
/// can serialize an idle reservoir without poisoning JSON consumers.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn std(xs: &[f64]) -> f64 {
    let mut w = Welford::new();
    for &x in xs {
        w.push(x);
    }
    w.std()
}

/// Linear-interpolated percentile, p in [0, 100].
///
/// Total on degenerate input — the telemetry surface calls this on live
/// reservoirs of any fill level: an empty slice yields 0.0 (never NaN),
/// and a `p` outside [0, 100] (or NaN) clamps to the nearest valid
/// percentile instead of indexing out of bounds.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let p = if p.is_nan() { 0.0 } else { p.clamp(0.0, 100.0) };
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    v[lo] * (1.0 - frac) + v[hi] * frac
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Mean and (mean-of-xs, std-of-xs) formatted like the paper's
/// "12.65 (± 0.06)" latency cells.
pub fn fmt_mean_pm_std(xs: &[f64]) -> String {
    format!("{:.2} (± {:.2})", mean(xs), std(xs))
}

/// Pearson correlation of two equal-length series.
pub fn pearson(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len() as f64;
    if a.is_empty() {
        return 0.0;
    }
    let ma = mean(a);
    let mb = mean(b);
    let mut num = 0.0;
    let mut da = 0.0;
    let mut db = 0.0;
    for i in 0..a.len() {
        let xa = a[i] - ma;
        let xb = b[i] - mb;
        num += xa * xb;
        da += xa * xa;
        db += xb * xb;
    }
    let _ = n;
    if da == 0.0 || db == 0.0 {
        0.0
    } else {
        num / (da.sqrt() * db.sqrt())
    }
}

/// Bounded sample store for long-running telemetry: keeps every value
/// exactly until `cap`, then switches to reservoir sampling (Vitter's
/// Algorithm R) so memory stays O(cap) under sustained traffic while the
/// kept set remains a uniform random sample of everything ever pushed —
/// percentiles computed over it stay meaningful for the whole run, not
/// just a recent window. Deterministically seeded (no clock, no OS
/// entropy), so telemetry never perturbs reproducibility tests.
#[derive(Debug, Clone)]
pub struct Reservoir {
    cap: usize,
    seen: u64,
    samples: Vec<f64>,
    rng: Rng,
}

impl Reservoir {
    pub fn new(cap: usize) -> Self {
        Self {
            cap: cap.max(1),
            seen: 0,
            samples: Vec::new(),
            rng: Rng::from_seed_and_label(0x5EED, "telemetry-reservoir"),
        }
    }

    pub fn push(&mut self, x: f64) {
        self.seen += 1;
        if self.samples.len() < self.cap {
            self.samples.push(x);
        } else {
            // Algorithm R: the i-th value replaces a kept sample with
            // probability cap/i, keeping the reservoir uniform.
            let j = (self.rng.next_u64() % self.seen) as usize;
            if j < self.cap {
                self.samples[j] = x;
            }
        }
    }

    /// Values currently held (≤ cap). Order is not meaningful.
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }

    /// Total values ever pushed (can exceed [`Reservoir::len`]).
    pub fn seen(&self) -> u64 {
        self.seen
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }
}

// --- dense vector helpers used on the hot path -----------------------------

/// Mean squared error between two equal-length f32 slices.
///
/// The difference is taken in f32 (matching the device-side `mse` fused
/// executable bit-for-bit), then squared and accumulated in f64 so this is
/// a rounding-stable reference the runtime property tests can compare the
/// device reduction against at 1e-6. Four independent f64 lanes break the
/// loop-carried dependency so the hot HotPath::Host measurement path still
/// autovectorises.
pub fn mse_f32(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    let mut lanes = [0.0f64; 4];
    let (a4, a_tail) = a.split_at(a.len() - a.len() % 4);
    let (b4, b_tail) = b.split_at(a4.len());
    for (ca, cb) in a4.chunks_exact(4).zip(b4.chunks_exact(4)) {
        for l in 0..4 {
            let d = (ca[l] - cb[l]) as f64;
            lanes[l] += d * d;
        }
    }
    let mut acc = (lanes[0] + lanes[1]) + (lanes[2] + lanes[3]);
    for (x, y) in a_tail.iter().zip(b_tail) {
        let d = (x - y) as f64;
        acc += d * d;
    }
    acc / a.len() as f64
}

/// Cosine similarity of two equal-length f32 slices (0 when either is 0).
pub fn cosine_f32(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut dot = 0.0f64;
    let mut na = 0.0f64;
    let mut nb = 0.0f64;
    for i in 0..a.len() {
        dot += (a[i] as f64) * (b[i] as f64);
        na += (a[i] as f64).powi(2);
        nb += (b[i] as f64).powi(2);
    }
    if na == 0.0 || nb == 0.0 {
        0.0
    } else {
        dot / (na.sqrt() * nb.sqrt())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.std() - std(&xs)).abs() < 1e-12);
        assert_eq!(w.count(), 5);
    }

    #[test]
    fn percentile_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(median(&xs), 3.0);
        assert_eq!(percentile(&xs, 25.0), 2.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 50.0) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn mse_zero_for_identical() {
        let a: Vec<f32> = (0..10_000).map(|i| (i as f32).sin()).collect();
        assert_eq!(mse_f32(&a, &a), 0.0);
    }

    #[test]
    fn mse_known_value() {
        let a = [0.0f32, 0.0, 0.0, 0.0];
        let b = [1.0f32, 1.0, 1.0, 1.0];
        assert!((mse_f32(&a, &b) - 1.0).abs() < 1e-12);
        let c = [2.0f32, 0.0, 0.0, 0.0];
        assert!((mse_f32(&a, &c) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn cosine_cases() {
        let a = [1.0f32, 0.0];
        let b = [0.0f32, 1.0];
        assert!(cosine_f32(&a, &a) > 0.999_999);
        assert!(cosine_f32(&a, &b).abs() < 1e-12);
        let c = [-1.0f32, 0.0];
        assert!((cosine_f32(&a, &c) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_perfect_correlation() {
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&a, &b) - 1.0).abs() < 1e-12);
        let c = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&a, &c) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn fmt_mean_pm_std_shape() {
        assert_eq!(fmt_mean_pm_std(&[1.0, 1.0, 1.0]), "1.00 (± 0.00)");
    }

    #[test]
    fn reservoir_exact_below_cap() {
        let mut r = Reservoir::new(8);
        for i in 0..5 {
            r.push(i as f64);
        }
        assert_eq!(r.len(), 5);
        assert_eq!(r.seen(), 5);
        assert_eq!(r.samples(), &[0.0, 1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn reservoir_caps_memory_and_counts_seen() {
        let mut r = Reservoir::new(16);
        for i in 0..10_000 {
            r.push(i as f64);
        }
        assert_eq!(r.len(), 16, "reservoir must never exceed its cap");
        assert_eq!(r.capacity(), 16);
        assert_eq!(r.seen(), 10_000);
        assert!(r.samples().iter().all(|&x| (0.0..10_000.0).contains(&x)));
    }

    #[test]
    fn reservoir_sample_is_roughly_uniform() {
        // Push 0..n uniformly; the kept sample's mean should approximate
        // the stream mean (n-1)/2 — Algorithm R keeps a uniform sample,
        // not a recency window.
        let mut r = Reservoir::new(512);
        let n = 50_000usize;
        for i in 0..n {
            r.push(i as f64);
        }
        let m = mean(r.samples());
        let expect = (n - 1) as f64 / 2.0;
        // stderr of a 512-sample mean over U[0, n) ≈ n/(sqrt(12*512)) ≈ 640
        assert!(
            (m - expect).abs() < 4_000.0,
            "reservoir mean {m} too far from stream mean {expect}"
        );
        // old values must still be represented (not a tail window)
        assert!(
            r.samples().iter().any(|&x| x < (n / 2) as f64),
            "reservoir degenerated into a recency window"
        );
    }

    #[test]
    fn empty_inputs_are_zero_never_nan() {
        let empty: [f64; 0] = [];
        assert_eq!(mean(&empty), 0.0);
        assert_eq!(std(&empty), 0.0);
        assert_eq!(percentile(&empty, 50.0), 0.0);
        assert_eq!(median(&empty), 0.0);
        let r = Reservoir::new(8);
        assert_eq!(mean(r.samples()), 0.0);
        assert_eq!(percentile(r.samples(), 99.0), 0.0);
    }

    #[test]
    fn percentile_clamps_out_of_range_p() {
        let xs = [1.0, 2.0, 3.0];
        assert_eq!(percentile(&xs, -50.0), 1.0);
        assert_eq!(percentile(&xs, 250.0), 3.0);
        assert_eq!(percentile(&xs, f64::NAN), 1.0);
    }

    #[test]
    fn single_sample_reservoir_stats_are_total() {
        let mut r = Reservoir::new(8);
        r.push(7.5);
        assert_eq!(mean(r.samples()), 7.5);
        assert_eq!(percentile(r.samples(), 0.0), 7.5);
        assert_eq!(percentile(r.samples(), 50.0), 7.5);
        assert_eq!(percentile(r.samples(), 100.0), 7.5);
    }

    #[test]
    fn post_overflow_reservoir_stats_stay_in_range() {
        // Past cap the reservoir subsamples; every derived stat must stay
        // finite and inside the pushed value range.
        let mut r = Reservoir::new(4);
        for i in 0..1_000 {
            r.push(i as f64);
        }
        assert_eq!(r.len(), 4);
        for p in [0.0, 50.0, 95.0, 100.0] {
            let v = percentile(r.samples(), p);
            assert!(v.is_finite() && (0.0..1_000.0).contains(&v), "p{p} = {v}");
        }
        let m = mean(r.samples());
        assert!(m.is_finite() && (0.0..1_000.0).contains(&m));
    }

    #[test]
    fn reservoir_zero_cap_clamps_to_one() {
        let mut r = Reservoir::new(0);
        r.push(1.0);
        r.push(2.0);
        assert_eq!(r.len(), 1);
        assert_eq!(r.seen(), 2);
    }
}
