//! Property-testing mini-framework (proptest substitute).
//!
//! Provides seeded case generation with bounded shrinking for the
//! coordinator-invariant properties DESIGN.md §7 calls out. Usage:
//!
//! ```ignore
//! proptest_cases(200, |g| {
//!     let n = g.usize_in(1..=8);
//!     let xs = g.vec_f32(n * 4, -2.0, 2.0);
//!     prop_assert(some_invariant(&xs), format!("violated for n={n}"));
//! });
//! ```
//!
//! On failure the harness re-runs the failing seed (reported in the panic
//! message) so failures are reproducible with `FORESIGHT_PROP_SEED`.

use super::prng::Rng;

/// Per-case generator handed to the property closure.
pub struct Gen {
    rng: Rng,
    /// Log of choices (used in the failure report).
    trace: Vec<String>,
}

impl Gen {
    fn new(seed: u64) -> Self {
        Self { rng: Rng::new(seed), trace: Vec::new() }
    }

    pub fn usize_in(&mut self, range: std::ops::RangeInclusive<usize>) -> usize {
        let (lo, hi) = (*range.start(), *range.end());
        let v = lo + self.rng.next_below(hi - lo + 1);
        self.trace.push(format!("usize={v}"));
        v
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        let v = lo + (hi - lo) * self.rng.next_f32();
        self.trace.push(format!("f32={v}"));
        v
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        let v = lo + (hi - lo) * self.rng.next_f64();
        self.trace.push(format!("f64={v}"));
        v
    }

    pub fn bool(&mut self) -> bool {
        let v = self.rng.next_u64() & 1 == 1;
        self.trace.push(format!("bool={v}"));
        v
    }

    pub fn vec_f32(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        self.trace.push(format!("vec_f32[{n}]"));
        self.rng.uniform_vec(n, lo, hi)
    }

    pub fn vec_normal(&mut self, n: usize) -> Vec<f32> {
        self.trace.push(format!("vec_normal[{n}]"));
        self.rng.normal_vec(n)
    }

    pub fn pick<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        let i = self.rng.next_below(items.len());
        self.trace.push(format!("pick#{i}"));
        &items[i]
    }

    /// Raw access for custom generators.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Outcome carried through the property closure via panic payloads.
#[derive(Debug)]
pub struct PropFailure(pub String);

/// Assert inside a property; failure message is attached to the case report.
pub fn prop_assert(cond: bool, msg: impl Into<String>) {
    if !cond {
        std::panic::panic_any(PropFailure(msg.into()));
    }
}

/// Two-sided approximate equality assertion for properties.
pub fn prop_assert_close(a: f64, b: f64, tol: f64, ctx: &str) {
    prop_assert(
        (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())),
        format!("{ctx}: {a} !~ {b} (tol {tol})"),
    );
}

/// Run `cases` random cases of `prop`. Panics with the failing seed and the
/// generator trace on first failure.
pub fn proptest_cases<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(cases: u64, prop: F) {
    let base_seed = std::env::var("FORESIGHT_PROP_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(0x5EED_F0E5);
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case);
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen::new(seed);
            prop(&mut g);
            g.trace
        });
        if let Err(payload) = result {
            let msg = if let Some(f) = payload.downcast_ref::<PropFailure>() {
                f.0.clone()
            } else if let Some(s) = payload.downcast_ref::<String>() {
                s.clone()
            } else if let Some(s) = payload.downcast_ref::<&str>() {
                s.to_string()
            } else {
                "<non-string panic>".to_string()
            };
            panic!(
                "property failed on case {case} (seed {seed}): {msg}\n\
                 reproduce with FORESIGHT_PROP_SEED={seed} and 1 case"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        proptest_cases(50, |g| {
            let n = g.usize_in(1..=16);
            let xs = g.vec_f32(n, -1.0, 1.0);
            prop_assert(xs.len() == n, "length mismatch");
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports_seed() {
        proptest_cases(50, |g| {
            let v = g.f32_in(0.0, 1.0);
            prop_assert(v < 0.9, format!("v={v}"));
        });
    }

    #[test]
    fn generators_in_bounds() {
        proptest_cases(100, |g| {
            let u = g.usize_in(3..=7);
            prop_assert((3..=7).contains(&u), format!("u={u}"));
            let f = g.f32_in(-2.0, -1.0);
            prop_assert((-2.0..-1.0).contains(&f), format!("f={f}"));
            let p = *g.pick(&[1, 2, 3]);
            prop_assert([1, 2, 3].contains(&p), format!("p={p}"));
        });
    }

    #[test]
    fn close_assertion() {
        prop_assert_close(1.0, 1.0 + 1e-12, 1e-9, "ok");
    }
}
