//! Fixed-size worker thread pool (tokio/rayon substitute).
//!
//! Used by benches that fan out independent generations. Jobs are boxed
//! closures delivered over an mpsc channel guarded by a mutex
//! (multi-consumer); `scope`-style joining is provided by
//! [`ThreadPool::run_all`]. All three internal locks are
//! [`OrderedMutex`]es (ranks `POOL_QUEUE` < `POOL_IN_FLIGHT` <
//! `POOL_SLOTS`), so the debug-build checker verifies the pool never
//! nests them out of order even though no pair is ever meant to nest.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar};
use std::thread::JoinHandle;

use crate::util::sync::{OrderedMutex, RANK_POOL_IN_FLIGHT, RANK_POOL_QUEUE, RANK_POOL_SLOTS};

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed pool of worker threads consuming jobs from a shared queue.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    in_flight: Arc<(OrderedMutex<usize>, Condvar)>,
    submitted: AtomicUsize,
}

impl ThreadPool {
    /// Spawn `n` workers (n >= 1).
    pub fn new(n: usize) -> Self {
        assert!(n >= 1, "thread pool needs at least one worker");
        let (tx, queue) = mpsc::channel::<Job>();
        let rx = Arc::new(OrderedMutex::new("pool.queue", RANK_POOL_QUEUE, queue));
        let in_flight = Arc::new((
            OrderedMutex::new("pool.in_flight", RANK_POOL_IN_FLIGHT, 0usize),
            Condvar::new(),
        ));
        let mut workers = Vec::with_capacity(n);
        for i in 0..n {
            let rx = Arc::clone(&rx);
            let inf = Arc::clone(&in_flight);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("foresight-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                job();
                                let (in_flight, cv) = &*inf;
                                let mut cnt = in_flight.lock();
                                *cnt = cnt.saturating_sub(1);
                                cv.notify_all();
                            }
                            Err(_) => return, // channel closed: shut down
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        Self { tx: Some(tx), workers, in_flight, submitted: AtomicUsize::new(0) }
    }

    /// Submit a job; returns immediately.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        {
            let (in_flight, _) = &*self.in_flight;
            *in_flight.lock() += 1;
        }
        self.submitted.fetch_add(1, Ordering::Relaxed);
        self.tx
            .as_ref()
            .expect("pool already shut down")
            .send(Box::new(f))
            .expect("worker channel closed");
    }

    /// Block until every submitted job has finished.
    pub fn wait_idle(&self) {
        let (in_flight, cv) = &*self.in_flight;
        let mut cnt = in_flight.lock();
        while *cnt > 0 {
            cnt = cnt.wait(cv);
        }
    }

    /// Total jobs ever submitted (telemetry).
    pub fn submitted(&self) -> usize {
        self.submitted.load(Ordering::Relaxed)
    }

    /// Run a batch of closures to completion, returning results in order.
    pub fn run_all<T, F>(&self, jobs: Vec<F>) -> Vec<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let n = jobs.len();
        let slots: Arc<OrderedMutex<Vec<Option<T>>>> = Arc::new(OrderedMutex::new(
            "pool.slots",
            RANK_POOL_SLOTS,
            (0..n).map(|_| None).collect(),
        ));
        for (i, job) in jobs.into_iter().enumerate() {
            let slots = Arc::clone(&slots);
            self.submit(move || {
                let r = job();
                slots.lock()[i] = Some(r);
            });
        }
        self.wait_idle();
        Arc::try_unwrap(slots)
            .unwrap_or_else(|_| panic!("slots still shared"))
            .into_inner()
            .into_iter()
            .map(|o| o.expect("job did not run"))
            .collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close channel -> workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
        assert_eq!(pool.submitted(), 100);
    }

    #[test]
    fn run_all_preserves_order() {
        let pool = ThreadPool::new(3);
        let jobs: Vec<_> = (0..20).map(|i| move || i * i).collect();
        let out = pool.run_all(jobs);
        assert_eq!(out, (0..20).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn wait_idle_on_empty_pool_returns() {
        let pool = ThreadPool::new(1);
        pool.wait_idle(); // must not hang
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        let c = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&c);
            pool.submit(move || {
                std::thread::sleep(std::time::Duration::from_millis(1));
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool);
        assert_eq!(c.load(Ordering::SeqCst), 10);
    }
}
