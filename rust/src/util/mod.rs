//! In-tree substrates (crates.io is unreachable in this environment).
//!
//! | module | replaces |
//! |---|---|
//! | [`json`] | serde_json |
//! | [`cli`] | clap |
//! | [`prng`] | rand |
//! | [`npy`] | ndarray-npy |
//! | [`stats`] | statrs bits used by metrics/benches |
//! | [`threadpool`] | rayon/tokio worker pools |
//! | [`benchkit`] | criterion |
//! | [`proptest`] | proptest |
//! | [`loadgen`] | locust/vegeta-style open-loop load generation |
//! | [`sync`] | parking_lot-style ranked/poison-tolerant mutexes |

pub mod benchkit;
pub mod cli;
pub mod json;
pub mod loadgen;
pub mod npy;
pub mod prng;
pub mod proptest;
pub mod stats;
pub mod sync;
pub mod threadpool;
