//! Ranked, poison-tolerant mutexes — the runtime half of the lock-order
//! discipline that `analysis::lint` checks statically.
//!
//! Every long-lived mutex in the serving stack is an [`OrderedMutex`]
//! carrying a name and a rank from the canonical order below. In debug
//! builds a thread-local checker panics the moment any thread acquires a
//! lock whose rank is not strictly greater than the highest rank it
//! already holds — turning a potential deadlock (which would hang CI) into
//! an immediate, attributed failure at the exact acquisition site. Release
//! builds compile the checker away; the wrapper then costs nothing beyond
//! the poison-tolerant `lock()`.
//!
//! # Canonical lock order
//!
//! Locks must be acquired in strictly increasing rank. The static
//! `analysis::lint` lock-order pass enforces the same table by field name.
//!
//! | rank | const                       | lock                                   |
//! |------|-----------------------------|----------------------------------------|
//! | 10   | [`RANK_ROUTER_STATE`]       | `server::scheduler::Router::state`     |
//! | 20   | [`RANK_POOL_QUEUE`]         | `util::threadpool` job receiver        |
//! | 30   | [`RANK_POOL_IN_FLIGHT`]     | `util::threadpool` in-flight counter   |
//! | 40   | [`RANK_RUNTIME_EXEC_CACHE`] | `runtime::Runtime::cache`              |
//! | 41   | [`RANK_RUNTIME_FUSED_CACHE`]| `runtime::Runtime::fused`              |
//! | 50   | [`RANK_TELEMETRY_LATENCY`]  | `server::Telemetry::latencies_s`       |
//! | 51   | [`RANK_TELEMETRY_QUEUE`]    | `server::Telemetry::queue_s`           |
//! | 52   | [`RANK_TELEMETRY_OCCUPANCY`]| `server::Telemetry::occupancy`         |
//! | 53   | [`RANK_DEVICE_OCCUPANCY`]   | `server::DeviceTelemetry::occupancy`   |
//! | 60   | [`RANK_POOL_SLOTS`]         | `util::threadpool::run_all` slots      |
//! | 70   | [`RANK_TRACE_RING`]         | `trace::Tracer` ring shards            |
//!
//! Gaps are deliberate: a new lock slots in without renumbering. When you
//! add one, give it a rank consistent with every existing nesting, add a
//! row here, and teach `analysis::lint::locks` its field name.
//!
//! # Poison policy
//!
//! A panicking thread must not take telemetry (or any other shared state)
//! down with it: `lock()`, the condvar waits and `into_inner()` all
//! recover the value from a poisoned mutex via `PoisonError::into_inner`.
//! Counters and reservoirs are monotonic aggregates, so the worst case is
//! one lost update from the thread that died — never a wedged `stats` op.
//!
//! # Condvar protocol
//!
//! `std::sync::Condvar` needs the raw `MutexGuard`, so [`OrderedGuard`]
//! exposes [`OrderedGuard::wait`] / [`OrderedGuard::wait_timeout`]: the
//! inner guard is lent to the condvar and re-wrapped on wake. The rank
//! stays registered across the wait — the blocked thread still conceptually
//! holds its slot in the order, and it re-acquires the same mutex before
//! continuing.

use std::ops::{Deref, DerefMut};
use std::sync::{Condvar, Mutex, MutexGuard, PoisonError, TryLockError};
use std::time::Duration;

/// `server::scheduler::Router::state` — queues + device table.
pub const RANK_ROUTER_STATE: u32 = 10;
/// `util::threadpool` shared job receiver.
pub const RANK_POOL_QUEUE: u32 = 20;
/// `util::threadpool` in-flight job counter (condvar-paired).
pub const RANK_POOL_IN_FLIGHT: u32 = 30;
/// `runtime::Runtime::cache` — HLO-path → executable.
pub const RANK_RUNTIME_EXEC_CACHE: u32 = 40;
/// `runtime::Runtime::fused` — (builder key, shape) → executable.
pub const RANK_RUNTIME_FUSED_CACHE: u32 = 41;
/// `server::Telemetry::latencies_s` reservoir.
pub const RANK_TELEMETRY_LATENCY: u32 = 50;
/// `server::Telemetry::queue_s` reservoir.
pub const RANK_TELEMETRY_QUEUE: u32 = 51;
/// `server::Telemetry::occupancy` reservoir.
pub const RANK_TELEMETRY_OCCUPANCY: u32 = 52;
/// `server::DeviceTelemetry::occupancy` reservoirs (one per device).
pub const RANK_DEVICE_OCCUPANCY: u32 = 53;
/// `util::threadpool::run_all` result slots.
pub const RANK_POOL_SLOTS: u32 = 60;
/// `trace::Tracer` event-ring shards. Highest rank on purpose: events are
/// emitted from under any other lock in the system, so the ring must nest
/// inside everything (and `trace` only ever takes it via `try_lock`, which
/// cannot block regardless).
pub const RANK_TRACE_RING: u32 = 70;

/// A named, ranked, poison-tolerant mutex. See the module docs for the
/// canonical rank table and the debug-build acquisition checker.
pub struct OrderedMutex<T> {
    name: &'static str,
    rank: u32,
    inner: Mutex<T>,
}

impl<T> OrderedMutex<T> {
    pub const fn new(name: &'static str, rank: u32, value: T) -> Self {
        Self { name, rank, inner: Mutex::new(value) }
    }

    /// Acquire the lock, recovering from poison. In debug builds, panics
    /// if this thread already holds a lock of equal or higher rank.
    pub fn lock(&self) -> OrderedGuard<'_, T> {
        #[cfg(debug_assertions)]
        checker::acquire(self.rank, self.name);
        let guard = self.inner.lock().unwrap_or_else(PoisonError::into_inner);
        OrderedGuard { lock: self, guard: Some(guard) }
    }

    /// Try to acquire the lock without blocking. Returns `None` when the
    /// mutex is currently held by another thread; recovers from poison like
    /// [`OrderedMutex::lock`]. The rank checker registers the acquisition
    /// only on success, so a failed try leaves the thread's held-lock stack
    /// untouched. This is the emission primitive for `trace`: contention
    /// means "drop the event", never "stall the hot path".
    pub fn try_lock(&self) -> Option<OrderedGuard<'_, T>> {
        let guard = match self.inner.try_lock() {
            Ok(g) => g,
            Err(TryLockError::Poisoned(p)) => p.into_inner(),
            Err(TryLockError::WouldBlock) => return None,
        };
        #[cfg(debug_assertions)]
        checker::acquire(self.rank, self.name);
        Some(OrderedGuard { lock: self, guard: Some(guard) })
    }

    /// Consume the mutex, recovering the value even if poisoned.
    pub fn into_inner(self) -> T {
        self.inner.into_inner().unwrap_or_else(PoisonError::into_inner)
    }

    pub fn name(&self) -> &'static str {
        self.name
    }

    pub fn rank(&self) -> u32 {
        self.rank
    }
}

impl<T: std::fmt::Debug> std::fmt::Debug for OrderedMutex<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("OrderedMutex")
            .field("name", &self.name)
            .field("rank", &self.rank)
            .field("inner", &self.inner)
            .finish()
    }
}

/// Guard for an [`OrderedMutex`]. Dropping it releases the mutex and
/// unregisters the rank from the thread's held-lock stack.
///
/// The inner guard lives in an `Option` solely so the condvar waits can
/// lend it to `std::sync::Condvar` and re-wrap the returned guard; it is
/// `Some` at every point user code can observe.
pub struct OrderedGuard<'a, T> {
    lock: &'a OrderedMutex<T>,
    guard: Option<MutexGuard<'a, T>>,
}

impl<'a, T> OrderedGuard<'a, T> {
    /// Block on `cv` until notified, releasing and re-acquiring the mutex
    /// like `Condvar::wait`. Poison during the wait is recovered; the rank
    /// stays registered (the thread re-holds the same lock on wake).
    pub fn wait(mut self, cv: &Condvar) -> OrderedGuard<'a, T> {
        if let Some(inner) = self.guard.take() {
            let inner = cv.wait(inner).unwrap_or_else(PoisonError::into_inner);
            self.guard = Some(inner);
        }
        self
    }

    /// Like [`OrderedGuard::wait`] with a timeout; the `bool` is true when
    /// the wait timed out rather than being notified.
    pub fn wait_timeout(mut self, cv: &Condvar, dur: Duration) -> (OrderedGuard<'a, T>, bool) {
        let mut timed_out = false;
        if let Some(inner) = self.guard.take() {
            let (inner, res) =
                cv.wait_timeout(inner, dur).unwrap_or_else(PoisonError::into_inner);
            timed_out = res.timed_out();
            self.guard = Some(inner);
        }
        (self, timed_out)
    }
}

impl<T> Deref for OrderedGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        match self.guard.as_deref() {
            Some(v) => v,
            None => unreachable!("guard lent to a condvar outside wait()"),
        }
    }
}

impl<T> DerefMut for OrderedGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        match self.guard.as_deref_mut() {
            Some(v) => v,
            None => unreachable!("guard lent to a condvar outside wait()"),
        }
    }
}

impl<T> Drop for OrderedGuard<'_, T> {
    fn drop(&mut self) {
        #[cfg(debug_assertions)]
        checker::release(self.lock.rank);
        #[cfg(not(debug_assertions))]
        let _ = self.lock;
    }
}

/// Debug-build acquisition checker: a thread-local stack of held ranks.
/// Pushes are strictly increasing, so the stack stays sorted and its last
/// element is the highest rank this thread holds; releases may happen in
/// any order (guards are droppable out of LIFO), so release removes the
/// topmost entry with the matching rank.
#[cfg(debug_assertions)]
mod checker {
    use std::cell::RefCell;

    thread_local! {
        static HELD: RefCell<Vec<(u32, &'static str)>> = const { RefCell::new(Vec::new()) };
    }

    pub fn acquire(rank: u32, name: &'static str) {
        HELD.with(|held| {
            let mut held = held.borrow_mut();
            if let Some(&(top_rank, top_name)) = held.last() {
                if rank <= top_rank {
                    panic!(
                        "lock-order violation: acquiring `{name}` (rank {rank}) while \
                         holding `{top_name}` (rank {top_rank}); ranks must strictly \
                         increase — see util::sync rank table"
                    );
                }
            }
            held.push((rank, name));
        });
    }

    pub fn release(rank: u32) {
        HELD.with(|held| {
            let mut held = held.borrow_mut();
            if let Some(i) = held.iter().rposition(|&(r, _)| r == rank) {
                held.remove(i);
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn ascending_acquisition_is_clean() {
        let low = OrderedMutex::new("test.low", 10, 1u32);
        let high = OrderedMutex::new("test.high", 50, 2u32);
        let a = low.lock();
        let b = high.lock();
        assert_eq!(*a + *b, 3);
        drop(a); // non-LIFO release must be fine
        assert_eq!(*b, 2);
        drop(b);
        // Re-acquiring after a full release starts a fresh ordering.
        let b = high.lock();
        drop(b);
        let a = low.lock();
        assert_eq!(*a, 1);
    }

    #[test]
    #[cfg(debug_assertions)]
    fn reverse_rank_acquisition_trips_checker() {
        let low = Arc::new(OrderedMutex::new("test.rev-low", 10, 0u32));
        let high = Arc::new(OrderedMutex::new("test.rev-high", 50, 0u32));

        // One thread takes the canonical order and is untouched; the other
        // takes the reverse order and must panic at the second acquire —
        // before it can deadlock anything.
        let (l0, h0) = (Arc::clone(&low), Arc::clone(&high));
        let ok = std::thread::spawn(move || {
            let mut a = l0.lock();
            *a += 1;
            let _b = h0.lock();
        });
        let (l1, h1) = (Arc::clone(&low), Arc::clone(&high));
        let bad = std::thread::spawn(move || {
            let _b = h1.lock();
            let _a = l1.lock(); // rank 10 while holding 50: boom
        });

        assert!(ok.join().is_ok());
        let err = match bad.join() {
            Err(e) => e,
            Ok(()) => panic!("reverse-rank acquisition did not trip the checker"),
        };
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_default();
        assert!(msg.contains("lock-order violation"), "unexpected panic: {msg}");

        // The panicked thread poisoned `high`; lock() must recover.
        assert_eq!(*high.lock(), 0);
        assert_eq!(*low.lock(), 1);
    }

    #[test]
    #[cfg(debug_assertions)]
    fn equal_rank_nesting_trips_checker() {
        let a = Arc::new(OrderedMutex::new("test.eq-a", 50, ()));
        let b = Arc::new(OrderedMutex::new("test.eq-b", 50, ()));
        let (a0, b0) = (Arc::clone(&a), Arc::clone(&b));
        let t = std::thread::spawn(move || {
            let _g = a0.lock();
            let _h = b0.lock();
        });
        assert!(t.join().is_err(), "equal-rank nesting must trip the checker");
    }

    #[test]
    fn try_lock_never_blocks_and_recovers_poison() {
        let m = Arc::new(OrderedMutex::new("test.try", 70, 5u32));
        // Uncontended: succeeds and the guard derefs.
        {
            let g = m.try_lock().expect("uncontended try_lock must succeed");
            assert_eq!(*g, 5);
            // Held: a second try on the same mutex from another thread fails
            // fast instead of blocking.
            let m2 = Arc::clone(&m);
            let t = std::thread::spawn(move || m2.try_lock().is_none());
            assert!(t.join().unwrap(), "contended try_lock must return None");
        }
        // Poisoned: recovers the value like lock().
        let m2 = Arc::clone(&m);
        let t = std::thread::spawn(move || {
            let _g = m2.try_lock().unwrap();
            panic!("die holding the lock");
        });
        assert!(t.join().is_err());
        assert_eq!(*m.try_lock().expect("poisoned try_lock must recover"), 5);
    }

    #[test]
    fn poisoned_lock_recovers_value() {
        let m = Arc::new(OrderedMutex::new("test.poison", 50, vec![1, 2, 3]));
        let m2 = Arc::clone(&m);
        let t = std::thread::spawn(move || {
            let mut g = m2.lock();
            g.push(4);
            panic!("die holding the lock");
        });
        assert!(t.join().is_err());
        // The panicking thread's completed update survives; the lock serves.
        assert_eq!(m.lock().len(), 4);
        let m = match Arc::try_unwrap(m) {
            Ok(m) => m,
            Err(_) => return, // other handle leaked; nothing left to check
        };
        assert_eq!(m.into_inner(), vec![1, 2, 3, 4]);
    }

    #[test]
    fn condvar_wait_roundtrip() {
        let pair = Arc::new((OrderedMutex::new("test.cv", 30, 0usize), Condvar::new()));
        let p2 = Arc::clone(&pair);
        let t = std::thread::spawn(move || {
            let (m, cv) = &*p2;
            let mut g = m.lock();
            *g = 7;
            cv.notify_all();
            drop(g);
        });
        let (m, cv) = &*pair;
        let mut g = m.lock();
        while *g != 7 {
            // A timeout just means the writer has not run yet; keep
            // waiting — the join below bounds the test.
            let (g2, _timed_out) = g.wait_timeout(cv, Duration::from_millis(200));
            g = g2;
        }
        assert_eq!(*g, 7);
        drop(g);
        assert!(t.join().is_ok());
    }
}
