//! Deterministic pseudo-random number generation (rand-crate substitute).
//!
//! SplitMix64 for seeding/stream-splitting and xoshiro256++ as the main
//! generator, plus Box-Muller normal sampling. Every stochastic input in the
//! system (initial latents, prompt embeddings, projection features in the
//! metric proxies) flows through this module, so runs are bit-reproducible
//! given a seed — a property the integration tests assert.

/// SplitMix64: used to expand a 64-bit seed into generator state and to
/// derive independent named streams.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — fast, high-quality 64-bit generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second output of Box-Muller.
    spare_normal: Option<f32>,
}

impl Rng {
    /// Seed via SplitMix64 expansion (never all-zero state).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            spare_normal: None,
        }
    }

    /// Derive an independent stream from a string label (e.g. per-prompt,
    /// per-metric feature bank) — stable across runs and platforms.
    pub fn from_seed_and_label(seed: u64, label: &str) -> Self {
        let mut h = 0xcbf29ce484222325u64; // FNV-1a
        for b in label.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        Self::new(seed ^ h)
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        // 24 high bits -> exactly representable uniform
        (self.next_u64() >> 40) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [0, 1) with f64 resolution.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn next_below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller (pair-cached).
    pub fn next_normal(&mut self) -> f32 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.next_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare_normal = Some((r * theta.sin()) as f32);
            return (r * theta.cos()) as f32;
        }
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.next_normal()).collect()
    }

    /// Vector of uniforms in [lo, hi).
    pub fn uniform_vec(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| lo + (hi - lo) * self.next_f32()).collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn labeled_streams_are_independent() {
        let mut a = Rng::from_seed_and_label(9, "latents");
        let mut b = Rng::from_seed_and_label(9, "prompt");
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Rng::new(3);
        let xs = r.uniform_vec(20_000, 0.0, 1.0);
        let mean: f32 = xs.iter().sum::<f32>() / xs.len() as f32;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(4);
        let xs = r.normal_vec(50_000);
        let n = xs.len() as f64;
        let mean = xs.iter().map(|&x| x as f64).sum::<f64>() / n;
        let var = xs.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
