//! NumPy `.npy` (format v1.0/v2.0) reader for the exported model weights.
//!
//! aot.py dumps every parameter with `np.save` (little-endian f32, C order);
//! this module parses the header dict and returns shape + data. Only the
//! dtypes the exporter produces are supported — anything else is a hard
//! error rather than a silent misread.

use anyhow::{bail, Context, Result};
use std::io::Read;
use std::path::Path;

/// A host-side f32 tensor loaded from a .npy file.
#[derive(Debug, Clone)]
pub struct NpyArray {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl NpyArray {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Parse the Python-literal header dict, e.g.
/// `{'descr': '<f4', 'fortran_order': False, 'shape': (96, 576), }`.
fn parse_header(h: &str) -> Result<(String, bool, Vec<usize>)> {
    let descr = extract_str_field(h, "descr").context("npy header: descr")?;
    let fortran = h
        .split("'fortran_order'")
        .nth(1)
        .map(|rest| rest.trim_start_matches([':', ' ']).starts_with("True"))
        .context("npy header: fortran_order")?;
    let shape_src = h
        .split("'shape'")
        .nth(1)
        .and_then(|rest| {
            let open = rest.find('(')?;
            let close = rest[open..].find(')')? + open;
            Some(&rest[open + 1..close])
        })
        .context("npy header: shape")?;
    let mut shape = Vec::new();
    for part in shape_src.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue; // trailing comma of 1-tuples / scalar ()
        }
        shape.push(part.parse::<usize>().context("npy header: shape dim")?);
    }
    Ok((descr, fortran, shape))
}

fn extract_str_field(h: &str, key: &str) -> Option<String> {
    let rest = h.split(&format!("'{key}'")).nth(1)?;
    let rest = rest.trim_start_matches([':', ' ']);
    let rest = rest.strip_prefix('\'')?;
    Some(rest[..rest.find('\'')?].to_string())
}

/// Load a .npy file containing little-endian f32 (or f8/i8-free) data.
pub fn load(path: &Path) -> Result<NpyArray> {
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic).context("npy magic")?;
    if &magic[..6] != b"\x93NUMPY" {
        bail!("{}: not a .npy file", path.display());
    }
    let major = magic[6];
    let header_len = match major {
        1 => {
            let mut b = [0u8; 2];
            f.read_exact(&mut b)?;
            u16::from_le_bytes(b) as usize
        }
        2 => {
            let mut b = [0u8; 4];
            f.read_exact(&mut b)?;
            u32::from_le_bytes(b) as usize
        }
        v => bail!("{}: unsupported npy version {v}", path.display()),
    };
    let mut header = vec![0u8; header_len];
    f.read_exact(&mut header)?;
    let header = String::from_utf8(header).context("npy header utf-8")?;
    let (descr, fortran, shape) = parse_header(&header)?;
    if fortran {
        bail!("{}: fortran_order not supported", path.display());
    }
    if descr != "<f4" {
        bail!("{}: dtype {descr} unsupported (expected <f4)", path.display());
    }
    let count: usize = shape.iter().product();
    let mut raw = vec![0u8; count * 4];
    f.read_exact(&mut raw)
        .with_context(|| format!("{}: payload", path.display()))?;
    let data = raw
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Ok(NpyArray { shape, data })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_npy(path: &Path, shape: &[usize], data: &[f32]) {
        // Emit exactly what np.save v1.0 produces.
        let shape_str = match shape.len() {
            0 => "()".to_string(),
            1 => format!("({},)", shape[0]),
            _ => format!(
                "({})",
                shape.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(", ")
            ),
        };
        let mut header = format!(
            "{{'descr': '<f4', 'fortran_order': False, 'shape': {shape_str}, }}"
        );
        let unpadded = 10 + header.len() + 1;
        let pad = (64 - unpadded % 64) % 64;
        header.push_str(&" ".repeat(pad));
        header.push('\n');
        let mut f = std::fs::File::create(path).unwrap();
        f.write_all(b"\x93NUMPY\x01\x00").unwrap();
        f.write_all(&(header.len() as u16).to_le_bytes()).unwrap();
        f.write_all(header.as_bytes()).unwrap();
        for v in data {
            f.write_all(&v.to_le_bytes()).unwrap();
        }
    }

    #[test]
    fn roundtrip_matrix() {
        let dir = std::env::temp_dir().join("foresight_npy_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("m.npy");
        let data: Vec<f32> = (0..12).map(|i| i as f32 * 0.5).collect();
        write_npy(&p, &[3, 4], &data);
        let arr = load(&p).unwrap();
        assert_eq!(arr.shape, vec![3, 4]);
        assert_eq!(arr.data, data);
    }

    #[test]
    fn roundtrip_vector_and_scalar() {
        let dir = std::env::temp_dir().join("foresight_npy_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("v.npy");
        write_npy(&p, &[5], &[1.0, 2.0, 3.0, 4.0, 5.0]);
        let arr = load(&p).unwrap();
        assert_eq!(arr.shape, vec![5]);
        assert_eq!(arr.element_count(), 5);

        let p2 = dir.join("s.npy");
        write_npy(&p2, &[], &[42.0]);
        let arr = load(&p2).unwrap();
        assert!(arr.shape.is_empty());
        assert_eq!(arr.data, vec![42.0]);
    }

    #[test]
    fn rejects_non_npy() {
        let dir = std::env::temp_dir().join("foresight_npy_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.npy");
        std::fs::write(&p, b"not numpy at all").unwrap();
        assert!(load(&p).is_err());
    }
}
