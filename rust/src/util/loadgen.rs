//! Trace-driven open-loop load generation (the fig22 overload harness).
//!
//! Production traffic is bursty, diurnal and adversarial — a Poisson-ish
//! steady drip (fig20/fig21) never exercises admission control. This
//! module generates deterministic arrival traces with the shapes that
//! break servers — sustained bursts, ramps past capacity, flash crowds —
//! and replays them **open-loop**: every arrival fires at its scheduled
//! time whether or not earlier requests have completed, so a slow server
//! faces growing concurrency exactly as it would behind real clients,
//! instead of the closed-loop self-throttling a simple request loop
//! produces.
//!
//! Traces are pure data (`Vec<Arrival>`), generated from a seed via
//! [`crate::util::prng::Rng`] — the same trace replays identically across
//! runs and machines. Rates are shaped by a time-varying rate function
//! sampled with exponential inter-arrival gaps (a piecewise approximation
//! of a nonhomogeneous Poisson process; exact enough for a load harness).
//! `class` tags each arrival with a caller-defined request class index
//! (fig22 maps classes to resolution buckets for mixed-bucket traffic).

use std::time::{Duration, Instant};

use crate::util::prng::Rng;

/// One scheduled request: fire at `at_s` seconds after trace start, using
/// the caller's request template `class` (an index the generator fills
/// uniformly; callers map it to buckets/models/policies).
#[derive(Debug, Clone, PartialEq)]
pub struct Arrival {
    pub at_s: f64,
    pub class: usize,
}

/// Arrivals over `[0, duration_s)` following `rate(t)` requests/second,
/// sampled with exponential gaps at the rate in force when each gap
/// starts. Deterministic in `(seed, label)`.
pub fn rate_trace(
    seed: u64,
    label: &str,
    duration_s: f64,
    classes: usize,
    rate: impl Fn(f64) -> f64,
) -> Vec<Arrival> {
    let mut rng = Rng::from_seed_and_label(seed, label);
    let mut out = Vec::new();
    let mut t = 0.0f64;
    loop {
        let r = rate(t).max(1e-9);
        // u ∈ [0,1): clamp away from 0 so ln never produces inf.
        let u = rng.next_f64().max(1e-12);
        t += -u.ln() / r;
        if !(t < duration_s) {
            break;
        }
        out.push(Arrival { at_s: t, class: rng.next_below(classes.max(1)) });
    }
    out
}

/// Square-wave bursts: `calm_rps` for the first half of every `period_s`,
/// `burst_rps` for the second half.
pub fn bursty(
    seed: u64,
    duration_s: f64,
    calm_rps: f64,
    burst_rps: f64,
    period_s: f64,
    classes: usize,
) -> Vec<Arrival> {
    let period = period_s.max(1e-6);
    rate_trace(seed, "loadgen-bursty", duration_s, classes, move |t| {
        if (t % period) < period / 2.0 {
            calm_rps
        } else {
            burst_rps
        }
    })
}

/// Linear ramp from `start_rps` to `end_rps` over the trace — the
/// capacity-crossing shape (starts under capacity, ends past it).
pub fn ramp(
    seed: u64,
    duration_s: f64,
    start_rps: f64,
    end_rps: f64,
    classes: usize,
) -> Vec<Arrival> {
    let dur = duration_s.max(1e-6);
    rate_trace(seed, "loadgen-ramp", duration_s, classes, move |t| {
        start_rps + (end_rps - start_rps) * (t / dur).clamp(0.0, 1.0)
    })
}

/// Calm baseline with one rectangular spike: `spike_rps` during
/// `[spike_at_s, spike_at_s + spike_len_s)`, `calm_rps` elsewhere.
pub fn flash_crowd(
    seed: u64,
    duration_s: f64,
    calm_rps: f64,
    spike_at_s: f64,
    spike_len_s: f64,
    spike_rps: f64,
    classes: usize,
) -> Vec<Arrival> {
    rate_trace(seed, "loadgen-flash", duration_s, classes, move |t| {
        if t >= spike_at_s && t < spike_at_s + spike_len_s {
            spike_rps
        } else {
            calm_rps
        }
    })
}

/// Merge several traces into one, ordered by arrival time (ties broken by
/// class then input order, so the result is deterministic).
pub fn merge(traces: &[Vec<Arrival>]) -> Vec<Arrival> {
    let mut all: Vec<Arrival> = traces.iter().flatten().cloned().collect();
    all.sort_by(|a, b| {
        a.at_s
            .partial_cmp(&b.at_s)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.class.cmp(&b.class))
    });
    all
}

/// Replay a trace open-loop against `f`: arrival `i` fires on its own
/// thread at `trace[i].at_s` (measured from the call), regardless of
/// whether earlier requests have returned — queueing shows up at the
/// server, not in the generator. Returns each arrival's result in trace
/// order. One thread per arrival: fine at harness scale (tens to a few
/// hundred arrivals); not a general-purpose client pool.
pub fn replay<T, F>(trace: &[Arrival], f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, &Arrival) -> T + Sync,
{
    let start = Instant::now();
    let f = &f;
    let mut results: Vec<Option<T>> = trace.iter().map(|_| None).collect();
    std::thread::scope(|s| {
        let handles: Vec<_> = trace
            .iter()
            .enumerate()
            .map(|(i, a)| {
                s.spawn(move || {
                    let target = Duration::from_secs_f64(a.at_s.max(0.0));
                    let elapsed = start.elapsed();
                    if target > elapsed {
                        std::thread::sleep(target - elapsed);
                    }
                    (i, f(i, a))
                })
            })
            .collect();
        for h in handles {
            let (i, r) = h.join().expect("replay client panicked");
            results[i] = Some(r);
        }
    });
    results.into_iter().map(|r| r.expect("all slots filled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn traces_are_deterministic_in_seed() {
        let a = bursty(7, 10.0, 2.0, 20.0, 2.0, 3);
        let b = bursty(7, 10.0, 2.0, 20.0, 2.0, 3);
        let c = bursty(8, 10.0, 2.0, 20.0, 2.0, 3);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert!(!a.is_empty());
    }

    #[test]
    fn arrivals_are_ordered_and_in_range() {
        for trace in [
            bursty(1, 8.0, 1.0, 30.0, 2.0, 2),
            ramp(2, 8.0, 1.0, 40.0, 2),
            flash_crowd(3, 8.0, 2.0, 3.0, 2.0, 50.0, 2),
        ] {
            let mut prev = 0.0;
            for a in &trace {
                assert!(a.at_s >= prev, "unordered: {} < {prev}", a.at_s);
                assert!(a.at_s < 8.0, "past duration: {}", a.at_s);
                assert!(a.class < 2);
                prev = a.at_s;
            }
        }
    }

    #[test]
    fn flash_crowd_concentrates_in_the_spike() {
        let trace = flash_crowd(5, 10.0, 1.0, 4.0, 2.0, 60.0, 1);
        let in_spike = trace.iter().filter(|a| a.at_s >= 4.0 && a.at_s < 6.0).count();
        let calm = trace.iter().filter(|a| a.at_s < 2.0).count();
        assert!(
            in_spike > 5 * calm.max(1),
            "spike {in_spike} vs calm {calm}: spike must dominate"
        );
    }

    #[test]
    fn ramp_back_half_denser_than_front_half() {
        let trace = ramp(6, 10.0, 1.0, 50.0, 1);
        let front = trace.iter().filter(|a| a.at_s < 5.0).count();
        let back = trace.len() - front;
        assert!(back > 2 * front, "ramp not ramping: front {front}, back {back}");
    }

    #[test]
    fn merge_orders_across_traces() {
        let merged = merge(&[
            bursty(1, 5.0, 2.0, 10.0, 2.0, 2),
            ramp(2, 5.0, 2.0, 10.0, 2),
        ]);
        let mut prev = 0.0;
        for a in &merged {
            assert!(a.at_s >= prev);
            prev = a.at_s;
        }
        assert_eq!(
            merged.len(),
            bursty(1, 5.0, 2.0, 10.0, 2.0, 2).len() + ramp(2, 5.0, 2.0, 10.0, 2).len()
        );
    }

    #[test]
    fn replay_is_open_loop_and_order_preserving() {
        // Four arrivals 30 ms apart, each handler holding 150 ms: closed
        // loop would take ≥ 600 ms, open loop ≈ 240 ms. Bound generously
        // for slow CI machines while still ruling out serialization.
        let trace: Vec<Arrival> =
            (0..4).map(|i| Arrival { at_s: 0.03 * i as f64, class: i }).collect();
        let t0 = Instant::now();
        let results = replay(&trace, |i, a| {
            std::thread::sleep(Duration::from_millis(150));
            (i, a.class)
        });
        let took = t0.elapsed();
        assert_eq!(results, vec![(0, 0), (1, 1), (2, 2), (3, 3)]);
        assert!(
            took < Duration::from_millis(500),
            "replay serialized the arrivals: {took:?}"
        );
    }
}
