//! Minimal JSON value model, parser and serializer.
//!
//! crates.io is unreachable in this environment (see `.cargo/config.toml`),
//! so this replaces `serde_json` for the two places the system needs JSON:
//! parsing `artifacts/manifest.json` (the Python→Rust ABI) and the TCP
//! JSON-lines serving protocol. It implements the full JSON grammar
//! (RFC 8259) minus `\u` surrogate-pair edge cases beyond the BMP handling
//! below, which the manifest and protocol never produce.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Numbers are kept as `f64` (the manifest only
/// contains small integers and floats, all exactly representable).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    /// Exact non-negative integer, or `None` for fractional / negative /
    /// non-numeric values (used for schema and profile versions, where a
    /// silent truncation would corrupt the comparison).
    pub fn as_u64(&self) -> Option<u64> {
        match self.as_f64() {
            Some(n) if n.is_finite() && n >= 0.0 && n.fract() == 0.0 && n < 2f64.powi(53) => {
                Some(n as u64)
            }
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access; `None` when not an object or key missing.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Builder helpers for protocol messages.
    pub fn obj(fields: Vec<(&str, Json)>) -> Json {
        Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }
    pub fn arr(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }
}

// ---------------------------------------------------------------------------
// Parsing
// ---------------------------------------------------------------------------

/// JSON parse error with byte offset.
#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: impl Into<String>) -> Result<T, ParseError> {
        Err(ParseError { pos: self.pos, msg: msg.into() })
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos -= usize::from(self.pos > 0);
            self.err(format!("expected '{}'", c as char))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => self.err(format!("unexpected byte 0x{c:02x}")),
            None => self.err("unexpected end of input"),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            self.err(format!("expected '{s}'"))
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            arr.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(arr)),
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return self.err("unterminated string"),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        if (0xD800..0xDC00).contains(&cp) {
                            // high surrogate: require \uXXXX low surrogate
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return self.err("invalid low surrogate");
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            match char::from_u32(c) {
                                Some(ch) => out.push(ch),
                                None => return self.err("invalid surrogate pair"),
                            }
                        } else {
                            match char::from_u32(cp) {
                                Some(ch) => out.push(ch),
                                None => return self.err("invalid codepoint"),
                            }
                        }
                    }
                    _ => return self.err("invalid escape"),
                },
                Some(c) if c < 0x20 => return self.err("control char in string"),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences byte-wise.
                    let len = match c {
                        0x00..=0x7F => 1,
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => return self.err("invalid utf-8 lead byte"),
                    };
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump();
                    }
                    match std::str::from_utf8(&self.b[start..self.pos]) {
                        Ok(s) => out.push_str(s),
                        Err(_) => return self.err("invalid utf-8"),
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = match self.bump() {
                Some(c) => c,
                None => return self.err("truncated \\u escape"),
            };
            let d = match c {
                b'0'..=b'9' => (c - b'0') as u32,
                b'a'..=b'f' => (c - b'a') as u32 + 10,
                b'A'..=b'F' => (c - b'A') as u32 + 10,
                _ => return self.err("invalid hex digit"),
            };
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        match s.parse::<f64>() {
            Ok(n) => Ok(Json::Num(n)),
            Err(_) => self.err(format!("bad number '{s}'")),
        }
    }
}

/// Parse a complete JSON document (trailing whitespace allowed).
pub fn parse(text: &str) -> Result<Json, ParseError> {
    let mut p = Parser { b: text.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.b.len() {
        return p.err("trailing garbage");
    }
    Ok(v)
}

// ---------------------------------------------------------------------------
// Serialization
// ---------------------------------------------------------------------------

fn escape_into(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_into(j: &Json, out: &mut String) {
    match j {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => escape_into(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, v) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_into(v, out);
            }
            out.push(']');
        }
        Json::Obj(o) => {
            out.push('{');
            for (i, (k, v)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape_into(k, out);
                out.push(':');
                write_into(v, out);
            }
            out.push('}');
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write_into(self, &mut s);
        f.write_str(&s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn parses_escapes() {
        let v = parse(r#""a\n\t\"\\Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\Aé");
    }

    #[test]
    fn parses_surrogate_pair() {
        let v = parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "😀");
    }

    #[test]
    fn parses_utf8_passthrough() {
        let v = parse("\"héllo 世界\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo 世界");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("01x").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("{} trailing").is_err());
    }

    #[test]
    fn roundtrip() {
        let cases = [
            r#"{"a":[1,2.5,-3],"b":{"c":"d\n"},"e":null,"f":true}"#,
            r#"[[],{},"",0]"#,
        ];
        for c in cases {
            let v = parse(c).unwrap();
            let s = v.to_string();
            assert_eq!(parse(&s).unwrap(), v, "case {c}");
        }
    }

    #[test]
    fn integer_formatting_is_stable() {
        assert_eq!(Json::Num(30.0).to_string(), "30");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn as_u64_is_exact() {
        assert_eq!(Json::Num(3.0).as_u64(), Some(3));
        assert_eq!(Json::Num(0.0).as_u64(), Some(0));
        assert_eq!(Json::Num(1.5).as_u64(), None);
        assert_eq!(Json::Num(-1.0).as_u64(), None);
        assert_eq!(Json::Num(f64::NAN).as_u64(), None);
        assert_eq!(Json::str("3").as_u64(), None);
    }
}
