//! Tiny declarative CLI argument parser (clap substitute).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args and
//! subcommands; generates `--help` text from the declared options.

use std::collections::BTreeMap;

/// One declared option.
#[derive(Debug, Clone)]
struct OptSpec {
    name: &'static str,
    help: &'static str,
    default: Option<String>,
    is_flag: bool,
}

/// Declarative parser for one (sub)command.
#[derive(Debug, Clone)]
pub struct Cli {
    program: String,
    about: &'static str,
    opts: Vec<OptSpec>,
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    positional: Vec<String>,
}

impl Cli {
    pub fn new(program: &str, about: &'static str) -> Self {
        Self {
            program: program.to_string(),
            about,
            opts: Vec::new(),
            values: BTreeMap::new(),
            flags: BTreeMap::new(),
            positional: Vec::new(),
        }
    }

    /// Declare `--name <value>` with a default.
    pub fn opt(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: Some(default.into()), is_flag: false });
        self
    }

    /// Declare a required `--name <value>`.
    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, is_flag: false });
        self
    }

    /// Declare a boolean `--name` flag.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, is_flag: true });
        self
    }

    pub fn help_text(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.program, self.about);
        for o in &self.opts {
            let left = if o.is_flag {
                format!("  --{}", o.name)
            } else {
                format!("  --{} <v>", o.name)
            };
            let def = match &o.default {
                Some(d) if !o.is_flag => format!(" [default: {d}]"),
                _ => String::new(),
            };
            s.push_str(&format!("{left:28}{}{def}\n", o.help));
        }
        s
    }

    /// Parse a raw arg list. Returns Err(message) on bad input, and
    /// Err(help text) when `--help` is present.
    pub fn parse(mut self, args: &[String]) -> Result<Parsed, String> {
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if a == "--help" || a == "-h" {
                return Err(self.help_text());
            }
            if let Some(rest) = a.strip_prefix("--") {
                let (key, inline_val) = match rest.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n\n{}", self.help_text()))?
                    .clone();
                if spec.is_flag {
                    if inline_val.is_some() {
                        return Err(format!("flag --{key} takes no value"));
                    }
                    self.flags.insert(key, true);
                } else {
                    let v = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            args.get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{key} needs a value"))?
                        }
                    };
                    self.values.insert(key, v);
                }
            } else {
                self.positional.push(a.clone());
            }
            i += 1;
        }
        // defaults + required check
        for o in &self.opts {
            if o.is_flag {
                self.flags.entry(o.name.to_string()).or_insert(false);
            } else if !self.values.contains_key(o.name) {
                match &o.default {
                    Some(d) => {
                        self.values.insert(o.name.to_string(), d.clone());
                    }
                    None => return Err(format!("missing required --{}", o.name)),
                }
            }
        }
        Ok(Parsed { values: self.values, flags: self.flags, positional: self.positional })
    }
}

/// Parse result with typed getters.
#[derive(Debug, Clone)]
pub struct Parsed {
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    pub positional: Vec<String>,
}

impl Parsed {
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("option --{name} was not declared"))
    }
    pub fn get_flag(&self, name: &str) -> bool {
        *self
            .flags
            .get(name)
            .unwrap_or_else(|| panic!("flag --{name} was not declared"))
    }
    pub fn get_usize(&self, name: &str) -> Result<usize, String> {
        self.get(name)
            .parse()
            .map_err(|_| format!("--{name}: expected integer, got '{}'", self.get(name)))
    }
    pub fn get_u64(&self, name: &str) -> Result<u64, String> {
        self.get(name)
            .parse()
            .map_err(|_| format!("--{name}: expected integer, got '{}'", self.get(name)))
    }
    pub fn get_f64(&self, name: &str) -> Result<f64, String> {
        self.get(name)
            .parse()
            .map_err(|_| format!("--{name}: expected number, got '{}'", self.get(name)))
    }
    /// Comma-separated list.
    pub fn get_list(&self, name: &str) -> Vec<String> {
        self.get(name)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.to_string())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    fn cli() -> Cli {
        Cli::new("t", "test")
            .opt("model", "opensora-sim", "model preset")
            .opt("steps", "30", "steps")
            .flag("verbose", "chatty")
            .req("out", "output path")
    }

    #[test]
    fn parses_defaults_and_required() {
        let p = cli().parse(&argv(&["--out", "x.md"])).unwrap();
        assert_eq!(p.get("model"), "opensora-sim");
        assert_eq!(p.get_usize("steps").unwrap(), 30);
        assert!(!p.get_flag("verbose"));
        assert_eq!(p.get("out"), "x.md");
    }

    #[test]
    fn parses_equals_and_flags() {
        let p = cli()
            .parse(&argv(&["--out=o", "--steps=50", "--verbose", "pos1"]))
            .unwrap();
        assert_eq!(p.get_usize("steps").unwrap(), 50);
        assert!(p.get_flag("verbose"));
        assert_eq!(p.positional, vec!["pos1"]);
    }

    #[test]
    fn missing_required_errors() {
        assert!(cli().parse(&argv(&[])).is_err());
    }

    #[test]
    fn unknown_option_errors() {
        assert!(cli().parse(&argv(&["--out", "o", "--nope"])).is_err());
    }

    #[test]
    fn help_lists_options() {
        let h = cli().parse(&argv(&["--help"])).unwrap_err();
        assert!(h.contains("--model"));
        assert!(h.contains("default: opensora-sim"));
    }

    #[test]
    fn list_parsing() {
        let p = cli().parse(&argv(&["--out", "a,b,c"])).unwrap();
        assert_eq!(p.get_list("out"), vec!["a", "b", "c"]);
    }
}
