//! Artifact manifest parsing — the Python→Rust ABI.
//!
//! `artifacts/manifest.json` (written by python/compile/aot.py) records
//! every model preset's architecture numbers, the ordered parameter name
//! lists per executable piece, the shape buckets, and the denoising
//! schedule constants. This module parses it into typed structs; everything
//! downstream (model loading, samplers, engine) works off these.

use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::{self, Json};

/// Denoising-schedule constants shared bit-for-bit with Python.
#[derive(Debug, Clone, Copy)]
pub struct ScheduleConfig {
    pub train_timesteps: usize,
    pub beta_start: f64,
    pub beta_end: f64,
}

/// Which sampler family a preset uses (paper §4.1: OpenSora uses rflow,
/// Latte/CogVideoX use DDIM).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SamplerKind {
    Rflow,
    Ddim,
}

impl SamplerKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "rflow" => Ok(Self::Rflow),
            "ddim" => Ok(Self::Ddim),
            other => Err(anyhow!("unknown sampler kind '{other}'")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Rflow => "rflow",
            Self::Ddim => "ddim",
        }
    }
}

/// Profile-store keys and reports serialize the sampler by this name;
/// [`SamplerKind::parse`] accepts it back.
impl std::fmt::Display for SamplerKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// One compilation bucket (static shapes).
#[derive(Debug, Clone)]
pub struct BucketInfo {
    pub name: String,
    pub ph: usize,
    pub pw: usize,
    pub frames: usize,
    pub tokens: usize,
    pub dir: String,
}

/// One model preset as exported.
#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub name: String,
    pub layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_text: usize,
    pub text_len: usize,
    pub latent_channels: usize,
    pub mlp_ratio: usize,
    pub t_freq_dim: usize,
    pub sampler: SamplerKind,
    pub steps: usize,
    pub cfg_scale: f64,
    pub weights_dir: String,
    /// Ordered parameter names per piece (the executable argument ABI).
    pub piece_params: BTreeMap<String, Vec<String>>,
    pub buckets: BTreeMap<String, BucketInfo>,
}

impl ModelInfo {
    pub fn bucket(&self, name: &str) -> Result<&BucketInfo> {
        self.buckets.get(name).ok_or_else(|| {
            anyhow!(
                "model {} has no bucket '{name}' (have: {})",
                self.name,
                self.buckets.keys().cloned().collect::<Vec<_>>().join(", ")
            )
        })
    }

    /// Feature elements per DiT-block activation [F, P, D].
    pub fn block_elements(&self, bucket: &BucketInfo) -> usize {
        bucket.frames * bucket.tokens * self.d_model
    }
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub root: PathBuf,
    pub schedule: ScheduleConfig,
    pub models: BTreeMap<String, ModelInfo>,
}

fn req<'a>(j: &'a Json, key: &str, ctx: &str) -> Result<&'a Json> {
    j.get(key).ok_or_else(|| anyhow!("manifest: missing {ctx}.{key}"))
}

fn req_usize(j: &Json, key: &str, ctx: &str) -> Result<usize> {
    req(j, key, ctx)?
        .as_usize()
        .ok_or_else(|| anyhow!("manifest: {ctx}.{key} not a number"))
}

fn req_f64(j: &Json, key: &str, ctx: &str) -> Result<f64> {
    req(j, key, ctx)?
        .as_f64()
        .ok_or_else(|| anyhow!("manifest: {ctx}.{key} not a number"))
}

fn req_str<'a>(j: &'a Json, key: &str, ctx: &str) -> Result<&'a str> {
    req(j, key, ctx)?
        .as_str()
        .ok_or_else(|| anyhow!("manifest: {ctx}.{key} not a string"))
}

impl Manifest {
    /// Load `<root>/manifest.json`.
    pub fn load(root: &Path) -> Result<Self> {
        let path = root.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "read {} — run `make artifacts` first",
                path.display()
            )
        })?;
        Self::parse(&text, root)
    }

    /// Parse manifest text (root used to resolve artifact paths).
    pub fn parse(text: &str, root: &Path) -> Result<Self> {
        let j = json::parse(text).map_err(|e| anyhow!("manifest.json: {e}"))?;
        let sched = req(&j, "schedule", "")?;
        let schedule = ScheduleConfig {
            train_timesteps: req_usize(sched, "train_timesteps", "schedule")?,
            beta_start: req_f64(sched, "beta_start", "schedule")?,
            beta_end: req_f64(sched, "beta_end", "schedule")?,
        };
        let mut models = BTreeMap::new();
        let mobj = req(&j, "models", "")?
            .as_obj()
            .ok_or_else(|| anyhow!("manifest: models not an object"))?;
        for (name, mj) in mobj {
            models.insert(name.clone(), Self::parse_model(name, mj)?);
        }
        Ok(Self { root: root.to_path_buf(), schedule, models })
    }

    fn parse_model(name: &str, mj: &Json) -> Result<ModelInfo> {
        let ctx = format!("models.{name}");
        let mut piece_params = BTreeMap::new();
        let pp = req(mj, "piece_params", &ctx)?
            .as_obj()
            .ok_or_else(|| anyhow!("manifest: {ctx}.piece_params not object"))?;
        for (piece, arr) in pp {
            let names = arr
                .as_arr()
                .ok_or_else(|| anyhow!("manifest: piece_params.{piece} not array"))?
                .iter()
                .map(|v| {
                    v.as_str()
                        .map(str::to_string)
                        .ok_or_else(|| anyhow!("manifest: non-string param name"))
                })
                .collect::<Result<Vec<_>>>()?;
            piece_params.insert(piece.clone(), names);
        }
        let mut buckets = BTreeMap::new();
        let bo = req(mj, "buckets", &ctx)?
            .as_obj()
            .ok_or_else(|| anyhow!("manifest: {ctx}.buckets not object"))?;
        for (bname, bj) in bo {
            let bctx = format!("{ctx}.buckets.{bname}");
            buckets.insert(
                bname.clone(),
                BucketInfo {
                    name: bname.clone(),
                    ph: req_usize(bj, "ph", &bctx)?,
                    pw: req_usize(bj, "pw", &bctx)?,
                    frames: req_usize(bj, "frames", &bctx)?,
                    tokens: req_usize(bj, "tokens", &bctx)?,
                    dir: req_str(bj, "dir", &bctx)?.to_string(),
                },
            );
        }
        Ok(ModelInfo {
            name: name.to_string(),
            layers: req_usize(mj, "layers", &ctx)?,
            d_model: req_usize(mj, "d_model", &ctx)?,
            n_heads: req_usize(mj, "n_heads", &ctx)?,
            d_text: req_usize(mj, "d_text", &ctx)?,
            text_len: req_usize(mj, "text_len", &ctx)?,
            latent_channels: req_usize(mj, "latent_channels", &ctx)?,
            mlp_ratio: req_usize(mj, "mlp_ratio", &ctx)?,
            t_freq_dim: req_usize(mj, "t_freq_dim", &ctx)?,
            sampler: SamplerKind::parse(req_str(mj, "sampler", &ctx)?)?,
            steps: req_usize(mj, "steps", &ctx)?,
            cfg_scale: req_f64(mj, "cfg_scale", &ctx)?,
            weights_dir: req_str(mj, "weights_dir", &ctx)?.to_string(),
            piece_params,
            buckets,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelInfo> {
        self.models.get(name).ok_or_else(|| {
            anyhow!(
                "unknown model '{name}' (have: {})",
                self.models.keys().cloned().collect::<Vec<_>>().join(", ")
            )
        })
    }

    /// Default artifacts root: $FORESIGHT_ARTIFACTS or ./artifacts.
    pub fn default_root() -> PathBuf {
        std::env::var("FORESIGHT_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "schedule": {"train_timesteps": 1000, "beta_start": 0.0001, "beta_end": 0.02},
      "models": {
        "m": {
          "layers": 2, "d_model": 16, "n_heads": 2, "d_text": 8, "text_len": 4,
          "latent_channels": 8, "mlp_ratio": 4, "t_freq_dim": 32,
          "sampler": "ddim", "steps": 50, "cfg_scale": 7.5,
          "weights_dir": "m/weights",
          "piece_params": {"embed": ["patch_w", "patch_b"]},
          "buckets": {"b": {"ph": 2, "pw": 3, "frames": 4, "tokens": 6, "dir": "m/b"}}
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.schedule.train_timesteps, 1000);
        let mm = m.model("m").unwrap();
        assert_eq!(mm.layers, 2);
        assert_eq!(mm.sampler, SamplerKind::Ddim);
        let b = mm.bucket("b").unwrap();
        assert_eq!(b.tokens, 6);
        assert_eq!(mm.block_elements(b), 4 * 6 * 16);
        assert_eq!(mm.piece_params["embed"], vec!["patch_w", "patch_b"]);
    }

    #[test]
    fn unknown_model_and_bucket_error() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert!(m.model("nope").is_err());
        assert!(m.model("m").unwrap().bucket("nope").is_err());
    }

    #[test]
    fn missing_field_errors() {
        let bad = r#"{"schedule": {"train_timesteps": 1000}, "models": {}}"#;
        assert!(Manifest::parse(bad, Path::new("/tmp")).is_err());
    }

    #[test]
    fn sampler_kind_parse() {
        assert_eq!(SamplerKind::parse("rflow").unwrap(), SamplerKind::Rflow);
        assert!(SamplerKind::parse("euler").is_err());
    }

    #[test]
    fn sampler_kind_display_roundtrips_through_parse() {
        for kind in [SamplerKind::Rflow, SamplerKind::Ddim] {
            assert_eq!(SamplerKind::parse(&kind.to_string()).unwrap(), kind);
        }
    }
}
