//! Denoising samplers: host reference stepping + fused device stepping.
//!
//! The paper's setups (§4.1): OpenSora uses rectified-flow (rflow) Euler
//! sampling with 30 steps; Latte and CogVideoX use DDIM with 50 steps.
//!
//! Each sampler exposes two equivalent step paths:
//!
//! * [`Sampler::step`] — the host f32 reference, used by
//!   [`crate::engine::HotPath::Host`] and as the ground truth in the
//!   property tests;
//! * [`Sampler::step_device`] — the resident-latent path: the per-step
//!   update runs as one fused executable ([`crate::runtime::Runtime::axpy`]
//!   for rflow Euler, [`crate::runtime::Runtime::ddim_step`] for DDIM) over
//!   a device latent, with the schedule scalars exported through
//!   [`Sampler::step_coeffs`] and uploaded as rank-0 runtime arguments
//!   (4 bytes each, all at request start). Nothing else crosses the
//!   host↔device bus; the two paths agree to ≤1e-6 per element.
//!
//! The model executables only ever see `(x_t, t)` pairs, so samplers and
//! the reuse policies compose freely.

use anyhow::{anyhow, Result};
use std::sync::Arc;

use crate::config::{SamplerKind, ScheduleConfig};
use crate::runtime::{DeviceTensor, Executable, Runtime};

/// x0-prediction clamp bounds shared by the host and device DDIM steps
/// (keeps random-weight trajectories bounded; uploading the same constants
/// to the device guarantees the two paths cannot drift apart here).
pub const X0_CLAMP: (f32, f32) = (-6.0, 6.0);

/// The scalar coefficients of one denoising step, exported so the fused
/// device step executable can advance the resident latent without any
/// host-side math. Every coefficient is known at request start.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum StepCoeffs {
    /// rflow Euler: `x' = dt·v + x` (`axpy` with `dt` as the runtime
    /// scalar; `dt` is negative — sigma descends toward 0).
    Rflow { dt: f32 },
    /// eta-0 DDIM: `x' = sqrt_aprev·clamp((x − sqrt_1mat·eps)/sqrt_at,
    /// ±6) + sqrt_1maprev·eps`.
    Ddim { sqrt_at: f32, sqrt_1mat: f32, sqrt_aprev: f32, sqrt_1maprev: f32 },
}

impl StepCoeffs {
    /// Which sampler family these coefficients drive.
    pub fn kind(&self) -> SamplerKind {
        match self {
            StepCoeffs::Rflow { .. } => SamplerKind::Rflow,
            StepCoeffs::Ddim { .. } => SamplerKind::Ddim,
        }
    }

    /// Scalar values in the device executable's argument order.
    pub fn values(&self) -> Vec<f32> {
        match *self {
            StepCoeffs::Rflow { dt } => vec![dt],
            StepCoeffs::Ddim { sqrt_at, sqrt_1mat, sqrt_aprev, sqrt_1maprev } => {
                vec![sqrt_at, sqrt_1mat, sqrt_aprev, sqrt_1maprev]
            }
        }
    }
}

/// A denoising schedule instance for one request.
pub trait Sampler: Send {
    fn kind(&self) -> SamplerKind;

    /// Number of denoising steps.
    fn n_steps(&self) -> usize;

    /// The scalar fed to the timestep-embedding executable at step `i`
    /// (training-timestep value for DDIM, sigma*1000 for rflow).
    fn t_value(&self, i: usize) -> f32;

    /// Advance `x` in place given the model output at step `i`
    /// (noise prediction for DDIM, velocity for rflow). Host reference
    /// path; the resident-latent engine uses [`Sampler::step_device`].
    fn step(&self, x: &mut [f32], model_out: &[f32], i: usize);

    /// Export step `i`'s scalar coefficients for the fused device step.
    fn step_coeffs(&self, i: usize) -> StepCoeffs;

    /// Advance the device-resident latent through the fused step
    /// executable. `coeffs` must come from this sampler's
    /// [`Sampler::step_coeffs`] (uploaded via
    /// [`DeviceStepper::upload_coeffs`]); no latent bytes cross the bus.
    fn step_device(
        &self,
        stepper: &DeviceStepper,
        x: &DeviceTensor,
        eps: &DeviceTensor,
        coeffs: &DeviceCoeffs,
    ) -> Result<DeviceTensor> {
        stepper.step(x, eps, coeffs)
    }
}

// ---------------------------------------------------------------------------
// Device stepping
// ---------------------------------------------------------------------------

/// One step's scalar coefficients resident on device (rank-0 tensors,
/// 4 bytes each, uploaded once at request start).
pub struct DeviceCoeffs {
    kind: SamplerKind,
    scalars: Vec<DeviceTensor>,
}

impl DeviceCoeffs {
    /// Number of rank-0 scalars (1 for rflow, 4 for DDIM) — the per-step
    /// upload cost in 4-byte units.
    pub fn len(&self) -> usize {
        self.scalars.len()
    }

    pub fn is_empty(&self) -> bool {
        self.scalars.is_empty()
    }

    /// The resident rank-0 scalar tensors in the device executable's
    /// argument order — the session cohort step feeds these per lane into
    /// the fused multi-lane advance ([`crate::runtime::Runtime`]'s
    /// `cohort_rflow_step`/`cohort_ddim_step`), indexed by each session's
    /// own schedule cursor.
    pub fn scalars(&self) -> &[DeviceTensor] {
        &self.scalars
    }
}

/// Device-side sampler stepping: owns the fused step executable for one
/// latent shape plus the request-constant scalar arguments (the DDIM x0
/// clamp bounds). Built once per request by the resident-latent engine.
pub struct DeviceStepper {
    kind: SamplerKind,
    exec: Arc<Executable>,
    /// DDIM clamp bounds, uploaded once (8 bytes per request).
    bounds: Option<(DeviceTensor, DeviceTensor)>,
    rt: Arc<Runtime>,
}

impl DeviceStepper {
    /// Build the fused step executable for `dims`-shaped latents.
    pub fn new(rt: &Arc<Runtime>, kind: SamplerKind, dims: &[usize]) -> Result<Self> {
        let (exec, bounds) = match kind {
            SamplerKind::Rflow => (rt.axpy(dims)?, None),
            SamplerKind::Ddim => {
                let lo = rt.upload(&[X0_CLAMP.0], &[])?;
                let hi = rt.upload(&[X0_CLAMP.1], &[])?;
                (rt.ddim_step(dims)?, Some((lo, hi)))
            }
        };
        Ok(Self { kind, exec, bounds, rt: rt.clone() })
    }

    pub fn kind(&self) -> SamplerKind {
        self.kind
    }

    /// Host→device bytes uploaded by construction (the DDIM clamp bounds);
    /// the engine mirrors these into its per-run byte meter.
    pub fn setup_h2d_bytes(&self) -> u64 {
        if self.bounds.is_some() {
            8
        } else {
            0
        }
    }

    /// Upload calls made by construction (see [`Self::setup_h2d_bytes`]).
    pub fn setup_h2d_calls(&self) -> u64 {
        if self.bounds.is_some() {
            2
        } else {
            0
        }
    }

    /// The resident DDIM x0-clamp bound scalars `(lo, hi)`; `None` for
    /// samplers without a clamp (rflow). The session cohort step reuses
    /// these as the shared trailing arguments of the fused multi-lane
    /// DDIM advance.
    pub fn clamp_bounds(&self) -> Option<&(DeviceTensor, DeviceTensor)> {
        self.bounds.as_ref()
    }

    /// Upload one step's scalars (4 bytes each, one call per scalar).
    pub fn upload_coeffs(&self, c: &StepCoeffs) -> Result<DeviceCoeffs> {
        if c.kind() != self.kind {
            return Err(anyhow!(
                "coeff kind {:?} does not match stepper kind {:?}",
                c.kind(),
                self.kind
            ));
        }
        let scalars = c
            .values()
            .iter()
            .map(|&v| self.rt.upload(&[v], &[]))
            .collect::<Result<Vec<_>>>()?;
        Ok(DeviceCoeffs { kind: self.kind, scalars })
    }

    /// One fused step: `x' = f(x, eps; coeffs)` entirely on device.
    pub fn step(
        &self,
        x: &DeviceTensor,
        eps: &DeviceTensor,
        c: &DeviceCoeffs,
    ) -> Result<DeviceTensor> {
        if c.kind != self.kind {
            return Err(anyhow!(
                "coeffs for {:?} fed to a {:?} stepper",
                c.kind,
                self.kind
            ));
        }
        match self.kind {
            // axpy computes alpha·x + y; host order x + dt·v is bitwise
            // identical (f32 add commutes).
            SamplerKind::Rflow => self.exec.run(&[eps, x, &c.scalars[0]]),
            SamplerKind::Ddim => {
                let (lo, hi) = self
                    .bounds
                    .as_ref()
                    .expect("ddim stepper uploads clamp bounds at construction");
                let s = &c.scalars;
                self.exec.run(&[x, eps, &s[0], &s[1], &s[2], &s[3], lo, hi])
            }
        }
    }
}

// ---------------------------------------------------------------------------
// DDIM
// ---------------------------------------------------------------------------

/// Deterministic DDIM (eta = 0) over a linear-beta schedule.
pub struct Ddim {
    /// Descending training timesteps, one per denoising step.
    pub timesteps: Vec<usize>,
    /// alpha-bar lookup over the full training schedule.
    alphas_cumprod: Vec<f64>,
}

impl Ddim {
    pub fn new(sched: &ScheduleConfig, steps: usize) -> Self {
        assert!(steps >= 1 && steps <= sched.train_timesteps);
        let t_train = sched.train_timesteps;
        let mut alphas_cumprod = Vec::with_capacity(t_train);
        let mut prod = 1.0f64;
        for i in 0..t_train {
            // linear beta ramp, matching configs.py constants
            let beta = sched.beta_start
                + (sched.beta_end - sched.beta_start) * (i as f64) / ((t_train - 1) as f64);
            prod *= 1.0 - beta;
            alphas_cumprod.push(prod);
        }
        // Quadratic ("quad") timestep subsequence as in the original DDIM
        // paper: dense near t=0, sparse at high t. Consecutive denoising
        // steps therefore make progressively smaller updates toward the end
        // of sampling — the decaying adjacent-step feature MSE the paper's
        // Fig. 2 shows and Foresight's warmup-derived thresholds rely on.
        let mut timesteps: Vec<usize> = (0..steps)
            .rev()
            .map(|i| {
                let frac = (i + 1) as f64 / steps as f64;
                ((frac * frac) * (t_train - 1) as f64).round() as usize
            })
            .collect();
        // enforce strictly decreasing after rounding
        for i in (0..timesteps.len().saturating_sub(1)).rev() {
            if timesteps[i] <= timesteps[i + 1] {
                timesteps[i] = timesteps[i + 1] + 1;
            }
        }
        Self { timesteps, alphas_cumprod }
    }

    fn abar(&self, t: Option<usize>) -> f64 {
        match t {
            Some(t) => self.alphas_cumprod[t],
            None => 1.0, // "alpha-bar past the last step" = fully denoised
        }
    }
}

impl Sampler for Ddim {
    fn kind(&self) -> SamplerKind {
        SamplerKind::Ddim
    }

    fn n_steps(&self) -> usize {
        self.timesteps.len()
    }

    fn t_value(&self, i: usize) -> f32 {
        self.timesteps[i] as f32
    }

    fn step(&self, x: &mut [f32], eps: &[f32], i: usize) {
        assert_eq!(x.len(), eps.len());
        let StepCoeffs::Ddim { sqrt_at, sqrt_1mat, sqrt_aprev, sqrt_1maprev } =
            self.step_coeffs(i)
        else {
            unreachable!("ddim exports ddim coefficients")
        };
        for (xv, ev) in x.iter_mut().zip(eps) {
            // x0-prediction then jump to t_prev (eta = 0)
            let x0 = (*xv - sqrt_1mat * ev) / sqrt_at;
            // clamp x0 to keep random-weight trajectories bounded
            let x0 = x0.clamp(X0_CLAMP.0, X0_CLAMP.1);
            *xv = sqrt_aprev * x0 + sqrt_1maprev * ev;
        }
    }

    fn step_coeffs(&self, i: usize) -> StepCoeffs {
        let t = self.timesteps[i];
        let t_prev = self.timesteps.get(i + 1).copied();
        let a_t = self.abar(Some(t));
        let a_prev = self.abar(t_prev);
        StepCoeffs::Ddim {
            sqrt_at: a_t.sqrt() as f32,
            sqrt_1mat: (1.0 - a_t).sqrt() as f32,
            sqrt_aprev: a_prev.sqrt() as f32,
            sqrt_1maprev: (1.0 - a_prev).sqrt() as f32,
        }
    }
}

// ---------------------------------------------------------------------------
// Rectified flow (Euler)
// ---------------------------------------------------------------------------

/// Rectified-flow Euler sampler: x moves along the predicted velocity field
/// from sigma=1 (noise) to sigma=0 (data).
pub struct Rflow {
    sigmas: Vec<f64>, // len = steps + 1, descending 1.0 -> 0.0
}

impl Rflow {
    pub fn new(steps: usize) -> Self {
        assert!(steps >= 1);
        // Quadratic sigma spacing: large Euler steps while x is mostly
        // noise, small steps as it converges — the step-size analogue of
        // DDIM "quad" spacing (see Ddim::new), giving the decaying
        // adjacent-step feature MSE of the paper's Fig. 2.
        let sigmas = (0..=steps)
            .map(|i| {
                let s = 1.0 - (i as f64) / (steps as f64);
                s * s
            })
            .collect();
        Self { sigmas }
    }
}

impl Sampler for Rflow {
    fn kind(&self) -> SamplerKind {
        SamplerKind::Rflow
    }

    fn n_steps(&self) -> usize {
        self.sigmas.len() - 1
    }

    fn t_value(&self, i: usize) -> f32 {
        // scale sigma into the same numeric range the t-embedding saw at
        // export time (0..1000)
        (self.sigmas[i] * 1000.0) as f32
    }

    fn step(&self, x: &mut [f32], velocity: &[f32], i: usize) {
        assert_eq!(x.len(), velocity.len());
        let StepCoeffs::Rflow { dt } = self.step_coeffs(i) else {
            unreachable!("rflow exports rflow coefficients")
        };
        for (xv, vv) in x.iter_mut().zip(velocity) {
            *xv += dt * vv;
        }
    }

    fn step_coeffs(&self, i: usize) -> StepCoeffs {
        StepCoeffs::Rflow { dt: (self.sigmas[i + 1] - self.sigmas[i]) as f32 }
    }
}

/// Construct the sampler a model preset asks for.
pub fn build(kind: SamplerKind, sched: &ScheduleConfig, steps: usize) -> Box<dyn Sampler> {
    match kind {
        SamplerKind::Ddim => Box::new(Ddim::new(sched, steps)),
        SamplerKind::Rflow => Box::new(Rflow::new(steps)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{prop_assert_close, proptest_cases};
    use std::panic::AssertUnwindSafe;

    fn sched() -> ScheduleConfig {
        ScheduleConfig { train_timesteps: 1000, beta_start: 1e-4, beta_end: 2e-2 }
    }

    #[test]
    fn ddim_timesteps_descend_within_range() {
        let d = Ddim::new(&sched(), 50);
        assert_eq!(d.n_steps(), 50);
        for w in d.timesteps.windows(2) {
            assert!(w[0] > w[1]);
        }
        assert!(*d.timesteps.first().unwrap() < 1000);
    }

    #[test]
    fn ddim_alphabar_monotone_decreasing() {
        let d = Ddim::new(&sched(), 10);
        for w in d.alphas_cumprod.windows(2) {
            assert!(w[0] > w[1]);
        }
        assert!(d.alphas_cumprod[0] < 1.0 && d.alphas_cumprod[999] > 0.0);
    }

    #[test]
    fn ddim_zero_eps_stays_finite() {
        let d = Ddim::new(&sched(), 50);
        let mut x = vec![1.0f32; 8];
        let eps = vec![0.0f32; 8];
        for i in 0..d.n_steps() {
            d.step(&mut x, &eps, i);
        }
        for &v in &x {
            assert!(v.is_finite());
            assert!(v > 1.0, "abar increases toward the end so x grows toward x0: {v}");
        }
    }

    #[test]
    fn rflow_integrates_constant_velocity_exactly() {
        let r = Rflow::new(30);
        assert_eq!(r.n_steps(), 30);
        let mut x = vec![1.0f32; 4];
        let v = vec![2.0f32; 4];
        for i in 0..r.n_steps() {
            r.step(&mut x, &v, i);
        }
        // total dt = -1, so x = 1 - 2 = -1
        for &xv in &x {
            assert!((xv + 1.0).abs() < 1e-5, "{xv}");
        }
    }

    #[test]
    fn rflow_t_values_descend_from_1000() {
        let r = Rflow::new(30);
        assert!((r.t_value(0) - 1000.0).abs() < 1e-3);
        for i in 1..r.n_steps() {
            assert!(r.t_value(i) < r.t_value(i - 1));
        }
    }

    #[test]
    fn build_dispatches() {
        assert_eq!(build(SamplerKind::Ddim, &sched(), 10).n_steps(), 10);
        assert_eq!(build(SamplerKind::Rflow, &sched(), 10).n_steps(), 10);
    }

    #[test]
    fn exported_coeffs_reproduce_the_host_step() {
        // Applying the exported scalars by hand must be exactly the host
        // step — if this drifts, the fused device step is computing a
        // different schedule than the reference.
        let d = Ddim::new(&sched(), 20);
        let eps = vec![0.2f32, -0.1, 0.4, 0.9];
        let mut x = vec![0.3f32, -2.0, 5.0, -0.7];
        let mut manual = x.clone();
        for i in 0..d.n_steps() {
            let StepCoeffs::Ddim { sqrt_at, sqrt_1mat, sqrt_aprev, sqrt_1maprev } =
                d.step_coeffs(i)
            else {
                panic!("ddim coeffs expected")
            };
            for (xv, ev) in manual.iter_mut().zip(&eps) {
                let x0 = ((*xv - sqrt_1mat * ev) / sqrt_at).clamp(X0_CLAMP.0, X0_CLAMP.1);
                *xv = sqrt_aprev * x0 + sqrt_1maprev * ev;
            }
            d.step(&mut x, &eps, i);
            assert_eq!(x, manual, "step {i}");
        }

        let r = Rflow::new(12);
        let dt_total: f32 = (0..r.n_steps())
            .map(|i| {
                let StepCoeffs::Rflow { dt } = r.step_coeffs(i) else { panic!() };
                dt
            })
            .sum();
        assert!((dt_total + 1.0).abs() < 1e-5, "rflow dts must integrate to -1: {dt_total}");
    }

    #[test]
    fn prop_device_stepping_matches_host_sampler() {
        // Satellite property: chaining the fused device step (axpy for
        // rflow, ddim_step for DDIM) matches the host Sampler::step
        // reference to ≤1e-6 across random latents, shapes and step
        // counts.
        let rt = std::sync::Arc::new(Runtime::cpu().unwrap());
        let rt = AssertUnwindSafe(&rt);
        let sc = sched();
        proptest_cases(30, |g| {
            let kind = *g.pick(&[SamplerKind::Rflow, SamplerKind::Ddim]);
            let steps = g.usize_in(2..=6);
            let smp = build(kind, &sc, steps);
            let n = g.usize_in(1..=32);
            let dims = [n];
            let stepper = DeviceStepper::new(*rt, kind, &dims).unwrap();
            let mut x_host = g.vec_f32(n, -2.0, 2.0);
            let mut x_dev = rt.upload(&x_host, &dims).unwrap();
            for i in 0..steps {
                let eps = g.vec_f32(n, -2.0, 2.0);
                let eps_dev = rt.upload(&eps, &dims).unwrap();
                let coeffs = stepper.upload_coeffs(&smp.step_coeffs(i)).unwrap();
                x_dev = smp.step_device(&stepper, &x_dev, &eps_dev, &coeffs).unwrap();
                smp.step(&mut x_host, &eps, i);
            }
            let mut out = vec![0.0f32; n];
            rt.download_into(&x_dev, &mut out).unwrap();
            for i in 0..n {
                prop_assert_close(
                    out[i] as f64,
                    x_host[i] as f64,
                    1e-6,
                    "device vs host sampler step",
                );
            }
        });
    }

    #[test]
    fn stepper_rejects_mismatched_coeffs() {
        let rt = std::sync::Arc::new(Runtime::cpu().unwrap());
        let dims = [4usize];
        let rf = DeviceStepper::new(&rt, SamplerKind::Rflow, &dims).unwrap();
        assert_eq!(rf.setup_h2d_bytes(), 0);
        let dd = DeviceStepper::new(&rt, SamplerKind::Ddim, &dims).unwrap();
        assert_eq!(dd.setup_h2d_bytes(), 8);
        let err = rf
            .upload_coeffs(&StepCoeffs::Ddim {
                sqrt_at: 1.0,
                sqrt_1mat: 0.0,
                sqrt_aprev: 1.0,
                sqrt_1maprev: 0.0,
            })
            .unwrap_err()
            .to_string();
        assert!(err.contains("does not match"), "{err}");
        // cross-feeding uploaded coeffs is rejected too
        let cf = rf.upload_coeffs(&StepCoeffs::Rflow { dt: -0.1 }).unwrap();
        assert_eq!(cf.len(), 1);
        let x = rt.upload(&[1.0, 2.0, 3.0, 4.0], &dims).unwrap();
        assert!(dd.step(&x, &x, &cf).is_err());
    }
}
