//! Denoising samplers (host-side math, no Python).
//!
//! The paper's setups (§4.1): OpenSora uses rectified-flow (rflow) Euler
//! sampling with 30 steps; Latte and CogVideoX use DDIM with 50 steps. Both
//! are implemented here over host f32 latents; the model executables only
//! ever see `(x_t, t)` pairs, so samplers and the reuse policies compose
//! freely.

use crate::config::{SamplerKind, ScheduleConfig};

/// A denoising schedule instance for one request.
pub trait Sampler: Send {
    fn kind(&self) -> SamplerKind;

    /// Number of denoising steps.
    fn n_steps(&self) -> usize;

    /// The scalar fed to the timestep-embedding executable at step `i`
    /// (training-timestep value for DDIM, sigma*1000 for rflow).
    fn t_value(&self, i: usize) -> f32;

    /// Advance `x` in place given the model output at step `i`
    /// (noise prediction for DDIM, velocity for rflow).
    fn step(&self, x: &mut [f32], model_out: &[f32], i: usize);
}

// ---------------------------------------------------------------------------
// DDIM
// ---------------------------------------------------------------------------

/// Deterministic DDIM (eta = 0) over a linear-beta schedule.
pub struct Ddim {
    /// Descending training timesteps, one per denoising step.
    pub timesteps: Vec<usize>,
    /// alpha-bar lookup over the full training schedule.
    alphas_cumprod: Vec<f64>,
}

impl Ddim {
    pub fn new(sched: &ScheduleConfig, steps: usize) -> Self {
        assert!(steps >= 1 && steps <= sched.train_timesteps);
        let t_train = sched.train_timesteps;
        let mut alphas_cumprod = Vec::with_capacity(t_train);
        let mut prod = 1.0f64;
        for i in 0..t_train {
            // linear beta ramp, matching configs.py constants
            let beta = sched.beta_start
                + (sched.beta_end - sched.beta_start) * (i as f64) / ((t_train - 1) as f64);
            prod *= 1.0 - beta;
            alphas_cumprod.push(prod);
        }
        // Quadratic ("quad") timestep subsequence as in the original DDIM
        // paper: dense near t=0, sparse at high t. Consecutive denoising
        // steps therefore make progressively smaller updates toward the end
        // of sampling — the decaying adjacent-step feature MSE the paper's
        // Fig. 2 shows and Foresight's warmup-derived thresholds rely on.
        let mut timesteps: Vec<usize> = (0..steps)
            .rev()
            .map(|i| {
                let frac = (i + 1) as f64 / steps as f64;
                ((frac * frac) * (t_train - 1) as f64).round() as usize
            })
            .collect();
        // enforce strictly decreasing after rounding
        for i in (0..timesteps.len().saturating_sub(1)).rev() {
            if timesteps[i] <= timesteps[i + 1] {
                timesteps[i] = timesteps[i + 1] + 1;
            }
        }
        Self { timesteps, alphas_cumprod }
    }

    fn abar(&self, t: Option<usize>) -> f64 {
        match t {
            Some(t) => self.alphas_cumprod[t],
            None => 1.0, // "alpha-bar past the last step" = fully denoised
        }
    }
}

impl Sampler for Ddim {
    fn kind(&self) -> SamplerKind {
        SamplerKind::Ddim
    }

    fn n_steps(&self) -> usize {
        self.timesteps.len()
    }

    fn t_value(&self, i: usize) -> f32 {
        self.timesteps[i] as f32
    }

    fn step(&self, x: &mut [f32], eps: &[f32], i: usize) {
        assert_eq!(x.len(), eps.len());
        let t = self.timesteps[i];
        let t_prev = self.timesteps.get(i + 1).copied();
        let a_t = self.abar(Some(t));
        let a_prev = self.abar(t_prev);
        let sqrt_at = a_t.sqrt() as f32;
        let sqrt_1mat = (1.0 - a_t).sqrt() as f32;
        let sqrt_aprev = a_prev.sqrt() as f32;
        let sqrt_1maprev = (1.0 - a_prev).sqrt() as f32;
        for (xv, ev) in x.iter_mut().zip(eps) {
            // x0-prediction then jump to t_prev (eta = 0)
            let x0 = (*xv - sqrt_1mat * ev) / sqrt_at;
            // clamp x0 to keep random-weight trajectories bounded
            let x0 = x0.clamp(-6.0, 6.0);
            *xv = sqrt_aprev * x0 + sqrt_1maprev * ev;
        }
    }
}

// ---------------------------------------------------------------------------
// Rectified flow (Euler)
// ---------------------------------------------------------------------------

/// Rectified-flow Euler sampler: x moves along the predicted velocity field
/// from sigma=1 (noise) to sigma=0 (data).
pub struct Rflow {
    sigmas: Vec<f64>, // len = steps + 1, descending 1.0 -> 0.0
}

impl Rflow {
    pub fn new(steps: usize) -> Self {
        assert!(steps >= 1);
        // Quadratic sigma spacing: large Euler steps while x is mostly
        // noise, small steps as it converges — the step-size analogue of
        // DDIM "quad" spacing (see Ddim::new), giving the decaying
        // adjacent-step feature MSE of the paper's Fig. 2.
        let sigmas = (0..=steps)
            .map(|i| {
                let s = 1.0 - (i as f64) / (steps as f64);
                s * s
            })
            .collect();
        Self { sigmas }
    }
}

impl Sampler for Rflow {
    fn kind(&self) -> SamplerKind {
        SamplerKind::Rflow
    }

    fn n_steps(&self) -> usize {
        self.sigmas.len() - 1
    }

    fn t_value(&self, i: usize) -> f32 {
        // scale sigma into the same numeric range the t-embedding saw at
        // export time (0..1000)
        (self.sigmas[i] * 1000.0) as f32
    }

    fn step(&self, x: &mut [f32], velocity: &[f32], i: usize) {
        assert_eq!(x.len(), velocity.len());
        let dt = (self.sigmas[i + 1] - self.sigmas[i]) as f32; // negative
        for (xv, vv) in x.iter_mut().zip(velocity) {
            *xv += dt * vv;
        }
    }
}

/// Construct the sampler a model preset asks for.
pub fn build(kind: SamplerKind, sched: &ScheduleConfig, steps: usize) -> Box<dyn Sampler> {
    match kind {
        SamplerKind::Ddim => Box::new(Ddim::new(sched, steps)),
        SamplerKind::Rflow => Box::new(Rflow::new(steps)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sched() -> ScheduleConfig {
        ScheduleConfig { train_timesteps: 1000, beta_start: 1e-4, beta_end: 2e-2 }
    }

    #[test]
    fn ddim_timesteps_descend_within_range() {
        let d = Ddim::new(&sched(), 50);
        assert_eq!(d.n_steps(), 50);
        for w in d.timesteps.windows(2) {
            assert!(w[0] > w[1]);
        }
        assert!(*d.timesteps.first().unwrap() < 1000);
    }

    #[test]
    fn ddim_alphabar_monotone_decreasing() {
        let d = Ddim::new(&sched(), 10);
        for w in d.alphas_cumprod.windows(2) {
            assert!(w[0] > w[1]);
        }
        assert!(d.alphas_cumprod[0] < 1.0 && d.alphas_cumprod[999] > 0.0);
    }

    #[test]
    fn ddim_zero_eps_stays_finite() {
        let d = Ddim::new(&sched(), 50);
        let mut x = vec![1.0f32; 8];
        let eps = vec![0.0f32; 8];
        for i in 0..d.n_steps() {
            d.step(&mut x, &eps, i);
        }
        for &v in &x {
            assert!(v.is_finite());
            assert!(v > 1.0, "abar increases toward the end so x grows toward x0: {v}");
        }
    }

    #[test]
    fn rflow_integrates_constant_velocity_exactly() {
        let r = Rflow::new(30);
        assert_eq!(r.n_steps(), 30);
        let mut x = vec![1.0f32; 4];
        let v = vec![2.0f32; 4];
        for i in 0..r.n_steps() {
            r.step(&mut x, &v, i);
        }
        // total dt = -1, so x = 1 - 2 = -1
        for &xv in &x {
            assert!((xv + 1.0).abs() < 1e-5, "{xv}");
        }
    }

    #[test]
    fn rflow_t_values_descend_from_1000() {
        let r = Rflow::new(30);
        assert!((r.t_value(0) - 1000.0).abs() < 1e-3);
        for i in 1..r.n_steps() {
            assert!(r.t_value(i) < r.t_value(i - 1));
        }
    }

    #[test]
    fn build_dispatches() {
        assert_eq!(build(SamplerKind::Ddim, &sched(), 10).n_steps(), 10);
        assert_eq!(build(SamplerKind::Rflow, &sched(), 10).n_steps(), 10);
    }
}
