//! Reuse policies: Foresight (the paper's contribution) and the four
//! static baselines it compares against (§4.1, Appendix A.6).
//!
//! A policy answers, for every (step, layer, block-kind, unit, CFG-branch):
//! *compute this unit, or reuse the cached activation?* The engine owns the
//! cache and the executions; policies are pure decision state machines fed
//! MSE observations — which keeps them unit-testable without a runtime and
//! lets the property tests drive them through thousands of synthetic
//! trajectories.
//!
//! # Composable wrappers
//!
//! Besides the base policies, specs can name **wrappers** that compose
//! with any base policy. The only wrapper today is [`Forecast`]
//! (`forecast:k=<order>,inner=<spec>`): the inner policy keeps deciding
//! *when* a site reuses, and the wrapper upgrades each `Reuse` to
//! [`Action::Predict`] so the engine extrapolates the site's next output
//! from its cached history instead of replaying a stale one. The
//! `inner=` value is the **last** key and swallows the rest of the spec
//! verbatim (embedded `:`/`,` included), so any spec that parses on its
//! own parses inside a wrapper — autotune round-trips both forms.

pub mod delta_dit;
pub mod forecast;
pub mod foresight;
pub mod none;
pub mod pab;
pub mod static_reuse;
pub mod tgate;

use anyhow::{anyhow, Result};
use std::collections::BTreeMap;

use crate::cache::Unit;
use crate::config::ModelInfo;
use crate::model::BlockKind;

pub use delta_dit::DeltaDit;
pub use forecast::Forecast;
pub use foresight::Foresight;
pub use none::NoReuse;
pub use pab::Pab;
pub use static_reuse::StaticReuse;
pub use tgate::TGate;

/// Whether a policy decides over whole DiT blocks or sublayers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Granularity {
    /// Whole DiT blocks — 2 cache entries per layer pair (2LHWF).
    Coarse,
    /// Attention / cross / MLP sublayers — up to 6 per layer pair (6LHWF).
    Fine,
}

/// What computed activations are cached as.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheMode {
    /// The block output itself (Foresight / Static: Eq. 3-4).
    Output,
    /// The residual delta `out - in` (Δ-DiT / PAB / T-GATE broadcast).
    Delta,
}

/// Per-unit decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Action {
    Compute {
        /// Refresh the cache with this unit's new activation.
        update_cache: bool,
        /// Report MSE(new, cached) back via `observe_mse`. On the
        /// device-resident hot path this is a fused on-device reduction
        /// against the cached buffer (a 4-byte scalar download — only
        /// Foresight pays it).
        measure: bool,
    },
    /// Feed the cached output forward (coarse output-mode reuse, Eq. 4).
    Reuse,
    /// Add the cached residual delta to the current state (delta-mode).
    ReuseResidual,
    /// Extrapolate this unit's output from its last `order` cached
    /// outputs (one fused `lms_combine` dispatch) instead of replaying
    /// the stale one. Emitted by the [`Forecast`] wrapper; the engine
    /// falls back to verbatim replay per site when the history ring is
    /// still shallower than `order`.
    Predict { order: usize },
}

impl Action {
    pub fn is_reuse(&self) -> bool {
        matches!(self, Action::Reuse | Action::ReuseResidual | Action::Predict { .. })
    }
}

/// Identifies one decision site.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct Site {
    pub layer: usize,
    pub kind: BlockKind,
    pub unit: Unit,
    pub branch: usize,
}

/// The policy interface the engine drives.
pub trait ReusePolicy: Send {
    /// Display name including parameters, e.g. `foresight(N1R2,g=0.5)`.
    fn name(&self) -> String;

    fn granularity(&self) -> Granularity;

    fn cache_mode(&self) -> CacheMode;

    /// True when the policy consumes MSE observations (the engine then
    /// measures computed activations against the cached device buffers).
    fn needs_measurement(&self) -> bool {
        false
    }

    /// How many outputs per site the engine's cache must retain (live
    /// entry plus history ring). 1 — the default — keeps only the live
    /// entry; forecasting wrappers return their predictor order `k`.
    fn history_depth(&self) -> usize {
        1
    }

    /// Reset state for a new request.
    fn begin_request(&mut self, layers: usize, steps: usize);

    /// Decision for one site at one step.
    fn action(&mut self, step: usize, site: Site) -> Action;

    /// MSE(new activation, cached activation) after a measured compute.
    fn observe_mse(&mut self, _step: usize, _site: Site, _mse: f64) {}

    /// Foresight's per-site reuse thresholds λ (Fig. 5); None otherwise.
    fn thresholds(&self) -> Option<BTreeMap<(usize, BlockKind, usize), f64>> {
        None
    }
}

/// Parse `name:key=val,key=val` policy specs into concrete policies, filling
/// paper-default parameters from the model preset (Appendix A.6 tables).
///
/// Examples: `none`, `static`, `static:n=2,r=3`,
/// `foresight:n=1,r=2,gamma=0.5,warmup=0.15`, `delta-dit`, `tgate`, `pab`,
/// `forecast:k=2,inner=foresight:n=1,r=2,gamma=0.5`.
///
/// The `forecast` wrapper is parsed before the generic `key=val` split:
/// its `inner=` value is the rest of the spec verbatim (embedded `:`/`,`
/// included) and recurses through this same parser, so wrapped and bare
/// specs round-trip identically.
///
/// Parsing is strict so errors are actionable at the wire and so the
/// `autotune` subsystem can round-trip every spec it emits:
/// * a malformed numeric value names the policy and field
///   (`policy 'foresight': arg gamma='abc' is not a number`);
/// * an arg key the policy does not define is rejected instead of being
///   silently ignored (`foresight:g=0.5` used to fall back to the default
///   gamma without a word);
/// * out-of-range values (negative `r`, `gamma<=0`, `warmup` outside
///   `[0,1)`, inverted `pab` ranges, ...) surface as `Result` errors from
///   the validated policy constructors — never as a worker-killing panic.
pub fn build_policy(spec: &str, model: &ModelInfo, steps: usize) -> Result<Box<dyn ReusePolicy>> {
    // Wrapper specs first: `inner=` swallows the remainder (it is itself a
    // full spec with embedded ':'/','), so the generic comma split below
    // must never see it.
    if spec == "forecast" || spec.starts_with("forecast:") {
        let args = spec.strip_prefix("forecast").unwrap_or_default();
        let args = args.strip_prefix(':').unwrap_or(args);
        let (head, inner_spec) = args.split_once("inner=").ok_or_else(|| {
            anyhow!("policy 'forecast': missing inner= spec (expected forecast:k=<order>,inner=<spec>)")
        })?;
        let mut order = 2usize;
        for pair in head.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let (k, v) = pair
                .split_once('=')
                .ok_or_else(|| anyhow!("policy 'forecast': arg '{pair}' is not key=val"))?;
            match k.trim() {
                "k" => {
                    order = v.trim().parse().map_err(|_| {
                        anyhow!("policy 'forecast': arg k='{}' is not a non-negative integer", v.trim())
                    })?;
                }
                other => {
                    return Err(anyhow!(
                        "policy 'forecast': unknown arg '{other}' (known: k, inner)"
                    ))
                }
            }
        }
        let inner = build_policy(inner_spec.trim(), model, steps)?;
        return Ok(Box::new(Forecast::new(order, inner)?));
    }

    let (name, args) = match spec.split_once(':') {
        Some((n, a)) => (n, a),
        None => (spec, ""),
    };
    let mut kv = BTreeMap::new();
    for pair in args.split(',').filter(|s| !s.is_empty()) {
        let (k, v) = pair
            .split_once('=')
            .ok_or_else(|| anyhow!("policy '{name}': arg '{pair}' is not key=val"))?;
        kv.insert(k.trim().to_string(), v.trim().to_string());
    }
    let known_keys = |known: &[&str]| -> Result<()> {
        for k in kv.keys() {
            if !known.contains(&k.as_str()) {
                return Err(anyhow!(
                    "policy '{name}': unknown arg '{k}' (known: {})",
                    known.join(", ")
                ));
            }
        }
        Ok(())
    };
    let get_f = |k: &str, default: f64| -> Result<f64> {
        match kv.get(k) {
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("policy '{name}': arg {k}='{v}' is not a number")),
            None => Ok(default),
        }
    };
    let get_u = |k: &str, default: usize| -> Result<usize> {
        match kv.get(k) {
            Some(v) => v.parse().map_err(|_| {
                anyhow!("policy '{name}': arg {k}='{v}' is not a non-negative integer")
            }),
            None => Ok(default),
        }
    };

    match name {
        "none" | "baseline" => {
            known_keys(&[])?;
            Ok(Box::new(NoReuse::new()))
        }
        "static" => {
            known_keys(&["n", "r"])?;
            let n = get_u("n", 1)?;
            let r = get_u("r", n + 1)?;
            Ok(Box::new(StaticReuse::new(n, r)?))
        }
        "foresight" => {
            known_keys(&["n", "r", "gamma", "warmup"])?;
            let n = get_u("n", 1)?;
            let r = get_u("r", n + 1)?;
            let gamma = get_f("gamma", 0.5)?;
            let warmup_frac = get_f("warmup", 0.15)?;
            Ok(Box::new(Foresight::new(n, r, gamma, warmup_frac)?))
        }
        "delta-dit" | "delta_dit" => {
            // Table 5: k=2; gate b=25/30 (OpenSora) or 48/50; block range
            // ~20% of layers.
            known_keys(&["k", "b", "range"])?;
            let k = get_u("k", 2)?;
            let default_b = ((steps as f64) * if steps <= 30 { 0.83 } else { 0.96 }) as usize;
            let b = get_u("b", default_b.max(1))?;
            let range = get_u("range", ((model.layers as f64) * 0.2).ceil().max(1.0) as usize)?;
            Ok(Box::new(DeltaDit::new(k, b, range)?))
        }
        "tgate" | "t-gate" => {
            // Table 6: k=2, gate m = 0.4*steps for both 30- and 50-step setups.
            known_keys(&["k", "m"])?;
            let k = get_u("k", 2)?;
            let m = get_u("m", (((steps as f64) * 0.4) as usize).max(1))?;
            Ok(Box::new(TGate::new(k, m)?))
        }
        "pab" => {
            // Table 7: spatial α=2, temporal β=4, cross γ=6; broadcast range
            // t∈[930,450] of 1000 → step fractions [0.07, 0.55]; MLP blocks
            // 0..5 with interval 2.
            known_keys(&["alpha", "beta", "gamma", "lo", "hi", "mlp_interval"])?;
            let alpha = get_u("alpha", 2)?;
            let beta = get_u("beta", 4)?;
            let gamma_c = get_u("gamma", 6)?;
            let lo = get_f("lo", 0.07)?;
            let hi = get_f("hi", 0.55)?;
            let mlp_interval = get_u("mlp_interval", 2)?;
            let mlp_blocks: Vec<usize> = (0..model.layers.min(5)).collect();
            Ok(Box::new(Pab::new(
                alpha, beta, gamma_c, lo, hi, mlp_blocks, mlp_interval, steps,
            )?))
        }
        other => Err(anyhow!(
            "unknown policy '{other}' (expected none|static|foresight|delta-dit|tgate|pab|\
             forecast:k=<order>,inner=<spec>)"
        )),
    }
}

/// Iterate all decision sites of one step in execution order for a model.
pub fn sites_for(model_layers: usize, granularity: Granularity, branch: usize) -> Vec<Site> {
    let mut out = Vec::new();
    for layer in 0..model_layers {
        for kind in BlockKind::ALL {
            match granularity {
                Granularity::Coarse => out.push(Site { layer, kind, unit: Unit::Block, branch }),
                Granularity::Fine => {
                    for s in crate::model::SubUnit::ALL {
                        out.push(Site { layer, kind, unit: Unit::Sub(s), branch });
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> ModelInfo {
        // hand-rolled minimal ModelInfo for parser tests
        ModelInfo {
            name: "m".into(),
            layers: 6,
            d_model: 96,
            n_heads: 4,
            d_text: 64,
            text_len: 16,
            latent_channels: 8,
            mlp_ratio: 4,
            t_freq_dim: 128,
            sampler: crate::config::SamplerKind::Rflow,
            steps: 30,
            cfg_scale: 7.5,
            weights_dir: "w".into(),
            piece_params: BTreeMap::new(),
            buckets: BTreeMap::new(),
        }
    }

    #[test]
    fn parses_all_policy_names() {
        let m = model();
        for spec in ["none", "static", "foresight", "delta-dit", "tgate", "pab"] {
            let p = build_policy(spec, &m, 30).unwrap();
            assert!(!p.name().is_empty(), "{spec}");
        }
    }

    #[test]
    fn parses_parameters() {
        let m = model();
        let p = build_policy("foresight:n=2,r=3,gamma=0.25,warmup=0.2", &m, 30).unwrap();
        assert!(p.name().contains("N2R3"));
        assert!(p.name().contains("0.25"));
    }

    #[test]
    fn rejects_garbage() {
        let m = model();
        assert!(build_policy("warp-drive", &m, 30).is_err());
        assert!(build_policy("static:nope", &m, 30).is_err());
        assert!(build_policy("static:n=abc", &m, 30).is_err());
    }

    #[test]
    fn malformed_numeric_args_name_the_field() {
        let m = model();
        let err = build_policy("foresight:gamma=abc", &m, 30).unwrap_err().to_string();
        assert!(err.contains("foresight") && err.contains("gamma") && err.contains("abc"), "{err}");
        let err = build_policy("static:r=-1", &m, 30).unwrap_err().to_string();
        assert!(err.contains("static") && err.contains("r='-1'"), "{err}");
        let err = build_policy("pab:lo=wide", &m, 30).unwrap_err().to_string();
        assert!(err.contains("pab") && err.contains("lo"), "{err}");
    }

    #[test]
    fn unknown_arg_keys_are_rejected_not_ignored() {
        // `foresight:g=0.5` used to silently fall back to the default gamma;
        // the autotuner round-trips specs, so typos must be loud.
        let m = model();
        let err = build_policy("foresight:g=0.5", &m, 30).unwrap_err().to_string();
        assert!(err.contains("unknown arg 'g'") && err.contains("gamma"), "{err}");
        assert!(build_policy("none:n=1", &m, 30).is_err());
        assert!(build_policy("tgate:gamma=1", &m, 30).is_err());
    }

    #[test]
    fn out_of_range_params_error_instead_of_panicking() {
        // Every one of these used to trip an assert! in a policy
        // constructor — reachable from the wire, so they must be Errs.
        let m = model();
        for spec in [
            "foresight:gamma=0",
            "foresight:gamma=-1",
            "foresight:warmup=1.5",
            "foresight:warmup=-0.1",
            "foresight:r=0",
            "static:r=0",
            "delta-dit:k=0",
            "delta-dit:range=0",
            "tgate:k=0",
            "tgate:m=0",
            "pab:alpha=0",
            "pab:lo=0.9,hi=0.1",
            "pab:hi=1.5",
        ] {
            let r = build_policy(spec, &m, 30);
            assert!(r.is_err(), "{spec} should be rejected");
        }
    }

    #[test]
    fn parses_forecast_wrapper_specs() {
        let m = model();
        // inner= swallows the remainder: embedded ':' and ',' intact
        let p = build_policy("forecast:k=2,inner=foresight:n=1,r=2,gamma=0.5", &m, 30).unwrap();
        assert!(p.name().contains("forecast(k=2"));
        assert!(p.name().contains("N1R2"));
        assert_eq!(p.history_depth(), 2);
        // bare inner spec without params
        let p = build_policy("forecast:k=3,inner=static", &m, 30).unwrap();
        assert_eq!(p.history_depth(), 3);
        // k defaults to 2
        let p = build_policy("forecast:inner=static:n=1,r=2", &m, 30).unwrap();
        assert_eq!(p.history_depth(), 2);
        // k=1 degenerates to depth 1 (verbatim replay)
        let p = build_policy("forecast:k=1,inner=foresight", &m, 30).unwrap();
        assert_eq!(p.history_depth(), 1);
    }

    #[test]
    fn forecast_wrapper_rejects_bad_specs() {
        let m = model();
        for spec in [
            "forecast",                        // no inner
            "forecast:k=2",                    // no inner
            "forecast:k=0,inner=static",       // order out of range
            "forecast:k=9,inner=static",       // order out of range
            "forecast:k=abc,inner=static",     // malformed order
            "forecast:q=2,inner=static",       // unknown key
            "forecast:k=2,inner=pab",          // fine/delta inner
            "forecast:k=2,inner=warp-drive",   // unknown inner
        ] {
            assert!(build_policy(spec, &m, 30).is_err(), "{spec} should be rejected");
        }
        let err = build_policy("forecast:k=2", &m, 30).unwrap_err().to_string();
        assert!(err.contains("inner="), "{err}");
    }

    #[test]
    fn sites_enumeration_counts() {
        assert_eq!(sites_for(6, Granularity::Coarse, 0).len(), 12);
        assert_eq!(sites_for(6, Granularity::Fine, 1).len(), 36);
        assert!(sites_for(6, Granularity::Fine, 1).iter().all(|s| s.branch == 1));
    }
}
