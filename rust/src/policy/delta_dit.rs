//! Δ-DiT baseline (Chen et al. 2024; paper Appendix A.6 Table 5).
//!
//! Caches feature-map *deviations* (residual deltas) rather than full
//! outputs, and applies reuse to different depth regions per generation
//! stage: **back** blocks during the early outline stage (the first `b`
//! steps) and **front** blocks during the late detail-refinement stage.
//! Within the active region, deltas refresh every `k` steps and are reused
//! in between.

use anyhow::{anyhow, Result};

use super::{Action, CacheMode, Granularity, ReusePolicy, Site};

pub struct DeltaDit {
    /// Cache interval k (Table 5: 2).
    pub k: usize,
    /// Gate step b separating outline and detail stages.
    pub b: usize,
    /// Number of layers in the reused region.
    pub range: usize,
    layers: usize,
}

impl DeltaDit {
    /// Validated constructor (wire-reachable via [`super::build_policy`]).
    pub fn new(k: usize, b: usize, range: usize) -> Result<Self> {
        if k < 1 {
            return Err(anyhow!("delta-dit: cache interval k must be >= 1, got {k}"));
        }
        if range < 1 {
            return Err(anyhow!("delta-dit: block range must be >= 1, got {range}"));
        }
        Ok(Self { k, b, range, layers: 0 })
    }

    fn in_region(&self, step: usize, layer: usize) -> bool {
        if step < self.b {
            // outline stage: back blocks
            layer >= self.layers.saturating_sub(self.range)
        } else {
            // detail stage: front blocks
            layer < self.range
        }
    }
}

impl ReusePolicy for DeltaDit {
    fn name(&self) -> String {
        format!("delta-dit(k={},b={},range={})", self.k, self.b, self.range)
    }

    fn granularity(&self) -> Granularity {
        Granularity::Coarse
    }

    fn cache_mode(&self) -> CacheMode {
        CacheMode::Delta
    }

    fn begin_request(&mut self, layers: usize, _steps: usize) {
        self.layers = layers;
    }

    fn action(&mut self, step: usize, site: Site) -> Action {
        if !self.in_region(step, site.layer) {
            return Action::Compute { update_cache: false, measure: false };
        }
        // Refresh the delta on the first region step and every k-th after;
        // reset the phase at the stage boundary so the detail stage starts
        // with a fresh delta for its (different) region.
        let phase = if step < self.b { step } else { step - self.b };
        if phase % self.k == 0 {
            Action::Compute { update_cache: true, measure: false }
        } else {
            Action::ReuseResidual
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::Unit;
    use crate::model::BlockKind;

    fn site(layer: usize) -> Site {
        Site { layer, kind: BlockKind::Spatial, unit: Unit::Block, branch: 0 }
    }

    #[test]
    fn outline_stage_reuses_back_blocks_only() {
        let mut p = DeltaDit::new(2, 25, 2).unwrap();
        p.begin_request(8, 30);
        // step 1 (odd → reuse-eligible), outline stage
        assert!(!p.action(1, site(0)).is_reuse(), "front must compute in outline");
        assert!(p.action(1, site(7)).is_reuse(), "back must reuse in outline");
        assert!(p.action(1, site(6)).is_reuse());
        assert!(!p.action(1, site(5)).is_reuse(), "outside range");
    }

    #[test]
    fn detail_stage_flips_to_front_blocks() {
        let mut p = DeltaDit::new(2, 25, 2).unwrap();
        p.begin_request(8, 30);
        // step 26: detail stage, phase = 1 → reuse-eligible
        assert!(p.action(26, site(0)).is_reuse());
        assert!(p.action(26, site(1)).is_reuse());
        assert!(!p.action(26, site(2)).is_reuse());
        assert!(!p.action(26, site(7)).is_reuse(), "back computes in detail stage");
    }

    #[test]
    fn refresh_every_k_steps() {
        let mut p = DeltaDit::new(2, 25, 1).unwrap();
        p.begin_request(4, 30);
        for step in 0..24 {
            let a = p.action(step, site(3));
            assert_eq!(a.is_reuse(), step % 2 == 1, "step {step}");
            if !a.is_reuse() {
                assert_eq!(a, Action::Compute { update_cache: true, measure: false });
            } else {
                assert_eq!(a, Action::ReuseResidual, "delta mode uses residual reuse");
            }
        }
    }

    #[test]
    fn stage_boundary_resets_refresh_phase() {
        let mut p = DeltaDit::new(2, 25, 1).unwrap();
        p.begin_request(4, 30);
        // first detail-stage step must refresh the (new) front-region delta
        assert_eq!(
            p.action(25, site(0)),
            Action::Compute { update_cache: true, measure: false }
        );
        assert!(p.action(26, site(0)).is_reuse());
    }
}
