//! PAB — Pyramid Attention Broadcast baseline (Zhao et al. 2024b; paper
//! Appendix A.6 Table 7).
//!
//! Within a broadcast step range, attention outputs are "broadcast" (their
//! residual contributions cached and re-applied) at hierarchical rates:
//! spatial attention every α steps (top of the pyramid, most reusable in
//! PAB's design at rate α=2), temporal every β=4, cross every γ=6. A subset
//! of front blocks additionally broadcasts its MLP output on its own
//! schedule. Outside the range everything computes. This is the paper's
//! strongest static baseline and is *fine-grained*: it caches up to 6
//! sublayer entries per layer pair (6LHWF) vs Foresight's 2 (2LHWF) —
//! reproducing the 3× memory-overhead comparison of §4.2.

use anyhow::{anyhow, Result};

use super::{Action, CacheMode, Granularity, ReusePolicy, Site};
use crate::cache::Unit;
use crate::model::{BlockKind, SubUnit};

pub struct Pab {
    pub alpha: usize,   // spatial attention broadcast rate
    pub beta: usize,    // temporal attention broadcast rate
    pub gamma_c: usize, // cross attention broadcast rate
    lo: usize,          // broadcast range start step (inclusive)
    hi: usize,          // broadcast range end step (exclusive)
    lo_frac: f64,
    hi_frac: f64,
    pub mlp_blocks: Vec<usize>,
    pub mlp_interval: usize,
}

impl Pab {
    /// Validated constructor (wire-reachable via [`super::build_policy`]).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        alpha: usize,
        beta: usize,
        gamma_c: usize,
        lo_frac: f64,
        hi_frac: f64,
        mlp_blocks: Vec<usize>,
        mlp_interval: usize,
        steps: usize,
    ) -> Result<Self> {
        for (name, v) in [
            ("alpha", alpha),
            ("beta", beta),
            ("gamma", gamma_c),
            ("mlp_interval", mlp_interval),
        ] {
            if v < 1 {
                return Err(anyhow!("pab: broadcast rate {name} must be >= 1, got {v}"));
            }
        }
        if !(lo_frac.is_finite() && hi_frac.is_finite()) {
            return Err(anyhow!("pab: broadcast range must be finite"));
        }
        if !((0.0..=1.0).contains(&lo_frac) && lo_frac < hi_frac && hi_frac <= 1.0) {
            return Err(anyhow!(
                "pab: broadcast range must satisfy 0 <= lo < hi <= 1, got lo={lo_frac} hi={hi_frac}"
            ));
        }
        let lo = (steps as f64 * lo_frac).round() as usize;
        let hi = (steps as f64 * hi_frac).round() as usize;
        Ok(Self { alpha, beta, gamma_c, lo, hi, lo_frac, hi_frac, mlp_blocks, mlp_interval })
    }

    fn rate_for(&self, kind: BlockKind, sub: SubUnit) -> Option<usize> {
        match sub {
            SubUnit::Attn => Some(match kind {
                BlockKind::Spatial => self.alpha,
                BlockKind::Temporal => self.beta,
            }),
            SubUnit::Cross => Some(self.gamma_c),
            SubUnit::Mlp => None, // handled separately per block list
        }
    }
}

impl ReusePolicy for Pab {
    fn name(&self) -> String {
        format!(
            "pab(a{}b{}c{},range={:.0}%-{:.0}%)",
            self.alpha,
            self.beta,
            self.gamma_c,
            self.lo_frac * 100.0,
            self.hi_frac * 100.0
        )
    }

    fn granularity(&self) -> Granularity {
        Granularity::Fine
    }

    fn cache_mode(&self) -> CacheMode {
        CacheMode::Delta
    }

    fn begin_request(&mut self, _layers: usize, steps: usize) {
        self.lo = (steps as f64 * self.lo_frac).round() as usize;
        self.hi = (steps as f64 * self.hi_frac).round() as usize;
    }

    fn action(&mut self, step: usize, site: Site) -> Action {
        let Unit::Sub(sub) = site.unit else {
            return Action::Compute { update_cache: false, measure: false };
        };
        let in_range = step >= self.lo && step < self.hi;
        if !in_range {
            return Action::Compute { update_cache: false, measure: false };
        }
        let phase = step - self.lo;
        match sub {
            SubUnit::Mlp => {
                if self.mlp_blocks.contains(&site.layer) {
                    if phase % self.mlp_interval == 0 {
                        Action::Compute { update_cache: true, measure: false }
                    } else {
                        Action::ReuseResidual
                    }
                } else {
                    Action::Compute { update_cache: false, measure: false }
                }
            }
            _ => {
                let rate = self.rate_for(site.kind, sub).unwrap();
                if phase % rate == 0 {
                    Action::Compute { update_cache: true, measure: false }
                } else {
                    Action::ReuseResidual
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pab(steps: usize) -> Pab {
        Pab::new(2, 4, 6, 0.07, 0.55, vec![0, 1, 2, 3, 4], 2, steps).unwrap()
    }

    fn site(layer: usize, kind: BlockKind, sub: SubUnit) -> Site {
        Site { layer, kind, unit: Unit::Sub(sub), branch: 0 }
    }

    #[test]
    fn pyramid_rates_inside_range() {
        let mut p = pab(30);
        p.begin_request(6, 30);
        // range [2, 17) for 30 steps
        let lo = 2;
        let mut sa_reuse = 0;
        let mut ta_reuse = 0;
        let mut ca_reuse = 0;
        for step in lo..17 {
            if p.action(step, site(5, BlockKind::Spatial, SubUnit::Attn)).is_reuse() {
                sa_reuse += 1;
            }
            if p.action(step, site(5, BlockKind::Temporal, SubUnit::Attn)).is_reuse() {
                ta_reuse += 1;
            }
            if p.action(step, site(5, BlockKind::Spatial, SubUnit::Cross)).is_reuse() {
                ca_reuse += 1;
            }
        }
        // hierarchy: cross (rate 6) reuses most often, then temporal (4),
        // then spatial (2)
        assert!(ca_reuse > ta_reuse, "cross {ca_reuse} vs temporal {ta_reuse}");
        assert!(ta_reuse > sa_reuse, "temporal {ta_reuse} vs spatial {sa_reuse}");
        assert!(sa_reuse > 0);
    }

    #[test]
    fn everything_computes_outside_range() {
        let mut p = pab(30);
        p.begin_request(6, 30);
        for step in [0, 1, 17, 25, 29] {
            for kind in BlockKind::ALL {
                for sub in SubUnit::ALL {
                    assert!(
                        !p.action(step, site(0, kind, sub)).is_reuse(),
                        "step {step} {kind:?} {sub:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn mlp_broadcast_only_for_listed_blocks() {
        let mut p = pab(30);
        p.begin_request(6, 30);
        // step 3 → phase 1 → mlp reuse step for listed blocks
        assert!(p.action(3, site(0, BlockKind::Spatial, SubUnit::Mlp)).is_reuse());
        assert!(!p.action(3, site(5, BlockKind::Spatial, SubUnit::Mlp)).is_reuse());
    }

    #[test]
    fn range_rescales_with_steps() {
        let mut p = pab(30);
        p.begin_request(6, 60);
        // with 60 steps, range = [4, 33): step 20 is inside
        assert!(p.action(21, site(0, BlockKind::Spatial, SubUnit::Attn)).is_reuse());
        assert!(!p.action(40, site(0, BlockKind::Spatial, SubUnit::Attn)).is_reuse());
    }
}
