//! Static coarse-grained caching baseline (paper §3.2, Appendix A.6
//! Table 4): compute + cache all blocks every R-th step, reuse the cached
//! outputs verbatim for the N = R-1 steps in between, uniformly across all
//! layers — exactly the behaviour whose limitations §3.3 analyses.

use anyhow::{anyhow, Result};

use super::{Action, CacheMode, Granularity, ReusePolicy, Site};

pub struct StaticReuse {
    pub n: usize,
    pub r: usize,
}

impl StaticReuse {
    /// Validated constructor (wire-reachable via [`super::build_policy`]).
    pub fn new(n: usize, r: usize) -> Result<Self> {
        if r < 1 {
            return Err(anyhow!("static: compute interval r must be >= 1, got {r}"));
        }
        Ok(Self { n, r })
    }
}

impl ReusePolicy for StaticReuse {
    fn name(&self) -> String {
        format!("static(N{}R{})", self.n, self.r)
    }

    fn granularity(&self) -> Granularity {
        Granularity::Coarse
    }

    fn cache_mode(&self) -> CacheMode {
        CacheMode::Output
    }

    fn begin_request(&mut self, _layers: usize, _steps: usize) {}

    fn action(&mut self, step: usize, _site: Site) -> Action {
        if step % self.r == 0 {
            Action::Compute { update_cache: true, measure: false }
        } else {
            Action::Reuse
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::Unit;
    use crate::model::BlockKind;

    fn site() -> Site {
        Site { layer: 0, kind: BlockKind::Temporal, unit: Unit::Block, branch: 0 }
    }

    #[test]
    fn n1r2_alternates() {
        let mut p = StaticReuse::new(1, 2).unwrap();
        p.begin_request(4, 30);
        for step in 0..30 {
            let a = p.action(step, site());
            assert_eq!(a.is_reuse(), step % 2 == 1, "step {step}");
        }
    }

    #[test]
    fn n2r3_two_reuse_steps_per_cycle() {
        let mut p = StaticReuse::new(2, 3).unwrap();
        p.begin_request(4, 30);
        let reused = (0..30).filter(|&s| p.action(s, site()).is_reuse()).count();
        assert_eq!(reused, 20);
    }

    #[test]
    fn uniform_across_layers() {
        let mut p = StaticReuse::new(1, 2).unwrap();
        p.begin_request(8, 30);
        for step in 0..30 {
            let mut actions = vec![];
            for l in 0..8 {
                actions.push(p.action(step, Site { layer: l, ..site() }).is_reuse());
            }
            assert!(actions.windows(2).all(|w| w[0] == w[1]), "non-uniform at {step}");
        }
    }
}
