//! No-reuse baseline: every block computes at every step. All quality
//! metrics in the paper (PSNR/SSIM/LPIPS/FVD) are measured relative to this
//! policy's output.

use super::{Action, CacheMode, Granularity, ReusePolicy, Site};

#[derive(Default)]
pub struct NoReuse;

impl NoReuse {
    pub fn new() -> Self {
        Self
    }
}

impl ReusePolicy for NoReuse {
    fn name(&self) -> String {
        "baseline".to_string()
    }

    fn granularity(&self) -> Granularity {
        Granularity::Coarse
    }

    fn cache_mode(&self) -> CacheMode {
        CacheMode::Output
    }

    fn begin_request(&mut self, _layers: usize, _steps: usize) {}

    fn action(&mut self, _step: usize, _site: Site) -> Action {
        Action::Compute { update_cache: false, measure: false }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::Unit;
    use crate::model::BlockKind;

    #[test]
    fn never_reuses_never_caches() {
        let mut p = NoReuse::new();
        p.begin_request(28, 50);
        for step in 0..50 {
            let a = p.action(
                step,
                Site { layer: step % 28, kind: BlockKind::Spatial, unit: Unit::Block, branch: 0 },
            );
            assert_eq!(a, Action::Compute { update_cache: false, measure: false });
        }
    }
}
