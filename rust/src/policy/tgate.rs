//! T-GATE baseline (Liu et al. 2024b; paper Appendix A.6 Table 6).
//!
//! Splits denoising into a **semantics-planning** phase (steps < m) and a
//! **fidelity-improvement** phase (steps ≥ m):
//!
//! * phase 1 — cross-attention stays live (and keeps its cache fresh so the
//!   gate step has up-to-date features); self/temporal attention computes
//!   every k-th step after a one-step warmup and reuses its cached delta
//!   otherwise;
//! * phase 2 — cross-attention is replaced by its cached features entirely
//!   (text conditioning is "gated off"), while self-attention computes.
//!
//! MLP sublayers always compute (T-GATE only touches attention).

use anyhow::{anyhow, Result};

use super::{Action, CacheMode, Granularity, ReusePolicy, Site};
use crate::cache::Unit;
use crate::model::SubUnit;

pub struct TGate {
    /// Self-attention cache interval k (Table 6: 2).
    pub k: usize,
    /// Gate step m between the two phases (Table 6: 12/30 or 20/50).
    pub m: usize,
}

impl TGate {
    /// Validated constructor (wire-reachable via [`super::build_policy`]).
    pub fn new(k: usize, m: usize) -> Result<Self> {
        if k < 1 {
            return Err(anyhow!("tgate: cache interval k must be >= 1, got {k}"));
        }
        if m < 1 {
            return Err(anyhow!("tgate: gate step m must be >= 1, got {m}"));
        }
        Ok(Self { k, m })
    }
}

impl ReusePolicy for TGate {
    fn name(&self) -> String {
        format!("tgate(k={},m={})", self.k, self.m)
    }

    fn granularity(&self) -> Granularity {
        Granularity::Fine
    }

    fn cache_mode(&self) -> CacheMode {
        CacheMode::Delta
    }

    fn begin_request(&mut self, _layers: usize, _steps: usize) {}

    fn action(&mut self, step: usize, site: Site) -> Action {
        let Unit::Sub(sub) = site.unit else {
            // engine always drives fine policies at sub granularity
            return Action::Compute { update_cache: false, measure: false };
        };
        match sub {
            SubUnit::Mlp => Action::Compute { update_cache: false, measure: false },
            SubUnit::Attn => {
                if step >= self.m {
                    // fidelity phase: SA continues
                    Action::Compute { update_cache: false, measure: false }
                } else if step == 0 || step % self.k == 0 {
                    Action::Compute { update_cache: true, measure: false }
                } else {
                    Action::ReuseResidual
                }
            }
            SubUnit::Cross => {
                if step < self.m {
                    // keep the CA cache fresh for the gate
                    Action::Compute { update_cache: true, measure: false }
                } else {
                    Action::ReuseResidual
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::BlockKind;

    fn site(sub: SubUnit) -> Site {
        Site { layer: 1, kind: BlockKind::Spatial, unit: Unit::Sub(sub), branch: 0 }
    }

    #[test]
    fn phase1_sa_broadcast_ca_live() {
        let mut p = TGate::new(2, 12).unwrap();
        p.begin_request(6, 30);
        for step in 0..12 {
            let sa = p.action(step, site(SubUnit::Attn));
            assert_eq!(sa.is_reuse(), step % 2 == 1, "SA step {step}");
            let ca = p.action(step, site(SubUnit::Cross));
            assert!(!ca.is_reuse(), "CA computes in phase 1");
            assert_eq!(ca, Action::Compute { update_cache: true, measure: false });
        }
    }

    #[test]
    fn phase2_ca_gated_sa_live() {
        let mut p = TGate::new(2, 12).unwrap();
        p.begin_request(6, 30);
        for step in 12..30 {
            assert!(!p.action(step, site(SubUnit::Attn)).is_reuse(), "SA step {step}");
            assert_eq!(p.action(step, site(SubUnit::Cross)), Action::ReuseResidual);
        }
    }

    #[test]
    fn mlp_always_computes() {
        let mut p = TGate::new(2, 12).unwrap();
        p.begin_request(6, 30);
        for step in 0..30 {
            assert!(!p.action(step, site(SubUnit::Mlp)).is_reuse());
        }
    }
}
